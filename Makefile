# Executable verify recipes (ISSUE 1 satellite). The tier-1 command is
# the ROADMAP's; test-dist proves the distributed MapReduce-SVM path on
# 8 faked host devices (the flag must be set before jax's backend init,
# hence a fresh process).

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: test test-dist test-dist-mp test-fast check

# Tier-1: the ROADMAP verify command.
test:
	$(PY) -m pytest -x -q

# Distributed: sharded MapReduce round ≡ functional round on 8 devices.
test-dist:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	$(PY) -m pytest -q tests/test_sharded_round.py tests/test_mapreduce.py

# Multi-process: 2 real jax.distributed CPU processes (localhost
# coordinator + gloo collectives), per-host loaders, both shuffles ≡
# the functional reference, PLUS the kill-a-worker leg (ISSUE 7):
# SIGKILL one process mid-wave, restart from the durable round-state
# checkpoint, resumed run ≡ uninterrupted run bit-for-bit. The tests
# spawn their own processes, so no XLA flags are needed here
# (ISSUE 5 / DESIGN.md §11, §13).
test-dist-mp:
	$(PY) -m pytest -q tests/test_multihost.py

# Quick signal while iterating (skips the slow dry-run subprocess tests).
test-fast:
	$(PY) -m pytest -x -q -m "not slow"

check: test test-dist
