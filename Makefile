# Executable verify recipes (ISSUE 1 satellite). The tier-1 command is
# the ROADMAP's; test-dist proves the distributed MapReduce-SVM path on
# 8 faked host devices (the flag must be set before jax's backend init,
# hence a fresh process).

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: test test-dist test-dist-mp test-chaos test-fast lint lint-jax lint-artifacts check

# Tier-1: the ROADMAP verify command.
test:
	$(PY) -m pytest -x -q

# Distributed: sharded MapReduce round ≡ functional round on 8 devices.
test-dist:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	$(PY) -m pytest -q tests/test_sharded_round.py tests/test_mapreduce.py

# Multi-process: 2 real jax.distributed CPU processes (localhost
# coordinator + gloo collectives), per-host loaders, both shuffles ≡
# the functional reference, PLUS the kill-a-worker leg (ISSUE 7):
# SIGKILL one process mid-wave, restart from the durable round-state
# checkpoint, resumed run ≡ uninterrupted run bit-for-bit. The tests
# spawn their own processes, so no XLA flags are needed here
# (ISSUE 5 / DESIGN.md §11, §13).
test-dist-mp:
	$(PY) -m pytest -q tests/test_multihost.py

# Chaos (ISSUE 9 / DESIGN.md §15): the deterministic fault-injection
# sweep — every armed plan must be SURVIVED (bit-for-bit vs the
# fault-free run) or DETECTED (typed FaultDetected naming layer,
# cause, operator action); never a hang, never a silent wrong answer.
# Then the 2-process leg: SIGKILL a peer (stranded survivor exits
# typed via the collective watchdog, code 17), corrupt the newest
# snapshot generation, restart through a flaky handshake and converge
# from the previous intact generation.
test-chaos:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	$(PY) -m repro.faults.chaos --seeds 0,1,2
	$(PY) -m pytest -q tests/test_multihost.py -k chaos

# Quick signal while iterating (skips the slow dry-run subprocess tests).
test-fast:
	$(PY) -m pytest -x -q -m "not slow"

# Static python lint (ruff, config in pyproject.toml). Degrades to a
# notice when ruff isn't on PATH — the container bakes in only the jax
# toolchain; CI installs ruff via requirements-dev.txt. Format check is
# advisory (`|| true`): the enforced families are E9/F only.
lint:
	@if command -v ruff >/dev/null 2>&1; then \
		ruff check src tests benchmarks && \
		ruff format --check src/repro/analysis || true; \
	else \
		echo "lint: ruff not installed; skipping (pip install -r requirements-dev.txt)"; \
	fi

# jaxpr/HLO invariant linter (ISSUE 8, DESIGN.md §14): the full rule
# matrix over the real round/sweep/serve step builders (both shuffle
# transports, dense + sparse rows) plus the seeded-violation self-test
# proving each rule still fires and names the offending op/program.
lint-jax:
	$(PY) -m repro.analysis.lint
	$(PY) -m repro.analysis.lint --self-test

# Collective-schedule gate over the committed dry-run artifacts: a
# fresh compile of each recorded program must reproduce the recorded
# per-kind collective counts, so stale artifacts fail loudly.
lint-artifacts:
	$(PY) -m repro.analysis.lint --artifacts benchmarks/artifacts

check: lint test test-dist
