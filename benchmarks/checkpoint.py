"""Checkpoint overhead of the fault-tolerant serving path (ISSUE 7).

The streaming service snapshots every tenant's ModelSnapshot to
flat-npz after each wave (svm_stream.checkpoint). That durability is
only free if save + restore wall time is small next to the fold wave
it shadows — this bench measures all three on the same S-tenant
service and reports the ckpt/fold ratio.

Standalone (forces 8 host devices, writes BENCH_checkpoint.json):

    PYTHONPATH=src python -m benchmarks.checkpoint
"""
from __future__ import annotations

import tempfile
import time
from typing import List

S_STREAMS = 4
NUM_FEATURES = 128
BATCH_ROWS = 512
PARTITIONS = 8
SV_CAP = 128

from benchmarks.sweep import _problem  # shared synthetic problem


def checkpoint_bench(S: int = S_STREAMS, d: int = NUM_FEATURES,
                     L: int = PARTITIONS) -> List[str]:
    import jax
    from repro.core import MRSVMConfig, SVMConfig, fit_mapreduce
    from repro.serving import StreamingSVMService

    cfg = MRSVMConfig(sv_capacity=SV_CAP, gamma=0.0, max_rounds=3,
                      svm=SVMConfig(C=1.0, max_epochs=10))
    with tempfile.TemporaryDirectory() as ckpt_dir:
        svc = StreamingSVMService(cfg, num_partitions=L,
                                  max_batches_per_wave=1)
        for s in range(S):
            Xh, yh = _problem(2048, d, seed=10 + s)
            svc.register(f"t{s}", fit_mapreduce(Xh, yh, L, cfg))

        def fold_wave():
            for s in range(S):
                Xn, yn = _problem(BATCH_ROWS, d, seed=100 + s)
                svc.submit(f"t{s}", Xn, yn)
            svc.run_wave()
            jax.block_until_ready(svc.snapshot("t0").model.sv.x)

        fold_wave()                                # warm the batched jit
        t0 = time.time()
        fold_wave()
        t_fold = time.time() - t0

        svc.checkpoint_dir = ckpt_dir              # save outside the wave
        svc.checkpoint()                           # warm (mkdir, tracing)
        t0 = time.time()
        svc.checkpoint()
        t_save = time.time() - t0

        StreamingSVMService.restore(cfg, ckpt_dir)     # warm
        t0 = time.time()
        svc2 = StreamingSVMService.restore(cfg, ckpt_dir)
        t_restore = time.time() - t0
        assert sorted(svc2.streams()) == sorted(svc.streams())

    frac = (t_save + t_restore) / max(t_fold, 1e-9)
    return [
        f"ckpt_save_wave,{t_save * 1e6:.0f},streams={S} cap={SV_CAP}",
        f"ckpt_restore_service,{t_restore * 1e6:.0f},streams={S}",
        f"ckpt_fold_wave,{t_fold * 1e6:.0f},streams={S} L={L}",
        f"ckpt_over_fold,0,frac={frac:.3f} (save+restore / fold wave)",
    ]


if __name__ == "__main__":
    import os
    os.environ.setdefault(
        "XLA_FLAGS",
        (os.environ.get("XLA_FLAGS", "")
         + " --xla_force_host_platform_device_count=8").strip())
    from benchmarks.run import write_bench_json
    lines = list(checkpoint_bench())
    print("name,us_per_call,derived")
    for line in lines:
        print(line)
    write_bench_json("checkpoint", lines)
