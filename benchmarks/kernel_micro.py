"""Pallas-kernel microbenchmarks (interpret mode on CPU: correctness +
call overhead; real speed is a TPU property — see §Roofline)."""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention, gram_matrix, risk_eval
from repro.kernels import ref


def _time(fn, *args, reps=3):
    fn(*args)                     # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6


def kernel_micro() -> List[str]:
    out = []
    key = jax.random.PRNGKey(0)
    X = jax.random.normal(key, (512, 256))
    Z = jax.random.normal(jax.random.PRNGKey(1), (512, 256))
    us_pal = _time(lambda a, b: gram_matrix(a, b, bm=128, bn=128, bk=128),
                   X, Z)
    us_ref = _time(jax.jit(lambda a, b: ref.gram_ref(a, b)), X, Z)
    err = float(jnp.max(jnp.abs(
        gram_matrix(X, Z, bm=128, bn=128, bk=128) - ref.gram_ref(X, Z))))
    out.append(f"kernel_gram_512x512x256,{us_pal:.0f},"
               f"ref_us={us_ref:.0f} maxerr={err:.2e}")

    W = jax.random.normal(jax.random.PRNGKey(2), (16, 256))
    b = jnp.zeros((16,))
    y = jnp.sign(jax.random.normal(jax.random.PRNGKey(3), (512,)))
    m = jnp.ones((512,))
    us_pal = _time(lambda: risk_eval(X, W, b, y, m, bn=128))
    l, _ = risk_eval(X, W, b, y, m, bn=128)
    lr, _ = ref.hinge_scores_ref(X, W, b, y, m)
    out.append(f"kernel_hinge_512x16,{us_pal:.0f},"
               f"maxerr={float(jnp.max(jnp.abs(l - lr))):.2e}")

    q = jax.random.normal(jax.random.PRNGKey(4), (4, 16, 64))
    k = jax.random.normal(jax.random.PRNGKey(5), (4, 4, 1024, 64))
    v = jax.random.normal(jax.random.PRNGKey(6), (4, 4, 1024, 64))
    vlen = jnp.asarray(1000)
    us_pal = _time(lambda: decode_attention(q, k, v, vlen, bs=256))
    errd = float(jnp.max(jnp.abs(
        decode_attention(q, k, v, vlen, bs=256) -
        ref.decode_attention_ref(q, k, v, vlen))))
    out.append(f"kernel_flashdecode_b4h16s1024,{us_pal:.0f},maxerr={errd:.2e}")
    return out
