"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from artifacts."""
from __future__ import annotations

import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "artifacts")
ART_OPT = os.path.join(os.path.dirname(__file__), "artifacts_optimized")
SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
               "long_500k": 3, "svm": 4, "None": 4}


def load(mesh=None, rules="baseline", art_dir=None):
    recs = []
    for f in glob.glob(os.path.join(art_dir or ART, "dryrun_*.json")):
        r = json.load(open(f))
        if mesh and r["mesh"] != mesh:
            continue
        if rules and r.get("rules") != rules:
            continue
        recs.append(r)
    recs.sort(key=lambda r: (r["arch"], SHAPE_ORDER.get(str(r.get("shape")), 9)))
    return recs


def fmt(x, digits=3):
    if x == 0:
        return "0"
    if abs(x) >= 0.01:
        return f"{x:.{digits}f}"
    return f"{x:.2e}"


def dryrun_table(mesh="16x16", art_dir=None) -> str:
    lines = [
        f"| arch | shape | status | compile_s | HLO flops/dev | "
        f"coll bytes/dev | args+temp GB/dev |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in load(mesh, art_dir=art_dir):
        if r["status"] == "skip":
            reason = r["reason"].split("—")[0].replace("SKIP: ", "")
            lines.append(f"| {r['arch']} | {r.get('shape')} | "
                         f"SKIP ({reason.strip()[:48]}) | | | | |")
            continue
        gb = (r.get("argument_size_in_bytes", 0) +
              r.get("temp_size_in_bytes", 0)) / 1e9
        lines.append(
            f"| {r['arch']} | {r.get('shape')} | ok | {r['compile_s']} | "
            f"{r['xla_per_device_flops']:.3g} | "
            f"{r['collective_bytes_per_device']:.3g} | {gb:.1f} |")
    return "\n".join(lines)


def roofline_table(mesh="16x16", rules="baseline", art_dir=None) -> str:
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | dominant | "
        "MODEL/HLO flops | one-line lever |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in load(mesh, rules, art_dir=art_dir):
        if r["status"] != "ok" or r["arch"] == "svm_tfidf":
            if r["status"] == "skip":
                lines.append(f"| {r['arch']} | {r.get('shape')} | — | — | — | "
                             f"SKIP | — | {r['reason'].split('—')[0][6:60]} |")
                continue
        t = r.get("roofline")
        if not t:
            continue
        lever = _lever(r)
        uf = r.get("useful_flops_ratio")
        lines.append(
            f"| {r['arch']} | {r.get('shape')} | {fmt(t['compute_s'])} | "
            f"{fmt(t['memory_s'])} | {fmt(t['collective_s'])} | "
            f"{r['dominant'][:-2]} | {f'{uf:.2f}' if uf else '—'} | {lever} |")
    return "\n".join(lines)


def _lever(r) -> str:
    dom = r["dominant"]
    coll = r.get("collectives", {})
    if dom == "collective_s":
        top = max(coll.items(), key=lambda kv: kv[1]["operand_bytes"])[0] \
            if coll else "?"
        if r["arch"].startswith("qwen3") or r["arch"].startswith("mixtral"):
            return (f"{top} dominates: shard MoE dispatch so token scatter "
                    "stays device-local (expert-major layout)")
        return (f"{top} dominates: sequence-parallel the activations "
                "(reduce-scatter+all-gather replaces all-reduce)")
    if dom == "memory_s":
        return "stream weights/cache in bf16; fuse score+hinge (Pallas)"
    return "compute-bound: near roofline; overlap collectives with compute"


if __name__ == "__main__":
    import sys
    art = ART_OPT if "--optimized" in sys.argv else None
    print("## Single-pod (16x16)\n")
    print(roofline_table("16x16", art_dir=art))
    print("\n## Multi-pod (2x16x16)\n")
    print(roofline_table("2x16x16", art_dir=art))
