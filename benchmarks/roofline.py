"""Aggregate dry-run artifacts into the §Roofline table.

Reads benchmarks/artifacts/dryrun_*.json (produced by
``python -m repro.launch.dryrun``) and emits one row per
(arch × shape × mesh × rules): the three terms, the dominant
bottleneck, MODEL_FLOPS ratio, and fit status.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

# prefer the post-hillclimb sweep (live framework state); the
# pre-hillclimb baseline artifacts remain in artifacts/ for §Perf diffs
_OPT = os.path.join(os.path.dirname(__file__), "artifacts_optimized")
_BASE = os.path.join(os.path.dirname(__file__), "artifacts")
ARTIFACTS = _OPT if os.path.isdir(_OPT) and os.listdir(_OPT) else _BASE
HBM_PER_CHIP = 16e9     # v5e


def load_records(pattern: str = "dryrun_*.json") -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(ARTIFACTS, pattern))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def roofline_rows(rules: str = None) -> List[str]:
    out = []
    for r in load_records():
        if rules and r.get("rules") != rules:
            continue
        tag = f"{r['arch']}|{r.get('shape')}|{r['mesh']}|{r.get('rules')}"
        if r["status"] == "skip":
            out.append(f"roofline_{tag},0,SKIP")
            continue
        if r["status"] != "ok":
            out.append(f"roofline_{tag},0,ERROR:{r.get('error', '')[:80]}")
            continue
        t = r["roofline"]
        arg = r.get("argument_size_in_bytes", 0)
        tmp = r.get("temp_size_in_bytes", 0)
        fits = (arg + tmp) <= HBM_PER_CHIP
        ratio = r.get("useful_flops_ratio")
        useful = f"useful={ratio:.2f} " if ratio else ""
        out.append(
            f"roofline_{tag},{t['compute_s'] * 1e6:.1f},"
            f"mem_s={t['memory_s']:.4g} coll_s={t['collective_s']:.4g} "
            f"dom={r['dominant'].replace('_s', '')} {useful}"
            f"hbm_args+temp={(arg + tmp) / 1e9:.1f}GB fits={fits}")
    return out


def summarize(rules: str = "baseline") -> List[str]:
    recs = [r for r in load_records() if r.get("rules") == rules]
    ok = [r for r in recs if r["status"] == "ok"]
    skip = [r for r in recs if r["status"] == "skip"]
    err = [r for r in recs if r["status"] == "error"]
    doms = {}
    for r in ok:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    return [f"roofline_summary,{len(recs)},"
            f"ok={len(ok)} skip={len(skip)} error={len(err)} dominants={doms}"]
