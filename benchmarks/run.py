"""Benchmark harness — one function per paper table/figure plus the
hardware benches. Prints ``name,us_per_call,derived`` CSV lines and
writes each bench's rows as a machine-readable
``benchmarks/artifacts/BENCH_<name>.json`` (uploaded from CI so the
perf trajectory is tracked across PRs).

    PYTHONPATH=src python -m benchmarks.run [--only sweep,streaming]

CI perf gate (ISSUE 4 satellite)::

    python -m benchmarks.run --only sweep,streaming,shuffle_overlap \
        --artifacts /tmp/bench-fresh --check-regression

runs the selected benches into a FRESH artifact dir and compares them
against the committed ``benchmarks/artifacts/`` baselines, failing on a
>25% slowdown of any tracked metric. Tracked metrics are the
machine-relative ``x=<speedup>`` ratios embedded in ``derived`` —
absolute microseconds vary wildly across runners, ratios don't; pass
``--abs`` to additionally gate raw ``us_per_call`` rows (same-machine
comparisons only).
"""
from __future__ import annotations

import argparse
import json
import os
import re
import sys
import time
import traceback

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "artifacts")

_RATIO_RE = re.compile(r"(?:^|\s)x=([0-9.]+)")


def parse_rows(lines) -> list:
    """``name,us_per_call,derived`` CSV lines → record dicts."""
    rows = []
    for line in lines:
        name, us, derived = line.split(",", 2)
        try:
            us_val = float(us)
        except ValueError:
            us_val = None
        rows.append({"name": name, "us_per_call": us_val,
                     "derived": derived})
    return rows


def write_bench_json(bench: str, lines, out_dir: str = None,
                     status: str = "ok") -> str:
    """Persist one bench's rows as BENCH_<bench>.json (CI artifact)."""
    out_dir = out_dir or ARTIFACT_DIR
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{bench}.json")
    with open(path, "w") as f:
        json.dump({"bench": bench, "status": status,
                   "generated_unix": int(time.time()),
                   "rows": parse_rows(lines)}, f, indent=2)
    return path


# ---------------------------------------------------------------------------
# Regression gate against committed baselines.
# ---------------------------------------------------------------------------

def _tracked_metrics(record: dict, with_abs: bool) -> dict:
    """name → (kind, value) for every gated metric of one BENCH json.

    ``ratio`` metrics are the ``x=<float>`` speedups parsed from
    ``derived`` (higher is better); ``us`` metrics are positive
    ``us_per_call`` timings (lower is better, only with ``--abs``).
    """
    metrics = {}
    for row in record.get("rows", []):
        m = _RATIO_RE.search(row.get("derived") or "")
        if m:
            metrics[row["name"]] = ("ratio", float(m.group(1)))
        elif with_abs and (row.get("us_per_call") or 0) > 0:
            metrics[row["name"]] = ("us", float(row["us_per_call"]))
    return metrics


def check_regressions(fresh_dir: str, baseline_dir: str,
                      threshold: float = 0.25,
                      with_abs: bool = False) -> int:
    """Compare fresh BENCH_*.json against committed baselines.

    Returns the number of regressions (>threshold slowdown of a
    tracked metric). Benches present on only one side are reported but
    don't fail — new benches gain a baseline when their json is
    committed.
    """
    import glob
    failures = 0
    fresh_files = sorted(glob.glob(os.path.join(fresh_dir, "BENCH_*.json")))
    if not fresh_files:
        print(f"[perf-gate] no fresh BENCH_*.json under {fresh_dir}")
        return 1
    for path in fresh_files:
        name = os.path.basename(path)
        base_path = os.path.join(baseline_dir, name)
        if not os.path.exists(base_path):
            print(f"[perf-gate] {name}: no committed baseline — skipped")
            continue
        with open(path) as f:
            fresh = json.load(f)
        with open(base_path) as f:
            base = json.load(f)
        if fresh.get("status") != "ok":
            print(f"[perf-gate] {name}: fresh run status="
                  f"{fresh.get('status')} — FAIL")
            failures += 1
            continue
        fm = _tracked_metrics(fresh, with_abs)
        bm = _tracked_metrics(base, with_abs)
        for metric, (kind, bval) in sorted(bm.items()):
            if metric not in fm or fm[metric][0] != kind:
                print(f"[perf-gate] {name}:{metric}: missing from fresh "
                      "run — FAIL")
                failures += 1
                continue
            fval = fm[metric][1]
            # slowdown fraction: ratios shrink, timings grow
            slow = (bval / max(fval, 1e-9) - 1.0) if kind == "ratio" \
                else (fval / max(bval, 1e-9) - 1.0)
            verdict = "FAIL" if slow > threshold else "ok"
            print(f"[perf-gate] {name}:{metric} [{kind}] baseline={bval:.2f} "
                  f"fresh={fval:.2f} slowdown={slow:+.0%} {verdict}")
            if slow > threshold:
                failures += 1
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated substring filters")
    ap.add_argument("--artifacts", default=ARTIFACT_DIR,
                    help="directory for BENCH_<name>.json records")
    ap.add_argument("--check-regression", action="store_true",
                    help="after running, gate fresh artifacts against "
                         "the committed --baseline-dir (fails on >"
                         "--threshold slowdown of any tracked metric)")
    ap.add_argument("--baseline-dir", default=ARTIFACT_DIR,
                    help="committed baseline BENCH_*.json directory")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="allowed fractional slowdown (default 0.25)")
    ap.add_argument("--abs", action="store_true",
                    help="also gate absolute us_per_call rows (only "
                         "meaningful comparing runs of one machine)")
    args = ap.parse_args()
    if args.check_regression and \
            os.path.abspath(args.artifacts) == os.path.abspath(
                args.baseline_dir):
        ap.error("--check-regression would overwrite its own baselines; "
                 "pass a fresh --artifacts dir")

    from benchmarks.tables import (table5_dataset, table6_confusion2,
                                   table7_rank2, table8_confusion3,
                                   table9_rank3)
    from benchmarks.scaling import scaling_partitions
    from benchmarks.kernel_micro import kernel_micro
    from benchmarks.roofline import roofline_rows, summarize
    from benchmarks.sweep import sweep_bench
    from benchmarks.streaming import streaming_bench
    from benchmarks.shuffle_overlap import shuffle_overlap_bench
    from benchmarks.sparse_gram import sparse_gram_bench
    from benchmarks.checkpoint import checkpoint_bench

    benches = [
        ("table5", table5_dataset),
        ("table6", table6_confusion2),
        ("table7", table7_rank2),
        ("table8", table8_confusion3),
        ("table9", table9_rank3),
        ("scaling", scaling_partitions),
        ("kernels", kernel_micro),
        ("roofline", roofline_rows),
        ("roofline_summary", summarize),
        ("sweep", sweep_bench),
        ("streaming", streaming_bench),
        ("shuffle_overlap", shuffle_overlap_bench),
        ("sparse_gram", sparse_gram_bench),
        ("checkpoint", checkpoint_bench),
    ]
    only = [s.strip() for s in args.only.split(",")] if args.only else None
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in benches:
        if only and not any(s in name for s in only):
            continue
        t0 = time.time()
        try:
            lines = []
            for line in fn():
                lines.append(line)
                print(line, flush=True)
            write_bench_json(name, lines, args.artifacts)
        except Exception as e:
            failures += 1
            print(f"{name},0,ERROR:{type(e).__name__}:{e}", flush=True)
            write_bench_json(name, [f"{name},0,ERROR:{type(e).__name__}"],
                             args.artifacts, status="error")
            traceback.print_exc(file=sys.stderr)
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
    if args.check_regression:
        failures += check_regressions(args.artifacts, args.baseline_dir,
                                      args.threshold, args.abs)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
