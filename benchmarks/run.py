"""Benchmark harness — one function per paper table/figure plus the
hardware benches. Prints ``name,us_per_call,derived`` CSV lines and
writes each bench's rows as a machine-readable
``benchmarks/artifacts/BENCH_<name>.json`` (uploaded from CI so the
perf trajectory is tracked across PRs).

    PYTHONPATH=src python -m benchmarks.run [--only tableX]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "artifacts")


def parse_rows(lines) -> list:
    """``name,us_per_call,derived`` CSV lines → record dicts."""
    rows = []
    for line in lines:
        name, us, derived = line.split(",", 2)
        try:
            us_val = float(us)
        except ValueError:
            us_val = None
        rows.append({"name": name, "us_per_call": us_val,
                     "derived": derived})
    return rows


def write_bench_json(bench: str, lines, out_dir: str = None,
                     status: str = "ok") -> str:
    """Persist one bench's rows as BENCH_<bench>.json (CI artifact)."""
    out_dir = out_dir or ARTIFACT_DIR
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{bench}.json")
    with open(path, "w") as f:
        json.dump({"bench": bench, "status": status,
                   "generated_unix": int(time.time()),
                   "rows": parse_rows(lines)}, f, indent=2)
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="substring filter")
    ap.add_argument("--artifacts", default=ARTIFACT_DIR,
                    help="directory for BENCH_<name>.json records")
    args = ap.parse_args()

    from benchmarks.tables import (table5_dataset, table6_confusion2,
                                   table7_rank2, table8_confusion3,
                                   table9_rank3)
    from benchmarks.scaling import scaling_partitions
    from benchmarks.kernel_micro import kernel_micro
    from benchmarks.roofline import roofline_rows, summarize
    from benchmarks.sweep import sweep_bench
    from benchmarks.streaming import streaming_bench

    benches = [
        ("table5", table5_dataset),
        ("table6", table6_confusion2),
        ("table7", table7_rank2),
        ("table8", table8_confusion3),
        ("table9", table9_rank3),
        ("scaling", scaling_partitions),
        ("kernels", kernel_micro),
        ("roofline", roofline_rows),
        ("roofline_summary", summarize),
        ("sweep", sweep_bench),
        ("streaming", streaming_bench),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in benches:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            lines = []
            for line in fn():
                lines.append(line)
                print(line, flush=True)
            write_bench_json(name, lines, args.artifacts)
        except Exception as e:
            failures += 1
            print(f"{name},0,ERROR:{type(e).__name__}:{e}", flush=True)
            write_bench_json(name, [f"{name},0,ERROR:{type(e).__name__}"],
                             args.artifacts, status="error")
            traceback.print_exc(file=sys.stderr)
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
