"""Benchmark harness — one function per paper table/figure plus the
hardware benches. Prints ``name,us_per_call,derived`` CSV lines.

    PYTHONPATH=src python -m benchmarks.run [--only tableX]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="substring filter")
    args = ap.parse_args()

    from benchmarks.tables import (table5_dataset, table6_confusion2,
                                   table7_rank2, table8_confusion3,
                                   table9_rank3)
    from benchmarks.scaling import scaling_partitions
    from benchmarks.kernel_micro import kernel_micro
    from benchmarks.roofline import roofline_rows, summarize
    from benchmarks.sweep import sweep_bench

    benches = [
        ("table5", table5_dataset),
        ("table6", table6_confusion2),
        ("table7", table7_rank2),
        ("table8", table8_confusion3),
        ("table9", table9_rank3),
        ("scaling", scaling_partitions),
        ("kernels", kernel_micro),
        ("roofline", roofline_rows),
        ("roofline_summary", summarize),
        ("sweep", sweep_bench),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in benches:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            for line in fn():
                print(line, flush=True)
        except Exception as e:
            failures += 1
            print(f"{name},0,ERROR:{type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
