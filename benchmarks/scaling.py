"""The paper's motivation benchmark: single-node O(m³) SVM vs the
MapReduce scheme as partition count L grows (Şekil 3 analogue).

Reports wall time per round and final empirical risk per L, plus the
undistributed baseline. On CPU the absolute numbers are illustrative;
the shape (time ↓ with L, risk ≈ flat) is the claim under test.
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp

from repro.core import (MRSVMConfig, SVMConfig, empirical_risk, fit_binary,
                        fit_mapreduce)
from repro.core.svm import decision_linear


def scaling_partitions(n: int = 4096, d: int = 256) -> List[str]:
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    X = jax.random.normal(k1, (n, d))
    w = jax.random.normal(k2, (d,))
    y = jnp.sign(X @ w + 0.05)
    out = []

    # single-node baseline (the paper's implicit comparison)
    t0 = time.time()
    single = fit_binary(X, y, cfg=SVMConfig(C=1.0, max_epochs=10))
    jax.block_until_ready(single.w)
    t_single = time.time() - t0
    r_single = float(empirical_risk(decision_linear(single.w, single.b, X), y))
    out.append(f"scaling_single_node,{t_single * 1e6:.0f},risk={r_single:.4f}")

    for L in (2, 4, 8, 16, 32):
        cap = 256
        cfg = MRSVMConfig(sv_capacity=cap, gamma=0.0, max_rounds=4,
                          svm=SVMConfig(C=1.0, max_epochs=10))
        t0 = time.time()
        model = fit_mapreduce(X, y, num_partitions=L, cfg=cfg)
        t = time.time() - t0
        # per-node workload fraction: dual-CD is O(epochs·rows·d); a node
        # sees n/L + cap rows instead of n — the paper's scalability claim.
        # (wall time on this 1-core host serializes the vmap; the fraction
        # is the hardware-independent statement.)
        frac = (n / L + cap) / n
        out.append(f"scaling_L{L},{t * 1e6 / cfg.max_rounds:.0f},"
                   f"risk={float(model.risk):.4f} rounds={model.rounds} "
                   f"per_node_workload={frac:.3f}x_of_single "
                   f"wall_speedup_1core={t_single / max(t / cfg.max_rounds, 1e-9):.2f}x")
    return out
