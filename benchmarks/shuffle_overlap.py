"""Ring-pipelined vs all-gather SV shuffle (ISSUE 4 tentpole).

The sharded MapReduce-SVM round's merge — SV^{t+1} = ∪_l SV_l — was a
blocking tiled ``all_gather`` of the full candidate buffer: every round
the reducers idle behind the ICI shuffle, and the sweep axis multiplies
the payload by S configs (the scaling bottleneck CloudSVM
arXiv:1301.0082 / binary MapReduce-SVM arXiv:1312.4108 identify).
``MRSVMConfig.shuffle_impl="ring"`` splits the merge into ring
``ppermute`` stages double-buffered against buffer assembly + eq. 7
scoring, ships feature rows as bf16, and dedups cross-config SV rows
(DESIGN.md §10). This bench measures both transports on the 8-device
host mesh:

* ``shuffle_single_*`` — one config per round, payload halved (bf16);
* ``shuffle_sweep_*``  — S=8 configs per round, dedup collapses the
  S× row traffic; the ≥1.3× round-throughput acceptance target lives
  here;
* ``shuffle_hlo_*``    — an HLO probe (reusing launch.hlo_analysis)
  verifying the ring actually lowered to collective-permutes whose
  start/done pairs bracket reducer compute (on backends that lower the
  permute synchronously — this container's CPU — the probe instead
  checks compute ops are scheduled between consecutive permutes, the
  order the TPU latency-hiding scheduler overlaps) and comparing wire
  bytes per round;
* ``shuffle_hier_*``   — the topology-aware two-level transport
  (ISSUE 10): classifies every collective-permute send in the compiled
  round by whether it crosses the simulated host boundary
  (``device // devices_per_host``) and gates the inter-host wire-byte
  ratio vs the flat ring. At H hosts × P devices the flat ring ships
  H·(P−1) inter-host sends per merge while hier's host-slice exchange
  ships (H−1)·P — the measured 8-device/2-host ratio is
  H(P−1)/((H−1)P) = 1.75×, asymptoting to H/(H−1) = 2× as P grows
  (DESIGN.md §16). Hier's intra-host legs lower to grouped all-gathers
  whose replica groups must stay within one host.

The bench asserts the ring round is NO SLOWER than the all-gather
round and that both converge to the same risks.

Standalone:

    PYTHONPATH=src python -m benchmarks.shuffle_overlap   # forces 8 devices
"""
from __future__ import annotations

import time
from typing import List

NDEV = 8
REPEATS = 10


def _bf16_exact(X):
    """Round to bf16-representable values so the ring's bf16 wire
    round-trip is lossless and equivalence checks stay strict."""
    import jax.numpy as jnp
    return X.astype(jnp.bfloat16).astype(jnp.float32)


def _problem(n, d, seed=0):
    import jax
    import jax.numpy as jnp
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    X = _bf16_exact(jax.random.normal(k1, (n, d)))
    w = jax.random.normal(k2, (d,))
    y = jnp.sign(X @ w + 0.05)
    return X, y


def _cfgs(cap, epochs):
    import dataclasses as dc
    from repro.core import MRSVMConfig, SVMConfig
    cfg_a = MRSVMConfig(sv_capacity=cap, max_rounds=3,
                        svm=SVMConfig(C=1.0, max_epochs=epochs))
    cfg_r = dc.replace(cfg_a, shuffle_impl="ring")
    return cfg_a, cfg_r


def _time_pair(fa, args_a, fr, args_r, repeats=REPEATS):
    """Interleaved best-of-N wall times of the two transports.

    Alternating the measured calls makes scheduler/load noise on the
    shared-core 8-thread host mesh hit both transports alike; min-of-N
    then discards the slow outliers.
    """
    import jax
    jax.block_until_ready(fa(*args_a))    # compile + warm
    jax.block_until_ready(fr(*args_r))
    best_a = best_r = float("inf")
    for _ in range(repeats):
        t0 = time.time()
        jax.block_until_ready(fa(*args_a))
        best_a = min(best_a, time.time() - t0)
        t0 = time.time()
        jax.block_until_ready(fr(*args_r))
        best_r = min(best_r, time.time() - t0)
    return best_a, best_r


def _payload_bytes(hlo_text):
    """Per-device collective traffic of one compiled round, by kind."""
    from repro.launch.hlo_analysis import collective_stats
    stats = collective_stats(hlo_text)
    return {kind: s["wire_bytes"] for kind, s in stats.items()}, stats


def _bracketing(hlo_text) -> dict:
    """Can reducer compute hide inside the ring's permute hops?

    Async lowering (TPU): the collective-permute-start/done pair exists
    in the text — require compute instructions scheduled between them.
    Sync lowering (this container's CPU): no start/done form exists and
    the linear scheduler is free to batch the hops, so the probe checks
    the DEPENDENCE window instead — the permutes must form a pipelined
    chain (each hop's operand derives from the previous hop) and each
    non-final hop's output must ALSO feed non-permute consumers (the
    stage's eq. 7 scoring / assembly), i.e. the compute is independent
    of the next hop and a latency-hiding scheduler may overlap them.
    """
    import re as _re
    compute_ops = ("dot(", "fusion(", "while(", "convolution(")
    lines = hlo_text.splitlines()
    starts, dones, compute_idx = [], [], []
    perms = {}                               # output name → line index
    for i, line in enumerate(lines):
        s = line.strip()
        if " = " not in s:
            continue
        lhs, rhs = s.split(" = ", 1)
        if "collective-permute-start(" in rhs:
            starts.append(i)
        elif "collective-permute-done(" in rhs:
            dones.append(i)
        elif "collective-permute(" in rhs:
            name = lhs.split()[-1].lstrip("%")
            perms[name] = i
        elif any(op in rhs for op in compute_ops):
            compute_idx.append(i)
    if starts and dones:
        gaps = list(zip(starts, sorted(dones)))
        bracketed = sum(1 for a, b in gaps
                        if any(a < c < b for c in compute_idx))
        return {"mode": "async_start_done", "permutes": len(starts),
                "gaps": len(gaps), "bracketed": bracketed}
    # sync: dependence-window analysis over the permute chain
    chained = overlapped = 0
    for name, i in perms.items():
        ref = _re.compile(r"%?" + _re.escape(name) + r"\b")
        perm_consumers = other_consumers = 0
        for j, line in enumerate(lines):
            if j == i or " = " not in line:
                continue
            rhs = line.split(" = ", 1)[1]
            if not ref.search(rhs):
                continue
            if "collective-permute(" in rhs:
                perm_consumers += 1
            else:
                other_consumers += 1
        chained += perm_consumers > 0
        overlapped += other_consumers > 0
    return {"mode": "sync_dependence", "permutes": len(perms),
            "gaps": chained, "bracketed": overlapped}


def shuffle_single(n: int = 1024, d: int = 4096, cap: int = 1024,
                   epochs: int = 1) -> List[str]:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro import compat
    from repro.core.mapreduce_svm import build_sharded_round, init_sv_buffer

    ndev = len(jax.devices())
    if ndev < NDEV:
        return [f"shuffle_single,0,SKIP:needs_{NDEV}_devices_have_{ndev}"
                " (run `python -m benchmarks.shuffle_overlap` standalone)"]
    X, y = _problem(n, d)
    mask = jnp.ones((n,))
    cfg_a, cfg_r = _cfgs(cap, epochs)
    mesh = compat.make_mesh((NDEV,), ("data",))
    fa = build_sharded_round(mesh, ("data",), cfg_a, n // NDEV)
    fr = build_sharded_round(mesh, ("data",), cfg_r, n // NDEV)
    sv_a = init_sv_buffer(cap, d)
    # the ring keeps the buffer's rows in the wire dtype between rounds
    sv_r = sv_a._replace(x=sv_a.x.astype(jnp.bfloat16))
    # one full driver round under each transport must agree (bf16-exact
    # rows make the ring's wire round-trip lossless)
    sva, ra, _, _ = fa(X, y, mask, sv_a)
    svr, rr, _, _ = fr(X, y, mask, sv_r)
    np.testing.assert_allclose(np.asarray(ra), np.asarray(rr),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(sva.ids), np.asarray(svr.ids))

    t_a, t_r = _time_pair(fa, (X, y, mask, sv_a), fr, (X, y, mask, sv_r))
    speed = t_a / max(t_r, 1e-9)
    # The single-config ring is parity-to-slightly-faster on an IDLE
    # host mesh (x ≈ 1.0-1.15 measured); its extra barriers make it the
    # load-sensitive transport on oversubscribed CPU cores, so the hard
    # bound here is a sanity check — the throughput acceptance target
    # lives on the sweep round, where dedup shrinks real work. On a
    # real ICI the overlap window (shuffle_hlo_bracketing) plus the
    # halved wire is the story for the single config too.
    assert t_r <= t_a * 1.35, (
        f"ring single-config round regressed beyond load noise: "
        f"{t_r*1e3:.1f}ms vs allgather {t_a*1e3:.1f}ms")
    # NB: ``ratio=`` (not ``x=``) keeps this load-noisy parity number
    # OUT of the CI regression gate's tracked metrics — run.py gates
    # only ``x=`` ratios, and this one legitimately swings ±25% with
    # runner load (the sweep speedup is the gated headline).
    return [
        f"shuffle_single_allgather,{t_a*1e6:.0f},ndev={NDEV} cap={cap} d={d}",
        f"shuffle_single_ring,{t_r*1e6:.0f},ndev={NDEV} cap={cap} d={d} "
        "bf16_wire",
        f"shuffle_single_speedup,0,ratio={speed:.2f} "
        f"parity_within_load_noise={bool(t_r <= t_a * 1.35)}",
    ]


def shuffle_sweep(n: int = 1024, d: int = 2048, cap: int = 512,
                  S: int = 8, epochs: int = 1) -> List[str]:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro import compat
    from repro.core import build_sharded_sweep_round, sweep_grid
    from repro.core.sweep import dedup_unique_cap

    ndev = len(jax.devices())
    if ndev < NDEV:
        return [f"shuffle_sweep,0,SKIP:needs_{NDEV}_devices_have_{ndev}"]
    X, y = _problem(n, d, seed=1)
    mask = jnp.ones((n,))
    cfg_a, cfg_r = _cfgs(cap, epochs)
    params = sweep_grid(cfg_a.svm, C=np.logspace(-1, 1, S))
    mesh = compat.make_mesh((NDEV,), ("data",))
    per = n // NDEV
    fa = build_sharded_sweep_round(mesh, ("data",), cfg_a, per)
    fr = build_sharded_sweep_round(mesh, ("data",), cfg_r, per)
    svb_a = fa.init_sv(S, d)
    svb_r = fr.init_sv(S, d)         # the shared-row dedup state

    _, ra, _, _ = fa(X, y, mask, svb_a, params)
    _, rr, _, _ = fr(X, y, mask, svb_r, params)
    np.testing.assert_allclose(np.asarray(ra), np.asarray(rr),
                               rtol=1e-5, atol=1e-6)

    t_a, t_r = _time_pair(fa, (X, y, mask, svb_a, params),
                          fr, (X, y, mask, svb_r, params))
    speed = t_a / max(t_r, 1e-9)
    k = cap // NDEV
    U = dedup_unique_cap(cfg_r, S, k, per)
    # per-round x-row traffic (the dominant payload): the allgather
    # replicates S full f32 buffers; the dedup ring ships/stores the
    # unique bf16 rows once
    bytes_a = S * cap * d * 4
    bytes_r = NDEV * U * d * 2
    assert t_r <= t_a, (
        f"ring sweep round regressed: {t_r*1e3:.1f}ms vs "
        f"allgather {t_a*1e3:.1f}ms")
    return [
        f"shuffle_sweep_allgather,{t_a*1e6:.0f},S={S} cap={cap} d={d} "
        f"xrow_bytes={bytes_a}",
        f"shuffle_sweep_ring,{t_r*1e6:.0f},S={S} cap={cap} d={d} "
        f"dedup_U={U} xrow_bytes={bytes_r}",
        f"shuffle_sweep_speedup,0,x={speed:.2f} target>=1.3 "
        f"met={bool(speed >= 1.3)} "
        f"payload_shrink={bytes_a/max(bytes_r,1):.1f}",
    ]


def shuffle_hlo_probe(n: int = 1024, d: int = 256, cap: int = 256,
                      S: int = 4, epochs: int = 2) -> List[str]:
    """Lower both transports and inspect the compiled HLO."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro import compat
    from repro.core import build_sharded_sweep_round, sweep_grid

    ndev = len(jax.devices())
    if ndev < NDEV:
        return [f"shuffle_hlo,0,SKIP:needs_{NDEV}_devices_have_{ndev}"]
    X, y = _problem(n, d, seed=2)
    mask = jnp.ones((n,))
    cfg_a, cfg_r = _cfgs(cap, epochs)
    params = sweep_grid(cfg_a.svm, C=np.logspace(-1, 1, S))
    mesh = compat.make_mesh((NDEV,), ("data",))
    out = []
    hlos = {}
    for name, cfg in (("allgather", cfg_a), ("ring", cfg_r)):
        fn = build_sharded_sweep_round(mesh, ("data",), cfg, n // NDEV)
        svb = fn.init_sv(S, d)
        hlos[name] = jax.jit(fn).lower(X, y, mask, svb, params) \
                        .compile().as_text()
        wire, _ = _payload_bytes(hlos[name])
        total = sum(wire.values())
        out.append(f"shuffle_hlo_{name}_wire_bytes,0,"
                   + " ".join(f"{k}={int(v)}" for k, v in sorted(wire.items()))
                   + f" total={int(total)}")
    br = _bracketing(hlos["ring"])
    # the ring must have lowered to collective-permutes whose hops have
    # compute in their overlap window (scheduled inside start/done on
    # async backends; data-independent of the next hop on sync ones)
    assert br["permutes"] > 0, "ring round lowered without ppermute"
    assert br["gaps"] == 0 or br["bracketed"] > 0, (
        f"no compute inside the permute hops' overlap window: {br}")
    assert "all-gather" not in _payload_bytes(hlos["ring"])[0], (
        "ring round still lowered an all-gather merge")
    wire_a = sum(_payload_bytes(hlos["allgather"])[0].values())
    wire_r = sum(_payload_bytes(hlos["ring"])[0].values())
    # NB: hlo_wire_ratio is the ratio of what THIS backend emitted —
    # the CPU lowering widens/splits some bf16 permutes to f32, so the
    # analytic payload shrink (shuffle_sweep row) is the wire story a
    # real ICI sees.
    out.append(
        f"shuffle_hlo_bracketing,0,mode={br['mode']} "
        f"permutes={br['permutes']} gaps={br['gaps']} "
        f"bracketed={br['bracketed']} "
        f"hlo_wire_ratio={wire_a/max(wire_r,1):.2f}")
    return out


def _interhost_cp_stats(hlo_text, hosts: int, ndev: int) -> dict:
    """Inter-host traffic of one compiled round's collective-permutes.

    A send ``src → tgt`` crosses hosts when ``src // dl != tgt // dl``
    (``dl`` devices per host, the process-major mesh layout
    ``resolve_topology`` guarantees). Per-send payload is the permute
    operand's per-device byte size from the HLO type string.
    """
    from repro.analysis.hlo import parse_collective_ops
    dl = ndev // hosts
    stats = {"cp_stages": 0, "sends": 0, "inter_sends": 0,
             "inter_bytes": 0, "send_nbytes": set(), "intra_ag": 0,
             "ag_cross_host": 0}
    for op in parse_collective_ops(hlo_text):
        if op.is_done:
            continue
        if op.kind == "collective-permute" and op.source_target_pairs:
            crossing = [(s, t) for s, t in op.source_target_pairs
                        if s // dl != t // dl]
            stats["cp_stages"] += 1
            stats["sends"] += len(op.source_target_pairs)
            stats["inter_sends"] += len(crossing)
            stats["inter_bytes"] += len(crossing) * op.max_nbytes
            stats["send_nbytes"].add(op.max_nbytes)
        elif op.kind == "all-gather" and op.replica_groups:
            within = all(len({dev // dl for dev in g}) == 1
                         for g in op.replica_groups)
            stats["intra_ag" if within else "ag_cross_host"] += 1
    return stats


def shuffle_hier_probe(n: int = 1024, d: int = 256, cap: int = 256,
                       epochs: int = 2, hosts: int = 2) -> List[str]:
    """Two-level hier vs flat ring: inter-host wire bytes + hop count.

    Both transports run the same f32 wire so every collective-permute
    send carries identical payload and the byte ratio is purely the
    hop schedule — a structural (deterministic) ratio, safe to CI-gate
    via ``x=`` unlike the load-noisy wall-time rows.
    """
    import dataclasses as dc
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro import compat
    from repro.core.mapreduce_svm import build_sharded_round, init_sv_buffer

    ndev = len(jax.devices())
    if ndev < NDEV:
        return [f"shuffle_hier,0,SKIP:needs_{NDEV}_devices_have_{ndev}"]
    X, y = _problem(n, d, seed=3)
    mask = jnp.ones((n,))
    cfg_a, _ = _cfgs(cap, epochs)
    cfg_r = dc.replace(cfg_a, shuffle_impl="ring",
                       shuffle_wire_dtype="float32")
    cfg_h = dc.replace(cfg_r, shuffle_impl="hier", hier_num_hosts=hosts)
    mesh = compat.make_mesh((NDEV,), ("data",))
    sv0 = init_sv_buffer(cap, d)
    fr = build_sharded_round(mesh, ("data",), cfg_r, n // NDEV)
    fh = build_sharded_round(mesh, ("data",), cfg_h, n // NDEV)

    # identical model output first — the schedule change must be free
    svr, rr, _, _ = fr(X, y, mask, sv0)
    svh, rh, _, _ = fh(X, y, mask, sv0)
    np.testing.assert_allclose(np.asarray(rr), np.asarray(rh),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(svr.ids), np.asarray(svh.ids))

    st = {}
    for name, fn in (("ring", fr), ("hier", fh)):
        hlo = jax.jit(fn).lower(X, y, mask, sv0).compile().as_text()
        st[name] = _interhost_cp_stats(hlo, hosts, NDEV)
    # flat ring: P-1 full-permutation hops, each crossing every one of
    # the H contiguous host boundaries once
    assert st["ring"]["cp_stages"] == NDEV - 1, st["ring"]
    assert st["ring"]["inter_sends"] == hosts * (NDEV - 1), st["ring"]
    # hier: H-1 host-slice exchange hops in which EVERY device sends
    # across (all P pairs crossing), intra-host legs as grouped
    # all-gathers confined to one host each
    assert st["hier"]["cp_stages"] == hosts - 1, st["hier"]
    assert st["hier"]["inter_sends"] == st["hier"]["sends"] \
        == (hosts - 1) * NDEV, st["hier"]
    assert st["hier"]["intra_ag"] > 0 and \
        st["hier"]["ag_cross_host"] == 0, st["hier"]
    # same packed wire format → identical per-send payload both sides
    assert st["ring"]["send_nbytes"] == st["hier"]["send_nbytes"], \
        (st["ring"]["send_nbytes"], st["hier"]["send_nbytes"])

    ratio = st["ring"]["inter_bytes"] / max(st["hier"]["inter_bytes"], 1)
    analytic = hosts * (NDEV - 1) / ((hosts - 1) * NDEV)
    assert abs(ratio - analytic) < 1e-9, (ratio, analytic)
    assert ratio >= 1.7, f"hier inter-host saving collapsed: {ratio:.2f}"
    return [
        f"shuffle_hier_ring_interhost,0,cp_stages={st['ring']['cp_stages']}"
        f" inter_sends={st['ring']['inter_sends']}"
        f" inter_bytes={st['ring']['inter_bytes']}"
        f" merge_stages={NDEV} (=num_devices)",
        f"shuffle_hier_interhost,0,cp_stages={st['hier']['cp_stages']}"
        f" inter_sends={st['hier']['inter_sends']}"
        f" inter_bytes={st['hier']['inter_bytes']}"
        f" merge_stages={hosts} (=num_processes)"
        f" intra_host_allgathers={st['hier']['intra_ag']}",
        f"hier_vs_ring_wire_bytes,0,x={ratio:.2f}"
        f" analytic_H(P-1)/((H-1)P)={analytic:.2f} asymptote=2.0"
        f" hosts={hosts} ndev={NDEV}",
    ]


def shuffle_overlap_bench() -> List[str]:
    return (shuffle_single() + shuffle_sweep() + shuffle_hlo_probe()
            + shuffle_hier_probe())


def main():
    from benchmarks.run import write_bench_json
    print("name,us_per_call,derived")
    rows = shuffle_overlap_bench()
    for line in rows:
        print(line, flush=True)
    path = write_bench_json("shuffle_overlap", rows)
    print(f"# wrote {path}")


if __name__ == "__main__":
    import os
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    main()
