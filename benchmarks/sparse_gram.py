"""Sparse vs dense Gram throughput + ring wire bytes (ISSUE 6).

The blocked-CSR path exists for the paper's actual regime: hashed
TF×IDF spaces of 16k–262k features where rows are >99% zero. This
bench measures, at matched data (sparse rows densified for the dense
leg):

* ``sparse_gram_d<d>`` — row-pairs/sec of the sparse Gram contraction
  vs the dense one, both as compiled XLA (the honest comparison on
  this CPU container — Pallas interpret mode is a Python correctness
  harness, not a performance mode; on TPU the same ratio story holds
  for ``pallas_sparse`` vs ``pallas`` since compare-accumulate work is
  O(nnz²) vs O(d) MACs per pair). The ≥2× acceptance target lives on
  the d≥65536 rows, gated via ``x=``.
* ``sparse_wire_d<d>`` — ring-shuffle payload of an SV buffer's rows
  under ``pack_wire_rows``: the sparse wire ships (values-packed +
  int32-bitcast indices) lanes, the dense wire ships d/2 bf16 lanes.
  Deterministic shape arithmetic (measured from the ACTUAL packed
  flat sizes), so the ≥5× target is load-noise-free in CI.

Standalone:

    PYTHONPATH=src python -m benchmarks.sparse_gram
"""
from __future__ import annotations

import time
from typing import List

N_ROWS = 256          # rows per side (n = m)
NNZ_CAP = 128         # blocked-CSR slots — ≤1% density at every d here
DIMS = (16384, 65536, 262144)
REPEATS = 5


def _sparse_problem(n, d, cap, seed=0):
    """Random SparseRows with DISTINCT in-row column ids (stratified
    one-per-stride draw — the generator contract) and exactly ``cap``
    nonzeros per row."""
    import numpy as np
    from repro import sparse

    rng = np.random.default_rng(seed)
    stride = d // cap
    cols = (np.arange(cap, dtype=np.int64) * stride)[None, :] \
        + rng.integers(0, stride, (n, cap))
    vals = rng.random((n, cap), dtype=np.float32) + 0.1
    vals /= np.linalg.norm(vals, axis=1, keepdims=True)
    return sparse.from_numpy_coo(cols.astype(np.int32), vals, d)


def _best_of(fn, args, repeats=REPEATS):
    import jax
    jax.block_until_ready(fn(*args))          # compile + warm
    best = float("inf")
    for _ in range(repeats):
        t0 = time.time()
        jax.block_until_ready(fn(*args))
        best = min(best, time.time() - t0)
    return best


def sparse_gram_speed() -> List[str]:
    import functools

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro import sparse
    from repro.kernels.ref import gram_ref, sparse_gram_ref

    out = []
    dense_ref = jax.jit(functools.partial(gram_ref, kind="rbf", gamma=0.5))
    sparse_ref = jax.jit(
        functools.partial(sparse_gram_ref, kind="rbf", gamma=0.5))
    for d in DIMS:
        Xs = _sparse_problem(N_ROWS, d, NNZ_CAP, seed=d)
        Zs = _sparse_problem(N_ROWS, d, NNZ_CAP, seed=d + 1)
        Xs = jax.tree_util.tree_map(jnp.asarray, Xs)
        Zs = jax.tree_util.tree_map(jnp.asarray, Zs)
        Xd, Zd = sparse.to_dense(Xs), sparse.to_dense(Zs)
        # matched-data correctness first, then the stopwatch
        np.testing.assert_allclose(
            np.asarray(sparse_ref(Xs, Zs)), np.asarray(dense_ref(Xd, Zd)),
            rtol=1e-4, atol=1e-5)
        t_d = _best_of(dense_ref, (Xd, Zd))
        t_s = _best_of(sparse_ref, (Xs, Zs))
        pairs = N_ROWS * N_ROWS
        speed = t_d / max(t_s, 1e-9)
        density = NNZ_CAP / d
        gated = d >= 65536
        tag = (f"x={speed:.2f} target>=2 met={bool(speed >= 2.0)}"
               if gated else f"ratio={speed:.2f}")
        out.append(
            f"sparse_gram_d{d},{t_s*1e6:.0f},n={N_ROWS} nnz={NNZ_CAP} "
            f"density={density:.4%} pairs_per_s={pairs/max(t_s,1e-9):.0f} "
            f"dense_us={t_d*1e6:.0f} {tag}")
        if gated:
            assert speed >= 2.0, (
                f"sparse Gram not ≥2× dense at d={d} "
                f"(density {density:.2%}): {speed:.2f}×")
    return out


def sparse_gram_kernel_check() -> List[str]:
    """Pin the Pallas index-match kernel against the XLA oracle (small
    shape — interpret mode runs the kernel body in Python)."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.kernels.gram import sparse_gram
    from repro.kernels.ref import sparse_gram_ref

    Xs = _sparse_problem(96, 4096, 16, seed=7)
    Zs = _sparse_problem(80, 4096, 16, seed=8)
    Xs = jax.tree_util.tree_map(jnp.asarray, Xs)
    Zs = jax.tree_util.tree_map(jnp.asarray, Zs)
    worst = 0.0
    for kind in ("linear", "rbf", "poly"):
        K = sparse_gram(Xs, Zs, 0.7, 0.3, kind=kind, interpret=True)
        Kr = sparse_gram_ref(Xs, Zs, kind, 0.7, 0.3)
        err = float(np.max(np.abs(np.asarray(K) - np.asarray(Kr))))
        np.testing.assert_allclose(np.asarray(K), np.asarray(Kr),
                                   rtol=1e-4, atol=1e-5)
        worst = max(worst, err)
    return [f"sparse_gram_pallas_check,0,kinds=linear+rbf+poly "
            f"max_abs_err={worst:.2e}"]


def sparse_wire_bytes() -> List[str]:
    """Ring payload of one SV buffer's rows, measured from the actual
    ``pack_wire_rows`` flat sizes (f32 lanes × 4 bytes)."""
    import jax.numpy as jnp
    from repro import sparse
    from repro.core.mapreduce_svm import pack_wire_rows

    out = []
    cap_rows = 256                     # SV rows shipped per ring hop
    wire_dt = jnp.bfloat16
    for d in DIMS:
        Xs = _sparse_problem(cap_rows, d, NNZ_CAP, seed=d + 2)
        Xs = sparse.SparseRows(jnp.asarray(Xs.indices),
                               jnp.asarray(Xs.values), Xs.d)
        Xd = sparse.to_dense(Xs)
        flat_d, _ = pack_wire_rows(Xd, wire_dt)
        flat_s, _ = pack_wire_rows(Xs, wire_dt)
        bytes_d, bytes_s = flat_d.size * 4, flat_s.size * 4
        shrink = bytes_d / max(bytes_s, 1)
        out.append(
            f"sparse_wire_d{d},0,rows={cap_rows} nnz_cap={NNZ_CAP} "
            f"dense_bytes={bytes_d} sparse_bytes={bytes_s} "
            f"x={shrink:.2f} target>=5 met={bool(shrink >= 5.0)}")
        assert shrink >= 5.0, (
            f"sparse wire not ≥5× smaller at d={d}: {shrink:.2f}×")
    return out


def sparse_gram_bench() -> List[str]:
    return (sparse_gram_kernel_check() + sparse_gram_speed()
            + sparse_wire_bytes())


def main():
    from benchmarks.run import write_bench_json
    print("name,us_per_call,derived")
    rows = sparse_gram_bench()
    for line in rows:
        print(line, flush=True)
    path = write_bench_json("sparse_gram", rows)
    print(f"# wrote {path}")


if __name__ == "__main__":
    main()
