"""Streaming update-round latency vs full retrain (ISSUE 3 tentpole).

The SV-as-sufficient-statistic argument, measured: folding a live
micro-batch via ``update_mapreduce`` trains on (new rows ∪ carried SVs)
— a few hundred rows — while the full retrain pays for the whole
accumulated corpus every time content drifts. Acceptance: the update
round beats full retrain by ≥5× at 8 partitions.

Also measures the multi-tenant wave: S streams folded in ONE batched
device pass (the sweep's config axis, ``fit_mapreduce_sweep`` with
per-job data) vs S sequential ``update_mapreduce`` calls.

Standalone (forces 8 host devices, writes BENCH_streaming.json):

    PYTHONPATH=src python -m benchmarks.streaming
"""
from __future__ import annotations

import time
from typing import List

HIST_ROWS = 8192      # accumulated corpus the full retrain must chew
BATCH_ROWS = 512      # one streaming micro-batch
NUM_FEATURES = 128
PARTITIONS = 8
SV_CAP = 128
MIN_SPEEDUP = 5.0     # ISSUE 3 acceptance at 8 partitions


from benchmarks.sweep import _problem  # shared synthetic problem


def _cfg():
    from repro.core import MRSVMConfig, SVMConfig
    # gamma=0 forces max_rounds everywhere: both paths run the same
    # number of rounds, isolating the per-round row-count advantage.
    return MRSVMConfig(sv_capacity=SV_CAP, gamma=0.0, max_rounds=3,
                       svm=SVMConfig(C=1.0, max_epochs=10))


def streaming_update(n_hist: int = HIST_ROWS, n_new: int = BATCH_ROWS,
                     d: int = NUM_FEATURES, L: int = PARTITIONS) -> List[str]:
    """update_mapreduce on (batch ∪ SVs) vs fit_mapreduce on everything."""
    import jax
    import jax.numpy as jnp
    from repro.core import fit_mapreduce, update_mapreduce

    cfg = _cfg()
    Xh, yh = _problem(n_hist, d, seed=0)
    Xn, yn = _problem(n_new, d, seed=1)
    model = fit_mapreduce(Xh, yh, L, cfg)          # the served model
    Xall = jnp.concatenate([Xh, Xn])
    yall = jnp.concatenate([yh, yn])

    # warm both jits: steady-state serving latency, not trace time
    jax.block_until_ready(update_mapreduce(model, Xn, yn, L, cfg).sv.x)
    jax.block_until_ready(fit_mapreduce(Xall, yall, L, cfg).sv.x)

    t0 = time.time()
    upd = update_mapreduce(model, Xn, yn, L, cfg)
    jax.block_until_ready(upd.sv.x)
    t_update = time.time() - t0

    t0 = time.time()
    full = fit_mapreduce(Xall, yall, L, cfg)
    jax.block_until_ready(full.sv.x)
    t_full = time.time() - t0

    speedup = t_full / max(t_update, 1e-9)
    rows_upd = n_new + SV_CAP
    # ISSUE 3 acceptance: ≥5× at 8 partitions.
    assert speedup >= MIN_SPEEDUP, (
        f"update round only {speedup:.2f}× over full retrain "
        f"(needs ≥{MIN_SPEEDUP}× at {L} partitions)")
    out = [
        f"streaming_update_round,{t_update * 1e6:.0f},"
        f"rows={rows_upd} L={L}",
        f"streaming_full_retrain,{t_full * 1e6:.0f},"
        f"rows={n_hist + n_new} L={L}",
        f"streaming_speedup,0,x={speedup:.2f} "
        f"row_ratio={(n_hist + n_new) / rows_upd:.1f} "
        f"target>={MIN_SPEEDUP}",
    ]
    return out


def streaming_wave(S: int = 4, n_new: int = BATCH_ROWS,
                   d: int = NUM_FEATURES, L: int = PARTITIONS) -> List[str]:
    """S tenant streams folded in one batched pass (the service's
    multi-tenant wave) vs S sequential update_mapreduce calls."""
    import jax
    from repro.core import fit_mapreduce, update_mapreduce
    from repro.serving import StreamingSVMService

    cfg = _cfg()
    models = {}
    batches = {}
    for s in range(S):
        Xh, yh = _problem(2048, d, seed=10 + s)
        models[f"t{s}"] = fit_mapreduce(Xh, yh, L, cfg)
        batches[f"t{s}"] = _problem(n_new, d, seed=100 + s)

    def run_service():
        svc = StreamingSVMService(cfg, num_partitions=L,
                                  max_batches_per_wave=1)
        for name, m in models.items():
            svc.register(name, m)
        for name, (Xn, yn) in batches.items():
            svc.submit(name, Xn, yn)
        svc.run_wave()
        jax.block_until_ready(svc.snapshot("t0").model.sv.x)
        return svc

    run_service()                                  # warm the batched jit
    t0 = time.time()
    svc = run_service()
    t_batched = time.time() - t0
    assert all(svc.snapshot(n).version == 1 for n in models)

    def run_sequential():
        outs = {}
        for name, (Xn, yn) in batches.items():
            outs[name] = update_mapreduce(models[name], Xn, yn, L, cfg)
        jax.block_until_ready(outs["t0"].sv.x)
        return outs

    run_sequential()                               # warm
    t0 = time.time()
    run_sequential()
    t_seq = time.time() - t0

    return [
        f"streaming_wave_batched,{t_batched * 1e6:.0f},"
        f"S={S} one_device_pass",
        f"streaming_wave_sequential,{t_seq * 1e6:.0f},S={S} S_updates",
        f"streaming_wave_speedup,0,"
        f"x={t_seq / max(t_batched, 1e-9):.2f}",
    ]


def streaming_bench() -> List[str]:
    return streaming_update() + streaming_wave()


def main():
    from benchmarks.run import write_bench_json
    print("name,us_per_call,derived")
    rows = streaming_bench()
    for line in rows:
        print(line, flush=True)
    path = write_bench_json("streaming", rows)
    print(f"# wrote {path}")


if __name__ == "__main__":
    import os
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    main()
