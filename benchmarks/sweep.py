"""Batched hyper-parameter sweep vs a sequential per-config loop.

The sweep subsystem's claim (ISSUE 2 tentpole): S (C, kernel) configs
per round under one outer vmap — one trace, one jit, one device pass —
beats S sequential ``fit_mapreduce`` calls, which pay S traces, S
compiles and S×rounds dispatches. This is the paper's amortize-across-
the-cluster argument applied across *jobs* (He et al. 2019).

Two comparisons:

* ``sweep_functional`` — any device count; batched
  :func:`fit_mapreduce_sweep` vs a loop of per-config
  :func:`fit_mapreduce` with identical ``SolverParams`` slices.
* ``sweep_sharded`` — needs ≥8 devices (standalone run forces 8 host
  devices); batched :func:`build_sharded_sweep_round` vs a per-config
  loop of :func:`build_sharded_round`.

Standalone:

    PYTHONPATH=src python -m benchmarks.sweep      # forces 8 devices
"""
from __future__ import annotations

import time
from typing import List

NUM_CONFIGS = 8


def _problem(n, d, seed=0):
    import jax
    import jax.numpy as jnp
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    X = jax.random.normal(k1, (n, d))
    w = jax.random.normal(k2, (d,))
    y = jnp.sign(X @ w + 0.05)
    return X, y


def _cfg_and_params(S):
    import numpy as np
    from repro.core import MRSVMConfig, SVMConfig, sweep_grid
    cfg = MRSVMConfig(sv_capacity=64, gamma=0.0, max_rounds=3,
                      svm=SVMConfig(C=1.0, max_epochs=10))
    params = sweep_grid(cfg.svm, C=np.logspace(-2, 1, S).astype(np.float32))
    return cfg, params


def sweep_functional(n: int = 2048, d: int = 64, S: int = NUM_CONFIGS,
                     L: int = 8) -> List[str]:
    import dataclasses as dc

    import jax
    import numpy as np
    from repro.core import fit_mapreduce, fit_mapreduce_sweep

    X, y = _problem(n, d)
    cfg, params = _cfg_and_params(S)
    out = []

    t0 = time.time()
    res = fit_mapreduce_sweep(X, y, L, cfg, params)
    jax.block_until_ready(res.risks)
    t_batched = time.time() - t0

    # sequential workflow: the naive S-config loop bakes each config's
    # values into a static SVMConfig — S distinct programs, S traces
    # (mirrors sweep_sharded; a traced-params loop would now share one
    # cached jit and measure only dispatch, not the workflow it models).
    t0 = time.time()
    seq_risks = []
    for s in range(S):
        cfg_s = dc.replace(
            cfg, svm=dc.replace(cfg.svm, C=float(params.C[s]),
                                tol=float(params.tol[s])))
        m = fit_mapreduce(X, y, L, cfg_s)
        seq_risks.append(float(m.risk))
    t_seq = time.time() - t0

    np.testing.assert_allclose(np.asarray(res.risks), np.asarray(seq_risks),
                               rtol=1e-4, atol=1e-5)
    # ISSUE 2 acceptance: batched must beat the sequential loop.
    assert t_batched < t_seq, (
        f"batched sweep regressed: {t_batched:.2f}s vs sequential "
        f"{t_seq:.2f}s")
    out.append(f"sweep_functional_batched,{t_batched * 1e6:.0f},"
               f"S={S} one_jit_S_models")
    out.append(f"sweep_functional_sequential,{t_seq * 1e6:.0f},"
               f"S={S} S_jits")
    out.append(f"sweep_functional_speedup,0,"
               f"x={t_seq / max(t_batched, 1e-9):.2f} "
               f"batched_faster={t_batched < t_seq}")
    return out


def sweep_sharded(n: int = 2048, d: int = 64,
                  S: int = NUM_CONFIGS) -> List[str]:
    import dataclasses as dc

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro import compat
    from repro.core import (build_sharded_sweep_round, init_sv_buffer,
                            run_sharded_sweep)
    from repro.core.mapreduce_svm import build_sharded_round

    ndev = len(jax.devices())
    if ndev < 8:
        return [f"sweep_sharded,0,SKIP:needs_8_devices_have_{ndev}"
                " (run `python -m benchmarks.sweep` standalone)"]

    X, y = _problem(n, d)
    cfg, params = _cfg_and_params(S)
    per = n // ndev
    mesh = compat.make_mesh((ndev,), ("data",))
    mask = jnp.ones((n,))
    out = []

    t0 = time.time()
    fn = build_sharded_sweep_round(mesh, ("data",), cfg, per)
    res = run_sharded_sweep(fn, X, y, mask, cfg, params)
    jax.block_until_ready(res.risks)
    t_batched = time.time() - t0

    # sequential workflow: one shard_map program per config (its own
    # trace + compile), rounds driven per config.
    t0 = time.time()
    seq_risks = []
    for s in range(S):
        cfg_s = dc.replace(
            cfg, svm=dc.replace(cfg.svm, C=float(params.C[s]),
                                tol=float(params.tol[s])))
        fn_s = build_sharded_round(mesh, ("data",), cfg_s, per)
        sv = init_sv_buffer(cfg.sv_capacity, d)
        best = np.inf
        prev = np.inf
        for t in range(cfg.max_rounds):
            sv, risks, w, b = fn_s(X, y, mask, sv)
            r = float(jnp.min(risks))
            best = min(best, r)
            if t > 0 and abs(prev - r) <= cfg.gamma:
                break
            prev = r
        seq_risks.append(best)
    t_seq = time.time() - t0

    np.testing.assert_allclose(np.asarray(res.risks), np.asarray(seq_risks),
                               rtol=1e-4, atol=1e-5)
    assert t_batched < t_seq, (
        f"batched sharded sweep regressed: {t_batched:.2f}s vs "
        f"sequential {t_seq:.2f}s")
    out.append(f"sweep_sharded_batched,{t_batched * 1e6:.0f},"
               f"S={S} ndev={ndev} one_jit_S_models")
    out.append(f"sweep_sharded_sequential,{t_seq * 1e6:.0f},"
               f"S={S} ndev={ndev} S_jits")
    out.append(f"sweep_sharded_speedup,0,"
               f"x={t_seq / max(t_batched, 1e-9):.2f} "
               f"batched_faster={t_batched < t_seq}")
    return out


def sweep_bench() -> List[str]:
    return sweep_functional() + sweep_sharded()


def main():
    from benchmarks.run import write_bench_json
    print("name,us_per_call,derived")
    rows = sweep_bench()
    for line in rows:
        print(line, flush=True)
    path = write_bench_json("sweep", rows)
    print(f"# wrote {path}")


if __name__ == "__main__":
    import os
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    main()
