"""One benchmark per paper table (Tablo 5-9), on the synthetic corpus
(DESIGN.md §6 — 2014 Twitter data unavailable offline; structure and
metrics match; the paper's absolute numbers are printed alongside)."""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import (MRSVMConfig, SVMConfig, confusion_matrix,
                        fit_mapreduce, fit_one_vs_rest, predict)
from repro.text import (CorpusConfig, fit_transform, generate, select_top_k,
                        vectorize)

# Paper reference numbers
PAPER_TABLO6 = np.array([[40.61, 9.03], [5.04, 45.31]])
PAPER_TABLO8 = np.array([[23.63, 6.24, 3.25],
                         [3.44, 21.47, 8.06],
                         [2.16, 8.46, 23.28]])

_N_MSG = 3000
_FEATURES = 4096
_SELECTED = 1024     # paper pipeline includes a feature-selection stage


def _pipeline(classes, seed=0, select=True):
    cfg = CorpusConfig(num_messages=_N_MSG, classes=classes, seed=seed)
    corpus = generate(cfg)
    X, _ = fit_transform(jnp.asarray(vectorize(corpus.texts, _FEATURES)))
    y = jnp.asarray(corpus.labels, jnp.float32)
    if select:       # χ² top-k ("nitelik seçimi", Yang & Pedersen ref)
        X, _ = select_top_k(X, y, list(classes), _SELECTED)
    return corpus, X, y


def table5_dataset() -> List[str]:
    """Tablo 5: class distribution of the training corpora."""
    out = []
    t0 = time.time()
    for classes, paper in (((-1, 1), (172489, 174669)),
                           ((-1, 0, 1), (111779, 109853, 113438))):
        corpus, _, y = _pipeline(classes)
        counts = {c: int(np.sum(corpus.labels == c)) for c in classes}
        out.append(f"table5_classes{len(classes)},"
                   f"{(time.time() - t0) * 1e6 / _N_MSG:.2f},"
                   f"counts={counts} paper={paper}")
    return out


def _fit2(X, y):
    mcfg = MRSVMConfig(sv_capacity=256, gamma=1e-4, max_rounds=4,
                       svm=SVMConfig(C=1.0, max_epochs=15))
    return fit_mapreduce(X, y, num_partitions=8, cfg=mcfg), mcfg


def table6_confusion2() -> List[str]:
    """Tablo 6: 2-class confusion matrix (global %)."""
    _, X, y = _pipeline((-1, 1))
    t0 = time.time()
    model, mcfg = _fit2(X, y)
    train_us = (time.time() - t0) * 1e6
    pred = predict(model, X, mcfg)
    cm = confusion_matrix(y, pred, [-1, 1])
    diag = np.trace(cm)
    return [f"table6_confusion2,{train_us:.0f},"
            f"diag={diag:.2f}% paper_diag={np.trace(PAPER_TABLO6):.2f}% "
            f"cm={np.round(cm, 2).tolist()}"]


def table7_rank2() -> List[str]:
    """Tablo 7: top-10 universities by message count with polarity rates."""
    corpus, X, y = _pipeline((-1, 1))
    t0 = time.time()
    model, mcfg = _fit2(X, y)
    pred = np.asarray(predict(model, X, mcfg))
    by_uni: Dict[int, Tuple[int, float]] = {}
    for u in range(len(corpus.university_names)):
        sel = corpus.universities == u
        n = int(sel.sum())
        if n:
            by_uni[u] = (n, float((pred[sel] > 0).mean()))
    top10 = sorted(by_uni.items(), key=lambda kv: -kv[1][0])[:10]
    rows = [f"{corpus.university_names[u][:24]}:n={n}:pos={p:.2f}"
            for u, (n, p) in top10]
    return [f"table7_rank2,{(time.time() - t0) * 1e6:.0f},{'|'.join(rows)}"]


def table8_confusion3() -> List[str]:
    """Tablo 8: 3-class confusion matrix (global %)."""
    _, X, y = _pipeline((-1, 0, 1))
    t0 = time.time()
    mcfg = MRSVMConfig(sv_capacity=256, gamma=1e-4, max_rounds=3,
                       svm=SVMConfig(C=1.0, max_epochs=15))
    ovr = fit_one_vs_rest(X, y, [-1, 0, 1], 8, mcfg)
    train_us = (time.time() - t0) * 1e6
    pred = ovr.predict(X)
    cm = confusion_matrix(y, pred, [-1, 0, 1])
    return [f"table8_confusion3,{train_us:.0f},"
            f"diag={np.trace(cm):.2f}% paper_diag={np.trace(PAPER_TABLO8):.2f}% "
            f"cm={np.round(cm, 2).tolist()}"]


def table9_rank3() -> List[str]:
    """Tablo 9: top-10 universities, 3-class rates."""
    corpus, X, y = _pipeline((-1, 0, 1), seed=1)
    t0 = time.time()
    mcfg = MRSVMConfig(sv_capacity=256, max_rounds=3,
                       svm=SVMConfig(C=1.0, max_epochs=15))
    ovr = fit_one_vs_rest(X, y, [-1, 0, 1], 8, mcfg)
    pred = np.asarray(ovr.predict(X))
    by_uni = {}
    for u in range(len(corpus.university_names)):
        sel = corpus.universities == u
        n = int(sel.sum())
        if n:
            by_uni[u] = (n, float((pred[sel] > 0).mean()),
                         float((pred[sel] == 0).mean()),
                         float((pred[sel] < 0).mean()))
    top10 = sorted(by_uni.items(), key=lambda kv: -kv[1][0])[:10]
    rows = [f"{corpus.university_names[u][:20]}:n={n}:+{p:.2f}/0{z:.2f}/-{m:.2f}"
            for u, (n, p, z, m) in top10]
    return [f"table9_rank3,{(time.time() - t0) * 1e6:.0f},{'|'.join(rows)}"]
