"""The paper's system in its production form: MapReduce-SVM rounds
executed under shard_map, with dataset rows sharded across devices and
the SV merge as an all-gather (the ICI 'shuffle').

Runs on 8 faked host devices (set before jax import):

    PYTHONPATH=src python examples/distributed_svm.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp

from repro import compat
from repro.core import MRSVMConfig, SVMConfig
from repro.core.mapreduce_svm import build_sharded_round, init_sv_buffer
from repro.text import CorpusConfig, fit_transform, generate, vectorize


def main():
    corpus = generate(CorpusConfig(num_messages=2048, classes=(-1, 1)))
    X, _ = fit_transform(jnp.asarray(vectorize(corpus.texts, 2048)))
    y = jnp.asarray(corpus.labels, jnp.float32)
    n, d = X.shape
    ndev = len(jax.devices())
    print(f"{n} rows × {d} features over {ndev} devices "
          f"({n // ndev} rows/device)")

    mesh = compat.make_mesh((ndev,), ("data",))
    cfg = MRSVMConfig(sv_capacity=256, gamma=1e-4,
                      svm=SVMConfig(C=1.0, max_epochs=15))
    round_fn = build_sharded_round(mesh, ("data",), cfg, n // ndev)

    sv = init_sv_buffer(cfg.sv_capacity, d)
    mask = jnp.ones((n,))
    prev = float("inf")
    for t in range(6):
        sv, risks, w, b = round_fn(X, y, mask, sv)
        r = float(jnp.min(risks))
        print(f"round {t}: R_emp={r:.4f} |SV|={int(jnp.sum(sv.mask))} "
              f"(all-gather merged {ndev} reducers)")
        if t > 0 and abs(prev - r) <= cfg.gamma:       # eq. 8
            print("eq. 8 convergence")
            break
        prev = r
    acc = float(jnp.mean(jnp.sign(X @ w + b) == y))
    print(f"best-reducer hypothesis accuracy: {acc:.3f}")


if __name__ == "__main__":
    main()
