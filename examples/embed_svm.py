"""Beyond-paper example: the paper's MapReduce-SVM head on FROZEN
BACKBONE EMBEDDINGS instead of TF×IDF — the 2026 version of the same
polarization pipeline (DESIGN.md §2, adaptation 3).

Tweets → tokens → (reduced) backbone → mean-pooled hidden states →
iterative MapReduce SVM → polarity.

    PYTHONPATH=src python examples/embed_svm.py --arch qwen2-1.5b
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import MRSVMConfig, SVMConfig, fit_mapreduce, predict
from repro.models.config import smoke_variant
from repro.models.transformer import build_model
from repro.text import CorpusConfig, generate, tokenize
from repro.text.tokenizer import hash_token


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--messages", type=int, default=800)
    args = ap.parse_args()

    corpus = generate(CorpusConfig(num_messages=args.messages,
                                   classes=(-1, 1), seed=0))
    cfg = smoke_variant(get_config(args.arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    S = 24
    tok_ids = np.zeros((args.messages, S), np.int32)
    for i, text in enumerate(corpus.texts):
        toks = tokenize(text)[:S]
        tok_ids[i, :len(toks)] = [hash_token(t, cfg.vocab_size - 1) + 1
                                  for t in toks]

    @jax.jit
    def embed(tokens):
        h, _ = model.hidden_states(params, tokens)
        return jnp.mean(h, axis=1)            # mean-pool (B, D)

    feats = []
    bs = 64
    for i in range(0, args.messages, bs):
        feats.append(embed(jnp.asarray(tok_ids[i:i + bs])))
    X = jnp.concatenate(feats)
    X = X / jnp.maximum(jnp.linalg.norm(X, axis=1, keepdims=True), 1e-9)
    y = jnp.asarray(corpus.labels, jnp.float32)
    print(f"embedded {X.shape[0]} messages → {X.shape[1]}-d "
          f"({cfg.name} reduced backbone)")

    mcfg = MRSVMConfig(sv_capacity=128, gamma=1e-4, max_rounds=5,
                       svm=SVMConfig(C=1.0, max_epochs=20))
    svm = fit_mapreduce(X, y, num_partitions=8, cfg=mcfg, verbose=True)
    acc = float(jnp.mean(predict(svm, X, mcfg) == y))
    print(f"embedding-SVM accuracy: {acc:.3f} "
          "(untrained backbone: structure only, not semantics)")


if __name__ == "__main__":
    main()
