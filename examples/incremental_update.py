"""The paper's stated future work (§SONUÇ): keep the classifier current
as message content drifts, by retraining on (new batch ∪ old SVs) only.

    PYTHONPATH=src python examples/incremental_update.py
"""
import jax.numpy as jnp

from repro.core import (MRSVMConfig, SVMConfig, fit_mapreduce, predict,
                        update_mapreduce)
from repro.text import CorpusConfig, fit_transform, generate, vectorize
from repro.text.tfidf import transform


def main():
    cfg = MRSVMConfig(sv_capacity=256, gamma=1e-4, max_rounds=4,
                      svm=SVMConfig(C=1.0, max_epochs=15))

    print("month 0: train on the initial corpus")
    c0 = generate(CorpusConfig(num_messages=1500, classes=(-1, 1), seed=0))
    X0, idf = fit_transform(jnp.asarray(vectorize(c0.texts, 4096)))
    y0 = jnp.asarray(c0.labels, jnp.float32)
    model = fit_mapreduce(X0, y0, 8, cfg)
    print(f"  acc={float(jnp.mean(predict(model, X0, cfg) == y0)):.3f} "
          f"|SV|={int(model.sv.mask.sum())}")

    for month in (1, 2):
        cm = generate(CorpusConfig(num_messages=1000, classes=(-1, 1),
                                   seed=100 + month))
        Xm = transform(jnp.asarray(vectorize(cm.texts, 4096)), idf)
        ym = jnp.asarray(cm.labels, jnp.float32)
        stale = float(jnp.mean(predict(model, Xm, cfg) == ym))
        model = update_mapreduce(model, Xm, ym, 8, cfg)
        fresh = float(jnp.mean(predict(model, Xm, cfg) == ym))
        print(f"month {month}: stale acc={stale:.3f} → updated acc={fresh:.3f} "
              f"(update saw {Xm.shape[0]} new rows + "
              f"{int(model.sv.mask.sum())} carried SVs, not the old corpus)")


if __name__ == "__main__":
    main()
