"""Multi-host MapReduce-SVM: the paper's actual deployment shape —
N processes, each holding only its shard of the TF×IDF rows, exchanging
nothing but support vectors (DESIGN.md §11).

The 2-process CPU launch line (run each in its own shell/host; same
flags work for `-m repro.launch.train --arch svm-tfidf`):

    PYTHONPATH=src python examples/multihost_svm.py \
        --coordinator localhost:9911 --num-processes 2 --process-id 0 &
    PYTHONPATH=src python examples/multihost_svm.py \
        --coordinator localhost:9911 --num-processes 2 --process-id 1

Run with NO flags to have the script spawn both processes itself.
"""
import argparse
import os
import subprocess
import sys


def worker(args) -> None:
    # init_cluster BEFORE first backend use: it wires the distributed
    # client, the gloo CPU collectives and the faked device count into
    # the backend at its first initialization.
    from repro.launch.cluster import cluster_config_from_args, init_cluster
    cluster = init_cluster(cluster_config_from_args(args))

    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.core import MRSVMConfig, SVMConfig
    from repro.core.mapreduce_svm import build_sharded_round, init_sv_buffer
    from repro.data import svm_rows_shard
    from repro.launch.mesh import make_host_mesh

    say = print if cluster.is_coordinator else (lambda *a, **k: None)
    say(f"cluster: {cluster.describe()}")

    ndev = cluster.device_count
    n, d = 128 * ndev, 2048
    mesh = make_host_mesh(ndev, 1, cluster=cluster)
    cfg = MRSVMConfig(sv_capacity=32 * ndev, gamma=1e-4,
                      svm=SVMConfig(C=1.0, max_epochs=15))

    # Each process materializes ONLY its disjoint row shard and
    # assembles the global arrays in place — no host ever sees the
    # full matrix, which is the paper's whole premise.
    Xl, yl = svm_rows_shard(n, d, seed=0,
                            process_index=cluster.process_index,
                            process_count=cluster.process_count)
    X = cluster.make_global_array(mesh, P("data"), Xl, (n, d))
    y = cluster.make_global_array(mesh, P("data"), yl, (n,))
    mask = cluster.make_global_array(
        mesh, P("data"), np.ones((Xl.shape[0],), np.float32), (n,))
    say(f"{n} rows × {d} features: {Xl.shape[0]} rows/host over "
        f"{cluster.process_count} processes, {ndev} global devices")

    round_fn = build_sharded_round(mesh, ("data",), cfg, n // ndev)
    sv = init_sv_buffer(cfg.sv_capacity, d)
    prev = float("inf")
    for t in range(6):
        sv, risks, w, b = round_fn(X, y, mask, sv)
        r = float(np.min(np.asarray(risks)))          # replicated output
        say(f"round {t}: R_emp={r:.4f} |SV|={int(np.asarray(sv.mask).sum())}")
        if t > 0 and abs(prev - r) <= cfg.gamma:      # eq. 8
            say("eq. 8 convergence")
            break
        prev = r
    acc = float((np.sign(Xl @ np.asarray(w)) == yl).mean())
    print(f"[p{cluster.process_index}] hypothesis accuracy on the "
          f"host-local shard: {acc:.3f}")


def main():
    ap = argparse.ArgumentParser()
    from repro.launch.cluster import add_cluster_flags
    add_cluster_flags(ap)
    args = ap.parse_args()
    if args.process_id is not None:
        return worker(args)

    # driver mode: spawn the 2-process launch above
    num, port = args.num_processes or 2, 9911
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env = dict(os.environ,
               PYTHONPATH=src + os.pathsep + os.environ.get("PYTHONPATH", ""),
               JAX_PLATFORMS=os.environ.get("JAX_PLATFORMS", "cpu"))
    procs = [subprocess.Popen(
        [sys.executable, __file__, "--coordinator", f"localhost:{port}",
         "--num-processes", str(num), "--process-id", str(i),
         "--local-devices", "4"], env=env) for i in range(num)]
    # signal-killed workers return NEGATIVE codes; any nonzero is failure
    sys.exit(1 if any(p.wait() != 0 for p in procs) else 0)


if __name__ == "__main__":
    main()
