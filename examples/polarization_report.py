"""The paper's full application: university polarity report (Tablo 6-9).

Trains both the 2-class and 3-class models and prints paper-style
tables: confusion matrices + top-10 university rankings by message
count, positive rate, and negative rate.

    PYTHONPATH=src python examples/polarization_report.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import (MRSVMConfig, SVMConfig, confusion_matrix,
                        fit_mapreduce, fit_one_vs_rest, predict)
from repro.text import CorpusConfig, fit_transform, generate, vectorize


def report_two_class():
    print("=" * 64)
    print("İki Sınıflı Model (2-class: Olumlu/Olumsuz)")
    print("=" * 64)
    corpus = generate(CorpusConfig(num_messages=3000, classes=(-1, 1)))
    X, _ = fit_transform(jnp.asarray(vectorize(corpus.texts, 4096)))
    y = jnp.asarray(corpus.labels, jnp.float32)
    cfg = MRSVMConfig(sv_capacity=256, gamma=1e-4, max_rounds=4,
                      svm=SVMConfig(C=1.0, max_epochs=15))
    model = fit_mapreduce(X, y, num_partitions=8, cfg=cfg)
    pred = np.asarray(predict(model, X, cfg))
    cm = confusion_matrix(y, jnp.asarray(pred), [-1, 1])
    print("\nTablo 6 analogue — confusion (global %):")
    print("            pred -1   pred +1")
    for i, c in enumerate([-1, 1]):
        print(f"  true {c:+d}   {cm[i, 0]:7.2f}   {cm[i, 1]:7.2f}")
    print(f"  diagonal: {np.trace(cm):.2f}%  (paper: 85.92%)")

    print("\nTablo 7 analogue — top-10 universities by message count:")
    _ranking(corpus, pred, two_class=True)
    return corpus, pred


def report_three_class():
    print("\n" + "=" * 64)
    print("Üç Sınıflı Model (3-class: Olumlu/Olumsuz/Nötr)")
    print("=" * 64)
    corpus = generate(CorpusConfig(num_messages=3000, classes=(-1, 0, 1),
                                   seed=1))
    X, _ = fit_transform(jnp.asarray(vectorize(corpus.texts, 4096)))
    y = jnp.asarray(corpus.labels, jnp.float32)
    cfg = MRSVMConfig(sv_capacity=256, max_rounds=3,
                      svm=SVMConfig(C=1.0, max_epochs=15))
    ovr = fit_one_vs_rest(X, y, [-1, 0, 1], 8, cfg)
    pred = np.asarray(ovr.predict(X))
    cm = confusion_matrix(y, jnp.asarray(pred), [-1, 0, 1])
    print("\nTablo 8 analogue — confusion (global %):")
    print("            pred -1   pred  0   pred +1")
    for i, c in enumerate([-1, 0, 1]):
        print(f"  true {c:+d}   {cm[i, 0]:7.2f}   {cm[i, 1]:7.2f}"
              f"   {cm[i, 2]:7.2f}")
    print(f"  diagonal: {np.trace(cm):.2f}%  (paper: 68.38%)")
    print("\nTablo 9 analogue — top-10 universities:")
    _ranking(corpus, pred, two_class=False)


def _ranking(corpus, pred, two_class: bool):
    rows = []
    for u, name in enumerate(corpus.university_names):
        sel = corpus.universities == u
        n = int(sel.sum())
        if n == 0:
            continue
        kind = "devlet" if corpus.university_kinds[u] == 0 else "vakıf"
        pos = float((pred[sel] > 0).mean())
        neg = float((pred[sel] < 0).mean())
        neu = float((pred[sel] == 0).mean()) if not two_class else None
        rows.append((n, name, kind, pos, neu, neg))
    rows.sort(key=lambda r: -r[0])
    hdr = f"  {'university':<28} {'kind':<7} {'n':>4}  {'pos':>5}"
    hdr += f"  {'neu':>5}" if not two_class else ""
    hdr += f"  {'neg':>5}"
    print(hdr)
    for n, name, kind, pos, neu, neg in rows[:10]:
        line = f"  {name[:28]:<28} {kind:<7} {n:>4}  {pos:5.2f}"
        line += f"  {neu:5.2f}" if neu is not None else ""
        line += f"  {neg:5.2f}"
        print(line)


if __name__ == "__main__":
    report_two_class()
    report_three_class()
