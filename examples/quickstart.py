"""Quickstart: the paper's pipeline in ~40 lines.

Synthetic Turkish-tweet corpus → Tablo-4 stopword removal → hashed
TF×IDF (eq. 10-11) → iterative MapReduce SVM (Tablo 1-2) → polarity.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import (MRSVMConfig, SVMConfig, confusion_matrix,
                        fit_mapreduce, predict)
from repro.text import CorpusConfig, fit_transform, generate, vectorize


def main():
    print("1) generating synthetic corpus (paper data is 2014 Twitter)...")
    corpus = generate(CorpusConfig(num_messages=2000, classes=(-1, 1)))
    print(f"   {len(corpus.texts)} messages, e.g.: {corpus.texts[0][:70]}...")

    print("2) TF×IDF vector space (hashed, 4096 dims)...")
    counts = vectorize(corpus.texts, num_features=4096)
    X, _ = fit_transform(jnp.asarray(counts))
    y = jnp.asarray(corpus.labels, jnp.float32)

    print("3) iterative MapReduce SVM over 8 partitions...")
    cfg = MRSVMConfig(sv_capacity=256, gamma=1e-4, max_rounds=5,
                      svm=SVMConfig(C=1.0, max_epochs=15))
    model = fit_mapreduce(X, y, num_partitions=8, cfg=cfg, verbose=True)

    pred = predict(model, X, cfg)
    acc = float(jnp.mean(pred == y))
    cm = confusion_matrix(y, pred, [-1, 1])
    print(f"4) accuracy={acc:.3f}  (paper Tablo 6 diagonal: 85.9%)")
    print("   confusion matrix (global %, rows=truth -1/+1):")
    print(np.round(cm, 2))


if __name__ == "__main__":
    main()
