"""Batched serving demo: prefill a batch of prompts, then decode with
the KV-cache serve path (greedy), reporting tokens/s.

    PYTHONPATH=src python examples/serve.py --arch tinyllama-1.1b --tokens 32
(archs run as REDUCED smoke variants on CPU; full configs are for TPU.)
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.config import smoke_variant
from repro.models.transformer import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=128)
    args = ap.parse_args()

    cfg = smoke_variant(get_config(args.arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B = args.batch
    print(f"serving {cfg.name} (reduced) batch={B} "
          f"cache={args.cache_len}")

    key = jax.random.PRNGKey(1)
    prompts = jax.random.randint(key, (B, args.prompt_len), 0,
                                 cfg.vocab_size)
    if cfg.family == "audio":
        frames = jax.random.normal(jax.random.PRNGKey(2),
                                   (B, cfg.encoder_seq, cfg.d_model))
        state = model.init_decode_state(B, args.cache_len, frames=frames,
                                        params=params)
    else:
        state = model.init_decode_state(B, args.cache_len)

    step = jax.jit(model.decode_step)
    # teacher-forced prefill through the decode path (prefill_32k-style
    # bulk prefill is the dryrun's prefill_step; here we stream)
    for t in range(args.prompt_len):
        logits, state = step(params, state, prompts[:, t:t + 1])

    tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for _ in range(args.tokens - 1):
        logits, state = step(params, state, tok)
        tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"generated {args.tokens} tokens × {B} seqs in {dt:.2f}s "
          f"→ {args.tokens * B / dt:,.0f} tok/s")
    print("sample token ids:", gen[0, :16].tolist())
    assert int(state.pos) == args.prompt_len + args.tokens - 1


if __name__ == "__main__":
    main()
