"""Streaming polarization over drifting monthly corpora — the paper's
§SONUÇ future work served live.

Two tenant streams of Twitter-style messages drift month over month.
Each month's vectorized micro-batches queue in the
:class:`~repro.serving.svm_stream.StreamingSVMService`; the async wave
scheduler folds them into each stream's SV_global (new rows ∪ carried
SVs only — the old corpus never travels) while predictions keep serving
from the double-buffered snapshot. Compare the stale model's accuracy
on the new month against the folded model's.

    PYTHONPATH=src python examples/stream_polarization.py
"""
import jax.numpy as jnp

from repro.core import MRSVMConfig, SVMConfig, fit_mapreduce
from repro.serving import StreamingSVMService
from repro.text import CorpusConfig, fit_transform, generate, vectorize
from repro.text.tfidf import transform


def month_corpus(seed: int, n: int):
    c = generate(CorpusConfig(num_messages=n, classes=(-1, 1), seed=seed))
    return c.texts, jnp.asarray(c.labels, jnp.float32)


def main():
    cfg = MRSVMConfig(sv_capacity=256, gamma=1e-4, max_rounds=4,
                      svm=SVMConfig(C=1.0, max_epochs=15))
    svc = StreamingSVMService(cfg, num_partitions=8,
                              max_batches_per_wave=4, keep_history=True)

    print("month 0: train each stream on its initial corpus")
    idfs = {}
    for tenant, seed in (("politics", 0), ("sports", 1)):
        texts, y0 = month_corpus(seed, 1200)
        X0, idf = fit_transform(jnp.asarray(vectorize(texts, 4096)))
        idfs[tenant] = idf
        model = fit_mapreduce(X0, y0, 8, cfg)
        svc.register(tenant, model)
        acc = float(jnp.mean(svc.predict(tenant, X0) == y0))
        print(f"  {tenant}: acc={acc:.3f} |SV|={int(model.sv.mask.sum())}")

    svc.start()           # async wave scheduler: folds happen off-line
    for month in (1, 2):
        batches = {}
        for tenant, seed in (("politics", 0), ("sports", 1)):
            texts, ym = month_corpus(100 * month + seed, 800)
            Xm = transform(jnp.asarray(vectorize(texts, 4096)), idfs[tenant])
            batches[tenant] = (Xm, ym)
            stale = float(jnp.mean(svc.predict(tenant, Xm) == ym))
            # split the month into micro-batches — they queue per stream
            for lo in range(0, Xm.shape[0], 400):
                svc.submit(tenant, Xm[lo:lo + 400], ym[lo:lo + 400])
            print(f"month {month} {tenant}: stale acc={stale:.3f} "
                  f"(queued {Xm.shape[0]} rows)")
        # wait until every queued batch has folded (both streams share
        # one wave — a single batched device pass updates both tenants)
        if not svc.wait_idle(timeout_s=300):
            raise RuntimeError(f"month {month} batches never folded")
        for tenant, (Xm, ym) in batches.items():
            fresh = float(jnp.mean(svc.predict(tenant, Xm) == ym))
            snap = svc.snapshot(tenant)
            print(f"month {month} {tenant}: folded acc={fresh:.3f} "
                  f"(model v{snap.version}, "
                  f"|SV|={int(snap.model.sv.mask.sum())})")
    svc.stop()
    print(svc.throughput_report())


if __name__ == "__main__":
    main()
