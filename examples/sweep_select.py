"""Model selection the paper's way, batched: train many (C, tol) SVM
variants over the TF×IDF polarization pipeline in ONE device program
(vmap-over-configs, repro.core.sweep), then pick the config with the
lowest empirical risk and report its Tablo-6-style confusion matrix.

    PYTHONPATH=src python examples/sweep_select.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import (MRSVMConfig, SVMConfig, confusion_matrix,
                        fit_mapreduce_sweep, predict_sweep, sweep_grid)
from repro.text import CorpusConfig, fit_transform, generate, vectorize


def main():
    corpus = generate(CorpusConfig(num_messages=2048, classes=(-1, 1)))
    counts = jnp.asarray(vectorize(corpus.texts, 2048))
    X, _ = fit_transform(counts)
    y = jnp.asarray(corpus.labels, jnp.float32)
    n_train = int(0.75 * X.shape[0])
    X_tr, y_tr = X[:n_train], y[:n_train]
    X_te, y_te = X[n_train:], y[n_train:]

    cfg = MRSVMConfig(sv_capacity=256, gamma=1e-4, max_rounds=5,
                      svm=SVMConfig(max_epochs=15))
    params = sweep_grid(cfg.svm,
                        C=np.logspace(-3, 1, 5).astype(np.float32),
                        tol=[1e-3, 1e-2])
    S = params.C.shape[0]
    print(f"sweeping {S} (C, tol) configs in one batched program "
          f"({n_train} train rows, {X.shape[1]} features)")

    res = fit_mapreduce_sweep(X_tr, y_tr, 8, cfg, params, verbose=True)
    preds = predict_sweep(res, X_te, cfg)
    accs = np.asarray(jnp.mean(preds == y_te[None, :], axis=1))
    for s in range(S):
        tag = " ← selected" if s == res.best else ""
        print(f"  C={float(params.C[s]):<9.4g} tol={float(params.tol[s]):<7.0e}"
              f" R_emp={float(res.risks[s]):.4f} "
              f"held-out acc={accs[s]:.3f} rounds={int(res.rounds[s])}{tag}")

    cm = confusion_matrix(y_te, preds[res.best], [-1, 1])
    print("\nconfusion matrix of the selected config "
          "(global %, Tablo 6 convention):")
    print(np.round(cm, 2))
    print("\nrow-normalized (per-class recall %):")
    print(np.round(confusion_matrix(y_te, preds[res.best], [-1, 1],
                                    normalize="true"), 2))


if __name__ == "__main__":
    main()
