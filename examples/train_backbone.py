"""End-to-end training driver: data pipeline → sharded train_step →
checkpointing → metrics. The e2e deliverable (train a ~100M model for
a few hundred steps).

CPU-friendly default is a 20M model at short context so a few hundred
steps finish in minutes; ``--preset 100m`` selects the ~100M-parameter
configuration (sized for a real accelerator).

    PYTHONPATH=src python examples/train_backbone.py --steps 200
    PYTHONPATH=src python examples/train_backbone.py --preset 100m --steps 300
"""
import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp

from repro import optim
from repro.ckpt import restore, save
from repro.metrics import MetricsLogger
from repro.data import DataConfig, lm_batch_at
from repro.models.config import ModelConfig
from repro.models.transformer import build_model

PRESETS = {
    # ~20M params: CPU-demo scale
    "20m": ModelConfig(name="demo-20m", family="dense", num_layers=6,
                       d_model=384, num_heads=6, num_kv_heads=2, d_ff=1024,
                       vocab_size=8192, tie_embeddings=True),
    # ~100M params: the deliverable scale (llama-style)
    "100m": ModelConfig(name="demo-100m", family="dense", num_layers=10,
                        d_model=640, num_heads=10, num_kv_heads=2, d_ff=2560,
                        vocab_size=32000, tie_embeddings=False),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="20m", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    model = build_model(cfg)
    n_params = sum(x.size for x in jax.tree.leaves(model.abstract()))
    print(f"model={cfg.name} params={n_params / 1e6:.1f}M "
          f"devices={jax.devices()}")

    params = model.init(jax.random.PRNGKey(0))
    ocfg = optim.OptConfig(lr=args.lr, warmup_steps=20,
                           total_steps=args.steps)
    opt_state = optim.init(params)
    dcfg = DataConfig(batch_size=args.batch, seq_len=args.seq, seed=0)
    start = 0
    ckpt_path = os.path.join(args.ckpt_dir, f"{cfg.name}.npz")
    if args.resume and os.path.exists(ckpt_path):
        from repro.ckpt import latest_step
        state = restore(ckpt_path, {"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        start = latest_step(args.ckpt_dir) or 0
        print(f"resumed from step {start}")

    @jax.jit
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(params, batch)
        params, opt_state, om = optim.apply_updates(
            params, grads, opt_state, ocfg)
        return params, opt_state, {"loss": loss, **om}

    t0 = time.time()
    first_loss = None
    mlog = MetricsLogger(args.ckpt_dir, f"{cfg.name}_metrics")
    for step in range(start, start + args.steps):
        batch = {k: jnp.asarray(v)
                 for k, v in lm_batch_at(dcfg, cfg, step).items()}
        params, opt_state, m = train_step(params, opt_state, batch)
        mlog.log(step, loss=float(m["loss"]), lr=float(m["lr"]),
                 grad_norm=float(m["grad_norm"]))
        if step % 20 == 0 or step == start + args.steps - 1:
            loss = float(m["loss"])
            first_loss = first_loss if first_loss is not None else loss
            tok_s = (step - start + 1) * args.batch * args.seq / \
                (time.time() - t0)
            print(f"step {step:5d}  loss {loss:.4f}  "
                  f"lr {float(m['lr']):.2e}  gnorm {float(m['grad_norm']):.2f}  "
                  f"tok/s {tok_s:,.0f}")
        if args.ckpt_every and (step + 1) % args.ckpt_every == 0:
            save(ckpt_path, {"params": params, "opt": opt_state},
                 step=step + 1)
    final_loss = float(m["loss"])
    save(ckpt_path, {"params": params, "opt": opt_state},
         step=start + args.steps)
    mlog.flush()
    print(f"done: loss {first_loss:.3f} → {final_loss:.3f} "
          f"({time.time() - t0:.0f}s); ckpt at {ckpt_path}; "
          f"metrics {mlog.summary('loss')}")
    assert final_loss < first_loss, "training did not reduce loss"


if __name__ == "__main__":
    main()
