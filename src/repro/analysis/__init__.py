"""repro.analysis — jaxpr/HLO invariant linter (DESIGN.md §14).

Static analysis over traced jaxprs and compiled post-SPMD HLO that
turns the repo's prose invariants into machine-checkable rules, each
with an explicit allowlist mechanism:

1. collective-schedule (:mod:`.schedule`) — every compiled program's
   ordered collective sequence is structurally valid (start/done
   pairing, deadlock-free permute hops, disjoint replica groups) and
   agrees across participants; committed dry-run artifacts stay in
   sync with fresh compiles.
2. retrace (:mod:`.retrace`) — steady-state hot regions (streaming
   waves, sweep rounds past the first) must hit the jit cache.
3. host-sync (:mod:`.hostsync`) — hot loops synchronize with the
   device only at their named readback points.
4. dense-materialization (:mod:`.denseleak`) — sparse programs never
   inflate an O(n·d) dense row block outside the chunked densify.
5. dtype-drift (:mod:`.dtype_drift`) — solver-state leaves (y/α/w/b)
   never pass a reduced-precision op outside the bf16 wire pack.

Entry points: ``make lint-jax`` → :mod:`repro.analysis.lint` (the full
matrix over the real step builders), ``tests/test_analysis.py`` (the
pytest tier), and the per-module check functions below for use inside
drivers (``core.sweep``, ``serving.svm_stream``).
"""
from repro.analysis.base import Allowed, LintViolation, RuleReport
from repro.analysis.denseleak import (DEFAULT_MAX_DENSE_ROWS,
                                      check_memory_ceiling,
                                      check_no_dense_materialization)
from repro.analysis.dtype_drift import check_no_dtype_drift
from repro.analysis.hlo import (CollectiveOp, dtype_nbits,
                                parse_collective_ops, tensor_nbytes,
                                tensor_shapes, while_body_computations)
from repro.analysis.hostsync import (allowed_host_sync,
                                     check_no_host_callbacks,
                                     host_guards_enforced,
                                     no_implicit_host_sync)
from repro.analysis.retrace import (RetraceError, RetraceStats, no_retrace,
                                    watch_compiles)
from repro.analysis.schedule import (assert_schedules_agree, check_schedule,
                                     collective_schedule,
                                     compare_collective_counts)

__all__ = [
    "Allowed", "LintViolation", "RuleReport",
    "CollectiveOp", "dtype_nbits", "parse_collective_ops",
    "tensor_nbytes", "tensor_shapes", "while_body_computations",
    "collective_schedule", "check_schedule", "assert_schedules_agree",
    "compare_collective_counts",
    "RetraceError", "RetraceStats", "no_retrace", "watch_compiles",
    "allowed_host_sync", "check_no_host_callbacks",
    "host_guards_enforced", "no_implicit_host_sync",
    "DEFAULT_MAX_DENSE_ROWS", "check_memory_ceiling",
    "check_no_dense_materialization",
    "check_no_dtype_drift",
]
