"""Shared vocabulary of the invariant linter (DESIGN.md §14).

Every rule reports through :class:`LintViolation` — one exception type
carrying (rule, program, op, detail) so `make lint-jax` and the pytest
tier print uniform, greppable messages naming the offending op AND the
program it appeared in. Rules never print-and-continue: a violation is
an exception, an allowlisted occurrence is silence plus an entry in the
returned report, so CI cannot drift into warning blindness.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


class LintViolation(AssertionError):
    """An invariant rule fired. ``rule``/``program``/``op`` are
    structured so tests can assert on WHAT failed, not on message
    prose."""

    def __init__(self, rule: str, program: str, op: str, detail: str):
        self.rule = rule
        self.program = program
        self.op = op
        self.detail = detail
        super().__init__(
            f"[{rule}] program={program!r} op={op!r}: {detail}")


@dataclasses.dataclass(frozen=True)
class Allowed:
    """One allowlisted occurrence: recorded, never raised. Rules return
    these so a reviewer can audit exactly what the allowlist absorbed
    (an allowlist that silently swallows everything is the bug the
    linter exists to prevent)."""
    rule: str
    program: str
    op: str
    reason: str


@dataclasses.dataclass(frozen=True)
class RuleReport:
    """Outcome of one rule over one program (returned on success; on
    failure the rule raises :class:`LintViolation` instead)."""
    rule: str
    program: str
    checked: int                       # ops/eqns the rule examined
    allowed: Tuple[Allowed, ...] = ()
    note: Optional[str] = None         # e.g. 'skipped: no memory_analysis'
