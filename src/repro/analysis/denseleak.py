"""Rule 4 — dense-materialization lint (DESIGN.md §14).

The sparse path's whole value proposition (PR 6) is that nothing ever
materializes an O(n·d) dense row block at vocabulary-scale ``d`` — the
one sanctioned densify is ``sparse.cross_dots``'s chunked scatter
(``chunk`` rows of scratch at a time, default 64) on the serve/Gram
path. A future edit that densifies a whole shard (`rows_to_dense`
applied to the batch, a stray ``@`` against a dense identity) silently
re-inflates memory by 100×+; this rule makes that a lint failure.

Two layers:

* :func:`check_no_dense_materialization` — jaxpr scan: any intermediate
  whose trailing dim is the feature dim ``d`` and whose leading dims
  multiply past ``max_dense_rows`` is a violation. The ceiling IS the
  allowlist: the chunked densify stays under it by construction.
* :func:`check_memory_ceiling` — the compiled program's
  ``memory_analysis().temp_size_in_bytes`` must stay under a caller-
  derived ceiling (e.g. a fraction of the dense block's bytes); skipped
  with a note where the backend exposes no memory analysis.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.analysis.base import LintViolation, RuleReport
from repro.analysis.hostsync import _iter_eqns

RULE = "dense-materialization"

# the sanctioned scratch width of sparse.cross_dots plus headroom for a
# vmapped config axis on top of it
DEFAULT_MAX_DENSE_ROWS = 256


def check_no_dense_materialization(
        fn, args, *, d: int,
        max_dense_rows: int = DEFAULT_MAX_DENSE_ROWS,
        program: str = "<program>") -> RuleReport:
    """Trace ``fn(*args)`` and reject intermediates of shape
    ``(..., d)`` with more than ``max_dense_rows`` leading rows. Run
    this on ``row_format='sparse'`` programs only — the dense path
    materializes (n, d) blocks by design."""
    jaxpr = jax.make_jaxpr(fn)(*args)
    checked = 0
    for eqn in _iter_eqns(jaxpr):
        for var in eqn.outvars:
            aval = getattr(var, "aval", None)
            shape = getattr(aval, "shape", None)
            if not shape or len(shape) < 2 or shape[-1] != d:
                continue
            checked += 1
            rows = 1
            for s in shape[:-1]:
                rows *= int(s)
            if rows > max_dense_rows:
                raise LintViolation(
                    RULE, program, eqn.primitive.name,
                    f"intermediate of shape {tuple(shape)} materializes "
                    f"{rows} dense rows at feature dim d={d} "
                    f"(ceiling: {max_dense_rows} rows — the chunked "
                    "cross_dots densify). A sparse program must never "
                    "inflate a full row block.")
    return RuleReport(rule=RULE, program=program, checked=checked)


def check_memory_ceiling(compiled, *, limit_bytes: int,
                         program: str = "<program>") -> RuleReport:
    """Compiled-program temp memory must stay under ``limit_bytes``.
    Callers derive the limit from the dense block the program must NOT
    allocate (e.g. ``n_rows * d * itemsize // 2``)."""
    mem = _memory_analysis(compiled)
    temp = getattr(mem, "temp_size_in_bytes", None) if mem else None
    if temp is None:
        return RuleReport(rule=RULE, program=program, checked=0,
                          note="skipped: backend exposes no "
                               "memory_analysis")
    if int(temp) > limit_bytes:
        raise LintViolation(
            RULE, program, "memory_analysis.temp_size_in_bytes",
            f"compiled temp memory {int(temp)} B exceeds the sparse "
            f"ceiling {limit_bytes} B — an O(n·d) dense intermediate "
            "is being materialized")
    return RuleReport(rule=RULE, program=program, checked=1)


def _memory_analysis(compiled) -> Optional[object]:
    try:
        return compiled.memory_analysis()
    except Exception:
        return None
