"""Rule 5 — dtype-drift lint (DESIGN.md §14).

Solver state — the labels ``y``, duals ``α``, hypothesis ``(w, b)`` —
is f32 by contract (``core.svm.fit_binary_linear`` promotes), and the
ONE sanctioned reduced-precision passage is the ring transport's bf16
wire pack, which immediately ``bitcast_convert_type``s the bf16 pairs
into f32 lanes (``core.mapreduce_svm._pack_lanes``). Anything else —
a stray ``.astype(cfg.dtype)`` on ``α``, a bf16 matmul pulling ``y``
down — is silent precision loss eq. 7/eq. 8 convergence then inherits.

Mechanism: forward taint propagation over the traced jaxpr. Caller
marks the solver-state input leaves; taint flows through every eqn
(control-flow sub-jaxprs included, ``while``/``scan`` carries to a
fixpoint) EXCEPT comparison-family ops, whose boolean outputs carry no
precision. A ``convert_element_type`` of a tainted value from a ≥32-bit
float to a narrower float is a violation — unless its result reaches a
``bitcast_convert_type`` through layout-only ops (the wire-pack
allowlist), or the caller allowlists the convert's source line.
"""
from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp

from repro.analysis.base import Allowed, LintViolation, RuleReport

RULE = "dtype-drift"

# ops that only rearrange bits between a downcast and the wire bitcast
_LAYOUT_PRIMS = frozenset({
    "reshape", "broadcast_in_dim", "squeeze", "expand_dims", "transpose",
    "slice", "dynamic_slice", "pad", "concatenate", "rev", "copy",
})
# outputs are boolean/ordinal structure, not solver precision
_STOP_PRIMS = frozenset({
    "eq", "ne", "lt", "gt", "ge", "le", "is_finite", "sign",
    "argmax", "argmin", "reduce_and", "reduce_or", "iota",
})


def _is_literal(v) -> bool:
    # jaxpr Literals carry .val; Vars don't. Structural test so the
    # 0.4.x→0.8.x jax.core/jax.extend.core move can't break us.
    return hasattr(v, "val")


def _is_float(aval) -> bool:
    dt = getattr(aval, "dtype", None)
    return dt is not None and jnp.issubdtype(dt, jnp.floating)


def _itemsize(aval) -> int:
    return jnp.dtype(aval.dtype).itemsize


def _source_line(eqn) -> str:
    try:
        from jax._src import source_info_util
        return source_info_util.summarize(eqn.source_info)
    except Exception:
        return "<unknown source>"


def _sub_positional(eqn):
    """(sub_jaxpr, …) when the eqn is a plain call-like wrapper whose
    invars/outvars map positionally (pjit, shard_map, remat, custom_*).
    ``while``/``scan``/``cond`` are handled structurally by the
    propagator and excluded here."""
    if eqn.primitive.name in ("while", "scan", "cond"):
        return None
    subs = []
    for v in eqn.params.values():
        for k in (v if isinstance(v, (tuple, list)) else (v,)):
            inner = getattr(k, "jaxpr", k)
            if hasattr(inner, "eqns"):
                subs.append(inner)
    if len(subs) == 1 and len(subs[0].invars) == len(eqn.invars):
        return subs[0]
    return None


def _layout_flow(jaxpr, start_vars) -> tuple:
    """Wire-pack allowlist reachability: does any var in ``start_vars``
    reach a ``bitcast_convert_type`` through layout-only ops? One
    forward pass (eqns are topologically ordered), descending into
    call-like sub-jaxprs — ``jnp.pad`` et al. trace as ``pjit[name=_pad]``
    wrappers, so the pack pipeline crosses call boundaries. Returns
    ``(hit_bitcast, reached_output_positions)``."""
    reached = set(start_vars)
    hit = False
    for eqn in jaxpr.eqns:
        in_hits = [i for i, v in enumerate(eqn.invars)
                   if not _is_literal(v) and v in reached]
        if not in_hits:
            continue
        name = eqn.primitive.name
        if name == "bitcast_convert_type":
            hit = True
            continue
        sub = _sub_positional(eqn)
        if sub is not None:
            sub_hit, sub_out = _layout_flow(
                sub, {sub.invars[i] for i in in_hits})
            hit = hit or sub_hit
            for j in sub_out:
                if j < len(eqn.outvars):
                    reached.add(eqn.outvars[j])
        elif name in _LAYOUT_PRIMS:
            reached.update(eqn.outvars)
    out_pos = {j for j, v in enumerate(jaxpr.outvars)
               if not _is_literal(v) and v in reached}
    return hit, out_pos


class _Prop:
    def __init__(self, program: str, allow_lines: Sequence[str]):
        self.program = program
        self.allow_lines = tuple(allow_lines)
        self.checked = 0
        self.allowed: List[Allowed] = []

    def run(self, jaxpr, in_taint: Sequence[bool]) -> List[bool]:
        """Propagate taint through one (open) jaxpr; returns out-taint.
        Downcast checks happen inline; the wire-pack allowlist is
        resolved against this jaxpr's consumer graph."""
        env = {}
        for var in jaxpr.constvars:
            env[var] = False
        if len(in_taint) != len(jaxpr.invars):
            raise ValueError(
                f"taint mask has {len(in_taint)} entries for "
                f"{len(jaxpr.invars)} jaxpr inputs ({self.program})")
        for var, t in zip(jaxpr.invars, in_taint):
            env[var] = bool(t)

        def read(v) -> bool:
            return False if _is_literal(v) else env.get(v, False)

        pending = []                       # (eqn, detail) downcasts
        for eqn in jaxpr.eqns:
            self.checked += 1
            name = eqn.primitive.name
            ts = [read(v) for v in eqn.invars]
            any_t = any(ts)

            if name == "while":
                out = self._while(eqn, ts)
            elif name == "scan":
                out = self._scan(eqn, ts)
            elif name == "cond":
                out = self._cond(eqn, ts)
            else:
                sub = _sub_positional(eqn)
                if sub is not None:
                    sub_out = self.run(sub, ts)
                    out = sub_out if len(sub_out) == len(eqn.outvars) \
                        else [any(sub_out)] * len(eqn.outvars)
                elif name in _STOP_PRIMS:
                    out = [False] * len(eqn.outvars)
                else:
                    if (name == "convert_element_type" and any_t
                            and _is_float(eqn.invars[0].aval)
                            and _itemsize(eqn.invars[0].aval) >= 4
                            and _is_float(eqn.outvars[0].aval)
                            and _itemsize(eqn.outvars[0].aval) < 4):
                        pending.append((eqn, (
                            f"solver state downcast "
                            f"{eqn.invars[0].aval.dtype}→"
                            f"{eqn.outvars[0].aval.dtype} at "
                            f"{_source_line(eqn)}")))
                    out = [any_t] * len(eqn.outvars)
            for var, t in zip(eqn.outvars, out):
                env[var] = bool(t)

        for eqn, detail in pending:
            if _layout_flow(jaxpr, {eqn.outvars[0]})[0]:
                self.allowed.append(Allowed(
                    RULE, self.program, "convert_element_type",
                    "bf16 wire pack (result bitcast into f32 lanes)"))
            elif any(tag in detail for tag in self.allow_lines):
                self.allowed.append(Allowed(
                    RULE, self.program, "convert_element_type",
                    f"caller allowlist: {detail}"))
            else:
                raise LintViolation(RULE, self.program,
                                    "convert_element_type", detail)
        return [read(v) for v in jaxpr.outvars]

    # -- control flow --------------------------------------------------

    def _while(self, eqn, ts):
        cn = eqn.params["cond_nconsts"]
        bn = eqn.params["body_nconsts"]
        body = getattr(eqn.params["body_jaxpr"], "jaxpr",
                       eqn.params["body_jaxpr"])
        body_consts = ts[cn:cn + bn]
        carry = list(ts[cn + bn:])
        for _ in range(len(carry) + 1):
            out = self.run(body, body_consts + carry)
            new = [a or b for a, b in zip(carry, out)]
            if new == carry:
                break
            carry = new
        return carry

    def _scan(self, eqn, ts):
        nc = eqn.params["num_consts"]
        ncar = eqn.params["num_carry"]
        body = getattr(eqn.params["jaxpr"], "jaxpr", eqn.params["jaxpr"])
        consts, carry, xs = ts[:nc], list(ts[nc:nc + ncar]), ts[nc + ncar:]
        ys_taint = [False] * (len(eqn.outvars) - ncar)
        for _ in range(len(carry) + 1):
            out = self.run(body, consts + carry + xs)
            new = [a or b for a, b in zip(carry, out[:ncar])]
            ys_taint = [a or b for a, b in zip(ys_taint, out[ncar:])]
            if new == carry:
                break
            carry = new
        return carry + ys_taint

    def _cond(self, eqn, ts):
        out = [False] * len(eqn.outvars)
        for br in eqn.params["branches"]:
            sub = getattr(br, "jaxpr", br)
            b_out = self.run(sub, ts[1:])
            out = [a or b for a, b in zip(out, b_out)]
        return out


def check_no_dtype_drift(fn, args, *, taint: Sequence[bool],
                         program: str = "<program>",
                         allow_lines: Sequence[str] = ()) -> RuleReport:
    """Trace ``fn(*args)`` and verify no tainted (solver-state) value
    passes through a reduced-precision convert outside the wire-pack
    allowlist. ``taint`` aligns with ``jax.tree_util.tree_leaves(args)``
    — True marks a solver-state leaf (y/α/w/b). ``allow_lines`` adds
    caller-sanctioned source substrings (file:line) to the allowlist."""
    closed = jax.make_jaxpr(fn)(*args)
    flat = len(jax.tree_util.tree_leaves(args))
    if len(taint) != flat:
        raise ValueError(f"taint mask has {len(taint)} entries for "
                         f"{flat} argument leaves")
    prop = _Prop(program, allow_lines)
    prop.run(closed.jaxpr, list(taint))
    return RuleReport(rule=RULE, program=program, checked=prop.checked,
                      allowed=tuple(prop.allowed))
