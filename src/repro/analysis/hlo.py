"""Hardened post-SPMD HLO text parser — the extraction layer under both
the collective-schedule rule and ``launch.hlo_analysis`` roofline math.

The compiled artifact JAX exposes portably is ``compiled.as_text()``;
this module turns that text into structured :class:`CollectiveOp`
records instead of the loose per-line regex scan the roofline gate grew
up with. Hardened over the original `launch/hlo_analysis.py` scan:

* tuple-typed outputs — ``(f32[8]{0}, u32[], token[])`` — yield every
  element's (dtype, dims), not just the ones a byte table knows;
* ``ROOT``-prefixed ops and ``-start``/``-done`` async pairs;
* full ``replica_groups={{0,1},{2,3}}`` group lists AND the iota form
  ``replica_groups=[2,4]<=[8]``;
* ``source_target_pairs`` of collective-permute (the ring transport's
  deadlock surface);
* computation attribution: every op knows which HLO computation it
  appeared in, and :func:`while_body_computations` names the ones that
  re-execute per loop trip (the EXPERIMENTS.md scan-counting caveat,
  now machine-readable).

Unknown dtypes no longer vanish: ``tensor_nbytes`` falls back to a
conservative 4-byte estimate and warns once per dtype, so a new XLA
narrow type (``f8e4m3``, ``u4``) can only OVERcount the perf gate's
wire bytes, never silently undercount them (ISSUE 8 satellite).
"""
from __future__ import annotations

import dataclasses
import math
import re
import warnings
from typing import List, Optional, Tuple

# Bits, not bytes: the sub-byte types (u4/s4, the fp8 family's 8) and
# pred pack differently on device, but wire math wants logical size.
_DTYPE_BITS = {
    "pred": 8,
    "s2": 2, "u2": 2, "s4": 4, "u4": 4,
    "f4e2m1fn": 4,
    "s8": 8, "u8": 8,
    "f8e5m2": 8, "f8e4m3": 8, "f8e4m3fn": 8, "f8e4m3b11fnz": 8,
    "f8e5m2fnuz": 8, "f8e4m3fnuz": 8, "f8e8m0fnu": 8,
    "s16": 16, "u16": 16, "bf16": 16, "f16": 16,
    "s32": 32, "u32": 32, "f32": 32, "tf32": 32,
    "s64": 64, "u64": 64, "f64": 64, "c64": 64,
    "c128": 128,
}
_FALLBACK_BITS = 32            # conservative: overcount, never undercount
_warned_dtypes = set()

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute",
                    "collective-broadcast")

_TYPE_RE = re.compile(r"([\w]+)\[([\d,]*)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{(\{[\d,]+\}(?:,\{[\d,]+\})*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_PAIRS_RE = re.compile(r"source_target_pairs=\{(\{\d+,\d+\}(?:,\{\d+,\d+\})*)\}")
_CHANNEL_RE = re.compile(r"channel_id=(\d+)")
_PAIR_RE = re.compile(r"\{(\d+),(\d+)\}")
_OP_RE = re.compile(
    r"^\s*(?P<root>ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*"
    r"(?P<out>\([^=]*?\)|[\w]+\[[\d,]*\](?:\{[\d,]*\})?)\s+"
    r"(?P<op>[\w\-]+)\(", re.M)
# computation header: '%name (args) -> type {' or 'ENTRY %name ... {',
# always at column 0 in printed HLO
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\([^)]*")
_WHILE_ATTR_RE = re.compile(r"(?:body|condition)=%?([\w.\-]+)")


def tensor_shapes(type_str: str) -> List[Tuple[str, Tuple[int, ...]]]:
    """Every (dtype, dims) in an HLO type string — tuple types yield all
    elements. ``token``/opaque pseudo-types carry no ``[dims]`` and are
    skipped by construction."""
    out = []
    for dt, dims in _TYPE_RE.findall(type_str):
        shape = tuple(int(d) for d in dims.split(",") if d)
        out.append((dt, shape))
    return out


def dtype_nbits(dt: str) -> int:
    """Logical bit width of an HLO dtype; unknown types warn once and
    fall back to a conservative 32 bits."""
    bits = _DTYPE_BITS.get(dt)
    if bits is None:
        if dt not in _warned_dtypes:
            _warned_dtypes.add(dt)
            warnings.warn(
                f"hlo parser: unknown dtype {dt!r}; counting it as "
                f"{_FALLBACK_BITS} bits (conservative overcount)",
                stacklevel=2)
        bits = _FALLBACK_BITS
    return bits


def tensor_nbytes(type_str: str) -> List[int]:
    """Byte size of every tensor in a type string (tuples flattened).
    Sub-byte element types round the total up to whole bytes."""
    sizes = []
    for dt, shape in tensor_shapes(type_str):
        n = 1
        for d in shape:
            n *= d
        sizes.append(math.ceil(n * dtype_nbits(dt) / 8))
    return sizes


@dataclasses.dataclass(frozen=True)
class CollectiveOp:
    """One collective in a compiled program, in textual order."""
    kind: str                                   # base: 'all-gather', …
    name: str                                   # HLO result name
    computation: str                            # owning computation
    shapes: Tuple[Tuple[str, Tuple[int, ...]], ...]   # output (dtype, dims)
    replica_groups: Optional[Tuple[Tuple[int, ...], ...]] = None
    iota_groups: Optional[Tuple[int, int]] = None     # (group_size, ngroups)
    source_target_pairs: Optional[Tuple[Tuple[int, int], ...]] = None
    channel_id: Optional[int] = None
    is_start: bool = False
    is_done: bool = False
    line: str = ""

    @property
    def group_size(self) -> int:
        if self.replica_groups:
            return max(len(g) for g in self.replica_groups)
        if self.iota_groups:
            return self.iota_groups[0]
        if self.source_target_pairs is not None:
            return 1
        return 1

    @property
    def max_nbytes(self) -> int:
        sizes = [math.ceil(_nelems(s) * dtype_nbits(dt) / 8)
                 for dt, s in self.shapes]
        return max(sizes) if sizes else 0

    def signature(self) -> tuple:
        """Schedule identity: what every participant must agree on.
        Names/channel ids are compiler-run-local and excluded."""
        return (self.kind, self.shapes, self.replica_groups,
                self.iota_groups, self.source_target_pairs)


def _nelems(shape: Tuple[int, ...]) -> int:
    n = 1
    for d in shape:
        n *= d
    return n


def _classify(op: str) -> Tuple[Optional[str], bool, bool]:
    """(base kind, is_start, is_done) of an HLO opcode, or (None, …)."""
    for kind in COLLECTIVE_KINDS:
        if op == kind:
            return kind, False, False
        if op == kind + "-start":
            return kind, True, False
        if op == kind + "-done":
            return kind, False, True
    return None, False, False


def _parse_groups(line: str):
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return tuple(tuple(int(x) for x in g.split(","))
                     for g in m.group(1)[1:-1].split("},{")), None
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return None, (int(m.group(2)), int(m.group(1)))
    return None, None


def parse_collective_ops(hlo_text: str) -> List[CollectiveOp]:
    """All collectives of a compiled module, in textual order, with
    computation attribution. ``-done`` halves of async pairs are
    included (callers filter on ``is_done`` — the roofline counts the
    start, the schedule checker pairs them)."""
    ops: List[CollectiveOp] = []
    computation = "<module>"
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        if line.endswith("{") and not raw[:1].isspace():
            m = _COMP_RE.match(line)
            if m:
                computation = m.group(1)
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        kind, is_start, is_done = _classify(m.group("op"))
        if kind is None:
            continue
        groups, iota = _parse_groups(line)
        pm = _PAIRS_RE.search(line)
        pairs = (tuple((int(a), int(b))
                       for a, b in _PAIR_RE.findall(pm.group(1)))
                 if pm else None)
        cm = _CHANNEL_RE.search(line)
        ops.append(CollectiveOp(
            kind=kind, name=m.group("name"), computation=computation,
            shapes=tuple(tensor_shapes(m.group("out"))),
            replica_groups=groups, iota_groups=iota,
            source_target_pairs=pairs,
            channel_id=int(cm.group(1)) if cm else None,
            is_start=is_start, is_done=is_done, line=line.strip()))
    return ops


def while_body_computations(hlo_text: str) -> frozenset:
    """Names of computations that re-execute per while-loop trip (their
    collectives appear ONCE in text but run once per trip — the scan
    caveat `launch.dryrun --measure` corrects for)."""
    out = set()
    for raw in hlo_text.splitlines():
        if " while(" in raw or "while-start(" in raw:
            for name in _WHILE_ATTR_RE.findall(raw):
                out.add(name)
    return frozenset(out)
