"""Rule 3 — host-sync lint (DESIGN.md §14).

Hot loops (`StreamingSVMService.run_wave`, `core.sweep._run_rounds`)
may synchronize with the device ONLY at their designed readback points
(the eq. 8 convergence risks). Two layers:

* runtime guard — :func:`no_implicit_host_sync` arms JAX's
  ``transfer_guard_device_to_host("disallow")`` for a region; the
  designed readbacks are wrapped in :func:`allowed_host_sync` (a nested
  ``"allow"`` guard — the innermost guard wins), which IS the explicit
  allowlist: every sanctioned sync point is named in source at the call
  site. On the CPU backend device buffers are host-resident, so the
  guard physically cannot fire there — it is the TPU/GPU tripwire; the
  static layer below is the backend-independent check.
* static lint — :func:`check_no_host_callbacks` walks the jaxpr of a
  hot-loop program and rejects host-callback primitives
  (``pure_callback``, ``io_callback``, ``debug_callback`` — each one an
  implicit device→host round-trip per call) anywhere in the traced
  program, including sub-jaxprs.
"""
from __future__ import annotations

import contextlib
from typing import Collection, Tuple

import jax

from repro.analysis.base import Allowed, LintViolation, RuleReport

RULE = "host-sync"

# one device→host round-trip per executed call, each
_CALLBACK_PRIMS = ("pure_callback", "io_callback", "debug_callback",
                   "outside_call", "host_callback_call")


@contextlib.contextmanager
def no_implicit_host_sync():
    """Arm the implicit device→host transfer tripwire for a region."""
    with jax.transfer_guard_device_to_host("disallow"):
        yield


@contextlib.contextmanager
def allowed_host_sync(reason: str):
    """A designed sync point inside a :func:`no_implicit_host_sync`
    region. ``reason`` is deliberately mandatory: the allowlist lives
    in source, next to the readback it sanctions."""
    del reason                       # documentation-only, by design
    with jax.transfer_guard_device_to_host("allow"):
        yield


def host_guards_enforced() -> bool:
    """Whether this backend can fire the runtime guard at all (False on
    CPU, where 'device' buffers already live in host memory)."""
    import numpy as np
    x = jax.numpy.zeros((), jax.numpy.float32)
    try:
        with jax.transfer_guard_device_to_host("disallow"):
            np.asarray(x)
        return False
    except Exception:
        return True


def _iter_eqns(jaxpr):
    """Every eqn of a (closed) jaxpr, sub-jaxprs included."""
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in _sub_jaxprs(eqn):
            yield from _iter_eqns(sub)


def _sub_jaxprs(eqn) -> Tuple:
    subs = []
    for v in eqn.params.values():
        kinds = v if isinstance(v, (tuple, list)) else (v,)
        for k in kinds:
            if hasattr(k, "eqns") or hasattr(getattr(k, "jaxpr", None),
                                             "eqns"):
                subs.append(k)
    return tuple(subs)


def check_no_host_callbacks(fn, args, program: str = "<program>",
                            allow: Collection[str] = ()) -> RuleReport:
    """Trace ``fn(*args)`` (ShapeDtypeStructs welcome) and reject
    host-callback primitives. ``allow`` names primitives explicitly
    sanctioned for this program (e.g. a deliberate ``io_callback`` in a
    checkpoint path)."""
    jaxpr = jax.make_jaxpr(fn)(*args)
    checked = 0
    allowed = []
    for eqn in _iter_eqns(jaxpr):
        checked += 1
        name = eqn.primitive.name
        if name in _CALLBACK_PRIMS or "callback" in name:
            if name in allow:
                allowed.append(Allowed(RULE, program, name,
                                       "caller allowlist"))
                continue
            raise LintViolation(
                RULE, program, name,
                "host-callback primitive inside a hot-loop program — "
                "one implicit device→host round-trip per call (move it "
                "out of the loop or allowlist it explicitly)")
    return RuleReport(rule=RULE, program=program, checked=checked,
                      allowed=tuple(allowed))
