"""`make lint-jax` — run the invariant rules against the real programs.

Matrix (static rules): every SVM step builder — ``build_svm_round_step``,
``build_svm_sweep_step``, ``build_svm_serve_step`` — under every shuffle
transport in ``SHUFFLE_IMPLS`` (``allgather``/``ring``/``hier``) and
both row formats (``dense``/``sparse_csr``) on an 8-device host mesh:

* host-sync: the traced program contains no host-callback primitive;
* dtype-drift: solver-state leaves (y/α) never downcast outside the
  bf16 wire-pack allowlist;
* dense-materialization (sparse programs): no intermediate inflates a
  dense row block past the chunked-densify ceiling, and the compiled
  temp memory stays under the dense block the program must not
  allocate;
* collective-schedule: each compiled program's schedule is structurally
  valid, and two independent builds of the same program extract the
  SAME ordered schedule (the single-process determinism proxy for
  cross-process agreement).

Dynamic rules: a real ``fit_mapreduce_sweep`` under
``no_implicit_host_sync`` with ``fail_on_retrace=True``, and a
``StreamingSVMService(fail_on_retrace=True)`` folding two
identically-shaped waves — the second must hit the jit cache.

Modes:
    python -m repro.analysis.lint                # the full matrix
    python -m repro.analysis.lint --artifacts D  # committed dry-run
        artifacts: re-compile each recorded (shape, mesh, transport)
        and fail if the schedule is invalid or the recorded collective
        counts went stale (the CI gate over benchmarks/artifacts/)
    python -m repro.analysis.lint --self-test    # seed one violation
        per rule family and require the rule to fire naming it
"""
from __future__ import annotations

import os
import sys


def _force_host_devices() -> None:
    # Artifact mode re-compiles against the production 16x16 / 2x16x16
    # meshes; the matrix runs on a small 8-device host mesh. Must be
    # set before first backend init (jax locks the device count).
    n = 512 if "--artifacts" in sys.argv else 8
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + f" --xla_force_host_platform_device_count={n}").strip()
    os.environ.setdefault("JAX_PLATFORMS", "cpu")


if __name__ == "__main__":
    _force_host_devices()

import argparse
import dataclasses
import glob
import json


# ---------------------------------------------------------------------------
# Harness configuration: small shapes, meaningful invariants.
# ---------------------------------------------------------------------------

# The feature dim is what the dense-leak ceiling keys on; the
# per-device row count is chosen ABOVE the ceiling so densifying a
# whole shard is a detectable violation, not noise under it.
LINT_FEATURES = 512
LINT_ROWS_PER_DEVICE = 512
LINT_SV_CAPACITY = 32
LINT_NNZ_CAP = 32
NUM_CONFIGS = 4
NUM_STREAMS = 4


def _lint_cfg(row_format: str):
    from repro.configs.svm_tfidf import SVMTfidfConfig
    # dtype is forced to f32: the dtype-drift rule tracks solver state
    # staying f32, which the bf16-featured default would trivialize.
    return dataclasses.replace(
        SVMTfidfConfig(), dtype="float32", num_features=LINT_FEATURES,
        rows_per_device=LINT_ROWS_PER_DEVICE, sv_capacity=LINT_SV_CAPACITY,
        nnz_cap=LINT_NNZ_CAP, row_format=row_format,
        stream_rows_per_wave=LINT_ROWS_PER_DEVICE)


def _build(kind: str, cfg, mesh, shuffle: str):
    from repro.launch import steps as steps_lib
    if kind == "round":
        return steps_lib.build_svm_round_step(cfg, mesh,
                                              shuffle_impl=shuffle)
    if kind == "sweep":
        return steps_lib.build_svm_sweep_step(cfg, mesh, NUM_CONFIGS,
                                              shuffle_impl=shuffle)
    return steps_lib.build_svm_serve_step(cfg, mesh, NUM_STREAMS,
                                          shuffle_impl=shuffle)


def _compile(bundle, mesh):
    import jax
    from repro import compat
    with compat.set_mesh(mesh):
        jitted = jax.jit(
            bundle.fn,
            in_shardings=compat.to_shardings(mesh, bundle.in_shardings),
            out_shardings=compat.to_shardings(mesh, bundle.out_shardings),
            donate_argnums=bundle.donate_argnums)
        return jitted.lower(*bundle.args).compile()


# ---------------------------------------------------------------------------
# Solver-state taint masks (dtype-drift rule).
# ---------------------------------------------------------------------------

def _taint_like(tree, val: bool = False):
    import jax
    return jax.tree_util.tree_map(lambda _: val, tree)


def _sv_taint(sv):
    """Taint tree of an SV state pytree: the label/dual sidebands
    (``y``, ``alpha``) are solver state; feature rows (deliberately
    wire-dtype on the ring), ids, ptr and masks are not."""
    solver_state = {"y": True, "alpha": True}
    fields = type(sv)._fields
    return type(sv)(*(_taint_like(getattr(sv, f), solver_state.get(f, False))
                      for f in fields))


def _bundle_taint(bundle):
    import jax
    rows, y, mask, sv = bundle.args[:4]
    taint = (_taint_like(rows), True, False, _sv_taint(sv)) + tuple(
        _taint_like(a) for a in bundle.args[4:])
    return jax.tree_util.tree_leaves(taint)


# ---------------------------------------------------------------------------
# The matrix.
# ---------------------------------------------------------------------------

def _report(rep) -> None:
    extra = f", allowed={len(rep.allowed)}" if rep.allowed else ""
    note = f" [{rep.note}]" if rep.note else ""
    print(f"  OK [{rep.rule}] checked={rep.checked}{extra}{note}")


def run_matrix() -> int:
    from repro import analysis
    from repro.core.mapreduce_svm import SHUFFLE_IMPLS
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(data=8)
    failures = 0
    for row_format in ("dense", "sparse_csr"):
        cfg = _lint_cfg(row_format)
        for shuffle in SHUFFLE_IMPLS:
            for kind in ("round", "sweep", "serve"):
                name = f"{kind}/{shuffle}/{row_format}"
                print(f"program {name}")
                bundle = _build(kind, cfg, mesh, shuffle)
                _report(analysis.check_no_host_callbacks(
                    bundle.fn, bundle.args, program=name))
                _report(analysis.check_no_dtype_drift(
                    bundle.fn, bundle.args, taint=_bundle_taint(bundle),
                    program=name))
                if row_format == "sparse_csr":
                    _report(analysis.check_no_dense_materialization(
                        bundle.fn, bundle.args, d=cfg.num_features,
                        program=name))
                compiled = _compile(bundle, mesh)
                if row_format == "sparse_csr":
                    _report(analysis.check_memory_ceiling(
                        compiled,
                        limit_bytes=_sparse_temp_ceiling(cfg, kind),
                        program=name))
                hlo = compiled.as_text()
                _report(analysis.check_schedule(hlo, program=name))
                # determinism proxy: an independent second build must
                # extract the SAME ordered collective schedule
                hlo2 = _compile(_build(kind, cfg, mesh, shuffle),
                                mesh).as_text()
                _report(analysis.assert_schedules_agree(
                    {"trace0": analysis.collective_schedule(hlo),
                     "trace1": analysis.collective_schedule(hlo2)},
                    program=name))
    failures += run_dynamic()
    return failures


def _sparse_temp_ceiling(cfg, kind: str) -> int:
    """Temp-memory ceiling of a sparse program: ONE dense copy of its
    vmapped per-device shard (jobs · per · d · f32) — the block the
    sparse path exists to never materialize. Measured legit temps sit
    at 15–55 % of this across all six sparse programs (the ring wire
    buffers and the vmapped solver scratch scale with nnz_cap = d/16,
    not d); a full densify adds the entire block on top and trips it."""
    per = cfg.rows_per_device
    jobs = 1
    if kind == "sweep":
        jobs = NUM_CONFIGS
    elif kind == "serve":
        jobs = NUM_STREAMS
        per = -(-(cfg.stream_rows_per_wave + cfg.sv_capacity) // 8)
    return jobs * per * cfg.num_features * 4


def run_dynamic() -> int:
    """Dynamic rules on the functional drivers: retrace + host-sync on
    live hot loops (small shapes; correctness of the loop discipline,
    not the model)."""
    import jax
    import jax.numpy as jnp

    from repro import analysis
    from repro.core import (MRSVMConfig, SVMConfig, fit_mapreduce,
                            fit_mapreduce_sweep, sweep_grid)
    from repro.serving import StreamingSVMService

    cfg = MRSVMConfig(sv_capacity=32, max_rounds=3, gamma=1e-4,
                      svm=SVMConfig(C=1.0, max_epochs=8))
    w = jax.random.normal(jax.random.PRNGKey(9), (16,))
    X = jax.random.normal(jax.random.PRNGKey(0), (128, 16))
    y = jnp.sign(X @ w)

    print("program dynamic/sweep-rounds")
    params = sweep_grid(cfg.svm, C=[0.5, 1.0])
    with analysis.no_implicit_host_sync():
        fit_mapreduce_sweep(X, y, 4, cfg, params, fail_on_retrace=True)
    print("  OK [retrace] steady-state sweep rounds hit the jit cache")
    print("  OK [host-sync] designed readbacks pass the armed guard"
          + ("" if analysis.host_guards_enforced()
             else " [note: CPU backend cannot fire the runtime guard]"))

    print("program dynamic/streaming-wave")
    svc = StreamingSVMService(cfg, num_partitions=4, fail_on_retrace=True)
    svc.register("t0", fit_mapreduce(X, y, 4, cfg))
    for wave in range(2):           # wave 0 warms; wave 1 must hit
        Xb = jax.random.normal(jax.random.PRNGKey(10 + wave), (64, 16))
        svc.submit("t0", Xb, jnp.sign(Xb @ w))
        svc.run_wave()
    rep = svc.throughput_report()
    print(f"  OK [retrace] steady-state wave fold hit the jit cache "
          f"(fold_programs={rep['fold_programs']}, "
          f"retraces={rep['retraces']})")
    return 0


# ---------------------------------------------------------------------------
# Artifact mode: the CI staleness gate over benchmarks/artifacts/.
# ---------------------------------------------------------------------------

def run_artifacts(art_dir: str) -> int:
    from repro import analysis
    from repro.configs import get_config
    from repro.launch.hlo_analysis import collective_stats
    from repro.launch.mesh import make_production_mesh

    paths = sorted(glob.glob(os.path.join(art_dir, "dryrun_*.json")))
    if not paths:
        print(f"no dryrun artifacts under {art_dir}")
        return 0
    failures = 0
    meshes = {}
    for path in paths:
        with open(path) as f:
            record = json.load(f)
        name = os.path.basename(path)
        if record.get("status") != "ok":
            print(f"skip {name}: status={record.get('status')}")
            continue
        if record.get("arch") != "svm_tfidf":
            print(f"skip {name}: non-svm arch (schedule gate covers the "
                  "paper workload)")
            continue
        multi_pod = record["mesh"] == "2x16x16"
        if multi_pod not in meshes:
            meshes[multi_pod] = make_production_mesh(multi_pod=multi_pod)
        mesh = meshes[multi_pod]
        cfg = get_config(record["arch"])
        over = {}
        if record.get("row_format"):
            over["row_format"] = record["row_format"]
        if record.get("nnz_cap") is not None:
            over["nnz_cap"] = record["nnz_cap"]
        if over:
            cfg = dataclasses.replace(cfg, **over)
        shape = record.get("shape")
        shuffle = record.get("shuffle")
        from repro.launch import steps as steps_lib
        if shape == "svm_sweep":
            bundle = steps_lib.build_svm_sweep_step(
                cfg, mesh, num_configs=8, shuffle_impl=shuffle)
        elif shape == "svm_serve":
            bundle = steps_lib.build_svm_serve_step(
                cfg, mesh, num_streams=4, shuffle_impl=shuffle)
        else:
            bundle = steps_lib.build_svm_round_step(
                cfg, mesh, shuffle_impl=shuffle)
        hlo = _compile(bundle, mesh).as_text()
        analysis.check_schedule(hlo, program=name)
        analysis.compare_collective_counts(
            record.get("collectives", {}), collective_stats(hlo),
            program=name)
        print(f"OK {name}: schedule valid, collective counts current")
    return failures


# ---------------------------------------------------------------------------
# Self-test: seed one violation per rule family; each must fire.
# ---------------------------------------------------------------------------

def _expect(rule: str, fn) -> int:
    from repro.analysis import LintViolation
    try:
        fn()
    except LintViolation as e:
        if e.rule != rule:
            print(f"FAIL self-test [{rule}]: wrong rule fired: {e}")
            return 1
        if not e.op or not e.program:
            print(f"FAIL self-test [{rule}]: violation does not name "
                  f"op/program: {e}")
            return 1
        print(f"  OK seeded [{rule}] violation fired: op={e.op!r} "
              f"program={e.program!r}")
        return 0
    print(f"FAIL self-test [{rule}]: seeded violation did not fire")
    return 1


def run_self_test() -> int:
    import jax
    import jax.numpy as jnp

    from repro import analysis
    from repro.core.mapreduce_svm import pack_wire_rows

    failures = 0

    # retrace: per-call jit(lambda) in a steady-state region — the
    # exact bug class the module-level-jit discipline exists to prevent
    def seeded_retrace():
        with analysis.no_retrace("self-test wave"):
            jax.jit(lambda x: x * 2.0)(jnp.float32(1.0))
    failures += _expect("retrace", seeded_retrace)

    # retrace allowlist: a declared warm-up budget absorbs the compile
    with analysis.no_retrace("self-test warmup", allow=1):
        jax.jit(lambda x: x * 3.0)(jnp.float32(1.0))
    print("  OK [retrace] allow=1 absorbs the declared warm-up compile")

    # collective-schedule: a ring hop where device 3 receives twice —
    # mismatched ppermute schedules deadlock exactly like this
    bad_ring = """\
ENTRY %main () -> f32[8] {
  %p = f32[8]{0} parameter(0)
  ROOT %cp = f32[8]{0} collective-permute(%p), channel_id=1, source_target_pairs={{0,3},{1,2},{2,3}}
}
"""
    failures += _expect("collective-schedule",
                        lambda: analysis.check_schedule(bad_ring,
                                                        "self-test ring"))

    # collective-schedule (hier): the two-level schedule mixes a grouped
    # all-gather with an inter-host collective-permute per stage — a
    # malformed host grouping that places device 3 in two host groups
    # breaks the disjoint-partition invariant the hier transport needs
    bad_hier = """\
ENTRY %main () -> f32[8] {
  %p = f32[8]{0} parameter(0)
  %cp = f32[8]{0} collective-permute(%p), channel_id=1, source_target_pairs={{0,4},{1,5},{2,6},{3,7},{4,0},{5,1},{6,2},{7,3}}
  ROOT %ag = f32[32]{0} all-gather(%cp), channel_id=2, replica_groups={{0,1,2,3},{3,4,5,6,7}}, dimensions={0}
}
"""
    failures += _expect("collective-schedule",
                        lambda: analysis.check_schedule(bad_hier,
                                                        "self-test hier"))

    # schedule agreement: one participant truncates the sequence
    good = analysis.collective_schedule("""\
ENTRY %main () -> f32[8] {
  %p = f32[8]{0} parameter(0)
  %ar = f32[8]{0} all-reduce(%p), replica_groups={{0,1,2,3}}
  ROOT %ag = f32[32]{0} all-gather(%ar), replica_groups={{0,1,2,3}}
}
""")
    failures += _expect(
        "collective-schedule",
        lambda: analysis.assert_schedules_agree(
            {"proc0": good, "proc1": good[:1]}, "self-test agreement"))

    # artifact staleness: recorded counts disagree with a fresh compile
    failures += _expect(
        "collective-schedule",
        lambda: analysis.compare_collective_counts(
            {"all-reduce": {"count": 3}}, {"all-reduce": {"count": 2}},
            "self-test artifact"))

    # host-sync: a debug callback inside a would-be hot-loop program
    def leaky(x):
        jax.debug.callback(lambda v: None, x)
        return x * 2.0
    failures += _expect(
        "host-sync",
        lambda: analysis.check_no_host_callbacks(
            leaky, (jnp.zeros((4,)),), "self-test hot loop"))

    # dense-materialization: densify a whole 512-row shard at d=512
    d = LINT_FEATURES
    def densify(v):
        return (v[:, None] * jnp.ones((LINT_ROWS_PER_DEVICE, d))).sum()
    failures += _expect(
        "dense-materialization",
        lambda: analysis.check_no_dense_materialization(
            densify, (jnp.zeros((LINT_ROWS_PER_DEVICE,)),), d=d,
            program="self-test densify"))

    # dtype-drift: a stray bf16 downcast of tainted solver state
    def drift(alpha):
        return alpha.astype(jnp.bfloat16).sum()
    failures += _expect(
        "dtype-drift",
        lambda: analysis.check_no_dtype_drift(
            drift, (jnp.zeros((8,), jnp.float32),), taint=[True],
            program="self-test drift"))

    # dtype-drift wire-pack allowlist: downcast → pack → bitcast passes
    def pack(alpha):
        return pack_wire_rows(alpha.astype(jnp.bfloat16), jnp.bfloat16)[0]
    rep = analysis.check_no_dtype_drift(
        pack, (jnp.zeros((8, 16), jnp.float32),), taint=[True],
        program="self-test wire pack")
    if not rep.allowed:
        print("FAIL self-test [dtype-drift]: wire-pack downcast was not "
              "recorded as allowlisted")
        failures += 1
    else:
        print(f"  OK [dtype-drift] wire-pack allowlist absorbed the "
              f"downcast ({rep.allowed[0].reason})")

    # unknown-dtype fallback: never a silent skip
    import warnings
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        sizes = analysis.tensor_nbytes("f6e3m2[64]")
    if sizes != [256] or not w:
        print(f"FAIL self-test [hlo-parser]: unknown dtype fallback "
              f"returned {sizes} (warned={bool(w)})")
        failures += 1
    else:
        print("  OK [hlo-parser] unknown dtype warned and counted "
              "conservatively")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="jaxpr/HLO invariant linter (DESIGN.md §14)")
    ap.add_argument("--artifacts", default=None, metavar="DIR",
                    help="verify committed dry-run artifacts instead of "
                         "running the builder matrix")
    ap.add_argument("--self-test", action="store_true",
                    help="seed one violation per rule family; each must "
                         "fire naming the offending op and program")
    args = ap.parse_args(argv)
    from repro.analysis.base import LintViolation
    try:
        if args.self_test:
            failures = run_self_test()
        elif args.artifacts:
            failures = run_artifacts(args.artifacts)
        else:
            failures = run_matrix()
    except LintViolation as e:
        print(f"LINT FAILURE: {e}")
        return 1
    if failures:
        print(f"{failures} lint failure(s)")
        return 1
    print("lint-jax: all invariant rules passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
