"""Rule 2 — retrace detector (DESIGN.md §14).

The repo's jit-cache discipline (module-level jits in
``core.mapreduce_svm`` / ``core.sweep``, power-of-two wave buckets in
``serving.svm_stream``) exists so steady-state hot loops NEVER
recompile. This context manager turns that discipline into a failing
check: wrap a region that must hit the cache; any compile inside it
raises :class:`RetraceError` naming the recompiled function.

Mechanism: ``jax_log_compiles`` emits a WARNING-level ``Compiling
<name> with global shapes and types …`` record on a ``jax.*`` logger
for every cache-missing trace→compile (stable across the supported
0.4.x→0.8.x matrix; see DESIGN.md §7). We attach one handler to the
root ``jax`` logger — child records propagate — and filter on the
message prefix, so the detector needs no private cache-stat APIs.
"""
from __future__ import annotations

import contextlib
import dataclasses
import logging
import re
from typing import List

import jax

from repro.analysis.base import LintViolation

RULE = "retrace"

_COMPILE_PREFIX = "Compiling "
_NAME_RE = re.compile(r"Compiling ([\w.<>\-]+)")


class RetraceError(LintViolation):
    def __init__(self, program: str, events: List[str]):
        names = ", ".join(events) or "<unknown>"
        super().__init__(RULE, program, names,
                         f"{len(events)} compilation(s) inside a "
                         "steady-state region that must hit the jit "
                         "cache")
        self.events = list(events)


@dataclasses.dataclass
class RetraceStats:
    """Mutable capture handed to the ``with`` body: ``events`` grows one
    function name per compile observed inside the region."""
    events: List[str] = dataclasses.field(default_factory=list)

    @property
    def count(self) -> int:
        return len(self.events)


class _CompileHandler(logging.Handler):
    def __init__(self, stats: RetraceStats):
        super().__init__(level=logging.WARNING)
        self.stats = stats

    def emit(self, record: logging.LogRecord) -> None:
        try:
            msg = record.getMessage()
        except Exception:
            return
        if not msg.startswith(_COMPILE_PREFIX):
            return
        m = _NAME_RE.match(msg)
        self.stats.events.append(m.group(1) if m else "<unknown>")


@contextlib.contextmanager
def watch_compiles():
    """Count compiles in a region WITHOUT failing — the accounting
    primitive under :func:`no_retrace` and the streaming service's
    retrace counters. Yields :class:`RetraceStats`."""
    stats = RetraceStats()
    handler = _CompileHandler(stats)
    logger = logging.getLogger("jax")
    prev_level = logger.level
    prev_propagate = logger.propagate
    prev_flag = bool(jax.config.jax_log_compiles)
    logger.addHandler(handler)
    # the log_compiles records are WARNING-level; make sure an app that
    # silenced the jax logger doesn't blind the detector
    if logger.getEffectiveLevel() > logging.WARNING:
        logger.setLevel(logging.WARNING)
    dispatch_logger = logging.getLogger("jax._src.dispatch")
    prev_dispatch = dispatch_logger.level
    if not prev_flag:
        jax.config.update("jax_log_compiles", True)
        # log_compiles promotes a firehose of jax-internal records to
        # WARNING; keep them off the app's handlers while armed (our
        # handler on the 'jax' logger still sees the pxla 'Compiling'
        # records it needs). A caller who turned log_compiles on
        # themselves keeps their output untouched.
        logger.propagate = False
        dispatch_logger.setLevel(logging.ERROR)
    try:
        yield stats
    finally:
        if not prev_flag:
            jax.config.update("jax_log_compiles", False)
        logger.removeHandler(handler)
        logger.setLevel(prev_level)
        logger.propagate = prev_propagate
        dispatch_logger.setLevel(prev_dispatch)


@contextlib.contextmanager
def no_retrace(program: str = "<steady state>", allow: int = 0):
    """Fail with :class:`RetraceError` if more than ``allow`` compiles
    happen inside the region. ``allow`` is the explicit allowlist knob:
    a warm-up region that legitimately compiles N programs passes
    ``allow=N`` and still catches the N+1st."""
    with watch_compiles() as stats:
        yield stats
    if stats.count > allow:
        raise RetraceError(program, stats.events)
