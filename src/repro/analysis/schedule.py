"""Rule 1 — collective-schedule checker (DESIGN.md §14).

A multi-process SPMD program deadlocks when participants disagree on
the ordered sequence of collectives they will issue (CloudSVM's global
iterate-merge loop is exactly such a schedule). Three machine checks:

* :func:`check_schedule` — structural validity of ONE compiled program:
  async ``-start``/``-done`` ops pair up within their computation, and
  every collective-permute's ``source_target_pairs`` form a partial
  permutation (no device is the source or target of two messages in
  one hop — the ring transport's deadlock-freedom condition).
* :func:`assert_schedules_agree` — N programs (one per process, or the
  same builder traced twice as the single-process determinism proxy)
  must extract to the SAME ordered schedule signature.
* :func:`compare_collective_counts` — per-kind op counts of a fresh
  compile vs. a committed dry-run artifact's recorded ``collectives``
  (the CI staleness gate over ``benchmarks/artifacts/``).
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

from repro.analysis import hlo
from repro.analysis.base import LintViolation, RuleReport

RULE = "collective-schedule"


def collective_schedule(hlo_text: str) -> Tuple[tuple, ...]:
    """Ordered schedule signature of a compiled program: one entry per
    issued collective (``-done`` halves excluded — the ``-start`` is
    the issue point), in textual order. While-body collectives appear
    once; per-trip multiplicity is schedule-invariant (every process
    runs the same trip count or the program is already wrong)."""
    return tuple(op.signature() for op in hlo.parse_collective_ops(hlo_text)
                 if not op.is_done)


def check_schedule(hlo_text: str, program: str = "<program>") -> RuleReport:
    """Structural schedule validity of one compiled program."""
    ops = hlo.parse_collective_ops(hlo_text)
    # -start/-done pairing, per computation and kind
    open_starts: Dict[Tuple[str, str], List[hlo.CollectiveOp]] = {}
    for op in ops:
        key = (op.computation, op.kind)
        if op.is_start:
            open_starts.setdefault(key, []).append(op)
        elif op.is_done:
            if not open_starts.get(key):
                raise LintViolation(
                    RULE, program, op.name,
                    f"{op.kind}-done in computation {op.computation!r} "
                    "with no preceding matching -start")
            open_starts[key].pop()
    for (comp, kind), pending in open_starts.items():
        if pending:
            raise LintViolation(
                RULE, program, pending[0].name,
                f"{kind}-start in computation {comp!r} never consumed "
                "by a matching -done (dangling async collective)")

    # collective-permute deadlock freedom: one send and one receive per
    # device per hop
    for op in ops:
        if op.kind != "collective-permute" or op.is_done:
            continue
        pairs = op.source_target_pairs or ()
        srcs = [s for s, _ in pairs]
        tgts = [t for _, t in pairs]
        if len(set(srcs)) != len(srcs):
            dup = sorted({s for s in srcs if srcs.count(s) > 1})
            raise LintViolation(
                RULE, program, op.name,
                f"collective-permute has duplicate source device(s) "
                f"{dup} in source_target_pairs={list(pairs)} — a device "
                "cannot issue two sends in one hop")
        if len(set(tgts)) != len(tgts):
            dup = sorted({t for t in tgts if tgts.count(t) > 1})
            raise LintViolation(
                RULE, program, op.name,
                f"collective-permute has duplicate target device(s) "
                f"{dup} in source_target_pairs={list(pairs)} — a device "
                "cannot receive two messages in one hop")

    # replica_groups must partition (no device in two groups)
    for op in ops:
        if not op.replica_groups or op.is_done:
            continue
        seen: Dict[int, int] = {}
        for gi, g in enumerate(op.replica_groups):
            for dev in g:
                if dev in seen:
                    raise LintViolation(
                        RULE, program, op.name,
                        f"{op.kind} replica_groups place device {dev} in "
                        f"groups {seen[dev]} and {gi} — groups must be "
                        "disjoint")
                seen[dev] = gi
    return RuleReport(rule=RULE, program=program, checked=len(ops))


def assert_schedules_agree(schedules: Dict[str, Sequence[tuple]],
                           program: str = "<program>") -> RuleReport:
    """All participants extracted the same ordered collective schedule.
    Keys name the participants (process ids, trace attempts); the error
    names the first position where two schedules diverge."""
    items = sorted(schedules.items())
    if len(items) < 2:
        return RuleReport(rule=RULE, program=program,
                          checked=len(items and items[0][1]))
    ref_name, ref = items[0]
    for name, sched in items[1:]:
        if len(sched) != len(ref):
            raise LintViolation(
                RULE, program, f"{ref_name} vs {name}",
                f"collective counts diverge: {ref_name} issues "
                f"{len(ref)} collectives, {name} issues {len(sched)}")
        for i, (a, b) in enumerate(zip(ref, sched)):
            if a != b:
                raise LintViolation(
                    RULE, program, f"schedule[{i}]",
                    f"{ref_name} and {name} disagree at collective #{i}: "
                    f"{a[0]}{a[1]} vs {b[0]}{b[1]} — a cross-process "
                    "launch of this pair would deadlock")
    return RuleReport(rule=RULE, program=program,
                      checked=len(ref) * len(items))


def compare_collective_counts(recorded: Dict[str, dict],
                              fresh: Dict[str, dict],
                              program: str = "<artifact>") -> RuleReport:
    """Per-kind collective COUNTS of a committed artifact vs. a fresh
    compile of the same (arch, shape, mesh, transport). Byte fields are
    excluded on purpose: they move with dtype-table fixes (this PR's
    satellite) without the schedule changing."""
    kinds = sorted(set(recorded) | set(fresh))
    for kind in kinds:
        r = int(recorded.get(kind, {}).get("count", 0))
        f = int(fresh.get(kind, {}).get("count", 0))
        if r != f:
            raise LintViolation(
                RULE, program, kind,
                f"committed artifact records {r} {kind} op(s) but a "
                f"fresh compile issues {f} — the artifact is stale; "
                "re-run `python -m repro.launch.dryrun`")
    return RuleReport(rule=RULE, program=program, checked=len(kinds))
