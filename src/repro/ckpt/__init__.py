from repro.ckpt.checkpoint import (CorruptCheckpointError,
                                   atomic_write_json, file_crc32,
                                   latest_path, latest_step,
                                   leaf_checksums, restore, save)

__all__ = ["CorruptCheckpointError", "atomic_write_json", "file_crc32",
           "latest_path", "latest_step", "leaf_checksums", "restore",
           "save"]
