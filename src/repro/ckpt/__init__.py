from repro.ckpt.checkpoint import (atomic_write_json, latest_path,
                                   latest_step, restore, save)
