"""Flat-npz pytree checkpointing (the framework's fault-tolerance layer;
stands in for HDFS durability in the paper's Hadoop deployment).

Hardening (DESIGN.md §15): writes are crash-durable (tmp file fsync'd,
directory fsync'd after the rename — a power cut at the wrong instant
can't leave a zero-length file installed), retried with backoff on
``OSError``, and content-addressed: ``save`` returns the written
file's crc32 and, with ``step``, records it in a monotonically-growing
``generations`` list in ``ckpt_meta.json`` (keep-last-N, older media
GC'd). ``latest_step``/``latest_path`` verify the recorded crc32
newest-first and SKIP corrupt generations, so a flipped bit in the
newest snapshot falls back to the previous intact one instead of
restoring silently wrong state.
"""
from __future__ import annotations

import json
import os
import zlib
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import faults

_SEP = "||"


_BF16 = "__bf16__"

_META = "ckpt_meta.json"

# generations kept per checkpoint directory (satellite knob; callers
# override per save)
DEFAULT_KEEP = 3


class CorruptCheckpointError(ValueError):
    """A stored leaf failed its recorded content checksum."""


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:     # npz can't store bf16: view u16
            key += _BF16
            arr = arr.view(np.uint16)
        flat[key] = arr
    return flat


def _fsync_dir(dirname: str) -> None:
    """Best-effort directory fsync: makes the rename itself durable
    (POSIX persists a replace only once the directory entry is synced;
    some filesystems refuse O_RDONLY dir fsync — then we did our best)."""
    try:
        fd = os.open(dirname or ".", os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def file_crc32(path: str) -> int:
    """crc32 of the file's bytes (chunked; zlib — no new deps)."""
    crc = 0
    with open(path, "rb") as f:
        while True:
            chunk = f.read(1 << 20)
            if not chunk:
                return crc
            crc = zlib.crc32(chunk, crc)


def leaf_checksums(tree: Any) -> Dict[str, int]:
    """crc32 per flat leaf key, computed over the STORED byte view
    (bf16 leaves checksum their u16 wire form) — recorded alongside a
    save so :func:`restore` can verify each payload independently of
    the npz container."""
    return {key: zlib.crc32(np.ascontiguousarray(arr).tobytes())
            for key, arr in _flatten(tree).items()}


def atomic_write_json(path: str, payload: Any, attempts: int = 3,
                      on_retry=None) -> None:
    """Write JSON via tmp + fsync + rename: readers see the old file or
    the new one, never a torn OR empty write (the same guarantee
    ``save`` gives npz). Retries transient ``OSError`` with backoff;
    exhaustion raises a typed ``FaultDetected("ckpt", ...)``."""
    def write():
        faults.maybe_raise("ckpt.write", kinds=("ckpt_write_fail",))
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(payload, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
        _fsync_dir(os.path.dirname(path))

    faults.retry_with_backoff(
        write, attempts=attempts, base_s=0.02, retry_on=OSError,
        on_retry=on_retry, layer="ckpt",
        cause=f"manifest write {os.path.basename(path)}",
        action="check disk space/permissions; the previously installed "
               "manifest is still intact")


def save(path: str, tree: Any, step: Optional[int] = None,
         keep: int = DEFAULT_KEEP, attempts: int = 3,
         on_retry=None) -> int:
    """Atomic, durable save (write tmp → fsync → rename → dir fsync).

    Returns the crc32 of the written bytes — the content address a
    manifest records so a later restore can verify the medium. With
    ``step`` the directory's ``ckpt_meta.json`` gains a generation
    record ``{step, file, crc32}``; only the newest ``keep``
    generations are retained and older npz files are GC'd (unless a
    kept generation still references them).
    """
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)

    def write() -> int:
        faults.maybe_raise("ckpt.write", kinds=("ckpt_write_fail",))
        tmp = path + ".tmp"
        np.savez(tmp, **flat)
        actual = tmp if tmp.endswith(".npz") else tmp + ".npz"
        with open(actual, "rb") as f:
            os.fsync(f.fileno())
        # crc of the INTENDED bytes, before the media-corruption seam:
        # a chaos-corrupted file must MISmatch its recorded crc, which
        # is exactly how restore detects it and falls back.
        crc = file_crc32(actual)
        spec = faults.fire("ckpt.media", kinds=("ckpt_corrupt",))
        if spec is not None:
            faults.corrupt_file(actual, spec)
        os.replace(actual, path)
        _fsync_dir(os.path.dirname(path))
        return crc

    crc = faults.retry_with_backoff(
        write, attempts=attempts, base_s=0.02, retry_on=OSError,
        on_retry=on_retry, layer="ckpt",
        cause=f"snapshot write {os.path.basename(path)}",
        action="check disk space/permissions; the previous snapshot "
               "generation is still intact")
    if step is not None:
        _record_generation(os.path.dirname(path) or ".", step,
                           os.path.basename(path), crc, keep, on_retry)
    return crc


def _record_generation(ckpt_dir: str, step: int, fname: str, crc: int,
                       keep: int, on_retry=None) -> None:
    """Append a generation to the meta pointer, prune to ``keep``, GC
    dropped media. The meta keeps the flat ``latest_step``/``file``
    fields too, so pre-generation readers stay compatible."""
    meta = _read_meta(ckpt_dir) or {}
    gens = [g for g in meta.get("generations", [])
            if g.get("file") != fname]
    gens.append({"step": step, "file": fname, "crc32": crc})
    dropped, gens = (gens[:-keep], gens[-keep:]) if keep >= 1 \
        else ([], gens)
    atomic_write_json(
        os.path.join(ckpt_dir, _META),
        {"latest_step": step, "file": fname, "generations": gens},
        on_retry=on_retry)
    kept_files = {g["file"] for g in gens}
    for g in dropped:
        if g["file"] not in kept_files:
            try:
                os.remove(os.path.join(ckpt_dir, g["file"]))
            except OSError:
                pass


def restore(path: str, like: Any,
            checksums: Optional[Dict[str, int]] = None) -> Any:
    """Restore into the structure of ``like`` (validates shapes/dtypes).

    Dtype drift raises instead of casting: a checkpoint restores
    bit-exact or not at all (silent f32→bf16 narrowing would make a
    resumed run diverge from the uninterrupted one). The bf16 u16-view
    round-trip is transparent — a bf16 leaf restored into a bf16
    ``like`` passes.

    With ``checksums`` (a :func:`leaf_checksums` record) every stored
    leaf's bytes are verified before adoption; a mismatch raises
    :class:`CorruptCheckpointError` — corrupt payload never restores
    silently.
    """
    data = np.load(path, allow_pickle=False)
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path_elems, leaf in paths:
        key = _SEP.join(str(p) for p in path_elems)
        if key + _BF16 in data:
            skey = key + _BF16
            raw = data[skey]
            arr = raw.view(jnp.bfloat16)
        elif key in data:
            skey = key
            raw = arr = data[key]
        else:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        if checksums is not None and skey in checksums:
            got = zlib.crc32(np.ascontiguousarray(raw).tobytes())
            if got != checksums[skey]:
                raise CorruptCheckpointError(
                    f"checksum mismatch for leaf {skey!r} in "
                    f"{os.path.basename(path)} — the snapshot payload "
                    "is corrupt")
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs {leaf.shape}")
        want = np.dtype(leaf.dtype)
        if arr.dtype != want:
            raise ValueError(
                f"dtype mismatch for {key}: ckpt {arr.dtype} vs like "
                f"{want} — checkpoints restore exactly, not cast")
        leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def leaf_dtypes(tree: Any) -> Dict[str, str]:
    """``str(dtype)`` per flat leaf key — recorded alongside a save so a
    restorer can rebuild an exactly-typed ``like`` tree from static
    shape facts alone (see :func:`with_dtypes`)."""
    return {_SEP.join(str(p) for p in path): str(np.asarray(leaf).dtype)
            for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]}


def with_dtypes(like: Any, dtypes: Dict[str, str]) -> Any:
    """Re-type ``like``'s leaves from a :func:`leaf_dtypes` record
    (shapes and structure kept; keys absent from the record keep their
    placeholder dtype)."""
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path_elems, leaf in paths:
        dt = dtypes.get(_SEP.join(str(p) for p in path_elems))
        leaves.append(leaf if dt is None
                      else jnp.zeros(np.shape(leaf), jnp.dtype(dt)))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _read_meta(ckpt_dir: str) -> Optional[dict]:
    meta = os.path.join(ckpt_dir, _META)
    if not os.path.exists(meta):
        return None
    try:
        with open(meta) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return None                     # unreadable pointer ≡ no pointer


def _newest_intact(ckpt_dir: str, meta: dict) -> Optional[dict]:
    """The newest generation whose medium verifies against its
    recorded crc32; corrupt/missing generations are skipped (counted
    as ``ckpt_fallbacks``). Pre-generation flat metas have no recorded
    crc — the pointer is trusted as before."""
    gens = meta.get("generations")
    if gens is None:
        if meta.get("file") is None:
            return None
        return {"step": meta.get("latest_step"), "file": meta["file"]}
    for rec in reversed(gens):
        p = os.path.join(ckpt_dir, rec["file"])
        if not os.path.exists(p):
            faults.count("ckpt_fallbacks")
            continue
        crc = rec.get("crc32")
        if crc is not None and file_crc32(p) != crc:
            faults.count("ckpt_fallbacks")
            continue
        return rec
    return None


def latest_step(ckpt_dir: str) -> Optional[int]:
    meta = _read_meta(ckpt_dir)
    if meta is None:
        return None
    rec = _newest_intact(ckpt_dir, meta)
    return rec.get("step") if rec is not None else None


def latest_path(ckpt_dir: str) -> Optional[str]:
    """Path of the newest INTACT checkpoint generation (crc32-verified
    when recorded), or ``None``."""
    meta = _read_meta(ckpt_dir)
    if meta is None:
        return None
    rec = _newest_intact(ckpt_dir, meta)
    if rec is None or not rec.get("file"):
        return None
    return os.path.join(ckpt_dir, rec["file"])
