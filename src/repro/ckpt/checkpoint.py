"""Flat-npz pytree checkpointing (the framework's fault-tolerance layer;
stands in for HDFS durability in the paper's Hadoop deployment)."""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "||"


_BF16 = "__bf16__"


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:     # npz can't store bf16: view u16
            key += _BF16
            arr = arr.view(np.uint16)
        flat[key] = arr
    return flat


def atomic_write_json(path: str, payload: Any) -> None:
    """Write JSON via tmp + rename: readers see the old file or the new
    one, never a torn write (the same guarantee ``save`` gives npz)."""
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f)
    os.replace(tmp, path)


def save(path: str, tree: Any, step: Optional[int] = None) -> None:
    """Atomic save (write tmp → rename)."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    np.savez(tmp, **_flatten(tree))
    os.replace(tmp + ".npz" if not tmp.endswith(".npz") else tmp, path)
    if step is not None:
        # The meta pointer is what every restore reads first — it must
        # be replaced atomically too, or a crash mid-write leaves the
        # whole directory unrestorable despite intact npz files.
        meta = os.path.join(os.path.dirname(path) or ".", "ckpt_meta.json")
        atomic_write_json(
            meta, {"latest_step": step, "file": os.path.basename(path)})


def restore(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (validates shapes/dtypes).

    Dtype drift raises instead of casting: a checkpoint restores
    bit-exact or not at all (silent f32→bf16 narrowing would make a
    resumed run diverge from the uninterrupted one). The bf16 u16-view
    round-trip is transparent — a bf16 leaf restored into a bf16
    ``like`` passes.
    """
    data = np.load(path, allow_pickle=False)
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path_elems, leaf in paths:
        key = _SEP.join(str(p) for p in path_elems)
        if key + _BF16 in data:
            arr = data[key + _BF16].view(jnp.bfloat16)
        elif key in data:
            arr = data[key]
        else:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs {leaf.shape}")
        want = np.dtype(leaf.dtype)
        if arr.dtype != want:
            raise ValueError(
                f"dtype mismatch for {key}: ckpt {arr.dtype} vs like "
                f"{want} — checkpoints restore exactly, not cast")
        leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def leaf_dtypes(tree: Any) -> Dict[str, str]:
    """``str(dtype)`` per flat leaf key — recorded alongside a save so a
    restorer can rebuild an exactly-typed ``like`` tree from static
    shape facts alone (see :func:`with_dtypes`)."""
    return {_SEP.join(str(p) for p in path): str(np.asarray(leaf).dtype)
            for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]}


def with_dtypes(like: Any, dtypes: Dict[str, str]) -> Any:
    """Re-type ``like``'s leaves from a :func:`leaf_dtypes` record
    (shapes and structure kept; keys absent from the record keep their
    placeholder dtype)."""
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path_elems, leaf in paths:
        dt = dtypes.get(_SEP.join(str(p) for p in path_elems))
        leaves.append(leaf if dt is None
                      else jnp.zeros(np.shape(leaf), jnp.dtype(dt)))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def latest_step(ckpt_dir: str) -> Optional[int]:
    meta = os.path.join(ckpt_dir, "ckpt_meta.json")
    if not os.path.exists(meta):
        return None
    with open(meta) as f:
        return json.load(f).get("latest_step")


def latest_path(ckpt_dir: str) -> Optional[str]:
    """Path of the checkpoint the meta pointer names, or ``None``."""
    meta = os.path.join(ckpt_dir, "ckpt_meta.json")
    if not os.path.exists(meta):
        return None
    with open(meta) as f:
        name = json.load(f).get("file")
    return os.path.join(ckpt_dir, name) if name else None
