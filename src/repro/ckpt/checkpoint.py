"""Flat-npz pytree checkpointing (the framework's fault-tolerance layer;
stands in for HDFS durability in the paper's Hadoop deployment)."""
from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "||"


_BF16 = "__bf16__"


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:     # npz can't store bf16: view u16
            key += _BF16
            arr = arr.view(np.uint16)
        flat[key] = arr
    return flat


def save(path: str, tree: Any, step: Optional[int] = None) -> None:
    """Atomic save (write tmp → rename)."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    np.savez(tmp, **_flatten(tree))
    os.replace(tmp + ".npz" if not tmp.endswith(".npz") else tmp, path)
    if step is not None:
        meta = os.path.join(os.path.dirname(path) or ".", "ckpt_meta.json")
        with open(meta, "w") as f:
            json.dump({"latest_step": step, "file": os.path.basename(path)}, f)


def restore(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (validates shapes/dtypes)."""
    data = np.load(path, allow_pickle=False)
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path_elems, leaf in paths:
        key = _SEP.join(str(p) for p in path_elems)
        if key + _BF16 in data:
            arr = data[key + _BF16].view(jnp.bfloat16)
        elif key in data:
            arr = data[key]
        else:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs {leaf.shape}")
        leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def latest_step(ckpt_dir: str) -> Optional[int]:
    meta = os.path.join(ckpt_dir, "ckpt_meta.json")
    if not os.path.exists(meta):
        return None
    with open(meta) as f:
        return json.load(f).get("latest_step")
