"""Version-portable JAX substrate (DESIGN.md §7).

The distributed MapReduce-SVM path targets the shard_map surface as it
exists across JAX 0.4.3x → 0.8.x. The relevant names drifted between
those versions, so this module is the ONE place allowed to touch them;
every other file imports the stable spellings below.

Drift handled here:

* ``jax.shard_map`` (new) vs ``jax.experimental.shard_map.shard_map``
  (0.4.x), and the ``check_vma`` (new) vs ``check_rep`` (old) kwarg.
* ``jax.lax.pcast`` (transitional) / ``jax.lax.pvary`` (new) /
  neither (0.4.x, where shard_map has no vma types at all and the
  correct behaviour is the identity).
* ``AbstractMesh((16, 16), ("data", "model"))`` (new positional
  ``axis_sizes, axis_names``) vs the 0.4.x
  ``AbstractMesh(shape_tuple=(("data", 16), ("model", 16)))``.
* ``jax.make_mesh`` (0.4.35+) vs hand-rolled ``Mesh`` over reshaped
  ``jax.devices()``.
* ``jax.tree.map`` (0.4.25+) vs ``jax.tree_util.tree_map``.
* ``jax.lax.axis_index`` over a TUPLE of axis names (flattened index),
  which older versions only accept for a single name.
* ``jax.distributed.initialize`` kwarg drift (newer versions grow
  kwargs like ``coordinator_bind_address``/``cluster_detection_method``
  that 0.4.x lacks), the ``jax_cpu_collectives_implementation`` config
  (spelled ``jax_cpu_enable_gloo_collectives`` on some versions, absent
  on others), and ``jax.make_array_from_process_local_data`` (newer)
  vs hand-assembly over ``make_array_from_single_device_arrays``.
"""
from __future__ import annotations

import inspect
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def jax_version() -> Tuple[int, ...]:
    """Installed JAX version as a comparable int tuple, e.g. (0, 4, 37)."""
    parts = []
    for p in jax.__version__.split(".")[:3]:
        digits = "".join(c for c in p if c.isdigit())
        parts.append(int(digits or 0))
    return tuple(parts)


# ---------------------------------------------------------------------------
# Pytree mapping.
# ---------------------------------------------------------------------------

try:
    tree_map = jax.tree.map
except AttributeError:                                    # pragma: no cover
    tree_map = jax.tree_util.tree_map


# ---------------------------------------------------------------------------
# shard_map.
# ---------------------------------------------------------------------------

def _resolve_shard_map() -> Callable:
    impl = getattr(jax, "shard_map", None)
    if impl is not None:
        return impl
    from jax.experimental.shard_map import shard_map as impl
    return impl


def shard_map(f: Callable, *, mesh, in_specs, out_specs,
              check_vma: Optional[bool] = None, **kwargs) -> Callable:
    """``jax.shard_map`` with one calling convention on every JAX.

    ``check_vma`` maps onto whichever replication/varying-manual-axes
    checker kwarg the installed version accepts — the name is chosen by
    signature, not by where the impl lives, because ~0.6.x exposes a
    top-level ``jax.shard_map`` that still spells it ``check_rep``.
    ``None`` leaves the version default in place.
    """
    impl = _resolve_shard_map()
    kw = dict(kwargs)
    if check_vma is not None:
        try:
            params = inspect.signature(impl).parameters
        except (TypeError, ValueError):
            params = None                    # unsignature-able: probe below
        if params is None or "check_vma" in params:
            kw["check_vma"] = check_vma
        elif "check_rep" in params:
            kw["check_rep"] = check_vma
        # else: checker kwarg gone entirely → run the version default
    try:
        return impl(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
    except TypeError:
        if "check_vma" in kw:                # probe failed: try old spelling
            kw["check_rep"] = kw.pop("check_vma")
            try:
                return impl(f, mesh=mesh, in_specs=in_specs,
                            out_specs=out_specs, **kw)
            except TypeError:
                pass
        kw.pop("check_rep", None)
        return impl(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


# ---------------------------------------------------------------------------
# Varying-manual-axes (vma) marking.
# ---------------------------------------------------------------------------

def pvary(tree: Any, axes: Sequence[str]) -> Any:
    """Mark a pytree as device-varying over shard_map manual ``axes``.

    Needed on vma-typed JAX (0.7+) because while_loop carries built from
    constants type as axis-invariant while loop-body outputs are
    varying. Resolution chain: ``jax.lax.pcast(..., to="varying")`` →
    ``jax.lax.pvary`` → identity. On JAX without either primitive the
    identity IS the correct lowering (no vma types exist to satisfy),
    so the chain never raises — only degrades.
    """
    axes = tuple(axes)
    if not axes:
        return tree
    pcast = getattr(jax.lax, "pcast", None)
    if pcast is not None:
        try:
            return tree_map(lambda x: pcast(x, axes, to="varying"), tree)
        except Exception:               # kwarg drift / unbound axis name
            pass
    pvary_prim = getattr(jax.lax, "pvary", None)
    if pvary_prim is not None:
        try:
            return tree_map(lambda x: pvary_prim(x, axes), tree)
        except Exception:
            # Unbound axis name, i.e. called outside shard_map on
            # vma-typed JAX: identity is the correct no-op there too.
            # pvary only annotates types — degrading never changes
            # values, so swallowing here cannot mask a numeric bug.
            pass
    return tree


# ---------------------------------------------------------------------------
# Mesh construction.
# ---------------------------------------------------------------------------

def make_abstract_mesh(axis_sizes: Sequence[int],
                       axis_names: Sequence[str]):
    """Device-free ``AbstractMesh`` across the constructor drift.

    New JAX: ``AbstractMesh(axis_sizes, axis_names)``.
    0.4.x:   ``AbstractMesh(shape_tuple)`` with (name, size) pairs.
    """
    from jax.sharding import AbstractMesh
    sizes, names = tuple(axis_sizes), tuple(axis_names)
    try:
        return AbstractMesh(sizes, names)
    except (TypeError, ValueError):
        return AbstractMesh(tuple(zip(names, sizes)))


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]):
    """``jax.make_mesh`` with a manual-``Mesh`` fallback for old JAX."""
    shapes, names = tuple(axis_shapes), tuple(axis_names)
    maker = getattr(jax, "make_mesh", None)
    if maker is not None:
        return maker(shapes, names)
    from jax.sharding import Mesh
    n = int(np.prod(shapes))
    devices = np.asarray(jax.devices()[:n]).reshape(shapes)
    return Mesh(devices, names)


def to_shardings(mesh, specs):
    """PartitionSpec pytree → NamedSharding pytree bound to ``mesh``.

    Old JAX's ``jax.jit`` rejects bare ``PartitionSpec`` in
    in_shardings/out_shardings (new JAX accepts them under an active
    mesh); ``NamedSharding`` works everywhere, so bind unconditionally.
    """
    from jax.sharding import NamedSharding, PartitionSpec
    is_spec = lambda s: isinstance(s, PartitionSpec)
    return tree_map(lambda s: NamedSharding(mesh, s) if is_spec(s) else s,
                    specs, is_leaf=is_spec)


def cost_analysis(compiled) -> dict:
    """Flat cost dict from a compiled executable: old JAX returns a
    one-element LIST of per-program dicts, new JAX the dict itself."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


def set_mesh(mesh):
    """Context manager activating ``mesh`` for bare-PartitionSpec
    sharding constraints: ``jax.set_mesh`` (new) → ``use_mesh``
    (transitional) → the legacy ``with mesh:`` resource env (0.4.x).
    """
    setter = getattr(jax, "set_mesh", None)
    if setter is not None:
        return setter(mesh)
    import jax.sharding as jshd
    use_mesh = getattr(jshd, "use_mesh", None)
    if use_mesh is not None:
        return use_mesh(mesh)
    return mesh                       # Mesh is itself a context manager


# ---------------------------------------------------------------------------
# Collectives: normalize tuple-of-axis-names handling.
# ---------------------------------------------------------------------------

def axis_index(axis_names) -> jax.Array:
    """Flattened device index over one or several mesh axes.

    Newer JAX accepts a tuple directly; older versions only a single
    name, so the row-major flattening is done by hand there.
    """
    if isinstance(axis_names, str):
        return jax.lax.axis_index(axis_names)
    axes = tuple(axis_names)
    try:
        return jax.lax.axis_index(axes)
    except (TypeError, ValueError):
        idx = jax.lax.axis_index(axes[0])
        for a in axes[1:]:
            idx = idx * jax.lax.psum(1, a) + jax.lax.axis_index(a)
        return idx


def psum(x, axis_names):
    return jax.lax.psum(x, tuple(axis_names)
                        if not isinstance(axis_names, str) else axis_names)


def pmax(x, axis_names):
    return jax.lax.pmax(x, tuple(axis_names)
                        if not isinstance(axis_names, str) else axis_names)


def all_gather(x, axis_names, *, axis: int = 0, tiled: bool = False):
    name = tuple(axis_names) if not isinstance(axis_names, str) \
        else axis_names
    return jax.lax.all_gather(x, name, axis=axis, tiled=tiled)


def all_gather_groups(x, axis_names, groups, *, axis: int = 0,
                      tiled: bool = False):
    """Grouped ``all_gather``: each device gathers only within its row
    of ``groups`` — lists of row-major FLATTENED indices over
    ``axis_names`` (matching :func:`axis_index`) that must partition
    the devices. The intra-host leg of the two-level hier shuffle
    (DESIGN.md §16): group = the devices of one host, so the gather
    rides the fast local interconnect and never crosses the network.
    """
    name = tuple(axis_names) if not isinstance(axis_names, str) \
        else axis_names
    return jax.lax.all_gather(x, name, axis=axis, tiled=tiled,
                              axis_index_groups=[list(g) for g in groups])


def axis_size(axis_names) -> int:
    """Product of the named manual-axis sizes (trace-time constant)."""
    if isinstance(axis_names, str):
        axis_names = (axis_names,)
    n = 1
    for a in axis_names:
        n *= jax.lax.psum(1, a)
    return n


def ppermute(x, axis_names, perm):
    """``jax.lax.ppermute`` accepting a tuple of axis names.

    ``perm`` is over the row-major FLATTENED index of ``axis_names``
    (matching :func:`axis_index`). Newer JAX takes the tuple directly;
    on versions that reject multi-name ppermute the only shape this
    module needs — a cyclic shift of the flattened ring — is
    reconstructed from per-axis permutes (see :func:`ring_shift`).
    """
    if isinstance(axis_names, str) or len(tuple(axis_names)) == 1:
        name = axis_names if isinstance(axis_names, str) \
            else tuple(axis_names)[0]
        return jax.lax.ppermute(x, name, perm)
    return jax.lax.ppermute(x, tuple(axis_names), perm)


def ring_shift(tree: Any, axis_names) -> Any:
    """Send each device's pytree to its flattened-ring successor.

    Device ``i`` (row-major flattened index over ``axis_names``)
    receives the value of device ``i-1 mod N`` — one stage of the
    ring-pipelined SV shuffle. Tries the flattened multi-axis
    ``ppermute`` first; where the installed JAX only permutes a single
    named axis, the same ring is built from a cyclic shift on the
    innermost axis plus a wrap-correcting shift on the outer axes:
    only the innermost-last devices take the outer-shifted value, so
    exactly one logical hop happens either way (at 2× wire cost on
    those versions — correctness over bandwidth).
    """
    axes = tuple((axis_names,) if isinstance(axis_names, str)
                 else axis_names)
    n = axis_size(axes)
    perm = [(i, (i + 1) % n) for i in range(n)]
    if len(axes) == 1:
        return tree_map(lambda x: jax.lax.ppermute(x, axes[0], perm), tree)
    try:
        return tree_map(lambda x: jax.lax.ppermute(x, axes, perm), tree)
    except (TypeError, ValueError, NotImplementedError, KeyError):
        pass
    # Fallback: row-major ring = inner-axis shift, plus an outer-ring
    # shift taken only by the wrapping (inner-last → inner-first)
    # devices. The outer correction is itself a flattened ring over the
    # remaining axes, so the decomposition recurses until single-name
    # ppermutes remain.
    inner = axes[-1]
    inner_n = jax.lax.psum(1, inner)
    inner_perm = [(i, (i + 1) % inner_n) for i in range(inner_n)]
    outer = axes[:-1]
    inner_idx = jax.lax.axis_index(inner)

    def shift_one(x):
        stepped = jax.lax.ppermute(x, inner, inner_perm)
        wrapped = ring_shift(stepped, outer)
        return jnp.where(inner_idx == 0, wrapped, stepped)

    return tree_map(shift_one, tree)


# ---------------------------------------------------------------------------
# Multi-process runtime (repro.launch.cluster rides on these).
# ---------------------------------------------------------------------------

def distributed_initialize(coordinator_address: Optional[str] = None,
                           num_processes: Optional[int] = None,
                           process_id: Optional[int] = None,
                           **kwargs) -> None:
    """``jax.distributed.initialize`` with unsupported kwargs dropped.

    The core triple (coordinator/num_processes/process_id) is stable
    back to 0.4.x; the optional extras (``initialization_timeout``,
    ``coordinator_bind_address``, ``cluster_detection_method``, …)
    drifted in over the CI version matrix, so they are filtered against
    the installed signature instead of hard-coded.
    """
    impl = jax.distributed.initialize
    try:
        params = inspect.signature(impl).parameters
        kwargs = {k: v for k, v in kwargs.items() if k in params}
    except (TypeError, ValueError):           # pragma: no cover
        kwargs = {}
    impl(coordinator_address=coordinator_address,
         num_processes=num_processes, process_id=process_id, **kwargs)


def enable_cpu_collectives(impl: str = "gloo") -> bool:
    """Turn on cross-process CPU collectives (needed for any
    multi-process run on the CPU backend; TPU/GPU ignore it). Config
    name drift: ``jax_cpu_collectives_implementation`` (current) →
    ``jax_cpu_enable_gloo_collectives`` (transitional) → absent (no
    multi-process CPU support; returns False so the caller can raise a
    readable error instead of hanging in a collective).

    Call ONLY on the distributed path, between
    :func:`distributed_initialize` being decided and the first backend
    use: gloo collectives are constructed at CPU-client init from the
    distributed runtime client, so enabling them in a single-process
    program breaks backend creation outright (``distributed_client:
    NoneType``) — which is exactly why ``init_cluster``'s 1-process
    fast path never touches this."""
    try:
        jax.config.update("jax_cpu_collectives_implementation", impl)
        return True
    except (AttributeError, ValueError):
        pass
    if impl == "gloo":
        try:
            jax.config.update("jax_cpu_enable_gloo_collectives", True)
            return True
        except (AttributeError, ValueError):
            pass
        # 0.5+ builds gloo CPU collectives by default; a missing knob
        # there means nothing needs enabling.
        return jax_version() >= (0, 5, 0)
    return False


def make_array_from_process_local_data(sharding, local_data,
                                       global_shape: Optional[Tuple[int, ...]]
                                       = None):
    """Assemble a global ``jax.Array`` from THIS process's shard.

    ``local_data`` is the concatenation (along the sharded dimension)
    of the shards this process's addressable devices hold.  Newer JAX
    has ``jax.make_array_from_process_local_data``; the fallback builds
    the same array by slicing ``local_data`` per addressable device and
    feeding ``make_array_from_single_device_arrays`` — it supports the
    shapes this repo uses (at most ONE sharded dimension per array,
    possibly replicated over further mesh axes).
    """
    maker = getattr(jax, "make_array_from_process_local_data", None)
    if maker is not None:
        return maker(sharding, local_data, global_shape)
    local_data = np.asarray(local_data)
    if global_shape is None:
        raise ValueError("global_shape is required on JAX without "
                         "make_array_from_process_local_data")
    global_shape = tuple(int(s) for s in global_shape)
    idx_map = sharding.addressable_devices_indices_map(global_shape)

    def bounds(idx):
        idx = idx if isinstance(idx, tuple) else (idx,)
        idx = idx + (slice(None),) * (len(global_shape) - len(idx))
        return tuple((0 if s.start is None else int(s.start),
                      dim if s.stop is None else int(s.stop))
                     for s, dim in zip(idx, global_shape))

    uniq = sorted({bounds(i) for i in idx_map.values()})
    varying = [k for k in range(len(global_shape))
               if len({u[k] for u in uniq}) > 1]
    if len(varying) > 1:
        raise NotImplementedError(
            "fallback assembly supports one sharded dimension, got "
            f"{len(varying)} over shape {global_shape}")
    dim = varying[0] if varying else 0
    offsets = {}
    pos = 0
    for u in uniq:                      # unique shards, ascending offset
        size = u[dim][1] - u[dim][0]
        offsets[u] = (pos, size)
        pos += size
    if pos != local_data.shape[dim] and varying:
        raise ValueError(
            f"local data has {local_data.shape[dim]} rows on dim {dim} "
            f"but this process's shards cover {pos}")
    arrays = []
    for dev, idx in idx_map.items():
        start, size = offsets[bounds(idx)]
        sel = [slice(None)] * len(global_shape)
        if varying:
            sel[dim] = slice(start, start + size)
        arrays.append(jax.device_put(local_data[tuple(sel)], dev))
    return jax.make_array_from_single_device_arrays(
        global_shape, sharding, arrays)


def process_index() -> int:
    return int(jax.process_index())


def process_count() -> int:
    return int(jax.process_count())
