"""Assigned-architecture registry: ``get_config(arch_id)``.

Every config cites its source (model card / paper) and carries the
exact dimensions from the assignment table.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

ARCH_IDS: List[str] = [
    "qwen3_moe_235b_a22b",
    "tinyllama_1_1b",
    "rwkv6_7b",
    "llava_next_34b",
    "mixtral_8x22b",
    "llama3_8b",
    "whisper_base",
    "qwen2_1_5b",
    "chatglm3_6b",
    "zamba2_1_2b",
    # the paper's own workload (not in the assigned 10; extra)
    "svm_tfidf",
]

_ALIASES = {
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "rwkv6-7b": "rwkv6_7b",
    "llava-next-34b": "llava_next_34b",
    "mixtral-8x22b": "mixtral_8x22b",
    "llama3-8b": "llama3_8b",
    "whisper-base": "whisper_base",
    "qwen2-1.5b": "qwen2_1_5b",
    "chatglm3-6b": "chatglm3_6b",
    "zamba2-1.2b": "zamba2_1_2b",
    "svm-tfidf": "svm_tfidf",
}


def canonical(arch: str) -> str:
    return _ALIASES.get(arch, arch.replace("-", "_").replace(".", "_"))


def get_config(arch: str):
    mod = importlib.import_module(f"repro.configs.{canonical(arch)}")
    return mod.CONFIG


def all_configs() -> Dict[str, object]:
    return {a: get_config(a) for a in ARCH_IDS}
