"""chatglm3-6b [dense] — 2d/partial RoPE, GQA [arXiv:2406.12793]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    num_layers=28,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    rope_fraction=0.5,          # 2d RoPE: rotate half the head dim
    qkv_bias=True,              # chatglm uses QKV bias
    dtype="bfloat16",
    citation="arXiv:2406.12793 (28L d4096 32H kv2 ff13696 vocab65024, "
             "partial rotary)",
)
