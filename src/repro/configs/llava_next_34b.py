"""llava-next-34b [vlm] — anyres tiling; vision tower STUBBED
[hf:llava-hf/llava-v1.6-mistral-7b-hf family, 34B dims per assignment]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    frontend="vision",
    num_prefix_tokens=576,      # one anyres base tile of patch embeddings
    dtype="bfloat16",
    citation="hf:llava-hf/llava-v1.6 (60L d7168 56H kv8 ff20480 vocab64000; "
             "ViT+projector stubbed per spec)",
)
