"""mixtral-8x22b [moe] — 8 experts top-2, sliding-window attention [arXiv:2401.04088]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    num_experts=8,
    experts_per_token=2,
    sliding_window=4096,        # per model card → long_500k runs windowed
    rope_theta=1000000.0,
    dtype="bfloat16",
    citation="arXiv:2401.04088 (56L d6144 48H kv8 ff16384 vocab32768, "
             "8e top-2, SWA 4096)",
)
