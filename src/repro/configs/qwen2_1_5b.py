"""qwen2-1.5b [dense] — GQA with QKV bias [arXiv:2407.10671]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b",
    family="dense",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1000000.0,
    tie_embeddings=True,
    dtype="bfloat16",
    citation="arXiv:2407.10671 (28L d1536 12H kv2 ff8960 vocab151936, QKV bias)",
)
