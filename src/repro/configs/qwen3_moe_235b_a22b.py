"""qwen3-moe-235b-a22b [moe] — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B scaled per assignment]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    d_ff=1536,                  # per-expert FFN width
    vocab_size=151936,
    num_experts=128,
    experts_per_token=8,
    rope_theta=1000000.0,
    dtype="bfloat16",
    citation="hf:Qwen/Qwen3-30B-A3B (assignment: 94L d4096 64H kv4 ff1536 "
             "vocab151936, 128e top-8)",
)
