"""rwkv6-7b [ssm] — Finch, data-dependent decay, attention-free [arXiv:2404.05892]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    attn_free=True,
    num_layers=32,
    d_model=4096,
    num_heads=64,               # wkv heads = d_model/64
    num_kv_heads=64,
    d_ff=14336,
    vocab_size=65536,
    norm_style="layernorm",
    rope_fraction=0.0,
    dtype="bfloat16",
    citation="arXiv:2404.05892 (32L d4096 attn-free ff14336 vocab65536)",
)
