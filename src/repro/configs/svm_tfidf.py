"""svm-tfidf — the paper's own workload: distributed MapReduce SVM on a
TF×IDF matrix (Çatak 2014). Not one of the assigned 10; used by the
paper-table benchmarks and the MapReduce-SVM dry-run."""
import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class SVMTfidfConfig:
    name: str = "svm-tfidf"
    family: str = "svm"
    num_features: int = 131072       # hashed TF×IDF space (2^17)
    sv_capacity: int = 2048
    rows_per_device: int = 8192      # training rows resident per device
    C: float = 1.0
    max_epochs: int = 10
    stream_rows_per_wave: int = 8192  # new message rows folded per serve wave
    dtype: str = "bfloat16"   # §Perf it.5: bf16 feature stream, f32 solver state
    shuffle_impl: str = "ring"  # SV merge transport (DESIGN.md §10);
    #                             'allgather' keeps the monolithic collective
    row_format: str = "dense"   # 'dense' | 'sparse_csr' (DESIGN.md §12)
    nnz_cap: int = 256          # sparse_csr: (index, value) slots per row
    row_nnz: Optional[int] = None  # synthetic generator nonzeros/row;
    #                                None = the d/256 density default
    citation: str = "Çatak 2014 (the reproduced paper)"

    def __post_init__(self):
        # Same source of truth as MRSVMConfig: a transport added there
        # can't silently miss this layer.
        from repro.core.mapreduce_svm import SHUFFLE_IMPLS
        if self.shuffle_impl not in SHUFFLE_IMPLS:
            raise ValueError(
                f"shuffle_impl must be one of {SHUFFLE_IMPLS}, "
                f"got {self.shuffle_impl!r}")


CONFIG = SVMTfidfConfig()
