"""tinyllama-1.1b [dense] — llama2-arch small [arXiv:2401.02385]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b",
    family="dense",
    num_layers=22,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    d_ff=5632,
    vocab_size=32000,
    dtype="bfloat16",
    citation="arXiv:2401.02385 (22L d2048 32H kv4 ff5632 vocab32000)",
)
