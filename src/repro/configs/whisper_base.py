"""whisper-base [audio] — enc-dec; mel+conv frontend STUBBED [arXiv:2212.04356]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    is_encoder_decoder=True,
    encoder_layers=6,
    num_layers=6,               # decoder layers
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    encoder_seq=1500,           # 30 s of audio → 1500 frames
    max_decoder_len=448,        # model-card cap (decode shapes exceed family
                                # range; lowered mechanically, see DESIGN.md)
    mlp_style="gelu",
    norm_style="layernorm",
    qkv_bias=True,
    rope_fraction=0.0,          # learned/sinusoidal absolute positions
    tie_embeddings=True,
    dtype="bfloat16",
    citation="arXiv:2212.04356 (6L enc + 6L dec, d512 8H ff2048 vocab51865)",
)
