"""zamba2-1.2b [hybrid] — Mamba2 trunk + shared attention blocks [arXiv:2411.15242]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,            # shared block is MHA
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    attn_every=19,              # shared block applied after each 19-layer segment
    dtype="bfloat16",
    citation="arXiv:2411.15242 (38L d2048 32H kv32 ff8192 vocab32000, "
             "ssm_state 64, Mamba2 + shared attn)",
)
