"""Core library: the paper's MapReduce SVM contribution in JAX."""
from repro.core.kernel_fns import KernelConfig, apply_kernel
from repro.core.svm import (BinarySVM, SolverParams, SVMConfig,
                            decision_kernel, decision_linear, fit_binary,
                            support_mask)
from repro.core.mapreduce_svm import (CONVERGE_IMPLS, PACKED_SHUFFLES,
                                      SHUFFLE_IMPLS, MapReduceSVM,
                                      MRSVMConfig, RoundResult, SVBuffer,
                                      decision_values, fit_mapreduce,
                                      init_sv_buffer, make_sharded_round,
                                      mapreduce_round, predict,
                                      resolve_topology, update_mapreduce)
from repro.core.multiclass import (OneVsOneSVM, OneVsRestSVM,
                                   confusion_matrix, fit_one_vs_one,
                                   fit_one_vs_rest)
from repro.core.risk import converged, empirical_risk, hinge_loss, zero_one_loss
from repro.core.sweep import (DedupChunk, ShardedSweep, SweepOneVsRest,
                              SweepResult, build_sharded_sweep_round,
                              dedup_candidates, dedup_unique_cap,
                              expand_chunk, expand_sweep_sv,
                              fit_mapreduce_sweep, fit_one_vs_rest_sweep,
                              init_sharded_sweep_sv, make_sharded_sweep_round,
                              predict_sweep, restore_sweep_state,
                              run_sharded_sweep, save_sweep_state,
                              stack_params, sweep_decision_values, sweep_grid)

__all__ = [
    "KernelConfig", "apply_kernel", "BinarySVM", "SolverParams", "SVMConfig",
    "decision_kernel", "decision_linear", "fit_binary", "support_mask",
    "CONVERGE_IMPLS", "PACKED_SHUFFLES", "SHUFFLE_IMPLS",
    "MapReduceSVM", "MRSVMConfig", "RoundResult", "SVBuffer",
    "resolve_topology",
    "decision_values", "fit_mapreduce", "init_sv_buffer",
    "make_sharded_round", "mapreduce_round", "predict",
    "update_mapreduce",
    "OneVsOneSVM", "OneVsRestSVM", "confusion_matrix", "fit_one_vs_one",
    "fit_one_vs_rest", "converged", "empirical_risk", "hinge_loss",
    "zero_one_loss",
    "DedupChunk", "ShardedSweep", "SweepOneVsRest", "SweepResult",
    "build_sharded_sweep_round", "dedup_candidates", "dedup_unique_cap",
    "expand_chunk", "expand_sweep_sv", "fit_mapreduce_sweep",
    "fit_one_vs_rest_sweep", "init_sharded_sweep_sv",
    "make_sharded_sweep_round", "predict_sweep", "restore_sweep_state",
    "run_sharded_sweep", "save_sweep_state", "stack_params",
    "sweep_decision_values", "sweep_grid",
]
