"""Kernel functions for the (soft-margin) SVM dual.

The paper (Çatak 2014) trains soft-margin SVMs (eq. 1-2) on TF×IDF
features; linear kernels dominate in text classification, but the
dual solver in :mod:`repro.core.svm` is kernelized so rbf/poly are
first-class too.

All kernels take ``X (n, d)`` and ``Z (m, d)`` and return ``K (n, m)``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Literal

import jax
import jax.numpy as jnp

from repro import sparse as sparse_rows

KernelName = Literal["linear", "rbf", "poly"]


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    name: KernelName = "linear"
    gamma: float = 1.0      # rbf / poly scale
    degree: int = 3         # poly
    coef0: float = 0.0      # poly

    def fn(self):
        return functools.partial(apply_kernel, cfg=self)


def linear_kernel(X: jax.Array, Z: jax.Array) -> jax.Array:
    return X @ Z.T


def rbf_kernel(X: jax.Array, Z: jax.Array, gamma: float) -> jax.Array:
    # ||x - z||^2 = ||x||^2 + ||z||^2 - 2 x.z ; numerically clamped at 0.
    xx = jnp.sum(X * X, axis=-1, keepdims=True)
    zz = jnp.sum(Z * Z, axis=-1, keepdims=True)
    sq = jnp.maximum(xx + zz.T - 2.0 * (X @ Z.T), 0.0)
    return jnp.exp(-gamma * sq)


def poly_kernel(X: jax.Array, Z: jax.Array, gamma: float, degree: int,
                coef0: float) -> jax.Array:
    return (gamma * (X @ Z.T) + coef0) ** degree


def apply_kernel(X: jax.Array, Z: jax.Array, *, cfg: KernelConfig,
                 gamma=None, coef0=None) -> jax.Array:
    """k(X, Z) under ``cfg``. ``gamma``/``coef0`` may be traced jnp
    scalars overriding the static dataclass values — the hook that lets
    the sweep subsystem vmap over kernel scales while the kernel *name*
    (a program choice) stays static. ``degree`` is deliberately not
    overridable: a traced exponent lowers to float ``pow`` with a
    NaN-producing negative-base branch."""
    g = cfg.gamma if gamma is None else gamma
    c0 = cfg.coef0 if coef0 is None else coef0
    if sparse_rows.is_sparse(X) or sparse_rows.is_sparse(Z):
        # Sparse path (ISSUE 6): one gather/segment-sum dot-product
        # build, then the same linear/rbf/poly transforms as dense.
        dots = sparse_rows.cross_dots(X, Z)
        if cfg.name == "linear":
            return dots
        if cfg.name == "rbf":
            xx = sparse_rows.row_sq_norms(X)[:, None]
            zz = sparse_rows.row_sq_norms(Z)[None, :]
            sq = jnp.maximum(xx + zz - 2.0 * dots, 0.0)
            return jnp.exp(-g * sq)
        if cfg.name == "poly":
            return (g * dots + c0) ** cfg.degree
        raise ValueError(f"unknown kernel {cfg.name!r}")
    if cfg.name == "linear":
        return linear_kernel(X, Z)
    if cfg.name == "rbf":
        return rbf_kernel(X, Z, g)
    if cfg.name == "poly":
        return poly_kernel(X, Z, g, cfg.degree, c0)
    raise ValueError(f"unknown kernel {cfg.name!r}")
