"""The paper's contribution: iterative MapReduce SVM with global
support-vector exchange (Çatak 2014, Tablo 1-2, eq. 6-9).

Algorithm (one *round* = one MapReduce job):

  map    : D_l^t ← D_l ∪ SV_global^t          (augment partitions)
  reduce : (SV_l, h_l^t) ← binarySvm(D_l^t)   (local dual solve)
  merge  : SV_global^{t+1} ← ∪_l SV_l          (the "shuffle")
  driver : h^t = argmin_l R_emp(h_l^t);  stop when
           |R_emp(h^{t-1}) − R_emp(h^t)| ≤ γ  (eq. 8)

TPU-native adaptations (see DESIGN.md §2):

* XLA needs static shapes, so SV_global is a **capacity-bounded,
  mask-padded buffer**. Each partition contributes its top
  ``capacity // L`` support vectors by α — a balanced union.
* A row's "is a support vector" evidence is ``max(α_home, α_copy)``
  over every copy of the row (its home partition + the appended
  global-SV copies on all other partitions), matching the paper's
  set-union semantics without duplicate rows.
* Two execution modes share the same math:
  - **functional** (`fit_mapreduce`): partitions on the leading axis,
    reducers run under `vmap`. Used by tests, benchmarks, examples.
  - **sharded** (`make_sharded_round`): partitions = devices of the
    ``("data",)`` / ``("pod", "data")`` mesh axes under `shard_map`;
    the merge — the ICI analogue of the Hadoop shuffle — is a tiled
    `lax.all_gather`, the ring-pipelined `ppermute` transport, or the
    topology-aware two-level hier transport (``MRSVMConfig.
    shuffle_impl``, DESIGN.md §10/§16). Used by the launcher and the
    multi-pod dry-run.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro import faults
from repro import sparse as sparse_rows
from repro.analysis.hostsync import allowed_host_sync
from repro.core import risk as risk_lib
from repro.core.svm import (BinarySVM, SolverParams, SVMConfig,
                            decision_kernel, decision_linear, fit_binary)

# Single source of truth for the merge-collective transports of the
# sharded mode (DESIGN.md §10, §16) and the eq. 8 convergence-readback
# collectives (§16). Config validation, ``configs/svm_tfidf.py``, the
# ``--shuffle`` CLI choices and the lint matrix all derive from these
# tuples, so a new transport cannot silently miss a layer.
SHUFFLE_IMPLS = ("allgather", "ring", "hier")
CONVERGE_IMPLS = ("psum", "tree")

# The transports whose wire format is the coalesced packed f32 message
# (ring stages or hier host-stages) — they share the hop engine
# (:func:`_merge_hops`) and, on sweeps, the dedup state layout.
PACKED_SHUFFLES = ("ring", "hier")


class SVBuffer(NamedTuple):
    """Capacity-bounded global support-vector set SV_global^t."""
    x: jax.Array      # (cap, d) feature rows
    y: jax.Array      # (cap,)   labels in {-1, +1} (0 on padding)
    alpha: jax.Array  # (cap,)   dual coefficient evidence (max over copies)
    ids: jax.Array    # (cap,)   stable global row ids (int32, -1 padding)
    mask: jax.Array   # (cap,)   1.0 where the slot holds a real SV


class RoundResult(NamedTuple):
    sv: SVBuffer
    risks: jax.Array   # (L,) empirical risk of every reducer hypothesis on FULL data
    ws: jax.Array      # (L, d) reducer primal hypotheses (linear path)
    bs: jax.Array      # (L,)
    sv_count: jax.Array  # () live slots in the new buffer


@dataclasses.dataclass(frozen=True)
class MRSVMConfig:
    """Driver configuration for the iterative MapReduce SVM.

    ``shuffle_impl`` selects the merge-collective transport of the
    sharded mode (DESIGN.md §10):

    * ``"allgather"`` — one blocking tiled ``all_gather`` of the full
      candidate buffer (the historical transport);
    * ``"ring"`` — the merge is split into ``num_devices`` ring stages
      over ``ppermute``, double-buffered so stage t's permute is in
      flight while stage t-1's chunk is consumed (buffer assembly +
      eq. 7 hypothesis scoring overlap the collective), with feature
      rows shipped in ``shuffle_wire_dtype`` (f32 α/ids sideband);
    * ``"hier"`` — the topology-aware two-level transport (§16): the
      flat ring's ``num_devices`` stages collapse to ``num_hosts``
      host-stages — per stage ONE inter-host ``ppermute`` (each device
      forwards its slice of the in-flight host super-message, so only
      the bytes a host has never seen cross the network) expanded by an
      intra-host grouped ``all_gather`` (fast local interconnect) into
      the arrived host's messages, still overlapping eq. 7 scoring.
      ``hier_num_hosts`` pins the host-group count for simulated
      topologies; ``None`` reads the real process count at build time.

    All transports converge to the same model; the packed transports
    (ring, hier) additionally dedup cross-config SV rows on the sweep
    axis (``sweep_dedup``, :mod:`repro.core.sweep`):
    ``dedup_max_unique`` caps the unique-row slots a device ships per
    round — ``None`` means ``min(S·k, per)``, which can never drop a
    live row (lossless) while shrinking the S× payload whenever configs
    share rows or ``per < S·k``.

    ``converge_impl`` selects the eq. 8 convergence-readback collective
    (the global risk mean): ``"psum"`` is the flat all-reduce,
    ``"tree"`` the log2(P) recursive-doubling (binomial-tree) exchange
    over XOR-partner ``ppermute`` stages (power-of-two device counts).
    """
    sv_capacity: int = 256
    svm: SVMConfig = SVMConfig()
    gamma: float = 1e-3          # eq. 8 convergence tolerance on R_emp
    max_rounds: int = 10
    risk_loss: str = "hinge"     # 'hinge' (used in eq. 6) or 'zero_one'
    shuffle_impl: str = "allgather"       # one of SHUFFLE_IMPLS
    shuffle_wire_dtype: str = "bfloat16"  # packed: feature-row wire dtype
    sweep_dedup: bool = True              # packed sweep: cross-config dedup
    dedup_max_unique: Optional[int] = None  # unique slots/chunk; None=lossless
    hier_num_hosts: Optional[int] = None  # hier: host groups; None=processes
    converge_impl: str = "psum"           # one of CONVERGE_IMPLS
    # Ring wire-integrity check (DESIGN.md §15): each hop's coalesced
    # message carries one extra f32 lane holding the int32 wrap-sum of
    # its bitcast payload; a receiver-side mismatch poisons the round's
    # risks to +inf, which the host driver turns into a typed
    # FaultDetected at its eq. 8 readback. Off by default — the lane
    # changes the compiled program, and the committed dry-run artifacts
    # record the unchecked transport.
    shuffle_wire_check: bool = False

    def __post_init__(self):
        if self.shuffle_impl not in SHUFFLE_IMPLS:
            raise ValueError(
                f"shuffle_impl must be one of {SHUFFLE_IMPLS}, "
                f"got {self.shuffle_impl!r}")
        if self.converge_impl not in CONVERGE_IMPLS:
            raise ValueError(
                f"converge_impl must be one of {CONVERGE_IMPLS}, "
                f"got {self.converge_impl!r}")
        if self.hier_num_hosts is not None and self.hier_num_hosts < 1:
            raise ValueError(
                f"hier_num_hosts must be >= 1, got {self.hier_num_hosts}")
        wdt = jnp.dtype(self.shuffle_wire_dtype)
        if wdt.itemsize not in (2, 4) or \
                not jnp.issubdtype(wdt, jnp.floating):
            raise ValueError(
                "shuffle_wire_dtype must be a 2- or 4-byte float "
                f"(bf16/f16/f32), got {self.shuffle_wire_dtype!r}")


def init_sv_buffer(capacity: int, d: int, dtype=jnp.float32,
                   nnz_cap: Optional[int] = None) -> SVBuffer:
    """SV_global^0 = ∅ (empty, mask-padded buffer). With ``nnz_cap``
    the feature rows are blocked-CSR :class:`repro.sparse.SparseRows`
    (index 0 / value 0 padding ≡ the empty row)."""
    if nnz_cap is None:
        x = jnp.zeros((capacity, d), dtype)
    else:
        x = sparse_rows.SparseRows(
            jnp.zeros((capacity, nnz_cap), jnp.int32),
            jnp.zeros((capacity, nnz_cap), dtype), d)
    return SVBuffer(
        x=x,
        y=jnp.zeros((capacity,), dtype),
        alpha=jnp.zeros((capacity,), dtype),
        ids=-jnp.ones((capacity,), jnp.int32),
        mask=jnp.zeros((capacity,), dtype),
    )


def _augment(Xl, yl, ml, sv: SVBuffer):
    """map phase: D_l ← D_l ∪ SV_global (per partition)."""
    Xa = sparse_rows.rows_concat(Xl, sv.x, axis=0)
    ya = jnp.concatenate([yl, sv.y], axis=0)
    ma = jnp.concatenate([ml, sv.mask], axis=0)
    return Xa, ya, ma


# ---------------------------------------------------------------------------
# Functional (vmap) mode — partitions on a leading axis.
# ---------------------------------------------------------------------------

def mapreduce_round(Xp: jax.Array, yp: jax.Array, maskp: jax.Array,
                    sv: SVBuffer, cfg: MRSVMConfig,
                    params: Optional[SolverParams] = None) -> RoundResult:
    """One full MapReduce round over stacked partitions.

    Xp: (L, per, d); rows are ordered so global id of (l, i) = l*per + i.
    ``params`` optionally overrides the value-like solver hyper-params
    with a traced pytree — the hook the sweep subsystem vmaps over.
    """
    L, per, d = Xp.shape
    p = cfg.svm.params() if params is None else params
    cap = sv.x.shape[0]
    if cap % L != 0:
        raise ValueError(f"sv_capacity {cap} must divide by partitions {L}")
    k = cap // L

    # --- map + reduce ------------------------------------------------------
    # NB: forward the *original* ``params`` (possibly None), not the
    # lifted ``p`` — fit_binary distinguishes "no override" (static
    # defaults, Pallas Gram allowed) from a traced sweep override.
    def reducer(Xl, yl, ml):
        Xa, ya, ma = _augment(Xl, yl, ml, sv)
        return fit_binary(Xa, ya, ma, cfg.svm, params=params)

    res: BinarySVM = jax.vmap(reducer)(Xp, yp, maskp)
    alpha = res.alpha                                # (L, per + cap)
    home_alpha = alpha[:, :per].reshape(-1)          # (L*per,) by global id
    copy_alpha = alpha[:, per:]                      # (L, cap) appended copies

    # --- union semantics: α_eff(row) = max over all copies ------------------
    buf_alpha = jnp.max(copy_alpha, axis=0) * sv.mask          # (cap,)
    safe_ids = jnp.where(sv.ids >= 0, sv.ids, 0)
    folded = jnp.zeros_like(home_alpha).at[safe_ids].max(
        jnp.where(sv.ids >= 0, buf_alpha, 0.0))
    home_alpha = jnp.maximum(home_alpha, folded).reshape(L, per) * maskp

    # --- merge: balanced top-k per partition, concatenated -------------------
    topv, topi = jax.lax.top_k(home_alpha, k)                   # (L, k)
    sel = lambda A: jnp.take_along_axis(A, topi, axis=1)
    new_x = sparse_rows.take_rows_along(Xp, topi).reshape(cap, d)
    new_y = sel(yp).reshape(cap)
    live = (topv > p.sv_threshold).astype(Xp.dtype)
    base_ids = (jnp.arange(L, dtype=jnp.int32) * per)[:, None] + topi.astype(jnp.int32)
    new_sv = SVBuffer(
        x=new_x * live.reshape(cap, 1),
        y=new_y * live.reshape(cap),
        alpha=(topv * live).reshape(cap),
        ids=jnp.where(live.reshape(cap) > 0, base_ids.reshape(cap), -1),
        mask=live.reshape(cap),
    )

    # --- driver: risk of every reducer hypothesis on the FULL data (eq. 7) --
    Xflat = Xp.reshape(L * per, d)
    yflat = yp.reshape(L * per)
    mflat = maskp.reshape(L * per)
    if cfg.svm.kernel.name == "linear" and not cfg.svm.use_gram:
        scores = Xflat @ res.w.T + res.b[None, :]               # (n, L)
        risks = jax.vmap(
            lambda s: risk_lib.empirical_risk(s, yflat, mflat, cfg.risk_loss),
            in_axes=1)(scores)
    else:
        def risk_of(Xa, ya, ma, a, b):
            coef = a * ya * ma
            s = decision_kernel(Xa, coef, b, Xflat, cfg.svm.kernel,
                                gamma=p.gamma, coef0=p.coef0)
            return risk_lib.empirical_risk(s, yflat, mflat, cfg.risk_loss)
        Xa, ya, ma = jax.vmap(lambda X, y, m: _augment(X, y, m, sv))(Xp, yp, maskp)
        risks = jax.vmap(risk_of)(Xa, ya, ma, alpha, res.b)
    return RoundResult(sv=new_sv, risks=risks, ws=res.w, bs=res.b,
                       sv_count=jnp.sum(new_sv.mask))


# Module-level jits keyed on the (hashable, frozen) cfg: repeated
# fit_mapreduce / update_mapreduce calls with the same shapes+config hit
# the jit cache instead of retracing per call. A per-call
# ``jax.jit(lambda ...)`` would recompile EVERY streaming wave — the
# trace cost then dwarfs the (new rows ∪ SVs) compute advantage the
# incremental update exists for (benchmarks/streaming.py).
@functools.partial(jax.jit, static_argnames=("cfg",))
def _round_jit(Xp, yp, maskp, sv, params, cfg: MRSVMConfig) -> RoundResult:
    return mapreduce_round(Xp, yp, maskp, sv, cfg, params=params)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _final_fit_jit(sv: SVBuffer, params, cfg: MRSVMConfig) -> BinarySVM:
    return fit_binary(sv.x, sv.y, sv.mask, cfg.svm, params=params)


class MapReduceSVM(NamedTuple):
    """Driver output: best reducer hypothesis (eq. 7) + final SV model."""
    w: jax.Array            # (d,) best linear hypothesis (zeros on kernel path)
    b: jax.Array
    sv: SVBuffer            # converged SV_global
    final: BinarySVM        # model retrained on SV_global alone
    risk: jax.Array         # R_emp(h^T) of the selected hypothesis
    rounds: int
    history: Tuple[dict, ...]


def fit_mapreduce(X: jax.Array, y: jax.Array, num_partitions: int,
                  cfg: MRSVMConfig,
                  mask: Optional[jax.Array] = None,
                  params: Optional[SolverParams] = None,
                  verbose: bool = False) -> MapReduceSVM:
    """Iterative MapReduce SVM driver (functional mode).

    Pads ``X`` to a multiple of ``num_partitions`` and loops rounds on
    the host until eq. 8 fires or ``max_rounds`` is hit. ``params``
    optionally overrides the value-like solver hyper-params (traced).
    """
    n, d = X.shape
    L = num_partitions
    per = -(-n // L)
    pad = L * per - n
    Xp = sparse_rows.pad_rows(X, pad).reshape(L, per, d)
    yp = jnp.pad(y.astype(X.dtype), (0, pad)).reshape(L, per)
    base_mask = jnp.ones((n,), X.dtype) if mask is None else mask.astype(X.dtype)
    maskp = jnp.pad(base_mask, (0, pad)).reshape(L, per)

    sv = init_sv_buffer(
        cfg.sv_capacity, d, X.dtype,
        nnz_cap=X.nnz_cap if sparse_rows.is_sparse(X) else None)

    best = (np.inf, None, None)
    prev_risk = np.inf
    history = []
    rounds_done = 0
    for t in range(cfg.max_rounds):
        # transport seams (DESIGN.md §15): a delayed round completes
        # late but EXACTLY (survived bit-for-bit); a transiently failing
        # merge is retried with backoff — only the injected
        # TransientFault retries, real solver errors surface at once.
        faults.maybe_sleep("transport.round", when=t)

        def run_round():
            faults.maybe_raise("transport.merge",
                               kinds=("transport_exc",), when=t)
            return _round_jit(Xp, yp, maskp, sv, params, cfg=cfg)

        out = faults.retry_with_backoff(
            run_round, attempts=3, base_s=0.05,
            retry_on=faults.TransientFault, layer="transport",
            cause=f"merge collective at round {t}",
            action="check inter-host links; a persistent failure means "
                   "the mesh lost a member — restart from the last "
                   "checkpoint")
        sv = out.sv
        # eq. 8's designed device→host sync point: sanctioned for the
        # host-sync lint (DESIGN.md §14) by name, right where it happens.
        with allowed_host_sync("eq. 8 risk readback"):
            risks = np.asarray(out.risks)
        faults.check_finite_risks(risks, where=f"mapreduce round {t}")
        l_star = int(np.argmin(risks))
        r_star = float(risks[l_star])
        if r_star < best[0]:
            best = (r_star, out.ws[l_star], out.bs[l_star])
        history.append({"round": t, "risk": r_star, "reducer": l_star,
                        "sv_count": int(out.sv_count)})
        rounds_done = t + 1
        if verbose:
            print(f"[mapreduce-svm] round={t} R_emp={r_star:.5f} "
                  f"|SV|={int(out.sv_count)}")
        if t > 0 and abs(prev_risk - r_star) <= cfg.gamma:   # eq. 8
            break
        prev_risk = r_star

    # Final consolidated model: retrain on SV_global alone (cascade-style).
    final = _final_fit_jit(sv, params, cfg=cfg)
    return MapReduceSVM(w=best[1], b=best[2], sv=sv, final=final,
                        risk=jnp.asarray(best[0]), rounds=rounds_done,
                        history=tuple(history))


def predict(model: MapReduceSVM, X: jax.Array, cfg: MRSVMConfig,
            use_final: bool = True,
            params: Optional[SolverParams] = None) -> jax.Array:
    """±1 predictions from the converged model. Pass the same ``params``
    the model was trained with (if any) so kernel scales match."""
    if cfg.svm.kernel.name == "linear" and not cfg.svm.use_gram:
        w, b = (model.final.w, model.final.b) if use_final else (model.w, model.b)
        return jnp.where(decision_linear(w, b, X) >= 0, 1.0, -1.0)
    s = decision_values(model, X, cfg, params=params)
    return jnp.where(s >= 0, 1.0, -1.0)


def decision_values(model: MapReduceSVM, X: jax.Array,
                    cfg: MRSVMConfig,
                    params: Optional[SolverParams] = None) -> jax.Array:
    if cfg.svm.kernel.name == "linear" and not cfg.svm.use_gram:
        return decision_linear(model.final.w, model.final.b, X)
    coef = model.final.alpha * model.sv.y * model.sv.mask
    gamma = None if params is None else params.gamma
    coef0 = None if params is None else params.coef0
    return decision_kernel(model.sv.x, coef, model.final.b, X,
                           cfg.svm.kernel, gamma=gamma, coef0=coef0)


def update_mapreduce(model: MapReduceSVM, X_new: jax.Array,
                     y_new: jax.Array, num_partitions: int,
                     cfg: MRSVMConfig,
                     params: Optional[SolverParams] = None,
                     verbose: bool = False) -> MapReduceSVM:
    """Incremental model update — the paper's stated future work
    (§SONUÇ: "zaman içerisinde kendini güncelleyen eğitim veri seti
    kullanılarak sınıflandırma modelinin güncelliğini koruması").

    The converged global SV set is the model's sufficient statistic:
    updating on a new message batch trains on (new data ∪ old SVs) —
    old non-support examples never travel, the same bandwidth argument
    as the original shuffle. Returns a fresh converged model.

    Pass the same ``params`` the model was trained with (if any): the
    carried SV alphas were solved at that kernel scale, so re-fitting
    with the config defaults would silently change gamma/coef0/C under
    a sweep-trained model.
    """
    d_model = model.sv.x.shape[1]
    if X_new.shape[1] != d_model:
        raise ValueError(
            f"update batch has {X_new.shape[1]} features but the model's "
            f"SV buffer holds {d_model}-dim rows — vectorize new messages "
            "with the SAME featurizer (hash space / idf) as training")
    X = sparse_rows.rows_concat(X_new, model.sv.x, axis=0)
    y = jnp.concatenate([y_new.astype(X_new.dtype), model.sv.y], axis=0)
    mask = jnp.concatenate([jnp.ones((X_new.shape[0],), X_new.dtype),
                            model.sv.mask], axis=0)
    return fit_mapreduce(X, y, num_partitions, cfg, mask=mask,
                         params=params, verbose=verbose)


# ---------------------------------------------------------------------------
# Sharded (shard_map) mode — partitions = devices.
# ---------------------------------------------------------------------------

def _round_candidates(Xl, yl, ml, sv: SVBuffer, cfg: MRSVMConfig,
                      axes, idx, k: int, per: int,
                      params: Optional[SolverParams]):
    """map + reduce + union-fold + balanced top-k of ONE device.

    Returns ``(cand, w, b)``: the device's (k,)-row candidate SV chunk
    and its reducer hypothesis. Shared by both merge transports and
    vmapped over the config axis by the sweep subsystem.
    """
    p = cfg.svm.params() if params is None else params
    # map + reduce (original ``params``, not ``p`` — see mapreduce_round)
    Xa, ya, ma = _augment(Xl, yl, ml, sv)
    res = fit_binary(Xa, ya, ma, cfg.svm, params=params, vma_axes=axes)
    home_alpha = res.alpha[:per]
    copy_alpha = res.alpha[per:] * sv.mask

    # union semantics: fold the max appended-copy α back into the
    # home rows (buffer row with global id g lives on device g//per).
    buf_alpha = compat.pmax(copy_alpha, axes)           # (cap,)
    mine = jnp.logical_and(sv.ids >= 0, sv.ids // per == idx)
    pos = jnp.where(mine, sv.ids % per, 0)
    folded = jnp.zeros((per,), Xl.dtype).at[pos].max(
        jnp.where(mine, buf_alpha, 0.0))
    home_alpha = jnp.maximum(home_alpha, folded) * ml

    # balanced top-k per device — the candidate chunk of the shuffle
    topv, topi = jax.lax.top_k(home_alpha, k)
    live = (topv > p.sv_threshold).astype(Xl.dtype)
    cand_ids = (idx * per + topi).astype(jnp.int32)
    cand = SVBuffer(
        x=Xl[topi] * live[:, None],
        y=yl[topi] * live,
        alpha=topv * live,
        ids=jnp.where(live > 0, cand_ids, -1),
        mask=live,
    )
    return cand, res.w, res.b


def _device_risks(scores, yl, ml, cfg: MRSVMConfig, axes, ndev: int):
    """eq. 7 empirical risks from per-device (per, ndev) scores.

    The global (Σ loss)/(Σ count) is the eq. 8 convergence-readback
    collective: ``converge_impl="psum"`` is the flat all-reduce;
    ``"tree"`` runs log2(ndev) recursive-doubling (binomial-tree)
    stages over XOR-partner ``ppermute``s — partial risks and the row
    count ride ONE combined vector, so each stage is a single wire
    message and the reduction finishes in log2(ndev) hops instead of
    the flat all-reduce's implementation-chosen schedule (§16).
    """
    if cfg.risk_loss == "hinge":
        per_ex = jnp.maximum(0.0, 1.0 - yl[:, None] * scores)
    else:
        # Shared decision convention (score >= 0 → +1) with
        # risk_lib.zero_one_loss / predict — see that docstring.
        per_ex = risk_lib.zero_one_loss(scores, yl[:, None]).astype(
            scores.dtype)
    part = jnp.sum(per_ex * ml[:, None], axis=0)
    cnt = jnp.sum(ml)
    if cfg.converge_impl == "tree":
        vec = jnp.concatenate([part, cnt.reshape(1).astype(part.dtype)])
        s = 1
        while s < ndev:                  # power of two — build-time checked
            vec = vec + compat.ppermute(
                vec, axes, [(i, i ^ s) for i in range(ndev)])
            s <<= 1
        return vec[:-1] / jnp.maximum(vec[-1], 1.0)
    return compat.psum(part, axes) / jnp.maximum(
        compat.psum(cnt, axes), 1.0)


def _pack_lanes(xw, wire_dt):
    """(n, m) wire-dtype matrix → ``(lanes (n, slots) f32, slots)``:
    2-byte dtypes bitcast element PAIRS into one f32 lane (lossless —
    the bits just ride along), 4-byte floats pass through."""
    n, m = xw.shape
    size = jnp.dtype(wire_dt).itemsize
    if size == 2:
        mp = m + (m % 2)
        xw = jnp.pad(xw, ((0, 0), (0, mp - m)))
        return jax.lax.bitcast_convert_type(
            xw.reshape(n, mp // 2, 2), jnp.float32), mp // 2
    if size != 4:
        raise ValueError(f"unsupported shuffle_wire_dtype {wire_dt}")
    return jax.lax.bitcast_convert_type(xw, jnp.float32), m


def _unpack_lanes(lanes, m: int, wire_dt):
    """Inverse of :func:`_pack_lanes`: (n, slots) f32 → (n, m) wire."""
    n = lanes.shape[0]
    if jnp.dtype(wire_dt).itemsize == 2:
        rows = jax.lax.bitcast_convert_type(lanes, wire_dt)  # (n, slots, 2)
        return rows.reshape(n, -1)[:, :m]
    return jax.lax.bitcast_convert_type(lanes, wire_dt)


def pack_wire_rows(x, wire_dt):
    """Flatten feature rows into f32 lanes for the coalesced ring
    message. Returns ``(flat, wslots)`` with ``wslots`` f32 lanes per
    row.

    Dense rows ship all ``d`` features in the wire dtype. Blocked-CSR
    rows (:class:`repro.sparse.SparseRows`) ship per row only the
    ``nnz_cap`` (index, value) pairs — values packed like the dense
    case, int32 indices bitcast into f32 lanes verbatim (never
    quantized) — so the payload scales with ``nnz_cap``, not ``d``:
    the ~10-100× shrink on top of the bf16 pair-packing (DESIGN.md
    §12)."""
    if sparse_rows.is_sparse(x):
        vf, vslots = _pack_lanes(x.values.astype(jnp.dtype(wire_dt)),
                                 wire_dt)
        idxf = jax.lax.bitcast_convert_type(x.indices, jnp.float32)
        lanes = jnp.concatenate([vf, idxf], axis=1)
        return lanes.reshape(-1), vslots + x.nnz_cap
    n, d = x.shape
    lanes, slots = _pack_lanes(x.astype(jnp.dtype(wire_dt)), wire_dt)
    return lanes.reshape(n * slots), slots


def unpack_wire_rows(flat, n: int, d: int, wire_dt, wslots: int,
                     nnz_cap: Optional[int] = None):
    """Inverse of :func:`pack_wire_rows`: f32 lanes → (n, d) wire-dtype
    feature rows (dense), or — with ``nnz_cap`` — the blocked-CSR
    :class:`repro.sparse.SparseRows` the sparse pack shipped."""
    wire_dt = jnp.dtype(wire_dt)
    arr = flat.reshape(n, wslots)
    if nnz_cap is not None:
        vslots = wslots - nnz_cap
        vals = _unpack_lanes(arr[:, :vslots], nnz_cap, wire_dt)
        idx = jax.lax.bitcast_convert_type(arr[:, vslots:], jnp.int32)
        return sparse_rows.SparseRows(idx, vals, d)
    return _unpack_lanes(arr, d, wire_dt)


class _HopPlan(NamedTuple):
    """Transport parameterization of the hop engine (:func:`_merge_hops`):
    ``num_stages`` hops of the ``shift`` permutation, each expanded by
    the ``expand`` group collective into ``m`` arrived messages; ``gi``
    is this device's (traced) origin-group index for the assembly roll.
    """
    num_stages: int   # hops of the merge (ring: ndev, hier: num_hosts)
    m: int            # messages consumed per stage (ring: 1, hier: ndev/H)
    gi: jax.Array     # this device's origin-group index (traced)
    shift: object     # hop permutation: in-flight (L,) msg -> next group
    expand: object    # group collective: (L,) msg -> (m, L) arrived block


def resolve_topology(cfg: MRSVMConfig, num_devices: int) -> int:
    """Build-time topology facts: the hier host-group count, plus the
    static validation the collectives need.

    ``cfg.hier_num_hosts`` pins the host count (simulated topologies,
    dry-runs); ``None`` reads the real process count — the process-major
    device order of ``launch.mesh.make_cluster_mesh`` guarantees
    host = flat_index // local_device_count, which is exactly the
    grouping the hier plan's groups/permutation assume. One host
    degenerates to a single grouped all_gather (zero inter-host hops);
    hosts == num_devices degenerates to the flat ring.
    """
    if cfg.converge_impl == "tree" and (num_devices & (num_devices - 1)):
        raise ValueError(
            "converge_impl='tree' (recursive doubling) needs a "
            f"power-of-two device count, got {num_devices}")
    if cfg.shuffle_impl != "hier":
        return 1
    hosts = cfg.hier_num_hosts or max(compat.process_count(), 1)
    if num_devices % hosts:
        raise ValueError(
            f"hier shuffle needs the device count ({num_devices}) "
            f"divisible by the host count ({hosts}); pin "
            "MRSVMConfig.hier_num_hosts for simulated topologies")
    return hosts


def _hop_plan(cfg: MRSVMConfig, axes, ndev: int, idx,
              hosts: int) -> _HopPlan:
    """The (group collective, hop permutation, messages-per-hop) triple
    of each packed transport (DESIGN.md §16).

    * ``ring``: ndev stages of the flattened-ring shift, one message
      per stage, no group collective (``expand`` is a reshape).
    * ``hier``: ``hosts`` host-stages. Device (h, l) = flat h·Dl+l
      forwards its (L,)-slice of the in-flight host super-message to
      device (h+1, l) — a FULL permutation whose every pair crosses a
      host boundary, so per stage exactly Dl·L values (the bytes the
      next host has never seen — the information floor) cross the
      network. The intra-host grouped all_gather then reassembles the
      arrived host's Dl messages on the local interconnect for scoring
      and assembly. The ppermute chain forwards the cp INPUT, not the
      gather output, so stage t+1's wire time overlaps stage t's
      expand+consume exactly like the flat ring's double buffering.
    """
    if cfg.shuffle_impl == "ring":
        return _HopPlan(
            num_stages=ndev, m=1, gi=idx,
            shift=lambda c: compat.ring_shift(c, axes),
            expand=lambda c: c[None, :])
    Dl = ndev // hosts
    groups = [[h * Dl + l for l in range(Dl)] for h in range(hosts)]
    perm = [(h * Dl + l, ((h + 1) % hosts) * Dl + l)
            for h in range(hosts) for l in range(Dl)]
    return _HopPlan(
        num_stages=hosts, m=Dl, gi=idx // Dl,
        shift=lambda c: compat.ppermute(c, axes, perm),
        expand=lambda c: compat.all_gather_groups(c, axes, groups))


def _merge_hops(side, plan: _HopPlan, consume):
    """The transport-generic hop engine every packed transport shares
    (DESIGN.md §16): ``plan.num_stages`` iterations, each launching the
    NEXT stage's ``shift`` (the wire permutation) before expanding the
    current in-flight message with the ``expand`` group collective into
    the (m, L) block that ARRIVED this stage and handing it to
    ``consume`` (the overlapped eq. 7 work) — XLA's
    collective-permute-start/done pair brackets the stage's compute, so
    the wire time hides behind it. ``allgather`` is the degenerate
    num_stages=1, m=ndev parameterization of the same loop; the
    baseline transport realizes it per-leaf in exact dtype instead
    (see :func:`make_sharded_round`).

    Stage t carries origin group ``(gi - t) mod num_stages``, so the
    REVERSED arrival list is origin groups gi+1, gi+2, … (contiguous
    mod the group count) and ONE roll of ``(gi + 1)`` group blocks is
    the origin-device-order layout — a per-stage dynamic-update-slice
    chain would rewrite the whole buffer every hop, costing
    num_stages× the assembly traffic.

    Returns ``(M, ordered)``: the (ndev, L) device-order message
    matrix and the per-stage ``consume`` outputs concatenated into
    device order along their leading (m,) axis.
    """
    L = side.shape[0]
    msgs, parts = [], []
    cur = side
    for t in range(plan.num_stages):
        # faults.garble_wire is the trace-time chaos seam: a no-op
        # (bit-identical program) unless a ring_garble plan is armed
        # while this round is being BUILT.
        nxt = (faults.garble_wire(plan.shift(cur), hop=t)
               if t < plan.num_stages - 1 else None)
        blk = plan.expand(cur)                 # (m, L) arrived messages
        msgs.append(blk.reshape(plan.m * L))
        parts.append(consume(blk))             # eq. 7 stage
        cur = nxt
    ndev = plan.num_stages * plan.m
    M = jnp.roll(jnp.concatenate(msgs[::-1]),
                 (plan.gi + 1) * (plan.m * L)).reshape(ndev, L)
    ordered = jnp.roll(jnp.concatenate(parts[::-1], axis=0),
                       (plan.gi + 1) * plan.m, axis=0)
    return M, ordered


def _packed_merge(cand: SVBuffer, w, b, Xl, cfg: MRSVMConfig, axes,
                  ndev: int, k: int, hosts: int = 1):
    """Packed-wire merge + eq. 7 scoring (DESIGN.md §10, §16) — the
    ring and hier transports over the shared hop engine.

    The monolithic all_gather is split into hop-engine stages (ring:
    ``ndev`` single-message stages; hier: ``hosts`` host-stages of
    ``ndev // hosts`` messages): at each stage a device consumes the
    arrived origin chunks — writing them into the assembling buffer and
    scoring those origins' hypotheses on the local rows — while the
    permutation carrying the next stage's payload is already in flight.
    Feature rows travel in ``cfg.shuffle_wire_dtype`` (bf16 halves the
    dominant payload, matching the bf16-feature convention of
    :mod:`repro.core.svm`); α/ids/y/mask and the (w, b) hypotheses stay
    a full-precision sideband — solver state is never quantized.

    Every device applies the identical wire round-trip to every chunk
    (including its own), so the assembled buffer is bit-identical and
    replicated across devices, exactly like the all_gather's output.
    The buffer's feature rows STAY in the wire dtype — candidates are
    re-gathered from the local f32/bf16 rows every round, so rounding
    never compounds, and the next round's augment reads ½ the bytes.
    """
    per, d = Xl.shape
    wire_dt = jnp.dtype(cfg.shuffle_wire_dtype)
    f32 = jnp.float32
    nnzc = cand.x.nnz_cap if sparse_rows.is_sparse(cand.x) else None
    idx = compat.axis_index(axes)
    plan = _hop_plan(cfg, axes, ndev, idx, hosts)

    # ONE coalesced f32 message per hop: the wire-dtype feature rows
    # (bf16 pairs bitcast into f32 lanes) followed by the packed
    # sideband [y | α | mask | ids | w | b]. Per-leaf permutes would
    # pay the collective's fixed launch/rendezvous cost 7× per stage.
    # ids/int values are exact in f32 below 2^24 rows.
    xf, wslots = pack_wire_rows(cand.x, wire_dt)
    side = jnp.concatenate([
        xf, cand.y.astype(f32), cand.alpha.astype(f32),
        cand.mask.astype(f32), cand.ids.astype(f32),
        w.astype(f32), b.reshape(1).astype(f32)])
    o_x = k * wslots
    o_w = o_x + 4 * k
    if cfg.shuffle_wire_check:
        # Integrity lane (DESIGN.md §15): the int32 wrap-sum of the
        # bitcast message rides as one trailing f32 lane. Every slice
        # below addresses the message by offset from the front, so the
        # lane is invisible to assembly; the receiver re-sums each
        # arrived chunk after the roll.
        csum = jnp.sum(jax.lax.bitcast_convert_type(side, jnp.int32))
        side = jnp.concatenate(
            [side, jax.lax.bitcast_convert_type(csum.reshape(1), f32)])
    L = side.shape[0]

    def consume(blk):                  # (m, L) arrived → (m, per) scores
        Wt = blk[:, o_w:o_w + d]
        Bt = blk[:, o_w + d]
        return (Xl @ Wt.T + Bt[None, :]).astype(w.dtype).T

    M, ordered = _merge_hops(side, plan, consume)
    col = lambda a, b2: M[:, o_x + a * k:o_x + b2 * k].reshape(ndev * k)
    bt_ = Xl.dtype
    sv_acc = SVBuffer(
        x=unpack_wire_rows(M[:, :o_x], ndev * k, d, wire_dt, wslots,
                           nnz_cap=nnzc),
        y=col(0, 1).astype(bt_),
        alpha=col(1, 2).astype(bt_),
        ids=col(3, 4).astype(jnp.int32),
        mask=col(2, 3).astype(bt_))
    W = M[:, o_w:o_w + d]                            # (ndev, d)
    B = M[:, o_w + d]                                # (ndev,)
    scores = ordered.T                               # (per, ndev)
    if cfg.shuffle_wire_check:
        got = jax.lax.bitcast_convert_type(M[:, L - 1], jnp.int32)
        want = jnp.sum(
            jax.lax.bitcast_convert_type(M[:, :L - 1], jnp.int32), axis=1)
        wire_ok = jnp.all(got == want)
    else:
        wire_ok = None
    return sv_acc, W, B, scores, wire_ok


def make_sharded_round(cfg: MRSVMConfig, axis_names: Sequence[str],
                       num_devices: int, rows_per_device: int):
    """Build the per-device body of one MapReduce round for `shard_map`.

    The returned function runs on ONE device's shard:
      Xl (per, d), yl (per,), ml (per,), sv (replicated SVBuffer)
    and returns (new_sv, risks (ndev,), best_w (d,), best_b ()).

    The merge collective — the ICI analogue of the Hadoop shuffle — is
    selected by ``cfg.shuffle_impl``:

    * ``"allgather"``: one tiled `all_gather` of the candidate chunks
      over ``axis_names``; hypothesis selection (eq. 7) all-gathers the
      per-device (w, b) and psums partial risks afterwards — reducer-
      side compute waits on the full collective. This is the hop
      engine's degenerate num_stages=1, m=ndev parameterization,
      realized per-leaf in exact dtype (no wire pack) so the baseline
      stays the bit-exact f32 oracle.
    * ``"ring"``: :func:`_packed_merge` — the chunk exchange is
      pipelined into ``num_devices`` `ppermute` stages, double-buffered
      so buffer assembly and the eq. 7 scoring of each arrived
      hypothesis overlap the next stage's wire time, with feature rows
      shipped in ``cfg.shuffle_wire_dtype``.
    * ``"hier"``: :func:`_packed_merge` over the two-level hop plan —
      ``num_hosts`` host-stages (one inter-host slice permutation +
      one intra-host grouped all_gather each), so only
      (hosts−1)·ndev·L values ever cross the network: the information
      floor, vs the flat ring's hosts·(ndev−1)·L (DESIGN.md §16).

    All transports produce the same converged model (the packed
    transports are bit-identical up to the wire-dtype round-trip of
    the feature rows; exactly identical when ``shuffle_wire_dtype``
    matches the data dtype) — enforced by
    ``tests/test_sharded_round.py``.

    The body takes an optional trailing ``params`` (a replicated traced
    :class:`~repro.core.svm.SolverParams`); the sweep subsystem vmaps
    the body over a leading config axis of (sv, params) — see
    :func:`repro.core.sweep.build_sharded_sweep_round`.
    """
    axes = tuple(axis_names)
    cap = cfg.sv_capacity
    if cap % num_devices != 0:
        raise ValueError("sv_capacity must divide the data-parallel size")
    k = cap // num_devices
    per = rows_per_device
    hosts = resolve_topology(cfg, num_devices)

    def round_body(Xl, yl, ml, sv: SVBuffer,
                   params: Optional[SolverParams] = None):
        idx = compat.axis_index(axes)           # flattened device index
        cand, w, b = _round_candidates(Xl, yl, ml, sv, cfg, axes, idx,
                                       k, per, params)
        if cfg.shuffle_impl in PACKED_SHUFFLES:
            new_sv, W, B, scores, wire_ok = _packed_merge(
                cand, w, b, Xl, cfg, axes, num_devices, k, hosts)
        else:
            new_sv = compat.tree_map(
                lambda a: compat.all_gather(a, axes, tiled=True), cand)
            # driver: eq. 7 over all-gathered hypotheses
            W = compat.all_gather(w, axes)                  # (ndev, d)
            B = compat.all_gather(b, axes)                  # (ndev,)
            scores = Xl @ W.T + B[None, :]                  # (per, ndev)
            wire_ok = None
        risks = _device_risks(scores, yl, ml, cfg, axes, num_devices)
        if wire_ok is not None:
            # wire-checksum sentinel: the host driver's eq. 8 readback
            # sees +inf and raises FaultDetected("transport", ...)
            risks = jnp.where(wire_ok, risks,
                              jnp.full_like(risks, jnp.inf))
        l_star = jnp.argmin(risks)
        return new_sv, risks, W[l_star], B[l_star]

    return round_body


def build_sharded_round(mesh, data_axes: Sequence[str], cfg: MRSVMConfig,
                        rows_per_device: int):
    """jit(shard_map(...)) one MapReduce round on ``mesh``.

    ``data_axes`` are the mesh axes the dataset rows are sharded over
    (e.g. ``("data",)`` or ``("pod", "data")``). Returns
    ``f(X, y, mask, sv) -> (sv', risks, w_best, b_best)`` where X is the
    GLOBAL array sharded on its leading axis.

    ``check_vma=False``: every output is replicated by construction
    (all_gather / psum results), which neither JAX 0.8's static vma
    checker nor 0.4.x's ``check_rep`` can always infer through
    while_loop-heavy reducers. :func:`repro.compat.shard_map` maps the
    flag onto whichever kwarg the installed version spells.
    """
    from jax.sharding import PartitionSpec as P

    axes = tuple(data_axes)
    ndev = int(np.prod([mesh.shape[a] for a in axes]))
    body = make_sharded_round(cfg, axes, ndev, rows_per_device)
    row_spec = P(axes if len(axes) > 1 else axes[0])
    fn = compat.shard_map(
        body, mesh=mesh,
        in_specs=(row_spec, row_spec, row_spec,
                  SVBuffer(x=P(), y=P(), alpha=P(), ids=P(), mask=P())),
        out_specs=(SVBuffer(x=P(), y=P(), alpha=P(), ids=P(), mask=P()),
                   P(), P(), P()),
        check_vma=False)
    return jax.jit(fn)
