"""Multi-class wrappers over the binary MapReduce SVM.

The paper builds a 2-class (Olumlu/Olumsuz) and a 3-class
(Olumlu/Olumsuz/Nötr, labels {-1, 0, +1}) model. Binary SVMs extend to
k classes via one-vs-rest (default) or one-vs-one voting.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mapreduce_svm import (MapReduceSVM, MRSVMConfig,
                                      decision_values, fit_mapreduce)


@dataclasses.dataclass
class OneVsRestSVM:
    classes: Tuple[int, ...]
    models: Dict[int, MapReduceSVM]
    cfg: MRSVMConfig

    def decision_matrix(self, X: jax.Array) -> jax.Array:
        cols = [decision_values(self.models[c], X, self.cfg)
                for c in self.classes]
        return jnp.stack(cols, axis=1)                       # (n, k)

    def predict(self, X: jax.Array) -> jax.Array:
        dm = self.decision_matrix(X)
        idx = jnp.argmax(dm, axis=1)
        return jnp.asarray(self.classes)[idx]


def fit_one_vs_rest(X: jax.Array, y: jax.Array, classes: Sequence[int],
                    num_partitions: int, cfg: MRSVMConfig,
                    verbose: bool = False) -> OneVsRestSVM:
    models = {}
    for c in classes:
        yc = jnp.where(y == c, 1.0, -1.0)
        if verbose:
            print(f"[ovr] training class {c} vs rest")
        models[c] = fit_mapreduce(X, yc, num_partitions, cfg, verbose=verbose)
    return OneVsRestSVM(classes=tuple(int(c) for c in classes),
                        models=models, cfg=cfg)


@dataclasses.dataclass
class OneVsOneSVM:
    classes: Tuple[int, ...]
    models: Dict[Tuple[int, int], MapReduceSVM]
    cfg: MRSVMConfig

    def predict(self, X: jax.Array) -> jax.Array:
        k = len(self.classes)
        votes = jnp.zeros((X.shape[0], k))
        for (i, j), model in self.models.items():
            s = decision_values(model, X, self.cfg)
            win_i = (s >= 0).astype(jnp.float32)
            ii = self.classes.index(i)
            jj = self.classes.index(j)
            votes = votes.at[:, ii].add(win_i)
            votes = votes.at[:, jj].add(1.0 - win_i)
        idx = jnp.argmax(votes, axis=1)
        return jnp.asarray(self.classes)[idx]


def fit_one_vs_one(X: jax.Array, y: jax.Array, classes: Sequence[int],
                   num_partitions: int, cfg: MRSVMConfig,
                   verbose: bool = False) -> OneVsOneSVM:
    X_np = np.asarray(X)
    y_np = np.asarray(y)
    models = {}
    for i, j in itertools.combinations(classes, 2):
        sel = np.logical_or(y_np == i, y_np == j)
        Xi = jnp.asarray(X_np[sel])
        yi = jnp.where(jnp.asarray(y_np[sel]) == i, 1.0, -1.0)
        if verbose:
            print(f"[ovo] training {i} vs {j} on {int(sel.sum())} rows")
        models[(int(i), int(j))] = fit_mapreduce(Xi, yi, num_partitions, cfg,
                                                 verbose=verbose)
    return OneVsOneSVM(classes=tuple(int(c) for c in classes),
                       models=models, cfg=cfg)


def confusion_matrix(y_true: jax.Array, y_pred: jax.Array,
                     classes: Sequence[int],
                     normalize: str = "all") -> np.ndarray:
    """Percentage confusion matrix like Tablo 6 / Tablo 8.

    ``normalize="all"`` (default) divides by the global count so the
    whole matrix sums to 100 — the convention the paper's tables use.
    ``normalize="true"`` row-normalizes: each true-class row sums to
    100 (per-class recall breakdown).
    """
    if normalize not in ("all", "true"):
        raise ValueError(f"normalize must be 'all' or 'true', "
                         f"got {normalize!r}")
    yt = np.asarray(y_true)
    yp = np.asarray(y_pred)
    k = len(classes)
    cm = np.zeros((k, k))
    for a, ca in enumerate(classes):
        for b, cb in enumerate(classes):
            cm[a, b] = np.sum((yt == ca) & (yp == cb))
    if normalize == "true":
        row = np.maximum(cm.sum(axis=1, keepdims=True), 1.0)
        return 100.0 * cm / row
    total = cm.sum()
    return 100.0 * cm / max(total, 1.0)   # paper reports global percentages
