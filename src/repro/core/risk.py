"""Empirical risk, losses, and the paper's stopping rule (eq. 6-8)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def hinge_loss(scores: jax.Array, y: jax.Array) -> jax.Array:
    """ℓ(h(x), y) = max(0, 1 - y·f(x)) per example."""
    return jnp.maximum(0.0, 1.0 - y * scores)


def zero_one_loss(scores: jax.Array, y: jax.Array) -> jax.Array:
    """ℓ(h(x), y) = 1[h(x) ≠ y] with the served decision convention.

    ``predict`` / ``predict_sign`` map the boundary score==0 to +1, so
    the loss must too — ``sign(0) = 0`` would count a boundary score as
    an error against BOTH classes, making eq. 6 risk disagree with the
    predictions actually served.
    """
    pred = jnp.where(scores >= 0.0, 1.0, -1.0).astype(scores.dtype)
    return (pred != jnp.sign(y)).astype(scores.dtype)


def empirical_risk(scores: jax.Array, y: jax.Array,
                   mask: Optional[jax.Array] = None,
                   loss: str = "hinge") -> jax.Array:
    """R_emp(h) = (1/n) Σ ℓ(h(x_i), y_i)  (paper eq. 6)."""
    per_ex = hinge_loss(scores, y) if loss == "hinge" else zero_one_loss(scores, y)
    if mask is None:
        return jnp.mean(per_ex)
    m = mask.astype(per_ex.dtype)
    return jnp.sum(per_ex * m) / jnp.maximum(jnp.sum(m), 1.0)


def converged(risk_prev: jax.Array, risk_curr: jax.Array,
              gamma: float) -> jax.Array:
    """|R_emp(h^{t-1}) - R_emp(h^t)| <= γ  (paper eq. 8)."""
    return jnp.abs(risk_prev - risk_curr) <= gamma
