"""Soft-margin binary SVM trained in the dual (paper eq. 1-2).

The paper's reducers each train a full binary soft-margin SVM on their
augmented partition. We implement the reducer's solver as dual
coordinate ascent (Hsieh et al. 2008 style, L1-loss), written entirely
in ``jax.lax`` control flow so it can be jit'ed, vmap'ed over
partitions (the functional MapReduce mode) and shard_map'ed over the
``data`` mesh axis (the distributed mode).

Two execution paths:

* **linear** (``fit_binary_linear``): maintains the primal vector
  ``w = Σ α_i y_i x_i`` directly — O(n·d) per epoch, no Gram matrix.
  This is the production path for TF×IDF text features.
* **kernel** (``fit_binary_kernel``): precomputes the Gram matrix
  (optionally via the Pallas kernel in :mod:`repro.kernels.gram`) and
  runs Gram-based dual CD — O(n²) per epoch.

The bias is handled LIBLINEAR-style by augmenting with a constant
feature (regularized bias): ``K ← K + 1`` / ``Q_ii ← Q_ii + 1`` and
``b = Σ α_i y_i``. Padded rows are masked: their updates are multiplied
by 0 so α stays exactly 0.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro import compat
from repro import sparse as sparse_rows
from repro.core.kernel_fns import KernelConfig, apply_kernel


def _pvary(tree, axes):
    """Mark a pytree as varying over shard_map manual axes (vma).

    No-op when ``axes`` is empty, outside shard_map, or on a JAX with
    no vma types at all. Needed because our while_loop carries start
    from constants, which JAX 0.8 types as axis-invariant, while the
    loop body outputs are device-varying. The pcast→pvary→identity
    resolution lives in :mod:`repro.compat`.
    """
    return compat.pvary(tree, axes)


class SolverParams(NamedTuple):
    """Traced (vmappable) solver hyper-parameters.

    The static/traced split (DESIGN.md §8): anything that changes the
    *program* — shapes, loop bounds, kernel family, execution path —
    stays in the frozen :class:`SVMConfig` shell; anything that only
    changes *values* lives here as a jnp scalar, so a batch of S
    configs is just a ``SolverParams`` with a leading (S,) axis fed
    through ``vmap`` (the sweep subsystem in :mod:`repro.core.sweep`).
    ``KernelConfig.degree`` stays static: a traced integer exponent
    would lower to a float ``pow`` whose negative-base branch NaNs.

    ``max_epochs`` is the traced *cutoff*: the dual-CD while_loop stops
    at ``min(cfg.max_epochs, params.max_epochs)``. The static shell
    keeps the program's loop bound; the traced value lets a sweep give
    each config its own epoch budget — and lets the sweep driver freeze
    a converged config at a cutoff of 0 (zero epochs) instead of
    spinning it to the shared bound. Kept float32 so the pytree stays
    leaf-uniform under ``stack_params``/``sweep_grid``.
    """
    C: jax.Array             # () box constraint (eq. 2)
    tol: jax.Array           # () max projected-gradient violation to stop
    sv_threshold: jax.Array  # () α above this counts as a support vector
    gamma: jax.Array         # () rbf / poly scale
    coef0: jax.Array         # () poly offset
    max_epochs: jax.Array    # () traced epoch cutoff ≤ the static bound


@dataclasses.dataclass(frozen=True)
class SVMConfig:
    """Reducer-level solver configuration (paper eq. 2 hyper-params).

    Static shell: fields here are compile-time constants. The float
    hyper-parameters double as *defaults* for :meth:`params`, which
    lifts them into a traced :class:`SolverParams` pytree.
    """
    C: float = 1.0
    max_epochs: int = 30
    tol: float = 1e-3            # max projected-gradient violation to stop
    kernel: KernelConfig = KernelConfig()
    sv_threshold: float = 1e-6   # α above this counts as a support vector
    use_gram: bool = False       # force the Gram path even for linear
    gram_impl: str = "xla"       # 'xla' | 'pallas' | 'pallas_sparse'
    row_format: str = "dense"    # 'dense' | 'sparse_csr' (blocked CSR/ELL)
    nnz_cap: int = 0             # slots per sparse row; required if sparse

    def __post_init__(self):
        if self.row_format not in ("dense", "sparse_csr"):
            raise ValueError(
                f"row_format must be 'dense' or 'sparse_csr', "
                f"got {self.row_format!r}")
        if self.gram_impl not in ("xla", "pallas", "pallas_sparse"):
            raise ValueError(
                f"gram_impl must be 'xla' | 'pallas' | 'pallas_sparse', "
                f"got {self.gram_impl!r}")
        if self.row_format == "sparse_csr" and self.nnz_cap < 1:
            raise ValueError(
                "row_format='sparse_csr' requires nnz_cap >= 1 (the "
                "static slot count of the blocked-CSR rows)")
        if self.gram_impl == "pallas_sparse" and self.row_format != \
                "sparse_csr":
            raise ValueError(
                "gram_impl='pallas_sparse' requires row_format="
                "'sparse_csr' (it consumes index/value blocks)")
        if self.gram_impl == "pallas" and self.row_format == "sparse_csr":
            raise ValueError(
                "the dense Pallas Gram kernel cannot consume sparse_csr "
                "rows; use gram_impl='pallas_sparse' or 'xla'")

    def params(self, dtype=jnp.float32) -> SolverParams:
        """Lift the value-like hyper-params into a traced pytree."""
        return SolverParams(
            C=jnp.asarray(self.C, dtype),
            tol=jnp.asarray(self.tol, dtype),
            sv_threshold=jnp.asarray(self.sv_threshold, dtype),
            gamma=jnp.asarray(self.kernel.gamma, dtype),
            coef0=jnp.asarray(self.kernel.coef0, dtype),
            max_epochs=jnp.asarray(float(self.max_epochs), dtype),
        )


class BinarySVM(NamedTuple):
    """Trained reducer output: dual coefs + primal view when linear."""
    alpha: jax.Array          # (n,) dual variables in [0, C]
    b: jax.Array              # () bias (regularized-bias convention)
    w: jax.Array              # (d,) primal weights; zeros on the kernel path
    epochs_run: jax.Array     # () actual epochs before tol hit
    max_violation: jax.Array  # () final max projected-gradient violation


def support_mask(alpha: jax.Array, threshold: float = 1e-6) -> jax.Array:
    """Boolean mask of support vectors (α > 0 up to threshold)."""
    return alpha > threshold


# ---------------------------------------------------------------------------
# Linear path: dual CD maintaining w directly.
# ---------------------------------------------------------------------------

def fit_binary_linear(X: jax.Array, y: jax.Array,
                      mask: Optional[jax.Array],
                      cfg: SVMConfig,
                      params: Optional[SolverParams] = None,
                      vma_axes: tuple = ()) -> BinarySVM:
    n, d = X.shape
    is_sp = sparse_rows.is_sparse(X)
    p = cfg.params() if params is None else params
    # Feature rows may be bf16 (halves the dominant HBM stream, §Perf
    # iteration 5); the solver state (w, α, b) stays f32.
    ct = jnp.promote_types(X.dtype, jnp.float32)
    y = y.astype(ct)
    m = jnp.ones((n,), ct) if mask is None else mask.astype(ct)

    # Q_ii = ||x_i||^2 + 1 (bias augmentation). Masked rows get 1 to avoid
    # 0-div. einsum keeps bf16 X un-materialized (no f32 copy of X).
    if is_sp:
        qdiag = sparse_rows.row_sq_norms(X).astype(ct) + 1.0
    else:
        qdiag = jnp.einsum("nd,nd->n", X, X,
                           preferred_element_type=ct) + 1.0
    qdiag = jnp.where(m > 0, qdiag, 1.0)
    C = p.C.astype(ct)
    tol = p.tol.astype(ct)
    # Static bound × traced cutoff (DESIGN.md §8): the program's loop
    # bound stays cfg.max_epochs; a per-config traced budget can only
    # tighten it.
    ecap = jnp.minimum(jnp.asarray(cfg.max_epochs, ct), p.max_epochs.astype(ct))

    def body_i(i, carry):
        alpha, w, b, viol = carry
        if is_sp:
            # sparse row i: gather w at its column ids, scatter-add the
            # update back — O(nnz) per inner step instead of O(d)
            ii = jax.lax.dynamic_index_in_dim(X.indices, i, keepdims=False)
            vv = jax.lax.dynamic_index_in_dim(
                X.values, i, keepdims=False).astype(ct)
            wx = jnp.dot(jnp.take(w, ii), vv)
        else:
            xi = jax.lax.dynamic_index_in_dim(X, i, keepdims=False).astype(ct)
            wx = jnp.dot(w, xi)
        yi = y[i]
        g = yi * (wx + b) - 1.0                        # ∂/∂α_i of dual obj
        a_old = alpha[i]
        # projected gradient for the box [0, C]
        pg = jnp.where(a_old <= 0.0, jnp.minimum(g, 0.0),
                       jnp.where(a_old >= C, jnp.maximum(g, 0.0), g))
        a_new = jnp.clip(a_old - g / qdiag[i], 0.0, C)
        delta = (a_new - a_old) * m[i]
        alpha = alpha.at[i].set(a_old + delta)
        if is_sp:
            w = w.at[ii].add(delta * yi * vv)
        else:
            w = w + delta * yi * xi
        b = b + delta * yi
        viol = jnp.maximum(viol, jnp.abs(pg) * m[i])
        return alpha, w, b, viol

    zero = _pvary(jnp.asarray(0.0, ct), vma_axes)

    def epoch(carry):
        alpha, w, b, _, t = carry
        alpha, w, b, viol = jax.lax.fori_loop(
            0, n, body_i, (alpha, w, b, zero))
        return alpha, w, b, viol, t + 1

    def cond(carry):
        _, _, _, viol, t = carry
        return jnp.logical_and(t < ecap,
                               jnp.logical_or(t == 0, viol > tol))

    init = _pvary((jnp.zeros((n,), ct), jnp.zeros((d,), ct),
                   jnp.asarray(0.0, ct), jnp.asarray(jnp.inf, ct),
                   jnp.asarray(0, jnp.int32)), vma_axes)
    alpha, w, b, viol, t = jax.lax.while_loop(cond, epoch, init)
    return BinarySVM(alpha=alpha, b=b, w=w, epochs_run=t, max_violation=viol)


# ---------------------------------------------------------------------------
# Kernel path: Gram-based dual CD.
# ---------------------------------------------------------------------------

GramFn = Callable[[jax.Array, jax.Array], jax.Array]


def _pallas_gram_fn(cfg: SVMConfig, p: SolverParams) -> GramFn:
    """Route the reducer's Gram build through the Pallas TPU kernel
    (:mod:`repro.kernels.gram`). ``gamma``/``coef0`` are *traced* scalar
    operands of the kernel (SMEM-style scalar inputs), so rbf/poly
    sweeps over :class:`SolverParams` run on the Pallas path — and every
    config shares ONE compiled kernel instead of re-specializing per
    value. Only the operator choice (``kernel.name``/``degree``) stays
    baked in at trace time."""
    from repro.kernels import gram as gram_lib
    kc = cfg.kernel
    build = (gram_lib.sparse_gram if cfg.gram_impl == "pallas_sparse"
             else gram_lib.gram)

    def fn(X, Z):
        K = build(X, Z, p.gamma, p.coef0, kind=kc.name, degree=kc.degree)
        return K.astype(X.dtype)
    return fn


def fit_binary_kernel(X: jax.Array, y: jax.Array,
                      mask: Optional[jax.Array],
                      cfg: SVMConfig,
                      gram_fn: Optional[GramFn] = None,
                      params: Optional[SolverParams] = None,
                      vma_axes: tuple = ()) -> BinarySVM:
    n, d = X.shape
    p = cfg.params() if params is None else params
    y = y.astype(X.dtype)
    m = jnp.ones((n,), X.dtype) if mask is None else mask.astype(X.dtype)

    if gram_fn is None and cfg.gram_impl in ("pallas", "pallas_sparse"):
        gram_fn = _pallas_gram_fn(cfg, p)
    if gram_fn is None:
        K = apply_kernel(X, X, cfg=cfg.kernel, gamma=p.gamma, coef0=p.coef0)
    else:
        K = gram_fn(X, X)
    K = K + 1.0                                   # regularized bias augment
    Q = (y[:, None] * y[None, :]) * K
    # Mask padded rows/cols out of Q so their updates are inert.
    Q = Q * (m[:, None] * m[None, :])
    qdiag = jnp.where(m > 0, jnp.diagonal(Q), 1.0)
    C = p.C.astype(X.dtype)
    tol = p.tol.astype(X.dtype)
    ecap = jnp.minimum(jnp.asarray(cfg.max_epochs, jnp.float32),
                       p.max_epochs.astype(jnp.float32))

    def body_i(i, carry):
        alpha, g, viol = carry
        gi = g[i]
        a_old = alpha[i]
        pg = jnp.where(a_old <= 0.0, jnp.minimum(gi, 0.0),
                       jnp.where(a_old >= C, jnp.maximum(gi, 0.0), gi))
        a_new = jnp.clip(a_old - gi / qdiag[i], 0.0, C)
        delta = (a_new - a_old) * m[i]
        alpha = alpha.at[i].set(a_old + delta)
        g = g + delta * Q[:, i]                   # rank-1 gradient refresh
        viol = jnp.maximum(viol, jnp.abs(pg) * m[i])
        return alpha, g, viol

    zero = _pvary(jnp.asarray(0.0, X.dtype), vma_axes)

    def epoch(carry):
        alpha, g, _, t = carry
        alpha, g, viol = jax.lax.fori_loop(
            0, n, body_i, (alpha, g, zero))
        return alpha, g, viol, t + 1

    def cond(carry):
        _, _, viol, t = carry
        return jnp.logical_and(t < ecap,
                               jnp.logical_or(t == 0, viol > tol))

    init = _pvary((jnp.zeros((n,), X.dtype), -jnp.ones((n,), X.dtype) * m,
                   jnp.asarray(jnp.inf, X.dtype), jnp.asarray(0, jnp.int32)),
                  vma_axes)
    alpha, g, viol, t = jax.lax.while_loop(cond, epoch, init)

    coef = alpha * y * m
    w = (sparse_rows.weighted_row_sum(X, coef).astype(X.dtype)
         if cfg.kernel.name == "linear" else jnp.zeros((d,), X.dtype))
    b = jnp.sum(coef)                             # bias-augment convention
    return BinarySVM(alpha=alpha, b=b, w=w, epochs_run=t, max_violation=viol)


def fit_binary(X: jax.Array, y: jax.Array, mask: Optional[jax.Array] = None,
               cfg: SVMConfig = SVMConfig(),
               gram_fn: Optional[GramFn] = None,
               params: Optional[SolverParams] = None,
               vma_axes: tuple = ()) -> BinarySVM:
    """Train one reducer's soft-margin binary SVM. y ∈ {-1, +1}.

    ``params`` overrides the value-like hyper-params of ``cfg`` with a
    traced :class:`SolverParams` pytree (vmappable for sweeps); when
    ``None`` the static defaults of ``cfg`` are lifted.
    """
    if cfg.kernel.name == "linear" and not cfg.use_gram:
        return fit_binary_linear(X, y, mask, cfg, params=params,
                                 vma_axes=vma_axes)
    return fit_binary_kernel(X, y, mask, cfg, gram_fn=gram_fn, params=params,
                             vma_axes=vma_axes)


# ---------------------------------------------------------------------------
# Decision functions.
# ---------------------------------------------------------------------------

def decision_linear(w: jax.Array, b: jax.Array, X: jax.Array) -> jax.Array:
    return X @ w + b


def decision_kernel(sv_x: jax.Array, sv_coef: jax.Array, b: jax.Array,
                    X: jax.Array, kcfg: KernelConfig,
                    gamma: Optional[jax.Array] = None,
                    coef0: Optional[jax.Array] = None) -> jax.Array:
    """f(x) = Σ_i coef_i K(x, sv_i) + b, coef = α·y (masked).

    ``gamma``/``coef0`` override the static kernel params with traced
    values (must match the values the model was trained with).
    """
    K = apply_kernel(X, sv_x, cfg=kcfg, gamma=gamma, coef0=coef0)
    return K @ sv_coef + b


def predict_sign(scores: jax.Array) -> jax.Array:
    """±1 labels; ties (score==0) resolve to +1 like the paper's tables."""
    return jnp.where(scores >= 0.0, 1.0, -1.0)
