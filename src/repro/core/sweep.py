"""Batched multi-config hyper-parameter sweeps (vmap-over-configs).

The paper selects between polarization models by training many SVM
variants and comparing confusion matrices (Tablo 6/8); its whole point
is amortizing training cost across a cluster. The same argument applies
across *jobs*: S (C, kernel-scale) configurations are embarrassingly
parallel, so instead of S sequential ``fit_mapreduce`` calls — S traces,
S compiles, S device round-trips per round — we lift the value-like
hyper-parameters into the traced :class:`~repro.core.svm.SolverParams`
pytree and run every config under one outer ``vmap``: one jit, one
device pass, S models (He et al. 2019 make the batched-solver-instances
case for modern hardware).

Per-config convergence (eq. 8) is masked, not synchronized:

* driver level — a host-side ``done`` mask freezes a finished config's
  SV buffer and best hypothesis, and the round loop exits when every
  config has converged;
* solver level — a finished config's ``tol`` is rewritten to ``+inf``
  (it is traced, so this costs nothing), which makes its dual-CD
  ``while_loop`` predicate go false after a single epoch; under
  ``vmap`` the while_loop batching rule then select-freezes that lane
  while unconverged configs keep iterating. Finished configs stop
  contributing work.

One-vs-rest multiclass folds into the same batch axis: k classes × S
configs are k·S independent binary jobs (:func:`fit_one_vs_rest_sweep`).

Two execution modes mirror :mod:`repro.core.mapreduce_svm`:

* **functional** (:func:`fit_mapreduce_sweep`) — configs on a leading
  ``vmap`` axis over :func:`mapreduce_round`;
* **sharded** (:func:`build_sharded_sweep_round`) — the same ``vmap``
  *inside* the ``shard_map`` round body, so each device solves S local
  subproblems per round and the all-gather shuffle moves S buffers in
  one collective.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.core.mapreduce_svm import (MRSVMConfig, SVBuffer, init_sv_buffer,
                                      make_sharded_round, mapreduce_round)
from repro.core.svm import (BinarySVM, SolverParams, SVMConfig,
                            decision_kernel, fit_binary)


class SweepResult(NamedTuple):
    """Converged state of every config in the sweep (leading axis S)."""
    params: SolverParams   # (S,)-batched hyper-parameters
    risks: jax.Array       # (S,) best R_emp per config over its rounds
    ws: jax.Array          # (S, d) best linear hypothesis per config
    bs: jax.Array          # (S,)
    sv: SVBuffer           # (S, cap, …) converged SV_global per config
    final: BinarySVM       # (S, …) models retrained on SV_global alone
    rounds: np.ndarray     # (S,) rounds each config ran before eq. 8
    history: Tuple[dict, ...]

    @property
    def num_configs(self) -> int:
        return int(self.risks.shape[0])

    @property
    def best(self) -> int:
        """Index of the sweep-selected config (min empirical risk)."""
        return int(np.argmin(np.asarray(self.risks)))


# ---------------------------------------------------------------------------
# Building batched SolverParams.
# ---------------------------------------------------------------------------

def stack_params(params_list: Sequence[SolverParams]) -> SolverParams:
    """Stack per-config params into one (S,)-batched pytree."""
    if not params_list:
        raise ValueError("empty sweep")
    return compat.tree_map(lambda *xs: jnp.stack(xs), *params_list)


def sweep_grid(cfg: SVMConfig,
               C: Optional[Sequence[float]] = None,
               gamma: Optional[Sequence[float]] = None,
               tol: Optional[Sequence[float]] = None,
               sv_threshold: Optional[Sequence[float]] = None,
               coef0: Optional[Sequence[float]] = None) -> SolverParams:
    """Cartesian grid over the traced hyper-params, defaults from ``cfg``.

    Returns a (S,)-batched :class:`SolverParams` with
    S = Π len(axis). Axis order is C-major, matching
    ``itertools.product(C, gamma, tol, sv_threshold, coef0)``.
    """
    base = cfg.params()
    axes = [np.atleast_1d(np.asarray(v, np.float32)) if v is not None
            else np.asarray([float(dflt)], np.float32)
            for v, dflt in ((C, base.C), (gamma, base.gamma),
                            (tol, base.tol),
                            (sv_threshold, base.sv_threshold),
                            (coef0, base.coef0))]
    grid = np.meshgrid(*axes, indexing="ij")
    flat = [jnp.asarray(g.reshape(-1)) for g in grid]
    c, g, t, s, c0 = flat
    return SolverParams(C=c, tol=t, sv_threshold=s, gamma=g, coef0=c0)


def _num_configs(params: SolverParams) -> int:
    S = params.C.shape[0]
    for leaf in params:
        if leaf.ndim != 1 or leaf.shape[0] != S:
            raise ValueError("sweep params must share one leading (S,) axis; "
                             f"got shapes {[l.shape for l in params]}")
    return int(S)


def _freeze(done: np.ndarray, old, new):
    """Per-config select: keep ``old`` state where ``done`` (leading S)."""
    d = jnp.asarray(done)
    sel = lambda o, n: jnp.where(d.reshape((-1,) + (1,) * (n.ndim - 1)), o, n)
    return compat.tree_map(sel, old, new)


def _run_rounds(step, svb: SVBuffer, d: int, cfg: MRSVMConfig,
                params: SolverParams, verbose: bool, tag: str):
    """Shared eq. 8-masked host round loop of both sweep modes.

    ``step(svb, eff_params) -> (sv_new, r_star (S,), ws (S, d), bs (S,))``
    where r_star/ws/bs are already reduced to each config's best
    reducer. Finished configs get ``tol=+inf`` (their solver
    while_loop exits after one epoch; vmap select-freezes the lane) and
    their SV buffer / best hypothesis frozen on the host; the loop
    exits when every config has converged.
    """
    S = _num_configs(params)
    done = np.zeros(S, bool)
    prev = np.full(S, np.inf)
    best_risk = np.full(S, np.inf)
    best_w = np.zeros((S, d), np.float32)
    best_b = np.zeros(S, np.float32)
    rounds = np.zeros(S, np.int64)
    history = []
    inf = jnp.asarray(np.inf, params.tol.dtype)
    for t in range(cfg.max_rounds):
        eff = params._replace(tol=jnp.where(jnp.asarray(done), inf,
                                            params.tol))
        sv_new, r_star, ws, bs = step(svb, eff)
        svb = _freeze(done, svb, sv_new)
        r_star = np.asarray(r_star)
        act = ~done
        improved = act & (r_star < best_risk)
        if improved.any():
            best_w[improved] = np.asarray(ws)[improved]
            best_b[improved] = np.asarray(bs)[improved]
            best_risk = np.where(improved, r_star, best_risk)
        rounds[act] += 1
        history.append({"round": t, "risks": np.where(act, r_star, np.nan),
                        "active": int(act.sum())})
        if verbose:
            print(f"[{tag}] round={t} active={int(act.sum())}/{S} "
                  f"best_R_emp={np.nanmin(np.where(act, r_star, np.nan)):.5f}")
        done |= act & (t > 0) & (np.abs(prev - r_star) <= cfg.gamma)  # eq. 8
        prev = np.where(act, r_star, prev)
        if done.all():
            break
    return svb, best_risk, best_w, best_b, rounds, tuple(history)


# ---------------------------------------------------------------------------
# Functional sweep driver.
# ---------------------------------------------------------------------------

# Module-level jits keyed on the frozen cfg (+ which inputs carry the
# (S,) job axis): repeated sweep calls with the same shapes hit the jit
# cache — the streaming service folds a wave per admission, and a
# per-call ``jax.jit`` would retrace every wave (see the twin note in
# repro.core.mapreduce_svm).
@functools.partial(jax.jit, static_argnames=("cfg", "x_ax", "m_ax"))
def _sweep_round_jit(Xp, ypb, maskp, sv_b, eff, cfg, x_ax, m_ax):
    out = jax.vmap(
        lambda Xq, yp, mp, sv, p: mapreduce_round(
            Xq, yp, mp, sv, cfg, params=p),
        in_axes=(x_ax, 0, m_ax, 0, 0))(Xp, ypb, maskp, sv_b, eff)
    # The per-config best-reducer pick (eq. 7) happens ON DEVICE so the
    # host transfer is (S, d), not the full (S, L, d) hypothesis tensor.
    l_star = jnp.argmin(out.risks, axis=1)               # (S,)
    r_sel = jnp.take_along_axis(out.risks, l_star[:, None], 1)[:, 0]
    w_sel = jnp.take_along_axis(out.ws, l_star[:, None, None], 1)[:, 0]
    b_sel = jnp.take_along_axis(out.bs, l_star[:, None], 1)[:, 0]
    return out.sv, r_sel, w_sel, b_sel


@functools.partial(jax.jit, static_argnames=("cfg",))
def _sweep_final_jit(svb: SVBuffer, params: SolverParams, cfg):
    return jax.vmap(
        lambda sv, p: fit_binary(sv.x, sv.y, sv.mask, cfg.svm, params=p))(
            svb, params)


def fit_mapreduce_sweep(X: jax.Array, y: jax.Array, num_partitions: int,
                        cfg: MRSVMConfig, params: SolverParams,
                        mask: Optional[jax.Array] = None,
                        verbose: bool = False) -> SweepResult:
    """Run S MapReduce-SVM jobs in one batched computation.

    Every data input is either shared or carries a leading (S,) job
    axis: ``X`` is ``(n, d)`` (shared) or ``(S, n, d)`` (per-job rows —
    the multi-tenant streaming fold); ``y`` is ``(n,)`` or ``(S, n)``
    (per-job labels — the one-vs-rest folding); ``mask`` is ``None``,
    ``(n,)`` or ``(S, n)``. Per-config eq. 8 masking freezes converged
    configs (see module docstring); each config's trajectory is
    identical to a sequential ``fit_mapreduce`` call with its
    ``params``/data slice.
    """
    S = _num_configs(params)
    n, d = X.shape[-2], X.shape[-1]
    L = num_partitions
    per = -(-n // L)
    pad = L * per - n
    if X.ndim == 3:
        if X.shape[0] != S:
            raise ValueError(f"per-job X has leading axis {X.shape[0]}, "
                             f"expected S={S}")
        Xp = jnp.pad(X, ((0, 0), (0, pad), (0, 0))).reshape(S, L, per, d)
        x_ax = 0
    else:
        Xp = jnp.pad(X, ((0, pad), (0, 0))).reshape(L, per, d)
        x_ax = None
    yb = jnp.broadcast_to(jnp.atleast_2d(y.astype(Xp.dtype)), (S, n))
    ypb = jnp.pad(yb, ((0, 0), (0, pad))).reshape(S, L, per)
    base_mask = (jnp.ones((n,), Xp.dtype) if mask is None
                 else mask.astype(Xp.dtype))
    if base_mask.ndim == 2:
        maskp = jnp.pad(base_mask, ((0, 0), (0, pad))).reshape(S, L, per)
        m_ax = 0
    else:
        maskp = jnp.pad(base_mask, (0, pad)).reshape(L, per)
        m_ax = None

    sv0 = init_sv_buffer(cfg.sv_capacity, d, Xp.dtype)
    svb = compat.tree_map(
        lambda a: jnp.broadcast_to(a, (S,) + a.shape), sv0)

    def step(sv_b, eff):
        return _sweep_round_jit(Xp, ypb, maskp, sv_b, eff,
                                cfg=cfg, x_ax=x_ax, m_ax=m_ax)

    svb, best_risk, best_w, best_b, rounds, history = _run_rounds(
        step, svb, d, cfg, params, verbose, "sweep")

    # Final consolidated models: retrain each config on its SV_global.
    final = _sweep_final_jit(svb, params, cfg=cfg)
    return SweepResult(params=params, risks=jnp.asarray(best_risk),
                       ws=jnp.asarray(best_w), bs=jnp.asarray(best_b),
                       sv=svb, final=final, rounds=rounds, history=history)


def sweep_decision_values(res: SweepResult, X: jax.Array,
                          cfg: MRSVMConfig) -> jax.Array:
    """(S, n) decision values of every config's final model on ``X``."""
    if cfg.svm.kernel.name == "linear" and not cfg.svm.use_gram:
        return jnp.einsum("nd,sd->sn", X, res.final.w) + res.final.b[:, None]

    def one(sv, alpha, b, p):
        coef = alpha * sv.y * sv.mask
        return decision_kernel(sv.x, coef, b, X, cfg.svm.kernel,
                               gamma=p.gamma, coef0=p.coef0)
    return jax.vmap(one)(res.sv, res.final.alpha, res.final.b, res.params)


def predict_sweep(res: SweepResult, X: jax.Array,
                  cfg: MRSVMConfig) -> jax.Array:
    """(S, n) ±1 predictions of every config's final model."""
    return jnp.where(sweep_decision_values(res, X, cfg) >= 0, 1.0, -1.0)


# ---------------------------------------------------------------------------
# One-vs-rest folded into the batch axis.
# ---------------------------------------------------------------------------

class SweepOneVsRest(NamedTuple):
    """k classes × S configs trained as one k·S-job batch.

    Job ``j`` is (config ``j // k``, class ``classes[j % k]``).
    """
    classes: Tuple[int, ...]
    num_configs: int
    result: SweepResult
    cfg: MRSVMConfig

    def decision_tensor(self, X: jax.Array) -> jax.Array:
        """(S, k, n) one-vs-rest decision values."""
        k = len(self.classes)
        dm = sweep_decision_values(self.result, X, self.cfg)   # (k*S, n)
        return dm.reshape(self.num_configs, k, X.shape[0])

    def predict(self, X: jax.Array) -> jax.Array:
        """(S, n) class labels per config (argmax over the k scores)."""
        idx = jnp.argmax(self.decision_tensor(X), axis=1)
        return jnp.asarray(self.classes)[idx]

    def risks(self) -> np.ndarray:
        """(S,) mean over the k binary jobs' best risks — the sweep's
        per-config model-selection score."""
        k = len(self.classes)
        return np.asarray(self.result.risks).reshape(
            self.num_configs, k).mean(axis=1)

    @property
    def best(self) -> int:
        return int(np.argmin(self.risks()))


def fit_one_vs_rest_sweep(X: jax.Array, y: jax.Array,
                          classes: Sequence[int], num_partitions: int,
                          cfg: MRSVMConfig, params: SolverParams,
                          verbose: bool = False) -> SweepOneVsRest:
    """One-vs-rest multiclass × hyper-param sweep as a single batch."""
    k = len(classes)
    S = _num_configs(params)
    y1 = jnp.stack([jnp.where(y == c, 1.0, -1.0).astype(X.dtype)
                    for c in classes])                       # (k, n)
    y_jobs = jnp.tile(y1, (S, 1))                            # (k*S, n)
    pj = compat.tree_map(lambda a: jnp.repeat(a, k, axis=0), params)
    res = fit_mapreduce_sweep(X, y_jobs, num_partitions, cfg, pj,
                              verbose=verbose)
    return SweepOneVsRest(classes=tuple(int(c) for c in classes),
                          num_configs=S, result=res, cfg=cfg)


# ---------------------------------------------------------------------------
# Sharded sweep: vmap-over-configs inside the shard_map round body.
# ---------------------------------------------------------------------------

def make_sharded_sweep_round(cfg: MRSVMConfig, axis_names: Sequence[str],
                             num_devices: int, rows_per_device: int,
                             per_config_data: bool = False):
    """Per-device body solving S local subproblems per round.

    Wraps :func:`make_sharded_round`'s body in an inner ``vmap`` over
    the leading config axis of ``(sv, params)``; the shuffle becomes S
    all-gathers batched into one collective per buffer leaf. With
    ``per_config_data`` the rows/labels/mask also carry the (S,) job
    axis — S *streams* with distinct data updating in one device pass
    (the multi-tenant streaming wave, :mod:`repro.serving.svm_stream`).
    """
    body = make_sharded_round(cfg, axis_names, num_devices, rows_per_device)

    def sweep_body(Xl, yl, ml, sv_b: SVBuffer, params_b: SolverParams):
        if per_config_data:
            return jax.vmap(body)(Xl, yl, ml, sv_b, params_b)
        return jax.vmap(lambda sv, p: body(Xl, yl, ml, sv, p))(sv_b, params_b)

    return sweep_body


def sharded_sweep_program(mesh, data_axes: Sequence[str],
                          cfg: MRSVMConfig, rows_per_device: int,
                          per_config_data: bool = False):
    """shard_map-wrapped sweep round + its partition-spec contract.

    Single source of the sweep round's sharding: rows sharded over the
    data axes, SV buffers and params replicated with a leading (S,)
    config axis; with ``per_config_data`` the row inputs are
    ``(S, n, …)``, sharded on their SECOND axis. Returns
    ``(fn, in_specs, out_specs)`` — consumed by the jitted driver
    (:func:`build_sharded_sweep_round`) and the dry-run step builders
    (``launch.steps.build_svm_sweep_step`` /
    ``build_svm_serve_step``), so the program the dry-run validates is
    the program actually run.
    """
    from jax.sharding import PartitionSpec as P

    axes = tuple(data_axes)
    ndev = int(np.prod([mesh.shape[a] for a in axes]))
    body = make_sharded_sweep_round(cfg, axes, ndev, rows_per_device,
                                    per_config_data=per_config_data)
    row_spec = P(axes if len(axes) > 1 else axes[0])
    if per_config_data:
        data_spec = P(None, axes if len(axes) > 1 else axes[0])
        in_rows = (data_spec, data_spec, data_spec)
    else:
        in_rows = (row_spec, row_spec, row_spec)
    rep_buf = SVBuffer(x=P(), y=P(), alpha=P(), ids=P(), mask=P())
    rep_par = SolverParams(C=P(), tol=P(), sv_threshold=P(),
                           gamma=P(), coef0=P())
    in_specs = in_rows + (rep_buf, rep_par)
    out_specs = (rep_buf, P(), P(), P())
    fn = compat.shard_map(body, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)
    return fn, in_specs, out_specs


def build_sharded_sweep_round(mesh, data_axes: Sequence[str],
                              cfg: MRSVMConfig, rows_per_device: int,
                              per_config_data: bool = False):
    """jit(shard_map(...)) one batched sweep round on ``mesh``.

    Returns ``f(X, y, mask, sv_b, params_b) -> (sv_b', risks (S, ndev),
    ws (S, d), bs (S,))`` where ``X`` is the GLOBAL array sharded on its
    leading axis (second axis when ``per_config_data``) and
    ``sv_b``/``params_b`` carry the replicated (S,) config axis.
    """
    fn, _, _ = sharded_sweep_program(mesh, data_axes, cfg, rows_per_device,
                                     per_config_data=per_config_data)
    return jax.jit(fn)


class ShardedSweep(NamedTuple):
    """Host-driver output of :func:`run_sharded_sweep`."""
    risks: jax.Array    # (S,) best R_emp per config
    ws: jax.Array       # (S, d)
    bs: jax.Array       # (S,)
    sv: SVBuffer        # (S, cap, …)
    rounds: np.ndarray  # (S,)
    history: Tuple[dict, ...]

    @property
    def best(self) -> int:
        return int(np.argmin(np.asarray(self.risks)))


def run_sharded_sweep(round_fn, X: jax.Array, y: jax.Array,
                      mask: Optional[jax.Array], cfg: MRSVMConfig,
                      params: SolverParams,
                      verbose: bool = False) -> ShardedSweep:
    """Host round loop over :func:`build_sharded_sweep_round` with the
    same per-config eq. 8 masking as :func:`fit_mapreduce_sweep`.
    When ``round_fn`` was built with ``per_config_data``, pass
    ``X (S, n, d)`` / ``y (S, n)`` / ``mask (S, n)``."""
    n, d = X.shape[-2], X.shape[-1]
    S = _num_configs(params)
    if mask is None:
        mask = jnp.ones(((S, n) if X.ndim == 3 else (n,)), X.dtype)
    sv0 = init_sv_buffer(cfg.sv_capacity, d, X.dtype)
    svb = compat.tree_map(lambda a: jnp.broadcast_to(a, (S,) + a.shape), sv0)

    def step(sv_b, eff):
        sv_new, risks, ws, bs = round_fn(X, y, mask, sv_b, eff)
        # (ws, bs) are already the per-config best-reducer picks.
        return sv_new, np.asarray(risks).min(axis=1), ws, bs

    svb, best_risk, best_w, best_b, rounds, history = _run_rounds(
        step, svb, d, cfg, params, verbose, "sharded-sweep")
    return ShardedSweep(risks=jnp.asarray(best_risk), ws=jnp.asarray(best_w),
                        bs=jnp.asarray(best_b), sv=svb, rounds=rounds,
                        history=history)
