"""Batched multi-config hyper-parameter sweeps (vmap-over-configs).

The paper selects between polarization models by training many SVM
variants and comparing confusion matrices (Tablo 6/8); its whole point
is amortizing training cost across a cluster. The same argument applies
across *jobs*: S (C, kernel-scale) configurations are embarrassingly
parallel, so instead of S sequential ``fit_mapreduce`` calls — S traces,
S compiles, S device round-trips per round — we lift the value-like
hyper-parameters into the traced :class:`~repro.core.svm.SolverParams`
pytree and run every config under one outer ``vmap``: one jit, one
device pass, S models (He et al. 2019 make the batched-solver-instances
case for modern hardware).

Per-config convergence (eq. 8) is masked, not synchronized:

* driver level — a host-side ``done`` mask freezes a finished config's
  SV buffer and best hypothesis, and the round loop exits when every
  config has converged;
* solver level — a finished config's ``tol`` is rewritten to ``+inf``
  (it is traced, so this costs nothing), which makes its dual-CD
  ``while_loop`` predicate go false after a single epoch; under
  ``vmap`` the while_loop batching rule then select-freezes that lane
  while unconverged configs keep iterating. Finished configs stop
  contributing work.

One-vs-rest multiclass folds into the same batch axis: k classes × S
configs are k·S independent binary jobs (:func:`fit_one_vs_rest_sweep`).

Two execution modes mirror :mod:`repro.core.mapreduce_svm`:

* **functional** (:func:`fit_mapreduce_sweep`) — configs on a leading
  ``vmap`` axis over :func:`mapreduce_round`;
* **sharded** (:func:`build_sharded_sweep_round`) — the same ``vmap``
  *inside* the ``shard_map`` round body, so each device solves S local
  subproblems per round and the all-gather shuffle moves S buffers in
  one collective.
"""
from __future__ import annotations

import contextlib
import functools
from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat, faults
from repro.analysis.hostsync import allowed_host_sync
from repro.analysis.retrace import no_retrace
from repro import sparse as sparse_rows
from repro.core.mapreduce_svm import (PACKED_SHUFFLES, MRSVMConfig,
                                      SVBuffer, _device_risks, _hop_plan,
                                      _merge_hops, _round_candidates,
                                      init_sv_buffer, make_sharded_round,
                                      mapreduce_round, pack_wire_rows,
                                      resolve_topology, unpack_wire_rows)
from repro.core.svm import (BinarySVM, SolverParams, SVMConfig,
                            decision_kernel, fit_binary)


class SweepResult(NamedTuple):
    """Converged state of every config in the sweep (leading axis S)."""
    params: SolverParams   # (S,)-batched hyper-parameters
    risks: jax.Array       # (S,) best R_emp per config over its rounds
    ws: jax.Array          # (S, d) best linear hypothesis per config
    bs: jax.Array          # (S,)
    sv: SVBuffer           # (S, cap, …) converged SV_global per config
    final: BinarySVM       # (S, …) models retrained on SV_global alone
    rounds: np.ndarray     # (S,) rounds each config ran before eq. 8
    history: Tuple[dict, ...]

    @property
    def num_configs(self) -> int:
        return int(self.risks.shape[0])

    @property
    def best(self) -> int:
        """Index of the sweep-selected config (min empirical risk)."""
        return int(np.argmin(np.asarray(self.risks)))


# ---------------------------------------------------------------------------
# Building batched SolverParams.
# ---------------------------------------------------------------------------

def stack_params(params_list: Sequence[SolverParams]) -> SolverParams:
    """Stack per-config params into one (S,)-batched pytree."""
    if not params_list:
        raise ValueError("empty sweep")
    return compat.tree_map(lambda *xs: jnp.stack(xs), *params_list)


def sweep_grid(cfg: SVMConfig,
               C: Optional[Sequence[float]] = None,
               gamma: Optional[Sequence[float]] = None,
               tol: Optional[Sequence[float]] = None,
               sv_threshold: Optional[Sequence[float]] = None,
               coef0: Optional[Sequence[float]] = None,
               max_epochs: Optional[Sequence[int]] = None) -> SolverParams:
    """Cartesian grid over the traced hyper-params, defaults from ``cfg``.

    Returns a (S,)-batched :class:`SolverParams` with
    S = Π len(axis). Axis order is C-major, matching
    ``itertools.product(C, gamma, tol, sv_threshold, coef0, max_epochs)``.
    ``max_epochs`` entries are traced *cutoffs*: they can only tighten
    the static ``cfg.max_epochs`` loop bound (DESIGN.md §8).
    """
    base = cfg.params()
    axes = [np.atleast_1d(np.asarray(v, np.float32)) if v is not None
            else np.asarray([float(dflt)], np.float32)
            for v, dflt in ((C, base.C), (gamma, base.gamma),
                            (tol, base.tol),
                            (sv_threshold, base.sv_threshold),
                            (coef0, base.coef0),
                            (max_epochs, base.max_epochs))]
    grid = np.meshgrid(*axes, indexing="ij")
    flat = [jnp.asarray(g.reshape(-1)) for g in grid]
    c, g, t, s, c0, me = flat
    return SolverParams(C=c, tol=t, sv_threshold=s, gamma=g, coef0=c0,
                        max_epochs=me)


def _num_configs(params: SolverParams) -> int:
    S = params.C.shape[0]
    for leaf in params:
        if leaf.ndim != 1 or leaf.shape[0] != S:
            raise ValueError("sweep params must share one leading (S,) axis; "
                             f"got shapes {[l.shape for l in params]}")
    return int(S)


def _freeze(done: np.ndarray, old, new):
    """Per-config select: keep ``old`` state where ``done`` (leading S)."""
    d = jnp.asarray(done)
    sel = lambda o, n: jnp.where(d.reshape((-1,) + (1,) * (n.ndim - 1)), o, n)
    return compat.tree_map(sel, old, new)


def _run_rounds(step, svb, d: int, cfg: MRSVMConfig,
                params: SolverParams, verbose: bool, tag: str,
                snapshot=None, fail_on_retrace: bool = False):
    """Shared eq. 8-masked host round loop of both sweep modes.

    ``step(svb, eff_params) -> (sv_new, r_star (S,), ws (S, d), bs (S,))``
    where r_star/ws/bs are already reduced to each config's best
    reducer. Finished configs get ``tol=+inf`` AND an epoch cutoff of 0
    (their solver while_loop runs ZERO epochs; vmap select-freezes the
    lane) and their SV buffer / best hypothesis frozen on the host; the
    loop exits when every config has converged.

    ``snapshot`` handles round states that are NOT per-config buffers
    (the dedup ring's shared-row :class:`DedupChunk`): the raw state
    threads through ``step`` unfrozen — finished configs must be inert
    in the round itself, which the 0-epoch cutoff guarantees (their
    candidates die, so they can neither claim unique slots nor change
    active configs' results) — and ``snapshot(state)`` materializes the
    per-config (S, cap, …) buffer ONLY on rounds where a config
    converges (its frozen view) and on the last active round, keeping
    the expansion off the per-round hot path.

    Invariant hooks (DESIGN.md §14): the per-round device→host
    readbacks (risks, improved hypotheses) are the loop's DESIGNED sync
    points and run under ``allowed_host_sync``, so a caller-armed
    ``no_implicit_host_sync`` guard passes them while catching any
    stray transfer. ``fail_on_retrace`` arms the retrace detector on
    every round past the first: steady-state rounds must hit the jit
    cache (round 0 compiles; a convergence round's ``snapshot``
    expansion is off the hot path by design and stays outside the
    guard).
    """
    S = _num_configs(params)
    done = np.zeros(S, bool)
    prev = np.full(S, np.inf)
    best_risk = np.full(S, np.inf)
    best_w = np.zeros((S, d), np.float32)
    best_b = np.zeros(S, np.float32)
    rounds = np.zeros(S, np.int64)
    history = []
    frozen = None if snapshot is not None else svb
    inf = jnp.asarray(np.inf, params.tol.dtype)
    for t in range(cfg.max_rounds):
        guard = (no_retrace(f"[{tag}] steady-state round {t}")
                 if fail_on_retrace and t >= 1
                 else contextlib.nullcontext())
        with guard:
            dmask = jnp.asarray(done)
            eff = params._replace(
                tol=jnp.where(dmask, inf, params.tol),
                max_epochs=jnp.where(dmask, 0.0, params.max_epochs))
            sv_new, r_star, ws, bs = step(svb, eff)
            if snapshot is None:
                frozen = _freeze(done, frozen, sv_new)
            svb = frozen if snapshot is None else sv_new
            with allowed_host_sync("eq. 8 convergence readback"):
                r_star = np.asarray(r_star)
        act = ~done
        faults.check_finite_risks(r_star, where=f"{tag} round {t}",
                                  mask=act)
        improved = act & (r_star < best_risk)
        if improved.any():
            with allowed_host_sync("improved-hypothesis readback"):
                best_w[improved] = np.asarray(ws)[improved]
                best_b[improved] = np.asarray(bs)[improved]
            best_risk = np.where(improved, r_star, best_risk)
        rounds[act] += 1
        history.append({"round": t, "risks": np.where(act, r_star, np.nan),
                        "active": int(act.sum())})
        if verbose:
            print(f"[{tag}] round={t} active={int(act.sum())}/{S} "
                  f"best_R_emp={np.nanmin(np.where(act, r_star, np.nan)):.5f}")
        newly = act & (t > 0) & (np.abs(prev - r_star) <= cfg.gamma)  # eq. 8
        if snapshot is not None and (newly.any()
                                     or t == cfg.max_rounds - 1):
            exp = snapshot(sv_new)
            frozen = exp if frozen is None else _freeze(done, frozen, exp)
        done |= newly
        prev = np.where(act, r_star, prev)
        if done.all():
            break
    return frozen, best_risk, best_w, best_b, rounds, tuple(history)


# ---------------------------------------------------------------------------
# Functional sweep driver.
# ---------------------------------------------------------------------------

# Module-level jits keyed on the frozen cfg (+ which inputs carry the
# (S,) job axis): repeated sweep calls with the same shapes hit the jit
# cache — the streaming service folds a wave per admission, and a
# per-call ``jax.jit`` would retrace every wave (see the twin note in
# repro.core.mapreduce_svm).
@functools.partial(jax.jit, static_argnames=("cfg", "x_ax", "m_ax"))
def _sweep_round_jit(Xp, ypb, maskp, sv_b, eff, cfg, x_ax, m_ax):
    out = jax.vmap(
        lambda Xq, yp, mp, sv, p: mapreduce_round(
            Xq, yp, mp, sv, cfg, params=p),
        in_axes=(x_ax, 0, m_ax, 0, 0))(Xp, ypb, maskp, sv_b, eff)
    # The per-config best-reducer pick (eq. 7) happens ON DEVICE so the
    # host transfer is (S, d), not the full (S, L, d) hypothesis tensor.
    l_star = jnp.argmin(out.risks, axis=1)               # (S,)
    r_sel = jnp.take_along_axis(out.risks, l_star[:, None], 1)[:, 0]
    w_sel = jnp.take_along_axis(out.ws, l_star[:, None, None], 1)[:, 0]
    b_sel = jnp.take_along_axis(out.bs, l_star[:, None], 1)[:, 0]
    return out.sv, r_sel, w_sel, b_sel


@functools.partial(jax.jit, static_argnames=("cfg",))
def _sweep_final_jit(svb: SVBuffer, params: SolverParams, cfg):
    return jax.vmap(
        lambda sv, p: fit_binary(sv.x, sv.y, sv.mask, cfg.svm, params=p))(
            svb, params)


def fit_mapreduce_sweep(X: jax.Array, y: jax.Array, num_partitions: int,
                        cfg: MRSVMConfig, params: SolverParams,
                        mask: Optional[jax.Array] = None,
                        verbose: bool = False,
                        fail_on_retrace: bool = False) -> SweepResult:
    """Run S MapReduce-SVM jobs in one batched computation.

    Every data input is either shared or carries a leading (S,) job
    axis: ``X`` is ``(n, d)`` (shared) or ``(S, n, d)`` (per-job rows —
    the multi-tenant streaming fold); ``y`` is ``(n,)`` or ``(S, n)``
    (per-job labels — the one-vs-rest folding); ``mask`` is ``None``,
    ``(n,)`` or ``(S, n)``. Per-config eq. 8 masking freezes converged
    configs (see module docstring); each config's trajectory is
    identical to a sequential ``fit_mapreduce`` call with its
    ``params``/data slice.
    """
    S = _num_configs(params)
    n, d = X.shape[-2], X.shape[-1]
    L = num_partitions
    per = -(-n // L)
    pad = L * per - n
    if X.ndim == 3:
        if X.shape[0] != S:
            raise ValueError(f"per-job X has leading axis {X.shape[0]}, "
                             f"expected S={S}")
        Xp = sparse_rows.pad_rows(X, pad).reshape(S, L, per, d)
        x_ax = 0
    else:
        Xp = sparse_rows.pad_rows(X, pad).reshape(L, per, d)
        x_ax = None
    yb = jnp.broadcast_to(jnp.atleast_2d(y.astype(Xp.dtype)), (S, n))
    ypb = jnp.pad(yb, ((0, 0), (0, pad))).reshape(S, L, per)
    base_mask = (jnp.ones((n,), Xp.dtype) if mask is None
                 else mask.astype(Xp.dtype))
    if base_mask.ndim == 2:
        maskp = jnp.pad(base_mask, ((0, 0), (0, pad))).reshape(S, L, per)
        m_ax = 0
    else:
        maskp = jnp.pad(base_mask, (0, pad)).reshape(L, per)
        m_ax = None

    sv0 = init_sv_buffer(
        cfg.sv_capacity, d, Xp.dtype,
        nnz_cap=Xp.nnz_cap if sparse_rows.is_sparse(Xp) else None)
    svb = compat.tree_map(
        lambda a: jnp.broadcast_to(a, (S,) + a.shape), sv0)

    def step(sv_b, eff):
        return _sweep_round_jit(Xp, ypb, maskp, sv_b, eff,
                                cfg=cfg, x_ax=x_ax, m_ax=m_ax)

    svb, best_risk, best_w, best_b, rounds, history = _run_rounds(
        step, svb, d, cfg, params, verbose, "sweep",
        fail_on_retrace=fail_on_retrace)

    # Final consolidated models: retrain each config on its SV_global.
    final = _sweep_final_jit(svb, params, cfg=cfg)
    return SweepResult(params=params, risks=jnp.asarray(best_risk),
                       ws=jnp.asarray(best_w), bs=jnp.asarray(best_b),
                       sv=svb, final=final, rounds=rounds, history=history)


def sweep_decision_values(res: SweepResult, X: jax.Array,
                          cfg: MRSVMConfig) -> jax.Array:
    """(S, n) decision values of every config's final model on ``X``."""
    if cfg.svm.kernel.name == "linear" and not cfg.svm.use_gram:
        if sparse_rows.is_sparse(X):
            return (X @ res.final.w.T).T + res.final.b[:, None]
        return jnp.einsum("nd,sd->sn", X, res.final.w) + res.final.b[:, None]

    def one(sv, alpha, b, p):
        coef = alpha * sv.y * sv.mask
        return decision_kernel(sv.x, coef, b, X, cfg.svm.kernel,
                               gamma=p.gamma, coef0=p.coef0)
    return jax.vmap(one)(res.sv, res.final.alpha, res.final.b, res.params)


def predict_sweep(res: SweepResult, X: jax.Array,
                  cfg: MRSVMConfig) -> jax.Array:
    """(S, n) ±1 predictions of every config's final model."""
    return jnp.where(sweep_decision_values(res, X, cfg) >= 0, 1.0, -1.0)


# ---------------------------------------------------------------------------
# One-vs-rest folded into the batch axis.
# ---------------------------------------------------------------------------

class SweepOneVsRest(NamedTuple):
    """k classes × S configs trained as one k·S-job batch.

    Job ``j`` is (config ``j // k``, class ``classes[j % k]``).
    """
    classes: Tuple[int, ...]
    num_configs: int
    result: SweepResult
    cfg: MRSVMConfig

    def decision_tensor(self, X: jax.Array) -> jax.Array:
        """(S, k, n) one-vs-rest decision values."""
        k = len(self.classes)
        dm = sweep_decision_values(self.result, X, self.cfg)   # (k*S, n)
        return dm.reshape(self.num_configs, k, X.shape[0])

    def predict(self, X: jax.Array) -> jax.Array:
        """(S, n) class labels per config (argmax over the k scores)."""
        idx = jnp.argmax(self.decision_tensor(X), axis=1)
        return jnp.asarray(self.classes)[idx]

    def risks(self) -> np.ndarray:
        """(S,) mean over the k binary jobs' best risks — the sweep's
        per-config model-selection score."""
        k = len(self.classes)
        return np.asarray(self.result.risks).reshape(
            self.num_configs, k).mean(axis=1)

    @property
    def best(self) -> int:
        return int(np.argmin(self.risks()))


def fit_one_vs_rest_sweep(X: jax.Array, y: jax.Array,
                          classes: Sequence[int], num_partitions: int,
                          cfg: MRSVMConfig, params: SolverParams,
                          verbose: bool = False) -> SweepOneVsRest:
    """One-vs-rest multiclass × hyper-param sweep as a single batch."""
    k = len(classes)
    S = _num_configs(params)
    y1 = jnp.stack([jnp.where(y == c, 1.0, -1.0).astype(X.dtype)
                    for c in classes])                       # (k, n)
    y_jobs = jnp.tile(y1, (S, 1))                            # (k*S, n)
    pj = compat.tree_map(lambda a: jnp.repeat(a, k, axis=0), params)
    res = fit_mapreduce_sweep(X, y_jobs, num_partitions, cfg, pj,
                              verbose=verbose)
    return SweepOneVsRest(classes=tuple(int(c) for c in classes),
                          num_configs=S, result=res, cfg=cfg)


# ---------------------------------------------------------------------------
# Cross-config SV dedup: the ring sweep's wire format (DESIGN.md §10).
# ---------------------------------------------------------------------------

class DedupChunk(NamedTuple):
    """Deduplicated per-device candidate chunk of a sweep round.

    S configs solving the SAME sharded data converge onto overlapping
    support sets — the margin of the data doesn't move much across
    nearby (C, γ). Shipping every config's (k, d) candidate rows
    therefore moves each shared row S times. The dedup layout collapses
    the chunk to its *unique home rows* plus per-config sidebands:

      x (U, d)      unique feature rows (wire dtype), each shipped once
      y (U,)        labels of the unique rows
      ids (U,)      global row ids (-1 on dead slots)
      ptr (S, k)    each config's j-th candidate → its unique slot (-1
                    when dead or evicted)
      alpha (S, k)  per-config α columns (full precision, never shared)
      mask (S, k)   per-config live flags

    Payload: U·d rows instead of S·k·d — the S× row traffic stops
    scaling in duplicated rows. With ``U = min(S·k, per)`` (the
    default) no live row can ever be evicted, so
    :func:`expand_chunk` ∘ :func:`dedup_candidates` is lossless
    (hypothesis-tested in ``tests/test_property.py``); a smaller
    explicit ``dedup_max_unique`` trades eviction of the
    lowest-evidence unique rows for wire bytes, the same
    capacity-bounding the SV buffer itself applies.
    """
    x: jax.Array
    y: jax.Array
    ids: jax.Array
    ptr: jax.Array
    alpha: jax.Array
    mask: jax.Array


def dedup_unique_cap(cfg: MRSVMConfig, num_configs: int, k: int,
                     per: int) -> int:
    """Unique-row slots a device ships per round (see DedupChunk)."""
    if cfg.dedup_max_unique is not None:
        return max(1, min(cfg.dedup_max_unique, num_configs * k, per))
    return min(num_configs * k, per)


def dedup_candidates(cand: SVBuffer, Xl: jax.Array, yl: jax.Array,
                     idx, per: int, unique_cap: int,
                     wire_dtype=jnp.bfloat16) -> DedupChunk:
    """Collapse (S, k) candidate chunks to unique home rows + sidebands.

    ``cand`` leaves carry a leading (S, k) config axis; all its ids
    point into THIS device's home rows ``[idx·per, (idx+1)·per)``, so a
    (per,)-slot scoreboard (max α across configs = eviction priority)
    finds the unique set without sorting. Assumes ``sv_threshold ≥ 0``
    (live candidates have α > 0), which the solver's box constraint
    already guarantees.
    """
    live = cand.mask > 0
    r = jnp.where(live, cand.ids - idx * per, 0)          # local row ids
    score = jnp.zeros((per,), jnp.float32).at[r].max(
        jnp.where(live, cand.alpha.astype(jnp.float32), 0.0))
    U = unique_cap
    top_score, top_r = jax.lax.top_k(score, U)            # evidence-ranked
    live_u = top_score > 0
    slot = jnp.where(live_u, jnp.arange(U, dtype=jnp.int32), -1)
    inv = jnp.full((per,), -1, jnp.int32).at[top_r].set(slot)
    return DedupChunk(
        x=(Xl[top_r] * live_u[:, None].astype(Xl.dtype)).astype(wire_dtype),
        y=yl[top_r] * live_u.astype(yl.dtype),
        ids=jnp.where(live_u, (idx * per + top_r).astype(jnp.int32), -1),
        ptr=jnp.where(live, inv[r], -1),
        alpha=cand.alpha,
        mask=cand.mask)


def expand_chunk(chunk: DedupChunk, buf_dtype=jnp.float32) -> SVBuffer:
    """Inverse of :func:`dedup_candidates`: per-config (S, k) chunks.

    Candidates whose unique row was evicted (``ptr == -1``) come back
    dead; with the lossless default capacity that never happens and the
    round-trip reproduces the undeduplicated chunks exactly (up to the
    wire-dtype round-trip of ``x``).
    """
    safe = jnp.maximum(chunk.ptr, 0)
    valid = jnp.logical_and(chunk.ptr >= 0, chunk.mask > 0)
    vf = valid.astype(buf_dtype)
    return SVBuffer(
        x=chunk.x[safe].astype(buf_dtype) * vf[..., None],
        y=chunk.y[safe].astype(buf_dtype) * vf,
        alpha=chunk.alpha.astype(buf_dtype) * vf,
        ids=jnp.where(valid, chunk.ids[safe], -1),
        mask=vf)


# ---------------------------------------------------------------------------
# Sharded sweep: vmap-over-configs inside the shard_map round body.
# ---------------------------------------------------------------------------

def uses_dedup_state(cfg: MRSVMConfig, per_config_data: bool) -> bool:
    """True when the sharded sweep's SV state IS the dedup wire format.

    Both packed transports (ring and hier) ship and store the shared
    rows once — the dedup layout is a property of the wire format, not
    of the hop schedule. Per-config-data waves (streams with distinct
    rows) keep per-config buffers — their global ids index different
    datasets, so cross-config dedup has no shared rows to collapse.
    """
    return (cfg.shuffle_impl in PACKED_SHUFFLES and cfg.sweep_dedup
            and not per_config_data)


def init_sharded_sweep_sv(cfg: MRSVMConfig, num_configs: int, d: int,
                          num_devices: int, rows_per_device: int,
                          dtype=jnp.float32, per_config_data: bool = False):
    """Empty round-0 SV state of the sharded sweep.

    Allgather rounds carry the (S, cap, …) :class:`SVBuffer`; the dedup
    packed transports (ring/hier) carry the shared-row
    :class:`DedupChunk` state directly — the expanded per-config buffer
    never materializes between rounds (DESIGN.md §10); per-config-data
    packed rounds keep per-config buffers with wire-dtype feature rows.
    """
    cap = cfg.sv_capacity
    nnzc = (cfg.svm.nnz_cap if cfg.svm.row_format == "sparse_csr"
            else None)
    if uses_dedup_state(cfg, per_config_data):
        k = cap // num_devices
        U = dedup_unique_cap(cfg, num_configs, k, rows_per_device)
        R = num_devices * U
        wire_dt = jnp.dtype(cfg.shuffle_wire_dtype)
        if nnzc is None:
            x0 = jnp.zeros((R, d), wire_dt)
        else:
            x0 = sparse_rows.SparseRows(
                jnp.zeros((R, nnzc), jnp.int32),
                jnp.zeros((R, nnzc), wire_dt), d)
        return DedupChunk(
            x=x0,
            y=jnp.zeros((R,), dtype),
            ids=jnp.full((R,), -1, jnp.int32),
            ptr=jnp.full((num_configs, cap), -1, jnp.int32),
            alpha=jnp.zeros((num_configs, cap), dtype),
            mask=jnp.zeros((num_configs, cap), dtype))
    sv0 = init_sv_buffer(cap, d, dtype, nnz_cap=nnzc)
    if cfg.shuffle_impl in PACKED_SHUFFLES:
        sv0 = sv0._replace(
            x=sv0.x.astype(jnp.dtype(cfg.shuffle_wire_dtype)))
    return compat.tree_map(
        lambda a: jnp.broadcast_to(a, (num_configs,) + a.shape), sv0)


def _state_views(state: DedupChunk, buf_dt):
    """Per-config :class:`SVBuffer` views of the shared-row state.

    Only the (S, cap) sidebands are per-config; the (cap, d) feature
    rows of config s are gathered from the shared unique rows — the
    same read volume the expanded buffer would cost, from a buffer
    S× smaller (and in the wire dtype).
    """
    def view(ptr_s, alpha_s, mask_s):
        safe = jnp.maximum(ptr_s, 0)
        valid = jnp.logical_and(ptr_s >= 0, mask_s > 0)
        vf = valid.astype(buf_dt)
        return SVBuffer(
            x=state.x[safe] * vf[:, None].astype(state.x.dtype),
            y=state.y[safe].astype(buf_dt) * vf,
            alpha=alpha_s.astype(buf_dt) * vf,
            ids=jnp.where(valid, state.ids[safe], -1),
            mask=vf)
    return view


def _make_packed_sweep_body(cfg: MRSVMConfig, axes, ndev: int, per: int,
                            per_config_data: bool):
    """Packed-wire sweep round: one transport for all S configs.

    The per-config solve/top-k (vmapped :func:`_round_candidates`) is
    followed by ONE pass of the shared hop engine
    (:func:`repro.core.mapreduce_svm._merge_hops`) over the round's
    wire payload — the stage's permutation is in flight while the
    arrived chunks are written into the assembling state and their S
    hypotheses are scored (eq. 7). The hop schedule is the transport's
    (ring: ndev single-message stages; hier: host-stages of
    ndev//hosts messages, DESIGN.md §16) — the wire format is the
    same. On shared-data sweeps the SV state IS the cross-config dedup
    format (:class:`DedupChunk` with ptr rebased to the global slot
    axis): unique rows are shipped AND stored once, so neither the
    wire nor the replicated round state scales in duplicated rows —
    the (S, cap, d) per-config buffer exists only as transient
    per-config gathers inside the reducer augment. Per-config-data
    waves (streams with distinct rows — ids aren't comparable) keep
    per-config buffers and ship the plain chunk with wire-dtype
    feature rows.
    """
    cap = cfg.sv_capacity
    k = cap // ndev
    wire_dt = jnp.dtype(cfg.shuffle_wire_dtype)
    dedup = uses_dedup_state(cfg, per_config_data)
    hosts = resolve_topology(cfg, ndev)

    def sweep_body(Xl, yl, ml, sv_state, params_b: SolverParams):
        idx = compat.axis_index(axes)
        S = params_b.C.shape[0]
        buf_dt = Xl.dtype
        d = Xl.shape[-1]
        comp = lambda X1, y1, m1, sv, p: _round_candidates(
            X1, y1, m1, sv, cfg, axes, idx, k, per, p)
        if per_config_data:
            cand_b, w_b, b_b = jax.vmap(comp)(Xl, yl, ml, sv_state,
                                              params_b)
        elif dedup:
            view = _state_views(sv_state, buf_dt)
            cand_b, w_b, b_b = jax.vmap(
                lambda pt, al, mk, p: comp(Xl, yl, ml, view(pt, al, mk), p))(
                    sv_state.ptr, sv_state.alpha, sv_state.mask, params_b)
        else:
            cand_b, w_b, b_b = jax.vmap(
                lambda sv, p: comp(Xl, yl, ml, sv, p))(sv_state, params_b)

        # The wire payload stays in chunk format through the hops —
        # each stage's consumption is the eq. 7 scoring of the arrived
        # hypotheses; the state is assembled AFTER the last hop with
        # one roll (a per-stage dynamic-update-slice chain would
        # rewrite the whole state every hop). ONE coalesced f32 message
        # per hop — the bitcast-packed wire rows plus the sidebands and
        # hypotheses — because per-leaf permutes would pay the
        # collective's fixed rendezvous cost 8× per stage.
        f32 = jnp.float32
        nnzc = Xl.nnz_cap if sparse_rows.is_sparse(Xl) else None
        if dedup:
            U = dedup_unique_cap(cfg, S, k, per)
            chunk0 = dedup_candidates(cand_b, Xl, yl, idx, per, U, wire_dt)
            xf, wslots = pack_wire_rows(chunk0.x, wire_dt)
            n_rows = U
            side0 = jnp.concatenate([
                xf, chunk0.y.astype(f32), chunk0.ids.astype(f32),
                chunk0.ptr.astype(f32).reshape(-1),
                chunk0.alpha.astype(f32).reshape(-1),
                chunk0.mask.astype(f32).reshape(-1),
                w_b.astype(f32).reshape(-1), b_b.astype(f32)])
            o_w = U * wslots + 2 * U + 3 * S * k
        else:
            U = k
            xf, wslots = pack_wire_rows(
                cand_b.x.reshape(S * k, d), wire_dt)
            n_rows = S * k
            side0 = jnp.concatenate([
                xf,
                cand_b.y.astype(f32).reshape(-1),
                cand_b.alpha.astype(f32).reshape(-1),
                cand_b.mask.astype(f32).reshape(-1),
                cand_b.ids.astype(f32).reshape(-1),
                w_b.astype(f32).reshape(-1), b_b.astype(f32)])
            o_w = S * k * wslots + 4 * S * k
        o_x = n_rows * wslots
        plan = _hop_plan(cfg, axes, ndev, idx, hosts)
        m = plan.m

        def consume(blk):         # (m, L) arrived → (m, S, per) eq. 7
            wt = blk[:, o_w:o_w + S * d].reshape(m, S, d)
            bt = blk[:, o_w + S * d:].reshape(m, S)
            if per_config_data:
                if nnzc is not None:
                    s = jax.vmap(lambda w1: jax.vmap(
                        lambda xs, w2: xs @ w2)(Xl, w1))(wt) \
                        + bt[:, :, None]
                else:
                    s = jnp.einsum("spd,msd->msp", Xl, wt) \
                        + bt[:, :, None]
            elif nnzc is not None:
                s = (Xl @ wt.reshape(m * S, d).T).T.reshape(m, S, per) \
                    + bt[:, :, None]
            else:
                s = jnp.einsum("pd,msd->msp", Xl, wt) + bt[:, :, None]
            return s.astype(w_b.dtype)

        # Stage t carried origin group (gi-t) → device order is ONE
        # roll of the reversed-arrival concat (see _merge_hops's note).
        M, ordered = _merge_hops(side0, plan, consume)
        xs = unpack_wire_rows(M[:, :o_x], ndev * n_rows, d, wire_dt,
                              wslots, nnz_cap=nnzc)
        if not dedup:
            xs = xs.reshape(ndev, S, k, d).swapaxes(0, 1) \
                   .reshape(S, cap, d)
        acc = _assemble_chunks(xs, M, o_x, dedup, ndev, U, k, S, buf_dt)
        W = jnp.swapaxes(M[:, o_w:o_w + S * d].reshape(ndev, S, d), 0, 1)
        B = M[:, o_w + S * d:].T                     # (S, ndev)
        scores = jnp.transpose(ordered, (1, 2, 0))   # (S, per, ndev)

        if per_config_data:
            risks = jax.vmap(
                lambda sc, y1, m1: _device_risks(
                    sc, y1, m1, cfg, axes, ndev))(scores, yl, ml)
        else:
            risks = jax.vmap(
                lambda sc: _device_risks(
                    sc, yl, ml, cfg, axes, ndev))(scores)
        l_star = jnp.argmin(risks, axis=1)                   # (S,)
        w_sel = jnp.take_along_axis(W, l_star[:, None, None], axis=1)[:, 0]
        b_sel = jnp.take_along_axis(B, l_star[:, None], axis=1)[:, 0]
        return acc, risks, w_sel, b_sel

    return sweep_body


def _assemble_chunks(xs, M, o_x: int, dedup: bool, ndev: int, U: int,
                     k: int, S: int, buf_dt):
    """Device-order state from the ring's reordered messages.

    ``xs`` is the unpacked wire-dtype row buffer already in device
    order — (ndev·U, d) for dedup chunks, (S, ndev·k, d) for plain
    chunks — and ``M`` the (ndev, L) message matrix in device order
    with the packed sidebands starting at column ``o_x``. Dedup chunks:
    the per-config ptr columns are rebased onto the global slot axis
    (block o adds o·U). Plain chunks (per-config-data waves): sideband
    leaves concatenate into the (S, ndev·k) columns.
    """
    cap = ndev * k
    sides = M[:, o_x:]
    if dedup:
        col = lambda a, b: sides[:, a:b]
        ptr = col(2 * U, 2 * U + S * k).reshape(ndev, S, k)
        base = jnp.arange(ndev, dtype=jnp.float32)[:, None, None] * U
        ptr = jnp.where(ptr >= 0, ptr + base, -1.0)
        per_cfg = lambda a: jnp.swapaxes(
            a.reshape(ndev, S, k), 0, 1).reshape(S, cap)
        return DedupChunk(
            x=xs,
            y=col(0, U).reshape(ndev * U).astype(buf_dt),
            ids=col(U, 2 * U).reshape(ndev * U).astype(jnp.int32),
            ptr=jnp.swapaxes(ptr, 0, 1).reshape(S, cap).astype(jnp.int32),
            alpha=per_cfg(col(2 * U + S * k, 2 * U + 2 * S * k)
                          ).astype(buf_dt),
            mask=per_cfg(col(2 * U + 2 * S * k, 2 * U + 3 * S * k)
                         ).astype(buf_dt))
    per_cfg = lambda a: jnp.swapaxes(
        a.reshape(ndev, S, k), 0, 1).reshape(S, cap)
    col = lambda i: sides[:, i * S * k:(i + 1) * S * k]
    return SVBuffer(
        x=xs,
        y=per_cfg(col(0)).astype(buf_dt),
        alpha=per_cfg(col(1)).astype(buf_dt),
        ids=per_cfg(col(3)).astype(jnp.int32),
        mask=per_cfg(col(2)).astype(buf_dt))


def expand_sweep_sv(state, buf_dtype=jnp.float32) -> SVBuffer:
    """Materialize the per-config (S, cap, …) SVBuffer from a round
    state — identity for per-config states, one gather for the dedup
    state (its ``ptr`` is already on the global slot axis). The sharded
    driver calls this only when a config converges (to freeze its
    buffer) and once at the end — never on the per-round hot path."""
    if isinstance(state, DedupChunk):
        return expand_chunk(state, buf_dtype)
    if state.x.dtype != jnp.dtype(buf_dtype):
        return state._replace(x=state.x.astype(buf_dtype))
    return state


def make_sharded_sweep_round(cfg: MRSVMConfig, axis_names: Sequence[str],
                             num_devices: int, rows_per_device: int,
                             per_config_data: bool = False):
    """Per-device body solving S local subproblems per round.

    With ``cfg.shuffle_impl == "allgather"`` this wraps
    :func:`make_sharded_round`'s body in an inner ``vmap`` over the
    leading config axis of ``(sv, params)``; the shuffle becomes S
    all-gathers batched into one collective per buffer leaf. With
    ``"ring"`` or ``"hier"`` the transport is the packed,
    cross-config-deduplicated merge of :func:`_make_packed_sweep_body`
    over that transport's hop schedule. With ``per_config_data`` the
    rows/labels/mask also carry the (S,) job axis — S *streams* with
    distinct data updating in one device pass (the multi-tenant
    streaming wave, :mod:`repro.serving.svm_stream`).
    """
    axes = tuple(axis_names)
    if cfg.shuffle_impl in PACKED_SHUFFLES:
        return _make_packed_sweep_body(cfg, axes, num_devices,
                                       rows_per_device, per_config_data)
    body = make_sharded_round(cfg, axis_names, num_devices, rows_per_device)

    def sweep_body(Xl, yl, ml, sv_b: SVBuffer, params_b: SolverParams):
        if per_config_data:
            return jax.vmap(body)(Xl, yl, ml, sv_b, params_b)
        return jax.vmap(lambda sv, p: body(Xl, yl, ml, sv, p))(sv_b, params_b)

    return sweep_body


def sharded_sweep_program(mesh, data_axes: Sequence[str],
                          cfg: MRSVMConfig, rows_per_device: int,
                          per_config_data: bool = False):
    """shard_map-wrapped sweep round + its partition-spec contract.

    Single source of the sweep round's sharding: rows sharded over the
    data axes, SV buffers and params replicated with a leading (S,)
    config axis; with ``per_config_data`` the row inputs are
    ``(S, n, …)``, sharded on their SECOND axis. Returns
    ``(fn, in_specs, out_specs)`` — consumed by the jitted driver
    (:func:`build_sharded_sweep_round`) and the dry-run step builders
    (``launch.steps.build_svm_sweep_step`` /
    ``build_svm_serve_step``), so the program the dry-run validates is
    the program actually run.
    """
    from jax.sharding import PartitionSpec as P

    axes = tuple(data_axes)
    ndev = int(np.prod([mesh.shape[a] for a in axes]))
    body = make_sharded_sweep_round(cfg, axes, ndev, rows_per_device,
                                    per_config_data=per_config_data)
    row_spec = P(axes if len(axes) > 1 else axes[0])
    if per_config_data:
        data_spec = P(None, axes if len(axes) > 1 else axes[0])
        in_rows = (data_spec, data_spec, data_spec)
    else:
        in_rows = (row_spec, row_spec, row_spec)
    if uses_dedup_state(cfg, per_config_data):
        rep_buf = DedupChunk(*(P() for _ in DedupChunk._fields))
    else:
        rep_buf = SVBuffer(x=P(), y=P(), alpha=P(), ids=P(), mask=P())
    rep_par = SolverParams(*(P() for _ in SolverParams._fields))
    in_specs = in_rows + (rep_buf, rep_par)
    out_specs = (rep_buf, P(), P(), P())
    fn = compat.shard_map(body, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)
    return fn, in_specs, out_specs


def build_sharded_sweep_round(mesh, data_axes: Sequence[str],
                              cfg: MRSVMConfig, rows_per_device: int,
                              per_config_data: bool = False):
    """jit(shard_map(...)) one batched sweep round on ``mesh``.

    Returns ``f(X, y, mask, sv_b, params_b) -> (sv_b', risks (S, ndev),
    ws (S, d), bs (S,))`` where ``X`` is the GLOBAL array sharded on its
    leading axis (second axis when ``per_config_data``) and
    ``sv_b``/``params_b`` carry the replicated (S,) config axis — on
    the dedup ring, ``sv_b`` is the shared-row :class:`DedupChunk`
    state instead.

    The returned callable carries two helpers so drivers don't have to
    know which state layout the transport uses: ``.init_sv(S, d,
    dtype)`` builds the empty round-0 state and ``.expand_sv(state)``
    materializes the per-config (S, cap, …) :class:`SVBuffer` view.
    """
    axes = tuple(data_axes)
    ndev = int(np.prod([mesh.shape[a] for a in axes]))
    fn, _, _ = sharded_sweep_program(mesh, data_axes, cfg, rows_per_device,
                                     per_config_data=per_config_data)
    jf = jax.jit(fn)

    def round_fn(X, y, mask, sv_b, params_b):
        return jf(X, y, mask, sv_b, params_b)

    round_fn.init_sv = lambda S, d, dtype=jnp.float32: init_sharded_sweep_sv(
        cfg, S, d, ndev, rows_per_device, dtype,
        per_config_data=per_config_data)
    round_fn.expand_sv = jax.jit(expand_sweep_sv) \
        if uses_dedup_state(cfg, per_config_data) else None
    return round_fn


class ShardedSweep(NamedTuple):
    """Host-driver output of :func:`run_sharded_sweep`."""
    risks: jax.Array    # (S,) best R_emp per config
    ws: jax.Array       # (S, d)
    bs: jax.Array       # (S,)
    sv: SVBuffer        # (S, cap, …)
    rounds: np.ndarray  # (S,)
    history: Tuple[dict, ...]

    @property
    def best(self) -> int:
        return int(np.argmin(np.asarray(self.risks)))


def run_sharded_sweep(round_fn, X: jax.Array, y: jax.Array,
                      mask: Optional[jax.Array], cfg: MRSVMConfig,
                      params: SolverParams,
                      verbose: bool = False,
                      fail_on_retrace: bool = False) -> ShardedSweep:
    """Host round loop over :func:`build_sharded_sweep_round` with the
    same per-config eq. 8 masking as :func:`fit_mapreduce_sweep`.
    When ``round_fn`` was built with ``per_config_data``, pass
    ``X (S, n, d)`` / ``y (S, n)`` / ``mask (S, n)``.

    On the dedup ring, ``round_fn`` threads the shared-row state and
    the driver snapshots per-config buffers only at convergence (see
    :func:`_run_rounds`); the returned :class:`ShardedSweep` always
    carries the standard (S, cap, …) :class:`SVBuffer`."""
    n, d = X.shape[-2], X.shape[-1]
    S = _num_configs(params)
    if mask is None:
        mask = jnp.ones(((S, n) if X.ndim == 3 else (n,)), X.dtype)
    init = getattr(round_fn, "init_sv", None)
    if init is not None:
        svb = init(S, d, X.dtype)
    else:
        sv0 = init_sv_buffer(cfg.sv_capacity, d, X.dtype)
        svb = compat.tree_map(
            lambda a: jnp.broadcast_to(a, (S,) + a.shape), sv0)
    snapshot = getattr(round_fn, "expand_sv", None)

    def step(sv_b, eff):
        sv_new, risks, ws, bs = round_fn(X, y, mask, sv_b, eff)
        # (ws, bs) are already the per-config best-reducer picks.
        with allowed_host_sync("per-reducer risk readback"):
            risks = np.asarray(risks)
        return sv_new, risks.min(axis=1), ws, bs

    svb, best_risk, best_w, best_b, rounds, history = _run_rounds(
        step, svb, d, cfg, params, verbose, "sharded-sweep",
        snapshot=snapshot, fail_on_retrace=fail_on_retrace)
    return ShardedSweep(risks=jnp.asarray(best_risk), ws=jnp.asarray(best_w),
                        bs=jnp.asarray(best_b), sv=svb, rounds=rounds,
                        history=history)


# ---------------------------------------------------------------------------
# Round-state ser/de (ISSUE 7) — the sweep's fault-tolerance hooks.
# ---------------------------------------------------------------------------

def save_sweep_state(path: str, state, step: Optional[int] = None) -> None:
    """Durably snapshot a sharded-sweep round state.

    ``state`` is whatever the transport threads between rounds — the
    per-config ``(S, cap, …)`` :class:`SVBuffer` on allgather, or the
    shared-row :class:`DedupChunk` on the dedup ring. Both are
    registered pytrees of array leaves, so the flat-npz checkpointer
    (:mod:`repro.ckpt.checkpoint`) takes them as-is; with ``step`` the
    directory's meta pointer advances atomically (crash-safe).
    """
    from repro.ckpt import checkpoint as ckpt
    ckpt.save(path, state, step=step)


def restore_sweep_state(path: str, cfg: MRSVMConfig, num_configs: int,
                        d: int, num_devices: int, rows_per_device: int,
                        dtype=jnp.float32, per_config_data: bool = False):
    """Restore a round state saved by :func:`save_sweep_state`.

    The ``like`` tree is rebuilt by :func:`init_sharded_sweep_sv` from
    the SAME static facts that shaped the original, so shape or dtype
    drift — a different sweep width, capacity, transport layout or wire
    dtype — fails loudly instead of resuming a subtly wrong sweep.
    """
    from repro.ckpt import checkpoint as ckpt
    like = init_sharded_sweep_sv(cfg, num_configs, d, num_devices,
                                 rows_per_device, dtype,
                                 per_config_data=per_config_data)
    return ckpt.restore(path, like)
