from repro.data.pipeline import DataConfig, lm_batch_at, lm_batches, svm_rows
