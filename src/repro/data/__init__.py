from repro.data.pipeline import (DataConfig, default_row_nnz,
                                 host_row_range, lm_batch_at, lm_batches,
                                 svm_rows, svm_rows_shard, svm_rows_sparse)
