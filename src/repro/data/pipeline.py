"""Deterministic synthetic data pipeline.

Two producers:
* token streams for backbone LM training (Zipf-distributed tokens with
  a planted n-gram structure so loss visibly decreases);
* the TF×IDF row stream for the MapReduce SVM (delegates to
  repro.text.corpus + tokenizer at small scale; direct synthetic
  feature rows at dry-run scale).

Batches are host-generated numpy, then device_put with the step's
input sharding by the launcher. Iterators are stateless-seeded
(seed, step) → reproducible and resumable from any checkpoint step.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch_size: int = 8
    seq_len: int = 128
    seed: int = 0


def _tokens_for_step(cfg: DataConfig, vocab: int, step: int,
                     structure: int = 97) -> np.ndarray:
    """Zipfian tokens with a deterministic bigram rule planted:
    after token t comes (t * 31 + 7) % structure with prob ~0.5 —
    learnable signal for smoke-training."""
    rng = np.random.default_rng((cfg.seed, step))
    B, S = cfg.batch_size, cfg.seq_len
    base = rng.zipf(1.3, size=(B, S)).clip(1, vocab - 1)
    follow = (base * 31 + 7) % min(structure, vocab)
    use_follow = rng.random((B, S)) < 0.5
    out = base.copy()
    out[:, 1:] = np.where(use_follow[:, 1:], follow[:, :-1], base[:, 1:])
    return out.astype(np.int32)


def lm_batches(cfg: DataConfig, model_cfg: ModelConfig,
               start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    """Next-token LM batches: {tokens, labels} (+ frontend stubs)."""
    step = start_step
    while True:
        yield lm_batch_at(cfg, model_cfg, step)
        step += 1


def lm_batch_at(cfg: DataConfig, model_cfg: ModelConfig,
                step: int) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng((cfg.seed, step, 1))
    S = cfg.seq_len
    P = model_cfg.num_prefix_tokens if model_cfg.frontend == "vision" else 0
    toks = _tokens_for_step(cfg, model_cfg.vocab_size, step)
    batch: Dict[str, np.ndarray] = {}
    if model_cfg.is_encoder_decoder:
        batch["frames"] = rng.normal(
            0, 1, (cfg.batch_size, model_cfg.encoder_seq,
                   model_cfg.d_model)).astype(np.float32)
        batch["tokens"] = toks[:, :S]
        batch["labels"] = np.concatenate(
            [toks[:, 1:S], toks[:, :1]], axis=1).astype(np.int32)
    elif P > 0:
        batch["prefix_embeds"] = rng.normal(
            0, 1, (cfg.batch_size, P, model_cfg.d_model)).astype(np.float32)
        text = toks[:, :S - P]
        batch["tokens"] = text[:, :-1] if text.shape[1] > 1 else text
        batch["labels"] = text[:, 1:] if text.shape[1] > 1 else text
        # keep tokens/labels same length
        batch["tokens"] = text
        batch["labels"] = np.concatenate(
            [text[:, 1:], text[:, :1]], axis=1).astype(np.int32)
    else:
        batch["tokens"] = toks
        batch["labels"] = np.concatenate(
            [toks[:, 1:], toks[:, :1]], axis=1).astype(np.int32)
    return batch


# ---------------------------------------------------------------------------
# TF×IDF row stream (MapReduce-SVM), multi-host aware (DESIGN.md §11).
#
# Rows are generated in BLOCK-STATELESS chunks: block j draws from
# default_rng((seed, 1, j)) independently of every other block, so
#   * generation is fully vectorized (no per-row Python loop — the old
#     host-side bottleneck at dry-run/bench scale), and
#   * a process can materialize exactly its own row range
#     (svm_rows_shard) while the union over processes is, by
#     construction, the single-host dataset svm_rows would return.
# NB the vectorization changed the raw random stream vs the historical
# per-row rng.choice loop (deliberate — no fixture pins exact values;
# the distribution, normalization and linear signal are unchanged).
# ---------------------------------------------------------------------------

_ROW_BLOCK = 1024     # rows per stateless block (host memory granule)


def _svm_signal(num_features: int, seed: int, signal_dims: int) -> np.ndarray:
    """The planted linear separator — identical on every host."""
    rng = np.random.default_rng((seed, 0))
    signal_dims = min(signal_dims, num_features)
    w = np.zeros(num_features, np.float32)
    idx = rng.choice(num_features, signal_dims, replace=False)
    w[idx] = rng.normal(0, 1, signal_dims)
    return w


def default_row_nnz(num_features: int) -> int:
    """Historical synthetic density: ~d/256 nonzeros, floor 4."""
    return min(num_features, max(4, num_features // 256))


def _svm_row_block(block: int, rows: int, num_features: int,
                   seed: int, nnz: Optional[int] = None) -> np.ndarray:
    """``rows`` normalized sparse-ish rows of stateless block ``block``.

    ``nnz`` sets the nonzeros per row (the sweep knob of the sparse
    benchmarks); ``None`` keeps the historical d/256 density."""
    rng = np.random.default_rng((seed, 1, block))
    nnz = default_row_nnz(num_features) if nnz is None \
        else min(num_features, max(1, int(nnz)))
    # nnz distinct columns per row without a Python loop: the nnz
    # smallest of d iid uniforms are a uniform no-replacement sample
    scores = rng.random((rows, num_features), dtype=np.float32)
    cols = np.argpartition(scores, nnz - 1, axis=1)[:, :nnz]
    X = np.zeros((rows, num_features), np.float32)
    np.put_along_axis(X, cols, rng.random((rows, nnz), dtype=np.float32),
                      axis=1)
    norm = np.linalg.norm(X, axis=1, keepdims=True)
    return X / np.maximum(norm, 1e-9)


def host_row_range(num_rows: int, process_index: int,
                   process_count: int) -> Tuple[int, int]:
    """Balanced contiguous ``[start, stop)`` of one process's rows.

    Ranges are pairwise disjoint and cover ``range(num_rows)`` exactly;
    contiguity matches the process-major device order of
    :func:`repro.launch.mesh.make_cluster_mesh`, so global row id
    ``g`` lives on the host whose range contains ``g``.
    """
    if not 0 <= process_index < process_count:
        raise ValueError(f"process_index {process_index} outside "
                         f"[0, {process_count})")
    return (process_index * num_rows // process_count,
            (process_index + 1) * num_rows // process_count)


def svm_rows(num_rows: int, num_features: int, seed: int = 0,
             signal_dims: int = 64, nnz: Optional[int] = None
             ) -> Tuple[np.ndarray, np.ndarray]:
    """Synthetic sparse-ish TF×IDF-like rows with a linear signal."""
    X, y = svm_rows_shard(num_rows, num_features, seed, signal_dims,
                          nnz=nnz)
    return X, y


def svm_rows_shard(num_rows: int, num_features: int, seed: int = 0,
                   signal_dims: int = 64, nnz: Optional[int] = None,
                   *, process_index: int = 0,
                   process_count: int = 1
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """THIS process's disjoint shard of the ``svm_rows`` dataset.

    Materializes only the stateless blocks covering the host's row
    range (plus at most one partial block per edge), never the full
    matrix: the per-host loading half of the multi-host substrate. With
    the defaults (one process) it IS the full dataset.
    """
    start, stop = host_row_range(num_rows, process_index, process_count)
    w = _svm_signal(num_features, seed, signal_dims)
    if stop == start:
        X = np.zeros((0, num_features), np.float32)
    else:
        parts = []
        for block in range(start // _ROW_BLOCK, (stop - 1) // _ROW_BLOCK + 1):
            b0 = block * _ROW_BLOCK
            rows = min(num_rows - b0, _ROW_BLOCK)
            full = _svm_row_block(block, rows, num_features, seed, nnz)
            parts.append(full[max(start - b0, 0):stop - b0])
        X = np.concatenate(parts, axis=0)
    y = np.sign(X @ w + 1e-3).astype(np.float32)
    return X, y


# -- sparse materialization (ISSUE 6): blocked-CSR rows straight from the
# generator — O(rows·nnz) host memory instead of O(rows·d), its own
# stateless stream (seed, 2, block) so dense and sparse draws never
# alias. Columns are drawn one-per-stratum (stride = d // nnz), which
# guarantees DISTINCT in-row indices — the SparseRows contract that
# makes Σv² row norms and duplicate-summing contractions agree with the
# densified matrix. (The per-row distribution differs from the dense
# generator's uniform no-replacement draw by design; no fixture pins
# raw values, and the normalization + planted linear signal match.)

def _svm_sparse_row_block(block: int, rows: int, num_features: int,
                          nnz_cap: int, nnz: int, seed: int
                          ) -> Tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng((seed, 2, block))
    stride = num_features // nnz
    offs = rng.integers(0, stride, (rows, nnz))
    cols = (np.arange(nnz, dtype=np.int64) * stride)[None, :] + offs
    vals = rng.random((rows, nnz), dtype=np.float32)
    norm = np.linalg.norm(vals, axis=1, keepdims=True)
    vals = vals / np.maximum(norm, 1e-9)
    indices = np.zeros((rows, nnz_cap), np.int32)
    values = np.zeros((rows, nnz_cap), np.float32)
    indices[:, :nnz] = cols.astype(np.int32)
    values[:, :nnz] = vals
    return indices, values


def svm_rows_sparse(num_rows: int, num_features: int, nnz_cap: int,
                    seed: int = 0, signal_dims: int = 64,
                    nnz: Optional[int] = None, *, process_index: int = 0,
                    process_count: int = 1):
    """THIS process's shard as blocked-CSR rows (``SparseRows``, numpy
    leaves) + labels — same block-stateless contract as
    :func:`svm_rows_shard`: the union over processes is the one-host
    dataset, and only the blocks covering the host's range materialize.
    """
    from repro import sparse as sparse_rows

    nnz = default_row_nnz(num_features) if nnz is None \
        else min(num_features, max(1, int(nnz)))
    if nnz > nnz_cap:
        raise ValueError(f"nnz={nnz} exceeds nnz_cap={nnz_cap}")
    start, stop = host_row_range(num_rows, process_index, process_count)
    w = _svm_signal(num_features, seed, signal_dims)
    if stop == start:
        indices = np.zeros((0, nnz_cap), np.int32)
        values = np.zeros((0, nnz_cap), np.float32)
    else:
        iparts, vparts = [], []
        for block in range(start // _ROW_BLOCK, (stop - 1) // _ROW_BLOCK + 1):
            b0 = block * _ROW_BLOCK
            rows = min(num_rows - b0, _ROW_BLOCK)
            bi, bv = _svm_sparse_row_block(block, rows, num_features,
                                           nnz_cap, nnz, seed)
            lo = max(start - b0, 0)
            iparts.append(bi[lo:stop - b0])
            vparts.append(bv[lo:stop - b0])
        indices = np.concatenate(iparts, axis=0)
        values = np.concatenate(vparts, axis=0)
    y = np.sign(np.sum(values * w[indices], axis=1) + 1e-3
                ).astype(np.float32)
    return sparse_rows.from_numpy_coo(indices, values, num_features), y
