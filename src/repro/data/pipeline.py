"""Deterministic synthetic data pipeline.

Two producers:
* token streams for backbone LM training (Zipf-distributed tokens with
  a planted n-gram structure so loss visibly decreases);
* the TF×IDF row stream for the MapReduce SVM (delegates to
  repro.text.corpus + tokenizer at small scale; direct synthetic
  feature rows at dry-run scale).

Batches are host-generated numpy, then device_put with the step's
input sharding by the launcher. Iterators are stateless-seeded
(seed, step) → reproducible and resumable from any checkpoint step.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch_size: int = 8
    seq_len: int = 128
    seed: int = 0


def _tokens_for_step(cfg: DataConfig, vocab: int, step: int,
                     structure: int = 97) -> np.ndarray:
    """Zipfian tokens with a deterministic bigram rule planted:
    after token t comes (t * 31 + 7) % structure with prob ~0.5 —
    learnable signal for smoke-training."""
    rng = np.random.default_rng((cfg.seed, step))
    B, S = cfg.batch_size, cfg.seq_len
    base = rng.zipf(1.3, size=(B, S)).clip(1, vocab - 1)
    follow = (base * 31 + 7) % min(structure, vocab)
    use_follow = rng.random((B, S)) < 0.5
    out = base.copy()
    out[:, 1:] = np.where(use_follow[:, 1:], follow[:, :-1], base[:, 1:])
    return out.astype(np.int32)


def lm_batches(cfg: DataConfig, model_cfg: ModelConfig,
               start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    """Next-token LM batches: {tokens, labels} (+ frontend stubs)."""
    step = start_step
    while True:
        yield lm_batch_at(cfg, model_cfg, step)
        step += 1


def lm_batch_at(cfg: DataConfig, model_cfg: ModelConfig,
                step: int) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng((cfg.seed, step, 1))
    S = cfg.seq_len
    P = model_cfg.num_prefix_tokens if model_cfg.frontend == "vision" else 0
    toks = _tokens_for_step(cfg, model_cfg.vocab_size, step)
    batch: Dict[str, np.ndarray] = {}
    if model_cfg.is_encoder_decoder:
        batch["frames"] = rng.normal(
            0, 1, (cfg.batch_size, model_cfg.encoder_seq,
                   model_cfg.d_model)).astype(np.float32)
        batch["tokens"] = toks[:, :S]
        batch["labels"] = np.concatenate(
            [toks[:, 1:S], toks[:, :1]], axis=1).astype(np.int32)
    elif P > 0:
        batch["prefix_embeds"] = rng.normal(
            0, 1, (cfg.batch_size, P, model_cfg.d_model)).astype(np.float32)
        text = toks[:, :S - P]
        batch["tokens"] = text[:, :-1] if text.shape[1] > 1 else text
        batch["labels"] = text[:, 1:] if text.shape[1] > 1 else text
        # keep tokens/labels same length
        batch["tokens"] = text
        batch["labels"] = np.concatenate(
            [text[:, 1:], text[:, :1]], axis=1).astype(np.int32)
    else:
        batch["tokens"] = toks
        batch["labels"] = np.concatenate(
            [toks[:, 1:], toks[:, :1]], axis=1).astype(np.int32)
    return batch


def svm_rows(num_rows: int, num_features: int, seed: int = 0,
             signal_dims: int = 64) -> Tuple[np.ndarray, np.ndarray]:
    """Synthetic sparse-ish TF×IDF-like rows with a linear signal."""
    rng = np.random.default_rng(seed)
    w = np.zeros(num_features, np.float32)
    idx = rng.choice(num_features, signal_dims, replace=False)
    w[idx] = rng.normal(0, 1, signal_dims)
    X = np.zeros((num_rows, num_features), np.float32)
    nnz = max(4, num_features // 256)
    for i in range(num_rows):
        cols = rng.choice(num_features, nnz, replace=False)
        X[i, cols] = rng.random(nnz).astype(np.float32)
    norm = np.linalg.norm(X, axis=1, keepdims=True)
    X /= np.maximum(norm, 1e-9)
    y = np.sign(X @ w + 1e-3).astype(np.float32)
    return X, y
