"""Deterministic fault injection + the hardening primitives it drives
(DESIGN.md §15). ``python -m repro.faults.chaos`` is the seed-sweep
harness (``make test-chaos``); :mod:`repro.faults.chaos` is imported
lazily there, never from here (it imports the layers under attack)."""
from repro.faults.plan import (KINDS, FaultDetected, FaultPlan, FaultSpec,
                               InjectedFault, InjectedWriteError,
                               TransientFault, active, check_finite_risks,
                               corrupt_file, count, counters, fire,
                               garble_wire, inject, maybe_raise,
                               maybe_sleep, poison_batch, reset_counters,
                               set_active)
from repro.faults.retry import retry_with_backoff
from repro.faults.watchdog import (WATCHDOG_EXIT_CODE, CollectiveWatchdog,
                                   exit_handler)

__all__ = [
    "KINDS", "FaultDetected", "FaultPlan", "FaultSpec", "InjectedFault",
    "InjectedWriteError", "TransientFault", "active",
    "check_finite_risks", "corrupt_file", "count", "counters", "fire",
    "garble_wire", "inject", "maybe_raise", "maybe_sleep",
    "poison_batch", "reset_counters", "set_active",
    "retry_with_backoff", "WATCHDOG_EXIT_CODE", "CollectiveWatchdog",
    "exit_handler",
]
