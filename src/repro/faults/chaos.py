"""Seed-sweep chaos harness (``make test-chaos``).

Runs every fault scenario under a deterministic :class:`FaultPlan` per
seed and asserts the survived-vs-detected contract (DESIGN.md §15):

* **survived** — transient faults (delayed round, flaky merge call,
  failed checkpoint write, flaky coordinator handshake, a killed wave
  scheduler, poisoned rows behind quarantine) are absorbed by the
  hardening and the result is BIT-FOR-BIT the fault-free one;
* **detected** — corrupting/terminal faults (garbled ring wire,
  corrupted snapshot media, a stalled collective) raise a typed
  :class:`FaultDetected` naming layer + cause — or demonstrably fall
  back to the newest intact checkpoint generation;
* never a hang (the whole sweep runs under its own self-protective
  :class:`CollectiveWatchdog`), never a silent wrong answer.

Usage::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.faults.chaos --seeds 0,1,2

The harness forces 8 faked host devices itself when launched before
jax's first import, so a bare ``python -m repro.faults.chaos`` works
too. Exit status 0 iff every scenario met its expected outcome.

NOT imported from :mod:`repro.faults` — this module imports the layers
under attack (core, ckpt, serving), which import ``repro.faults``.
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile
import time

from repro.faults.plan import (FaultDetected, FaultPlan, InjectedFault,
                               counters, inject, reset_counters)
from repro.faults.watchdog import CollectiveWatchdog

NDEV = 8


def _ensure_devices() -> None:
    """Force 8 faked host devices BEFORE jax's first backend init (the
    count locks at first use; a harness that silently ran on 1 device
    would skip every sharded scenario)."""
    if "jax" in sys.modules:
        return
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    xf = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in xf:
        os.environ["XLA_FLAGS"] = \
            (xf + f" --xla_force_host_platform_device_count={NDEV}").strip()


# ---------------------------------------------------------------------------
# shared fixtures (built once, reused across seeds)
# ---------------------------------------------------------------------------

class Ctx:
    """Lazily-built clean references the scenarios diff against."""

    def __init__(self):
        self._cache = {}

    def problem(self):
        if "problem" not in self._cache:
            import jax
            import jax.numpy as jnp
            X = jax.random.normal(jax.random.PRNGKey(0), (256, 16))
            w = jax.random.normal(jax.random.PRNGKey(1), (16,))
            y = jnp.sign(X @ w)
            self._cache["problem"] = (X, y)
        return self._cache["problem"]

    def cfg(self):
        if "cfg" not in self._cache:
            from repro.core import MRSVMConfig, SVMConfig
            self._cache["cfg"] = MRSVMConfig(
                sv_capacity=64, max_rounds=3, gamma=1e-4,
                svm=SVMConfig(C=1.0, max_epochs=10))
        return self._cache["cfg"]

    def clean_model(self):
        """Fault-free functional fit — the bit-for-bit oracle."""
        if "clean" not in self._cache:
            from repro.core.mapreduce_svm import fit_mapreduce
            X, y = self.problem()
            self._cache["clean"] = fit_mapreduce(X, y, NDEV, self.cfg())
        return self._cache["clean"]

    def ring_cfg(self, wire_check: bool):
        import dataclasses as dc
        return dc.replace(self.cfg(), shuffle_impl="ring",
                          shuffle_wire_dtype="float32",
                          shuffle_wire_check=wire_check)

    def hier_cfg(self, wire_check: bool):
        """Two-level transport at the simulated 2-host × 4-local
        topology (DESIGN.md §16)."""
        import dataclasses as dc
        return dc.replace(self.cfg(), shuffle_impl="hier",
                          shuffle_wire_dtype="float32",
                          hier_num_hosts=2,
                          shuffle_wire_check=wire_check)

    def mesh(self):
        if "mesh" not in self._cache:
            from repro import compat
            self._cache["mesh"] = compat.make_mesh((NDEV,), ("data",))
        return self._cache["mesh"]


def _model_leaves(m):
    import numpy as np
    return {"w": np.asarray(m.w), "b": np.asarray(m.b),
            "alpha": np.asarray(m.final.alpha),
            "fw": np.asarray(m.final.w), "fb": np.asarray(m.final.b),
            "ids": np.asarray(m.sv.ids), "mask": np.asarray(m.sv.mask),
            "svx": np.asarray(m.sv.x)}


def _assert_bitwise_equal(got, want, what: str) -> None:
    import numpy as np
    a, b = _model_leaves(got), _model_leaves(want)
    for k in a:
        if not np.array_equal(a[k], b[k]):
            raise AssertionError(
                f"{what}: leaf {k!r} differs from the fault-free run "
                "— the fault was absorbed but NOT bit-for-bit")


# ---------------------------------------------------------------------------
# scenarios — each returns a detail string on the expected outcome and
# raises AssertionError on a contract violation
# ---------------------------------------------------------------------------

def scenario_delay_round(seed: int, ctx: Ctx) -> str:
    """delay_round → SURVIVED: a stalled round completes late but the
    converged model is bit-identical to the fault-free run."""
    from repro.core.mapreduce_svm import fit_mapreduce
    X, y = ctx.problem()
    plan = FaultPlan.single("delay_round", seed)
    t0 = time.monotonic()
    with inject(plan) as armed:
        m = fit_mapreduce(X, y, NDEV, ctx.cfg())
    assert armed.fired, "the delay never fired (dead seam)"
    _assert_bitwise_equal(m, ctx.clean_model(), "delay_round")
    return (f"slept at round {plan.specs[0].when}, "
            f"+{time.monotonic() - t0:.2f}s wall, model bit-identical")


def scenario_transport_exc(seed: int, ctx: Ctx) -> str:
    """transport_exc → SURVIVED: the merge call fails transiently 1-2×
    and retry-with-backoff absorbs it; model bit-identical."""
    from repro.core.mapreduce_svm import fit_mapreduce
    X, y = ctx.problem()
    plan = FaultPlan.single("transport_exc", seed)
    before = counters().get("retries", 0)
    with inject(plan) as armed:
        m = fit_mapreduce(X, y, NDEV, ctx.cfg())
    assert sum(armed.remaining) == 0, "injected failures not all raised"
    retried = counters().get("retries", 0) - before
    assert retried >= plan.specs[0].count, \
        f"expected ≥{plan.specs[0].count} retries, saw {retried}"
    _assert_bitwise_equal(m, ctx.clean_model(), "transport_exc")
    return f"{retried} retries absorbed, model bit-identical"


def scenario_wire_check_clean(seed: int, ctx: Ctx) -> str:
    """No fault, integrity lane ON → the checked ring reproduces the
    unchecked ring bit-for-bit (the lane is free when honest)."""
    import numpy as np
    from repro.core.mapreduce_svm import (build_sharded_round,
                                          init_sv_buffer)
    from repro.faults.plan import check_finite_risks
    X, y = ctx.problem()
    n, d = X.shape
    import jax.numpy as jnp
    mask = jnp.ones((n,))
    outs = []
    for wire_check in (False, True):
        cfg = ctx.ring_cfg(wire_check)
        fn = build_sharded_round(ctx.mesh(), ("data",), cfg, n // NDEV)
        sv = init_sv_buffer(cfg.sv_capacity, d)
        for _ in range(2):
            sv, risks, w, b = fn(X, y, mask, sv)
        check_finite_risks(risks, where="clean checked ring")
        outs.append((np.asarray(risks), np.asarray(sv.ids),
                     np.asarray(sv.x), np.asarray(w)))
    for a, b2 in zip(outs[0], outs[1]):
        assert np.array_equal(a, b2), \
            "integrity lane changed the clean ring's results"
    return "checked ring ≡ unchecked ring bit-for-bit, risks finite"


def scenario_ring_garble(seed: int, ctx: Ctx) -> str:
    """ring_garble → DETECTED: one mantissa bit flipped on one ring hop
    is caught by the wire checksum — FaultDetected names transport."""
    from repro.core.mapreduce_svm import (build_sharded_round,
                                          init_sv_buffer)
    from repro.faults.plan import check_finite_risks
    import jax.numpy as jnp
    X, y = ctx.problem()
    n, d = X.shape
    mask = jnp.ones((n,))
    cfg = ctx.ring_cfg(True)
    plan = FaultPlan.single("ring_garble", seed)
    with inject(plan) as armed:
        # garble is a TRACE-time seam: the plan must be armed while the
        # round program is built+first-traced (fresh build per seed)
        fn = build_sharded_round(ctx.mesh(), ("data",), cfg, n // NDEV)
        sv = init_sv_buffer(cfg.sv_capacity, d)
        sv, risks, w, b = fn(X, y, mask, sv)
    assert armed.fired, "the garble never baked into the trace"
    try:
        check_finite_risks(risks, where="garbled ring round")
    except FaultDetected as e:
        assert e.layer == "transport", f"wrong layer {e.layer!r}"
        return (f"hop {plan.specs[0].when} garble caught: "
                f"[{e.layer}] wire checksum sentinel")
    raise AssertionError(
        "garbled wire produced FINITE risks — silent corruption")


def scenario_hier_transient(seed: int, ctx: Ctx) -> str:
    """delay_round + transport_exc over the HIER transport → SURVIVED:
    a slow hop and 1-2 transient merge failures are absorbed by the
    same host-driver seams the flat transports use (the two-level
    schedule changes the collective, not the hardening), and the
    sharded hier rounds stay bit-identical to the fault-free run."""
    import jax.numpy as jnp
    import numpy as np
    from repro.core.mapreduce_svm import (build_sharded_round,
                                          init_sv_buffer)
    from repro.faults.plan import TransientFault, maybe_raise, maybe_sleep
    from repro.faults.retry import retry_with_backoff

    X, y = ctx.problem()
    n, d = X.shape
    mask = jnp.ones((n,))
    cfg = ctx.hier_cfg(True)
    fn = build_sharded_round(ctx.mesh(), ("data",), cfg, n // NDEV)

    def drive():
        """The production driver loop's transport seams (DESIGN.md §15)
        around the sharded hier round."""
        sv = init_sv_buffer(cfg.sv_capacity, d)
        for t in range(3):
            maybe_sleep("transport.round", when=t)

            def run_round():
                maybe_raise("transport.merge", kinds=("transport_exc",),
                            when=t)
                return fn(X, y, mask, sv)

            sv, risks, w, b = retry_with_backoff(
                run_round, attempts=3, base_s=0.01,
                retry_on=TransientFault, layer="transport",
                cause=f"hier merge collective at round {t}")
        return np.asarray(risks), np.asarray(sv.ids), np.asarray(sv.x), \
            np.asarray(w)

    clean = drive()                     # no plan armed: the oracle
    plan = FaultPlan(seed=seed,
                     specs=(FaultPlan.single("delay_round", seed).specs
                            + FaultPlan.single("transport_exc", seed).specs))
    before = counters().get("retries", 0)
    with inject(plan) as armed:
        chaos = drive()
    assert armed.fired, "neither transport fault fired over hier"
    assert sum(armed.remaining) == 0, "injected failures not all raised"
    retried = counters().get("retries", 0) - before
    for a, b2 in zip(chaos, clean):
        assert np.array_equal(a, b2), \
            "hier rounds under transient faults are NOT bit-identical"
    return (f"slow hop at round {plan.specs[0].when} + {retried} merge "
            "retries absorbed, hier rounds bit-identical")


def scenario_hier_garble(seed: int, ctx: Ctx) -> str:
    """ring_garble over the HIER transport → DETECTED: a mantissa bit
    flipped on the inter-host slice exchange is caught by the same wire
    checksum lane as the flat ring. At 2 simulated hosts only hop 0
    shifts, so the spec pins ``when=None`` (first opportunity) rather
    than ``FaultPlan.single``'s 1..6 draw."""
    import jax.numpy as jnp
    import numpy as np
    from repro.core.mapreduce_svm import (build_sharded_round,
                                          init_sv_buffer)
    from repro.faults.plan import FaultSpec, check_finite_risks
    X, y = ctx.problem()
    n, d = X.shape
    mask = jnp.ones((n,))
    cfg = ctx.hier_cfg(True)
    param = int(np.random.default_rng([seed, 1093]).integers(0, 1 << 30))
    plan = FaultPlan(seed=seed,
                     specs=(FaultSpec("ring_garble", when=None, count=1,
                                      param=param),))
    with inject(plan) as armed:
        # trace-time seam: arm while the hier program is built
        fn = build_sharded_round(ctx.mesh(), ("data",), cfg, n // NDEV)
        sv = init_sv_buffer(cfg.sv_capacity, d)
        sv, risks, w, b = fn(X, y, mask, sv)
    assert armed.fired, "the garble never baked into the hier trace"
    try:
        check_finite_risks(risks, where="garbled hier round")
    except FaultDetected as e:
        assert e.layer == "transport", f"wrong layer {e.layer!r}"
        return ("inter-host hop garble caught: "
                f"[{e.layer}] wire checksum sentinel")
    raise AssertionError(
        "garbled hier wire produced FINITE risks — silent corruption")


def scenario_stall(seed: int, ctx: Ctx) -> str:
    """stall → DETECTED: a body that stops beating trips the collective
    watchdog; the heartbeat file records the typed diagnosis."""
    import json
    plan = FaultPlan.single("stall", seed)
    hb = os.path.join(tempfile.mkdtemp(prefix="chaos_hb_"), "hb.json")
    fired = []
    with inject(plan):
        with CollectiveWatchdog(0.25, heartbeat_path=hb,
                                layer="transport",
                                cause=f"seed {seed} stalled merge",
                                on_timeout=fired.append) as wd:
            time.sleep(0.7)            # stranded: no beat() arrives
        try:
            wd.check()
        except FaultDetected as e:
            assert e.layer == "transport"
            with open(hb) as f:
                status = json.load(f)
            assert status["status"] == "timeout", status
            return (f"watchdog fired after {status['elapsed_s']}s "
                    "(deadline 0.25s), heartbeat says timeout")
    raise AssertionError("stalled section did not trip the watchdog")


def _service(cfg, ckpt_dir, **kw):
    from repro.serving import StreamingSVMService
    return StreamingSVMService(cfg, num_partitions=4,
                               checkpoint_dir=ckpt_dir, **kw)


def _register_stream(svc, ctx):
    from repro.core.mapreduce_svm import fit_mapreduce
    X, y = ctx.problem()
    svc.register("t", fit_mapreduce(X, y, 4, ctx.cfg()))


def scenario_ckpt_write_fail(seed: int, ctx: Ctx) -> str:
    """ckpt_write_fail → SURVIVED: 1-2 injected write failures are
    retried; the installed checkpoint restores bit-exact."""
    from repro.serving import StreamingSVMService
    d = tempfile.mkdtemp(prefix="chaos_ckpt_")
    svc = _service(ctx.cfg(), d)
    _register_stream(svc, ctx)
    plan = FaultPlan.single("ckpt_write_fail", seed)
    with inject(plan) as armed:
        svc.checkpoint()
    assert sum(armed.remaining) == 0, "write failures not all injected"
    assert svc.throughput_report()["retries"] >= plan.specs[0].count
    svc2 = StreamingSVMService.restore(ctx.cfg(), d)
    _assert_bitwise_equal(svc2.snapshot("t").model,
                          svc.snapshot("t").model, "ckpt_write_fail")
    return (f"{svc.throughput_report()['retries']} write retries, "
            "restore bit-exact")


def scenario_ckpt_corrupt(seed: int, ctx: Ctx) -> str:
    """ckpt_corrupt → DETECTED + FALLBACK: the newest generation's
    medium is corrupted in flight; restore skips it (crc mismatch) and
    comes back from the previous intact generation."""
    import numpy as np
    from repro.core.mapreduce_svm import update_mapreduce
    from repro.serving import StreamingSVMService
    X, y = ctx.problem()
    d = tempfile.mkdtemp(prefix="chaos_ckpt_")
    svc = _service(ctx.cfg(), d)
    _register_stream(svc, ctx)          # generation 0 (intact)
    w_gen0 = np.asarray(svc.snapshot("t").model.w)
    # advance the model, then checkpoint generation 1 under corruption
    m1 = update_mapreduce(svc.snapshot("t").model, X[:64], y[:64], 4,
                          ctx.cfg())
    svc._swap("t", m1, None)
    plan = FaultPlan.single("ckpt_corrupt", seed)
    with inject(plan) as armed:
        svc.checkpoint()
    assert armed.fired, "the media corruption never fired"
    svc2 = StreamingSVMService.restore(ctx.cfg(), d)
    assert svc2.restore_fallbacks >= 1, \
        "restore trusted a corrupt newest generation"
    got = np.asarray(svc2.snapshot("t").model.w)
    assert np.array_equal(got, w_gen0), \
        "fallback restored something other than the previous generation"
    return ("gen 1 media corrupt → crc mismatch, fell back to intact "
            "gen 0 bit-exact")


def scenario_poison_rows(seed: int, ctx: Ctx) -> str:
    """poison_rows → SURVIVED: the poisoned batch is quarantined at
    submit(); the folded model is bit-identical to a clean-only fold."""
    import jax.numpy as jnp
    X, y = ctx.problem()
    Xa, ya = X[:96], y[:96]
    Xb, yb = X[96:192], y[96:192]

    def fold(poison: bool):
        svc = _service(ctx.cfg(), None)
        _register_stream(svc, ctx)
        if poison:
            plan = FaultPlan.single("poison_rows", seed)
            with inject(plan) as armed:
                svc.submit("t", Xb, yb)     # poisoned → quarantined
            assert armed.fired, "poison seam never fired"
            assert svc.throughput_report()["quarantined"] == 1
        svc.submit("t", Xa, ya)
        svc.drain()
        return svc

    clean = fold(poison=False)
    chaos = fold(poison=True)
    assert jnp.isfinite(chaos.snapshot("t").model.w).all()
    _assert_bitwise_equal(chaos.snapshot("t").model,
                          clean.snapshot("t").model, "poison_rows")
    return "1 batch quarantined, model ≡ clean-only fold bit-for-bit"


def scenario_scheduler_kill(seed: int, ctx: Ctx) -> str:
    """scheduler_kill → SURVIVED after restart: the wave dies, its
    batches requeue at the HEAD, the retry wave folds them exactly
    once — model ≡ an uninterrupted fold."""
    X, y = ctx.problem()
    Xa, ya = X[:96], y[:96]

    svc_ref = _service(ctx.cfg(), None)
    _register_stream(svc_ref, ctx)
    svc_ref.submit("t", Xa, ya)
    svc_ref.drain()

    svc = _service(ctx.cfg(), None)
    _register_stream(svc, ctx)
    svc.submit("t", Xa, ya)
    plan = FaultPlan.single("scheduler_kill", seed)
    with inject(plan):
        try:
            svc.run_wave()
            raise AssertionError("injected scheduler death did not kill "
                                 "the wave")
        except InjectedFault:
            pass
    assert svc.pending() == 1, "dead wave's batch was not requeued"
    assert svc.throughput_report()["requeued"] == 1
    svc.drain()                          # the restarted scheduler's wave
    _assert_bitwise_equal(svc.snapshot("t").model,
                          svc_ref.snapshot("t").model, "scheduler_kill")
    return "wave died, batch requeued, refolded exactly once bit-exact"


def scenario_handshake_flake(seed: int, ctx: Ctx) -> str:
    """handshake_flake → SURVIVED: the coordinator handshake flaps 1-2×
    and the bounded retry in init_cluster's wrapper absorbs it (the
    REAL init_cluster path runs in the 2-process chaos leg of
    tests/test_multihost.py)."""
    from repro.faults.retry import retry_with_backoff
    from repro.faults.plan import maybe_raise, TransientFault
    plan = FaultPlan.single("handshake_flake", seed)
    calls = []

    def handshake():
        maybe_raise("cluster.handshake", kinds=("handshake_flake",))
        calls.append(1)

    with inject(plan) as armed:
        retry_with_backoff(handshake, attempts=3, base_s=0.01,
                           retry_on=TransientFault, layer="cluster",
                           cause="coordinator handshake")
    assert calls == [1], "handshake did not complete exactly once"
    assert sum(armed.remaining) == 0
    return (f"{plan.specs[0].count} flakes absorbed, "
            "handshake completed once")


SCENARIOS = [
    ("delay_round", "survived", scenario_delay_round),
    ("transport_exc", "survived", scenario_transport_exc),
    ("wire_check_clean", "survived", scenario_wire_check_clean),
    ("ring_garble", "detected", scenario_ring_garble),
    ("hier_transient", "survived", scenario_hier_transient),
    ("hier_garble", "detected", scenario_hier_garble),
    ("stall", "detected", scenario_stall),
    ("ckpt_write_fail", "survived", scenario_ckpt_write_fail),
    ("ckpt_corrupt", "detected", scenario_ckpt_corrupt),
    ("poison_rows", "survived", scenario_poison_rows),
    ("scheduler_kill", "survived", scenario_scheduler_kill),
    ("handshake_flake", "survived", scenario_handshake_flake),
]


def main(argv=None) -> int:
    _ensure_devices()
    ap = argparse.ArgumentParser(
        description="deterministic fault-injection sweep")
    ap.add_argument("--seeds", default="0,1,2",
                    help="comma-separated plan seeds")
    ap.add_argument("--only", default=None,
                    help="run only scenarios whose name contains this")
    ap.add_argument("--deadline", type=float, default=240.0,
                    help="per-scenario watchdog deadline (s) — the "
                         "harness itself must never hang")
    args = ap.parse_args(argv)
    seeds = [int(s) for s in args.seeds.split(",") if s != ""]

    import jax
    if len(jax.devices()) < NDEV:
        print(f"chaos: need {NDEV} devices for the sharded scenarios, "
              f"have {len(jax.devices())} — set XLA_FLAGS="
              f"--xla_force_host_platform_device_count={NDEV}",
              file=sys.stderr)
        return 2

    reset_counters()
    rows = []
    failures = 0
    t_start = time.monotonic()
    # The harness eats its own dogfood: every scenario runs under the
    # watchdog, so a hung scenario exits 17 with a typed diagnosis
    # instead of stranding CI.
    with CollectiveWatchdog(args.deadline, layer="harness",
                            cause="chaos scenario") as wd:
        for seed in seeds:
            for name, expect, fn in SCENARIOS:
                if args.only and args.only not in name:
                    continue
                t0 = time.monotonic()
                try:
                    detail = fn(seed, _CTX)
                    outcome, ok = expect, True
                except AssertionError as e:
                    outcome, ok, detail = "VIOLATED", False, str(e)
                except BaseException as e:
                    outcome, ok = "ERROR", False
                    detail = f"{type(e).__name__}: {e}"
                rows.append((seed, name, expect, outcome, ok,
                             time.monotonic() - t0, detail))
                failures += not ok
                wd.beat()

    width = max(len(r[1]) for r in rows) if rows else 10
    print(f"\nchaos sweep: seeds={seeds} "
          f"({time.monotonic() - t_start:.1f}s total)")
    print(f"{'seed':>4}  {'scenario':<{width}}  {'expect':<9} "
          f"{'outcome':<9} {'t(s)':>6}  detail")
    for seed, name, expect, outcome, ok, dt, detail in rows:
        mark = "ok " if ok else "FAIL"
        print(f"{seed:>4}  {name:<{width}}  {expect:<9} "
              f"{outcome:<9} {dt:>6.1f}  [{mark}] {detail}")
    cts = {k: v for k, v in sorted(counters().items())}
    print(f"counters: {cts}")
    if failures:
        print(f"chaos: {failures} scenario(s) violated the "
              "survived-vs-detected contract", file=sys.stderr)
        return 1
    print("chaos: every fault survived bit-for-bit or was detected "
          "and named — no hangs, no silent wrong answers")
    return 0


_CTX = Ctx()

if __name__ == "__main__":
    sys.exit(main())
