"""Deterministic fault-injection layer (DESIGN.md §15).

Production failures are routine inputs, not test-only events: the
paper's iterate-global-merge loop is pitched for cluster-scale corpora
and CloudSVM (arXiv:1301.0082) frames it as a resilient cloud service.
This module gives every data boundary in the repo an explicit,
seed-driven *seam* where a fault can be injected — and a single typed
vocabulary (:class:`FaultDetected`) for how a hardened layer reports
one it caught.

The contract every seam-bearing layer owes the chaos harness
(``make test-chaos``, :mod:`repro.faults.chaos`):

* **survived** — a *transient* fault (delayed hop, flaky transport
  call, failed checkpoint write) is absorbed by retry/backoff and the
  run converges bit-for-bit with the fault-free run;
* **detected** — a *corrupting or terminal* fault (garbled wire bits,
  flipped snapshot bytes, poisoned rows, a dead scheduler, a stranded
  collective) raises :class:`FaultDetected` naming the layer and the
  cause, with the operator action attached;
* never a hang, never a silent wrong answer.

Seams consult the process-wide *active plan* (:func:`inject` /
:func:`set_active`) and are free when no plan is armed. Host-level
seams (:func:`maybe_raise`, :func:`maybe_sleep`) fire at call time;
:func:`garble_wire` fires at TRACE time — compiled collectives cannot
take runtime Python hooks, so the corruption is baked into the program
built while the plan is active (the chaos harness builds a fresh
round program per garble scenario).
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import threading
import time
import zlib
from collections import Counter
from typing import Dict, Iterable, Optional, Tuple

import numpy as np

# fault kind → the layer whose hardening owns it
KINDS: Dict[str, str] = {
    "delay_round": "transport",      # a ring hop stalls, then completes
    "transport_exc": "transport",    # the merge call raises transiently
    "ring_garble": "transport",      # bits flip on the wire mid-hop
    "stall": "transport",            # stranded-in-collective hang
    "ckpt_write_fail": "ckpt",       # snapshot/manifest write raises
    "ckpt_corrupt": "ckpt",          # written media truncated/bit-flipped
    "poison_rows": "serving",        # NaN/Inf rows at the featurizer seam
    "scheduler_kill": "serving",     # the wave scheduler thread dies
    "handshake_flake": "cluster",    # coordinator handshake flaps
}


class FaultDetected(RuntimeError):
    """A fault crossed a hardened boundary and was *caught* — typed,
    named by layer + cause, and carrying the operator action. The
    survived-vs-detected contract's "detected" arm: never a hang,
    never a silent wrong answer."""

    def __init__(self, layer: str, cause: str,
                 action: Optional[str] = None):
        self.layer, self.cause, self.action = layer, cause, action
        msg = f"[{layer}] {cause}"
        if action:
            msg += f" — {action}"
        super().__init__(msg)


class InjectedFault(RuntimeError):
    """Raised BY an armed seam: the fault itself, not its detection."""

    def __init__(self, spec: "FaultSpec", seam: str):
        self.spec, self.seam = spec, seam
        super().__init__(f"injected {spec.kind} at seam {seam!r}")


class TransientFault(InjectedFault):
    """An injected failure a retry is expected to absorb."""


class InjectedWriteError(OSError):
    """Injected I/O failure — an ``OSError`` so generic write-retry
    filters (``retry_on=OSError``) treat it like the real thing."""

    def __init__(self, spec: "FaultSpec", seam: str):
        self.spec, self.seam = spec, seam
        super().__init__(f"injected {spec.kind} at seam {seam!r}")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault: ``kind`` (see :data:`KINDS`), ``when`` —
    the round/wave/hop index it targets (``None`` = the first
    opportunity), ``count`` — how many times a transient seam fires
    before letting the call through, ``param`` — kind-specific salt
    (corruption mode, poison row seed, …)."""
    kind: str
    when: Optional[int] = None
    count: int = 1
    param: int = 0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(known: {sorted(KINDS)})")

    @property
    def layer(self) -> str:
        return KINDS[self.kind]


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seeded, fully deterministic schedule of faults. The same
    (constructor, seed) always yields the same specs AND the same
    per-seam randomness (:meth:`rng` derives independent substreams
    from the plan seed + a string salt), so every chaos scenario is
    replayable from its seed alone."""
    seed: int
    specs: Tuple[FaultSpec, ...]

    def rng(self, *salt) -> np.random.Generator:
        keys = [self.seed] + [zlib.crc32(str(s).encode()) for s in salt]
        return np.random.default_rng(keys)

    @classmethod
    def single(cls, kind: str, seed: int) -> "FaultPlan":
        """One seeded fault of ``kind`` (the chaos sweep's unit)."""
        g = np.random.default_rng([seed, zlib.crc32(kind.encode())])
        when: Optional[int] = None
        count = 1
        if kind == "delay_round":
            when = int(g.integers(0, 3))
        elif kind == "ring_garble":
            when = int(g.integers(1, 7))        # hop 1..6 of an 8-ring
        elif kind in ("transport_exc", "ckpt_write_fail",
                      "handshake_flake"):
            count = 1 + int(g.integers(0, 2))   # 1-2 transient failures
        return cls(seed=seed,
                   specs=(FaultSpec(kind, when=when, count=count,
                                    param=int(g.integers(0, 1 << 30))),))

    @classmethod
    def from_seed(cls, seed: int,
                  kinds: Optional[Iterable[str]] = None) -> "FaultPlan":
        """A mixed plan: 2-4 seeded faults drawn from ``kinds``."""
        pool = sorted(kinds) if kinds is not None else sorted(KINDS)
        g = np.random.default_rng([seed, len(pool)])
        picked = g.choice(len(pool), size=int(g.integers(2, 5)),
                          replace=True)
        specs = tuple(s for i in picked
                      for s in cls.single(pool[i], seed).specs)
        return cls(seed=seed, specs=specs)


class _ArmedPlan:
    """Runtime state of an active plan: per-spec remaining fire counts
    and a log of what actually fired (scenario assertions read it)."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.remaining = [s.count for s in plan.specs]
        self.fired: list = []
        self.lock = threading.Lock()


_ACTIVE: Optional[_ArmedPlan] = None
_COUNTS: Counter = Counter()
_COUNT_LOCK = threading.Lock()


def count(name: str, n: int = 1) -> None:
    """Bump a process-wide chaos/hardening counter (retries,
    watchdog_fires, quarantined, ckpt_fallbacks, …)."""
    with _COUNT_LOCK:
        _COUNTS[name] += n


def counters() -> Dict[str, int]:
    with _COUNT_LOCK:
        return dict(_COUNTS)


def reset_counters() -> None:
    with _COUNT_LOCK:
        _COUNTS.clear()


def set_active(plan: Optional[FaultPlan]) -> None:
    """Arm ``plan`` process-wide (``None`` disarms). Subprocess entry
    points use this; tests prefer the scoped :func:`inject`."""
    global _ACTIVE
    _ACTIVE = _ArmedPlan(plan) if plan is not None else None


def active() -> Optional[_ArmedPlan]:
    return _ACTIVE


@contextlib.contextmanager
def inject(plan: FaultPlan):
    """Scope an armed plan: seams fire inside, the previous plan (if
    any) is restored on exit. Yields the armed state so callers can
    assert on ``.fired`` / ``.remaining``."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = armed = _ArmedPlan(plan)
    try:
        yield armed
    finally:
        _ACTIVE = prev


def fire(seam: str, kinds: Iterable[str],
         when: Optional[int] = None) -> Optional[FaultSpec]:
    """Consume one armed fault matching this seam, or ``None``.

    A spec matches when its kind is one the seam serves, its ``when``
    is unset or equals the caller's, and it has fires remaining. Each
    successful match decrements the spec's count — "transient, fires
    twice" is ``count=2``.
    """
    armed = _ACTIVE
    if armed is None:
        return None
    kindset = set(kinds)
    with armed.lock:
        for i, spec in enumerate(armed.plan.specs):
            if (spec.kind in kindset and armed.remaining[i] > 0
                    and (spec.when is None or when is None
                         or spec.when == when)):
                armed.remaining[i] -= 1
                armed.fired.append((seam, spec, when))
                count(f"injected.{spec.kind}")
                return spec
    return None


def maybe_raise(seam: str, kinds: Iterable[str],
                when: Optional[int] = None) -> None:
    """Raise the typed injected error if a matching fault is armed:
    write-kinds raise :class:`InjectedWriteError` (an ``OSError``),
    transient kinds :class:`TransientFault`, the rest
    :class:`InjectedFault`."""
    spec = fire(seam, kinds, when)
    if spec is None:
        return
    if spec.kind == "ckpt_write_fail":
        raise InjectedWriteError(spec, seam)
    if spec.kind in ("transport_exc", "handshake_flake"):
        raise TransientFault(spec, seam)
    raise InjectedFault(spec, seam)


def maybe_sleep(seam: str, when: Optional[int] = None,
                max_s: float = 0.5) -> float:
    """Host-level delay seam (``delay_round``): stall the caller for a
    seeded sub-``max_s`` duration. Returns the seconds slept."""
    armed = _ACTIVE
    spec = fire(seam, ("delay_round",), when)
    if spec is None:
        return 0.0
    dt = float(armed.plan.rng("delay", spec.param).uniform(0.05, max_s))
    time.sleep(dt)
    return dt


def garble_wire(msg, hop: int):
    """TRACE-TIME wire corruption seam (``ring_garble``).

    Called on the output of every ring ``ppermute`` while the round
    program is being traced; with a matching armed fault it bakes a
    single-bit XOR of one seeded f32 lane into the compiled program
    (lane < len-1, so an appended integrity lane is never the flipped
    one and a checksum mismatch is guaranteed, not probabilistic).
    Without an armed plan the message passes through untouched and the
    compiled program is byte-identical to the clean build.
    """
    armed = _ACTIVE
    spec = fire("transport.wire", ("ring_garble",), when=hop)
    if spec is None or msg is None:
        return msg
    import jax
    import jax.numpy as jnp
    g = armed.plan.rng("garble", hop, spec.param)
    lane = int(g.integers(0, max(int(msg.shape[0]) - 1, 1)))
    bit = 1 << int(g.integers(1, 23))           # mantissa bit: value changes
    bits = jax.lax.bitcast_convert_type(msg, jnp.int32)
    flip = jnp.zeros_like(bits).at[lane].set(jnp.int32(bit))
    return jax.lax.bitcast_convert_type(bits ^ flip, jnp.float32)


def poison_batch(X, y, spec: FaultSpec):
    """Featurizer-seam corruption (``poison_rows``): a seeded NaN or
    Inf entry lands in one row of the batch, exactly what a hostile or
    buggy upstream vectorizer would hand ``submit()``."""
    armed = _ACTIVE
    g = (armed.plan.rng("poison", spec.param) if armed is not None
         else np.random.default_rng(spec.param))
    import jax.numpy as jnp
    from repro import sparse as sparse_rows
    bad = float("nan") if int(g.integers(0, 2)) else float("inf")
    row = int(g.integers(0, X.shape[0]))
    if sparse_rows.is_sparse(X):
        vals = jnp.asarray(X.values).at[row, 0].set(bad)
        X = sparse_rows.SparseRows(X.indices, vals, X.shape[1])
    else:
        col = int(g.integers(0, X.shape[1]))
        X = jnp.asarray(X).at[row, col].set(bad)
    return X, y


def corrupt_file(path: str, spec: FaultSpec,
                 rng: Optional[np.random.Generator] = None) -> str:
    """Media-corruption seam (``ckpt_corrupt``): truncate the file or
    flip one seeded byte — the two shapes a torn write / bad disk
    leaves behind. Returns a description of what was done."""
    armed = _ACTIVE
    g = rng if rng is not None else (
        armed.plan.rng("media", spec.param) if armed is not None
        else np.random.default_rng(spec.param))
    size = os.path.getsize(path)
    if spec.param % 2:
        keep = max(size // 2, 1)
        with open(path, "r+b") as f:
            f.truncate(keep)
        return f"truncated {size}B→{keep}B"
    off = int(g.integers(0, max(size, 1)))
    with open(path, "r+b") as f:
        f.seek(off)
        byte = f.read(1) or b"\x00"
        f.seek(off)
        f.write(bytes([byte[0] ^ (1 << int(g.integers(0, 8)))]))
    return f"bit-flipped byte {off}/{size}"


def check_finite_risks(risks, where: str = "round",
                       mask=None) -> None:
    """Host-readback detection of poisoned state: +inf risk is the
    ring wire checksum's sentinel (``MRSVMConfig.shuffle_wire_check``),
    NaN means non-finite rows reached a fold. Raises
    :class:`FaultDetected` naming the layer; silent on finite risks."""
    r = np.asarray(risks)
    if mask is not None:
        r = r[np.asarray(mask)]
    if r.size == 0 or bool(np.isfinite(r).all()):
        return
    if bool(np.isinf(r).any()) and not bool(np.isnan(r).any()):
        raise FaultDetected(
            "transport",
            f"+inf empirical risk at {where}: the ring wire checksum "
            "flagged a garbled merge message",
            action="re-run the round from the last checkpoint (the "
            "fault is transient; persistent mismatches mean a bad link)")
    raise FaultDetected(
        "core",
        f"NaN empirical risk at {where}: non-finite feature rows or "
        "labels reached a fold",
        action="quarantine the offending batch (serving does this at "
        "submit()) and restore the last intact snapshot")
