"""Bounded retry with exponential backoff (DESIGN.md §15).

The "survived" arm of the fault contract for transient failures:
coordinator handshakes and checkpoint writes retry a bounded number of
times with deterministic backoff; exhaustion converts the last error
into a typed :class:`~repro.faults.plan.FaultDetected` naming the
layer, the cause and the operator action — never an anonymous
stack trace from deep inside a retry loop.
"""
from __future__ import annotations

import time
from typing import Callable, Optional, Tuple, Type

from repro.faults.plan import FaultDetected, count


def retry_with_backoff(fn: Callable, *, attempts: int = 3,
                       base_s: float = 0.05, factor: float = 2.0,
                       max_s: float = 2.0,
                       retry_on: Tuple[Type[BaseException], ...]
                       = (Exception,),
                       on_retry: Optional[Callable] = None,
                       layer: str = "core", cause: str = "operation",
                       action: Optional[str] = None):
    """Call ``fn()`` up to ``attempts`` times, sleeping
    ``base_s * factor**i`` (capped at ``max_s``) between tries.

    Only ``retry_on`` exceptions are retried — anything else
    propagates immediately (a validation error is not a flaky wire).
    Each retry bumps the process-wide ``retries`` counter and calls
    ``on_retry(attempt_index, exc)`` so services can account for it in
    their throughput reports. Exhaustion raises
    :class:`FaultDetected(layer, cause, action)` chained to the last
    underlying error.
    """
    if attempts < 1:
        raise ValueError(f"attempts must be >= 1, got {attempts}")
    last: Optional[BaseException] = None
    for i in range(attempts):
        try:
            return fn()
        except retry_on as e:          # noqa: PERF203 — bounded loop
            last = e
            if i == attempts - 1:
                break
            count("retries")
            if on_retry is not None:
                on_retry(i, e)
            time.sleep(min(base_s * factor ** i, max_s))
    raise FaultDetected(
        layer, f"{cause} failed after {attempts} attempts: {last}",
        action) from last
