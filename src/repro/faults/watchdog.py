"""Collective watchdog: heartbeat file + deadline thread
(DESIGN.md §15).

A peer that dies mid-wave strands every survivor inside the merge
collective — Python cannot interrupt a thread blocked in a C/gloo
collective, so in-process recovery is impossible by construction. What
CAN be guaranteed is that the hang becomes a *typed, observable*
event: the watchdog thread watches a deadline between ``beat()``
calls, keeps a JSON heartbeat file an operator (or the chaos harness)
can poll, and on expiry writes the diagnosis — layer, cause, elapsed,
restart instruction — then hands off to the timeout handler. The
default handler exits the process with :data:`WATCHDOG_EXIT_CODE`
(the supervisor's restart-from-checkpoint signal); tests and the
chaos harness install recording handlers and use :meth:`check` to
turn a fired deadline into a :class:`FaultDetected`.
"""
from __future__ import annotations

import json
import os
import sys
import threading
import time
from typing import Callable, Optional

from repro.faults.plan import FaultDetected, count

# Exit status of a watchdog-killed process: distinct from crash (!=1)
# and from SIGKILL (negative in waitpid terms), so a supervisor can
# tell "stranded in a collective, restart me from the checkpoint"
# apart from every other death.
WATCHDOG_EXIT_CODE = 17

_DEFAULT_ACTION = ("the process is stranded in a collective — kill it "
                   "and restart from the last checkpoint generation")


def exit_handler(info: dict) -> None:
    """Default timeout handler: print the typed diagnosis and exit
    with :data:`WATCHDOG_EXIT_CODE`. ``os._exit`` on purpose — the
    stranded collective would block any orderly interpreter teardown."""
    print(f"FaultDetected[{info['layer']}]: {info['cause']} exceeded "
          f"its {info['deadline_s']}s deadline — {info['action']}",
          file=sys.stderr, flush=True)
    os._exit(WATCHDOG_EXIT_CODE)


class CollectiveWatchdog:
    """Deadline thread + heartbeat file around a blocking section.

    Usage::

        with CollectiveWatchdog(30, heartbeat_path=hb,
                                cause="wave 7 merge") as wd:
            for t in rounds:
                run_round()      # may strand forever on peer loss
                wd.beat()        # resets the deadline, stamps the file
        wd.check()               # record-mode: raise if it fired
    """

    def __init__(self, deadline_s: float,
                 heartbeat_path: Optional[str] = None,
                 layer: str = "transport",
                 cause: str = "collective",
                 action: Optional[str] = None,
                 on_timeout: Optional[Callable[[dict], None]] = None,
                 poll_s: Optional[float] = None):
        if deadline_s <= 0:
            raise ValueError(f"deadline_s must be > 0, got {deadline_s}")
        self.deadline_s = float(deadline_s)
        self.heartbeat_path = heartbeat_path
        self.layer, self.cause = layer, cause
        self.action = action or _DEFAULT_ACTION
        self._on_timeout = on_timeout if on_timeout is not None \
            else exit_handler
        self._poll_s = poll_s if poll_s is not None \
            else max(min(deadline_s / 4.0, 0.5), 0.01)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._last = 0.0
        self._beats = 0
        self._fired = False
        self._info: Optional[dict] = None

    # -- heartbeat file (atomic, self-contained: no ckpt import) -----------

    def _write(self, status: str, **extra) -> None:
        if self.heartbeat_path is None:
            return
        payload = {"status": status, "layer": self.layer,
                   "cause": self.cause, "beats": self._beats,
                   "deadline_s": self.deadline_s, "ts": time.time(),
                   **extra}
        tmp = self.heartbeat_path + ".tmp"
        try:
            with open(tmp, "w") as f:
                json.dump(payload, f)
            os.replace(tmp, self.heartbeat_path)
        except OSError:
            pass                       # a failing heartbeat disk must
            #                            never take the workload down

    # -- lifecycle ---------------------------------------------------------

    def __enter__(self) -> "CollectiveWatchdog":
        self._last = time.monotonic()
        self._stop.clear()
        self._write("alive")
        self._thread = threading.Thread(
            target=self._loop, name="collective-watchdog", daemon=True)
        self._thread.start()
        return self

    def __exit__(self, *exc) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=max(self._poll_s * 4, 1.0))
            self._thread = None

    def beat(self) -> None:
        """Progress proof: resets the deadline, stamps the heartbeat."""
        self._last = time.monotonic()
        self._beats += 1
        self._write("alive")

    def _loop(self) -> None:
        while not self._stop.wait(self._poll_s):
            elapsed = time.monotonic() - self._last
            if elapsed <= self.deadline_s:
                continue
            self._fired = True
            count("watchdog_fires")
            self._info = {"layer": self.layer, "cause": self.cause,
                          "deadline_s": self.deadline_s,
                          "elapsed_s": round(elapsed, 3),
                          "action": self.action}
            self._write("timeout", **self._info)
            self._on_timeout(self._info)
            return

    # -- record-mode surface -----------------------------------------------

    @property
    def fired(self) -> bool:
        return self._fired

    @property
    def info(self) -> Optional[dict]:
        return self._info

    def check(self) -> None:
        """Raise the typed timeout if the deadline fired (for handlers
        that record instead of exiting)."""
        if self._fired:
            raise FaultDetected(
                self.layer,
                f"{self.cause} exceeded its {self.deadline_s}s "
                "watchdog deadline", self.action)
