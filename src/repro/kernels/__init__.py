"""Pallas TPU kernels for the paper's compute hot spots + serving.

Each kernel ships: <name>.py (pl.pallas_call + BlockSpec), an oracle in
ref.py, a wrapper in ops.py, and a shape/dtype sweep in tests/.
"""
from repro.kernels.ops import (decode_attention, gram_matrix,
                               risk_eval, svm_cd_epoch)
