"""Pallas TPU kernel: flash-decode single-token GQA attention.

The serving hot path (decode_32k / long_500k) is one query token
against a deep KV cache — memory-bound streaming of K/V. This kernel
tiles the cache's sequence axis; each grid step loads a (bs, hd) K/V
block into VMEM and maintains the online-softmax running (max, sum,
acc) in the output block, so the (S,) score row never materializes in
HBM. Beyond-paper: the jnp path materializes (B, H, S) scores.

Grid: (B, KV, S/bs). Blocks: q (G, hd); k/v (bs, hd);
out (G, hd) f32 accumulated in-place + (G, 1) running max/sum buffers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _flash_decode_kernel(q_ref, k_ref, v_ref, vlen_ref, o_ref, m_ref, l_ref,
                         *, s_steps: int, bs: int, scale: float):
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale         # (G, hd)
    k = k_ref[0, 0].astype(jnp.float32)                 # (bs, hd)
    v = v_ref[0, 0].astype(jnp.float32)                 # (bs, hd)
    scores = jax.lax.dot_general(                       # (G, bs)
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    base = s_idx * bs
    pos = base + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    scores = jnp.where(pos < vlen_ref[0, 0], scores, -1e30)

    m_prev = m_ref[0, 0]                                # (G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=1, keepdims=True))
    p = jnp.exp(scores - m_new)                         # (G, bs)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[0, 0] = l_ref[0, 0] * alpha + jnp.sum(p, axis=1, keepdims=True)
    o_ref[0, 0] = o_ref[0, 0] * alpha + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[0, 0] = m_new

    @pl.when(s_idx == s_steps - 1)
    def _final():
        o_ref[0, 0] = o_ref[0, 0] / jnp.maximum(l_ref[0, 0], 1e-30)


@functools.partial(jax.jit, static_argnames=("bs", "interpret"))
def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                 valid_len: jax.Array, *, bs: int = 512,
                 interpret: bool = True) -> jax.Array:
    """q (B, H, hd); k/v (B, KV, S, hd); valid_len () → (B, H, hd)."""
    B, H, hd = q.shape
    KV, S = k.shape[1], k.shape[2]
    G = H // KV
    bs_ = min(bs, S)
    assert S % bs_ == 0, f"cache len {S} must divide block {bs_}"
    s_steps = S // bs_
    qg = q.reshape(B, KV, G, hd)
    vlen = jnp.broadcast_to(valid_len.astype(jnp.int32), (1, 1))

    out, m, l = pl.pallas_call(
        functools.partial(_flash_decode_kernel, s_steps=s_steps, bs=bs_,
                          scale=1.0 / (hd ** 0.5)),
        grid=(B, KV, s_steps),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bs_, hd), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1, bs_, hd), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1), lambda b, h, s: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, G, 1), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, G, 1), lambda b, h, s: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, KV, G, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, KV, G, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, KV, G, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k, v, vlen)
    return out.reshape(B, H, hd).astype(q.dtype)
