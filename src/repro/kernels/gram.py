"""Pallas TPU kernel: tiled Gram matrix K = k(X, Zᵀ).

The kernel-SVM reducer's dominant cost is the (n × n) Gram matrix
(paper: O(m²) space is *why* MapReduce partitioning exists). On TPU we
tile it for the MXU: grid over (n/bm, m/bn, d/bk) with (bm, bk)×(bk, bn)
VMEM blocks accumulating into a float32 (bm, bn) output block; the
kernel transform (rbf/poly) is fused into the last k-step so K never
round-trips to HBM in raw dot-product form.

``gamma``/``coef0`` are TRACED scalar operands, not trace-time
constants: they ride in as (1, 1) blocks (the SMEM scalar-input
pattern), so a :class:`~repro.core.svm.SolverParams` sweep over kernel
scales reuses ONE compiled kernel — and the sweep subsystem's
vmap-over-configs batches straight through the pallas_call. Only the
operator choice stays static: ``kind`` picks the fused transform and
``degree`` must be an integer exponent (a traced float ``pow`` would
NaN on negative bases).

Block shapes default to 256×256×512 — MXU-aligned (multiples of 128)
and ≤ ~1.3 MB/input block, comfortably inside the ~16 MB/core VMEM
budget with double buffering.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram_kernel(gamma_ref, coef0_ref, x_ref, z_ref, rownorm_ref,
                 colnorm_ref, o_ref, *, kind: str, degree: int,
                 k_steps: int):
    """One (bm, bn) output tile; grid dim 2 walks the shared d axis."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)          # (bm, bk)
    z = z_ref[...].astype(jnp.float32)          # (bn, bk)
    o_ref[...] += jax.lax.dot_general(
        x, z, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _finalize():
        acc = o_ref[...]
        gamma = gamma_ref[0, 0]
        coef0 = coef0_ref[0, 0]
        if kind == "poly":
            o_ref[...] = (gamma * acc + coef0) ** degree
        elif kind == "rbf":
            sq = rownorm_ref[...].T + colnorm_ref[...] - 2.0 * acc
            o_ref[...] = jnp.exp(-gamma * jnp.maximum(sq, 0.0))
        # linear: accumulator already is K


@functools.partial(jax.jit, static_argnames=("kind", "degree", "bm", "bn",
                                             "bk", "interpret"))
def gram(X: jax.Array, Z: jax.Array, gamma=1.0, coef0=0.0, *,
         kind: str = "linear", degree: int = 3,
         bm: int = 256, bn: int = 256, bk: int = 512,
         interpret: bool = True) -> jax.Array:
    """K (n, m) = k(X (n, d), Z (m, d)). Pads to block multiples.

    ``gamma``/``coef0`` may be Python floats or traced scalars — they
    are operands of the compiled kernel either way.
    """
    n, d = X.shape
    m = Z.shape[0]
    bm_, bn_, bk_ = min(bm, _ceil(n)), min(bn, _ceil(m)), min(bk, _ceil(d))
    n_p, m_p, d_p = _pad_to(n, bm_), _pad_to(m, bn_), _pad_to(d, bk_)
    Xp = jnp.pad(X, ((0, n_p - n), (0, d_p - d)))
    Zp = jnp.pad(Z, ((0, m_p - m), (0, d_p - d)))
    rown = jnp.sum(Xp.astype(jnp.float32) ** 2, axis=1, keepdims=True)  # (n,1)
    coln = jnp.sum(Zp.astype(jnp.float32) ** 2, axis=1, keepdims=True).T
    g = jnp.asarray(gamma, jnp.float32).reshape(1, 1)
    c0 = jnp.asarray(coef0, jnp.float32).reshape(1, 1)

    k_steps = d_p // bk_
    scalar = pl.BlockSpec((1, 1), lambda i, j, k: (0, 0))
    out = pl.pallas_call(
        functools.partial(_gram_kernel, kind=kind, degree=degree,
                          k_steps=k_steps),
        grid=(n_p // bm_, m_p // bn_, k_steps),
        in_specs=[
            scalar,
            scalar,
            pl.BlockSpec((bm_, bk_), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn_, bk_), lambda i, j, k: (j, k)),
            pl.BlockSpec((1, bm_), lambda i, j, k: (0, i)),
            pl.BlockSpec((1, bn_), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_p, m_p), jnp.float32),
        interpret=interpret,
    )(g, c0, Xp, Zp, rown.T, coln)
    return out[:n, :m]


def _ceil(x: int, to: int = 128) -> int:
    return max(to, (x + to - 1) // to * to)


def _pad_to(x: int, block: int) -> int:
    return (x + block - 1) // block * block
