"""Pallas TPU kernel: tiled Gram matrix K = k(X, Zᵀ).

The kernel-SVM reducer's dominant cost is the (n × n) Gram matrix
(paper: O(m²) space is *why* MapReduce partitioning exists). On TPU we
tile it for the MXU: grid over (n/bm, m/bn, d/bk) with (bm, bk)×(bk, bn)
VMEM blocks accumulating into a float32 (bm, bn) output block; the
kernel transform (rbf/poly) is fused into the last k-step so K never
round-trips to HBM in raw dot-product form.

``gamma``/``coef0`` are TRACED scalar operands, not trace-time
constants: they ride in as (1, 1) blocks (the SMEM scalar-input
pattern), so a :class:`~repro.core.svm.SolverParams` sweep over kernel
scales reuses ONE compiled kernel — and the sweep subsystem's
vmap-over-configs batches straight through the pallas_call. Only the
operator choice stays static: ``kind`` picks the fused transform and
``degree`` must be an integer exponent (a traced float ``pow`` would
NaN on negative bases).

Block shapes default to 256×256×512 — MXU-aligned (multiples of 128)
and ≤ ~1.3 MB/input block, comfortably inside the ~16 MB/core VMEM
budget with double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram_kernel(gamma_ref, coef0_ref, x_ref, z_ref, rownorm_ref,
                 colnorm_ref, o_ref, *, kind: str, degree: int,
                 k_steps: int):
    """One (bm, bn) output tile; grid dim 2 walks the shared d axis."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)          # (bm, bk)
    z = z_ref[...].astype(jnp.float32)          # (bn, bk)
    o_ref[...] += jax.lax.dot_general(
        x, z, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _finalize():
        acc = o_ref[...]
        gamma = gamma_ref[0, 0]
        coef0 = coef0_ref[0, 0]
        if kind == "poly":
            o_ref[...] = (gamma * acc + coef0) ** degree
        elif kind == "rbf":
            sq = rownorm_ref[...].T + colnorm_ref[...] - 2.0 * acc
            o_ref[...] = jnp.exp(-gamma * jnp.maximum(sq, 0.0))
        # linear: accumulator already is K


@functools.partial(jax.jit, static_argnames=("kind", "degree", "bm", "bn",
                                             "bk", "interpret"))
def gram(X: jax.Array, Z: jax.Array, gamma=1.0, coef0=0.0, *,
         kind: str = "linear", degree: int = 3,
         bm: int = 256, bn: int = 256, bk: int = 512,
         interpret: bool = True) -> jax.Array:
    """K (n, m) = k(X (n, d), Z (m, d)). Pads to block multiples.

    ``gamma``/``coef0`` may be Python floats or traced scalars — they
    are operands of the compiled kernel either way.
    """
    n, d = X.shape
    m = Z.shape[0]
    bm_, bn_, bk_ = min(bm, _ceil(n)), min(bn, _ceil(m)), min(bk, _ceil(d))
    n_p, m_p, d_p = _pad_to(n, bm_), _pad_to(m, bn_), _pad_to(d, bk_)
    Xp = jnp.pad(X, ((0, n_p - n), (0, d_p - d)))
    Zp = jnp.pad(Z, ((0, m_p - m), (0, d_p - d)))
    rown = jnp.sum(Xp.astype(jnp.float32) ** 2, axis=1, keepdims=True)  # (n,1)
    coln = jnp.sum(Zp.astype(jnp.float32) ** 2, axis=1, keepdims=True).T
    g = jnp.asarray(gamma, jnp.float32).reshape(1, 1)
    c0 = jnp.asarray(coef0, jnp.float32).reshape(1, 1)

    k_steps = d_p // bk_
    scalar = pl.BlockSpec((1, 1), lambda i, j, k: (0, 0))
    out = pl.pallas_call(
        functools.partial(_gram_kernel, kind=kind, degree=degree,
                          k_steps=k_steps),
        grid=(n_p // bm_, m_p // bn_, k_steps),
        in_specs=[
            scalar,
            scalar,
            pl.BlockSpec((bm_, bk_), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn_, bk_), lambda i, j, k: (j, k)),
            pl.BlockSpec((1, bm_), lambda i, j, k: (0, i)),
            pl.BlockSpec((1, bn_), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_p, m_p), jnp.float32),
        interpret=interpret,
    )(g, c0, Xp, Zp, rown.T, coln)
    return out[:n, :m]


def _ceil(x: int, to: int = 128) -> int:
    return max(to, (x + to - 1) // to * to)


def _pad_to(x: int, block: int) -> int:
    return (x + block - 1) // block * block


# ---------------------------------------------------------------------------
# Sparse Gram: blocked-CSR rows (ISSUE 6, gram_impl="pallas_sparse").
# ---------------------------------------------------------------------------

def _sparse_gram_kernel(gamma_ref, coef0_ref, xi_ref, xv_ref, zi_ref,
                        zv_ref, rownorm_ref, colnorm_ref, o_ref, *,
                        kind: str, degree: int, z_slots: int):
    """One (bm, bn) tile from index/value blocks (no dense (·, d) tile
    ever exists). The contraction is an index-match accumulate: for
    each z-side slot q, the x-side slots whose column id equals
    ``zi[:, q]`` contribute ``xv · zv[:, q]``. Padding slots are
    (index 0, value 0) on BOTH sides, so every spurious 0==0 match
    multiplies a zero value — contributions vanish without masking.
    O(bm·bn·px·pz) compare-work replaces O(bm·bn·d) dense MACs: a win
    whenever nnz_cap² ≪ d (the >99%-zero TF×IDF regime this kernel
    exists for)."""
    xi = xi_ref[...]                              # (bm, px) int32
    xv = xv_ref[...].astype(jnp.float32)          # (bm, px)
    zi = zi_ref[...]                              # (bn, pz) int32
    zv = zv_ref[...].astype(jnp.float32)          # (bn, pz)

    def match_step(q, acc):
        zq = jax.lax.dynamic_index_in_dim(zi, q, axis=1, keepdims=False)
        vq = jax.lax.dynamic_index_in_dim(zv, q, axis=1, keepdims=False)
        hit = xi[:, :, None] == zq[None, None, :]        # (bm, px, bn)
        part = jnp.sum(jnp.where(hit, xv[:, :, None], 0.0), axis=1)
        return acc + part * vq[None, :]

    acc = jax.lax.fori_loop(
        0, z_slots, match_step,
        jnp.zeros(o_ref.shape, jnp.float32))

    gamma = gamma_ref[0, 0]
    coef0 = coef0_ref[0, 0]
    if kind == "poly":
        o_ref[...] = (gamma * acc + coef0) ** degree
    elif kind == "rbf":
        sq = rownorm_ref[...].T + colnorm_ref[...] - 2.0 * acc
        o_ref[...] = jnp.exp(-gamma * jnp.maximum(sq, 0.0))
    else:
        o_ref[...] = acc


def _pad_sparse(sp, n_p: int):
    pad = n_p - sp.values.shape[0]
    return (jnp.pad(sp.indices, ((0, pad), (0, 0))),
            jnp.pad(sp.values, ((0, pad), (0, 0))))


@functools.partial(jax.jit, static_argnames=("kind", "degree", "bm", "bn",
                                             "interpret"))
def sparse_gram(X, Z, gamma=1.0, coef0=0.0, *, kind: str = "linear",
                degree: int = 3, bm: int = 128, bn: int = 128,
                interpret: bool = True) -> jax.Array:
    """K (n, m) = k(X, Z) over blocked-CSR rows (``SparseRows``).

    Both-sparse runs the Pallas index-match kernel tiled (n/bm, m/bn)
    with each side's full (index, value) slot axis resident per tile
    (keep ``nnz_cap`` ≲ 512 for VMEM); ``gamma``/``coef0`` ride in as
    traced (1, 1) scalar blocks exactly like the dense kernel, so
    SolverParams sweeps share one compiled kernel. Mixed dense×sparse
    (the serve-side decision path: dense query rows against the sparse
    SV buffer) routes through the XLA gather contraction of
    :mod:`repro.sparse` with the same fused transforms — there is no
    dense (·, d) tile a Pallas block could hold at 100k+ features.
    """
    from repro import sparse as sparse_rows

    if not (sparse_rows.is_sparse(X) and sparse_rows.is_sparse(Z)):
        dots = sparse_rows.cross_dots(X, Z).astype(jnp.float32)
        g = jnp.asarray(gamma, jnp.float32)
        c0 = jnp.asarray(coef0, jnp.float32)
        if kind == "poly":
            return (g * dots + c0) ** degree
        if kind == "rbf":
            xx = sparse_rows.row_sq_norms(X).astype(jnp.float32)[:, None]
            zz = sparse_rows.row_sq_norms(Z).astype(jnp.float32)[None, :]
            return jnp.exp(-g * jnp.maximum(xx + zz - 2.0 * dots, 0.0))
        return dots
    n, m = X.values.shape[0], Z.values.shape[0]
    bm_, bn_ = min(bm, _ceil(n)), min(bn, _ceil(m))
    n_p, m_p = _pad_to(n, bm_), _pad_to(m, bn_)
    xi, xv = _pad_sparse(X, n_p)
    zi, zv = _pad_sparse(Z, m_p)
    rown = jnp.sum(xv.astype(jnp.float32) ** 2, axis=1, keepdims=True)
    coln = jnp.sum(zv.astype(jnp.float32) ** 2, axis=1, keepdims=True).T
    g = jnp.asarray(gamma, jnp.float32).reshape(1, 1)
    c0 = jnp.asarray(coef0, jnp.float32).reshape(1, 1)
    px, pz = xi.shape[1], zi.shape[1]

    scalar = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    out = pl.pallas_call(
        functools.partial(_sparse_gram_kernel, kind=kind, degree=degree,
                          z_slots=pz),
        grid=(n_p // bm_, m_p // bn_),
        in_specs=[
            scalar,
            scalar,
            pl.BlockSpec((bm_, px), lambda i, j: (i, 0)),
            pl.BlockSpec((bm_, px), lambda i, j: (i, 0)),
            pl.BlockSpec((bn_, pz), lambda i, j: (j, 0)),
            pl.BlockSpec((bn_, pz), lambda i, j: (j, 0)),
            pl.BlockSpec((1, bm_), lambda i, j: (0, i)),
            pl.BlockSpec((1, bn_), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_p, m_p), jnp.float32),
        interpret=interpret,
    )(g, c0, xi, xv, zi, zv, rown.T, coln)
    return out[:n, :m]
