"""Pallas TPU kernel: fused hypothesis scoring + hinge-risk reduction.

The MapReduce-SVM driver evaluates EVERY reducer hypothesis on the
full dataset each round (paper eq. 6-7) — an (n, d) × (d, L) matmul
followed by hinge loss and a masked reduction. Unfused, the (n, L)
score matrix round-trips HBM; this kernel keeps each (bn, L) score
tile in VMEM, applies the hinge, and accumulates the per-hypothesis
partial sums in-place.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hinge_kernel(x_ref, w_ref, b_ref, y_ref, m_ref, loss_ref, cnt_ref, *,
                  n_steps: int):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        loss_ref[...] = jnp.zeros_like(loss_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    x = x_ref[...].astype(jnp.float32)           # (bn, d)
    w = w_ref[...].astype(jnp.float32)           # (L, d)
    scores = jax.lax.dot_general(                # (bn, L)
        x, w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) + b_ref[...]
    y = y_ref[...].astype(jnp.float32)           # (1, bn)
    m = m_ref[...].astype(jnp.float32)
    hinge = jnp.maximum(0.0, 1.0 - y.T * scores) * m.T
    loss_ref[...] += jnp.sum(hinge, axis=0, keepdims=True)
    cnt_ref[...] += jnp.sum(m, axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def hinge_scores(X: jax.Array, W: jax.Array, b: jax.Array, y: jax.Array,
                 mask: jax.Array, *, bn: int = 1024,
                 interpret: bool = True):
    """→ (losses (L,), count ()). X (n,d), W (L,d), b (L,)."""
    n, d = X.shape
    L = W.shape[0]
    bn_ = min(bn, max(128, (n + 127) // 128 * 128))
    n_p = (n + bn_ - 1) // bn_ * bn_
    Xp = jnp.pad(X, ((0, n_p - n), (0, 0)))
    yp = jnp.pad(y, (0, n_p - n))[None, :]
    mp = jnp.pad(mask, (0, n_p - n))[None, :]
    n_steps = n_p // bn_

    loss, cnt = pl.pallas_call(
        functools.partial(_hinge_kernel, n_steps=n_steps),
        grid=(n_steps,),
        in_specs=[
            pl.BlockSpec((bn_, d), lambda i: (i, 0)),
            pl.BlockSpec((L, d), lambda i: (0, 0)),
            pl.BlockSpec((1, L), lambda i: (0, 0)),
            pl.BlockSpec((1, bn_), lambda i: (0, i)),
            pl.BlockSpec((1, bn_), lambda i: (0, i)),
        ],
        out_specs=[pl.BlockSpec((1, L), lambda i: (0, 0)),
                   pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((1, L), jnp.float32),
                   jax.ShapeDtypeStruct((1, 1), jnp.float32)],
        interpret=interpret,
    )(Xp, W, b[None, :], yp, mp)
    return loss[0], cnt[0, 0]
