"""Public jit'd wrappers for the Pallas kernels.

``interpret=True`` (default here) executes the kernel body in Python on
CPU — the validation mode for this container; on real TPU hardware pass
``interpret=False`` (the launcher does, keyed on backend).
"""
from __future__ import annotations

import jax

from repro.kernels.gram import gram, sparse_gram
from repro.kernels.hinge_score import hinge_scores
from repro.kernels.decode_attention import flash_decode
from repro.kernels.svm_step import cd_epoch


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def gram_matrix(X, Z, kind="linear", **kw):
    """Tiled Gram matrix; drop-in ``gram_fn`` for core.svm.fit_binary."""
    kw.setdefault("interpret", not on_tpu())
    return gram(X, Z, kind=kind, **kw)


def sparse_gram_matrix(X, Z, kind="linear", **kw):
    """Blocked-CSR Gram matrix (gram_impl="pallas_sparse")."""
    kw.setdefault("interpret", not on_tpu())
    return sparse_gram(X, Z, kind=kind, **kw)


def risk_eval(X, W, b, y, mask, **kw):
    """Fused hinge risk of L hypotheses; → (losses (L,), count ())."""
    kw.setdefault("interpret", not on_tpu())
    return hinge_scores(X, W, b, y, mask, **kw)


def decode_attention(q, k, v, valid_len, **kw):
    """Flash-decode attention for the serving path."""
    kw.setdefault("interpret", not on_tpu())
    return flash_decode(q, k, v, valid_len, **kw)


def svm_cd_epoch(X, y, alpha, w, b, mask, C=1.0, **kw):
    """VMEM-resident dual-CD epoch (the paper's reducer hot loop)."""
    kw.setdefault("interpret", not on_tpu())
    return cd_epoch(X, y, alpha, w, b, mask, C=C, **kw)
