"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gram_ref(X: jax.Array, Z: jax.Array, kind: str = "linear",
             gamma: float = 1.0, coef0: float = 0.0,
             degree: int = 3) -> jax.Array:
    """K = k(X, Z): (n, d) × (m, d) → (n, m)."""
    G = X @ Z.T
    if kind == "linear":
        return G
    if kind == "poly":
        return (gamma * G + coef0) ** degree
    if kind == "rbf":
        xx = jnp.sum(X * X, axis=-1, keepdims=True)
        zz = jnp.sum(Z * Z, axis=-1, keepdims=True)
        return jnp.exp(-gamma * jnp.maximum(xx + zz.T - 2.0 * G, 0.0))
    raise ValueError(kind)


def sparse_gram_ref(X, Z, kind: str = "linear", gamma: float = 1.0,
                    coef0: float = 0.0, degree: int = 3) -> jax.Array:
    """K = k(X, Z) for blocked-CSR ``SparseRows`` operands.

    The XLA oracle for :func:`repro.kernels.gram.sparse_gram`: dots via
    the segment-sum gather contraction (scatter-densify small Z chunks,
    gather at X's column ids), never a full (n, d) densify. Either
    operand may also be dense — mixed pairs take the same path.
    """
    from repro import sparse as sparse_rows

    dots = sparse_rows.cross_dots(X, Z).astype(jnp.float32)
    if kind == "linear":
        return dots
    if kind == "poly":
        return (gamma * dots + coef0) ** degree
    if kind == "rbf":
        xx = sparse_rows.row_sq_norms(X).astype(jnp.float32)[:, None]
        zz = sparse_rows.row_sq_norms(Z).astype(jnp.float32)[None, :]
        return jnp.exp(-gamma * jnp.maximum(xx + zz - 2.0 * dots, 0.0))
    raise ValueError(kind)


def hinge_scores_ref(X: jax.Array, W: jax.Array, b: jax.Array,
                     y: jax.Array, mask: jax.Array):
    """Fused risk evaluation (paper eq. 6/7 hot path).

    X (n, d), W (L, d), b (L,), y (n,), mask (n,) →
      losses (L,): Σ_i mask_i · max(0, 1 − y_i·(x_i·w_l + b_l))
      counts (): Σ mask
    """
    scores = X @ W.T + b[None, :]
    hinge = jnp.maximum(0.0, 1.0 - y[:, None] * scores)
    return jnp.sum(hinge * mask[:, None], axis=0), jnp.sum(mask)


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         valid_len: jax.Array) -> jax.Array:
    """Single-token GQA decode attention.

    q (B, H, hd), k/v (B, KV, S, hd), valid_len () → out (B, H, hd).
    Positions ≥ valid_len are masked.
    """
    B, H, hd = q.shape
    KV, S = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, hd)
    scores = jnp.einsum("bkgh,bkth->bkgt", qg, k) / jnp.sqrt(hd)
    pos = jnp.arange(S)
    scores = jnp.where(pos[None, None, None, :] < valid_len,
                       scores, -1e30)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bkgt,bkth->bkgh", probs.astype(v.dtype), v)
    return out.reshape(B, H, hd)


def cd_epoch_ref(X, W_unused=None, *, alpha, w, b, y, mask, C=1.0):
    """Sequential dual-CD epoch — mirrors core.svm.fit_binary_linear."""
    import numpy as np
    Xn = np.asarray(X, np.float32)
    a = np.asarray(alpha, np.float32).copy()
    wv = np.asarray(w, np.float32).copy()
    bv = float(b)
    yn = np.asarray(y, np.float32)
    mn = np.asarray(mask, np.float32)
    q = (Xn * Xn).sum(1) + 1.0
    q = np.where(mn > 0, q, 1.0)
    for i in range(Xn.shape[0]):
        g = yn[i] * (wv @ Xn[i] + bv) - 1.0
        a_new = min(max(a[i] - g / q[i], 0.0), C)
        delta = (a_new - a[i]) * mn[i]
        a[i] += delta
        wv += delta * yn[i] * Xn[i]
        bv += delta * yn[i]
    return a, wv, bv
