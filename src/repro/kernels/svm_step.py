"""Pallas TPU kernel: one dual coordinate-descent epoch over row tiles.

The reducer's inner loop (Hsieh et al. dual CD) is sequential in rows:
    g_i  = y_i·(w·x_i + b) − 1
    α_i ← clip(α_i − g_i/Q_ii, 0, C);  w += Δα·y_i·x_i;  b += Δα·y_i

The HLO version round-trips w through HBM on every row
(dynamic-slice/update chains). This kernel keeps (w, b) resident in
VMEM for the WHOLE epoch — the sequential TPU grid walks (bn, d) row
tiles, the α block streams per tile, and the row recurrence is a
fori_loop over VMEM-resident data.

VMEM budget: w (d ≤ 16k f32 = 64 KB) + X tile (256×4096×4 = 4 MB) —
comfortably inside ~16 MB/core with double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cd_epoch_kernel(x_ref, y_ref, qdiag_ref, m_ref, a_in_ref, w_in_ref,
                     b_in_ref, alpha_ref, w_ref, b_ref, *, C: float,
                     bn: int):
    @pl.when(pl.program_id(0) == 0)
    def _init_state():
        w_ref[...] = w_in_ref[...]          # persistent across the grid
        b_ref[...] = b_in_ref[...]

    alpha_t = a_in_ref[...]                 # this tile's α slice
    x = x_ref[...].astype(jnp.float32)      # (bn, d)
    y = y_ref[...].astype(jnp.float32)      # (1, bn)
    q = qdiag_ref[...]
    m = m_ref[...]

    def row(i, carry):
        alpha_t, w, b = carry               # (1,bn), (1,d), (1,1)
        xi = x[i, :][None, :]
        yi = y[0, i]
        g = yi * (jnp.sum(w * xi) + b[0, 0]) - 1.0
        a_old = alpha_t[0, i]
        a_new = jnp.clip(a_old - g / q[0, i], 0.0, C)
        delta = (a_new - a_old) * m[0, i]
        alpha_t = alpha_t.at[0, i].set(a_old + delta)
        w = w + delta * yi * xi
        b = b.at[0, 0].add(delta * yi)
        return alpha_t, w, b

    alpha_t, w, b = jax.lax.fori_loop(
        0, bn, row, (alpha_t, w_ref[...], b_ref[...]))
    alpha_ref[...] = alpha_t
    w_ref[...] = w
    b_ref[...] = b


@functools.partial(jax.jit, static_argnames=("C", "bn", "interpret"))
def cd_epoch(X: jax.Array, y: jax.Array, alpha: jax.Array, w: jax.Array,
             b: jax.Array, mask: jax.Array, *, C: float = 1.0,
             bn: int = 256, interpret: bool = True):
    """One full CD epoch; → (alpha, w, b) updated.

    Matches core.svm.fit_binary_linear's epoch body exactly (same
    update order, Q_ii = ||x_i||² + 1 regularized-bias convention).
    """
    n, d = X.shape
    bn_ = min(bn, n)
    n_p = (n + bn_ - 1) // bn_ * bn_
    Xp = jnp.pad(X, ((0, n_p - n), (0, 0)))
    yp = jnp.pad(y, (0, n_p - n))[None, :].astype(jnp.float32)
    mp = jnp.pad(mask, (0, n_p - n))[None, :].astype(jnp.float32)
    qdiag = (jnp.einsum("nd,nd->n", Xp, Xp,
                        preferred_element_type=jnp.float32) + 1.0)
    qdiag = jnp.where(mp[0] > 0, qdiag, 1.0)[None, :]
    ap = jnp.pad(alpha, (0, n_p - n))[None, :].astype(jnp.float32)
    w0 = w[None, :].astype(jnp.float32)
    b0 = jnp.reshape(b, (1, 1)).astype(jnp.float32)

    alpha_o, w_o, b_o = pl.pallas_call(
        functools.partial(_cd_epoch_kernel, C=C, bn=bn_),
        grid=(n_p // bn_,),
        in_specs=[
            pl.BlockSpec((bn_, d), lambda i: (i, 0)),
            pl.BlockSpec((1, bn_), lambda i: (0, i)),
            pl.BlockSpec((1, bn_), lambda i: (0, i)),
            pl.BlockSpec((1, bn_), lambda i: (0, i)),
            pl.BlockSpec((1, bn_), lambda i: (0, i)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bn_), lambda i: (0, i)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),   # persistent state
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, n_p), jnp.float32),
            jax.ShapeDtypeStruct((1, d), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(Xp, yp, qdiag, mp, ap, w0, b0)
    return alpha_o[0, :n], w_o[0], b_o[0, 0]
