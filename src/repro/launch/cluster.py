"""Cluster runtime: the process-count-agnostic multi-host substrate
(DESIGN.md §11).

The paper's premise is that ONE machine cannot hold the quadratic SVM
training problem — training is distributed across nodes and only
support vectors travel (Çatak 2014; CloudSVM arXiv:1301.0082). Every
layer above this module is written against the *global* topology this
module reports, so the same program runs unchanged on one process
(laptop / CI), N CPU processes (``examples/multihost_svm.py``,
``make test-dist-mp``), or a real multi-host TPU slice:

  init_cluster()      — wraps ``jax.distributed.initialize`` (explicit
                        --coordinator/--num-processes/--process-id
                        flags, env auto-detect, 1-process fast path
                        that never opens a coordinator);
  Cluster             — topology handle: process index/count, local vs
                        global devices, coordinator gating;
  make_global_array() — assembles each process's local numpy shard
                        into a globally-sharded ``jax.Array``
                        (``jax.make_array_from_process_local_data``
                        with a ``from_single_device_arrays`` fallback
                        behind :mod:`repro.compat`).

Ordering contract: ``init_cluster`` MUST run before the first use of
the jax backend in the process (``jax.devices()``, any op). The
distributed client and the CPU gloo collectives are wired into the
backend at its first initialization, so the entry points in
``launch/{train,serve}.py`` parse flags and call this before anything
else touches a device.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Optional, Sequence

from repro import compat, faults

# One process-wide runtime: jax.distributed can only initialize once,
# so repeated init_cluster() calls return the same handle.
_CLUSTER: Optional["Cluster"] = None


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    """How to join (or not join) a multi-process cluster.

    All ``None`` → single process, unless the ``REPRO_COORDINATOR`` /
    ``REPRO_NUM_PROCESSES`` / ``REPRO_PROCESS_ID`` environment
    variables (or their ``JAX_``-prefixed spellings) supply the triple
    — the env auto-detect path for launchers that template per-process
    env instead of argv.
    """
    coordinator: Optional[str] = None      # "host:port" of process 0
    num_processes: Optional[int] = None
    process_id: Optional[int] = None
    # Faked host devices for multi-process CPU runs; set BEFORE backend
    # init (XLA locks the per-process device count at first use).
    local_device_count: Optional[int] = None
    cpu_collectives: str = "gloo"
    initialization_timeout: int = 120      # s; bounds a dead-peer hang
    # Coordinator handshake retry (DESIGN.md §15): a restarted process
    # often races the coordinator coming back up; a bounded
    # retry-with-backoff turns that window into a survived transient
    # instead of a launch failure.
    handshake_retries: int = 3
    handshake_backoff_s: float = 0.5

    def resolved(self) -> "ClusterConfig":
        """Fill unset fields from the environment (explicit args win)."""
        def env(*names):
            for n in names:
                v = os.environ.get(n)
                if v:
                    return v
            return None

        coord = self.coordinator or env("REPRO_COORDINATOR",
                                        "JAX_COORDINATOR_ADDRESS")
        num = self.num_processes
        if num is None:
            v = env("REPRO_NUM_PROCESSES", "JAX_NUM_PROCESSES")
            num = int(v) if v else None
        pid = self.process_id
        if pid is None:
            v = env("REPRO_PROCESS_ID", "JAX_PROCESS_ID")
            pid = int(v) if v else None
        return dataclasses.replace(self, coordinator=coord,
                                   num_processes=num, process_id=pid)

    @property
    def is_multiprocess(self) -> bool:
        return (self.num_processes or 1) > 1 or self.coordinator is not None


@dataclasses.dataclass(frozen=True)
class Cluster:
    """Topology of the running job, as every layer above sees it."""
    process_index: int
    process_count: int
    coordinator: Optional[str] = None

    @property
    def is_distributed(self) -> bool:
        return self.process_count > 1

    @property
    def is_coordinator(self) -> bool:
        """Process 0: the one host that ingests/admits/reports."""
        return self.process_index == 0

    # -- devices (queried live: backend state, not config) ----------------

    def devices(self) -> list:
        """GLOBAL devices, in process-major order (jax device-id order
        groups each process's local devices contiguously — the layout
        the per-host row loaders assume)."""
        import jax
        return jax.devices()

    def local_devices(self) -> list:
        import jax
        return jax.local_devices()

    @property
    def device_count(self) -> int:
        return len(self.devices())

    @property
    def local_device_count(self) -> int:
        return len(self.local_devices())

    def describe(self) -> dict:
        """Topology report (JSON-able) for logs and dry-run artifacts."""
        import jax
        return {
            "process_index": self.process_index,
            "process_count": self.process_count,
            "coordinator": self.coordinator,
            "platform": jax.devices()[0].platform,
            "local_devices": self.local_device_count,
            "global_devices": self.device_count,
        }

    # -- per-host shard assembly -------------------------------------------

    def make_global_array(self, mesh, spec, local_data,
                          global_shape: Optional[Sequence[int]] = None):
        """Globally-sharded ``jax.Array`` from THIS process's shard.

        ``local_data`` is the process-local block of the global array:
        the concatenation, along the dimension ``spec`` shards, of the
        shards this process's devices hold (for a 1-process cluster
        that is simply the whole array — the result then equals
        ``jax.device_put(local_data, NamedSharding(mesh, spec))``).
        """
        from jax.sharding import NamedSharding, PartitionSpec
        sharding = (NamedSharding(mesh, spec)
                    if isinstance(spec, PartitionSpec) else spec)
        if global_shape is not None:
            global_shape = tuple(int(s) for s in global_shape)
        return compat.make_array_from_process_local_data(
            sharding, local_data, global_shape)


def local_cluster() -> Cluster:
    """The 1-process topology (no coordinator, backend as-is)."""
    return Cluster(process_index=0, process_count=1)


def init_cluster(cfg: Optional[ClusterConfig] = None) -> Cluster:
    """Join the cluster described by ``cfg`` (+ env) and report topology.

    Single-process fast path: with no coordinator configured anywhere
    this performs NO distributed handshake at all — no coordinator
    socket, no timeout, no backend side effects — and just returns the
    1-process :class:`Cluster`. Multi-process: enables cross-process
    CPU collectives (gloo) where the backend is CPU, sets the faked
    local device count if requested, and calls
    ``jax.distributed.initialize`` via :mod:`repro.compat`.

    Idempotent: the first call wins; later calls return the same
    handle (jax.distributed can only initialize once per process).
    """
    global _CLUSTER
    if _CLUSTER is not None:
        return _CLUSTER
    cfg = (cfg or ClusterConfig()).resolved()

    if not cfg.is_multiprocess:
        _CLUSTER = local_cluster()
        return _CLUSTER

    # Validate the FULL triple before any side effect: past this point
    # gloo gets wired into the backend config, which a process without
    # a distributed client cannot survive (see enable_cpu_collectives).
    if cfg.coordinator is None or cfg.num_processes is None \
            or cfg.process_id is None:
        raise ValueError(
            "multi-process launch needs the full triple: coordinator "
            f"address, num_processes and process_id (got {cfg})")
    if cfg.local_device_count:
        flag = (f"--xla_force_host_platform_device_count="
                f"{cfg.local_device_count}")
        os.environ["XLA_FLAGS"] = \
            (os.environ.get("XLA_FLAGS", "") + " " + flag).strip()
    platform = os.environ.get("JAX_PLATFORMS", "").split(",")[0]
    if platform in ("", "cpu"):
        if not compat.enable_cpu_collectives(cfg.cpu_collectives):
            raise RuntimeError(
                "this JAX has no cross-process CPU collectives "
                f"({cfg.cpu_collectives!r}); a multi-process CPU run "
                "would hang at the first collective")
    def handshake():
        faults.maybe_raise("cluster.handshake", kinds=("handshake_flake",))
        compat.distributed_initialize(
            coordinator_address=cfg.coordinator,
            num_processes=cfg.num_processes,
            process_id=cfg.process_id,
            initialization_timeout=cfg.initialization_timeout)

    faults.retry_with_backoff(
        handshake, attempts=cfg.handshake_retries,
        base_s=cfg.handshake_backoff_s, layer="cluster",
        cause=f"coordinator handshake with {cfg.coordinator}",
        action="check that process 0 is reachable at the coordinator "
               "address, then relaunch this process (the restarted "
               "process rejoins from the last checkpoint)")
    _CLUSTER = Cluster(process_index=compat.process_index(),
                       process_count=compat.process_count(),
                       coordinator=cfg.coordinator)
    return _CLUSTER


# ---------------------------------------------------------------------------
# Entry-point wiring (launch/{train,serve}.py, examples).
# ---------------------------------------------------------------------------

def add_cluster_flags(parser) -> None:
    """The launch flags every entry point shares."""
    parser.add_argument("--coordinator", default=None,
                        help="process 0 address host:port "
                             "(multi-process launch)")
    parser.add_argument("--num-processes", type=int, default=None)
    parser.add_argument("--process-id", type=int, default=None)
    parser.add_argument("--local-devices", type=int, default=None,
                        help="faked host devices per process "
                             "(multi-process CPU)")
    parser.add_argument("--cluster-timeout", type=int, default=120,
                        help="jax.distributed initialization timeout (s) "
                             "— bounds how long a restarted process "
                             "waits for dead peers to rejoin")


def cluster_config_from_args(args) -> ClusterConfig:
    return ClusterConfig(coordinator=args.coordinator,
                         num_processes=args.num_processes,
                         process_id=args.process_id,
                         local_device_count=args.local_devices,
                         initialization_timeout=args.cluster_timeout)


def simulated_topology(num_processes: int, device_count: int) -> dict:
    """Per-host split of a ``device_count``-chip job over
    ``num_processes`` hosts — the dry-run's view of a topology it is
    not actually running (``dryrun --processes N``)."""
    if device_count % num_processes != 0:
        raise ValueError(f"{device_count} devices do not split over "
                         f"{num_processes} processes")
    return {"process_count": num_processes,
            "devices_per_process": device_count // num_processes}
