"""Analytic cost model per architecture family.

Why analytic: XLA's cost_analysis counts while-loop bodies ONCE, so any
scan-over-layers program under-reports FLOPs/bytes by ~L×. We therefore
derive the roofline's compute/memory terms from exact per-op formulas
(the MaxText/MFU convention), and use the compiled artifact for:
  * memory_analysis (does it fit),
  * collective stats (corrected by scan trip counts via a standalone
    single-layer compile — see dryrun --measure),
  * cross-checks of these formulas (tests/test_costs.py validates the
    analytic numbers against an UNROLLED small-depth compile).

All counts are GLOBAL (whole step, all chips); divide by chips×peak
at report time. Backward pass ≈ 2× forward (standard); attention and
SSM sequence terms are counted explicitly.
"""
from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig


def _attn_flops(cfg: ModelConfig, B: int, S: int, kv_len: int,
                causal: bool) -> float:
    """QKᵀ + PV flops for one layer, forward. Causal halves the area."""
    H, hd = cfg.num_heads, cfg.hd
    area = S * kv_len * (0.5 if causal and S == kv_len else 1.0)
    if cfg.sliding_window and kv_len > cfg.sliding_window:
        area = S * cfg.sliding_window  # banded
    return 2.0 * B * H * hd * area * 2.0          # QK^T and P·V


def _proj_flops(cfg: ModelConfig, B: int, S: int) -> float:
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    return 2.0 * B * S * D * (H * hd + 2 * KV * hd + H * hd)


def _mlp_flops(cfg: ModelConfig, B: int, S: int) -> float:
    D, F = cfg.d_model, cfg.d_ff
    mats = 3 if cfg.mlp_style == "swiglu" else 2
    return 2.0 * B * S * D * F * mats


def _moe_flops(cfg: ModelConfig, B: int, S: int) -> float:
    D, F = cfg.d_model, cfg.d_ff
    tokens = B * S * cfg.experts_per_token * cfg.moe_capacity_factor
    router = 2.0 * B * S * D * cfg.num_experts
    return router + 2.0 * tokens * D * F * 3


def _rwkv_layer_flops(cfg: ModelConfig, B: int, S: int) -> float:
    from repro.models.rwkv6 import CHUNK
    D, F = cfg.d_model, cfg.d_ff
    proj = 2.0 * B * S * D * D * 5 + 2.0 * B * S * (D * 64 + 64 * D)
    Q = min(CHUNK, S)
    # chunked GLA: A=(Q,Q) scores + A@V + state read/write per chunk
    per_chunk = 2.0 * B * (D * Q * Q) * 2 + 2.0 * B * D * 64 * Q * 2
    wkv = per_chunk * (S // Q if S >= Q else 1)
    cmix = 2.0 * B * S * (D * F + F * D + D * D)
    out = 2.0 * B * S * D * D
    return proj + wkv + cmix + out


def _mamba_layer_flops(cfg: ModelConfig, B: int, S: int) -> float:
    from repro.models.mamba2 import CHUNK, HEADDIM, ssm_dims
    d_inner, nh, N = ssm_dims(cfg)
    D = cfg.d_model
    proj = 2.0 * B * S * D * (2 * d_inner + 2 * N + nh)
    conv = 2.0 * B * S * (d_inner + 2 * N) * cfg.ssm_conv
    Q = min(CHUNK, S)
    nc = S // Q if S >= Q else 1
    per_chunk = (2.0 * B * Q * Q * N          # C·B
                 + 2.0 * B * Q * Q * nh * HEADDIM   # W @ x
                 + 2.0 * B * Q * nh * HEADDIM * N * 2)  # state read + inject
    out = 2.0 * B * S * d_inner * D
    return proj + conv + per_chunk * nc + out


def _logits_flops(cfg: ModelConfig, B: int, S: int) -> float:
    return 2.0 * B * S * cfg.d_model * cfg.vocab_size


def _embed_bytes(cfg: ModelConfig) -> float:
    mult = 1 if cfg.tie_embeddings else 2
    return cfg.vocab_size * cfg.d_model * mult * cfg.jdtype.itemsize


def forward_flops(cfg: ModelConfig, B: int, S: int, kv_len: int = 0,
                  causal: bool = True) -> float:
    """One forward pass over B sequences of S tokens (kv_len for decode)."""
    kv = kv_len or S
    L = cfg.num_layers
    total = _logits_flops(cfg, B, S)
    if cfg.attn_free:
        return total + L * _rwkv_layer_flops(cfg, B, S)
    if cfg.family == "hybrid":
        n_shared = L // cfg.attn_every if cfg.attn_every else 0
        total += L * _mamba_layer_flops(cfg, B, S)
        total += n_shared * (_proj_flops(cfg, B, S) +
                             _attn_flops(cfg, B, S, kv, causal) +
                             _mlp_flops(cfg, B, S))
        return total
    if cfg.is_encoder_decoder:
        Te = cfg.encoder_seq
        enc = cfg.encoder_layers * (_proj_flops(cfg, B, Te) +
                                    _attn_flops(cfg, B, Te, Te, False) +
                                    _mlp_flops(cfg, B, Te))
        dec = L * (_proj_flops(cfg, B, S) +
                   _attn_flops(cfg, B, S, kv, causal) +
                   _proj_flops(cfg, B, S) +            # cross proj (q + kv on Te)
                   _attn_flops(cfg, B, S, Te, False) +
                   _mlp_flops(cfg, B, S))
        return total + enc + dec
    mlp = _moe_flops(cfg, B, S) if cfg.is_moe else _mlp_flops(cfg, B, S)
    per_layer = _proj_flops(cfg, B, S) + \
        _attn_flops(cfg, B, S, kv, causal) + mlp
    return total + L * per_layer


def step_flops(cfg: ModelConfig, shape) -> float:
    """Whole-step FLOPs: train = fwd + 2×bwd (+remat refwd ≈ +1×fwd)."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.frontend == "vision":
        pass  # prefix tokens included in S already
    if shape.kind == "train":
        S_eff = min(S, cfg.max_decoder_len) if cfg.is_encoder_decoder else S
        f = forward_flops(cfg, B, S_eff)
        return 4.0 * f       # fwd + 2×bwd + remat re-forward (remat is on)
    if shape.kind == "prefill":
        S_eff = min(S, cfg.max_decoder_len) if cfg.is_encoder_decoder else S
        return forward_flops(cfg, B, S_eff)
    # decode: 1 token, cache depth = S
    return forward_flops(cfg, B, 1, kv_len=S, causal=False)


def param_bytes(cfg: ModelConfig) -> float:
    return cfg.param_count() * cfg.jdtype.itemsize


def step_hbm_bytes(cfg: ModelConfig, shape) -> float:
    """Analytic HBM traffic for one step (global, all chips).

    train: params read (fwd+bwd+remat ≈ 3×) + grads written+read +
           opt m/v read+write (f32) + params written + activations
           (≈ c·tokens·D·L·itemsize with c≈12 r/w passes per layer).
    decode: params read once + cache read+write.
    """
    P = param_bytes(cfg)
    B, S = shape.global_batch, shape.seq_len
    D, L = cfg.d_model, max(cfg.num_layers, 1)
    it = cfg.jdtype.itemsize
    if shape.kind == "train":
        S_eff = min(S, cfg.max_decoder_len) if cfg.is_encoder_decoder else S
        opt = 4 * (cfg.param_count() * 4)      # m,v read+write f32
        grads = 2 * P
        act = 12.0 * B * S_eff * D * L * it
        return 3 * P + grads + opt + P + act
    if shape.kind == "prefill":
        S_eff = min(S, cfg.max_decoder_len) if cfg.is_encoder_decoder else S
        act = 8.0 * B * S_eff * D * L * it
        cache = 0.0
        if not cfg.attn_free and cfg.family != "hybrid":
            ck = min(S_eff, cfg.sliding_window or S_eff)
            cache = 2.0 * B * ck * cfg.num_kv_heads * cfg.hd * L * it
        return P + act + cache
    # decode: weights once + full cache read + state write
    cache = 0.0
    if cfg.attn_free:
        from repro.models.rwkv6 import HEADDIM, rwkv_heads
        cache = 2.0 * B * rwkv_heads(cfg) * HEADDIM * HEADDIM * L * 4
    elif cfg.family == "hybrid":
        from repro.models.mamba2 import HEADDIM, ssm_dims
        d_inner, nh, N = ssm_dims(cfg)
        cache = 2.0 * B * nh * HEADDIM * N * L * 4
        n_shared = L // cfg.attn_every if cfg.attn_every else 0
        ck = min(S, cfg.sliding_window or S)
        cache += 2.0 * B * ck * cfg.num_kv_heads * cfg.hd * n_shared * it
    else:
        ck = min(S, cfg.sliding_window or S)
        kvh = cfg.num_kv_heads
        Lk = cfg.num_layers
        cache = (1.0 + 1.0 / max(ck, 1)) * 2.0 * B * ck * kvh * cfg.hd * Lk * it
        if cfg.is_encoder_decoder:
            cache += 2.0 * B * cfg.encoder_seq * kvh * cfg.hd * Lk * it
    return P + cache + 2.0 * B * D * L * it


@dataclasses.dataclass(frozen=True)
class AnalyticCosts:
    flops: float
    hbm_bytes: float


def analytic_costs(cfg: ModelConfig, shape) -> AnalyticCosts:
    return AnalyticCosts(flops=step_flops(cfg, shape),
                         hbm_bytes=step_hbm_bytes(cfg, shape))
