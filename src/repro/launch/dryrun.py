import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
# ^ MUST run before any other import (jax locks the device count on first
#   backend init). 512 host devices exist ONLY inside this program.

"""Multi-pod dry-run: prove the distribution config is coherent.

For a given (arch × input-shape × mesh), builds the step program,
``jit(...).lower(...).compile()``s it against the production mesh, and
records memory_analysis / cost_analysis / collective stats as a JSON
artifact for EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
    python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
    python -m repro.launch.dryrun --arch llama3-8b --shape decode_32k --multi-pod
    python -m repro.launch.dryrun --all            # every pair, single-pod
"""
import argparse
import json
import sys
import time
import traceback


def run_one(arch: str, shape_name: str, multi_pod: bool,
            rules_name: str = "baseline", out_dir: str = "benchmarks/artifacts",
            verbose: bool = True, measure_layers: bool = True,
            shuffle: str = None, processes: int = 1,
            row_format: str = None, nnz_cap: int = None) -> dict:
    import jax
    import numpy as np

    from repro import compat
    from repro.configs import get_config
    from repro.launch import steps as steps_lib
    from repro.launch.cluster import simulated_topology
    from repro.launch.costs import analytic_costs
    from repro.launch.hlo_analysis import (collective_stats,
                                           combine_with_layer, dominant_term,
                                           roofline_terms,
                                           total_collective_bytes)
    from repro.launch.mesh import make_production_mesh
    from repro.launch.rules import get_rules

    from repro.configs import canonical
    cfg = get_config(arch)
    arch = canonical(arch)          # one artifact name per arch
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    record = {"arch": arch, "shape": shape_name,
              "mesh": "2x16x16" if multi_pod else "16x16",
              "chips": chips, "rules": rules_name, "status": "ok"}
    if processes > 1:
        record["processes"] = processes

    t0 = time.time()
    try:
        if processes > 1:
            # Simulated multi-host split (DESIGN.md §11): what each of
            # the N processes would hold of the job. Recorded in the
            # artifact — and in its NAME, so single- and multi-host
            # rooflines of the same (arch, shape, mesh) never clobber
            # each other. Inside the try: an indivisible split becomes
            # a structured status:error record like every other
            # invalid input, not a raw traceback.
            record["topology"] = simulated_topology(processes, chips)
        if getattr(cfg, "family", None) == "svm":
            # SV merge transport: the ring-pipelined shuffle or the
            # monolithic all-gather (DESIGN.md §10); default from the
            # arch config, overridable per dry-run for A/B roofline runs.
            record["shuffle"] = steps_lib._svm_shuffle(cfg, shuffle)
            # row format: dense (n, d) rows or blocked-CSR (DESIGN.md
            # §12); overridable for sparse-vs-dense roofline A/Bs.
            import dataclasses as _dc
            over = {k: v for k, v in (("row_format", row_format),
                                      ("nnz_cap", nnz_cap))
                    if v is not None}
            if over:
                cfg = _dc.replace(cfg, **over)
            record["row_format"] = getattr(cfg, "row_format", "dense")
            if record["row_format"] == "sparse_csr":
                record["nnz_cap"] = cfg.nnz_cap
            if shape_name == "svm_sweep":
                bundle = steps_lib.build_svm_sweep_step(cfg, mesh,
                                                        num_configs=8,
                                                        shuffle_impl=shuffle)
            elif shape_name == "svm_serve":
                bundle = steps_lib.build_svm_serve_step(cfg, mesh,
                                                        num_streams=4,
                                                        shuffle_impl=shuffle)
            else:
                bundle = steps_lib.build_svm_round_step(cfg, mesh,
                                                        shuffle_impl=shuffle)
            shape = None
        else:
            shape = steps_lib.INPUT_SHAPES[shape_name]
            skip = steps_lib.applicability(cfg, shape)
            if skip:
                record.update(status="skip", reason=skip)
                _write(record, out_dir)
                if verbose:
                    print(json.dumps(record, indent=2))
                return record
            bundle = steps_lib.build_step(cfg, mesh, shape,
                                          rules=get_rules(rules_name))

        if processes > 1:
            # per-host input shapes: what each process's loader must
            # materialize before make_global_array assembly
            local_abs = steps_lib.per_host_abstract(
                bundle.args, bundle.in_shardings, mesh, processes)
            from repro import sparse as sparse_rows

            def _fmt(a):
                if sparse_rows.is_sparse(a):
                    return (f"sparse_csr[d={a.d}] "
                            f"idx={a.indices.dtype}{list(a.indices.shape)} "
                            f"val={a.values.dtype}{list(a.values.shape)}")
                return f"{a.dtype}{list(a.shape)}"
            record["per_host_args"] = jax.tree_util.tree_map(
                _fmt, local_abs, is_leaf=sparse_rows.is_sparse)

        with compat.set_mesh(mesh):
            jitted = jax.jit(
                bundle.fn,
                in_shardings=compat.to_shardings(mesh, bundle.in_shardings),
                out_shardings=compat.to_shardings(mesh, bundle.out_shardings),
                donate_argnums=bundle.donate_argnums)
            lowered = jitted.lower(*bundle.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compat.cost_analysis(compiled)
        hlo = compiled.as_text()
        coll = collective_stats(hlo)

        # scan-trip correction: standalone single-layer probes recover the
        # collectives hidden inside while-loop bodies (counted once in text)
        if measure_layers and getattr(cfg, "family", None) != "svm":
            try:
                from repro.launch.probes import build_probes, measure_probes
                probes = build_probes(cfg, mesh, shape, get_rules(rules_name))
                pm = measure_probes(probes, mesh)
                record["probes"] = {
                    k: {"extra_trips": v["extra_trips"],
                        "collectives": v["collectives"]}
                    for k, v in pm.items()}
                for v in pm.values():
                    coll = combine_with_layer(coll, v["collectives"],
                                              v["extra_trips"])
            except Exception as e:          # probes are best-effort
                record["probe_error"] = f"{type(e).__name__}: {e}"
        coll_bytes = total_collective_bytes(coll)
        wire_bytes = total_collective_bytes(coll, "wire_bytes")

        # raw XLA numbers (per-device module; loop bodies counted once)
        flops_xla = float(cost.get("flops", 0.0)) if cost else 0.0
        bytes_xla = float(cost.get("bytes accessed", 0.0)) if cost else 0.0

        if getattr(cfg, "family", None) == "svm":
            # no scan-over-layers: XLA numbers usable directly (×chips)
            flops_glob, hbm_glob = flops_xla * chips, bytes_xla * chips
        else:
            ac = analytic_costs(cfg, shape)
            flops_glob, hbm_glob = ac.flops, ac.hbm_bytes
        terms = roofline_terms(flops_glob, hbm_glob, coll_bytes, chips)
        terms_wire = roofline_terms(flops_glob, hbm_glob, wire_bytes, chips)
        record.update(
            lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            flops_global=flops_glob, hbm_bytes_global=hbm_glob,
            xla_per_device_flops=flops_xla, xla_per_device_bytes=bytes_xla,
            collective_bytes_per_device=coll_bytes,
            collective_wire_bytes_per_device=wire_bytes,
            collectives=coll,
            roofline=terms, collective_s_wire=terms_wire["collective_s"],
            dominant=dominant_term(terms))
        if mem is not None:
            for k in ("temp_size_in_bytes", "argument_size_in_bytes",
                      "output_size_in_bytes", "alias_size_in_bytes",
                      "generated_code_size_in_bytes"):
                v = getattr(mem, k, None)
                if v is not None:
                    record[k] = int(v)
        if getattr(cfg, "family", None) != "svm":
            record["model_flops_analytic"] = _model_flops(cfg, shape)
            record["useful_flops_ratio"] = (
                record["model_flops_analytic"] / max(flops_glob, 1.0))
    except Exception as e:
        record.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-2000:])
    _write(record, out_dir)
    if verbose:
        slim = {k: v for k, v in record.items() if k != "traceback"}
        print(json.dumps(slim, indent=2, default=str))
    return record


def _model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D for the step's tokens.
    Training counts fwd+bwd (6·N per token); prefill/decode fwd only (2·N)."""
    n_active = cfg.active_param_count()
    S = shape.seq_len
    if cfg.is_encoder_decoder:
        S = min(S, cfg.max_decoder_len)   # decoder-context cap
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * S
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * S
    return 2.0 * n_active * shape.global_batch     # decode: 1 token/seq


def _write(record: dict, out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    shuffle = f"_{record['shuffle']}" if "shuffle" in record else ""
    procs = (f"_p{record['processes']}"
             if record.get("processes", 1) > 1 else "")
    sparse = (f"_sparse{record['nnz_cap']}"
              if record.get("row_format") == "sparse_csr" else "")
    name = (f"dryrun_{record['arch']}_{record.get('shape')}"
            f"_{record['mesh']}_{record.get('rules', 'baseline')}"
            f"{shuffle}{sparse}{procs}.json")
    with open(os.path.join(out_dir, name.replace("/", "_")), "w") as f:
        json.dump(record, f, indent=2, default=str)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default="train_4k",
                    choices=list(("train_4k", "prefill_32k", "decode_32k",
                                  "long_500k", "svm", "svm_sweep",
                                  "svm_serve")))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--rules", default="baseline")
    from repro.core.mapreduce_svm import SHUFFLE_IMPLS
    ap.add_argument("--shuffle", default=None,
                    choices=SHUFFLE_IMPLS,
                    help="svm family: SV merge transport (default: the "
                         "arch config's shuffle_impl)")
    ap.add_argument("--processes", type=int, default=1,
                    help="simulate the job split over N hosts: records "
                         "per-host input shapes and suffixes the "
                         "artifact name with _pN")
    ap.add_argument("--row-format", default=None,
                    choices=("dense", "sparse_csr"),
                    help="svm family: row representation (default: the "
                         "arch config's row_format); sparse_csr suffixes "
                         "the artifact name with _sparse<nnz_cap>")
    ap.add_argument("--nnz-cap", type=int, default=None,
                    help="svm family, sparse_csr: (index, value) slots "
                         "per blocked-CSR row")
    ap.add_argument("--all", action="store_true",
                    help="run every (assigned arch × shape) on this mesh")
    ap.add_argument("--out", default="benchmarks/artifacts")
    args = ap.parse_args()

    if args.all:
        from repro.configs import ARCH_IDS
        ok = True
        for arch in ARCH_IDS:
            if arch == "svm_tfidf":
                rec = run_one(arch, "svm", args.multi_pod, args.rules,
                              args.out, shuffle=args.shuffle)
                ok &= rec["status"] in ("ok", "skip")
                continue
            for shape in ("train_4k", "prefill_32k", "decode_32k",
                          "long_500k"):
                rec = run_one(arch, shape, args.multi_pod, args.rules,
                              args.out)
                ok &= rec["status"] in ("ok", "skip")
        sys.exit(0 if ok else 1)

    rec = run_one(args.arch, args.shape, args.multi_pod, args.rules, args.out,
                  shuffle=args.shuffle, processes=args.processes,
                  row_format=args.row_format, nnz_cap=args.nnz_cap)
    sys.exit(0 if rec["status"] in ("ok", "skip") else 1)


if __name__ == "__main__":
    main()
