"""Roofline-term extraction from a lowered/compiled XLA program.

compute term    = FLOPs / (chips × peak)
memory term     = HBM bytes / (chips × HBM bw)
collective term = collective bytes / ICI bw   (per-device program)

Collective bytes are NOT in cost_analysis — we parse the
post-SPMD-partitioning HLO text. Post-optimization HLO prints operand
NAMES without types, so sizes are derived from the op's output type and
its replica_groups:

    all-gather          operand = out/g      wire ≈ out·(g-1)/g
    reduce-scatter      operand = out·g      wire ≈ out·(g-1)   (=op·(g-1)/g)
    all-reduce          operand = out        wire ≈ 2·out·(g-1)/g
    all-to-all          operand = out        wire ≈ out·(g-1)/g
    collective-permute  operand = out        wire = out

Caveat recorded in EXPERIMENTS.md: ops inside while-loop (scan) bodies
appear ONCE in the text; dryrun's --measure pass compiles a standalone
single layer to recover per-trip counts (collective_total =
full + (L-1)·layer).
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_TYPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?P<out>\([^=]*?\)|[\w.\-]+\[[\d,]*\]"
    r"(?:\{[\d,]*\})?)\s+(?P<op>[\w\-]+)\(", re.M)


def _tensor_sizes(type_str: str) -> List[int]:
    out = []
    for dt, dims in _TYPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out.append(n * _DTYPE_BYTES[dt])
    return out


def _group_size(line: str) -> int:
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return 1


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-kind {count, operand_bytes, output_bytes, wire_bytes}."""
    stats: Dict[str, Dict[str, float]] = {}
    lines = hlo_text.splitlines()
    for line in lines:
        m = _OP_RE.match(line)
        if not m:
            continue
        op = m.group("op")
        base = next((c for c in _COLLECTIVES
                     if op == c or op == c + "-start"), None)
        if base is None:
            continue
        sizes = _tensor_sizes(m.group("out"))
        if not sizes:
            continue
        biggest = max(sizes)
        g = max(_group_size(line), 1)
        if base == "all-gather":
            operand, wire = biggest / g, biggest * (g - 1) / g
        elif base == "reduce-scatter":
            operand, wire = float(biggest), biggest * (g - 1) / g
        elif base == "all-reduce":
            operand, wire = float(biggest), 2.0 * biggest * (g - 1) / g
        elif base == "all-to-all":
            operand, wire = float(biggest), biggest * (g - 1) / g
        else:                                   # collective-permute
            operand, wire = float(biggest), float(biggest)
        s = stats.setdefault(base, {"count": 0, "operand_bytes": 0.0,
                                    "output_bytes": 0.0, "wire_bytes": 0.0})
        s["count"] += 1
        s["operand_bytes"] += operand
        s["output_bytes"] += biggest
        s["wire_bytes"] += wire
    return stats


def total_collective_bytes(stats: Dict[str, Dict[str, float]],
                           key: str = "operand_bytes") -> float:
    """Spec convention: sum of operand sizes over every collective op.
    ``wire_bytes`` available as the physically-motivated alternative."""
    return float(sum(s[key] for s in stats.values()))


def combine_with_layer(full: Dict, layer: Dict, extra_trips: int) -> Dict:
    """collective_total = full + extra_trips × standalone-layer (scan fix)."""
    out = {k: dict(v) for k, v in full.items()}
    for kind, s in layer.items():
        t = out.setdefault(kind, {"count": 0, "operand_bytes": 0.0,
                                  "output_bytes": 0.0, "wire_bytes": 0.0})
        for key in ("count", "operand_bytes", "output_bytes", "wire_bytes"):
            t[key] = t.get(key, 0) + extra_trips * s.get(key, 0)
    return out


def roofline_terms(flops: float, hbm_bytes: float, collective_bytes: float,
                   chips: int) -> Dict[str, float]:
    """Terms in seconds. flops/hbm_bytes are GLOBAL; collective_bytes is
    the per-device program's traffic (post-partition HLO)."""
    return {
        "compute_s": flops / (chips * PEAK_FLOPS_BF16),
        "memory_s": hbm_bytes / (chips * HBM_BW),
        "collective_s": collective_bytes / ICI_BW,
    }


def dominant_term(terms: Dict[str, float]) -> str:
    return max(("compute_s", "memory_s", "collective_s"),
               key=lambda k: terms[k])
