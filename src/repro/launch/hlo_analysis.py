"""Roofline-term extraction from a lowered/compiled XLA program.

compute term    = FLOPs / (chips × peak)
memory term     = HBM bytes / (chips × HBM bw)
collective term = collective bytes / ICI bw   (per-device program)

Collective bytes are NOT in cost_analysis — we parse the
post-SPMD-partitioning HLO text. Post-optimization HLO prints operand
NAMES without types, so sizes are derived from the op's output type and
its replica_groups:

    all-gather          operand = out/g      wire ≈ out·(g-1)/g
    reduce-scatter      operand = out·g      wire ≈ out·(g-1)   (=op·(g-1)/g)
    all-reduce          operand = out        wire ≈ 2·out·(g-1)/g
    all-to-all          operand = out        wire ≈ out·(g-1)/g
    collective-permute  operand = out        wire = out

Caveat recorded in EXPERIMENTS.md: ops inside while-loop (scan) bodies
appear ONCE in the text; dryrun's --measure pass compiles a standalone
single layer to recover per-trip counts (collective_total =
full + (L-1)·layer).

Extraction is delegated to the hardened parser in
:mod:`repro.analysis.hlo` (ISSUE 8): structured :class:`CollectiveOp`
records with full replica_groups / source_target_pairs / start-done
pairing, shared with the collective-schedule lint rule — the roofline
gate and the deadlock checker read the SAME ops. Unknown dtypes no
longer silently drop out of the byte math: they warn once and count at
a conservative 4-byte fallback.
"""
from __future__ import annotations

from typing import Dict, List

from repro.analysis import hlo as hlo_parser
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

# Byte widths kept for external readers of this module; the parser's
# bit-level table (repro.analysis.hlo._DTYPE_BITS) is the source of
# truth and additionally covers the sub-byte types (u4/s4, fp8 family).
_DTYPE_BYTES = {
    dt: max(1, bits // 8) for dt, bits in hlo_parser._DTYPE_BITS.items()
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _tensor_sizes(type_str: str) -> List[int]:
    """Byte sizes of every tensor in an HLO type string. Unknown dtypes
    warn once and count at a conservative fallback (never skipped: a
    silent skip undercounts the perf gate's wire bytes)."""
    return hlo_parser.tensor_nbytes(type_str)


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-kind {count, operand_bytes, output_bytes, wire_bytes}."""
    stats: Dict[str, Dict[str, float]] = {}
    for op in hlo_parser.parse_collective_ops(hlo_text):
        if op.is_done or op.kind not in _COLLECTIVES:
            continue
        biggest = op.max_nbytes
        if not biggest:
            continue
        base = op.kind
        g = max(op.group_size, 1)
        if base == "all-gather":
            operand, wire = biggest / g, biggest * (g - 1) / g
        elif base == "reduce-scatter":
            operand, wire = float(biggest), biggest * (g - 1) / g
        elif base == "all-reduce":
            operand, wire = float(biggest), 2.0 * biggest * (g - 1) / g
        elif base == "all-to-all":
            operand, wire = float(biggest), biggest * (g - 1) / g
        else:                                   # collective-permute
            operand, wire = float(biggest), float(biggest)
        s = stats.setdefault(base, {"count": 0, "operand_bytes": 0.0,
                                    "output_bytes": 0.0, "wire_bytes": 0.0})
        s["count"] += 1
        s["operand_bytes"] += operand
        s["output_bytes"] += biggest
        s["wire_bytes"] += wire
    return stats


def total_collective_bytes(stats: Dict[str, Dict[str, float]],
                           key: str = "operand_bytes") -> float:
    """Spec convention: sum of operand sizes over every collective op.
    ``wire_bytes`` available as the physically-motivated alternative."""
    return float(sum(s[key] for s in stats.values()))


def combine_with_layer(full: Dict, layer: Dict, extra_trips: int) -> Dict:
    """collective_total = full + extra_trips × standalone-layer (scan fix)."""
    out = {k: dict(v) for k, v in full.items()}
    for kind, s in layer.items():
        t = out.setdefault(kind, {"count": 0, "operand_bytes": 0.0,
                                  "output_bytes": 0.0, "wire_bytes": 0.0})
        for key in ("count", "operand_bytes", "output_bytes", "wire_bytes"):
            t[key] = t.get(key, 0) + extra_trips * s.get(key, 0)
    return out


def roofline_terms(flops: float, hbm_bytes: float, collective_bytes: float,
                   chips: int) -> Dict[str, float]:
    """Terms in seconds. flops/hbm_bytes are GLOBAL; collective_bytes is
    the per-device program's traffic (post-partition HLO)."""
    return {
        "compute_s": flops / (chips * PEAK_FLOPS_BF16),
        "memory_s": hbm_bytes / (chips * HBM_BW),
        "collective_s": collective_bytes / ICI_BW,
    }


def dominant_term(terms: Dict[str, float]) -> str:
    return max(("compute_s", "memory_s", "collective_s"),
               key=lambda k: terms[k])
