"""Production mesh construction (DESIGN.md §5, §11).

Defined as FUNCTIONS so importing this module never touches jax device
state — jax locks the device count at first backend init, and only
``dryrun.py`` (which sets XLA_FLAGS first) may see 512 host devices.

Meshes are built from CLUSTER topology, not ``len(jax.devices())``
assumptions: on a multi-process run the devices are global and the
data axis must enumerate them in process-major order so each host's
contiguous row block is addressable where it was loaded
(:meth:`repro.launch.cluster.Cluster.make_global_array`).
"""
from __future__ import annotations

import jax
import numpy as np

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e target: 16×16 = 256 chips per pod; 2 pods multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_cluster_mesh(cluster, data: int = 0, model: int = 1):
    """("data", "model") mesh over the cluster's GLOBAL devices.

    Device order is taken verbatim from ``cluster.devices()`` (process-
    major) rather than ``jax.make_mesh``'s topology-optimized
    reordering: the per-host loaders materialize the row block of THIS
    process, so the data axis must keep each process's devices
    contiguous or ``make_global_array`` would need to ship rows across
    hosts just to lay the array out.
    """
    devs = cluster.devices()
    n = len(devs)
    model = max(1, min(model, n))
    data = data or n // model
    data = min(data, n // model)
    from jax.sharding import Mesh
    arr = np.asarray(devs[:data * model]).reshape(data, model)
    return Mesh(arr, ("data", "model"))


def make_host_mesh(data: int = 1, model: int = 1, cluster=None):
    """Small mesh over whatever devices exist (tests/examples).

    ``cluster`` makes it process-count-agnostic: the mesh spans the
    cluster's global devices, in the process-major order multi-host
    data loading relies on. Without one, the historical single-process
    behaviour (local devices via ``compat.make_mesh``) is unchanged.
    """
    if cluster is not None and cluster.is_distributed:
        return make_cluster_mesh(cluster, data=data, model=model)
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // max(data, 1)))
    return compat.make_mesh((data, model), ("data", "model"))


def simulated_hier_hosts(ndev: int):
    """Host count for ``shuffle_impl="hier"`` launch configs.

    On a real multi-process run returns ``None`` so the round builder
    resolves the host count from ``compat.process_count()`` (the actual
    topology). Single-process — the simulated case every CI/dryrun
    program runs in — picks a non-degenerate two-level split so the
    hier schedule actually exercises both legs: ``ndev // 8`` hosts
    (one simulated host per 8 locals, e.g. 512 devices → 64 hosts),
    falling back to 2, and only degenerating to 1 when ``ndev`` is odd.
    """
    if compat.process_count() > 1:
        return None
    for hosts in (max(2, ndev // 8), 2):
        if hosts <= ndev and ndev % hosts == 0:
            return hosts
    return 1


def batch_axes(mesh) -> tuple:
    """Mesh axes the batch dim shards over."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def data_parallel_size(mesh) -> int:
    size = 1
    for a in batch_axes(mesh):
        size *= mesh.shape[a]
    return size


def model_parallel_size(mesh) -> int:
    return mesh.shape.get("model", 1)


# Hardware constants for the roofline (TPU v5e, per chip).
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link
