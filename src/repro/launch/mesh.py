"""Production mesh construction (DESIGN.md §5).

Defined as FUNCTIONS so importing this module never touches jax device
state — jax locks the device count at first backend init, and only
``dryrun.py`` (which sets XLA_FLAGS first) may see 512 host devices.
"""
from __future__ import annotations

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e target: 16×16 = 256 chips per pod; 2 pods multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever local devices exist (tests/examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // max(data, 1)))
    return compat.make_mesh((data, model), ("data", "model"))


def batch_axes(mesh) -> tuple:
    """Mesh axes the batch dim shards over."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def data_parallel_size(mesh) -> int:
    size = 1
    for a in batch_axes(mesh):
        size *= mesh.shape[a]
    return size


def model_parallel_size(mesh) -> int:
    return mesh.shape.get("model", 1)


# Hardware constants for the roofline (TPU v5e, per chip).
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link
