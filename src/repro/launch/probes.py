"""Standalone single-layer probes.

XLA counts scan bodies once, so the full-program HLO text shows ONE
layer's collectives. Compiling the SAME layer standalone recovers the
per-trip contribution:

    collective_total = full_program + Σ_probe (trips_probe − 1) × probe

Each probe returns a StepBundle-compatible (fn, args, in_shardings)
plus its extra-trip multiplier for the given architecture.
"""
from __future__ import annotations

from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax

from repro import compat
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.launch import sharding as shd
from repro.launch.steps import InputShape
from repro.models.config import ModelConfig
from repro.models.layers import template_abstract
from repro.models.transformer import build_model


class Probe(NamedTuple):
    name: str
    fn: Any
    args: Tuple
    in_shardings: Tuple
    extra_trips: int      # multiplier applied to this probe's collectives


def _hidden_abstract(cfg, B, S):
    return jax.ShapeDtypeStruct((B, S, cfg.d_model), cfg.jdtype)


def _hidden_spec(mesh, B):
    bp = shd.batch_pspec(mesh, B)
    b = tuple(bp) if bp != P(None) else (None,)
    return P(*(b + (None, None)))


def _layer_pspecs(tpl, mesh, rules):
    from repro.models.layers import template_axes
    abstract = template_abstract(tpl, jnp.float32)
    axes = template_axes(tpl)
    return jax.tree.map(
        lambda a, ax: shd.pspec_for(a.shape, ax, mesh, rules),
        abstract, axes,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def build_probes(cfg: ModelConfig, mesh, shape: InputShape,
                 rules: Optional[dict] = None) -> List[Probe]:
    kv_r = shd.kv_repeat_for(cfg, mesh)
    model = build_model(cfg, kv_repeat=kv_r, mesh=mesh)
    B = shape.global_batch
    S = shape.seq_len
    if cfg.is_encoder_decoder and shape.kind != "decode":
        S = min(S, cfg.max_decoder_len)
    probes: List[Probe] = []
    hs = _hidden_spec(mesh, B)

    def fwd_probe(name, layer_fn, tpl, trips, seq=S, grad=(shape.kind == "train")):
        lspec = _layer_pspecs(tpl, mesh, rules)
        labs = template_abstract(tpl, cfg.jdtype)
        h = _hidden_abstract(cfg, B, seq)

        if grad:
            def fn(h, lp):
                def obj(h, lp):
                    # keep the objective in the native activation dtype —
                    # an f32 upcast here would poison the cotangent stream
                    # and overstate backward collective bytes 2×
                    return jnp.sum(layer_fn(lp, h)).astype(jnp.float32)
                return jax.grad(obj, argnums=(0, 1))(h, lp)
        else:
            def fn(h, lp):
                return layer_fn(lp, h)
        probes.append(Probe(name, fn, (h, labs), (hs, lspec), trips))

    if shape.kind in ("train", "prefill"):
        positions = None

        if cfg.family in ("dense", "moe", "vlm"):
            def layer_fn(lp, h):
                Bs, Ss, _ = h.shape
                pos = jnp.broadcast_to(jnp.arange(Ss)[None, :], (Bs, Ss))
                out, _ = model._layer_fwd(lp, h, pos)
                return out
            fwd_probe("layer", layer_fn, model.layer_template(),
                      cfg.num_layers - 1)
        elif cfg.attn_free:
            from repro.models import rwkv6
            from repro.models.layers import apply_norm

            def layer_fn(lp, h):
                zp = jnp.zeros((h.shape[0], 1, cfg.d_model), h.dtype)
                x = apply_norm(h, lp["ln1"], "layernorm", cfg.norm_eps)
                h = h + rwkv6.apply_rwkv_time(lp["time"], x, cfg, zp)
                x = apply_norm(h, lp["ln2"], "layernorm", cfg.norm_eps)
                return h + rwkv6.apply_rwkv_channel(lp["channel"], x, zp)
            fwd_probe("layer", layer_fn, model.layer_template(),
                      cfg.num_layers - 1)
        elif cfg.family == "hybrid":
            from repro.models import mamba2
            from repro.models.layers import apply_norm, norm_template

            def mamba_fn(lp, h):
                x = apply_norm(h, lp["norm"], cfg.norm_style, cfg.norm_eps)
                return h + mamba2.apply_mamba2(lp["mamba"], x, cfg)
            mamba_tpl = {"norm": norm_template(cfg.d_model, cfg.norm_style),
                         "mamba": mamba2.mamba2_template(cfg)}
            fwd_probe("mamba_layer", mamba_fn, mamba_tpl, cfg.num_layers - 1)

            def shared_fn(sp, h):
                Bs, Ss, _ = h.shape
                pos = jnp.broadcast_to(jnp.arange(Ss)[None, :], (Bs, Ss))
                return model._shared_block(sp, h, pos)
            n_shared = cfg.num_layers // cfg.attn_every
            fwd_probe("shared_block", shared_fn,
                      model.template()["shared"], n_shared - 1)
        elif cfg.is_encoder_decoder:
            from repro.models import attention as attn_lib
            from repro.models.layers import apply_mlp, apply_norm

            def enc_fn(lp, h):
                Bs, Ss, _ = h.shape
                pos = jnp.broadcast_to(jnp.arange(Ss)[None, :], (Bs, Ss))
                a = apply_norm(h, lp["attn_norm"], "layernorm", cfg.norm_eps)
                h = h + attn_lib.attention(lp["attn"], a, cfg, positions=pos,
                                           causal=False, kv_repeat=kv_r)
                m = apply_norm(h, lp["mlp_norm"], "layernorm", cfg.norm_eps)
                return h + apply_mlp(m, lp["mlp"], "gelu")
            # un-stack: rebuild the unstacked encoder layer template
            from repro.models.layers import mlp_template as _mlp, norm_template as _norm
            enc_layer_tpl = {
                "attn_norm": _norm(cfg.d_model, "layernorm"),
                "attn": attn_lib.attn_template(cfg),
                "mlp_norm": _norm(cfg.d_model, "layernorm"),
                "mlp": _mlp(cfg.d_model, cfg.d_ff, "gelu"),
            }
            fwd_probe("enc_layer", enc_fn, enc_layer_tpl,
                      cfg.encoder_layers - 1, seq=min(cfg.encoder_seq, 1536))

            def dec_fn(lp, h):
                Bs, Ss, _ = h.shape
                pos = jnp.broadcast_to(jnp.arange(Ss)[None, :], (Bs, Ss))
                enc_pos = pos
                a = apply_norm(h, lp["self_norm"], "layernorm", cfg.norm_eps)
                h = h + attn_lib.attention(lp["self_attn"], a, cfg,
                                           positions=pos, kv_repeat=kv_r)
                c = apply_norm(h, lp["cross_norm"], "layernorm", cfg.norm_eps)
                h = h + attn_lib.attention(lp["cross_attn"], c, cfg,
                                           positions=pos, causal=False,
                                           kv_x=h, kv_positions=enc_pos,
                                           kv_repeat=kv_r)
                m = apply_norm(h, lp["mlp_norm"], "layernorm", cfg.norm_eps)
                return h + apply_mlp(m, lp["mlp"], "gelu")
            dec_layer_tpl = {
                "self_norm": _norm(cfg.d_model, "layernorm"),
                "self_attn": attn_lib.attn_template(cfg),
                "cross_norm": _norm(cfg.d_model, "layernorm"),
                "cross_attn": attn_lib.attn_template(cfg),
                "mlp_norm": _norm(cfg.d_model, "layernorm"),
                "mlp": _mlp(cfg.d_model, cfg.d_ff, "gelu"),
            }
            fwd_probe("dec_layer", dec_fn, dec_layer_tpl, cfg.num_layers - 1)
        return probes

    # ---- decode probes ------------------------------------------------------
    pos_abs = jax.ShapeDtypeStruct((), jnp.int32)
    h1 = _hidden_abstract(cfg, B, 1)

    if cfg.family in ("dense", "moe", "vlm") or cfg.is_encoder_decoder:
        from repro.models import attention as attn_lib
        cache_len = min(S, cfg.sliding_window) if cfg.sliding_window else S
        KVr = cfg.num_kv_heads * kv_r
        kv_abs = jax.ShapeDtypeStruct((B, KVr, cache_len, cfg.hd), cfg.jdtype)
        cache_abs = attn_lib.LayerKVCache(k=kv_abs, v=kv_abs)
        bp = shd.batch_pspec(mesh, B)
        b = tuple(bp) if bp != P(None) else (None,)
        kv_spec = P(*b, shd._axis_if_divisible(mesh, "model", KVr),
                    None, None)
        cache_spec = attn_lib.LayerKVCache(k=kv_spec, v=kv_spec)
        if cfg.is_encoder_decoder:
            # decode probe: self-attention step only (cross uses static enc KV)
            from repro.models.layers import norm_template as _norm
            tpl = {"attn_norm": _norm(cfg.d_model, "layernorm"),
                   "attn": attn_lib.attn_template(cfg)}

            def fn(h, lp, cache, pos):
                from repro.models.layers import apply_norm
                a = apply_norm(h, lp["attn_norm"], "layernorm", cfg.norm_eps)
                out, cache = attn_lib.attention_decode_step(
                    lp["attn"], a, cache, pos, cfg, kv_r)
                return h + out, cache
        else:
            tpl = model.layer_template()

            def fn(h, lp, cache, pos):
                from repro.models.layers import apply_norm, apply_mlp
                from repro.models import moe as moe_lib
                a = apply_norm(h, lp["attn_norm"], cfg.norm_style,
                               cfg.norm_eps)
                out, cache = attn_lib.attention_decode_step(
                    lp["attn"], a, cache, pos, cfg, kv_r)
                h = h + out
                m = apply_norm(h, lp["mlp_norm"], cfg.norm_style, cfg.norm_eps)
                if cfg.is_moe:
                    y, _ = moe_lib.apply_moe(lp["mlp"], m, cfg)
                else:
                    y = apply_mlp(m, lp["mlp"], cfg.mlp_style)
                return h + y, cache
        lspec = _layer_pspecs(tpl, mesh, rules)
        labs = template_abstract(tpl, cfg.jdtype)
        probes.append(Probe("layer_decode", fn,
                            (h1, labs, cache_abs, pos_abs),
                            (_hidden_spec(mesh, B), lspec, cache_spec, P()),
                            cfg.num_layers - 1))
    elif cfg.attn_free:
        from repro.models import rwkv6
        from repro.models.layers import apply_norm, norm_template as _norm
        H = rwkv6.rwkv_heads(cfg)
        S_abs = jax.ShapeDtypeStruct((B, H, rwkv6.HEADDIM, rwkv6.HEADDIM),
                                     jnp.float32)
        xp = jax.ShapeDtypeStruct((B, 1, cfg.d_model), cfg.jdtype)
        tpl = model.layer_template()

        def fn(h, lp, Swk, xpt, xpc):
            x = apply_norm(h, lp["ln1"], "layernorm", cfg.norm_eps)
            y, S_new = rwkv6.rwkv_time_decode_step(lp["time"], x, Swk, xpt,
                                                   cfg)
            h = h + y
            x2 = apply_norm(h, lp["ln2"], "layernorm", cfg.norm_eps)
            h = h + rwkv6.apply_rwkv_channel(lp["channel"], x2, xpc)
            return h, S_new
        bp = shd.batch_pspec(mesh, B)
        b = tuple(bp) if bp != P(None) else (None,)
        S_spec = P(*b, shd._axis_if_divisible(mesh, "model", H), None, None)
        xp_spec = P(*b, None, None)
        lspec = _layer_pspecs(tpl, mesh, rules)
        labs = template_abstract(tpl, cfg.jdtype)
        probes.append(Probe("layer_decode", fn, (h1, labs, S_abs, xp, xp),
                            (_hidden_spec(mesh, B), lspec, S_spec, xp_spec,
                             xp_spec), cfg.num_layers - 1))
    elif cfg.family == "hybrid":
        from repro.models import mamba2
        from repro.models.layers import apply_norm, norm_template as _norm
        d_inner, nh, N = mamba2.ssm_dims(cfg)
        tpl = {"norm": _norm(cfg.d_model, cfg.norm_style),
               "mamba": mamba2.mamba2_template(cfg)}
        hst = jax.ShapeDtypeStruct((B, nh, mamba2.HEADDIM, N), jnp.float32)
        cb = jax.ShapeDtypeStruct((B, cfg.ssm_conv - 1, d_inner + 2 * N),
                                  cfg.jdtype)

        def fn(h, lp, st_h, st_c):
            st = mamba2.Mamba2State(h=st_h, conv_buf=st_c)
            x = apply_norm(h, lp["norm"], cfg.norm_style, cfg.norm_eps)
            y, st = mamba2.mamba2_decode_step(lp["mamba"], x, st, cfg)
            return h + y, st
        bp = shd.batch_pspec(mesh, B)
        b = tuple(bp) if bp != P(None) else (None,)
        h_spec = P(*b, shd._axis_if_divisible(mesh, "model", nh), None, None)
        c_spec = P(*b, None,
                   shd._axis_if_divisible(mesh, "model", d_inner + 2 * N))
        lspec = _layer_pspecs(tpl, mesh, rules)
        labs = template_abstract(tpl, cfg.jdtype)
        probes.append(Probe("mamba_decode", fn, (h1, labs, hst, cb),
                            (_hidden_spec(mesh, B), lspec, h_spec, c_spec),
                            cfg.num_layers - 1))
        # shared attention decode probe
        from repro.models import attention as attn_lib
        cache_len = min(S, cfg.sliding_window) if cfg.sliding_window else S
        KVr = cfg.num_kv_heads * kv_r
        kv_abs = jax.ShapeDtypeStruct((B, KVr, cache_len, cfg.hd), cfg.jdtype)
        cache_abs = attn_lib.LayerKVCache(k=kv_abs, v=kv_abs)
        kv_spec = P(*b, shd._axis_if_divisible(mesh, "model", KVr), None,
                    None)

        def sfn(h, sp, cache, pos):
            from repro.models.layers import apply_mlp
            a = apply_norm(h, sp["attn_norm"], cfg.norm_style, cfg.norm_eps)
            out, cache = attn_lib.attention_decode_step(
                sp["attn"], a, cache, pos, cfg, kv_r)
            h = h + out
            m = apply_norm(h, sp["mlp_norm"], cfg.norm_style, cfg.norm_eps)
            return h + apply_mlp(m, sp["mlp"], cfg.mlp_style), cache
        stpl = model.template()["shared"]
        n_shared = cfg.num_layers // cfg.attn_every
        probes.append(Probe(
            "shared_decode", sfn,
            (h1, template_abstract(stpl, cfg.jdtype), cache_abs, pos_abs),
            (_hidden_spec(mesh, B), _layer_pspecs(stpl, mesh, rules),
             attn_lib.LayerKVCache(k=kv_spec, v=kv_spec), P()),
            n_shared - 1))
    return probes


def measure_probes(probes: List[Probe], mesh) -> Dict[str, dict]:
    """Compile each probe, return its collective stats + multiplier."""
    from repro.launch.hlo_analysis import collective_stats
    out = {}
    for p in probes:
        with compat.set_mesh(mesh):
            lowered = jax.jit(
                p.fn,
                in_shardings=compat.to_shardings(mesh, p.in_shardings),
            ).lower(*p.args)
            compiled = lowered.compile()
        cost = compat.cost_analysis(compiled)
        out[p.name] = {
            "extra_trips": p.extra_trips,
            "collectives": collective_stats(compiled.as_text()),
            "per_device_flops": float(cost.get("flops", 0.0)),
            "per_device_bytes": float(cost.get("bytes accessed", 0.0)),
        }
    return out
