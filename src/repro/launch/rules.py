"""Named sharding-rule sets for §Perf experiments.

``baseline`` is the paper-faithful-era standard (megatron TP + fsdp);
the others are beyond-paper hillclimb variants toggled per experiment
via ``--rules`` without touching model code.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

from repro.launch.sharding import DEFAULT_RULES

RULE_SETS: Dict[str, Dict[Optional[str], Tuple[str, ...]]] = {
    "baseline": DEFAULT_RULES,
    # TP-only: params replicated over data (no fsdp all-gathers; only
    # valid for models that fit replicated — small archs).
    "tp_only": {**DEFAULT_RULES, "embed": ()},
    # fsdp-heavier: push ffn to data first (reduces model-axis traffic,
    # increases data-axis gathers).
    "fsdp_ffn": {**DEFAULT_RULES, "ffn": ("data", "model")},
    # expert-first: for MoE, prefer experts on model and ffn on data.
    "expert_first": {**DEFAULT_RULES, "ffn": ("data", "model"),
                     "experts": ("model",)},
}


def get_rules(name: str):
    if name not in RULE_SETS:
        raise KeyError(f"unknown rule set {name!r}; have {list(RULE_SETS)}")
    return RULE_SETS[name]
