"""Production serving entry point: sharded single-token decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --smoke --batch 4 --tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax

from repro import compat
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import InputShape, build_serve_step
from repro.models.config import smoke_variant


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--data-par", type=int, default=1)
    ap.add_argument("--model-par", type=int, default=1)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    mesh = make_host_mesh(args.data_par, args.model_par)
    shape = InputShape("cli", "decode", args.cache_len, args.batch)
    bundle = build_serve_step(cfg, mesh, shape)
    model = bundle.model

    with compat.set_mesh(mesh):
        step_fn = jax.jit(
            bundle.fn,
            in_shardings=compat.to_shardings(mesh, bundle.in_shardings),
            out_shardings=compat.to_shardings(mesh, bundle.out_shardings),
            donate_argnums=bundle.donate_argnums)
        params = model.init(jax.random.PRNGKey(0))
        if cfg.is_encoder_decoder:
            frames = jax.random.normal(
                jax.random.PRNGKey(1),
                (args.batch, cfg.encoder_seq, cfg.d_model), cfg.jdtype)
            state = model.init_decode_state(args.batch, args.cache_len,
                                            frames=frames, params=params)
        else:
            state = model.init_decode_state(args.batch, args.cache_len)
        tok = jnp.zeros((args.batch, 1), jnp.int32)
        t0 = time.time()
        for i in range(args.tokens):
            tok, state = step_fn(params, state, tok)
            tok = tok[:, None]
        jax.block_until_ready(tok)
        dt = time.time() - t0
    print(f"{cfg.name}: {args.tokens} tokens × {args.batch} seqs "
          f"in {dt:.2f}s → {args.tokens * args.batch / dt:,.1f} tok/s")


if __name__ == "__main__":
    main()
