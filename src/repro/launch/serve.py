"""Production serving entry points.

LLM family — sharded single-token decode loop:

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --smoke --batch 4 --tokens 16

svm family — streaming polarization service: micro-batches of drifting
messages fold into each tenant's SV_global behind the async wave
scheduler (repro.serving.svm_stream); S streams update in one batched
device pass:

    PYTHONPATH=src python -m repro.launch.serve --arch svm-tfidf \
        --smoke --streams 4 --waves 3
"""
from __future__ import annotations

import argparse
import time

import jax

from repro import compat
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.cluster import (add_cluster_flags, cluster_config_from_args,
                                  init_cluster)
from repro.launch.mesh import make_host_mesh, simulated_hier_hosts
from repro.launch.steps import InputShape, build_serve_step
from repro.models.config import smoke_variant


def serve_svm(svm_cfg, args, cluster) -> None:
    """Streaming polarization serve mode (``--arch svm-tfidf``).

    Multi-process topology: message admission runs on process 0 (the
    coordinator owns the queues and drives the folds) while model
    snapshots stay readable everywhere — non-coordinator processes get
    a registered service they can ``predict``/``snapshot`` against but
    not ``submit`` to (DESIGN.md §11).
    """
    import dataclasses as dc

    from repro.core import MRSVMConfig, SVMConfig, fit_mapreduce
    from repro.serving import StreamingSVMService

    if args.smoke:
        svm_cfg = dc.replace(svm_cfg, num_features=128, sv_capacity=64,
                             stream_rows_per_wave=256, dtype="float32")
    d = svm_cfg.num_features
    rows = svm_cfg.stream_rows_per_wave
    L = args.data_par if args.data_par > 1 else 8   # partitions (default 8)
    shuffle = args.shuffle or getattr(svm_cfg, "shuffle_impl", "allgather")
    hosts = simulated_hier_hosts(L) if shuffle == "hier" else None
    cfg = MRSVMConfig(sv_capacity=svm_cfg.sv_capacity, gamma=1e-4,
                      max_rounds=3, shuffle_impl=shuffle,
                      hier_num_hosts=hosts,
                      svm=SVMConfig(C=svm_cfg.C,
                                    max_epochs=svm_cfg.max_epochs))
    dt = jnp.dtype(svm_cfg.dtype)

    def batch(stream: int, wave: int, drift: float = 0.4):
        """Synthetic drifting message batch: stream s's true separator
        rotates steadily along a per-stream drift direction."""
        kx = jax.random.PRNGKey(1000 * stream + wave)
        w0 = jax.random.normal(jax.random.PRNGKey(stream), (d,))
        wd = jax.random.normal(jax.random.PRNGKey(500 + stream), (d,))
        w = w0 + drift * wave * wd
        X = jax.random.normal(kx, (rows, d), dt)
        y = jnp.sign((X @ w).astype(jnp.float32)).astype(dt)
        return X, y

    hardening = dict(checkpoint_keep=args.checkpoint_keep,
                     quarantine=not args.no_quarantine,
                     fold_deadline_s=args.fold_deadline,
                     heartbeat_path=args.heartbeat)
    if args.restore:
        if not args.checkpoint_dir:
            raise SystemExit("--restore requires --checkpoint-dir")
        svc = StreamingSVMService.restore(
            cfg, args.checkpoint_dir, cluster=cluster,
            checkpoint_every_waves=args.checkpoint_every, **hardening)
        print(f"svm-serve: restored {len(svc.streams())} streams from "
              f"{args.checkpoint_dir}")
    else:
        svc = StreamingSVMService(
            cfg, num_partitions=L, max_batches_per_wave=args.streams,
            cluster=cluster, checkpoint_dir=args.checkpoint_dir,
            checkpoint_every_waves=args.checkpoint_every, **hardening)
    print(f"svm-serve: {args.streams} streams × {rows} rows/wave, "
          f"{d} features, {L} partitions "
          f"(process {cluster.process_index}/{cluster.process_count})")
    for s in range(args.streams):
        if f"stream{s}" in svc.streams():
            continue                   # came back with the checkpoint
        X0, y0 = batch(s, 0)
        svc.register(f"stream{s}", fit_mapreduce(X0, y0, L, cfg))
    if not cluster.is_coordinator:
        # snapshots are served from every process; admission is not.
        acc = float(jnp.mean(svc.predict("stream0", batch(0, 0)[0])
                             == batch(0, 0)[1]))
        print(f"process {cluster.process_index}: read-only replica "
              f"(stream0 snapshot v{svc.snapshot('stream0').version}, "
              f"acc={acc:.3f}); admission runs on process 0")
        return

    svc.start()
    # post-restore the version counters resume where the checkpoint
    # left them, so wave completion is measured against the base
    base = {s: svc.snapshot(f"stream{s}").version
            for s in range(args.streams)}
    for wave in range(1, args.waves + 1):
        batches = [batch(s, wave) for s in range(args.streams)]
        stale = [float(jnp.mean(svc.predict(f"stream{s}", X) == y))
                 for s, (X, y) in enumerate(batches)]
        t0 = time.time()
        for s, (X, y) in enumerate(batches):
            svc.submit(f"stream{s}", X, y)
        deadline = time.time() + 300
        while any(svc.snapshot(f"stream{s}").version < base[s] + wave
                  for s in range(args.streams)):
            if svc.scheduler_error is not None or time.time() > deadline:
                raise RuntimeError(
                    f"wave {wave} never folded") from svc.scheduler_error
            time.sleep(0.01)
        fresh = [float(jnp.mean(svc.predict(f"stream{s}", X) == y))
                 for s, (X, y) in enumerate(batches)]
        print(f"wave {wave}: stale acc={sum(stale)/len(stale):.3f} → "
              f"folded acc={sum(fresh)/len(fresh):.3f} "
              f"({time.time() - t0:.2f}s)")
    svc.stop()
    print(svc.throughput_report())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--data-par", type=int, default=1)
    ap.add_argument("--model-par", type=int, default=1)
    ap.add_argument("--streams", type=int, default=4,
                    help="svm family: tenant streams served")
    ap.add_argument("--waves", type=int, default=3,
                    help="svm family: update waves to run")
    from repro.core.mapreduce_svm import SHUFFLE_IMPLS
    ap.add_argument("--shuffle", default=None,
                    choices=SHUFFLE_IMPLS,
                    help="svm family: SV merge transport of the sharded "
                         "fold programs (default: the arch config's)")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="svm family: durable per-stream ModelSnapshot "
                         "checkpoints (DESIGN.md §13)")
    ap.add_argument("--checkpoint-every", type=int, default=1,
                    help="svm family: waves between checkpoints")
    ap.add_argument("--restore", action="store_true",
                    help="svm family: rebuild the service from the "
                         "latest manifest in --checkpoint-dir instead "
                         "of retraining stream models")
    ap.add_argument("--checkpoint-keep", type=int, default=3,
                    help="svm family: snapshot generations retained; "
                         "restore falls back past corrupt ones "
                         "(DESIGN.md §15)")
    ap.add_argument("--no-quarantine", action="store_true",
                    help="svm family: fold non-finite batches instead "
                         "of diverting them at submit()")
    ap.add_argument("--fold-deadline", type=float, default=None,
                    help="svm family: watchdog deadline (s) per wave "
                         "fold — a stranded collective exits the "
                         "process with code 17 instead of hanging")
    ap.add_argument("--heartbeat", default=None,
                    help="svm family: path of the watchdog's JSON "
                         "heartbeat file (operators poll it)")
    add_cluster_flags(ap)
    args = ap.parse_args()

    # Before first backend use — see launch/cluster.py ordering contract.
    cluster = init_cluster(cluster_config_from_args(args))
    cfg = get_config(args.arch)
    if getattr(cfg, "family", None) == "svm":
        return serve_svm(cfg, args, cluster)
    if cluster.is_distributed:
        raise SystemExit(
            "multi-process launch currently covers the svm family")
    if args.smoke:
        cfg = smoke_variant(cfg)
    mesh = make_host_mesh(args.data_par, args.model_par, cluster=cluster)
    shape = InputShape("cli", "decode", args.cache_len, args.batch)
    bundle = build_serve_step(cfg, mesh, shape)
    model = bundle.model

    with compat.set_mesh(mesh):
        step_fn = jax.jit(
            bundle.fn,
            in_shardings=compat.to_shardings(mesh, bundle.in_shardings),
            out_shardings=compat.to_shardings(mesh, bundle.out_shardings),
            donate_argnums=bundle.donate_argnums)
        params = model.init(jax.random.PRNGKey(0))
        if cfg.is_encoder_decoder:
            frames = jax.random.normal(
                jax.random.PRNGKey(1),
                (args.batch, cfg.encoder_seq, cfg.d_model), cfg.jdtype)
            state = model.init_decode_state(args.batch, args.cache_len,
                                            frames=frames, params=params)
        else:
            state = model.init_decode_state(args.batch, args.cache_len)
        tok = jnp.zeros((args.batch, 1), jnp.int32)
        t0 = time.time()
        for i in range(args.tokens):
            tok, state = step_fn(params, state, tok)
            tok = tok[:, None]
        jax.block_until_ready(tok)
        dt = time.time() - t0
    print(f"{cfg.name}: {args.tokens} tokens × {args.batch} seqs "
          f"in {dt:.2f}s → {args.tokens * args.batch / dt:,.1f} tok/s")


if __name__ == "__main__":
    main()
