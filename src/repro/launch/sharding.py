"""Logical-axis → mesh sharding rules with divisibility fallbacks.

Baseline policy (recorded in EXPERIMENTS.md §Perf as "paper-faithful +
standard megatron/fsdp"; beyond-paper variants toggle these rules):

  vocab     → model      (vocab-parallel embedding + logits)
  ffn       → model      (megatron column/row)
  heads     → model      (attention head parallel)
  experts   → model      (expert parallel; falls back when E < 16)
  embed     → data       (ZeRO/FSDP: params+opt sharded over data)
  kv_heads  → replicated (cache sharding handled via kv_repeat)
  batch     → (pod, data)

A rule is skipped when the dim doesn't divide the mesh axis or the axis
is already used by another dim of the same tensor — the fallback chain
picks the next candidate, ending at replication.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.models.config import ModelConfig

# Ordered mesh-axis candidates per logical axis.
DEFAULT_RULES: Dict[Optional[str], Tuple[str, ...]] = {
    "vocab": ("model",),
    "embed": ("data",),
    "embed_out": ("model",),
    "ffn": ("model", "data"),
    "heads": ("model",),
    "heads_flat": ("model",),
    "kv_heads": (),
    "head_dim": (),
    "experts": ("model",),
    "layers": (),
    None: (),
}


def pspec_for(shape: Sequence[int], axes: Sequence[Optional[str]],
              mesh, rules: Optional[Dict] = None) -> P:
    """Pick a PartitionSpec for one tensor, honoring divisibility and
    one-mesh-axis-per-tensor constraints."""
    rules = rules or DEFAULT_RULES
    used = set()
    out = []
    for dim, ax in zip(shape, axes):
        choice = None
        for cand in rules.get(ax, ()):  # ordered candidates
            if cand in mesh.axis_names and cand not in used \
                    and dim % mesh.shape[cand] == 0:
                choice = cand
                break
        if choice:
            used.add(choice)
        out.append(choice)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def param_pspecs(model, mesh, rules: Optional[Dict] = None):
    """Walk the model's template → pytree of PartitionSpecs."""
    abstract = model.abstract()
    logical = model.logical_axes()
    return compat.tree_map(
        lambda a, ax: pspec_for(a.shape, ax, mesh, rules),
        abstract, logical,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def kv_repeat_for(cfg: ModelConfig, mesh) -> int:
    """Duplicate KV heads so the KV cache shards over ``model``.

    r is the smallest factor with (KV·r) % model == 0 and (KV·r) | H;
    r = 1 when impossible (cache replicated over model instead)."""
    m = mesh.shape.get("model", 1)
    KV, H = cfg.num_kv_heads, cfg.num_heads
    if cfg.attn_free or m == 1 or KV % m == 0:
        return 1
    r = m // math.gcd(KV, m)
    if (KV * r) % m == 0 and H % (KV * r) == 0:
        return r
    return 1


def batch_pspec(mesh, batch_size: int) -> P:
    """Shard batch over (pod, data) when divisible, else replicate."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    if axes and batch_size % dp == 0:
        return P(axes if len(axes) > 1 else axes[0])
    return P(None)


def leading_batch_specs(tree_abstract, mesh, batch_size: int):
    """Shard dim0 (batch) of every input leaf; rest replicated."""
    bp = batch_pspec(mesh, batch_size)
    def spec(a):
        rest = (None,) * (len(a.shape) - 1)
        return P(*(tuple(bp) + rest)) if bp != P(None) else P()
    return compat.tree_map(spec, tree_abstract,
                           is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


# ---------------------------------------------------------------------------
# Decode-state PartitionSpecs (per state family, by construction).
# ---------------------------------------------------------------------------

def _axis_if_divisible(mesh, axis: str, dim: int) -> Optional[str]:
    return axis if (axis in mesh.axis_names and dim % mesh.shape[axis] == 0) \
        else None


def decode_state_pspecs(model, state_abstract, mesh, batch_size: int):
    """PartitionSpecs for a decode-state pytree, keyed on leaf NAMES
    (NamedTuple fields), which are stable by construction:

      caches.k/v, shared_cache.*, cross_k/v : (L|nseg, B, KVr, S, hd)
                                               → B→batch, KVr→model
      S (RWKV wkv state)   : (L, B, H, hd, hd) → B→batch, H→model
      ssm.h (Mamba2)       : (nseg, slen, B, nh, P, N) → B→batch, nh→model
      ssm.conv_buf         : (nseg, slen, B, K, C) → B→batch, C→model
      x_prev_*             : (L, B, 1, D) → B→batch
      pos                  : () replicated
    """
    bp = batch_pspec(mesh, batch_size)
    b = tuple(bp) if bp != P(None) else (None,)
    md = lambda dim: _axis_if_divisible(mesh, "model", dim)

    paths, treedef = jax.tree_util.tree_flatten_with_path(state_abstract)
    specs = []
    for path, leaf in paths:
        name = str(path[-1]).strip(".")
        sh = leaf.shape
        if len(sh) == 0:
            specs.append(P())
        elif name in ("k", "v", "cross_k", "cross_v"):
            specs.append(P(None, *b, md(sh[2]), None, None))
        elif name == "S":
            specs.append(P(None, *b, md(sh[2]), None, None))
        elif name == "h":                       # (nseg, slen, B, nh, P, N)
            specs.append(P(None, None, *b, md(sh[3]), None, None))
        elif name == "conv_buf":                # (nseg, slen, B, K, C)
            specs.append(P(None, None, *b, None, md(sh[4])))
        elif name.startswith("x_prev"):         # (L, B, 1, D)
            specs.append(P(None, *b, None, None))
        else:
            specs.append(P())
    return jax.tree_util.tree_unflatten(treedef, specs)
