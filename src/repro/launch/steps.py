"""Step builders: training, prefill and decode programs with their
abstract inputs (ShapeDtypeStruct) and shardings — the unit the
multi-pod dry-run lowers and the real launchers execute.

Input-shape suite (assignment):
    train_4k     seq=4096    global_batch=256   (training)
    prefill_32k  seq=32768   global_batch=32    (inference-prefill)
    decode_32k   seq=32768   global_batch=128   (decode: 1 token, KV=seq)
    long_500k    seq=524288  global_batch=1     (long-context decode)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat, optim
from repro.launch import sharding as shd
from repro.launch.mesh import batch_axes
from repro.models.config import ModelConfig
from repro.models.transformer import build_model


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    kind: str          # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", "train", 4096, 256),
    "prefill_32k": InputShape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": InputShape("decode_32k", "decode", 32768, 128),
    "long_500k": InputShape("long_500k", "decode", 524288, 1),
}


def applicability(cfg: ModelConfig, shape: InputShape) -> Optional[str]:
    """None if the (arch, shape) pair runs; else a skip reason (DESIGN.md §4)."""
    if shape.name == "long_500k":
        sub_quadratic = (cfg.attn_free or cfg.family == "hybrid"
                         or cfg.sliding_window is not None)
        if cfg.is_encoder_decoder:
            return ("SKIP: encoder-decoder with architecturally capped "
                    "decoder context (448) — long_500k out of family range")
        if not sub_quadratic:
            return ("SKIP: pure full-attention arch — long_500k requires "
                    "sub-quadratic attention (no SWA variant in model card)")
    return None


# ---------------------------------------------------------------------------
# Abstract batch construction
# ---------------------------------------------------------------------------

def train_batch_abstract(cfg: ModelConfig, shape: InputShape):
    B, S = shape.global_batch, shape.seq_len
    i32 = lambda s: jax.ShapeDtypeStruct(s, jnp.int32)
    f = lambda s: jax.ShapeDtypeStruct(s, cfg.jdtype)
    if cfg.is_encoder_decoder:
        S_dec = min(S, cfg.max_decoder_len)
        return {"frames": f((B, cfg.encoder_seq, cfg.d_model)),
                "tokens": i32((B, S_dec)), "labels": i32((B, S_dec))}
    if cfg.frontend == "vision":
        P_tok = cfg.num_prefix_tokens
        return {"prefix_embeds": f((B, P_tok, cfg.d_model)),
                "tokens": i32((B, S - P_tok)), "labels": i32((B, S - P_tok))}
    return {"tokens": i32((B, S)), "labels": i32((B, S))}


# ---------------------------------------------------------------------------
# Step builders. Each returns (fn, args_abstract, in_shardings,
# out_shardings, donate_argnums).
# ---------------------------------------------------------------------------

class StepBundle(NamedTuple):
    fn: Callable
    args: Tuple
    in_shardings: Tuple
    out_shardings: Any
    donate_argnums: Tuple[int, ...]
    model: Any


def build_train_step(cfg: ModelConfig, mesh, shape: InputShape,
                     opt_cfg: Optional[optim.OptConfig] = None,
                     rules: Optional[dict] = None,
                     remat: bool = True) -> StepBundle:
    if remat and not cfg.remat:
        cfg = dataclasses.replace(cfg, remat=True)
    kv_r = shd.kv_repeat_for(cfg, mesh)
    model = build_model(cfg, kv_repeat=kv_r, mesh=mesh)
    opt_cfg = opt_cfg or optim.OptConfig()

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(params, batch)
        params, opt_state, om = optim.apply_updates(
            params, grads, opt_state, opt_cfg)
        return params, opt_state, {"loss": loss, **metrics, **om}

    params_abs = model.abstract()
    opt_abs = optim.abstract_state(params_abs)
    batch_abs = train_batch_abstract(cfg, shape)

    pspecs = shd.param_pspecs(model, mesh, rules)
    opt_specs = optim.OptState(mu=pspecs, nu=pspecs, step=P())
    batch_specs = shd.leading_batch_specs(batch_abs, mesh, shape.global_batch)
    metric_specs = {k: P() for k in
                    ("loss", "ce", "aux", "lr", "grad_norm")}
    return StepBundle(
        fn=train_step,
        args=(params_abs, opt_abs, batch_abs),
        in_shardings=(pspecs, opt_specs, batch_specs),
        out_shardings=(pspecs, opt_specs, metric_specs),
        donate_argnums=(0, 1),
        model=model)


def build_prefill_step(cfg: ModelConfig, mesh, shape: InputShape,
                       rules: Optional[dict] = None) -> StepBundle:
    kv_r = shd.kv_repeat_for(cfg, mesh)
    model = build_model(cfg, kv_repeat=kv_r, mesh=mesh)

    def prefill_step(params, batch):
        """Full-context forward; emit last-position logits only (the
        production prefill result; full logits would be B·S·V)."""
        if cfg.is_encoder_decoder:
            enc = model.encode(params, batch["frames"])
            h, _ = model.hidden_states(params, batch["tokens"], enc)
        else:
            h, _ = model.hidden_states(params, batch["tokens"],
                                       batch.get("prefix_embeds"))
        from repro.models.layers import lm_logits
        last = h[:, -1:, :]
        return lm_logits(params["embed"], last, cfg.tie_embeddings)

    batch_abs = train_batch_abstract(cfg, shape)
    batch_abs.pop("labels")
    params_abs = model.abstract()
    pspecs = shd.param_pspecs(model, mesh, rules)
    batch_specs = shd.leading_batch_specs(batch_abs, mesh, shape.global_batch)
    out_spec = shd.batch_pspec(mesh, shape.global_batch)
    out = P(*(tuple(out_spec) + (None, None))) if out_spec != P(None) else P()
    return StepBundle(
        fn=prefill_step,
        args=(params_abs, batch_abs),
        in_shardings=(pspecs, batch_specs),
        out_shardings=out,
        donate_argnums=(),
        model=model)


def build_serve_step(cfg: ModelConfig, mesh, shape: InputShape,
                     rules: Optional[dict] = None) -> StepBundle:
    """One decode step: new token given a seq_len-deep cache/state."""
    kv_r = shd.kv_repeat_for(cfg, mesh)
    model = build_model(cfg, kv_repeat=kv_r)
    B = shape.global_batch

    def serve_step(params, state, tokens):
        logits, state = model.decode_step(params, state, tokens)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok, state

    params_abs = model.abstract()
    state_abs = model.decode_state_abstract(B, shape.seq_len)
    tok_abs = jax.ShapeDtypeStruct((B, 1), jnp.int32)

    pspecs = shd.param_pspecs(model, mesh, rules)
    state_specs = shd.decode_state_pspecs(model, state_abs, mesh, B)
    bp = shd.batch_pspec(mesh, B)
    tok_spec = P(*(tuple(bp) + (None,))) if bp != P(None) else P()
    out_tok_spec = bp if bp != P(None) else P()
    return StepBundle(
        fn=serve_step,
        args=(params_abs, state_abs, tok_abs),
        in_shardings=(pspecs, state_specs, tok_spec),
        out_shardings=(out_tok_spec, state_specs),
        donate_argnums=(1,),
        model=model)


def build_step(cfg: ModelConfig, mesh, shape: InputShape,
               rules: Optional[dict] = None, **kw) -> StepBundle:
    if shape.kind == "train":
        return build_train_step(cfg, mesh, shape, rules=rules, **kw)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, mesh, shape, rules=rules)
    return build_serve_step(cfg, mesh, shape, rules=rules)


def per_host_abstract(args, in_shardings, mesh, num_processes: int):
    """Per-process LOCAL view of a bundle's abstract inputs.

    Step builders consume globally-sharded abstract inputs; at launch
    each of the ``num_processes`` hosts materializes only its block of
    every data-sharded dimension and assembles the global array via
    ``Cluster.make_global_array`` (DESIGN.md §11). This maps the global
    ``ShapeDtypeStruct`` pytree to that per-host shape — what one
    host's loader must produce — assuming the data axes span the
    process dimension (the ``make_cluster_mesh`` layout). Used by
    ``dryrun --processes N`` to record multi-host input shapes without
    running multi-host.
    """
    from jax.sharding import PartitionSpec
    data_ax = set(batch_axes(mesh))

    def one(a, spec):
        if not isinstance(spec, PartitionSpec):
            return a
        shape = list(a.shape)
        for i, ax in enumerate(spec):
            axes = (ax,) if isinstance(ax, str) else tuple(ax or ())
            if set(axes) & data_ax:
                if shape[i] % num_processes:
                    raise ValueError(
                        f"dim {i} of {tuple(a.shape)} does not split "
                        f"over {num_processes} processes")
                shape[i] //= num_processes
        return jax.ShapeDtypeStruct(tuple(shape), a.dtype)

    # Specs may sit ABOVE the args' leaf structure (shard_map prefix
    # semantics: one P broadcast over a whole SparseRows subtree), so
    # flatten by the SPECS' treedef — with PartitionSpec pinned as a
    # leaf, whether the installed jax treats it as a tuple or not —
    # and map each spec over its entire args subtree.
    spec_flat, spec_tree = jax.tree_util.tree_flatten(
        in_shardings, is_leaf=lambda s: isinstance(s, PartitionSpec))
    subtrees = spec_tree.flatten_up_to(args)
    mapped = [jax.tree_util.tree_map(functools.partial(one, spec=s), sub)
              for sub, s in zip(subtrees, spec_flat)]
    return jax.tree_util.tree_unflatten(spec_tree, mapped)


# ---------------------------------------------------------------------------
# The paper's own workload as a dry-runnable step (svm-tfidf "arch").
# ---------------------------------------------------------------------------

def _svm_shuffle(svm_cfg, shuffle_impl: Optional[str]) -> str:
    """Merge-transport choice: explicit override > config default."""
    return shuffle_impl if shuffle_impl is not None \
        else getattr(svm_cfg, "shuffle_impl", "allgather")


def _svm_mr_cfg(svm_cfg, shuffle_impl: Optional[str], ndev: int):
    """MRSVMConfig for a launch step. For the two-level hier transport
    the host count comes from the real process topology when there is
    one, else from ``simulated_hier_hosts`` so single-process dry-runs
    still lower a non-degenerate two-level schedule (DESIGN.md §16)."""
    from repro.core.mapreduce_svm import MRSVMConfig
    from repro.launch.mesh import simulated_hier_hosts

    shuffle = _svm_shuffle(svm_cfg, shuffle_impl)
    hosts = simulated_hier_hosts(ndev) if shuffle == "hier" else None
    return MRSVMConfig(
        sv_capacity=svm_cfg.sv_capacity,
        shuffle_impl=shuffle,
        hier_num_hosts=hosts,
        svm=_svm_solver_cfg(svm_cfg))


def _svm_solver_cfg(svm_cfg):
    """Reducer SVMConfig from the workload config, carrying the row
    format (DESIGN.md §12) so the whole sharded program — SV buffers,
    wire packing, Gram path — keys off one switch."""
    from repro.core.svm import SVMConfig
    rf = getattr(svm_cfg, "row_format", "dense")
    return SVMConfig(
        C=svm_cfg.C, max_epochs=svm_cfg.max_epochs, row_format=rf,
        nnz_cap=getattr(svm_cfg, "nnz_cap", 0) if rf == "sparse_csr"
        else 0)


def _svm_rows_abstract(svm_cfg, shape, dt):
    """Abstract row batch for the workload's row format: a dense
    ShapeDtypeStruct, or a SparseRows whose two leaves are
    ShapeDtypeStructs (the pytree the dry-run lowers against)."""
    from repro import sparse as sparse_rows
    if getattr(svm_cfg, "row_format", "dense") != "sparse_csr":
        return jax.ShapeDtypeStruct(shape, dt)
    lead = tuple(shape[:-1]) + (svm_cfg.nnz_cap,)
    return sparse_rows.SparseRows(
        jax.ShapeDtypeStruct(lead, jnp.int32),
        jax.ShapeDtypeStruct(lead, dt), shape[-1])


def build_svm_round_step(svm_cfg, mesh,
                         shuffle_impl: Optional[str] = None) -> StepBundle:
    """One MapReduce-SVM round on the production mesh: rows sharded over
    (pod,)data; the SV merge 'shuffle' is the all-gather or the
    ring-pipelined transport per ``shuffle_impl`` (DESIGN.md §2/§10)."""
    import numpy as np
    from repro.core.mapreduce_svm import SVBuffer, make_sharded_round

    axes = batch_axes(mesh)
    ndev = int(np.prod([mesh.shape[a] for a in axes]))
    per = svm_cfg.rows_per_device
    n, d = ndev * per, svm_cfg.num_features
    mr_cfg = _svm_mr_cfg(svm_cfg, shuffle_impl, ndev)
    body = make_sharded_round(mr_cfg, axes, ndev, per)
    row_spec = P(axes if len(axes) > 1 else axes[0])
    rep = SVBuffer(x=P(), y=P(), alpha=P(), ids=P(), mask=P())
    fn = compat.shard_map(
        body, mesh=mesh,
        in_specs=(row_spec, row_spec, row_spec, rep),
        out_specs=(rep, P(), P(), P()),
        check_vma=False)

    dt = jnp.dtype(svm_cfg.dtype)
    args = (_svm_rows_abstract(svm_cfg, (n, d), dt),
            jax.ShapeDtypeStruct((n,), dt),
            jax.ShapeDtypeStruct((n,), dt),
            SVBuffer(
                x=_svm_rows_abstract(svm_cfg, (svm_cfg.sv_capacity, d), dt),
                y=jax.ShapeDtypeStruct((svm_cfg.sv_capacity,), dt),
                alpha=jax.ShapeDtypeStruct((svm_cfg.sv_capacity,), dt),
                ids=jax.ShapeDtypeStruct((svm_cfg.sv_capacity,), jnp.int32),
                mask=jax.ShapeDtypeStruct((svm_cfg.sv_capacity,), dt)))
    return StepBundle(
        fn=fn, args=args,
        in_shardings=(row_spec, row_spec, row_spec, rep),
        out_shardings=(rep, P(), P(), P()),
        donate_argnums=(),
        model=None)


def build_svm_sweep_step(svm_cfg, mesh, num_configs: int,
                         shuffle_impl: Optional[str] = None) -> StepBundle:
    """S MapReduce-SVM jobs per round on the production mesh: one jit,
    one device pass, S models — the sweep subsystem's vmap-over-configs
    inside the shard_map round body (repro.core.sweep). Under the ring
    transport the S buffers additionally ride the cross-config dedup
    wire format (DESIGN.md §10)."""
    import numpy as np
    from repro.core.svm import SolverParams
    from repro.core.sweep import init_sharded_sweep_sv, sharded_sweep_program

    axes = batch_axes(mesh)
    ndev = int(np.prod([mesh.shape[a] for a in axes]))
    per = svm_cfg.rows_per_device
    n, d = ndev * per, svm_cfg.num_features
    S = num_configs
    cap = svm_cfg.sv_capacity
    mr_cfg = _svm_mr_cfg(svm_cfg, shuffle_impl, ndev)
    fn, in_specs, out_specs = sharded_sweep_program(mesh, axes, mr_cfg, per)

    dt = jnp.dtype(svm_cfg.dtype)
    f32 = jnp.float32
    # abstract SV state: the (S, cap, …) buffer, or the shared-row dedup
    # state under the ring transport (same pytree the driver would init)
    sv_abs = jax.eval_shape(
        lambda: init_sharded_sweep_sv(mr_cfg, S, d, ndev, per, dt))
    args = (_svm_rows_abstract(svm_cfg, (n, d), dt),
            jax.ShapeDtypeStruct((n,), dt),
            jax.ShapeDtypeStruct((n,), dt),
            sv_abs,
            SolverParams(*(jax.ShapeDtypeStruct((S,), f32)
                           for _ in SolverParams._fields)))
    return StepBundle(
        fn=fn, args=args,
        in_shardings=in_specs,
        out_shardings=out_specs,
        donate_argnums=(),
        model=None)


def build_svm_serve_step(svm_cfg, mesh, num_streams: int = 4,
                         shuffle_impl: Optional[str] = None) -> StepBundle:
    """One streaming update WAVE on the production mesh: S tenant
    streams each fold (new rows ∪ carried SVs) in a single jitted
    device pass — the sweep program with per-stream data
    (repro.core.sweep.sharded_sweep_program(per_config_data=True),
    the device-side shape of repro.serving.svm_stream's batched fold).
    Rows per stream = stream_rows_per_wave new messages + the carried
    SV capacity, sharded over the data axes."""
    import numpy as np
    from repro.core.svm import SolverParams
    from repro.core.sweep import init_sharded_sweep_sv, sharded_sweep_program

    axes = batch_axes(mesh)
    ndev = int(np.prod([mesh.shape[a] for a in axes]))
    cap = svm_cfg.sv_capacity
    wave_rows = svm_cfg.stream_rows_per_wave + cap
    per = -(-wave_rows // ndev)
    n, d = ndev * per, svm_cfg.num_features
    S = num_streams
    mr_cfg = _svm_mr_cfg(svm_cfg, shuffle_impl, ndev)
    fn, in_specs, out_specs = sharded_sweep_program(
        mesh, axes, mr_cfg, per, per_config_data=True)

    dt = jnp.dtype(svm_cfg.dtype)
    f32 = jnp.float32
    sv_abs = jax.eval_shape(
        lambda: init_sharded_sweep_sv(mr_cfg, S, d, ndev, per, dt,
                                      per_config_data=True))
    args = (_svm_rows_abstract(svm_cfg, (S, n, d), dt),
            jax.ShapeDtypeStruct((S, n), dt),
            jax.ShapeDtypeStruct((S, n), dt),
            sv_abs,
            SolverParams(*(jax.ShapeDtypeStruct((S,), f32)
                           for _ in SolverParams._fields)))
    return StepBundle(
        fn=fn, args=args,
        in_shardings=in_specs,
        out_shardings=out_specs,
        donate_argnums=(),
        model=None)
