"""Production training entry point.

Builds the sharded train_step for ``--arch`` on the cluster's device
mesh (one process, N CPU processes via --coordinator/--num-processes/
--process-id, or the production mesh on a real TPU slice), runs the
data pipeline, checkpoints, and logs. On this CPU container use
``--smoke`` to train the reduced variant; the full configs are
exercised by dryrun.py.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --smoke --steps 50 --batch 8 --seq 128

Multi-process (each line its own host/process; see
examples/multihost_svm.py for a self-spawning demo):

    PYTHONPATH=src python -m repro.launch.train --arch svm-tfidf --smoke \
        --coordinator localhost:9911 --num-processes 2 --process-id 0
"""
from __future__ import annotations

import argparse
import time

import jax

from repro import compat
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import optim
from repro.ckpt import save
from repro.configs import get_config
from repro.data import DataConfig, lm_batch_at, svm_rows_shard
from repro.launch.cluster import (add_cluster_flags, cluster_config_from_args,
                                  init_cluster)
from repro.launch.mesh import make_host_mesh, simulated_hier_hosts
from repro.launch.steps import InputShape, build_train_step
from repro.models.config import smoke_variant


def train_svm(svm_cfg, args, cluster) -> None:
    """MapReduce-SVM training mode (``--arch svm-tfidf``): rows sharded
    over the data mesh, rounds driven on the host. ``--sweep S`` runs S
    (C, γ) hyper-parameter configs per round as one batched program —
    the vmap-over-configs sweep subsystem (repro.core.sweep).

    Process-count-agnostic (DESIGN.md §11): each process loads only its
    disjoint TF×IDF row shard (``svm_rows_shard``) and assembles the
    global arrays via ``cluster.make_global_array``; the sharded round
    itself is the SAME program at any process count.
    """
    import dataclasses as dc

    from repro.core.mapreduce_svm import (MRSVMConfig, build_sharded_round,
                                          init_sv_buffer)
    from repro.core.svm import SVMConfig
    from repro.core.sweep import (build_sharded_sweep_round,
                                  run_sharded_sweep, sweep_grid)

    if args.smoke:
        svm_cfg = dc.replace(svm_cfg, num_features=256, sv_capacity=64,
                             rows_per_device=64, dtype="float32")
    say = print if cluster.is_coordinator else (lambda *a, **k: None)
    ndev = cluster.device_count
    per = args.rows_per_device or svm_cfg.rows_per_device
    n, d = ndev * per, svm_cfg.num_features
    mesh = make_host_mesh(ndev, 1, cluster=cluster)
    rounds = max(1, args.rounds)
    shuffle = args.shuffle or getattr(svm_cfg, "shuffle_impl", "allgather")
    hosts = simulated_hier_hosts(ndev) if shuffle == "hier" else None
    cfg = MRSVMConfig(sv_capacity=svm_cfg.sv_capacity,
                      gamma=1e-4, max_rounds=rounds,
                      shuffle_impl=shuffle, hier_num_hosts=hosts,
                      svm=SVMConfig(C=svm_cfg.C,
                                    max_epochs=svm_cfg.max_epochs))

    dt = jnp.dtype(svm_cfg.dtype)
    Xl, yl = svm_rows_shard(n, d, seed=0,
                            process_index=cluster.process_index,
                            process_count=cluster.process_count)
    X = cluster.make_global_array(mesh, P("data"), Xl.astype(dt), (n, d))
    y = cluster.make_global_array(mesh, P("data"), yl.astype(dt), (n,))
    say(f"svm-tfidf: {n} rows × {d} features over {ndev} devices, "
        f"{cluster.process_count} process(es) "
        f"({Xl.shape[0]} rows loaded per host)")

    # Accuracy is reported on the process-local shard: the selected
    # hypothesis (w, b) is replicated, so this needs NO extra collective
    # and equals the global accuracy at one process.
    def local_acc(w_, b_):
        s = Xl.astype(np.float32) @ np.asarray(w_, np.float32).T \
            + np.asarray(b_, np.float32)
        return (np.sign(s) == (yl[:, None] if s.ndim > 1
                               else yl)).mean(axis=0)

    if args.sweep >= 1:
        params = sweep_grid(
            cfg.svm,
            C=np.logspace(-2, 1, args.sweep).astype(np.float32))
        round_fn = build_sharded_sweep_round(mesh, ("data",), cfg, per)
        t0 = time.time()
        out = run_sharded_sweep(round_fn, X, y, None, cfg, params,
                                verbose=cluster.is_coordinator)
        dt_s = time.time() - t0
        accs = local_acc(out.ws, out.bs)
        for s in range(args.sweep):
            say(f"  config C={float(params.C[s]):<8.4g} "
                f"R_emp={float(out.risks[s]):.4f} acc={accs[s]:.3f} "
                f"rounds={int(out.rounds[s])}")
        say(f"sweep selected C={float(params.C[out.best]):.4g} "
            f"({args.sweep} configs, one jit, {dt_s:.1f}s)")
        return

    round_fn = build_sharded_round(mesh, ("data",), cfg, per)
    sv = init_sv_buffer(cfg.sv_capacity, d, X.dtype)
    mask = cluster.make_global_array(
        mesh, P("data"), np.ones((Xl.shape[0],), Xl.dtype).astype(dt), (n,))
    prev = float("inf")
    for t in range(rounds):
        sv, risks, w, b = round_fn(X, y, mask, sv)
        r = float(jnp.min(risks))
        say(f"round {t}: R_emp={r:.4f} |SV|={int(jnp.sum(sv.mask))}")
        if t > 0 and abs(prev - r) <= cfg.gamma:
            break
        prev = r
    say(f"best-reducer accuracy: {float(local_acc(w, b)):.3f}"
        + (" (host-local shard)" if cluster.is_distributed else ""))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced variant (CPU)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--data-par", type=int, default=1)
    ap.add_argument("--model-par", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--sweep", type=int, default=0,
                    help="svm family: run S hyper-param configs per "
                         "round as one batched sweep")
    ap.add_argument("--rounds", type=int, default=6,
                    help="svm family: MapReduce rounds")
    ap.add_argument("--rows-per-device", type=int, default=0,
                    help="svm family: override rows per device")
    from repro.core.mapreduce_svm import SHUFFLE_IMPLS
    ap.add_argument("--shuffle", default=None,
                    choices=SHUFFLE_IMPLS,
                    help="svm family: SV merge transport (default: the "
                         "arch config's shuffle_impl)")
    add_cluster_flags(ap)
    args = ap.parse_args()

    # BEFORE anything touches a device: the distributed client and the
    # CPU collectives wire into the backend at first init (DESIGN.md §11).
    cluster = init_cluster(cluster_config_from_args(args))
    cfg = get_config(args.arch)
    if getattr(cfg, "family", None) == "svm":
        return train_svm(cfg, args, cluster)
    if cluster.is_distributed:
        raise SystemExit(
            "multi-process launch currently covers the svm family; the "
            "LM data pipeline still materializes full global batches")
    if args.smoke:
        cfg = smoke_variant(cfg)
    mesh = make_host_mesh(args.data_par, args.model_par, cluster=cluster)
    shape = InputShape("cli", "train", args.seq, args.batch)
    bundle = build_train_step(cfg, mesh, shape, remat=False)
    model = bundle.model

    with compat.set_mesh(mesh):
        step_fn = jax.jit(
            bundle.fn,
            in_shardings=compat.to_shardings(mesh, bundle.in_shardings),
            out_shardings=compat.to_shardings(mesh, bundle.out_shardings),
            donate_argnums=bundle.donate_argnums)
        params = model.init(jax.random.PRNGKey(0))
        opt_state = optim.init(params)
        dcfg = DataConfig(batch_size=args.batch, seq_len=args.seq)
        t0 = time.time()
        for step in range(args.steps):
            batch = {k: jnp.asarray(v)
                     for k, v in lm_batch_at(dcfg, cfg, step).items()}
            params, opt_state, m = step_fn(params, opt_state, batch)
            if step % 10 == 0 or step == args.steps - 1:
                print(f"step {step:4d} loss {float(m['loss']):.4f} "
                      f"gnorm {float(m['grad_norm']):.2f} "
                      f"{(step + 1) * args.batch * args.seq / (time.time() - t0):,.0f} tok/s",
                      flush=True)
    if args.ckpt:
        save(args.ckpt, {"params": params}, step=args.steps)
        print(f"saved {args.ckpt}")


if __name__ == "__main__":
    main()
