"""Production training entry point.

Builds the sharded train_step for ``--arch`` on the local device mesh
(or the production mesh on a real TPU slice), runs the data pipeline,
checkpoints, and logs. On this CPU container use ``--smoke`` to train
the reduced variant; the full configs are exercised by dryrun.py.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --smoke --steps 50 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax

from repro import compat
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import optim
from repro.ckpt import save
from repro.configs import get_config
from repro.data import DataConfig, lm_batch_at
from repro.launch import sharding as shd
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import InputShape, build_train_step
from repro.models.config import smoke_variant


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced variant (CPU)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--data-par", type=int, default=1)
    ap.add_argument("--model-par", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    mesh = make_host_mesh(args.data_par, args.model_par)
    shape = InputShape("cli", "train", args.seq, args.batch)
    bundle = build_train_step(cfg, mesh, shape, remat=False)
    model = bundle.model

    with compat.set_mesh(mesh):
        step_fn = jax.jit(
            bundle.fn,
            in_shardings=compat.to_shardings(mesh, bundle.in_shardings),
            out_shardings=compat.to_shardings(mesh, bundle.out_shardings),
            donate_argnums=bundle.donate_argnums)
        params = model.init(jax.random.PRNGKey(0))
        opt_state = optim.init(params)
        dcfg = DataConfig(batch_size=args.batch, seq_len=args.seq)
        t0 = time.time()
        for step in range(args.steps):
            batch = {k: jnp.asarray(v)
                     for k, v in lm_batch_at(dcfg, cfg, step).items()}
            params, opt_state, m = step_fn(params, opt_state, batch)
            if step % 10 == 0 or step == args.steps - 1:
                print(f"step {step:4d} loss {float(m['loss']):.4f} "
                      f"gnorm {float(m['grad_norm']):.2f} "
                      f"{(step + 1) * args.batch * args.seq / (time.time() - t0):,.0f} tok/s",
                      flush=True)
    if args.ckpt:
        save(args.ckpt, {"params": params}, step=args.steps)
        print(f"saved {args.ckpt}")


if __name__ == "__main__":
    main()
