from repro.metrics.logger import MetricsLogger, read_jsonl
