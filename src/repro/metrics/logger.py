"""Structured run metrics: JSONL stream + rolling aggregates.

The framework's observability layer (stands in for the TB/W&B sink a
real deployment would attach). Pure stdlib; safe on any host.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, List, Optional


class MetricsLogger:
    def __init__(self, run_dir: Optional[str] = None, run_name: str = "run",
                 flush_every: int = 20):
        self.run_dir = run_dir
        self.run_name = run_name
        self.flush_every = flush_every
        self._buf: List[Dict[str, Any]] = []
        self._t0 = time.time()
        self._path = None
        if run_dir:
            os.makedirs(run_dir, exist_ok=True)
            self._path = os.path.join(run_dir, f"{run_name}.jsonl")
            # truncate previous run of the same name
            open(self._path, "w").close()

    def log(self, step: int, **metrics: float) -> None:
        rec = {"step": step, "t": round(time.time() - self._t0, 3)}
        rec.update({k: float(v) for k, v in metrics.items()})
        self._buf.append(rec)
        if self._path and len(self._buf) % self.flush_every == 0:
            self.flush()

    def flush(self) -> None:
        if self._path and self._buf:
            with open(self._path, "a") as f:
                for rec in self._buf:
                    f.write(json.dumps(rec) + "\n")
            self._buf.clear()

    def summary(self, key: str, last_k: int = 20) -> Dict[str, float]:
        vals = [r[key] for r in self._buf if key in r]
        if self._path and os.path.exists(self._path):
            with open(self._path) as f:
                vals = [json.loads(l).get(key) for l in f
                        if key in l] + vals
        vals = [v for v in vals if v is not None]
        if not vals:
            return {}
        tail = vals[-last_k:]
        return {"last": vals[-1], "min": min(vals), "max": max(vals),
                "mean_tail": sum(tail) / len(tail), "n": len(vals)}


def read_jsonl(path: str) -> List[Dict[str, Any]]:
    with open(path) as f:
        return [json.loads(l) for l in f if l.strip()]
