"""Model zoo: assigned-architecture backbones (DESIGN.md §4)."""
from repro.models.config import ModelConfig, smoke_variant
from repro.models.transformer import TransformerModel, build_model

__all__ = ["ModelConfig", "smoke_variant", "TransformerModel", "build_model"]
