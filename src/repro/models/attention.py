"""GQA attention: training/prefill (query-chunked, memory-safe) and
single-token decode against a (optionally sliding-window) KV cache.

TPU adaptations:
* query-chunked softmax(QKᵀ)V — scores never materialize beyond
  (B, heads, q_chunk, S), the HLO-level analogue of flash attention
  (the Pallas decode kernel in repro.kernels goes further for the
  hot decode path).
* ``kv_repeat``: when tensor-parallel degree exceeds num_kv_heads, KV
  heads are physically duplicated r× so the KV cache shards over the
  ``model`` axis (Megatron convention; chosen by launch/sharding.py).
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import PSpec, apply_rope

_NEG_INF = -1e30
_Q_CHUNK = 512


def attn_template(cfg: ModelConfig, d_in: Optional[int] = None) -> Dict[str, PSpec]:
    d = d_in or cfg.d_model
    hd, H, KV = cfg.hd, cfg.num_heads, cfg.num_kv_heads
    t = {
        "wq": PSpec((d, H, hd), ("embed", "heads", "head_dim"), "normal", d),
        "wk": PSpec((d, KV, hd), ("embed", "kv_heads", "head_dim"),
                    "normal", d),
        "wv": PSpec((d, KV, hd), ("embed", "kv_heads", "head_dim"),
                    "normal", d),
        "wo": PSpec((H, hd, d), ("heads", "head_dim", "embed"), "normal",
                    H * hd),
    }
    if cfg.qkv_bias:
        t["bq"] = PSpec((H, hd), ("heads", "head_dim"), "zeros")
        t["bk"] = PSpec((KV, hd), ("kv_heads", "head_dim"), "zeros")
        t["bv"] = PSpec((KV, hd), ("kv_heads", "head_dim"), "zeros")
    return t


def _project_qkv(p, x, kv_x, cfg: ModelConfig, kv_repeat: int):
    # preferred_element_type = activation dtype: without it jnp.einsum
    # asks XLA for an f32 accumulator and GSPMD all-reduces the f32
    # partial sums — 2× the sharded-matmul collective bytes (§Perf it.2).
    pe = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"], preferred_element_type=pe)
    k = jnp.einsum("bsd,dhk->bshk", kv_x, p["wk"], preferred_element_type=pe)
    v = jnp.einsum("bsd,dhk->bshk", kv_x, p["wv"], preferred_element_type=pe)
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if kv_repeat > 1:
        k = jnp.repeat(k, kv_repeat, axis=2)
        v = jnp.repeat(v, kv_repeat, axis=2)
    return q, k, v


def _grouped_scores(q, k):
    """q: (B,Sq,H,hd) k: (B,Sk,KVr,hd) → scores (B,KVr,G,Sq,Sk)."""
    B, Sq, H, hd = q.shape
    KVr = k.shape[2]
    G = H // KVr
    qg = q.reshape(B, Sq, KVr, G, hd)
    return jnp.einsum("bskgh,btkh->bkgst", qg, k) / jnp.sqrt(hd).astype(q.dtype)


def _grouped_out(probs, v, H):
    """probs (B,KVr,G,Sq,Sk), v (B,Sk,KVr,hd) → (B,Sq,H,hd)."""
    B, KVr, G, Sq, Sk = probs.shape
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
    return out.reshape(B, Sq, KVr * G, out.shape[-1])


def attention(p, x: jax.Array, cfg: ModelConfig, *,
              positions: jax.Array,
              kv_repeat: int = 1,
              causal: bool = True,
              kv_x: Optional[jax.Array] = None,
              kv_positions: Optional[jax.Array] = None) -> jax.Array:
    """Full-sequence attention (training / prefill / encoder / cross).

    x: (B, S, D); positions: (B, S). ``kv_x`` switches to cross-attention
    (no causal mask, no RoPE sharing assumptions beyond positions).
    """
    B, S, D = x.shape
    H, hd = cfg.num_heads, cfg.hd
    self_attn = kv_x is None
    kv_x = x if self_attn else kv_x
    kv_pos = positions if self_attn else kv_positions
    q, k, v = _project_qkv(p, x, kv_x, cfg, kv_repeat)
    if self_attn:   # RoPE only for self-attention stacks that use it
        if cfg.rope_fraction > 0:
            q = apply_rope(q, positions, cfg.rope_fraction, cfg.rope_theta)
            k = apply_rope(k, kv_pos, cfg.rope_fraction, cfg.rope_theta)
    Sk = k.shape[1]
    window = cfg.sliding_window

    def block_attend(q_blk, qpos_blk):
        scores = _grouped_scores(q_blk, k).astype(jnp.float32)
        mask = jnp.ones((B, q_blk.shape[1], Sk), bool)
        if causal:
            mask &= qpos_blk[:, :, None] >= kv_pos[:, None, :]
        if window is not None:
            mask &= qpos_blk[:, :, None] - kv_pos[:, None, :] < window
        scores = jnp.where(mask[:, None, None, :, :], scores, _NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        return _grouped_out(probs, v, H)

    if S > _Q_CHUNK and S % _Q_CHUNK == 0:
        nblk = S // _Q_CHUNK
        qb = q.reshape(B, nblk, _Q_CHUNK, H, hd).transpose(1, 0, 2, 3, 4)
        pb = positions.reshape(B, nblk, _Q_CHUNK).transpose(1, 0, 2)
        # jax.checkpoint per q-block: the (B, heads, chunk, S) probs are
        # recomputed in the backward instead of being saved for every
        # block — O(S²) attention residuals become O(S·chunk)
        # (§Perf iteration 1; before: 112 GB/dev temp on tinyllama train).
        blk = jax.checkpoint(lambda args: block_attend(*args))
        out = jax.lax.map(blk, (qb, pb))
        out = out.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)
    else:
        out = block_attend(q, positions)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"],
                      preferred_element_type=x.dtype)


# ---------------------------------------------------------------------------
# Decode: one token vs a KV cache (ring buffer when sliding window).
# ---------------------------------------------------------------------------

class LayerKVCache(NamedTuple):
    k: jax.Array          # (B, KVr, S_cache, hd)
    v: jax.Array          # (B, KVr, S_cache, hd)


def init_layer_cache(cfg: ModelConfig, batch: int, seq_len: int,
                     kv_repeat: int, dtype) -> LayerKVCache:
    S = min(seq_len, cfg.sliding_window) if cfg.sliding_window else seq_len
    KVr = cfg.num_kv_heads * kv_repeat
    shape = (batch, KVr, S, cfg.hd)
    return LayerKVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


def cache_slot_positions(cfg: ModelConfig, cache_len: int,
                         pos: jax.Array) -> jax.Array:
    """Absolute position held by each ring-buffer slot at decode step ``pos``.

    Full cache: slot j holds position j (valid if j <= pos).
    Sliding window W: slot j holds the largest p ≤ pos with p % W == j.
    """
    slots = jnp.arange(cache_len)
    if not cfg.sliding_window:
        return slots
    W = cache_len
    cur = pos % W
    return jnp.where(slots <= cur, pos - cur + slots, pos - cur + slots - W)


def attention_decode_step(p, x: jax.Array, cache: LayerKVCache,
                          pos: jax.Array, cfg: ModelConfig,
                          kv_repeat: int = 1,
                          use_pallas: bool = False) -> Tuple[jax.Array,
                                                             LayerKVCache]:
    """x: (B, 1, D); pos: () int32 current absolute position.

    ``use_pallas`` routes the cache attention through the flash-decode
    Pallas kernel (repro.kernels) — the TPU serving hot path; requires
    a full (non-ring) cache.
    """
    B, _, D = x.shape
    H, hd = cfg.num_heads, cfg.hd
    q, k, v = _project_qkv(p, x, x, cfg, kv_repeat)      # (B,1,·,hd)
    posb = jnp.broadcast_to(pos[None], (B,))[:, None]    # (B,1)
    if cfg.rope_fraction > 0:
        q = apply_rope(q, posb, cfg.rope_fraction, cfg.rope_theta)
        k = apply_rope(k, posb, cfg.rope_fraction, cfg.rope_theta)

    S_cache = cache.k.shape[2]
    slot = (pos % S_cache).astype(jnp.int32)
    k_new = jax.lax.dynamic_update_slice(
        cache.k, k.transpose(0, 2, 1, 3), (0, 0, slot, 0))
    v_new = jax.lax.dynamic_update_slice(
        cache.v, v.transpose(0, 2, 1, 3), (0, 0, slot, 0))

    if use_pallas and not cfg.sliding_window:
        from repro.kernels import decode_attention
        bs = 128 if S_cache % 128 == 0 else S_cache
        out = decode_attention(q[:, 0], k_new, v_new,
                               (pos + 1).astype(jnp.int32), bs=bs)
        out = out.reshape(B, 1, H, hd).astype(x.dtype)
    else:
        slot_pos = cache_slot_positions(cfg, S_cache, pos)    # (S_cache,)
        valid = jnp.logical_and(slot_pos >= 0, slot_pos <= pos)

        KVr = k_new.shape[1]
        G = H // KVr
        qg = q.reshape(B, KVr, G, hd)
        scores = jnp.einsum("bkgh,bkth->bkgt", qg, k_new).astype(jnp.float32)
        scores = scores / jnp.sqrt(hd)
        scores = jnp.where(valid[None, None, None, :], scores, _NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        out = jnp.einsum("bkgt,bkth->bkgh", probs, v_new).reshape(B, 1, H, hd)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"],
                   preferred_element_type=x.dtype)
    return y, LayerKVCache(k=k_new, v=v_new)
