"""Architecture configuration — single schema covering all assigned
families (dense / moe / ssm / hybrid / vlm / audio enc-dec)."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

Family = str  # 'dense' | 'moe' | 'ssm' | 'hybrid' | 'vlm' | 'audio'


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    router_aux_coef: float = 0.01
    moe_capacity_factor: float = 1.25

    # attention flavour
    rope_fraction: float = 1.0        # chatglm3: 0.5 (2d/partial rotary)
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None   # mixtral: 4096
    qkv_bias: bool = False                 # qwen2: True
    mlp_style: str = "swiglu"              # 'swiglu' | 'gelu' (whisper)
    norm_style: str = "rmsnorm"            # 'rmsnorm' | 'layernorm'

    # SSM / RWKV
    attn_free: bool = False                # rwkv6
    ssm_state: int = 0                     # mamba2 d_state (zamba2: 64)
    ssm_conv: int = 4
    attn_every: int = 0                    # hybrid: shared attn block period

    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 1500                # whisper-base 30 s → 1500 frames
    max_decoder_len: int = 448             # whisper model-card cap

    # modality frontend STUB (vlm/audio): prefix embeddings provided
    frontend: Optional[str] = None         # 'vision' | 'audio'
    num_prefix_tokens: int = 0             # llava anyres patch tokens

    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "float32"                 # 'float32' for CPU, 'bfloat16' for dry-run
    remat: bool = False                    # activation checkpoint the layer scan
    citation: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    def param_count(self) -> int:
        """Total parameters N (analytic; used for 6·N·D roofline)."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        H, KV, hd = self.num_heads, self.num_kv_heads, self.hd
        emb = V * D * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.attn_free:                        # rwkv6: timemix + channelmix
            # time: r,k,v,g,o (5·D²) + low-rank decay; channel: k,v,r
            per_layer = 5 * D * D + 2 * D * 64 + 2 * D * F + D * D
        elif self.family in ("ssm", "hybrid"):
            dssm = 2 * D                              # mamba2 d_inner = 2*D
            per_layer = D * (2 * dssm + 2 * self.ssm_state +
                             self.num_heads) + dssm * D
        else:
            attn = D * H * hd + 2 * D * KV * hd + H * hd * D
            if self.is_moe:
                mlp = self.num_experts * 3 * D * F
            else:
                mlp = 3 * D * F if self.mlp_style == "swiglu" else 2 * D * F
            per_layer = attn + mlp
        total = emb + L * per_layer
        if self.family == "hybrid" and self.attn_every:
            shared = (D * H * hd + 2 * D * KV * hd + H * hd * D + 3 * D * F)
            total += shared
        if self.is_encoder_decoder:
            enc_attn = D * H * hd + 2 * D * KV * hd + H * hd * D
            enc_mlp = 2 * D * F
            cross = D * H * hd + 2 * D * KV * hd + H * hd * D
            total += self.encoder_layers * (enc_attn + enc_mlp)
            total += L * cross
        return int(total)

    def active_param_count(self) -> int:
        """N_active for MoE (6·N_active·D roofline)."""
        if not self.is_moe:
            return self.param_count()
        D, F, L = self.d_model, self.d_ff, self.num_layers
        dense_total = self.param_count()
        all_experts = L * self.num_experts * 3 * D * F
        active = L * self.experts_per_token * 3 * D * F
        return int(dense_total - all_experts + active)


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced config for CPU smoke tests: ≤2 layers, d_model≤512, ≤4 experts."""
    d = min(cfg.d_model, 256)
    heads = min(cfg.num_heads, 4)
    kv = max(1, min(cfg.num_kv_heads, heads))
    while heads % kv:
        kv -= 1
    return dataclasses.replace(
        cfg,
        num_layers=2,
        d_model=d,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=d // heads,
        d_ff=min(cfg.d_ff, 512),
        vocab_size=min(cfg.vocab_size, 512),
        num_experts=min(cfg.num_experts, 4) if cfg.is_moe else 0,
        experts_per_token=min(cfg.experts_per_token, 2) if cfg.is_moe else 0,
        moe_capacity_factor=8.0 if cfg.is_moe else cfg.moe_capacity_factor,
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else None,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        attn_every=2 if cfg.attn_every else 0,
        encoder_layers=2 if cfg.is_encoder_decoder else 0,
        encoder_seq=16 if cfg.is_encoder_decoder else cfg.encoder_seq,
        num_prefix_tokens=4 if cfg.frontend else 0,
        dtype="float32",
    )
