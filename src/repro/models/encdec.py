"""Whisper-style encoder-decoder (audio family).

The mel-spectrogram + conv feature extractor is a STUB per the spec:
``input_specs()`` supplies pre-computed frame embeddings
(B, encoder_seq, d_model). We implement the transformer: bidirectional
encoder (sinusoidal positions), causal decoder with cross-attention
(learned positions), GELU MLPs, LayerNorms, biased projections."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models.config import ModelConfig
from repro.models.layers import (PSpec, apply_mlp, apply_norm,
                                 chunked_lm_loss,
                                 embed_template, embed_tokens, lm_logits,
                                 mlp_template, norm_template,
                                 template_abstract, template_axes,
                                 template_init)
from repro.models.transformer import stack_template


def sinusoidal_positions(length: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(length)[:, None].astype(jnp.float32)
    dim = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    ang = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


class EncDecDecodeState(NamedTuple):
    self_cache: attn_lib.LayerKVCache  # (L, B, KVr, S, hd)
    cross_k: jax.Array                 # (L, B, KVr, T_enc, hd)
    cross_v: jax.Array
    pos: jax.Array


class EncDecModel:
    def __init__(self, cfg: ModelConfig, kv_repeat: int = 1):
        self.cfg = cfg
        self.kv_repeat = kv_repeat

    # -- parameters -----------------------------------------------------------
    def template(self):
        cfg = self.cfg
        enc_layer = {
            "attn_norm": norm_template(cfg.d_model, "layernorm"),
            "attn": attn_lib.attn_template(cfg),
            "mlp_norm": norm_template(cfg.d_model, "layernorm"),
            "mlp": mlp_template(cfg.d_model, cfg.d_ff, "gelu"),
        }
        dec_layer = {
            "self_norm": norm_template(cfg.d_model, "layernorm"),
            "self_attn": attn_lib.attn_template(cfg),
            "cross_norm": norm_template(cfg.d_model, "layernorm"),
            "cross_attn": attn_lib.attn_template(cfg),
            "mlp_norm": norm_template(cfg.d_model, "layernorm"),
            "mlp": mlp_template(cfg.d_model, cfg.d_ff, "gelu"),
        }
        return {
            "embed": embed_template(cfg.vocab_size, cfg.d_model,
                                    cfg.tie_embeddings),
            "dec_pos": PSpec((cfg.max_decoder_len, cfg.d_model),
                             (None, "embed"), "normal"),
            "enc_layers": stack_template(enc_layer, cfg.encoder_layers),
            "enc_norm": norm_template(cfg.d_model, "layernorm"),
            "dec_layers": stack_template(dec_layer, cfg.num_layers),
            "final_norm": norm_template(cfg.d_model, "layernorm"),
        }

    def abstract(self):
        return template_abstract(self.template(), self.cfg.jdtype)

    def init(self, key):
        return template_init(self.template(), key, self.cfg.jdtype)

    def logical_axes(self):
        return template_axes(self.template())

    # -- encoder ---------------------------------------------------------------
    def encode(self, params, frames: jax.Array) -> jax.Array:
        """frames: (B, T_enc, D) stub embeddings → encoder states."""
        cfg = self.cfg
        B, T, D = frames.shape
        h = frames + sinusoidal_positions(T, D)[None].astype(frames.dtype)
        positions = jnp.broadcast_to(jnp.arange(T)[None, :], (B, T))

        def body(h, lp):
            a_in = apply_norm(h, lp["attn_norm"], "layernorm", cfg.norm_eps)
            h = h + attn_lib.attention(lp["attn"], a_in, cfg,
                                       positions=positions, causal=False,
                                       kv_repeat=self.kv_repeat)
            m_in = apply_norm(h, lp["mlp_norm"], "layernorm", cfg.norm_eps)
            return h + apply_mlp(m_in, lp["mlp"], "gelu"), None

        if cfg.remat:
            body = jax.checkpoint(body)
        h, _ = jax.lax.scan(body, h, params["enc_layers"])
        return apply_norm(h, params["enc_norm"], "layernorm", cfg.norm_eps)

    # -- decoder (training / scoring) -------------------------------------------
    def _dec_positions(self, B, S):
        return jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))

    def hidden_states(self, params, tokens, enc_out):
        cfg = self.cfg
        B, S = tokens.shape
        h = embed_tokens(params["embed"], tokens)
        h = h + params["dec_pos"][:S][None].astype(h.dtype)
        positions = self._dec_positions(B, S)
        T_enc = enc_out.shape[1]
        enc_pos = jnp.broadcast_to(jnp.arange(T_enc)[None, :], (B, T_enc))

        def body(h, lp):
            a_in = apply_norm(h, lp["self_norm"], "layernorm", cfg.norm_eps)
            h = h + attn_lib.attention(lp["self_attn"], a_in, cfg,
                                       positions=positions,
                                       kv_repeat=self.kv_repeat)
            c_in = apply_norm(h, lp["cross_norm"], "layernorm", cfg.norm_eps)
            h = h + attn_lib.attention(lp["cross_attn"], c_in, cfg,
                                       positions=positions, causal=False,
                                       kv_x=enc_out, kv_positions=enc_pos,
                                       kv_repeat=self.kv_repeat)
            m_in = apply_norm(h, lp["mlp_norm"], "layernorm", cfg.norm_eps)
            return h + apply_mlp(m_in, lp["mlp"], "gelu"), None

        if cfg.remat:
            body = jax.checkpoint(body)
        h, _ = jax.lax.scan(body, h, params["dec_layers"])
        return apply_norm(h, params["final_norm"], "layernorm",
                          cfg.norm_eps), jnp.float32(0)

    def loss(self, params, batch):
        enc_out = self.encode(params, batch["frames"])
        h, aux = self.hidden_states(params, batch["tokens"], enc_out)
        ce = chunked_lm_loss(params["embed"], h, batch["labels"],
                             self.cfg.tie_embeddings, batch.get("loss_mask"))
        return ce + aux, {"ce": ce, "aux": aux}

    # -- decode ---------------------------------------------------------------
    def _cross_kv(self, params, enc_out):
        """Precompute per-decoder-layer cross K/V from encoder states."""
        cfg = self.cfg

        def per_layer(lp):
            k = jnp.einsum("btd,dhk->bhtk", enc_out, lp["cross_attn"]["wk"])
            v = jnp.einsum("btd,dhk->bhtk", enc_out, lp["cross_attn"]["wv"])
            if cfg.qkv_bias:
                k = k + lp["cross_attn"]["bk"][None, :, None, :]
                v = v + lp["cross_attn"]["bv"][None, :, None, :]
            if self.kv_repeat > 1:
                k = jnp.repeat(k, self.kv_repeat, axis=1)
                v = jnp.repeat(v, self.kv_repeat, axis=1)
            return k, v   # (B, KVr, T_enc, hd)

        return jax.lax.map(lambda lp: per_layer(lp), params["dec_layers"])

    def init_decode_state(self, batch: int, cache_len: int,
                          frames=None, params=None) -> EncDecDecodeState:
        cfg = self.cfg
        one = attn_lib.init_layer_cache(cfg, batch, cache_len,
                                        self.kv_repeat, cfg.jdtype)
        caches = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.num_layers,) + a.shape),
            one)
        KVr = cfg.num_kv_heads * self.kv_repeat
        if frames is not None and params is not None:
            enc_out = self.encode(params, frames)
            ck, cv = self._cross_kv(params, enc_out)
        else:
            shape = (cfg.num_layers, batch, KVr, cfg.encoder_seq, cfg.hd)
            ck = jnp.zeros(shape, cfg.jdtype)
            cv = jnp.zeros(shape, cfg.jdtype)
        return EncDecDecodeState(self_cache=caches, cross_k=ck, cross_v=cv,
                                 pos=jnp.zeros((), jnp.int32))

    def decode_state_abstract(self, batch: int, cache_len: int):
        cfg = self.cfg
        KVr = cfg.num_kv_heads * self.kv_repeat
        sd = jax.ShapeDtypeStruct
        kv = sd((cfg.num_layers, batch, KVr, cache_len, cfg.hd), cfg.jdtype)
        cross = sd((cfg.num_layers, batch, KVr, cfg.encoder_seq, cfg.hd),
                   cfg.jdtype)
        return EncDecDecodeState(
            self_cache=attn_lib.LayerKVCache(k=kv, v=kv),
            cross_k=cross, cross_v=cross, pos=sd((), jnp.int32))

    def _cross_step(self, lp, x, ck, cv):
        """Single-token cross attention vs precomputed encoder K/V."""
        cfg = self.cfg
        B = x.shape[0]
        H, hd = cfg.num_heads, cfg.hd
        q = jnp.einsum("bsd,dhk->bshk", x, lp["cross_attn"]["wq"])
        if cfg.qkv_bias:
            q = q + lp["cross_attn"]["bq"]
        KVr = ck.shape[1]
        G = H // KVr
        qg = q.reshape(B, KVr, G, hd)
        scores = jnp.einsum("bkgh,bkth->bkgt", qg, ck).astype(jnp.float32)
        probs = jax.nn.softmax(scores / jnp.sqrt(hd), axis=-1).astype(x.dtype)
        out = jnp.einsum("bkgt,bkth->bkgh", probs, cv).reshape(B, 1, H, hd)
        return jnp.einsum("bshk,hkd->bsd", out, lp["cross_attn"]["wo"])

    def decode_step(self, params, state: EncDecDecodeState, tokens):
        cfg = self.cfg
        pos = state.pos
        h = embed_tokens(params["embed"], tokens)
        pos_emb = jax.lax.dynamic_slice_in_dim(
            params["dec_pos"], jnp.minimum(pos, cfg.max_decoder_len - 1), 1)
        h = h + pos_emb[None].astype(h.dtype)[:, 0][:, None]

        def body(h, xs):
            lp, cache, ck, cv = xs
            a_in = apply_norm(h, lp["self_norm"], "layernorm", cfg.norm_eps)
            a_out, cache = attn_lib.attention_decode_step(
                lp["self_attn"], a_in, cache, pos, cfg, self.kv_repeat)
            h = h + a_out
            c_in = apply_norm(h, lp["cross_norm"], "layernorm", cfg.norm_eps)
            h = h + self._cross_step(lp, c_in, ck, cv)
            m_in = apply_norm(h, lp["mlp_norm"], "layernorm", cfg.norm_eps)
            return h + apply_mlp(m_in, lp["mlp"], "gelu"), cache

        h, caches = jax.lax.scan(
            body, h, (params["dec_layers"], state.self_cache,
                      state.cross_k, state.cross_v))
        h = apply_norm(h, params["final_norm"], "layernorm", cfg.norm_eps)
        logits = lm_logits(params["embed"], h, cfg.tie_embeddings)
        return logits, EncDecDecodeState(self_cache=caches,
                                         cross_k=state.cross_k,
                                         cross_v=state.cross_v, pos=pos + 1)
