"""Zamba2-style hybrid: Mamba-2 trunk + a SHARED attention block applied
every ``attn_every`` layers (arXiv:2411.15242). The shared block's
weights are reused at each application point; each application keeps
its own KV cache during decode."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import mamba2
from repro.models.config import ModelConfig
from repro.models.layers import (apply_mlp, apply_norm,
                                 chunked_lm_loss,
                                 embed_template, embed_tokens, lm_logits,
                                 mlp_template, norm_template,
                                 template_abstract, template_axes,
                                 template_init)
from repro.models.transformer import stack_template


class HybridDecodeState(NamedTuple):
    ssm: mamba2.Mamba2State        # leaves stacked (n_seg, seg_len, B, ...)
    shared_cache: attn_lib.LayerKVCache  # (n_seg, B, KVr, S, hd)
    pos: jax.Array


class HybridModel:
    def __init__(self, cfg: ModelConfig, kv_repeat: int = 1, mesh=None,
                 batch_axes=("pod", "data")):
        if cfg.attn_every <= 0 or cfg.num_layers % cfg.attn_every:
            raise ValueError("hybrid needs attn_every | num_layers")
        self.cfg = cfg
        self.kv_repeat = kv_repeat
        self.mesh = mesh
        self.batch_axes = batch_axes
        self.n_seg = cfg.num_layers // cfg.attn_every
        self.seg_len = cfg.attn_every

    # -- parameters -------------------------------------------------------
    def template(self):
        cfg = self.cfg
        mamba_layer = {
            "norm": norm_template(cfg.d_model, cfg.norm_style),
            "mamba": mamba2.mamba2_template(cfg),
        }
        shared = {
            "attn_norm": norm_template(cfg.d_model, cfg.norm_style),
            "attn": attn_lib.attn_template(cfg),
            "mlp_norm": norm_template(cfg.d_model, cfg.norm_style),
            "mlp": mlp_template(cfg.d_model, cfg.d_ff, cfg.mlp_style),
        }
        return {
            "embed": embed_template(cfg.vocab_size, cfg.d_model,
                                    cfg.tie_embeddings),
            "mamba_layers": stack_template(
                stack_template(mamba_layer, self.seg_len), self.n_seg),
            "shared": shared,
            "final_norm": norm_template(cfg.d_model, cfg.norm_style),
        }

    def abstract(self):
        return template_abstract(self.template(), self.cfg.jdtype)

    def init(self, key):
        return template_init(self.template(), key, self.cfg.jdtype)

    def logical_axes(self):
        return template_axes(self.template())

    # -- forward ------------------------------------------------------------
    def _shared_block(self, sp, h, positions):
        cfg = self.cfg
        a_in = apply_norm(h, sp["attn_norm"], cfg.norm_style, cfg.norm_eps)
        h = h + attn_lib.attention(sp["attn"], a_in, cfg, positions=positions,
                                   kv_repeat=self.kv_repeat)
        m_in = apply_norm(h, sp["mlp_norm"], cfg.norm_style, cfg.norm_eps)
        return h + apply_mlp(m_in, sp["mlp"], cfg.mlp_style)

    def hidden_states(self, params, tokens, prefix_embeds=None):
        cfg = self.cfg
        h = embed_tokens(params["embed"], tokens)
        B, S, _ = h.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))

        from repro.models.transformer import constrain_seq_parallel

        def mamba_body(h, lp):
            x = apply_norm(h, lp["norm"], cfg.norm_style, cfg.norm_eps)
            return h + mamba2.apply_mamba2(lp["mamba"], x, cfg), None

        def segment(h, seg_params):
            h, _ = jax.lax.scan(jax.checkpoint(mamba_body), h, seg_params)
            h = self._shared_block(params["shared"], h, positions)
            return constrain_seq_parallel(h, self.mesh, self.batch_axes), None

        if cfg.remat:
            segment = jax.checkpoint(segment)
        h, _ = jax.lax.scan(segment, h, params["mamba_layers"])
        return apply_norm(h, params["final_norm"], cfg.norm_style,
                          cfg.norm_eps), jnp.float32(0)

    def forward(self, params, tokens, prefix_embeds=None):
        h, aux = self.hidden_states(params, tokens)
        return lm_logits(params["embed"], h, self.cfg.tie_embeddings), aux

    def loss(self, params, batch):
        h, aux = self.hidden_states(params, batch["tokens"])
        ce = chunked_lm_loss(params["embed"], h, batch["labels"],
                             self.cfg.tie_embeddings, batch.get("loss_mask"))
        return ce + aux, {"ce": ce, "aux": aux}

    # -- decode ---------------------------------------------------------------
    def init_decode_state(self, batch: int, cache_len: int) -> HybridDecodeState:
        cfg = self.cfg
        one = mamba2.init_mamba2_state(cfg, batch, cfg.jdtype)
        ssm = jax.tree.map(
            lambda a: jnp.broadcast_to(
                a[None, None], (self.n_seg, self.seg_len) + a.shape), one)
        kv = attn_lib.init_layer_cache(cfg, batch, cache_len,
                                       self.kv_repeat, cfg.jdtype)
        shared = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (self.n_seg,) + a.shape), kv)
        return HybridDecodeState(ssm=ssm, shared_cache=shared,
                                 pos=jnp.zeros((), jnp.int32))

    def decode_state_abstract(self, batch: int, cache_len: int):
        cfg = self.cfg
        d_inner, nh, N = mamba2.ssm_dims(cfg)
        S = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
        KVr = cfg.num_kv_heads * self.kv_repeat
        sd = lambda s, dt: jax.ShapeDtypeStruct(s, dt)
        return HybridDecodeState(
            ssm=mamba2.Mamba2State(
                h=sd((self.n_seg, self.seg_len, batch, nh, mamba2.HEADDIM, N),
                     jnp.float32),
                conv_buf=sd((self.n_seg, self.seg_len, batch,
                             cfg.ssm_conv - 1, d_inner + 2 * N), cfg.jdtype)),
            shared_cache=attn_lib.LayerKVCache(
                k=sd((self.n_seg, batch, KVr, S, cfg.hd), cfg.jdtype),
                v=sd((self.n_seg, batch, KVr, S, cfg.hd), cfg.jdtype)),
            pos=sd((), jnp.int32))

    def decode_step(self, params, state: HybridDecodeState, tokens):
        cfg = self.cfg
        h = embed_tokens(params["embed"], tokens)
        pos = state.pos

        def mamba_body(h, xs):
            lp, st = xs
            x = apply_norm(h, lp["norm"], cfg.norm_style, cfg.norm_eps)
            y, st = mamba2.mamba2_decode_step(lp["mamba"], x, st, cfg)
            return h + y, st

        def segment(h, xs):
            seg_params, seg_ssm, seg_kv = xs
            h, ssm = jax.lax.scan(mamba_body, h, (seg_params, seg_ssm))
            sp = params["shared"]
            a_in = apply_norm(h, sp["attn_norm"], cfg.norm_style, cfg.norm_eps)
            a_out, kv = attn_lib.attention_decode_step(
                sp["attn"], a_in, seg_kv, pos, cfg, self.kv_repeat)
            h = h + a_out
            m_in = apply_norm(h, sp["mlp_norm"], cfg.norm_style, cfg.norm_eps)
            h = h + apply_mlp(m_in, sp["mlp"], cfg.mlp_style)
            return h, (ssm, kv)

        h, (ssm, kv) = jax.lax.scan(
            segment, h, (params["mamba_layers"], state.ssm,
                         state.shared_cache))
        h = apply_norm(h, params["final_norm"], cfg.norm_style, cfg.norm_eps)
        logits = lm_logits(params["embed"], h, cfg.tie_embeddings)
        return logits, HybridDecodeState(ssm=ssm, shared_cache=kv,
                                         pos=pos + 1)
