"""Common building blocks + the parameter-template machinery.

Parameters are plain pytrees. Every leaf is declared once as a
``PSpec(shape, axes)`` where ``axes`` are *logical* axis names
("vocab", "embed", "ffn", "heads", "experts", "layers", ...). From one
template we derive:
  * abstract params (ShapeDtypeStruct)   → dry-run lowering
  * materialized random params           → smoke tests / real training
  * PartitionSpecs via repro.launch.sharding rules → pjit shardings

Layer stacks store weights with a leading "layers" dim and run under
``jax.lax.scan`` so HLO size is depth-independent (DESIGN.md §5).
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class PSpec(NamedTuple):
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]   # logical names, len == len(shape)
    init: str = "normal"              # 'normal' | 'zeros' | 'ones' | 'embed'
    fan_in: Optional[int] = None      # explicit fan-in when shape[-2] lies
                                      # (e.g. (D,H,hd) projections)


def template_abstract(tpl, dtype) -> Any:
    """Template → pytree of ShapeDtypeStruct (no allocation)."""
    return jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, dtype),
        tpl, is_leaf=lambda x: isinstance(x, PSpec))


def template_init(tpl, key, dtype) -> Any:
    """Template → materialized params (fan-in scaled normal init)."""
    leaves, treedef = jax.tree.flatten(
        tpl, is_leaf=lambda x: isinstance(x, PSpec))
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, p in zip(keys, leaves):
        if p.init == "zeros":
            out.append(jnp.zeros(p.shape, dtype))
        elif p.init == "ones":
            out.append(jnp.ones(p.shape, dtype))
        elif p.init == "embed":
            # 1/√d_model embedding rows (NOT fan-in=vocab): keeps the
            # first RMSNorm's backward conditioned AND, under tied
            # embeddings, gives unit-variance logits (h_norm @ E.T).
            std = 1.0 / math.sqrt(max(p.shape[-1], 1))
            out.append((jax.random.normal(k, p.shape) * std).astype(dtype))
        else:
            fan_in = p.fan_in or (p.shape[-2] if len(p.shape) >= 2
                                  else p.shape[-1])
            std = 1.0 / math.sqrt(max(fan_in, 1))
            out.append((jax.random.normal(k, p.shape) * std).astype(dtype))
    return jax.tree.unflatten(treedef, out)


def template_axes(tpl) -> Any:
    """Template → pytree of logical-axis tuples (for sharding rules)."""
    return jax.tree.map(lambda p: p.axes, tpl,
                        is_leaf=lambda x: isinstance(x, PSpec))


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array,
              eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale + bias).astype(x.dtype)


def apply_norm(x, p, style: str, eps: float):
    if style == "rmsnorm":
        return rmsnorm(x, p["scale"], eps)
    return layernorm(x, p["scale"], p["bias"], eps)


def norm_template(d: int, style: str) -> Dict[str, PSpec]:
    t = {"scale": PSpec((d,), ("embed",), "ones")}
    if style == "layernorm":
        t["bias"] = PSpec((d,), ("embed",), "zeros")
    return t


# ---------------------------------------------------------------------------
# RoPE (standard + partial/2d fraction à la chatglm3)
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, fraction: float, theta: float):
    rot = int(head_dim * fraction) // 2 * 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    return inv, rot


def apply_rope(x: jax.Array, positions: jax.Array, fraction: float,
               theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    inv, rot = rope_frequencies(hd, fraction, theta)
    if rot == 0:
        return x
    ang = positions[..., :, None, None].astype(jnp.float32) * inv  # (...,S,1,rot/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    xr = x[..., :rot].astype(jnp.float32)
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x1 * sin + x2 * cos
    rotated = jnp.stack([r1, r2], axis=-1).reshape(*xr.shape)
    return jnp.concatenate(
        [rotated.astype(x.dtype), x[..., rot:]], axis=-1)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_template(d: int, f: int, style: str) -> Dict[str, PSpec]:
    if style == "swiglu":
        return {"w_gate": PSpec((d, f), ("embed", "ffn")),
                "w_up": PSpec((d, f), ("embed", "ffn")),
                "w_down": PSpec((f, d), ("ffn", "embed"))}
    return {"w_in": PSpec((d, f), ("embed", "ffn")),
            "b_in": PSpec((f,), ("ffn",), "zeros"),
            "w_out": PSpec((f, d), ("ffn", "embed")),
            "b_out": PSpec((d,), ("embed",), "zeros")}


def apply_mlp(x: jax.Array, p, style: str) -> jax.Array:
    mm = lambda a, b: jnp.matmul(a, b, preferred_element_type=x.dtype)
    if style == "swiglu":
        g = jax.nn.silu(mm(x, p["w_gate"]))
        return mm(g * mm(x, p["w_up"]), p["w_down"])
    h = jax.nn.gelu(mm(x, p["w_in"]) + p["b_in"])
    return mm(h, p["w_out"]) + p["b_out"]


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------

def embed_template(vocab: int, d: int, tie: bool) -> Dict[str, PSpec]:
    t = {"embedding": PSpec((vocab, d), ("vocab", "embed"), "embed")}
    if not tie:
        t["lm_head"] = PSpec((d, vocab), ("embed", "vocab"))
    return t


def embed_tokens(p, tokens: jax.Array) -> jax.Array:
    """Lookup × √D (T5/Gemma convention): puts the residual stream at
    unit rms from step 0, so the first norm's backward is conditioned,
    while the tied LM head still sees 1/√D-scale rows."""
    E = p["embedding"]
    return E[tokens] * math.sqrt(E.shape[-1])


def lm_logits(p, x: jax.Array, tie: bool) -> jax.Array:
    if tie:
        return x @ p["embedding"].T
    return x @ p["lm_head"]


def chunked_lm_loss(embed_params, h: jax.Array, labels: jax.Array,
                    tie: bool, mask: Optional[jax.Array] = None,
                    chunk: int = 8192) -> jax.Array:
    """CE loss computed seq-chunk-wise with rematerialized logits.

    Full (B, S, V) f32 logits are a top HBM consumer at 128k-vocab
    (llava train: 16.7 GB/device just for logits). Scanning S in chunks
    with jax.checkpoint keeps only (B, chunk, V) transient; backward
    recomputes each chunk's logits (§Perf iteration 1b).
    """
    B, S, D = h.shape
    T = B * S
    if T <= chunk:
        logits = lm_logits(embed_params, h, tie)
        return cross_entropy_loss(logits, labels, mask)
    # token-major chunking (works for any B, S — e.g. whisper's B·448)
    hf = h.reshape(T, D)
    lf = labels.reshape(T)
    mf = (mask.reshape(T).astype(jnp.float32) if mask is not None
          else jnp.ones((T,), jnp.float32))
    pad = (-T) % chunk
    if pad:
        hf = jnp.pad(hf, ((0, pad), (0, 0)))
        lf = jnp.pad(lf, (0, pad))
        mf = jnp.pad(mf, (0, pad))
    nb = (T + pad) // chunk
    hb = hf.reshape(nb, chunk, D)
    lb = lf.reshape(nb, chunk)
    mb = mf.reshape(nb, chunk)

    @jax.checkpoint
    def chunk_loss(carry, xs):
        hc, lc, mc = xs
        logits = lm_logits(embed_params, hc, tie).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mc
        return (carry[0] + jnp.sum(nll), carry[1] + jnp.sum(mc)), None

    (tot, cnt), _ = jax.lax.scan(chunk_loss,
                                 (jnp.float32(0), jnp.float32(0)),
                                 (hb, lb, mb))
    return tot / jnp.maximum(cnt, 1.0)


def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean next-token CE in f32 (logits (B,S,V), labels (B,S))."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        m = mask.astype(jnp.float32)
        return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
    return jnp.mean(nll)
