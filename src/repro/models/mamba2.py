"""Mamba-2 SSD block (Dao & Gu 2024), chunked for TPU.

State-space recurrence per head (scalar decay a_t = exp(-Δt·A)):

    h_t = a_t · h_{t-1} + Δt · x_t ⊗ B_t          h ∈ (P, N)
    y_t = h_t · C_t + D_skip · x_t

Training/prefill uses the *chunked* SSD form: sequences are split into
chunks of ``CHUNK``; within a chunk the recurrence is an attention-like
masked matmul (MXU-friendly), across chunks a short `lax.scan` carries
the (P, N) state. This is the TPU-native adaptation — a step-by-step
scan over 4k-500k tokens would serialize the MXU (DESIGN.md §2).

Decode is the O(1) single-step recurrence — the reason `long_500k` is
trivial for SSM archs (no KV cache at all).
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import PSpec

CHUNK = 128
HEADDIM = 64   # P


def ssm_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    """(d_inner, num_heads, d_state)."""
    d_inner = 2 * cfg.d_model
    return d_inner, d_inner // HEADDIM, cfg.ssm_state


def mamba2_template(cfg: ModelConfig) -> Dict[str, PSpec]:
    D = cfg.d_model
    d_inner, nh, N = ssm_dims(cfg)
    return {
        # projections: z (gate), x, B, C, dt
        "w_in": PSpec((D, 2 * d_inner + 2 * N + nh), ("embed", "ffn")),
        "conv": PSpec((cfg.ssm_conv, d_inner + 2 * N), (None, "ffn"), "normal"),
        "a_log": PSpec((nh,), (None,), "zeros"),       # A = -exp(a_log)
        "d_skip": PSpec((nh,), (None,), "ones"),
        "dt_bias": PSpec((nh,), (None,), "zeros"),
        "norm_scale": PSpec((d_inner,), ("ffn",), "ones"),
        "w_out": PSpec((d_inner, D), ("ffn", "embed")),
    }


def _split_proj(p, u, cfg):
    d_inner, nh, N = ssm_dims(cfg)
    zxbcdt = u @ p["w_in"]
    z, xBC, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * N], axis=-1)
    return z, xBC, dt


def _causal_conv(xBC: jax.Array, conv_w: jax.Array) -> jax.Array:
    """Depthwise causal conv over time. xBC: (B, T, Cdim)."""
    K = conv_w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xBC.shape[1], :] * conv_w[i] for i in range(K))
    return jax.nn.silu(out)


def _gated_rmsnorm(y, z, scale, eps=1e-5):
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    return (y.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
            * scale).astype(y.dtype)


class Mamba2State(NamedTuple):
    h: jax.Array          # (B, nh, P, N) SSM state
    conv_buf: jax.Array   # (B, K-1, d_inner + 2N) causal-conv tail


def init_mamba2_state(cfg: ModelConfig, batch: int, dtype) -> Mamba2State:
    d_inner, nh, N = ssm_dims(cfg)
    return Mamba2State(
        h=jnp.zeros((batch, nh, HEADDIM, N), jnp.float32),
        conv_buf=jnp.zeros((batch, cfg.ssm_conv - 1, d_inner + 2 * N), dtype))


def apply_mamba2(p, u: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Training/prefill. u: (B, T, D) → (B, T, D). T % CHUNK == 0 or T < CHUNK."""
    B, T, D = u.shape
    d_inner, nh, N = ssm_dims(cfg)
    z, xBC, dt = _split_proj(p, u, cfg)
    xBC = _causal_conv(xBC, p["conv"])
    x, Bc, Cc = jnp.split(xBC, [d_inner, d_inner + N], axis=-1)
    x = x.reshape(B, T, nh, HEADDIM)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])    # (B,T,nh)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))                    # (nh,)
    loga = dt * A[None, None, :]                                    # (B,T,nh) ≤ 0

    Q = CHUNK if (T % CHUNK == 0 and T > CHUNK) else T
    nchunks = T // Q
    # chunk-major layout (nc, B, Q, ...) for a scan over chunks; all the
    # intra-chunk work happens INSIDE the scan body so the (Q, Q, nh)
    # decay tensor is a transient, not an O(T) buffer.
    xq = x.reshape(B, nchunks, Q, nh, HEADDIM).transpose(1, 0, 2, 3, 4).astype(jnp.float32)
    Bq = Bc.reshape(B, nchunks, Q, N).transpose(1, 0, 2, 3).astype(jnp.float32)
    Cq = Cc.reshape(B, nchunks, Q, N).transpose(1, 0, 2, 3).astype(jnp.float32)
    dtq = dt.reshape(B, nchunks, Q, nh).transpose(1, 0, 2, 3)
    logaq = loga.reshape(B, nchunks, Q, nh).transpose(1, 0, 2, 3)
    tril = jnp.tril(jnp.ones((Q, Q), bool))

    def chunk_body(h, inp):
        xc, Bt, Ct, dtc, lac = inp                       # (B,Q,...)
        cum = jnp.cumsum(lac, axis=1)                    # (B,Q,nh), ≤ 0, ↓
        # intra: y_t = Σ_{s≤t} (C_t·B_s)·exp(cum_t−cum_s)·Δt_s·x_s
        decay = cum[:, :, None, :] - cum[:, None, :, :]  # (B,t,s,nh) ≤ 0 on tril
        # clamp BEFORE exp: above-diagonal decay is positive and would
        # overflow to inf, poisoning the VJP (0·inf = NaN) even though
        # the forward masks it out.
        decay = jnp.where(tril[None, :, :, None], decay, -1e9)
        M = jnp.exp(decay)
        CB = jnp.einsum("btn,bsn->bts", Ct, Bt)
        W = CB[..., None] * M * dtc[:, None, :, :]       # (B,t,s,nh)
        y_intra = jnp.einsum("btsh,bshp->bthp", W, xc)
        # inter: read the carried state
        y_inter = jnp.einsum("bhpn,btn,bth->bthp", h, Ct, jnp.exp(cum))
        # state update: h' = exp(cum_Q)·h + Σ_s exp(cum_Q−cum_s)·Δt_s·x_s⊗B_s
        wS = jnp.exp(cum[:, -1:, :] - cum) * dtc         # (B,Q,nh)
        inj = jnp.einsum("bsh,bshp,bsn->bhpn", wS, xc, Bt)
        h_new = jnp.exp(cum[:, -1, :])[:, :, None, None] * h + inj
        return h_new, y_intra + y_inter

    h0 = jnp.zeros((B, nh, HEADDIM, N), jnp.float32)
    # checkpoint per chunk: the (B,Q,Q,nh) decay/score tensors are
    # recomputed in backward instead of being saved for all nc chunks
    # (§Perf iteration 6: zamba2 train temp 630 GB → see EXPERIMENTS.md)
    _, y = jax.lax.scan(jax.checkpoint(chunk_body), h0,
                        (xq, Bq, Cq, dtq, logaq))
    y = y.transpose(1, 0, 2, 3, 4).reshape(B, T, nh, HEADDIM)       # (B,T,nh,P)
    y = y + p["d_skip"].astype(jnp.float32)[None, None, :, None] * \
        x.reshape(B, T, nh, HEADDIM).astype(jnp.float32)
    y = y.reshape(B, T, d_inner).astype(u.dtype)
    y = _gated_rmsnorm(y, z, p["norm_scale"])
    return y @ p["w_out"]


def mamba2_decode_step(p, u: jax.Array, state: Mamba2State,
                       cfg: ModelConfig) -> Tuple[jax.Array, Mamba2State]:
    """u: (B, 1, D) → (y (B,1,D), new state). O(1) per token."""
    B = u.shape[0]
    d_inner, nh, N = ssm_dims(cfg)
    z, xBC, dt = _split_proj(p, u, cfg)                    # (B,1,·)
    # causal conv via the rolling buffer
    window = jnp.concatenate([state.conv_buf, xBC], axis=1)   # (B,K,·)
    conv_out = jax.nn.silu(jnp.einsum("bkc,kc->bc", window, p["conv"]))
    new_buf = window[:, 1:, :]
    x, Bc, Cc = jnp.split(conv_out, [d_inner, d_inner + N], axis=-1)
    x = x.reshape(B, nh, HEADDIM).astype(jnp.float32)

    dt1 = jax.nn.softplus(dt[:, 0, :].astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    a = jnp.exp(dt1 * A[None, :])                           # (B,nh)
    Bf = Bc.astype(jnp.float32)
    Cf = Cc.astype(jnp.float32)
    h = a[:, :, None, None] * state.h + \
        jnp.einsum("bh,bhp,bn->bhpn", dt1, x, Bf)
    y = jnp.einsum("bhpn,bn->bhp", h, Cf) + \
        p["d_skip"].astype(jnp.float32)[None, :, None] * x
    y = y.reshape(B, 1, d_inner).astype(u.dtype)
    y = _gated_rmsnorm(y, z, p["norm_scale"])
    return y @ p["w_out"], Mamba2State(h=h, conv_buf=new_buf)
