"""Mixture-of-Experts layer: top-k router + capacity-bounded sorted
dispatch (Switch/GShard style, reformulated for TPU as dense batched
matmuls over (experts, capacity, d) blocks).

Dispatch is sort-based (MaxText-style) rather than the (tokens, E, C)
one-hot einsum of the original GShard paper — the one-hot tensor is
O(T·E·C) memory, hopeless at T=65k/E=128; sorting is O(T log T) and the
expert compute is a single (E, C, D) × (E, D, F) batched matmul that
shards cleanly with experts on the ``model`` mesh axis.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.models.config import ModelConfig
from repro.models.layers import PSpec


def moe_template(cfg: ModelConfig) -> Dict[str, PSpec]:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    return {
        "router": PSpec((D, E), ("embed", "experts")),
        "w_gate": PSpec((E, D, F), ("experts", "embed", "ffn")),
        "w_up": PSpec((E, D, F), ("experts", "embed", "ffn")),
        "w_down": PSpec((E, F, D), ("experts", "ffn", "embed")),
    }


def apply_moe(p, x: jax.Array, cfg: ModelConfig,
              capacity_factor: Optional[float] = None) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) → (y (B,S,D), aux load-balance loss).

    Tokens overflowing an expert's capacity are dropped (standard
    Switch behaviour); gates are renormalized over the selected top-k.
    """
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    T = B * S
    xf = x.reshape(T, D)

    logits = (xf @ p["router"]).astype(jnp.float32)          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)          # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # Load-balance aux loss (Switch eq. 4): E · Σ_e f_e · P_e
    assign_frac = jnp.mean(
        jax.nn.one_hot(expert_idx, E, dtype=jnp.float32), axis=(0, 1)) * K
    router_prob = jnp.mean(probs, axis=0)
    aux = cfg.router_aux_coef * E * jnp.sum(assign_frac * router_prob)

    # ---- sort-based dispatch ------------------------------------------------
    if capacity_factor is None:
        capacity_factor = cfg.moe_capacity_factor
    C = max(1, int(T * K * capacity_factor / E))
    flat_expert = expert_idx.reshape(T * K)
    flat_token = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    flat_gate = gate_vals.reshape(T * K).astype(x.dtype)

    order = jnp.argsort(flat_expert)                         # stable
    e_sorted = flat_expert[order]
    t_sorted = flat_token[order]
    g_sorted = flat_gate[order]

    counts = jnp.bincount(e_sorted, length=E)                # (E,)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    pos_in_expert = jnp.arange(T * K) - starts[e_sorted]
    keep = (pos_in_expert < C).astype(x.dtype)
    dest = (e_sorted * C + jnp.minimum(pos_in_expert, C - 1)).astype(jnp.int32)

    # gather tokens into (E*C, D) expert blocks (overflow slots zeroed)
    xin = jnp.zeros((E * C, D), x.dtype).at[dest].add(
        xf[t_sorted] * keep[:, None])
    xin = xin.reshape(E, C, D)

    # expert compute: one batched swiglu matmul
    pe = x.dtype
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xin, p["w_gate"],
                               preferred_element_type=pe))
    h = g * jnp.einsum("ecd,edf->ecf", xin, p["w_up"],
                       preferred_element_type=pe)
    yout = jnp.einsum("ecf,efd->ecd", h, p["w_down"],
                      preferred_element_type=pe).reshape(E * C, D)

    # combine back to tokens, weighted by renormalized gates
    contrib = yout[dest] * (keep * g_sorted)[:, None]
    y = jnp.zeros((T, D), x.dtype).at[t_sorted].add(contrib)
    return y.reshape(B, S, D), aux.astype(jnp.float32)

# ---------------------------------------------------------------------------
# Distributed dispatch (§Perf iteration 3).
#
# Under plain GSPMD the (E·C, D) scatter-add crosses device boundaries
# and the partitioner falls back to "replicate + all-reduce": measured
# 171 GB of all-reduce per layer for qwen3-moe train_4k. The shard_map
# version keeps dispatch DEVICE-LOCAL:
#   * tokens sharded over (pod, data); replicated over model;
#   * experts sharded over model (E % model == 0, e.g. qwen3 128/16) —
#     each device dispatches only to its local experts and the partial
#     outputs psum over model;
#   * when E < model (mixtral 8 < 16) experts are replicated and the
#     FFN dim shards over model instead (megatron-TP inside each
#     expert) — dispatch again local, same single psum.
# Collectives per layer: fsdp weight all-gather over data + ONE
# (T_loc, D) psum over model.
# ---------------------------------------------------------------------------

def apply_moe_sharded(p, x: jax.Array, cfg: ModelConfig, mesh,
                      batch_axes: Tuple[str, ...],
                      capacity_factor: Optional[float] = None,
                      model_axis: str = "model"):
    """Drop-in for apply_moe when a mesh is available (train/prefill)."""
    m_size = mesh.shape[model_axis]
    E = cfg.num_experts
    expert_parallel = E % m_size == 0 and E >= m_size
    cf = capacity_factor or cfg.moe_capacity_factor

    from jax.sharding import PartitionSpec as P
    baxes = tuple(a for a in batch_axes if a in mesh.axis_names)
    bspec = baxes if len(baxes) > 1 else (baxes[0] if baxes else None)

    # in_specs must match launch/sharding.py's baseline param pspecs so
    # no resharding is inserted at the shard_map boundary:
    #   router (D, E)   → P(data, model)
    #   w_*   (E, D, F) → expert-parallel: P(model, data, None)
    #                     TP mode (E<16):  P(None, data, model)
    if expert_parallel:
        w_in = P(model_axis, "data", None)
        wd_in = P(model_axis, "data", None)
        router_in = P("data", model_axis)
    else:
        w_in = P(None, "data", model_axis)
        wd_in = P(None, model_axis, "data")   # (E, F, D): F on model
        router_in = P("data", None)           # E < model: replicated

    def body(xl, router_s, wg_s, wu_s, wd_s):
        ag = lambda a, ax: jax.lax.all_gather(a, "data", axis=ax, tiled=True)
        router = ag(router_s, 0)                                # (D, E?)
        if expert_parallel:
            router = jax.lax.all_gather(router, model_axis, axis=1,
                                        tiled=True)             # (D, E)
        wg = ag(wg_s, 1)                                        # (E?, D, F?)
        wu = ag(wu_s, 1)
        if expert_parallel:
            wd = ag(wd_s, 1)                                    # (E_loc, F, D)
            e_base = jax.lax.axis_index(model_axis) * (E // m_size)
            e_count = E // m_size
        else:
            wd = ag(wd_s, 2)                                    # (E, F_loc, D)
            e_base = jnp.int32(0)
            e_count = E

        Bl, S, D = xl.shape
        T = Bl * S
        xf = xl.reshape(T, D)
        logits = (xf @ router).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_idx = jax.lax.top_k(probs, cfg.experts_per_token)
        gate_vals = gate_vals / jnp.maximum(
            jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

        assign_frac = jnp.mean(jax.nn.one_hot(
            expert_idx, E, dtype=jnp.float32), axis=(0, 1)) * \
            cfg.experts_per_token
        router_prob = jnp.mean(probs, axis=0)
        aux = cfg.router_aux_coef * E * jnp.sum(assign_frac * router_prob)
        if baxes:
            # per-shard load-balance loss averaged over shards (standard
            # for EP: E[f·P] per shard, not global — differs by a Jensen
            # gap of O(1/shards), and locally balanced routing is what
            # the dispatch capacity actually needs)
            aux = jax.lax.pmean(aux, baxes)

        K = cfg.experts_per_token
        C = max(1, int(T * K * cf / E))
        flat_e = expert_idx.reshape(T * K)
        local_e = flat_e - e_base
        valid = jnp.logical_and(local_e >= 0, local_e < e_count)
        sort_key = jnp.where(valid, local_e, e_count)
        flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
        flat_g = gate_vals.reshape(T * K).astype(xl.dtype)

        order = jnp.argsort(sort_key)
        e_sorted = sort_key[order]
        t_sorted = flat_t[order]
        g_sorted = flat_g[order]
        counts = jnp.bincount(e_sorted, length=e_count + 1)[:e_count]
        starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                                  jnp.cumsum(counts)[:-1]])
        safe_e = jnp.minimum(e_sorted, e_count - 1)
        pos = jnp.arange(T * K) - starts[safe_e]
        keep = (jnp.logical_and(e_sorted < e_count, pos < C)).astype(xl.dtype)
        dest = (safe_e * C + jnp.clip(pos, 0, C - 1)).astype(jnp.int32)

        xin = jnp.zeros((e_count * C, D), xl.dtype).at[dest].add(
            xf[t_sorted] * keep[:, None])
        xin = xin.reshape(e_count, C, D)
        pe = xl.dtype
        g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xin, wg,
                                   preferred_element_type=pe))
        h = g * jnp.einsum("ecd,edf->ecf", xin, wu,
                           preferred_element_type=pe)
        yout = jnp.einsum("ecf,efd->ecd", h, wd,
                          preferred_element_type=pe).reshape(e_count * C, D)

        contrib = yout[dest] * (keep * g_sorted)[:, None]
        y = jnp.zeros((T, D), xl.dtype).at[t_sorted].add(contrib)
        y = jax.lax.psum(y, model_axis)          # combine expert partials
        return y.reshape(Bl, S, D), aux

    if baxes:
        x = jax.lax.with_sharding_constraint(x, P(bspec, None, None))
    fn = compat.shard_map(
        body, mesh=mesh,
        in_specs=(P(bspec, None, None), router_in, w_in, w_in, wd_in),
        out_specs=(P(bspec, None, None), P()),
        check_vma=False)
    y, aux = fn(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    return y, aux.astype(jnp.float32)
