"""RWKV-6 "Finch" block: token-shift + data-dependent per-channel decay
(arXiv:2404.05892), chunked for TPU.

Per head (k/v head dim = 64), with data-dependent decay w_t ∈ (0,1)^hd:

    S_t = diag(w_t) · S_{t-1} + k_t v_tᵀ
    y_t = r_tᵀ (S_{t-1} + diag(u) k_t v_tᵀ)

Training/prefill uses chunked gated linear attention: within a chunk
the decay products become a masked (Q, Q) matmul computed in f32 with
per-step log-decay clamped to ≥ LOG_W_MIN so exp(Σ) stays inside f32
range (TPU adaptation recorded in DESIGN.md — the CUDA kernel does the
recurrence stepwise in registers instead; a step-scan would serialize
the MXU). Decode is the O(1) recurrence, so `long_500k` runs.
"""
from __future__ import annotations

from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import PSpec

CHUNK = 32
HEADDIM = 64
LOG_W_MIN = -1.2   # per-token decay floor: w ≥ e^-1.2 ≈ 0.30; |Σ over chunk| ≤ 38.4


def rwkv_heads(cfg: ModelConfig) -> int:
    return cfg.d_model // HEADDIM


def rwkv6_template(cfg: ModelConfig) -> Dict[str, PSpec]:
    D, F = cfg.d_model, cfg.d_ff
    H = rwkv_heads(cfg)
    return {
        "time": {
            # token-shift interpolation weights (r,k,v,w,g)
            "mu": PSpec((5, D), (None, "embed"), "zeros"),
            "w_r": PSpec((D, D), ("embed", "heads_flat")),
            "w_k": PSpec((D, D), ("embed", "heads_flat")),
            "w_v": PSpec((D, D), ("embed", "heads_flat")),
            "w_g": PSpec((D, D), ("embed", "heads_flat")),
            # data-dependent decay (low-rank: D → 64 → D) + base
            "w_dec1": PSpec((D, 64), ("embed", None)),
            "w_dec2": PSpec((64, D), (None, "heads_flat")),
            "dec_base": PSpec((D,), ("heads_flat",), "zeros"),
            "u_bonus": PSpec((H, HEADDIM), (None, None), "zeros"),
            "w_o": PSpec((D, D), ("heads_flat", "embed")),
            "ln_scale": PSpec((D,), ("embed",), "ones"),   # per-head groupnorm
        },
        "channel": {
            "mu": PSpec((2, D), (None, "embed"), "zeros"),
            "w_k": PSpec((D, F), ("embed", "ffn")),
            "w_v": PSpec((F, D), ("ffn", "embed")),
            "w_r": PSpec((D, D), ("embed", "embed_out")),
        },
    }


def _token_shift(x: jax.Array, x_prev: jax.Array) -> jax.Array:
    """shift(x)[t] = x[t-1]; x_prev fills t=0. x: (B,T,D), x_prev: (B,1,D)."""
    return jnp.concatenate([x_prev, x[:, :-1, :]], axis=1)


def _decay(tp, xw: jax.Array) -> jax.Array:
    """Data-dependent log-decay, clamped. → (B,T,D), values ≤ 0."""
    raw = tp["dec_base"] + jnp.tanh(xw @ tp["w_dec1"]) @ tp["w_dec2"]
    # w = exp(-exp(raw)) ⇒ log w = -exp(raw); clamp for chunked f32 math.
    return jnp.clip(-jnp.exp(raw.astype(jnp.float32)), LOG_W_MIN, -1e-4)


def _project(tp, x, x_prev):
    xs = _token_shift(x, x_prev)
    mu = tp["mu"]
    mix = lambda i: x + (xs - x) * jax.nn.sigmoid(mu[i])[None, None, :]
    r = mix(0) @ tp["w_r"]
    k = mix(1) @ tp["w_k"]
    v = mix(2) @ tp["w_v"]
    logw = _decay(tp, mix(3))
    g = jax.nn.silu(mix(4) @ tp["w_g"])
    return r, k, v, logw, g


def _group_norm(y: jax.Array, scale: jax.Array, H: int) -> jax.Array:
    """Per-head LayerNorm of the wkv output (RWKV's GroupNorm)."""
    B, T, D = y.shape
    yh = y.reshape(B, T, H, D // H).astype(jnp.float32)
    mu = jnp.mean(yh, axis=-1, keepdims=True)
    var = jnp.var(yh, axis=-1, keepdims=True)
    yh = (yh - mu) * jax.lax.rsqrt(var + 1e-5)
    return (yh.reshape(B, T, D) * scale).astype(y.dtype)


class RWKVState(NamedTuple):
    S: jax.Array        # (B, H, hd, hd) wkv state (f32)
    x_prev_t: jax.Array  # (B, 1, D) last token for time-mix shift
    x_prev_c: jax.Array  # (B, 1, D) last token for channel-mix shift


def init_rwkv_state(cfg: ModelConfig, batch: int, dtype) -> RWKVState:
    H = rwkv_heads(cfg)
    return RWKVState(
        S=jnp.zeros((batch, H, HEADDIM, HEADDIM), jnp.float32),
        x_prev_t=jnp.zeros((batch, 1, cfg.d_model), dtype),
        x_prev_c=jnp.zeros((batch, 1, cfg.d_model), dtype))


def _wkv_chunked(r, k, v, logw, u, H):
    """Chunked GLA. r,k,v: (B,T,D); logw: (B,T,D) ≤ 0; u: (H,hd)."""
    B, T, D = r.shape
    hd = HEADDIM
    Q = CHUNK if (T % CHUNK == 0 and T > CHUNK) else T
    nc = T // Q

    def heads(x):  # (B,T,D) → (nc,B,H,Q,hd) f32, chunk-major
        return (x.reshape(B, nc, Q, H, hd).transpose(1, 0, 3, 2, 4)
                .astype(jnp.float32))

    rq, kq, vq, lwq = heads(r), heads(k), heads(v), heads(logw)
    tril_strict = jnp.tril(jnp.ones((Q, Q), bool), k=-1)

    def chunk_body(S, inp):
        rc, kc, vc, lw = inp                    # (B,H,Q,hd)
        cum = jnp.cumsum(lw, axis=2)            # (B,H,Q,hd) ≤ 0, ↓ in t
        cum_prev = cum - lw                     # Σ_{u<t} log w
        # intra (s<t): A_ts = Σ_c r_tc·exp(cum_prev_t − cum_s)_c·k_sc
        q_ = rc * jnp.exp(cum_prev)             # r ⊙ exp(cum_{t-1})
        k_ = kc * jnp.exp(-cum)                 # k ⊙ exp(−cum_s) (bounded: clamp)
        A = jnp.einsum("bhtc,bhsc->bhts", q_, k_)
        A = jnp.where(tril_strict[None, None], A, 0.0)
        # current-token bonus: (r_t ⊙ u ⊙ k_t)·v_t
        diag = jnp.einsum("bhtc,hc,bhtc->bht", rc, u, kc)
        y = A @ vc + diag[..., None] * vc
        # carried state read: y_t += (r_t ⊙ exp(cum_prev_t)) S
        y = y + jnp.einsum("bhtc,bhcd->bhtd", q_, S)
        # state update: S' = diag(exp(cum_Q)) S + Σ_s diag(exp(cum_Q−cum_s)) k_s v_sᵀ
        wS = jnp.exp(cum[:, :, -1:, :] - cum)   # (B,H,Q,hd)
        S_new = jnp.exp(cum[:, :, -1, :])[..., None] * S + \
            jnp.einsum("bhsc,bhsd->bhcd", kc * wS, vc)
        return S_new, y

    S0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    # checkpoint per chunk (same rationale as mamba2: §Perf iteration 6)
    S_fin, y = jax.lax.scan(jax.checkpoint(chunk_body), S0,
                            (rq, kq, vq, lwq))
    y = y.transpose(1, 0, 3, 2, 4).reshape(B, T, D)   # (nc,B,H,Q,hd) → (B,T,D)
    return y, S_fin


def apply_rwkv_time(tp, x: jax.Array, cfg: ModelConfig,
                    x_prev: jax.Array) -> jax.Array:
    """Time-mix (wkv attention substitute) for training/prefill."""
    H = rwkv_heads(cfg)
    r, k, v, logw, g = _project(tp, x, x_prev)
    y, _ = _wkv_chunked(r, k, v, logw, tp["u_bonus"].astype(jnp.float32), H)
    y = _group_norm(y.astype(x.dtype), tp["ln_scale"], H)
    return (y * g) @ tp["w_o"]


def apply_rwkv_channel(cp, x: jax.Array, x_prev: jax.Array) -> jax.Array:
    xs = _token_shift(x, x_prev)
    mu = cp["mu"]
    mix = lambda i: x + (xs - x) * jax.nn.sigmoid(mu[i])[None, None, :]
    k = jnp.square(jax.nn.relu(mix(0) @ cp["w_k"]))
    return jax.nn.sigmoid(mix(1) @ cp["w_r"]) * (k @ cp["w_v"])


def rwkv_time_decode_step(tp, x: jax.Array, S: jax.Array, x_prev: jax.Array,
                          cfg: ModelConfig):
    """One-token time-mix. x: (B,1,D); S: (B,H,hd,hd)."""
    B, _, D = x.shape
    H = rwkv_heads(cfg)
    hd = HEADDIM
    r, k, v, logw, g = _project(tp, x, x_prev)
    rh = r.reshape(B, H, hd).astype(jnp.float32)
    kh = k.reshape(B, H, hd).astype(jnp.float32)
    vh = v.reshape(B, H, hd).astype(jnp.float32)
    w = jnp.exp(logw.reshape(B, H, hd))
    u = tp["u_bonus"].astype(jnp.float32)
    kv = jnp.einsum("bhc,bhd->bhcd", kh, vh)
    y = jnp.einsum("bhc,bhcd->bhd", rh, S + u[None, :, :, None] * kv)
    S_new = w[..., None] * S + kv
    y = y.reshape(B, 1, D).astype(x.dtype)
    y = _group_norm(y, tp["ln_scale"], H)
    return (y * g) @ tp["w_o"], S_new
