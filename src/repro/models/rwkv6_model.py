"""RWKV-6 full model (attention-free 'ssm' family)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import rwkv6
from repro.models.config import ModelConfig
from repro.models.layers import (apply_norm, chunked_lm_loss,
                                 embed_template,
                                 embed_tokens, lm_logits, norm_template,
                                 template_abstract, template_axes,
                                 template_init)
from repro.models.transformer import stack_template


class RWKVDecodeState(NamedTuple):
    S: jax.Array         # (L, B, H, hd, hd) f32 wkv states
    x_prev_t: jax.Array  # (L, B, 1, D)
    x_prev_c: jax.Array  # (L, B, 1, D)
    pos: jax.Array


class RWKV6Model:
    def __init__(self, cfg: ModelConfig, kv_repeat: int = 1, mesh=None,
                 batch_axes=("pod", "data")):
        self.cfg = cfg
        self.kv_repeat = kv_repeat   # unused (attention-free); kept for API
        self.mesh = mesh
        self.batch_axes = batch_axes

    def layer_template(self):
        cfg = self.cfg
        t = rwkv6.rwkv6_template(cfg)
        return {
            "ln1": norm_template(cfg.d_model, "layernorm"),
            "time": t["time"],
            "ln2": norm_template(cfg.d_model, "layernorm"),
            "channel": t["channel"],
        }

    def template(self):
        cfg = self.cfg
        return {
            "embed": embed_template(cfg.vocab_size, cfg.d_model,
                                    cfg.tie_embeddings),
            "layers": stack_template(self.layer_template(), cfg.num_layers),
            "final_norm": norm_template(cfg.d_model, "layernorm"),
        }

    def abstract(self):
        return template_abstract(self.template(), self.cfg.jdtype)

    def init(self, key):
        return template_init(self.template(), key, self.cfg.jdtype)

    def logical_axes(self):
        return template_axes(self.template())

    def hidden_states(self, params, tokens, prefix_embeds=None):
        cfg = self.cfg
        h = embed_tokens(params["embed"], tokens)
        B = h.shape[0]
        zero_prev = jnp.zeros((B, 1, cfg.d_model), h.dtype)

        from repro.models.transformer import constrain_seq_parallel

        def body(h, lp):
            x = apply_norm(h, lp["ln1"], "layernorm", cfg.norm_eps)
            h = h + rwkv6.apply_rwkv_time(lp["time"], x, cfg, zero_prev)
            x = apply_norm(h, lp["ln2"], "layernorm", cfg.norm_eps)
            h = h + rwkv6.apply_rwkv_channel(lp["channel"], x, zero_prev)
            # NOTE: constraint applies only to the channel-mix segment —
            # wkv time-mix needs the full sequence per device (recurrence)
            return constrain_seq_parallel(h, self.mesh, self.batch_axes), None

        if cfg.remat:
            body = jax.checkpoint(body)
        h, _ = jax.lax.scan(body, h, params["layers"])
        return apply_norm(h, params["final_norm"], "layernorm",
                          cfg.norm_eps), jnp.float32(0)

    def forward(self, params, tokens, prefix_embeds=None):
        h, aux = self.hidden_states(params, tokens)
        return lm_logits(params["embed"], h, self.cfg.tie_embeddings), aux

    def loss(self, params, batch):
        h, aux = self.hidden_states(params, batch["tokens"])
        ce = chunked_lm_loss(params["embed"], h, batch["labels"],
                             self.cfg.tie_embeddings, batch.get("loss_mask"))
        return ce + aux, {"ce": ce, "aux": aux}

    # -- decode (O(1) state per token — no KV cache at any context length) --
    def init_decode_state(self, batch: int, cache_len: int) -> RWKVDecodeState:
        cfg = self.cfg
        L, D = cfg.num_layers, cfg.d_model
        H = rwkv6.rwkv_heads(cfg)
        return RWKVDecodeState(
            S=jnp.zeros((L, batch, H, rwkv6.HEADDIM, rwkv6.HEADDIM),
                        jnp.float32),
            x_prev_t=jnp.zeros((L, batch, 1, D), cfg.jdtype),
            x_prev_c=jnp.zeros((L, batch, 1, D), cfg.jdtype),
            pos=jnp.zeros((), jnp.int32))

    def decode_state_abstract(self, batch: int, cache_len: int):
        cfg = self.cfg
        L, D = cfg.num_layers, cfg.d_model
        H = rwkv6.rwkv_heads(cfg)
        return RWKVDecodeState(
            S=jax.ShapeDtypeStruct((L, batch, H, rwkv6.HEADDIM,
                                    rwkv6.HEADDIM), jnp.float32),
            x_prev_t=jax.ShapeDtypeStruct((L, batch, 1, D), cfg.jdtype),
            x_prev_c=jax.ShapeDtypeStruct((L, batch, 1, D), cfg.jdtype),
            pos=jax.ShapeDtypeStruct((), jnp.int32))

    def decode_step(self, params, state: RWKVDecodeState, tokens):
        cfg = self.cfg
        h = embed_tokens(params["embed"], tokens)   # (B, 1, D)

        def body(h, xs):
            lp, S, xpt, xpc = xs
            x = apply_norm(h, lp["ln1"], "layernorm", cfg.norm_eps)
            y, S_new = rwkv6.rwkv_time_decode_step(lp["time"], x, S, xpt, cfg)
            h = h + y
            x2 = apply_norm(h, lp["ln2"], "layernorm", cfg.norm_eps)
            h = h + rwkv6.apply_rwkv_channel(lp["channel"], x2, xpc)
            return h, (S_new, x, x2)

        h, (S, xpt, xpc) = jax.lax.scan(
            body, h, (params["layers"], state.S, state.x_prev_t,
                      state.x_prev_c))
        h = apply_norm(h, params["final_norm"], "layernorm", cfg.norm_eps)
        logits = lm_logits(params["embed"], h, cfg.tie_embeddings)
        return logits, RWKVDecodeState(S=S, x_prev_t=xpt, x_prev_c=xpc,
                                       pos=state.pos + 1)
