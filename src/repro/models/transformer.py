"""Decoder-only transformer stack (dense / moe / vlm families) plus the
unified Model API every family implements:

    model = build_model(cfg, kv_repeat=r)
    params = model.init(key)          /  model.abstract()
    loss, metrics = model.loss(params, batch)
    state = model.init_decode_state(batch_size, cache_len)
    logits, state = model.decode_step(params, state, tokens)

Layer weights are stacked on a leading "layers" axis and the stack runs
under ``lax.scan`` → HLO size is O(1) in depth (94-layer qwen3-moe
compiles in the same budget as 6-layer whisper).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models.config import ModelConfig
from repro.models.layers import (PSpec, apply_mlp, apply_norm,
                                 chunked_lm_loss,
                                 embed_template, embed_tokens, lm_logits,
                                 mlp_template, norm_template,
                                 template_abstract, template_axes,
                                 template_init)


def stack_template(tpl, n: int):
    """Prepend a stacked 'layers' dim to every leaf of a layer template."""
    return jax.tree.map(
        lambda p: PSpec((n,) + p.shape, ("layers",) + p.axes, p.init,
                        p.fan_in),
        tpl, is_leaf=lambda x: isinstance(x, PSpec))


class DecodeState(NamedTuple):
    caches: attn_lib.LayerKVCache   # stacked (L, B, KVr, S, hd)
    pos: jax.Array                  # () int32 — next write position


class TransformerModel:
    """dense | moe | vlm (vlm = dense consuming stub patch embeddings)."""

    def __init__(self, cfg: ModelConfig, kv_repeat: int = 1, mesh=None,
                 batch_axes=("pod", "data")):
        self.cfg = cfg
        self.kv_repeat = kv_repeat
        self.mesh = mesh            # set by the launcher → distributed MoE
        self.batch_axes = batch_axes

    # -- parameters -----------------------------------------------------
    def layer_template(self) -> Dict[str, Any]:
        cfg = self.cfg
        mlp = (moe_lib.moe_template(cfg) if cfg.is_moe
               else mlp_template(cfg.d_model, cfg.d_ff, cfg.mlp_style))
        return {
            "attn_norm": norm_template(cfg.d_model, cfg.norm_style),
            "attn": attn_lib.attn_template(cfg),
            "mlp_norm": norm_template(cfg.d_model, cfg.norm_style),
            "mlp": mlp,
        }

    def template(self) -> Dict[str, Any]:
        cfg = self.cfg
        return {
            "embed": embed_template(cfg.vocab_size, cfg.d_model,
                                    cfg.tie_embeddings),
            "layers": stack_template(self.layer_template(), cfg.num_layers),
            "final_norm": norm_template(cfg.d_model, cfg.norm_style),
        }

    def abstract(self):
        return template_abstract(self.template(), self.cfg.jdtype)

    def init(self, key):
        return template_init(self.template(), key, self.cfg.jdtype)

    def logical_axes(self):
        return template_axes(self.template())

    # -- forward ----------------------------------------------------------
    def _constrain_sp(self, h):
        return constrain_seq_parallel(h, self.mesh, self.batch_axes)

    def _layer_fwd(self, lp, h, positions):
        cfg = self.cfg
        a_in = apply_norm(h, lp["attn_norm"], cfg.norm_style, cfg.norm_eps)
        h = h + attn_lib.attention(lp["attn"], a_in, cfg, positions=positions,
                                   kv_repeat=self.kv_repeat)
        m_in = apply_norm(h, lp["mlp_norm"], cfg.norm_style, cfg.norm_eps)
        if cfg.is_moe:
            if (self.mesh is not None
                    and self.mesh.shape.get("model", 1) > 1
                    and m_in.shape[1] > 1):
                y, aux = moe_lib.apply_moe_sharded(
                    lp["mlp"], m_in, cfg, self.mesh, self.batch_axes)
            else:
                y, aux = moe_lib.apply_moe(lp["mlp"], m_in, cfg)
        else:
            y, aux = apply_mlp(m_in, lp["mlp"], cfg.mlp_style), jnp.float32(0)
        return h + y, aux

    def hidden_states(self, params, tokens: jax.Array,
                      prefix_embeds: Optional[jax.Array] = None):
        """→ (hidden (B, S_total, D), aux_loss). S_total = P + S_text."""
        cfg = self.cfg
        h = embed_tokens(params["embed"], tokens)
        if prefix_embeds is not None:
            h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)
        B, S, _ = h.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))

        def body(carry, lp):
            h, aux = carry
            h, a = self._layer_fwd(lp, h, positions)
            return (self._constrain_sp(h), aux + a), None

        h = self._constrain_sp(h)
        scan = jax.lax.scan
        if cfg.remat:
            body = jax.checkpoint(body)
        (h, aux), _ = scan(body, (h, jnp.float32(0)), params["layers"])
        h = apply_norm(h, params["final_norm"], cfg.norm_style, cfg.norm_eps)
        return h, aux

    def forward(self, params, tokens, prefix_embeds=None):
        h, aux = self.hidden_states(params, tokens, prefix_embeds)
        return lm_logits(params["embed"], h, self.cfg.tie_embeddings), aux

    def loss(self, params, batch: Dict[str, jax.Array]):
        h, aux = self.hidden_states(params, batch["tokens"],
                                    batch.get("prefix_embeds"))
        P = h.shape[1] - batch["labels"].shape[1]
        if P > 0:
            h = h[:, P:, :]                     # loss only on text positions
        ce = chunked_lm_loss(params["embed"], h, batch["labels"],
                             self.cfg.tie_embeddings, batch.get("loss_mask"))
        return ce + aux, {"ce": ce, "aux": aux}

    # -- decode -----------------------------------------------------------
    def init_decode_state(self, batch: int, cache_len: int) -> DecodeState:
        cfg = self.cfg
        one = attn_lib.init_layer_cache(cfg, batch, cache_len,
                                        self.kv_repeat, cfg.jdtype)
        caches = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.num_layers,) + a.shape),
            one)
        return DecodeState(caches=caches, pos=jnp.zeros((), jnp.int32))

    def decode_state_abstract(self, batch: int, cache_len: int) -> DecodeState:
        cfg = self.cfg
        S = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
        KVr = cfg.num_kv_heads * self.kv_repeat
        shape = (cfg.num_layers, batch, KVr, S, cfg.hd)
        kv = jax.ShapeDtypeStruct(shape, cfg.jdtype)
        return DecodeState(
            caches=attn_lib.LayerKVCache(k=kv, v=kv),
            pos=jax.ShapeDtypeStruct((), jnp.int32))

    def decode_step(self, params, state: DecodeState, tokens: jax.Array):
        """tokens: (B, 1) → (logits (B, 1, V), new state)."""
        cfg = self.cfg
        h = embed_tokens(params["embed"], tokens)
        pos = state.pos

        def body(h, xs):
            lp, cache = xs
            a_in = apply_norm(h, lp["attn_norm"], cfg.norm_style, cfg.norm_eps)
            a_out, cache = attn_lib.attention_decode_step(
                lp["attn"], a_in, cache, pos, cfg, self.kv_repeat)
            h = h + a_out
            m_in = apply_norm(h, lp["mlp_norm"], cfg.norm_style, cfg.norm_eps)
            if cfg.is_moe:
                y, _ = moe_lib.apply_moe(lp["mlp"], m_in, cfg)
            else:
                y = apply_mlp(m_in, lp["mlp"], cfg.mlp_style)
            return h + y, cache

        h, caches = jax.lax.scan(body, h, (params["layers"], state.caches))
        h = apply_norm(h, params["final_norm"], cfg.norm_style, cfg.norm_eps)
        logits = lm_logits(params["embed"], h, cfg.tie_embeddings)
        return logits, DecodeState(caches=caches, pos=pos + 1)


def constrain_seq_parallel(h, mesh, batch_axes=("pod", "data")):
    """Shard the residual stream (B, S, D) as (batch, model, None)
    between layers (§Perf iteration 4/6)."""
    if mesh is None or mesh.shape.get("model", 1) <= 1:
        return h
    if h.shape[1] % mesh.shape["model"]:
        return h
    from jax.sharding import PartitionSpec as P
    baxes = tuple(a for a in batch_axes if a in mesh.axis_names)
    bspec = baxes if len(baxes) > 1 else (baxes[0] if baxes else None)
    return jax.lax.with_sharding_constraint(h, P(bspec, "model", None))


def build_model(cfg: ModelConfig, kv_repeat: int = 1, mesh=None):
    """Family dispatcher. Import cycles avoided by deferred imports."""
    if cfg.family in ("dense", "moe", "vlm"):
        return TransformerModel(cfg, kv_repeat, mesh=mesh)
    if cfg.family == "ssm" and cfg.attn_free:
        from repro.models.rwkv6_model import RWKV6Model
        return RWKV6Model(cfg, mesh=mesh)
    if cfg.family == "hybrid":
        from repro.models.hybrid import HybridModel
        return HybridModel(cfg, kv_repeat, mesh=mesh)
    if cfg.family == "audio" and cfg.is_encoder_decoder:
        from repro.models.encdec import EncDecModel
        return EncDecModel(cfg, kv_repeat)
    raise ValueError(f"unknown family {cfg.family!r} for {cfg.name}")
