from repro.optim.adamw import (OptConfig, OptState, abstract_state,
                               apply_updates, clip_by_global_norm,
                               global_norm, init, schedule)
