"""AdamW + cosine schedule + global-norm clipping (pure jnp pytrees).

Optimizer states are pytrees mirroring the params, so the launcher can
shard them with the same PartitionSpecs (ZeRO-1 comes free when params
are fsdp-sharded over the ``data`` axis — DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    mu: Any         # first moment (pytree like params)
    nu: Any         # second moment
    step: jax.Array


def init(params) -> OptState:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return OptState(mu=jax.tree.map(zeros, params),
                    nu=jax.tree.map(zeros, params),
                    step=jnp.zeros((), jnp.int32))


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    """Linear warmup → cosine decay to min_lr_ratio·lr."""
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    frac = jnp.clip((s - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * \
        0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * jnp.where(s < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def apply_updates(params, grads, state: OptState,
                  cfg: OptConfig) -> Tuple[Any, OptState, dict]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    if cfg.clip_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.betas
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * gf
        v = b2 * v + (1 - b2) * jnp.square(gf)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, OptState(mu=new_m, nu=new_v, step=step), \
        {"lr": lr, "grad_norm": gnorm}


def abstract_state(abstract_params) -> OptState:
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return OptState(mu=jax.tree.map(f32, abstract_params),
                    nu=jax.tree.map(f32, abstract_params),
                    step=jax.ShapeDtypeStruct((), jnp.int32))
