from repro.serving.scheduler import BatchScheduler, Request, WaveStats
from repro.serving.svm_stream import (MicroBatch, ModelSnapshot,
                                      StreamingSVMService, StreamWaveStats)
