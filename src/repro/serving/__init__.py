from repro.serving.scheduler import BatchScheduler, Request, WaveStats
