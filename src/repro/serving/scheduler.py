"""Wave-based batch scheduler for the decode path.

Production serving batches independent requests through the same
decode_step. Our decode API tracks one shared position per batch
(synchronized waves), so the scheduler implements iteration-level
batching at wave granularity:

  queue → admit ≤ B requests → right-align prompts into the wave →
  teacher-forced prefill through decode_step → greedy decode until
  every slot hits EOS/max → emit, admit the next wave.

Right-alignment (pad LEFT) lets one shared position serve ragged
prompts: every prompt ENDS at the same step, so generation starts
synchronously; pad tokens at the front attend to nothing real because
they precede the prompt (documented approximation: pads do enter the
cache — with a dedicated pad embedding and few pad steps this is the
standard static-batching trade-off; slot-level continuous batching
needs per-slot positions, noted as future work in DESIGN.md).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    # filled by the scheduler:
    output: Optional[List[int]] = None
    latency_s: float = 0.0


@dataclasses.dataclass
class WaveStats:
    wave: int
    batch: int
    prompt_steps: int
    decode_steps: int
    wall_s: float

    @property
    def tokens_per_s(self) -> float:
        return self.batch * self.decode_steps / max(self.wall_s, 1e-9)


class BatchScheduler:
    """Drives ``model.decode_step`` over a queue of requests."""

    def __init__(self, model, params, batch_size: int, cache_len: int,
                 pad_id: int = 0, frames: Optional[jax.Array] = None):
        self.model = model
        self.params = params
        self.B = batch_size
        self.cache_len = cache_len
        self.pad_id = pad_id
        self.frames = frames
        self._step = jax.jit(model.decode_step)
        self.queue: List[Request] = []
        self.done: List[Request] = []
        self.stats: List[WaveStats] = []

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def run(self) -> List[Request]:
        wave = 0
        while self.queue:
            batch = self.queue[: self.B]
            self.queue = self.queue[self.B:]
            self._run_wave(wave, batch)
            wave += 1
        return self.done

    # ------------------------------------------------------------------
    def _run_wave(self, wave: int, batch: List[Request]) -> None:
        t0 = time.time()
        B = self.B
        max_prompt = max(len(r.prompt) for r in batch)
        max_new = max(r.max_new_tokens for r in batch)
        assert max_prompt + max_new <= self.cache_len, "wave exceeds cache"

        # right-aligned prompt matrix (left pad)
        toks = np.full((B, max_prompt), self.pad_id, np.int32)
        for j, r in enumerate(batch):
            toks[j, max_prompt - len(r.prompt):] = r.prompt

        if self.frames is not None:
            state = self.model.init_decode_state(
                B, self.cache_len, frames=self.frames, params=self.params)
        else:
            state = self.model.init_decode_state(B, self.cache_len)

        # prefill (teacher forced through the decode path)
        logits = None
        for t in range(max_prompt):
            logits, state = self._step(self.params, state,
                                       jnp.asarray(toks[:, t:t + 1]))

        # greedy decode with per-slot completion tracking
        out = [[] for _ in batch]
        live = np.array([True] * B)
        live[len(batch):] = False
        done_at = np.zeros(B)            # admit → slot's EOS step, per slot
        tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
        steps = 0
        while live.any() and steps < max_new:
            tok_np = np.asarray(tok)[:, 0]
            now = time.time()
            for j, r in enumerate(batch):
                if live[j]:
                    out[j].append(int(tok_np[j]))
                    if (r.eos_id is not None and tok_np[j] == r.eos_id) \
                            or len(out[j]) >= r.max_new_tokens:
                        live[j] = False
                        done_at[j] = now
            if not live.any():
                break
            logits, state = self._step(self.params, state, tok)
            tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
            steps += 1

        wall = time.time() - t0
        for j, r in enumerate(batch):
            r.output = out[j]
            # Per-slot latency: a request is done at its own EOS step,
            # not when the whole wave drains — stamping every slot with
            # the wave wall time made throughput uniformly pessimistic.
            r.latency_s = (done_at[j] - t0) if done_at[j] > 0 else wall
            self.done.append(r)
        self.stats.append(WaveStats(wave=wave, batch=len(batch),
                                    prompt_steps=max_prompt,
                                    decode_steps=steps + 1, wall_s=wall))

    def throughput_report(self) -> Dict[str, float]:
        total_tok = sum(len(r.output or []) for r in self.done)
        total_s = sum(s.wall_s for s in self.stats)
        lats = [r.latency_s for r in self.done]
        return {"requests": len(self.done), "tokens": total_tok,
                "wall_s": round(total_s, 3),
                "tok_per_s": round(total_tok / max(total_s, 1e-9), 1),
                "mean_latency_s": round(float(np.mean(lats)), 4) if lats else 0.0,
                "waves": len(self.stats)}
