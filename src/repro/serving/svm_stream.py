"""Streaming polarization service: fold live message batches into
SV_global behind an async wave scheduler (the paper's §SONUÇ future
work, productionized).

The converged global SV set is the model's sufficient statistic
(CloudSVM arXiv:1301.0082, binary MapReduce-SVM arXiv:1312.4108): a
drifted month of messages is absorbed by retraining on
(new batch ∪ carried SVs) — old non-support rows never travel, the
same bandwidth argument as the MapReduce shuffle itself.

Architecture (DESIGN.md §9):

  submit  : vectorized micro-batches queue per tenant *stream*
  admit   : the scheduler pops ≤ ``max_batches_per_wave`` batches per
            stream into one *wave*
  fold    : each admitted stream retrains on (its new rows ∪ its
            carried SVs) via ``update_mapreduce``; when several streams
            are admitted, the wave rides the sweep machinery — S
            streams become S jobs on the config/batch axis of
            :func:`~repro.core.sweep.fit_mapreduce_sweep` (per-job X /
            y / mask + stacked per-stream ``SolverParams``), so all S
            tenants update in ONE jitted device pass; a single admitted
            stream falls back to the plain round
  swap    : ``predict`` / ``decision_values`` keep serving from a
            double-buffered immutable :class:`ModelSnapshot`; the new
            model is fully materialized on device
            (``block_until_ready``) BEFORE the reference swap, so a
            reader never observes a half-updated model

Per-slot accounting mirrors the corrected decode scheduler
(:mod:`repro.serving.scheduler`): every micro-batch records submit →
admit → completion, so queue wait and fold service time are separable
and throughput reports aren't uniformly pessimistic.

Fault tolerance + elasticity (DESIGN.md §13): with ``checkpoint_dir``
set, every tenant's :class:`ModelSnapshot` persists through the
flat-npz checkpointer after each ``checkpoint_every_waves``-th wave
(the model *is* its support vectors — snapshots are tiny, restore is
instant), and :meth:`StreamingSVMService.restore` rebuilds a
queues-empty service from the latest manifest. A fold that dies
mid-wave requeues the un-swapped streams' micro-batches at the HEAD of
their queues — batches complete only *after* the snapshot swap, so
re-admission is exactly-once at the model level. Admission control
bounds the per-tenant queues (``max_queue_per_stream`` +
``shed_policy``), tracks a latency SLO (``slo_s``), and pads the
sweep's job axis to power-of-two buckets so a wave of any width reuses
a handful of compiled programs.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import threading
import time
from typing import Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat, faults
from repro import sparse as sparse_rows
from repro.analysis.retrace import RetraceError, watch_compiles
from repro.ckpt import checkpoint as ckpt
from repro.core.mapreduce_svm import (MapReduceSVM, MRSVMConfig, SVBuffer,
                                      decision_values as mr_decision_values,
                                      init_sv_buffer,
                                      predict as mr_predict,
                                      update_mapreduce)
from repro.core.svm import BinarySVM, SolverParams
from repro.core.sweep import fit_mapreduce_sweep, stack_params

_MANIFEST = "service_manifest.json"


def _all_finite(X, y) -> bool:
    """Whether a micro-batch's features and labels are all finite —
    the quarantine gate at the submit() boundary (DESIGN.md §15): one
    NaN row folded into SV_global poisons the model for every later
    reader, so the check runs once per batch, not per fold."""
    vals = X.values if sparse_rows.is_sparse(X) else X
    return bool(np.isfinite(np.asarray(vals)).all()
                and np.isfinite(np.asarray(y)).all())


def _snapshot_tree(snap: "ModelSnapshot") -> dict:
    """The checkpointable (array-leaf) view of one stream's snapshot.

    ``rounds``/``history``/``version`` are not array leaves — the
    manifest carries ``rounds`` and ``version``; ``history`` is a
    debugging trace and restores empty.
    """
    m = snap.model
    tree = {"model": {"w": m.w, "b": m.b, "risk": jnp.asarray(m.risk),
                      "sv": dict(m.sv._asdict()),
                      "final": dict(m.final._asdict())}}
    if snap.params is not None:
        tree["params"] = dict(snap.params._asdict())
    return tree


def _abstract_snapshot_tree(cfg: MRSVMConfig, d: int,
                            nnz_cap: Optional[int], has_params: bool,
                            dtypes: Dict[str, str]) -> dict:
    """Rebuild the ``like`` tree of :func:`_snapshot_tree` from the
    manifest's static facts: shapes from (cfg, d, nnz_cap), exact leaf
    dtypes from the recorded :func:`repro.ckpt.checkpoint.leaf_dtypes`
    map — so restore validates instead of guessing."""
    cap = cfg.sv_capacity
    f32 = jnp.float32

    def zf(*shape):
        return jnp.zeros(shape, f32)

    sv = init_sv_buffer(cap, d, f32, nnz_cap=nnz_cap)
    final = BinarySVM(alpha=zf(cap), b=zf(), w=zf(d),
                      epochs_run=jnp.zeros((), jnp.int32),
                      max_violation=zf())
    tree = {"model": {"w": zf(d), "b": zf(), "risk": zf(),
                      "sv": dict(sv._asdict()),
                      "final": dict(final._asdict())}}
    if has_params:
        tree["params"] = dict(cfg.svm.params()._asdict())
    return ckpt.with_dtypes(tree, dtypes)


@dataclasses.dataclass
class MicroBatch:
    """One vectorized message micro-batch queued for a stream."""
    uid: int
    stream: str
    X: Optional[jax.Array]      # dropped (None) once the batch folds
    y: Optional[jax.Array]
    # per-slot accounting (stamped by the service):
    submitted_s: float = 0.0
    admitted_s: float = 0.0
    completed_s: float = 0.0
    wave: int = -1

    @property
    def queue_s(self) -> float:
        """Time spent waiting for admission."""
        return max(self.admitted_s - self.submitted_s, 0.0)

    @property
    def latency_s(self) -> float:
        """Submit → the batch's model swap (NOT the whole-wave wall)."""
        return max(self.completed_s - self.submitted_s, 0.0)


class ModelSnapshot(NamedTuple):
    """Immutable served state of one stream.

    Snapshots are never mutated: a fold builds a NEW snapshot off-line
    (double buffer) and the service swaps the reference atomically.
    ``version`` increments per swap — readers can tag results with the
    exact model that produced them.
    """
    model: MapReduceSVM
    params: Optional[SolverParams]
    version: int


@dataclasses.dataclass
class StreamWaveStats:
    """One admission wave of the streaming service."""
    wave: int
    streams: int        # tenants folded this wave
    batches: int        # micro-batches admitted
    rows: int           # new message rows folded
    batched: bool       # True: one jitted sweep pass; False: plain round
    wall_s: float


class StreamingSVMService:
    """Multi-tenant streaming polarization service.

    One service hosts many tenant *streams* sharing a static
    :class:`MRSVMConfig` shell (shapes / kernel family / loop bounds);
    per-stream hyper-params ride the traced :class:`SolverParams`
    pytree, which is exactly what lets S streams update in one batched
    device pass (DESIGN.md §8/§9).
    """

    def __init__(self, cfg: MRSVMConfig, num_partitions: int = 8,
                 max_batches_per_wave: int = 4,
                 keep_history: bool = False,
                 shuffle_impl: Optional[str] = None,
                 cluster=None,
                 checkpoint_dir: Optional[str] = None,
                 checkpoint_every_waves: int = 1,
                 max_queue_per_stream: Optional[int] = None,
                 shed_policy: str = "drop_oldest",
                 max_streams_per_wave: Optional[int] = None,
                 slo_s: Optional[float] = None,
                 pad_wave_to_bucket: bool = True,
                 fail_on_retrace: bool = False,
                 checkpoint_keep: int = 3,
                 quarantine: bool = True,
                 fold_deadline_s: Optional[float] = None,
                 heartbeat_path: Optional[str] = None,
                 watchdog_handler=None):
        # ``shuffle_impl`` overrides the SV merge transport of the
        # config — any of SHUFFLE_IMPLS, including the two-level
        # "hier" schedule (DESIGN.md §10/§16). The functional folds
        # this host-local
        # service runs have no collective, but the config is the single
        # source of truth for any sharded program derived from the
        # service (launch.steps.build_svm_serve_step / dryrun
        # --shape svm_serve), so the override is applied here.
        if shuffle_impl is not None:
            cfg = dataclasses.replace(cfg, shuffle_impl=shuffle_impl)
        # ``cluster`` (repro.launch.cluster.Cluster) makes the service
        # process-count-aware (DESIGN.md §11): ADMISSION — submit,
        # run_wave, the background scheduler — runs on process 0 only
        # (the coordinator owns the queues and drives the folds), while
        # SNAPSHOTS stay readable everywhere (register/predict/
        # decision_values/snapshot are process-local). None → the
        # historical single-process behaviour, every method enabled.
        # Fault tolerance (DESIGN.md §13): ``checkpoint_dir`` turns on
        # durable snapshots — every registered stream persists on
        # register and after each ``checkpoint_every_waves``-th wave;
        # ``restore`` rebuilds the service from the latest manifest.
        # Admission control: ``max_queue_per_stream`` caps each tenant's
        # backlog (``shed_policy``: 'drop_oldest' sheds the stalest
        # batch, 'reject' refuses the submit), ``max_streams_per_wave``
        # bounds the fold's job-axis width (oldest-waiting streams
        # first), ``slo_s`` counts latency-SLO violations, and
        # ``pad_wave_to_bucket`` pads the job axis to the next power of
        # two so any tenant count reuses log2 compiled sweep programs.
        # ``fail_on_retrace`` arms the invariant linter's retrace
        # detector (DESIGN.md §14): a STEADY-STATE fold — one whose
        # exact input signature (bucket width, row padding, formats)
        # already compiled in this service's lifetime — must hit the
        # jit cache; any compile inside it raises ``RetraceError``
        # naming the recompiled program. First-time signatures warm the
        # cache freely.
        # Degraded-mode survival (DESIGN.md §15): ``checkpoint_keep``
        # retains the last N snapshot *generations* (manifest format 2)
        # so restore can fall back past a corrupt newest one;
        # ``quarantine`` diverts non-finite batches at submit() instead
        # of folding NaN into SV_global; ``fold_deadline_s`` arms a
        # CollectiveWatchdog around each wave's folds (heartbeat at
        # ``heartbeat_path``) — ``watchdog_handler`` overrides the
        # default exit-the-process timeout handler for tests/harnesses.
        if shed_policy not in ("drop_oldest", "reject"):
            raise ValueError(f"unknown shed_policy {shed_policy!r} "
                             "(expected 'drop_oldest' or 'reject')")
        self.cluster = cluster
        self.cfg = cfg
        self.L = num_partitions
        self.max_batches_per_wave = max_batches_per_wave
        self.keep_history = keep_history
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_every_waves = checkpoint_every_waves
        self.max_queue_per_stream = max_queue_per_stream
        self.shed_policy = shed_policy
        self.max_streams_per_wave = max_streams_per_wave
        self.slo_s = slo_s
        self.pad_wave_to_bucket = pad_wave_to_bucket
        self.fail_on_retrace = fail_on_retrace
        self.checkpoint_keep = checkpoint_keep
        self.quarantine = quarantine
        self.fold_deadline_s = fold_deadline_s
        self.heartbeat_path = heartbeat_path
        self.watchdog_handler = watchdog_handler
        self._fold_signatures: set = set()
        self._retraces = 0
        self.shed: List[MicroBatch] = []
        self.quarantined: List[MicroBatch] = []
        self.restore_fallbacks = 0
        self._retries = 0
        self._watchdog_fires = 0
        self._requeued = 0
        self._slo_violations = 0
        self._waves_since_ckpt = 0
        self._stream_slot: Dict[str, int] = {}
        self._snapshots: Dict[str, ModelSnapshot] = {}
        self._queues: Dict[str, List[MicroBatch]] = {}
        self._history: Dict[str, Dict[int, ModelSnapshot]] = {}
        self._lock = threading.Lock()          # queues + snapshot refs
        self._cv = threading.Condition(self._lock)
        self._wave_lock = threading.Lock()     # serializes folds
        self._ckpt_lock = threading.Lock()     # serializes checkpoints
        self._uid = 0
        self._wave = 0
        # Generation counter resumes past an existing manifest so a new
        # checkpoint NEVER reuses a file name a kept generation record
        # still references (that would corrupt restorable history).
        self._generation = 0
        self._gen_records: List[dict] = []
        if checkpoint_dir is not None:
            man = self._read_manifest(checkpoint_dir)
            if man is not None and man.get("format", 1) >= 2:
                self._generation = int(man.get("generation", -1)) + 1
                self._gen_records = list(man.get("generations", []))
        self.done: List[MicroBatch] = []
        self.stats: List[StreamWaveStats] = []
        self._thread: Optional[threading.Thread] = None
        self._stop_evt = threading.Event()
        self._scheduler_error: Optional[BaseException] = None

    # -- stream lifecycle --------------------------------------------------

    def register(self, stream: str, model: MapReduceSVM,
                 params: Optional[SolverParams] = None) -> ModelSnapshot:
        """Install a stream's initial model (its version-0 snapshot).

        ``params`` must be the :class:`SolverParams` the model was
        trained with (sweep-selected streams), else the config defaults
        are assumed — the same contract as :func:`update_mapreduce`.
        """
        snap = ModelSnapshot(model=model, params=params, version=0)
        with self._lock:
            if stream in self._snapshots:
                raise ValueError(f"stream {stream!r} already registered")
            self._snapshots[stream] = snap
            self._queues[stream] = []
            self._stream_slot[stream] = len(self._stream_slot)
            if self.keep_history:
                self._history[stream] = {0: snap}
        if self.checkpoint_dir is not None and self._admits:
            # a stream is durable from the moment it exists — a crash
            # between register and the first wave must not lose it
            self.checkpoint()
        return snap

    @classmethod
    def restore(cls, cfg: MRSVMConfig, checkpoint_dir: str,
                **kwargs) -> "StreamingSVMService":
        """Rebuild a queues-empty service from the latest manifest.

        Every stream's snapshot restores at its checkpointed version
        (SV buffer, SolverParams, w/b/final/risk); wave and uid
        counters resume from the manifest so post-restore versions and
        uids keep ascending. Queued-but-unfolded batches are NOT
        durable — clients re-submit anything they never saw complete
        (the exactly-once guarantee is at the model level: a fold is in
        the checkpoint iff its swap happened before the save).

        ``cfg`` must match the checkpointed service's shapes
        (``sv_capacity`` is validated here; per-leaf shape/dtype drift
        fails in :func:`repro.ckpt.checkpoint.restore`). Remaining
        kwargs forward to ``__init__`` — ``num_partitions`` and
        ``max_batches_per_wave`` default to their manifest values.
        """
        path = os.path.join(checkpoint_dir, _MANIFEST)
        if not os.path.exists(path):
            raise FileNotFoundError(
                f"no service manifest under {checkpoint_dir!r} — the "
                "service checkpoints on register and every "
                "checkpoint_every_waves-th wave")
        with open(path) as f:
            man = json.load(f)
        if man.get("sv_capacity") != cfg.sv_capacity:
            raise ValueError(
                f"checkpoint was taken at sv_capacity="
                f"{man.get('sv_capacity')} but cfg has {cfg.sv_capacity} "
                "— restore with the training-time config")
        kwargs.setdefault("num_partitions", man["num_partitions"])
        kwargs.setdefault("max_batches_per_wave",
                          man["max_batches_per_wave"])
        svc = cls(cfg, checkpoint_dir=checkpoint_dir, **kwargs)
        if man.get("format", 1) >= 2:
            gens = list(man.get("generations", []))
        else:                          # format-1: one implicit generation
            gens = [{"generation": 0, "wave": man["wave"],
                     "uid": man["uid"], "streams": man["streams"]}]
        errors: List[str] = []
        restored = None
        for rec in reversed(gens):
            try:
                loaded = {}
                for stream in sorted(rec["streams"]):
                    meta = rec["streams"][stream]
                    fpath = os.path.join(checkpoint_dir, meta["file"])
                    want = meta.get("file_crc32")
                    if want is not None and ckpt.file_crc32(fpath) != want:
                        raise ckpt.CorruptCheckpointError(
                            f"{meta['file']}: medium does not match its "
                            f"recorded crc32")
                    like = _abstract_snapshot_tree(
                        cfg, meta["d"], meta["nnz_cap"],
                        meta["has_params"], meta["dtypes"])
                    tree = ckpt.restore(fpath, like,
                                        checksums=meta.get("checksums"))
                    model = MapReduceSVM(
                        w=tree["model"]["w"], b=tree["model"]["b"],
                        sv=SVBuffer(**tree["model"]["sv"]),
                        final=BinarySVM(**tree["model"]["final"]),
                        risk=tree["model"]["risk"], rounds=meta["rounds"],
                        history=())
                    params = (SolverParams(**tree["params"])
                              if meta["has_params"] else None)
                    loaded[stream] = (
                        ModelSnapshot(model=model, params=params,
                                      version=meta["version"]),
                        meta["slot"])
                restored = (rec, loaded)
                break
            except Exception as e:     # this generation is corrupt/missing
                errors.append(f"generation {rec.get('generation')}: {e}")
                faults.count("ckpt_fallbacks")
                svc.restore_fallbacks += 1
        if restored is None:
            raise faults.FaultDetected(
                "ckpt",
                f"no intact snapshot generation under {checkpoint_dir!r}"
                f" ({'; '.join(errors) or 'no generations recorded'})",
                action="restore from an older backup or re-register the "
                       "streams from their training pipelines")
        rec, loaded = restored
        if svc.restore_fallbacks:
            print(f"[svm_stream] newest snapshot generation(s) failed "
                  f"verification — restored generation "
                  f"{rec.get('generation')} instead "
                  f"({svc.restore_fallbacks} skipped)", flush=True)
        with svc._lock:
            for stream, (snap, slot) in loaded.items():
                svc._snapshots[stream] = snap
                svc._queues[stream] = []
                svc._stream_slot[stream] = slot
                if svc.keep_history:
                    svc._history[stream] = {snap.version: snap}
            svc._wave = rec["wave"]
            svc._uid = rec["uid"]
        return svc

    @staticmethod
    def _read_manifest(checkpoint_dir: str) -> Optional[dict]:
        try:
            with open(os.path.join(checkpoint_dir, _MANIFEST)) as f:
                return json.load(f)
        except (OSError, json.JSONDecodeError, ValueError):
            return None

    def checkpoint(self) -> str:
        """Durably snapshot every stream + the service counters;
        returns the manifest path.

        Layout under ``checkpoint_dir``: one flat-npz per stream per
        *generation* (``gen000007_stream0.npz``; atomic tmp→rename,
        :func:`repro.ckpt.checkpoint.save`) plus an atomically-replaced
        JSON manifest (format 2) recording the last
        ``checkpoint_keep`` generations — per-stream per-leaf crc32s
        and the file crc32 ride along, so :meth:`restore` verifies each
        payload and falls BACK past a corrupt newest generation instead
        of restoring silently wrong state. A crash at ANY point leaves
        the previous complete checkpoint installed, never a torn one;
        media of pruned generations are GC'd.
        """
        if self.checkpoint_dir is None:
            raise RuntimeError(
                "service was built without checkpoint_dir")
        os.makedirs(self.checkpoint_dir, exist_ok=True)
        with self._ckpt_lock:
            gen = self._generation
            self._generation += 1
            with self._lock:
                snaps = dict(self._snapshots)
                slots = dict(self._stream_slot)
                wave, uid = self._wave, self._uid
            streams_meta = {}
            for stream, snap in snaps.items():
                fname = f"gen{gen:06d}_stream{slots[stream]}.npz"
                tree = _snapshot_tree(snap)
                crc = ckpt.save(
                    os.path.join(self.checkpoint_dir, fname), tree,
                    on_retry=self._note_retry)
                x = snap.model.sv.x
                sp = sparse_rows.is_sparse(x)
                streams_meta[stream] = {
                    "file": fname, "slot": slots[stream],
                    "version": snap.version,
                    "rounds": int(snap.model.rounds),
                    "d": int(x.shape[1]),
                    "nnz_cap": int(x.nnz_cap) if sp else None,
                    "has_params": snap.params is not None,
                    "dtypes": ckpt.leaf_dtypes(tree),
                    "checksums": ckpt.leaf_checksums(tree),
                    "file_crc32": crc,
                }
            rec = {"generation": gen, "wave": wave, "uid": uid,
                   "streams": streams_meta}
            records = [r for r in self._gen_records
                       if r.get("generation") != gen] + [rec]
            keep = max(int(self.checkpoint_keep), 1)
            dropped, records = records[:-keep], records[-keep:]
            self._gen_records = records
            # Top-level wave/uid/streams mirror the newest generation so
            # format-1 readers (benchmarks, older tooling) keep working.
            ckpt.atomic_write_json(
                os.path.join(self.checkpoint_dir, _MANIFEST),
                {"format": 2, "wave": wave, "uid": uid,
                 "sv_capacity": self.cfg.sv_capacity,
                 "num_partitions": self.L,
                 "max_batches_per_wave": self.max_batches_per_wave,
                 "generation": gen, "generations": records,
                 "streams": streams_meta},
                on_retry=self._note_retry)
            kept = {m["file"] for r in records
                    for m in r["streams"].values()}
            for r in dropped:
                for m in r["streams"].values():
                    if m["file"] not in kept:
                        try:
                            os.remove(os.path.join(self.checkpoint_dir,
                                                   m["file"]))
                        except OSError:
                            pass
            self._waves_since_ckpt = 0
            return os.path.join(self.checkpoint_dir, _MANIFEST)

    def _note_retry(self, attempt: int, exc: BaseException) -> None:
        self._retries += 1

    def streams(self) -> List[str]:
        with self._lock:
            return list(self._snapshots)

    def snapshot(self, stream: str) -> ModelSnapshot:
        """The stream's current served snapshot (atomic reference read)."""
        with self._lock:
            return self._snapshots[stream]

    def history(self, stream: str) -> Dict[int, ModelSnapshot]:
        """version → snapshot (only populated with ``keep_history``)."""
        with self._lock:
            return dict(self._history.get(stream, {}))

    # -- ingest ------------------------------------------------------------

    @property
    def _admits(self) -> bool:
        """Whether THIS process runs admission (process 0, or local)."""
        return self.cluster is None or self.cluster.is_coordinator

    def submit(self, stream: str, X: jax.Array, y: jax.Array) -> int:
        """Queue one vectorized micro-batch; returns its uid. ``X`` is
        dense ``(n, d)`` or blocked-CSR :class:`repro.sparse.SparseRows`
        — whichever format the stream's model serves.

        Admission is coordinator-only on a multi-process cluster: a
        submit on any other process is a routing bug (its queue would
        silently never fold), so it raises instead of enqueueing. A
        dead scheduler raises too — enqueueing behind one grows queues
        that can never fold while readers pin the stale snapshot.
        """
        if self._scheduler_error is not None:
            raise RuntimeError(
                "streaming scheduler died — restart the service (or "
                "StreamingSVMService.restore from its checkpoint) before "
                "submitting more work") from self._scheduler_error
        if not self._admits:
            raise RuntimeError(
                f"stream admission runs on process 0; this is process "
                f"{self.cluster.process_index} of "
                f"{self.cluster.process_count} (snapshots stay readable "
                "here — route submissions to the coordinator)")
        # featurizer seam: an armed poison_rows fault lands NaN/Inf in
        # the batch exactly where a buggy upstream vectorizer would
        spec = faults.fire("serving.submit", kinds=("poison_rows",))
        if spec is not None:
            X, y = faults.poison_batch(X, y, spec)
        if not sparse_rows.is_sparse(X):
            X = jnp.asarray(X)
        y = jnp.asarray(y)
        if X.ndim != 2 or y.shape[0] != X.shape[0]:
            raise ValueError(f"micro-batch must be (n, d) rows with (n,) "
                             f"labels; got X{X.shape} y{y.shape}")
        with self._cv:
            if stream not in self._snapshots:
                raise KeyError(f"unregistered stream {stream!r}")
            sv_x = self._snapshots[stream].model.sv.x
            d_model = sv_x.shape[1]
            if X.shape[1] != d_model:
                raise ValueError(
                    f"stream {stream!r} serves {d_model}-dim features but "
                    f"the batch has {X.shape[1]} — vectorize with the same "
                    "featurizer as training")
            sp_model = sparse_rows.is_sparse(sv_x)
            sp_batch = sparse_rows.is_sparse(X)
            if sp_model != sp_batch:
                raise ValueError(
                    f"stream {stream!r} serves "
                    f"{'sparse' if sp_model else 'dense'} rows but the "
                    f"batch is {'sparse' if sp_batch else 'dense'} — "
                    "submit the model's row format")
            if sp_batch and X.nnz_cap != sv_x.nnz_cap:
                raise ValueError(
                    f"stream {stream!r} serves nnz_cap={sv_x.nnz_cap} "
                    f"rows but the batch has nnz_cap={X.nnz_cap} — "
                    "re-block with the model's cap")
            if self.quarantine and not _all_finite(X, y):
                # NaN/Inf never reaches a fold: one poisoned row in
                # SV_global would corrupt every later wave's model.
                # The batch is acknowledged (uid) but diverted —
                # counted in throughput_report for the operator.
                faults.count("quarantined")
                self._uid += 1
                mb = MicroBatch(uid=self._uid, stream=stream,
                                X=None, y=None,
                                submitted_s=time.time())
                self.quarantined.append(mb)
                return mb.uid
            q = self._queues[stream]
            if (self.max_queue_per_stream is not None
                    and len(q) >= self.max_queue_per_stream):
                if self.shed_policy == "reject":
                    raise RuntimeError(
                        f"stream {stream!r} queue is at its cap "
                        f"({self.max_queue_per_stream}) — admission "
                        "control rejected the batch (shed_policy="
                        "'reject')")
                # drop_oldest: the stalest queued batch is the least
                # valuable under drift — shed it, keep the fresh one
                old = q.pop(0)
                old.X = old.y = None
                self.shed.append(old)
            self._uid += 1
            mb = MicroBatch(uid=self._uid, stream=stream, X=X, y=y,
                            submitted_s=time.time())
            self._queues[stream].append(mb)
            self._cv.notify_all()
            return mb.uid

    def pending(self) -> int:
        with self._lock:
            return sum(len(q) for q in self._queues.values())

    # -- serve -------------------------------------------------------------

    def decision_values(self, stream: str, X: jax.Array) -> jax.Array:
        """Scores from the stream's CURRENT snapshot. The snapshot
        reference is read once, so an update swapping mid-call can never
        yield a half-updated model (snapshots are immutable)."""
        snap = self.snapshot(stream)
        return mr_decision_values(snap.model, X, self.cfg, params=snap.params)

    def predict(self, stream: str, X: jax.Array,
                with_version: bool = False):
        """±1 polarization labels from the current snapshot."""
        snap = self.snapshot(stream)
        pred = mr_predict(snap.model, X, self.cfg, params=snap.params)
        return (pred, snap.version) if with_version else pred

    # -- wave admission + fold --------------------------------------------

    def _admit(self) -> Dict[str, Tuple[ModelSnapshot, List[MicroBatch]]]:
        """Pop ≤ max_batches_per_wave batches per stream, pairing each
        admitted stream with the snapshot whose SVs the fold carries.
        With ``max_streams_per_wave`` the wave is width-bounded: the
        streams whose HEAD batch has waited longest go first, so a
        narrow fold never starves a tenant."""
        now = time.time()
        admitted: Dict[str, Tuple[ModelSnapshot, List[MicroBatch]]] = {}
        with self._lock:
            ready = sorted((q[0].submitted_s, stream)
                           for stream, q in self._queues.items() if q)
            if self.max_streams_per_wave is not None:
                ready = ready[:self.max_streams_per_wave]
            for _, stream in ready:
                q = self._queues[stream]
                take, self._queues[stream] = (q[:self.max_batches_per_wave],
                                              q[self.max_batches_per_wave:])
                for mb in take:
                    mb.admitted_s = now
                    mb.wave = self._wave
                admitted[stream] = (self._snapshots[stream], take)
        return admitted

    def _swap(self, stream: str, model: MapReduceSVM,
              params: Optional[SolverParams]) -> ModelSnapshot:
        """Atomically publish a fully-materialized new snapshot."""
        jax.block_until_ready((model.sv, model.final, model.w, model.b))
        with self._lock:
            old = self._snapshots[stream]
            snap = ModelSnapshot(model=model, params=params,
                                 version=old.version + 1)
            self._snapshots[stream] = snap
            if self.keep_history:
                self._history[stream][snap.version] = snap
        return snap

    def run_wave(self) -> Optional[StreamWaveStats]:
        """Admit one wave and fold it. Returns its stats, or ``None``
        when every queue was empty. Thread-safe; folds are serialized.
        No-op (``None``) off the coordinator — nothing can be queued
        there (see :meth:`submit`)."""
        if not self._admits:
            return None
        with self._wave_lock:
            t0 = time.time()
            admitted = self._admit()
            if not admitted:
                return None
            wave_id = self._wave
            self._wave += 1

            names = sorted(admitted)
            joined = {}
            for s in names:
                snap, batches = admitted[s]
                Xn = sparse_rows.rows_concat_all(
                    [mb.X for mb in batches], axis=0)
                yn = jnp.concatenate([mb.y.astype(Xn.dtype)
                                      for mb in batches], axis=0)
                joined[s] = (snap, batches, Xn, yn)

            swapped: List[str] = []
            any_batched = False
            try:
                # scheduler seam: an armed scheduler_kill dies here, so
                # _recover_wave requeues every admitted batch (HEAD of
                # queue) before the error surfaces.
                faults.maybe_raise("serving.wave",
                                   kinds=("scheduler_kill",),
                                   when=wave_id)
                wd_ctx = (faults.CollectiveWatchdog(
                              self.fold_deadline_s,
                              heartbeat_path=self.heartbeat_path,
                              layer="serving",
                              cause=f"wave {wave_id} fold",
                              action="kill the process and restore the "
                                     "service from its last checkpoint "
                                     "generation",
                              on_timeout=self._on_watchdog_timeout)
                          if self.fold_deadline_s is not None
                          else contextlib.nullcontext())
                with wd_ctx as wd:
                    # stall seam: a fold that stops making progress —
                    # bounded sleep past the deadline, so the watchdog
                    # (not the harness's patience) ends it
                    if faults.fire("serving.stall", ("stall",),
                                   when=wave_id) is not None:
                        time.sleep((self.fold_deadline_s or 0.5) * 1.5)
                    for group in self._fold_groups(names, joined):
                        if len(group) == 1:
                            # single tenant: the plain incremental round
                            s = group[0]
                            snap, batches, Xn, yn = joined[s]
                            sig = self._fold_signature(
                                "single", Xn, yn, snap.model.sv)
                            with self._retrace_guard(
                                    sig,
                                    f"run_wave single-tenant fold {s}"):
                                model = update_mapreduce(
                                    snap.model, Xn, yn, self.L,
                                    self.cfg, params=snap.params)
                            self._swap(s, model, snap.params)
                            swapped.append(s)
                        else:
                            any_batched = True
                            self._fold_batched(joined, group, swapped)
                        if wd is not None:
                            wd.beat()
                if wd is not None:
                    wd.check()
            except BaseException:
                self._recover_wave(joined, names, swapped)
                raise

            now = time.time()
            n_batches = n_rows = 0
            for s in names:
                _, batches, Xn, _ = joined[s]
                n_batches += len(batches)
                n_rows += int(Xn.shape[0])
                for mb in batches:
                    mb.completed_s = now
                    if self.slo_s is not None and mb.latency_s > self.slo_s:
                        self._slo_violations += 1
                    # Folded rows live on in SV_global (or were
                    # discarded as non-support); keeping every
                    # historical batch pinned in ``done`` would grow
                    # memory without bound in a long-running service —
                    # only the accounting fields survive.
                    mb.X = mb.y = None
                    self.done.append(mb)
            st = StreamWaveStats(wave=wave_id, streams=len(names),
                                 batches=n_batches, rows=n_rows,
                                 batched=any_batched,
                                 wall_s=now - t0)
            self.stats.append(st)
            if (self.checkpoint_dir is not None
                    and self.checkpoint_every_waves > 0):
                self._waves_since_ckpt += 1
                if self._waves_since_ckpt >= self.checkpoint_every_waves:
                    self.checkpoint()
            return st

    @contextlib.contextmanager
    def _retrace_guard(self, signature: tuple, label: str):
        """Steady-state jit-cache tripwire around one fold
        (DESIGN.md §14). The signature — every folded leaf's
        (shape, dtype) plus the driver width — identifies a compiled
        program family; the first fold of a signature warms the cache,
        any later fold of the SAME signature that still compiles is a
        retrace bug and raises :class:`RetraceError`."""
        if not self.fail_on_retrace:
            self._fold_signatures.add(signature)
            yield
            return
        first = signature not in self._fold_signatures
        with watch_compiles() as stats:
            yield
        self._fold_signatures.add(signature)
        if not first and stats.count:
            self._retraces += stats.count
            raise RetraceError(label, stats.events)

    @staticmethod
    def _fold_signature(kind: str, *trees) -> tuple:
        leaves = jax.tree_util.tree_leaves(trees)
        return (kind,) + tuple((tuple(a.shape), str(a.dtype))
                               for a in leaves)

    def _fold_groups(self, names, joined) -> List[List[str]]:
        """Partition admitted streams into stackable fold groups.

        The batched fold stacks per-job rows on the sweep axis, so jobs
        must agree on (format, d, nnz_cap); a mixed wave — PR 6 sparse
        tenants next to dense ones, or tenants on different hash spaces
        — folds as one sweep pass per group instead of failing."""
        groups: Dict[tuple, List[str]] = {}
        for s in names:
            x = joined[s][0].model.sv.x
            sp = sparse_rows.is_sparse(x)
            key = (sp, int(x.shape[1]), int(x.nnz_cap) if sp else -1)
            groups.setdefault(key, []).append(s)
        return [groups[k] for k in sorted(groups)]

    def _bucket_width(self, n: int) -> int:
        """Job-axis width the fold compiles at: the next power of two
        (elastic waves of 3, 5-8, … tenants share log2 programs
        instead of retracing per width)."""
        if not self.pad_wave_to_bucket or n <= 1:
            return n
        width = 1
        while width < n:
            width *= 2
        return width

    def _recover_wave(self, joined, names, swapped) -> None:
        """Mid-wave failure (worker loss, preemption, OOM): exactly-once
        at the model level.

        Streams whose snapshot already swapped have their batches
        completed — the published model contains them. Every other
        admitted batch goes BACK to the HEAD of its queue with its rows
        still pinned (X/y drop only on completion), so the next wave —
        on whatever mesh survived, or after a checkpoint restart —
        re-admits and re-folds it exactly once."""
        now = time.time()
        done_set = set(swapped)
        with self._lock:
            for s in names:
                _, batches, _, _ = joined[s]
                if s in done_set:
                    for mb in batches:
                        mb.completed_s = now
                        mb.X = mb.y = None
                        self.done.append(mb)
                else:
                    self._queues[s][:0] = batches
                    self._requeued += len(batches)

    def _fold_batched(self, joined, names, swapped) -> None:
        """S admitted streams = S jobs on the sweep's config/batch axis:
        per-job (X, y, mask) + stacked per-stream SolverParams, one
        jitted device pass (DESIGN.md §9). Rows route through the
        format-generic sparse helpers, so blocked-CSR tenants batch the
        same way dense ones do. Each stream appends to ``swapped`` the
        moment its snapshot publishes (recovery bookkeeping)."""
        cap = self.cfg.sv_capacity
        d = joined[names[0]][0].model.sv.x.shape[1]
        n_max = max(int(joined[s][2].shape[0]) for s in names) + cap

        Xs, ys, ms, ps = [], [], [], []
        for s in names:
            snap, _, Xn, yn = joined[s]
            sv = snap.model.sv
            n_new = int(Xn.shape[0])
            pad = n_max - n_new - cap
            dt = yn.dtype
            Xs.append(sparse_rows.pad_rows(
                sparse_rows.rows_concat(Xn, sv.x, axis=0), pad))
            ys.append(jnp.concatenate(
                [yn, sv.y.astype(dt), jnp.zeros((pad,), dt)], axis=0))
            ms.append(jnp.concatenate(
                [jnp.ones((n_new,), dt), sv.mask.astype(dt),
                 jnp.zeros((pad,), dt)], axis=0))
            ps.append(snap.params if snap.params is not None
                      else self.cfg.svm.params())
        # Elastic job axis: pad to the bucket width with all-masked
        # zero jobs (their results are discarded below) so a wave of
        # any tenant count reuses the bucket's compiled program.
        for _ in range(self._bucket_width(len(names)) - len(names)):
            Xs.append(sparse_rows.rows_zeros_like(Xs[0]))
            ys.append(jnp.zeros_like(ys[0]))
            ms.append(jnp.zeros_like(ms[0]))
            ps.append(ps[0])
        Xb = sparse_rows.rows_stack(Xs)          # (S', n_max, d)
        yb = jnp.stack(ys)                       # (S', n_max)
        mb_ = jnp.stack(ms)                      # (S', n_max)
        params_b = stack_params(ps)

        sig = self._fold_signature("batched", Xb, yb, mb_, params_b)
        with self._retrace_guard(
                sig, f"run_wave batched fold ({len(names)} streams)"):
            res = fit_mapreduce_sweep(Xb, yb, self.L, self.cfg, params_b,
                                      mask=mb_)
        for i, s in enumerate(names):            # padding jobs dropped
            snap = joined[s][0]
            model = MapReduceSVM(
                w=res.ws[i], b=res.bs[i],
                sv=compat.tree_map(lambda a: a[i], res.sv),
                final=compat.tree_map(lambda a: a[i], res.final),
                risk=res.risks[i], rounds=int(res.rounds[i]), history=())
            self._swap(s, model, snap.params)
            swapped.append(s)

    def drain(self) -> int:
        """Run waves until every queue is empty; returns waves run."""
        waves = 0
        while self.run_wave() is not None:
            waves += 1
        return waves

    # -- async scheduler ---------------------------------------------------

    def start(self, idle_poll_s: float = 0.05) -> None:
        """Start the background wave scheduler: batches submitted after
        this fold in continuously without blocking the submitter.
        No-op off the coordinator, so symmetric SPMD launch code can
        call it unconditionally."""
        if not self._admits:
            return
        with self._lock:
            if self._thread is not None:
                return
            self._stop_evt.clear()
            self._scheduler_error = None
            self._thread = threading.Thread(
                target=self._scheduler_loop, args=(idle_poll_s,),
                name="svm-stream-scheduler", daemon=True)
            self._thread.start()

    @property
    def scheduler_error(self) -> Optional[BaseException]:
        """The exception that killed the background scheduler, if any."""
        return self._scheduler_error

    def _scheduler_loop(self, idle_poll_s: float) -> None:
        while not self._stop_evt.is_set():
            with self._cv:
                while (not self._stop_evt.is_set()
                       and not any(self._queues.values())):
                    self._cv.wait(timeout=idle_poll_s)
                if self._stop_evt.is_set():
                    return
            try:
                self.run_wave()
            except BaseException as e:
                # A silently dead daemon thread would leave queues
                # growing and readers on the stale snapshot forever —
                # record the error (wait_idle/stop re-raise it) and
                # shut the loop down loudly.
                self._scheduler_error = e
                self._stop_evt.set()
                import traceback
                traceback.print_exc()
                return

    def _on_watchdog_timeout(self, info: dict) -> None:
        self._watchdog_fires += 1
        handler = self.watchdog_handler
        if handler is not None:
            handler(info)
        else:
            faults.exit_handler(info)

    def wait_idle(self, timeout_s: float = 120.0,
                  poll_s: float = 0.01) -> bool:
        """Block until every queue is empty AND no wave is in flight.

        A doomed wait surfaces IMMEDIATELY instead of burning the full
        timeout: a recorded scheduler error re-raises, a scheduler
        thread that died WITHOUT recording one (killed interpreter-side,
        a bug in the loop itself) raises, and queued work with no
        scheduler running at all raises — in every one of those states
        no amount of waiting can drain the queues. Returns ``False``
        only for a genuine timeout (slow folds still in flight)."""
        deadline = time.time() + timeout_s
        while True:
            if self._scheduler_error is not None:
                raise RuntimeError(
                    "streaming scheduler died") from self._scheduler_error
            thread = self._thread
            if (thread is not None and not thread.is_alive()
                    and not self._stop_evt.is_set()):
                raise RuntimeError(
                    "scheduler thread died without recording an error — "
                    "restart the service (restore from its checkpoint "
                    "if one was configured)")
            if thread is None and self.pending() > 0:
                raise RuntimeError(
                    "no scheduler is running but work is queued — call "
                    "start() (or drain() synchronously) first")
            if self.pending() == 0 and not self._wave_lock.locked():
                return True
            if time.time() >= deadline:
                return False
            time.sleep(poll_s)

    def stop(self, drain: bool = True, timeout_s: float = 60.0) -> None:
        """Stop the scheduler thread; optionally fold what's queued.
        Re-raises the error that killed the scheduler, if any. A thread
        that refuses to die within ``timeout_s`` — stranded in a fold
        collective — raises a typed :class:`~repro.faults.FaultDetected`
        instead of silently leaking the daemon."""
        thread = self._thread
        if thread is None:
            return
        self._stop_evt.set()
        with self._cv:
            self._cv.notify_all()
        thread.join(timeout=timeout_s)
        if thread.is_alive():
            raise faults.FaultDetected(
                "serving",
                f"scheduler thread refused to die within {timeout_s:.0f}s"
                " (likely stranded in a fold collective)",
                action="kill the process and restart from the last "
                       "checkpoint generation")
        self._thread = None
        if self._scheduler_error is not None:
            raise RuntimeError(
                "streaming scheduler died") from self._scheduler_error
        if drain:
            self.drain()

    # -- reporting ---------------------------------------------------------

    def throughput_report(self) -> Dict[str, float]:
        lats = [mb.latency_s for mb in self.done]
        queues = [mb.queue_s for mb in self.done]
        rows = sum(s.rows for s in self.stats)
        wall = sum(s.wall_s for s in self.stats)
        return {
            "batches": len(self.done),
            "rows": rows,
            "waves": len(self.stats),
            "wall_s": round(wall, 3),
            "rows_per_s": round(rows / max(wall, 1e-9), 1),
            "mean_latency_s": round(float(np.mean(lats)), 4) if lats else 0.0,
            "p95_latency_s": (round(float(np.percentile(lats, 95)), 4)
                              if lats else 0.0),
            "mean_queue_s": (round(float(np.mean(queues)), 4)
                             if queues else 0.0),
            "shed": len(self.shed),
            "requeued": self._requeued,
            "slo_violations": self._slo_violations,
            "fold_programs": len(self._fold_signatures),
            "retraces": self._retraces,
            "quarantined": len(self.quarantined),
            "retries": self._retries,
            "watchdog_fires": self._watchdog_fires,
        }
