"""Streaming polarization service: fold live message batches into
SV_global behind an async wave scheduler (the paper's §SONUÇ future
work, productionized).

The converged global SV set is the model's sufficient statistic
(CloudSVM arXiv:1301.0082, binary MapReduce-SVM arXiv:1312.4108): a
drifted month of messages is absorbed by retraining on
(new batch ∪ carried SVs) — old non-support rows never travel, the
same bandwidth argument as the MapReduce shuffle itself.

Architecture (DESIGN.md §9):

  submit  : vectorized micro-batches queue per tenant *stream*
  admit   : the scheduler pops ≤ ``max_batches_per_wave`` batches per
            stream into one *wave*
  fold    : each admitted stream retrains on (its new rows ∪ its
            carried SVs) via ``update_mapreduce``; when several streams
            are admitted, the wave rides the sweep machinery — S
            streams become S jobs on the config/batch axis of
            :func:`~repro.core.sweep.fit_mapreduce_sweep` (per-job X /
            y / mask + stacked per-stream ``SolverParams``), so all S
            tenants update in ONE jitted device pass; a single admitted
            stream falls back to the plain round
  swap    : ``predict`` / ``decision_values`` keep serving from a
            double-buffered immutable :class:`ModelSnapshot`; the new
            model is fully materialized on device
            (``block_until_ready``) BEFORE the reference swap, so a
            reader never observes a half-updated model

Per-slot accounting mirrors the corrected decode scheduler
(:mod:`repro.serving.scheduler`): every micro-batch records submit →
admit → completion, so queue wait and fold service time are separable
and throughput reports aren't uniformly pessimistic.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.core.mapreduce_svm import (MapReduceSVM, MRSVMConfig,
                                      decision_values as mr_decision_values,
                                      predict as mr_predict,
                                      update_mapreduce)
from repro.core.svm import SolverParams
from repro.core.sweep import fit_mapreduce_sweep, stack_params


@dataclasses.dataclass
class MicroBatch:
    """One vectorized message micro-batch queued for a stream."""
    uid: int
    stream: str
    X: Optional[jax.Array]      # dropped (None) once the batch folds
    y: Optional[jax.Array]
    # per-slot accounting (stamped by the service):
    submitted_s: float = 0.0
    admitted_s: float = 0.0
    completed_s: float = 0.0
    wave: int = -1

    @property
    def queue_s(self) -> float:
        """Time spent waiting for admission."""
        return max(self.admitted_s - self.submitted_s, 0.0)

    @property
    def latency_s(self) -> float:
        """Submit → the batch's model swap (NOT the whole-wave wall)."""
        return max(self.completed_s - self.submitted_s, 0.0)


class ModelSnapshot(NamedTuple):
    """Immutable served state of one stream.

    Snapshots are never mutated: a fold builds a NEW snapshot off-line
    (double buffer) and the service swaps the reference atomically.
    ``version`` increments per swap — readers can tag results with the
    exact model that produced them.
    """
    model: MapReduceSVM
    params: Optional[SolverParams]
    version: int


@dataclasses.dataclass
class StreamWaveStats:
    """One admission wave of the streaming service."""
    wave: int
    streams: int        # tenants folded this wave
    batches: int        # micro-batches admitted
    rows: int           # new message rows folded
    batched: bool       # True: one jitted sweep pass; False: plain round
    wall_s: float


class StreamingSVMService:
    """Multi-tenant streaming polarization service.

    One service hosts many tenant *streams* sharing a static
    :class:`MRSVMConfig` shell (shapes / kernel family / loop bounds);
    per-stream hyper-params ride the traced :class:`SolverParams`
    pytree, which is exactly what lets S streams update in one batched
    device pass (DESIGN.md §8/§9).
    """

    def __init__(self, cfg: MRSVMConfig, num_partitions: int = 8,
                 max_batches_per_wave: int = 4,
                 keep_history: bool = False,
                 shuffle_impl: Optional[str] = None,
                 cluster=None):
        # ``shuffle_impl`` overrides the SV merge transport of the
        # config (DESIGN.md §10). The functional folds this host-local
        # service runs have no collective, but the config is the single
        # source of truth for any sharded program derived from the
        # service (launch.steps.build_svm_serve_step / dryrun
        # --shape svm_serve), so the override is applied here.
        if shuffle_impl is not None:
            cfg = dataclasses.replace(cfg, shuffle_impl=shuffle_impl)
        # ``cluster`` (repro.launch.cluster.Cluster) makes the service
        # process-count-aware (DESIGN.md §11): ADMISSION — submit,
        # run_wave, the background scheduler — runs on process 0 only
        # (the coordinator owns the queues and drives the folds), while
        # SNAPSHOTS stay readable everywhere (register/predict/
        # decision_values/snapshot are process-local). None → the
        # historical single-process behaviour, every method enabled.
        self.cluster = cluster
        self.cfg = cfg
        self.L = num_partitions
        self.max_batches_per_wave = max_batches_per_wave
        self.keep_history = keep_history
        self._snapshots: Dict[str, ModelSnapshot] = {}
        self._queues: Dict[str, List[MicroBatch]] = {}
        self._history: Dict[str, Dict[int, ModelSnapshot]] = {}
        self._lock = threading.Lock()          # queues + snapshot refs
        self._cv = threading.Condition(self._lock)
        self._wave_lock = threading.Lock()     # serializes folds
        self._uid = 0
        self._wave = 0
        self.done: List[MicroBatch] = []
        self.stats: List[StreamWaveStats] = []
        self._thread: Optional[threading.Thread] = None
        self._stop_evt = threading.Event()
        self._scheduler_error: Optional[BaseException] = None

    # -- stream lifecycle --------------------------------------------------

    def register(self, stream: str, model: MapReduceSVM,
                 params: Optional[SolverParams] = None) -> ModelSnapshot:
        """Install a stream's initial model (its version-0 snapshot).

        ``params`` must be the :class:`SolverParams` the model was
        trained with (sweep-selected streams), else the config defaults
        are assumed — the same contract as :func:`update_mapreduce`.
        """
        snap = ModelSnapshot(model=model, params=params, version=0)
        with self._lock:
            if stream in self._snapshots:
                raise ValueError(f"stream {stream!r} already registered")
            self._snapshots[stream] = snap
            self._queues[stream] = []
            if self.keep_history:
                self._history[stream] = {0: snap}
        return snap

    def streams(self) -> List[str]:
        with self._lock:
            return list(self._snapshots)

    def snapshot(self, stream: str) -> ModelSnapshot:
        """The stream's current served snapshot (atomic reference read)."""
        with self._lock:
            return self._snapshots[stream]

    def history(self, stream: str) -> Dict[int, ModelSnapshot]:
        """version → snapshot (only populated with ``keep_history``)."""
        with self._lock:
            return dict(self._history.get(stream, {}))

    # -- ingest ------------------------------------------------------------

    @property
    def _admits(self) -> bool:
        """Whether THIS process runs admission (process 0, or local)."""
        return self.cluster is None or self.cluster.is_coordinator

    def submit(self, stream: str, X: jax.Array, y: jax.Array) -> int:
        """Queue one vectorized micro-batch; returns its uid.

        Admission is coordinator-only on a multi-process cluster: a
        submit on any other process is a routing bug (its queue would
        silently never fold), so it raises instead of enqueueing.
        """
        if not self._admits:
            raise RuntimeError(
                f"stream admission runs on process 0; this is process "
                f"{self.cluster.process_index} of "
                f"{self.cluster.process_count} (snapshots stay readable "
                "here — route submissions to the coordinator)")
        X = jnp.asarray(X)
        y = jnp.asarray(y)
        if X.ndim != 2 or y.shape[0] != X.shape[0]:
            raise ValueError(f"micro-batch must be (n, d) rows with (n,) "
                             f"labels; got X{X.shape} y{y.shape}")
        with self._cv:
            if stream not in self._snapshots:
                raise KeyError(f"unregistered stream {stream!r}")
            d_model = self._snapshots[stream].model.sv.x.shape[1]
            if X.shape[1] != d_model:
                raise ValueError(
                    f"stream {stream!r} serves {d_model}-dim features but "
                    f"the batch has {X.shape[1]} — vectorize with the same "
                    "featurizer as training")
            self._uid += 1
            mb = MicroBatch(uid=self._uid, stream=stream, X=X, y=y,
                            submitted_s=time.time())
            self._queues[stream].append(mb)
            self._cv.notify_all()
            return mb.uid

    def pending(self) -> int:
        with self._lock:
            return sum(len(q) for q in self._queues.values())

    # -- serve -------------------------------------------------------------

    def decision_values(self, stream: str, X: jax.Array) -> jax.Array:
        """Scores from the stream's CURRENT snapshot. The snapshot
        reference is read once, so an update swapping mid-call can never
        yield a half-updated model (snapshots are immutable)."""
        snap = self.snapshot(stream)
        return mr_decision_values(snap.model, X, self.cfg, params=snap.params)

    def predict(self, stream: str, X: jax.Array,
                with_version: bool = False):
        """±1 polarization labels from the current snapshot."""
        snap = self.snapshot(stream)
        pred = mr_predict(snap.model, X, self.cfg, params=snap.params)
        return (pred, snap.version) if with_version else pred

    # -- wave admission + fold --------------------------------------------

    def _admit(self) -> Dict[str, Tuple[ModelSnapshot, List[MicroBatch]]]:
        """Pop ≤ max_batches_per_wave batches per stream, pairing each
        admitted stream with the snapshot whose SVs the fold carries."""
        now = time.time()
        admitted: Dict[str, Tuple[ModelSnapshot, List[MicroBatch]]] = {}
        with self._lock:
            for stream, q in self._queues.items():
                if not q:
                    continue
                take, self._queues[stream] = (q[:self.max_batches_per_wave],
                                              q[self.max_batches_per_wave:])
                for mb in take:
                    mb.admitted_s = now
                    mb.wave = self._wave
                admitted[stream] = (self._snapshots[stream], take)
        return admitted

    def _swap(self, stream: str, model: MapReduceSVM,
              params: Optional[SolverParams]) -> ModelSnapshot:
        """Atomically publish a fully-materialized new snapshot."""
        jax.block_until_ready((model.sv, model.final, model.w, model.b))
        with self._lock:
            old = self._snapshots[stream]
            snap = ModelSnapshot(model=model, params=params,
                                 version=old.version + 1)
            self._snapshots[stream] = snap
            if self.keep_history:
                self._history[stream][snap.version] = snap
        return snap

    def run_wave(self) -> Optional[StreamWaveStats]:
        """Admit one wave and fold it. Returns its stats, or ``None``
        when every queue was empty. Thread-safe; folds are serialized.
        No-op (``None``) off the coordinator — nothing can be queued
        there (see :meth:`submit`)."""
        if not self._admits:
            return None
        with self._wave_lock:
            t0 = time.time()
            admitted = self._admit()
            if not admitted:
                return None
            wave_id = self._wave
            self._wave += 1

            names = sorted(admitted)
            joined = {}
            for s in names:
                snap, batches = admitted[s]
                Xn = jnp.concatenate([mb.X for mb in batches], axis=0)
                yn = jnp.concatenate([mb.y.astype(Xn.dtype)
                                      for mb in batches], axis=0)
                joined[s] = (snap, batches, Xn, yn)

            if len(names) == 1:
                # single tenant: the plain incremental round
                s = names[0]
                snap, batches, Xn, yn = joined[s]
                model = update_mapreduce(snap.model, Xn, yn, self.L,
                                         self.cfg, params=snap.params)
                self._swap(s, model, snap.params)
            else:
                self._fold_batched(joined, names)

            now = time.time()
            n_batches = n_rows = 0
            for s in names:
                _, batches, Xn, _ = joined[s]
                n_batches += len(batches)
                n_rows += int(Xn.shape[0])
                for mb in batches:
                    mb.completed_s = now
                    # Folded rows live on in SV_global (or were
                    # discarded as non-support); keeping every
                    # historical batch pinned in ``done`` would grow
                    # memory without bound in a long-running service —
                    # only the accounting fields survive.
                    mb.X = mb.y = None
                    self.done.append(mb)
            st = StreamWaveStats(wave=wave_id, streams=len(names),
                                 batches=n_batches, rows=n_rows,
                                 batched=len(names) > 1,
                                 wall_s=now - t0)
            self.stats.append(st)
            return st

    def _fold_batched(self, joined, names) -> None:
        """S admitted streams = S jobs on the sweep's config/batch axis:
        per-job (X, y, mask) + stacked per-stream SolverParams, one
        jitted device pass (DESIGN.md §9)."""
        cap = self.cfg.sv_capacity
        d = joined[names[0]][0].model.sv.x.shape[1]
        n_max = max(int(joined[s][2].shape[0]) for s in names) + cap

        Xs, ys, ms, ps = [], [], [], []
        for s in names:
            snap, _, Xn, yn = joined[s]
            sv = snap.model.sv
            n_new = int(Xn.shape[0])
            pad = n_max - n_new - cap
            Xs.append(jnp.concatenate(
                [Xn, sv.x, jnp.zeros((pad, d), Xn.dtype)], axis=0))
            ys.append(jnp.concatenate(
                [yn, sv.y, jnp.zeros((pad,), Xn.dtype)], axis=0))
            ms.append(jnp.concatenate(
                [jnp.ones((n_new,), Xn.dtype), sv.mask,
                 jnp.zeros((pad,), Xn.dtype)], axis=0))
            ps.append(snap.params if snap.params is not None
                      else self.cfg.svm.params())
        Xb = jnp.stack(Xs)                       # (S, n_max, d)
        yb = jnp.stack(ys)                       # (S, n_max)
        mb_ = jnp.stack(ms)                      # (S, n_max)
        params_b = stack_params(ps)

        res = fit_mapreduce_sweep(Xb, yb, self.L, self.cfg, params_b,
                                  mask=mb_)
        for i, s in enumerate(names):
            snap = joined[s][0]
            model = MapReduceSVM(
                w=res.ws[i], b=res.bs[i],
                sv=compat.tree_map(lambda a: a[i], res.sv),
                final=compat.tree_map(lambda a: a[i], res.final),
                risk=res.risks[i], rounds=int(res.rounds[i]), history=())
            self._swap(s, model, snap.params)

    def drain(self) -> int:
        """Run waves until every queue is empty; returns waves run."""
        waves = 0
        while self.run_wave() is not None:
            waves += 1
        return waves

    # -- async scheduler ---------------------------------------------------

    def start(self, idle_poll_s: float = 0.05) -> None:
        """Start the background wave scheduler: batches submitted after
        this fold in continuously without blocking the submitter.
        No-op off the coordinator, so symmetric SPMD launch code can
        call it unconditionally."""
        if not self._admits:
            return
        with self._lock:
            if self._thread is not None:
                return
            self._stop_evt.clear()
            self._scheduler_error = None
            self._thread = threading.Thread(
                target=self._scheduler_loop, args=(idle_poll_s,),
                name="svm-stream-scheduler", daemon=True)
            self._thread.start()

    @property
    def scheduler_error(self) -> Optional[BaseException]:
        """The exception that killed the background scheduler, if any."""
        return self._scheduler_error

    def _scheduler_loop(self, idle_poll_s: float) -> None:
        while not self._stop_evt.is_set():
            with self._cv:
                while (not self._stop_evt.is_set()
                       and not any(self._queues.values())):
                    self._cv.wait(timeout=idle_poll_s)
                if self._stop_evt.is_set():
                    return
            try:
                self.run_wave()
            except BaseException as e:
                # A silently dead daemon thread would leave queues
                # growing and readers on the stale snapshot forever —
                # record the error (wait_idle/stop re-raise it) and
                # shut the loop down loudly.
                self._scheduler_error = e
                self._stop_evt.set()
                import traceback
                traceback.print_exc()
                return

    def wait_idle(self, timeout_s: float = 120.0,
                  poll_s: float = 0.01) -> bool:
        """Block until every queue is empty AND no wave is in flight.
        Only meaningful while the background scheduler is running (an
        idle service with queued work but no scheduler never drains —
        returns False at the timeout). Raises if the scheduler died."""
        deadline = time.time() + timeout_s
        while time.time() < deadline:
            if self._scheduler_error is not None:
                raise RuntimeError(
                    "streaming scheduler died") from self._scheduler_error
            if self.pending() == 0 and not self._wave_lock.locked():
                return True
            time.sleep(poll_s)
        return False

    def stop(self, drain: bool = True) -> None:
        """Stop the scheduler thread; optionally fold what's queued.
        Re-raises the error that killed the scheduler, if any."""
        thread = self._thread
        if thread is None:
            return
        self._stop_evt.set()
        with self._cv:
            self._cv.notify_all()
        thread.join(timeout=60)
        self._thread = None
        if self._scheduler_error is not None:
            raise RuntimeError(
                "streaming scheduler died") from self._scheduler_error
        if drain:
            self.drain()

    # -- reporting ---------------------------------------------------------

    def throughput_report(self) -> Dict[str, float]:
        lats = [mb.latency_s for mb in self.done]
        queues = [mb.queue_s for mb in self.done]
        rows = sum(s.rows for s in self.stats)
        wall = sum(s.wall_s for s in self.stats)
        return {
            "batches": len(self.done),
            "rows": rows,
            "waves": len(self.stats),
            "wall_s": round(wall, 3),
            "rows_per_s": round(rows / max(wall, 1e-9), 1),
            "mean_latency_s": round(float(np.mean(lats)), 4) if lats else 0.0,
            "p95_latency_s": (round(float(np.percentile(lats, 95)), 4)
                              if lats else 0.0),
            "mean_queue_s": (round(float(np.mean(queues)), 4)
                             if queues else 0.0),
        }
