"""Blocked sparse rows: fixed-``nnz_cap`` padded CSR/ELL (ISSUE 6).

The paper's TF×IDF matrices are >99% zero at realistic vocabularies
(100k–1M hashed terms), yet until this refactor every hot path — Gram
build, SV buffers, the ring wire format — was dense ``(n, d)``.
``SparseRows`` stores each row as ``nnz_cap`` column-id / value pairs:

    indices : (..., n, nnz_cap) int32   — column ids, 0 on padding slots
    values  : (..., n, nnz_cap) float   — 0.0 on padding slots

Fixed ``nnz_cap`` keeps every shape static, so the type composes with
``jit`` / ``vmap`` / ``shard_map`` exactly like a dense array: it is a
registered pytree whose two leaves carry the batch dims and whose
feature dimension ``d`` rides along as static aux data. Padding slots
use index 0 with value 0.0 — duplicate indices are legal and always
mean *sum* (matching ``to_dense``'s scatter-add), so a padded slot is a
no-op contribution to every contraction.

Rows with more than ``nnz_cap`` structural nonzeros are truncated by
``from_dense`` keeping the top-``nnz_cap`` |value| entries (for TF×IDF
rows: the highest-weight terms — same semantics as feature selection).

Everything here is format plumbing; the kernels live in
``repro.kernels.gram`` (Pallas) and ``repro.kernels.ref`` (XLA
reference). DESIGN.md §12 documents the layout and the wire format.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
class SparseRows:
    """Batch of sparse feature rows in padded-CSR (ELL) layout.

    Behaves like the dense ``(..., n, d)`` array it represents where
    cheap to do so (``.shape``/``.dtype``/``.ndim`` report the *dense*
    view; ``[]``, ``*`` by a trailing-1 broadcast, ``@`` by a dense
    matrix, ``.astype``, ``.reshape`` of batch dims), so dense-written
    call sites in core/ run unchanged on either format.
    """

    __slots__ = ("indices", "values", "d")

    def __init__(self, indices, values, d: int):
        self.indices = indices
        self.values = values
        self.d = int(d)

    # -- pytree protocol ---------------------------------------------------
    def tree_flatten(self):
        return (self.indices, self.values), self.d

    @classmethod
    def tree_unflatten(cls, d, children):
        indices, values = children
        return cls(indices, values, d)

    # -- dense-like surface ------------------------------------------------
    @property
    def shape(self) -> Tuple[int, ...]:
        """Shape of the DENSE row matrix this represents: (..., n, d)."""
        return tuple(self.values.shape[:-1]) + (self.d,)

    @property
    def ndim(self) -> int:
        return self.values.ndim

    @property
    def dtype(self):
        return self.values.dtype

    @property
    def nnz_cap(self) -> int:
        return int(self.values.shape[-1])

    def astype(self, dtype) -> "SparseRows":
        """Cast VALUES only — indices stay int32 (the wire ships them
        bitcast, never quantized)."""
        return SparseRows(self.indices, self.values.astype(dtype), self.d)

    def __getitem__(self, idx) -> "SparseRows":
        """Row indexing/slicing over the batch dims; the slot axis is
        not addressable from the dense-view API."""
        return SparseRows(self.indices[idx], self.values[idx], self.d)

    def __mul__(self, other) -> "SparseRows":
        """Row-wise scale: ``other`` must broadcast against the batch
        dims with a trailing axis of 1 (e.g. ``live[:, None]``), i.e.
        constant along features — the structure is unchanged."""
        o = jnp.asarray(other)
        if o.ndim and o.shape[-1] not in (1,):
            raise ValueError(
                "SparseRows * x requires x constant along the feature axis "
                f"(trailing dim 1), got shape {o.shape}")
        return SparseRows(self.indices, self.values * o, self.d)

    __rmul__ = __mul__

    def __matmul__(self, other):
        """``X @ W`` against a DENSE ``(d,)`` or ``(d, k)`` operand via
        gather-and-accumulate — O(n·nnz·k) instead of O(n·d·k)."""
        other = jnp.asarray(other)
        if other.shape[0] != self.d:
            raise ValueError(f"matmul dim mismatch: d={self.d} vs "
                             f"{other.shape}")
        g = jnp.take(other, self.indices, axis=0)   # (..., n, nnz[, k])
        if other.ndim == 1:
            return jnp.sum(g * self.values, axis=-1)
        return jnp.sum(g * self.values[..., None], axis=-2)

    def reshape(self, *shape) -> "SparseRows":
        """Reshape the BATCH dims; the last entry must be ``d`` (the
        dense-view contract) or -1 is not supported for it."""
        if len(shape) == 1 and isinstance(shape[0], (tuple, list)):
            shape = tuple(shape[0])
        if not shape or shape[-1] != self.d:
            raise ValueError(
                f"SparseRows.reshape last dim must be d={self.d}, "
                f"got {shape}")
        lead = tuple(int(s) for s in shape[:-1])
        cap = self.values.shape[-1]
        return SparseRows(self.indices.reshape(lead + (cap,)),
                          self.values.reshape(lead + (cap,)), self.d)

    def swapaxes(self, a: int, b: int) -> "SparseRows":
        """Swap two BATCH axes (never the slot axis)."""
        nb = self.values.ndim - 1                    # number of batch axes
        a, b = a % self.values.ndim, b % self.values.ndim
        if a >= nb or b >= nb:
            raise ValueError("cannot swap the slot axis of SparseRows")
        return SparseRows(jnp.swapaxes(self.indices, a, b),
                          jnp.swapaxes(self.values, a, b), self.d)

    def __repr__(self):
        return (f"SparseRows(shape={self.shape}, nnz_cap={self.nnz_cap}, "
                f"dtype={self.values.dtype})")


def is_sparse(x) -> bool:
    return isinstance(x, SparseRows)


# ---------------------------------------------------------------------------
# conversions
# ---------------------------------------------------------------------------

def from_dense(X, nnz_cap: int, d: int | None = None) -> SparseRows:
    """Dense ``(..., n, d)`` → ``SparseRows`` keeping, per row, the
    ``nnz_cap`` largest-|value| entries (ties broken toward lower column
    ids via top_k's stable ordering over the negated magnitude). Rows
    with ≤ ``nnz_cap`` nonzeros round-trip exactly; denser rows are
    truncated to their top-|value| terms (the TF×IDF feature-selection
    semantics documented in DESIGN.md §12)."""
    X = jnp.asarray(X)
    d = X.shape[-1] if d is None else d
    if nnz_cap > d:
        raise ValueError(f"nnz_cap={nnz_cap} exceeds d={d}")
    _, idx = jax.lax.top_k(jnp.abs(X), nnz_cap)      # (..., n, nnz_cap)
    idx = idx.astype(jnp.int32)
    vals = jnp.take_along_axis(X, idx, axis=-1)
    # normalize padding: slots selected for zero entries → index 0
    idx = jnp.where(vals != 0, idx, 0)
    return SparseRows(idx, vals, d)


def to_dense(sp: SparseRows):
    """``SparseRows`` → dense ``(..., n, d)`` by scatter-ADD (duplicate
    indices sum; padding slots add 0 at column 0)."""
    lead = sp.values.shape[:-1]
    cap = sp.values.shape[-1]
    flat_i = sp.indices.reshape(-1, cap)
    flat_v = sp.values.reshape(-1, cap)
    n = flat_i.shape[0]
    rows = jnp.repeat(jnp.arange(n, dtype=jnp.int32), cap)
    out = jnp.zeros((n, sp.d), sp.values.dtype)
    out = out.at[rows, flat_i.reshape(-1)].add(flat_v.reshape(-1))
    return out.reshape(lead + (sp.d,))


def from_numpy_coo(indices: np.ndarray, values: np.ndarray,
                   d: int) -> SparseRows:
    """Host-side constructor from already-blocked numpy arrays (the
    tokenizer/generator emit this layout directly)."""
    return SparseRows(np.asarray(indices, np.int32),
                      np.asarray(values), int(d))


# ---------------------------------------------------------------------------
# structural ops used by core/ (concat, pad, gather — all on batch dims)
# ---------------------------------------------------------------------------

def rows_concat(a, b, axis: int = 0):
    """Concatenate two row batches along a batch axis; both operands
    must share the format (and, when sparse, ``d`` and ``nnz_cap``)."""
    sa, sb = is_sparse(a), is_sparse(b)
    if sa != sb:
        raise TypeError("cannot concatenate sparse rows with dense rows")
    if not sa:
        return jnp.concatenate([a, b], axis=axis)
    if a.d != b.d:
        raise ValueError(f"feature-dim mismatch: {a.d} vs {b.d}")
    if a.nnz_cap != b.nnz_cap:
        raise ValueError(
            f"nnz_cap mismatch: {a.nnz_cap} vs {b.nnz_cap}")
    vals = jnp.concatenate([a.values, b.values.astype(a.values.dtype)],
                           axis=axis)
    return SparseRows(jnp.concatenate([a.indices, b.indices], axis=axis),
                      vals, a.d)


def rows_concat_all(parts, axis: int = 0):
    """Concatenate ≥1 row batches along ``axis`` (the streaming wave's
    micro-batch join); every operand must share the format."""
    if not parts:
        raise ValueError("rows_concat_all: empty sequence")
    out = parts[0]
    for p in parts[1:]:
        out = rows_concat(out, p, axis=axis)
    return out


def rows_stack(parts):
    """Stack same-shape row batches on a NEW leading axis (the sweep's
    job axis): ``jnp.stack`` for dense, leaf-wise stack for blocked-CSR."""
    if not parts:
        raise ValueError("rows_stack: empty sequence")
    sp = is_sparse(parts[0])
    if any(is_sparse(p) != sp for p in parts[1:]):
        raise TypeError("rows_stack: mixed dense/sparse inputs")
    if not sp:
        return jnp.stack(parts)
    first = parts[0]
    for p in parts[1:]:
        if p.d != first.d:
            raise ValueError(f"feature-dim mismatch: {p.d} vs {first.d}")
        if p.nnz_cap != first.nnz_cap:
            raise ValueError(
                f"nnz_cap mismatch: {p.nnz_cap} vs {first.nnz_cap}")
    return SparseRows(
        jnp.stack([p.indices for p in parts]),
        jnp.stack([p.values.astype(first.values.dtype) for p in parts]),
        first.d)


def rows_zeros_like(x):
    """An all-empty row batch shaped like ``x`` (index 0 / value 0 ≡ the
    empty row) — mask-padding jobs on the sweep axis."""
    if not is_sparse(x):
        return jnp.zeros_like(x)
    return SparseRows(jnp.zeros_like(x.indices),
                      jnp.zeros_like(x.values), x.d)


def pad_rows(x, pad: int):
    """Zero-pad ``pad`` rows at the end of the ROW axis (-2 of the
    dense view), for either format."""
    if not is_sparse(x):
        widths = [(0, 0)] * x.ndim
        widths[-2] = (0, pad)
        return jnp.pad(x, widths)
    widths = [(0, 0)] * x.values.ndim
    widths[-2] = (0, pad)
    return SparseRows(jnp.pad(x.indices, widths),
                      jnp.pad(x.values, widths), x.d)


def take_rows_along(x, topi):
    """``take_along_axis(x, topi[..., None], axis=1)`` for either format
    (select ``k`` rows per leading batch entry)."""
    if not is_sparse(x):
        return jnp.take_along_axis(x, topi[..., None], axis=1)
    sel = lambda leaf: jnp.take_along_axis(leaf, topi[..., None], axis=1)
    return SparseRows(sel(x.indices), sel(x.values), x.d)


def dynamic_row(x, i):
    """Row ``i`` (traced index) of a 2-D row batch → dense-compatible
    pieces: dense → the row; sparse → (indices_i, values_i)."""
    if not is_sparse(x):
        return jax.lax.dynamic_index_in_dim(x, i, keepdims=False)
    return (jax.lax.dynamic_index_in_dim(x.indices, i, keepdims=False),
            jax.lax.dynamic_index_in_dim(x.values, i, keepdims=False))


# ---------------------------------------------------------------------------
# contractions used by the solver / risk paths
# ---------------------------------------------------------------------------

def row_sq_norms(x):
    """Σ_j x_ij² per row. NOTE: assumes distinct in-row indices (the
    featurizer/generator contract); duplicates would need a merge."""
    if not is_sparse(x):
        return jnp.einsum("...nd,...nd->...n", x, x)
    return jnp.sum(x.values * x.values, axis=-1)


def weighted_row_sum(x, coef):
    """``X.T @ coef`` → dense ``(d,)``: the primal weight recovery
    ``w = Σ_i coef_i · x_i`` (scatter-add over nonzeros when sparse)."""
    if not is_sparse(x):
        return x.T @ coef
    contrib = x.values * coef[:, None]
    w = jnp.zeros((x.d,), contrib.dtype)
    return w.at[x.indices.reshape(-1)].add(contrib.reshape(-1))


def matmat(x, other):
    """``X @ other`` for either format (dense falls through to ``@``)."""
    return x @ other


def cross_dots(x, z, *, chunk: int = 64):
    """Dense dot-product matrix ``<x_i, z_j>`` → ``(n, m)`` for ANY
    format mix. The sparse×sparse case is the segment-sum idiom from
    :mod:`repro.kernels.ref`: densify ``z`` in row chunks of ``chunk``
    (bounding the scratch at ``chunk × d``) by scatter-add, then gather
    each chunk's columns at ``x``'s indices and contract — O(n·m·nnz +
    m·d) instead of the dense O(n·m·d)."""
    xs, zs = is_sparse(x), is_sparse(z)
    if not xs and not zs:
        return x @ z.T
    if xs and not zs:
        return x @ jnp.asarray(z).T       # gather from the dense side
    if not xs and zs:
        return (z @ jnp.asarray(x).T).T
    if x.d != z.d:
        raise ValueError(f"feature-dim mismatch: {x.d} vs {z.d}")
    n, m = x.values.shape[-2], z.values.shape[-2]
    ct = jnp.promote_types(x.dtype, z.dtype)
    chunk = min(chunk, m)
    mp = -(-m // chunk) * chunk
    zi = jnp.pad(z.indices, ((0, mp - m), (0, 0)))
    zv = jnp.pad(z.values.astype(ct), ((0, mp - m), (0, 0)))
    cap_z = zi.shape[-1]
    rows = jnp.repeat(jnp.arange(chunk, dtype=jnp.int32), cap_z)
    xv = x.values.astype(ct)

    def one(args):
        ic, vc = args                                 # (chunk, cap_z)
        zd = jnp.zeros((chunk, x.d), ct)
        zd = zd.at[rows, ic.reshape(-1)].add(vc.reshape(-1))
        g = jnp.take(zd.T, x.indices, axis=0)         # (n, nnz, chunk)
        return jnp.sum(g * xv[..., None], axis=-2)    # (n, chunk)

    out = jax.lax.map(one, (zi.reshape(mp // chunk, chunk, cap_z),
                            zv.reshape(mp // chunk, chunk, cap_z)))
    return jnp.moveaxis(out, 0, 1).reshape(n, mp)[:, :m]


def score_rows(x, W, b=None):
    """Decision scores ``X @ W.T (+ b)`` with dense ``W (L, d)`` —
    the reducer-scoring shape used by the merge and sweep paths."""
    s = x @ jnp.swapaxes(jnp.asarray(W), -1, -2)
    return s if b is None else s + b
