"""Text substrate: the paper's TF×IDF sentiment pipeline."""
from repro.text.stopwords import TURKISH_STOPWORDS, is_stopword
from repro.text.tokenizer import (count_matrix, count_rows_sparse,
                                  hash_token, normalize, tokenize,
                                  vectorize, vectorize_sparse)
from repro.text.tfidf import TfidfModel, fit_idf, fit_transform, transform
from repro.text.feature_select import chi2_scores, select_top_k
from repro.text.corpus import (CLASS_NEG, CLASS_NEU, CLASS_POS, Corpus,
                               CorpusConfig, generate)

__all__ = [
    "TURKISH_STOPWORDS", "is_stopword", "count_matrix", "hash_token",
    "normalize", "tokenize", "vectorize", "count_rows_sparse",
    "vectorize_sparse", "TfidfModel", "fit_idf",
    "fit_transform", "transform", "chi2_scores", "select_top_k",
    "CLASS_NEG", "CLASS_NEU", "CLASS_POS", "Corpus", "CorpusConfig",
    "generate",
]
