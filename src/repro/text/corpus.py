"""Synthetic Turkish-tweet corpus with planted polarity signal.

The paper's corpus (3.4M tweets about 108 public + 66 private Turkish
universities via the 2014 Twitter Streaming API) is not available
offline, so experiments run on a synthetic corpus with the same
*structure*: university-entity mentions, Tablo 4 stopwords as noise,
class-conditional sentiment lexicons, and Tablo 5 class proportions.
DESIGN.md §6 records this honesty note; EXPERIMENTS.md reports the
paper's absolute numbers next to ours.
"""
from __future__ import annotations

import dataclasses
from typing import List, NamedTuple, Optional, Sequence, Tuple

import numpy as np

from repro.text.stopwords import TURKISH_STOPWORDS

# A few dozen real names; the remainder are synthesized to reach the
# paper's 108 public + 66 private.
_PUBLIC_SEED = [
    "istanbul üniversitesi", "odtü", "boğaziçi üniversitesi", "itü",
    "ankara üniversitesi", "ege üniversitesi", "hacettepe üniversitesi",
    "marmara üniversitesi", "gazi üniversitesi", "dokuz eylül üniversitesi",
    "yıldız teknik üniversitesi", "anadolu üniversitesi",
    "akdeniz üniversitesi", "selçuk üniversitesi", "erciyes üniversitesi",
    "karadeniz teknik üniversitesi", "çukurova üniversitesi",
    "uludağ üniversitesi", "atatürk üniversitesi", "fırat üniversitesi",
]
_PRIVATE_SEED = [
    "bilkent üniversitesi", "koç üniversitesi", "sabancı üniversitesi",
    "başkent üniversitesi", "yeditepe üniversitesi", "bahçeşehir üniversitesi",
    "istanbul bilgi üniversitesi", "kadir has üniversitesi",
    "özyeğin üniversitesi", "tobb etü", "atılım üniversitesi",
    "çankaya üniversitesi", "işık üniversitesi", "maltepe üniversitesi",
]

POSITIVE_LEXICON = [
    "güzel", "harika", "başarılı", "mutlu", "teşekkürler", "mükemmel",
    "sevindim", "iyi", "kaliteli", "gurur", "muhteşem", "tebrikler",
    "kazandım", "süper", "keyifli", "memnun", "başarı", "sevgi",
]
NEGATIVE_LEXICON = [
    "kötü", "berbat", "rezalet", "üzgün", "şikayet", "sorun", "yetersiz",
    "mağdur", "zam", "kalitesiz", "saçma", "bıktım", "korkunç", "kaybettim",
    "sinir", "perişan", "skandal", "başarısız",
]
NEUTRAL_LEXICON = [
    "kayıt", "duyuru", "sınav", "ders", "kampüs", "etkinlik", "konferans",
    "bölüm", "öğrenci", "akademik", "yemekhane", "kütüphane", "tercih",
    "seminer", "yurt", "dönem", "hoca", "not",
]
_STOPWORD_LIST = sorted(TURKISH_STOPWORDS)

CLASS_NEG, CLASS_NEU, CLASS_POS = -1, 0, 1


class Corpus(NamedTuple):
    texts: List[str]
    labels: np.ndarray        # int in {-1, 0, +1}
    universities: np.ndarray  # index into .university_names
    university_names: List[str]
    university_kinds: np.ndarray  # 0 = public (devlet), 1 = private (vakıf)


@dataclasses.dataclass(frozen=True)
class CorpusConfig:
    num_messages: int = 4096
    classes: Tuple[int, ...] = (CLASS_NEG, CLASS_POS)   # or (-1, 0, 1)
    # Tablo 5 proportions: 2-class 174669/172489; 3-class 113438/111779/109853
    class_probs: Optional[Tuple[float, ...]] = None
    num_public: int = 108
    num_private: int = 66
    min_tokens: int = 4
    max_tokens: int = 18
    # token mixture inside a message
    p_signal: float = 0.45    # class-lexicon tokens
    p_cross: float = 0.08     # wrong-class lexicon tokens (label noise)
    p_stopword: float = 0.22  # Tablo 4 noise (removed by the pipeline)
    p_neutral: float = 0.25   # topic filler
    seed: int = 0


def university_names(cfg: CorpusConfig) -> Tuple[List[str], np.ndarray]:
    pub = list(_PUBLIC_SEED)
    while len(pub) < cfg.num_public:
        pub.append(f"devlet üniversitesi {len(pub) + 1:03d}")
    pri = list(_PRIVATE_SEED)
    while len(pri) < cfg.num_private:
        pri.append(f"vakıf üniversitesi {len(pri) + 1:03d}")
    names = pub[:cfg.num_public] + pri[:cfg.num_private]
    kinds = np.array([0] * cfg.num_public + [1] * cfg.num_private)
    return names, kinds


def _default_probs(classes: Sequence[int]) -> Tuple[float, ...]:
    if tuple(classes) == (CLASS_NEG, CLASS_POS):
        tot = 174669 + 172489
        return (172489 / tot, 174669 / tot)       # (neg, pos) per Tablo 5
    if tuple(classes) == (CLASS_NEG, CLASS_NEU, CLASS_POS):
        tot = 113438 + 111779 + 109853
        return (111779 / tot, 109853 / tot, 113438 / tot)
    k = len(classes)
    return tuple(1.0 / k for _ in classes)


def _lexicon_for(c: int) -> List[str]:
    return {CLASS_NEG: NEGATIVE_LEXICON, CLASS_NEU: NEUTRAL_LEXICON,
            CLASS_POS: POSITIVE_LEXICON}[c]


def generate(cfg: CorpusConfig) -> Corpus:
    rng = np.random.default_rng(cfg.seed)
    names, kinds = university_names(cfg)
    probs = cfg.class_probs or _default_probs(cfg.classes)
    assert abs(sum(probs) - 1.0) < 1e-6

    labels = rng.choice(cfg.classes, size=cfg.num_messages, p=probs)
    # Polarity skew per university so Tablo 7/9-style rankings are non-trivial:
    # each university gets a bias that tilts its messages' class draw.
    uni_bias = rng.normal(0.0, 0.8, size=len(names))
    unis = rng.integers(0, len(names), size=cfg.num_messages)
    for i in range(cfg.num_messages):
        if len(cfg.classes) >= 2 and rng.random() < abs(np.tanh(uni_bias[unis[i]])) * 0.5:
            labels[i] = CLASS_POS if uni_bias[unis[i]] > 0 else CLASS_NEG

    texts: List[str] = []
    buckets = ("signal", "cross", "stop", "neutral")
    bucket_p = np.array([cfg.p_signal, cfg.p_cross, cfg.p_stopword,
                         cfg.p_neutral])
    bucket_p = bucket_p / bucket_p.sum()
    for i in range(cfg.num_messages):
        c = int(labels[i])
        n_tok = int(rng.integers(cfg.min_tokens, cfg.max_tokens + 1))
        lex = _lexicon_for(c)
        other = [w for cc in cfg.classes if cc != c for w in _lexicon_for(cc)]
        toks = [names[unis[i]]]
        for _ in range(n_tok):
            b = buckets[int(rng.choice(4, p=bucket_p))]
            if b == "signal":
                toks.append(str(rng.choice(lex)))
            elif b == "cross":
                toks.append(str(rng.choice(other)))
            elif b == "stop":
                toks.append(str(rng.choice(_STOPWORD_LIST)))
            else:
                toks.append(str(rng.choice(NEUTRAL_LEXICON)))
        rng.shuffle(toks)
        texts.append(" ".join(toks))
    return Corpus(texts=texts, labels=labels.astype(np.int32),
                  universities=unis.astype(np.int32),
                  university_names=names, university_kinds=kinds)
