"""χ² feature selection (the paper cites Yang & Pedersen 1997 for
"nitelik seçimi" — feature selection on the vector space)."""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp


def chi2_scores(X: jax.Array, y: jax.Array,
                classes: Sequence[int]) -> jax.Array:
    """Per-feature χ² statistic for non-negative features (counts/tfidf).

    Standard sklearn-style contingency: observed class-conditional
    feature mass vs expectation under independence.
    """
    Y = jnp.stack([(y == c).astype(X.dtype) for c in classes], axis=1)  # (n,k)
    observed = Y.T @ X                                   # (k, d)
    feature_mass = jnp.sum(X, axis=0)                    # (d,)
    class_prob = jnp.mean(Y, axis=0)                     # (k,)
    expected = class_prob[:, None] * feature_mass[None, :]
    chi2 = jnp.sum((observed - expected) ** 2 /
                   jnp.maximum(expected, 1e-12), axis=0)
    return jnp.where(feature_mass > 0, chi2, 0.0)


def select_top_k(X: jax.Array, y: jax.Array, classes: Sequence[int],
                 k: int) -> Tuple[jax.Array, jax.Array]:
    """Return (X[:, top_idx], top_idx) by χ² score."""
    scores = chi2_scores(X, y, classes)
    _, idx = jax.lax.top_k(scores, k)
    return X[:, idx], idx
