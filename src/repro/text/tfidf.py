"""TF×IDF weighting (paper eq. 10-11), in jnp so it runs on device.

    idf_t     = log(N / df_t)                      (eq. 10)
    tfidf_t,d = tf_t,d × idf_t                     (eq. 11)

Both entry points accept dense ``(n, d)`` count matrices OR blocked-CSR
:class:`repro.sparse.SparseRows` counts (ISSUE 6): the sparse overloads
never densify — df is a scatter-add over the nonzero slots and the
tf×idf weighting is a gather of ``idf`` at each row's column ids.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro import sparse as sparse_rows


class TfidfModel(NamedTuple):
    idf: jax.Array        # (d,)
    num_docs: jax.Array   # ()


def fit_idf(counts, smooth: bool = True) -> TfidfModel:
    """idf from a training count matrix (n, d) — dense or SparseRows.

    ``smooth`` uses log((1+N)/(1+df)) + 1 so unseen terms stay finite —
    the standard safe variant of eq. 10 (hashed spaces always contain
    empty buckets).
    """
    n = counts.shape[0]
    if sparse_rows.is_sparse(counts):
        # df via scatter-add of the live slots: padding (value 0) and
        # dead slots contribute nothing; in-row indices are distinct by
        # the featurizer contract, so no term is double-counted.
        live = (counts.values > 0).astype(jnp.float32)
        df = jnp.zeros((counts.d,), jnp.float32).at[
            counts.indices.reshape(-1)].add(live.reshape(-1))
    else:
        df = jnp.sum((counts > 0).astype(counts.dtype), axis=0)
    if smooth:
        idf = jnp.log((1.0 + n) / (1.0 + df)) + 1.0
    else:
        idf = jnp.log(n / jnp.maximum(df, 1.0))
    return TfidfModel(idf=idf, num_docs=jnp.asarray(n))


def transform(counts, model: TfidfModel, l2_normalize: bool = True):
    """tf × idf, optionally L2-row-normalized (standard for linear SVM).

    SparseRows counts come back as SparseRows with IDENTICAL structure:
    the idf gather is guarded so weighting can never resurrect a zero —
    padding slots (value 0) stay exactly 0 even though the smooth idf of
    their column id is nonzero, so the blocked-CSR padding invariant
    survives the weighting (the satellite bugfix of ISSUE 6).
    """
    if sparse_rows.is_sparse(counts):
        scale = jnp.take(model.idf, counts.indices, axis=0)
        vals = jnp.where(counts.values != 0,
                         counts.values * scale.astype(counts.dtype), 0.0)
        if l2_normalize:
            norm = jnp.sqrt(jnp.sum(vals * vals, axis=-1, keepdims=True))
            vals = vals / jnp.maximum(norm, 1e-12)
        return sparse_rows.SparseRows(counts.indices, vals, counts.d)
    X = counts * model.idf[None, :]
    if l2_normalize:
        norm = jnp.sqrt(jnp.sum(X * X, axis=1, keepdims=True))
        X = X / jnp.maximum(norm, 1e-12)
    return X


def fit_transform(counts, smooth: bool = True, l2_normalize: bool = True):
    model = fit_idf(counts, smooth)
    return transform(counts, model, l2_normalize), model
