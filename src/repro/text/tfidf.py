"""TF×IDF weighting (paper eq. 10-11), in jnp so it runs on device.

    idf_t     = log(N / df_t)                      (eq. 10)
    tfidf_t,d = tf_t,d × idf_t                     (eq. 11)
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class TfidfModel(NamedTuple):
    idf: jax.Array        # (d,)
    num_docs: jax.Array   # ()


def fit_idf(counts: jax.Array, smooth: bool = True) -> TfidfModel:
    """idf from a training count matrix (n, d).

    ``smooth`` uses log((1+N)/(1+df)) + 1 so unseen terms stay finite —
    the standard safe variant of eq. 10 (hashed spaces always contain
    empty buckets).
    """
    n = counts.shape[0]
    df = jnp.sum((counts > 0).astype(counts.dtype), axis=0)
    if smooth:
        idf = jnp.log((1.0 + n) / (1.0 + df)) + 1.0
    else:
        idf = jnp.log(n / jnp.maximum(df, 1.0))
    return TfidfModel(idf=idf, num_docs=jnp.asarray(n))


def transform(counts: jax.Array, model: TfidfModel,
              l2_normalize: bool = True) -> jax.Array:
    """tf × idf, optionally L2-row-normalized (standard for linear SVM)."""
    X = counts * model.idf[None, :]
    if l2_normalize:
        norm = jnp.sqrt(jnp.sum(X * X, axis=1, keepdims=True))
        X = X / jnp.maximum(norm, 1e-12)
    return X


def fit_transform(counts: jax.Array, smooth: bool = True,
                  l2_normalize: bool = True):
    model = fit_idf(counts, smooth)
    return transform(counts, model, l2_normalize), model
