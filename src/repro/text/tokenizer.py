"""Tweet normalization + hashing vectorizer.

The paper's pipeline: stopword removal (Tablo 4) → vector space → TF×IDF.
2014 Hadoop used sparse term dictionaries; on TPU we hash terms into a
fixed dense feature space (``num_features``) so downstream SVM math is
MXU matmuls (DESIGN.md §2, adaptation 2). Host-side (numpy) by design:
text decoding is not TPU work.
"""
from __future__ import annotations

import re
import zlib
from typing import Iterable, List, Sequence

import numpy as np

from repro.text.stopwords import TURKISH_STOPWORDS

_URL_RE = re.compile(r"https?://\S+|www\.\S+")
_MENTION_RE = re.compile(r"[@#]\w+")
_NONWORD_RE = re.compile(r"[^a-zçğıöşü0-9\s]+")

# Turkish-aware lowercase: dotted/dotless i must not go through ASCII rules.
_TR_LOWER = str.maketrans({"İ": "i", "I": "ı"})


def normalize(text: str) -> str:
    text = text.translate(_TR_LOWER).lower()
    text = _URL_RE.sub(" ", text)
    text = _MENTION_RE.sub(" ", text)
    text = _NONWORD_RE.sub(" ", text)
    return text


def tokenize(text: str, remove_stopwords: bool = True) -> List[str]:
    toks = normalize(text).split()
    if remove_stopwords:
        toks = [t for t in toks if t not in TURKISH_STOPWORDS]
    return toks


def hash_token(token: str, num_features: int) -> int:
    """Stable (process-independent) token hash — zlib.crc32, not hash()."""
    return zlib.crc32(token.encode("utf-8")) % num_features


def count_matrix(docs: Iterable[Sequence[str]], num_features: int,
                 dtype=np.float32) -> np.ndarray:
    """Token-count matrix (n_docs, num_features) from tokenized docs."""
    docs = list(docs)
    out = np.zeros((len(docs), num_features), dtype)
    for i, toks in enumerate(docs):
        for t in toks:
            out[i, hash_token(t, num_features)] += 1.0
    return out


def vectorize(texts: Iterable[str], num_features: int,
              remove_stopwords: bool = True) -> np.ndarray:
    """Text → hashed count matrix in one shot."""
    return count_matrix((tokenize(t, remove_stopwords) for t in texts),
                        num_features)


def count_rows_sparse(docs: Iterable[Sequence[str]], num_features: int,
                      nnz_cap: int, dtype=np.float32):
    """Blocked-CSR token counts straight from tokenized docs (ISSUE 6).

    Hashing already gives bounded column ids, so each doc maps to at
    most ``nnz_cap`` (column, count) pairs WITHOUT ever materializing
    the (n, d) dense matrix — O(n·nnz_cap) host memory at million-term
    vocabularies. Docs with more distinct hashed terms than ``nnz_cap``
    keep their ``nnz_cap`` highest-count terms (the same top-weight
    truncation :func:`repro.sparse.from_dense` applies; DESIGN.md §12).
    In-row column ids are distinct by construction (one slot per hashed
    term) — the SparseRows contract.
    """
    from collections import Counter

    from repro import sparse as sparse_rows

    docs = list(docs)
    indices = np.zeros((len(docs), nnz_cap), np.int32)
    values = np.zeros((len(docs), nnz_cap), dtype)
    for i, toks in enumerate(docs):
        counts = Counter(hash_token(t, num_features) for t in toks)
        # highest count first; ties by column id for determinism
        top = sorted(counts.items(), key=lambda kv: (-kv[1], kv[0]))
        top = top[:nnz_cap]
        for j, (col, cnt) in enumerate(top):
            indices[i, j] = col
            values[i, j] = cnt
    return sparse_rows.from_numpy_coo(indices, values, num_features)


def vectorize_sparse(texts: Iterable[str], num_features: int,
                     nnz_cap: int, remove_stopwords: bool = True):
    """Text → blocked-CSR hashed count rows in one shot."""
    return count_rows_sparse(
        (tokenize(t, remove_stopwords) for t in texts), num_features,
        nnz_cap)
