"""Tweet normalization + hashing vectorizer.

The paper's pipeline: stopword removal (Tablo 4) → vector space → TF×IDF.
2014 Hadoop used sparse term dictionaries; on TPU we hash terms into a
fixed dense feature space (``num_features``) so downstream SVM math is
MXU matmuls (DESIGN.md §2, adaptation 2). Host-side (numpy) by design:
text decoding is not TPU work.
"""
from __future__ import annotations

import re
import zlib
from typing import Iterable, List, Sequence

import numpy as np

from repro.text.stopwords import TURKISH_STOPWORDS

_URL_RE = re.compile(r"https?://\S+|www\.\S+")
_MENTION_RE = re.compile(r"[@#]\w+")
_NONWORD_RE = re.compile(r"[^a-zçğıöşü0-9\s]+")

# Turkish-aware lowercase: dotted/dotless i must not go through ASCII rules.
_TR_LOWER = str.maketrans({"İ": "i", "I": "ı"})


def normalize(text: str) -> str:
    text = text.translate(_TR_LOWER).lower()
    text = _URL_RE.sub(" ", text)
    text = _MENTION_RE.sub(" ", text)
    text = _NONWORD_RE.sub(" ", text)
    return text


def tokenize(text: str, remove_stopwords: bool = True) -> List[str]:
    toks = normalize(text).split()
    if remove_stopwords:
        toks = [t for t in toks if t not in TURKISH_STOPWORDS]
    return toks


def hash_token(token: str, num_features: int) -> int:
    """Stable (process-independent) token hash — zlib.crc32, not hash()."""
    return zlib.crc32(token.encode("utf-8")) % num_features


def count_matrix(docs: Iterable[Sequence[str]], num_features: int,
                 dtype=np.float32) -> np.ndarray:
    """Token-count matrix (n_docs, num_features) from tokenized docs."""
    docs = list(docs)
    out = np.zeros((len(docs), num_features), dtype)
    for i, toks in enumerate(docs):
        for t in toks:
            out[i, hash_token(t, num_features)] += 1.0
    return out


def vectorize(texts: Iterable[str], num_features: int,
              remove_stopwords: bool = True) -> np.ndarray:
    """Text → hashed count matrix in one shot."""
    return count_matrix((tokenize(t, remove_stopwords) for t in texts),
                        num_features)
