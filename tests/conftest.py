import pytest


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: dry-run subprocess tests")
