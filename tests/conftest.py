"""Shared fixtures. The dual-CD ``fori_loop`` reducers dominate suite
wall-clock, so convergence-insensitive tests take their solver/driver
configs from the session-scoped fast fixtures below instead of
hand-rolling slow ones (ISSUE 1 satellite)."""
import os

import pytest


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: dry-run subprocess tests")


def subprocess_env(**overrides):
    """Minimal env for subprocess-based tests (fake-device runs need a
    fresh backend init). JAX_PLATFORMS must survive into the child:
    without it jax probes the baked-in libtpu and hangs retrying TPU
    metadata — these forced-host-device runs are cpu by construction."""
    env = {"PYTHONPATH": "src",
           "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
           "HOME": os.environ.get("HOME", "/root"),
           "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")}
    env.update(overrides)
    return env


@pytest.fixture(scope="session")
def fast_svm_cfg():
    """Small-epoch reducer solver: enough to find the support set on the
    synthetic separable problems, ~2-3× cheaper than the defaults."""
    from repro.core import SVMConfig
    return SVMConfig(C=1.0, max_epochs=12, tol=5e-3)


@pytest.fixture(scope="session")
def fast_mr_cfg(fast_svm_cfg):
    """Small-capacity MapReduce driver riding on ``fast_svm_cfg``."""
    from repro.core import MRSVMConfig
    return MRSVMConfig(sv_capacity=32, max_rounds=3, svm=fast_svm_cfg)
