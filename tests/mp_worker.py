"""One process of the multi-process equivalence harness (ISSUE 5).

Launched N times by tests/test_multihost.py (argv: process_id
num_processes port [rounds]). Each process:

  1. joins the cluster via the runtime under test (init_cluster with
     explicit coordinator/num_processes/process_id and faked local CPU
     devices — cluster.py sets the XLA flag and the gloo collectives
     BEFORE first backend use);
  2. loads ONLY its disjoint TF×IDF row shard (svm_rows_shard) and
     assembles the global arrays with Cluster.make_global_array;
  3. runs the sharded MapReduce-SVM round — build_sharded_round
     UNCHANGED, under both merge transports — over the global mesh;
  4. checks the result against the single-process functional reference
     (mapreduce_round over the full dataset, recomputed locally).

Prints MP_ROUND_OK as the last line on success; any assertion failure
or hang is surfaced by the parent test.
"""
import sys

PID, NPROC, PORT = int(sys.argv[1]), int(sys.argv[2]), sys.argv[3]
ROUNDS = int(sys.argv[4]) if len(sys.argv) > 4 else 3
NDEV = 8                                     # global devices, any NPROC

from repro.launch.cluster import ClusterConfig, init_cluster  # noqa: E402

cluster = init_cluster(ClusterConfig(
    coordinator=f"localhost:{PORT}", num_processes=NPROC, process_id=PID,
    local_device_count=NDEV // NPROC))

import jax                                    # noqa: E402  (backend now up)
import numpy as np                            # noqa: E402
from jax.sharding import PartitionSpec as P   # noqa: E402

assert cluster.process_index == PID and cluster.process_count == NPROC
assert cluster.local_device_count == NDEV // NPROC
assert cluster.device_count == NDEV, cluster.describe()
assert cluster.is_coordinator == (PID == 0)

from repro.core import MRSVMConfig, SVMConfig                 # noqa: E402
from repro.core.mapreduce_svm import (build_sharded_round,    # noqa: E402
                                      init_sv_buffer, mapreduce_round)
from repro.data import host_row_range, svm_rows, svm_rows_shard  # noqa: E402
from repro.launch.mesh import make_host_mesh                  # noqa: E402

N_ROWS, D, SEED = 512, 16, 3
mesh = make_host_mesh(NDEV, 1, cluster=cluster)
assert tuple(mesh.shape.values()) == (NDEV, 1)

# -- per-host loading: this process's disjoint shard ------------------------
Xl, yl = svm_rows_shard(N_ROWS, D, seed=SEED,
                        process_index=PID, process_count=NPROC)
start, stop = host_row_range(N_ROWS, PID, NPROC)
Xf, yf = svm_rows(N_ROWS, D, seed=SEED)       # full set, for the oracle
np.testing.assert_array_equal(Xl, Xf[start:stop])   # shard ≡ its row range
np.testing.assert_array_equal(yl, yf[start:stop])

X = cluster.make_global_array(mesh, P("data"), Xl, (N_ROWS, D))
y = cluster.make_global_array(mesh, P("data"), yl, (N_ROWS,))
mask = cluster.make_global_array(
    mesh, P("data"), np.ones((stop - start,), np.float32), (N_ROWS,))

# -- functional single-process reference (identical on every process) -------
per = N_ROWS // NDEV


def reference(cfg):
    Xp = Xf.reshape(NDEV, per, D)
    yp = yf.reshape(NDEV, per)
    mp = np.ones((NDEV, per), np.float32)
    sv = init_sv_buffer(cfg.sv_capacity, D)
    risks = None
    for _ in range(ROUNDS):
        out = mapreduce_round(Xp, yp, mp, sv, cfg)
        sv, risks = out.sv, out.risks
    return sv, risks


for shuffle in ("allgather", "ring"):
    # f32 wire keeps the ring bit-exact so the functional reference
    # stays the strict oracle (same convention as test_sharded_round)
    cfg = MRSVMConfig(sv_capacity=64, svm=SVMConfig(C=1.0, max_epochs=15),
                      shuffle_impl=shuffle, shuffle_wire_dtype="float32")
    fn = build_sharded_round(mesh, ("data",), cfg, per)
    sv_s = init_sv_buffer(cfg.sv_capacity, D)
    risks_s = None
    for _ in range(ROUNDS):
        sv_s, risks_s, w_s, b_s = fn(X, y, mask, sv_s)

    sv_f, risks_f = reference(cfg)
    # every output is replicated → fully addressable on each process
    np.testing.assert_allclose(np.asarray(risks_s), np.asarray(risks_f),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(sv_s.ids), np.asarray(sv_f.ids))
    np.testing.assert_array_equal(np.asarray(sv_s.mask),
                                  np.asarray(sv_f.mask))
    np.testing.assert_allclose(np.asarray(sv_s.alpha),
                               np.asarray(sv_f.alpha), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(sv_s.x), np.asarray(sv_f.x),
                               rtol=1e-5, atol=1e-6)
    assert np.asarray(w_s).shape == (D,) and np.asarray(b_s).shape == ()
    print(f"[p{PID}] {shuffle}: {NPROC}-process round ≡ functional "
          f"reference over {ROUNDS} rounds", flush=True)

# -- blocked-CSR leg (ISSUE 6): sparse sharded round, sparse wire -----------
# Same rows, same dense functional oracle: svm_rows emits ≤4 nonzeros
# per row at D=16, so from_dense at CAP=8 is lossless and the dense
# reference stays the strict truth. Only the FORMAT changes — per-host
# blocked-CSR leaves assembled into one global SparseRows, the SV
# buffer and the merge wire (values-packed + bitcast indices) sparse
# throughout.
import dataclasses as dc                      # noqa: E402

import jax.numpy as jnp                       # noqa: E402
from repro import sparse                      # noqa: E402

CAP = 8
Xls = sparse.from_dense(jnp.asarray(Xl), CAP)
np.testing.assert_array_equal(np.asarray(sparse.to_dense(Xls)), Xl)
Xsp = sparse.SparseRows(
    cluster.make_global_array(mesh, P("data"), np.asarray(Xls.indices),
                              (N_ROWS, CAP)),
    cluster.make_global_array(mesh, P("data"), np.asarray(Xls.values),
                              (N_ROWS, CAP)),
    D)

for shuffle in ("allgather", "ring"):
    cfg_d = MRSVMConfig(sv_capacity=64, svm=SVMConfig(C=1.0, max_epochs=15),
                        shuffle_impl=shuffle, shuffle_wire_dtype="float32")
    cfg_s = dc.replace(cfg_d, svm=dc.replace(
        cfg_d.svm, row_format="sparse_csr", nnz_cap=CAP))
    fn = build_sharded_round(mesh, ("data",), cfg_s, per)
    sv_s = init_sv_buffer(cfg_s.sv_capacity, D, nnz_cap=CAP)
    risks_s = None
    for _ in range(ROUNDS):
        sv_s, risks_s, w_s, b_s = fn(Xsp, y, mask, sv_s)

    sv_f, risks_f = reference(cfg_d)
    np.testing.assert_allclose(np.asarray(risks_s), np.asarray(risks_f),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(sv_s.ids), np.asarray(sv_f.ids))
    np.testing.assert_array_equal(np.asarray(sv_s.mask),
                                  np.asarray(sv_f.mask))
    np.testing.assert_allclose(np.asarray(sv_s.alpha),
                               np.asarray(sv_f.alpha), rtol=1e-4, atol=1e-5)
    assert sparse.is_sparse(sv_s.x) and sv_s.x.nnz_cap == CAP
    np.testing.assert_allclose(np.asarray(sparse.to_dense(sv_s.x)),
                               np.asarray(sv_f.x), rtol=1e-5, atol=1e-6)
    print(f"[p{PID}] {shuffle}: sparse {NPROC}-process round ≡ dense "
          f"functional reference over {ROUNDS} rounds", flush=True)

print("MP_ROUND_OK", flush=True)
