"""One process of the multi-process equivalence harness (ISSUE 5).

Launched N times by tests/test_multihost.py (argv: process_id
num_processes port [rounds] [ft ckpt_dir kill_round crash|resume]).
Each process:

  1. joins the cluster via the runtime under test (init_cluster with
     explicit coordinator/num_processes/process_id and faked local CPU
     devices — cluster.py sets the XLA flag and the gloo collectives
     BEFORE first backend use);
  2. loads ONLY its disjoint TF×IDF row shard (svm_rows_shard) and
     assembles the global arrays with Cluster.make_global_array;
  3. runs the sharded MapReduce-SVM round — build_sharded_round
     UNCHANGED, under every merge transport (allgather / ring / the
     two-level hier, whose host count comes from the real process
     topology) — over the global mesh;
  4. checks the result against the single-process functional reference
     (mapreduce_round over the full dataset, recomputed locally).

Prints MP_ROUND_OK as the last line on success; any assertion failure
or hang is surfaced by the parent test.

Fault-tolerance mode (ISSUE 7, ``ft`` argv tail): instead of the
equivalence legs, run the dedup-ring SWEEP round loop with per-round
durable snapshots (core.sweep.save_sweep_state on the coordinator). In
the ``crash`` phase process 1 SIGKILLs itself after completing round
``kill_round - 1``, stranding process 0 mid-collective in round
``kill_round`` — the parent reaps both and checks the checkpoint
pointer. In the ``resume`` phase (fresh coordinator port) both
processes restore the round state from disk, finish the remaining
rounds, and assert the result is BIT-FOR-BIT identical to an
uninterrupted run from scratch; prints MP_FT_OK on success.

Chaos mode (ISSUE 9, ``chaos`` argv tail): the ft leg under the
fault-injection harness. ``crash`` phase: the round loop runs inside a
CollectiveWatchdog with a heartbeat file per process — when process 1
SIGKILLs itself, process 0 strands in the merge collective and must
exit with WATCHDOG_EXIT_CODE (17) carrying the typed transport
diagnosis, never hang. ``resume`` phase: an armed handshake_flake plan
makes init_cluster's coordinator handshake flap (absorbed by its
retry), the parent has CORRUPTED the newest snapshot generation, so
latest_step must fall back to ``kill_round - 2`` — and the resumed run
still lands bit-for-bit on the uninterrupted result; prints
MP_CHAOS_OK.
"""
import sys

PID, NPROC, PORT = int(sys.argv[1]), int(sys.argv[2]), sys.argv[3]
ROUNDS = int(sys.argv[4]) if len(sys.argv) > 4 else 3
MODE = sys.argv[5] if len(sys.argv) > 5 else None
FT = MODE in ("ft", "chaos")
CHAOS = MODE == "chaos"
if FT:
    FT_DIR, KILL_ROUND, FT_PHASE = sys.argv[6], int(sys.argv[7]), sys.argv[8]
    assert FT_PHASE in ("crash", "resume"), FT_PHASE
NDEV = 8                                     # global devices, any NPROC

from repro import faults                     # noqa: E402
from repro.launch.cluster import ClusterConfig, init_cluster  # noqa: E402

if CHAOS and FT_PHASE == "resume":
    # arm BEFORE init_cluster: the restarted process's coordinator
    # handshake flaps 1-2× and the retry in init_cluster absorbs it
    faults.set_active(faults.FaultPlan.single("handshake_flake", seed=PID))

cluster = init_cluster(ClusterConfig(
    coordinator=f"localhost:{PORT}", num_processes=NPROC, process_id=PID,
    local_device_count=NDEV // NPROC))

if CHAOS and FT_PHASE == "resume":
    assert faults.counters().get("retries", 0) >= 1, \
        "handshake flake was armed but init_cluster never retried"
    faults.set_active(None)
    print(f"[p{PID}] chaos: flaky coordinator handshake absorbed by "
          f"retry ({faults.counters()['retries']} attempts)", flush=True)

import jax                                    # noqa: E402  (backend now up)
import numpy as np                            # noqa: E402
from jax.sharding import PartitionSpec as P   # noqa: E402

assert cluster.process_index == PID and cluster.process_count == NPROC
assert cluster.local_device_count == NDEV // NPROC
assert cluster.device_count == NDEV, cluster.describe()
assert cluster.is_coordinator == (PID == 0)

from repro.core import MRSVMConfig, SVMConfig                 # noqa: E402
from repro.core.mapreduce_svm import (build_sharded_round,    # noqa: E402
                                      init_sv_buffer, mapreduce_round)
from repro.data import host_row_range, svm_rows, svm_rows_shard  # noqa: E402
from repro.launch.mesh import make_host_mesh                  # noqa: E402

N_ROWS, D, SEED = 512, 16, 3
mesh = make_host_mesh(NDEV, 1, cluster=cluster)
assert tuple(mesh.shape.values()) == (NDEV, 1)

# -- per-host loading: this process's disjoint shard ------------------------
Xl, yl = svm_rows_shard(N_ROWS, D, seed=SEED,
                        process_index=PID, process_count=NPROC)
start, stop = host_row_range(N_ROWS, PID, NPROC)
Xf, yf = svm_rows(N_ROWS, D, seed=SEED)       # full set, for the oracle
np.testing.assert_array_equal(Xl, Xf[start:stop])   # shard ≡ its row range
np.testing.assert_array_equal(yl, yf[start:stop])

X = cluster.make_global_array(mesh, P("data"), Xl, (N_ROWS, D))
y = cluster.make_global_array(mesh, P("data"), yl, (N_ROWS,))
mask = cluster.make_global_array(
    mesh, P("data"), np.ones((stop - start,), np.float32), (N_ROWS,))

# -- functional single-process reference (identical on every process) -------
per = N_ROWS // NDEV


def reference(cfg):
    Xp = Xf.reshape(NDEV, per, D)
    yp = yf.reshape(NDEV, per)
    mp = np.ones((NDEV, per), np.float32)
    sv = init_sv_buffer(cfg.sv_capacity, D)
    risks = None
    for _ in range(ROUNDS):
        out = mapreduce_round(Xp, yp, mp, sv, cfg)
        sv, risks = out.sv, out.risks
    return sv, risks


# -- fault-tolerance leg (ISSUE 7): kill-a-worker, restart, converge --------
if FT:
    import os                                 # noqa: E402
    import signal                             # noqa: E402
    import time                               # noqa: E402
    import dataclasses as dc                  # noqa: E402

    from repro.ckpt.checkpoint import latest_path, latest_step  # noqa: E402
    from repro.core.sweep import (build_sharded_sweep_round,    # noqa: E402
                                  restore_sweep_state,
                                  save_sweep_state, stack_params)

    # Dedup-ring sweep: the round state on the wire is the shared-row
    # DedupChunk — the layout the checkpointer must round-trip. f32
    # wire keeps every collective bit-exact, so resumed ≡ scratch is an
    # equality assertion, not a tolerance.
    cfg = MRSVMConfig(sv_capacity=64, svm=SVMConfig(C=1.0, max_epochs=15),
                      shuffle_impl="ring", shuffle_wire_dtype="float32")
    S = 2
    params = stack_params([dc.replace(cfg.svm, C=c).params()
                           for c in (1.0, 0.5)])
    fn = build_sharded_sweep_round(mesh, ("data",), cfg, per)
    assert fn.expand_sv is not None           # proves DedupChunk state

    def run(state, start, stop, checkpoint=False):
        out = None
        for t in range(start, stop):
            state, risks, ws, bs = fn(X, y, mask, state, params)
            jax.block_until_ready((state, risks, ws, bs))
            if checkpoint and cluster.is_coordinator:
                save_sweep_state(
                    os.path.join(FT_DIR, f"sweep_{t}.npz"), state, step=t)
            if checkpoint and PID == 1 and t == KILL_ROUND - 1:
                time.sleep(0.5)   # let the peer finish round t and save
                os.kill(os.getpid(), signal.SIGKILL)
            out = (risks, ws, bs)
        return state, out

    if FT_PHASE == "crash":
        if CHAOS:
            # Chaos crash: the round loop runs under the collective
            # watchdog. Round 0 warms the jit cache OUTSIDE the
            # deadline (compile time must not trip it); every later
            # round beats. When p1 SIGKILLs itself, p0 strands in the
            # merge ppermute — Python cannot interrupt the gloo C call,
            # so the guaranteed outcome is the TYPED exit: watchdog →
            # heartbeat "timeout" → exit 17. Some gloo versions raise
            # instead of stranding; that surfaces the same typed way.
            import json                       # noqa: E402
            hb = os.path.join(FT_DIR, f"hb_p{PID}.json")
            state, _ = run(fn.init_sv(S, D), 0, 1, checkpoint=True)
            try:
                with faults.CollectiveWatchdog(
                        60.0, heartbeat_path=hb, layer="transport",
                        cause=f"p{PID} ring merge collective") as wd:
                    for t in range(1, ROUNDS):
                        state, _ = run(state, t, t + 1, checkpoint=True)
                        wd.beat()
            except BaseException as e:        # raised, not stranded
                tmp = hb + ".tmp"
                with open(tmp, "w") as f:
                    json.dump({"status": "detected",
                               "layer": "transport",
                               "cause": f"{type(e).__name__}: {e}"}, f)
                os.replace(tmp, hb)
                print(f"FaultDetected[transport]: peer loss surfaced "
                      f"as {type(e).__name__} — restart from the last "
                      "checkpoint generation", flush=True)
                sys.exit(faults.WATCHDOG_EXIT_CODE)
            raise SystemExit(
                "chaos crash phase completed — process 1 never died")
        run(fn.init_sv(S, D), 0, ROUNDS, checkpoint=True)
        raise SystemExit("crash phase completed — process 1 never died")

    # resume: pick up the interrupted run from the durable state…
    # (chaos: the parent corrupted the newest generation's medium, so
    # the crc walk must land one generation EARLIER — and count it)
    t0 = latest_step(FT_DIR)
    want = KILL_ROUND - 2 if CHAOS else KILL_ROUND - 1
    assert t0 == want, (t0, want, KILL_ROUND)
    if CHAOS:
        assert faults.counters().get("ckpt_fallbacks", 0) >= 1, \
            "corrupt newest generation was not skipped via crc"
        print(f"[p{PID}] chaos: newest snapshot generation corrupt — "
              f"resuming from intact generation {t0}", flush=True)
    state = restore_sweep_state(latest_path(FT_DIR), cfg, S, D, NDEV, per)
    state_r, out_r = run(state, t0 + 1, ROUNDS)
    # …and land bit-for-bit where an uninterrupted run lands.
    state_u, out_u = run(fn.init_sv(S, D), 0, ROUNDS)
    leaves_r = jax.tree_util.tree_leaves((fn.expand_sv(state_r), *out_r))
    leaves_u = jax.tree_util.tree_leaves((fn.expand_sv(state_u), *out_u))
    assert len(leaves_r) == len(leaves_u)
    for a, b in zip(leaves_r, leaves_u):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print(f"[p{PID}] ft: resumed sweep ≡ uninterrupted sweep "
          f"(killed mid-round {KILL_ROUND}, {ROUNDS} rounds, "
          f"{len(leaves_r)} leaves bit-for-bit)", flush=True)
    print("MP_CHAOS_OK" if CHAOS else "MP_FT_OK", flush=True)
    sys.exit(0)

for shuffle in ("allgather", "ring", "hier"):
    # f32 wire keeps the packed transports bit-exact so the functional
    # reference stays the strict oracle (same convention as
    # test_sharded_round). hier resolves its host count from the REAL
    # process topology here (hier_num_hosts=None → process_count): the
    # 2-process × 4-local run is the genuine two-level schedule.
    cfg = MRSVMConfig(sv_capacity=64, svm=SVMConfig(C=1.0, max_epochs=15),
                      shuffle_impl=shuffle, shuffle_wire_dtype="float32")
    fn = build_sharded_round(mesh, ("data",), cfg, per)
    sv_s = init_sv_buffer(cfg.sv_capacity, D)
    risks_s = None
    for _ in range(ROUNDS):
        sv_s, risks_s, w_s, b_s = fn(X, y, mask, sv_s)

    sv_f, risks_f = reference(cfg)
    # every output is replicated → fully addressable on each process
    np.testing.assert_allclose(np.asarray(risks_s), np.asarray(risks_f),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(sv_s.ids), np.asarray(sv_f.ids))
    np.testing.assert_array_equal(np.asarray(sv_s.mask),
                                  np.asarray(sv_f.mask))
    np.testing.assert_allclose(np.asarray(sv_s.alpha),
                               np.asarray(sv_f.alpha), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(sv_s.x), np.asarray(sv_f.x),
                               rtol=1e-5, atol=1e-6)
    assert np.asarray(w_s).shape == (D,) and np.asarray(b_s).shape == ()
    print(f"[p{PID}] {shuffle}: {NPROC}-process round ≡ functional "
          f"reference over {ROUNDS} rounds", flush=True)

# -- blocked-CSR leg (ISSUE 6): sparse sharded round, sparse wire -----------
# Same rows, same dense functional oracle: svm_rows emits ≤4 nonzeros
# per row at D=16, so from_dense at CAP=8 is lossless and the dense
# reference stays the strict truth. Only the FORMAT changes — per-host
# blocked-CSR leaves assembled into one global SparseRows, the SV
# buffer and the merge wire (values-packed + bitcast indices) sparse
# throughout.
import dataclasses as dc                      # noqa: E402

import jax.numpy as jnp                       # noqa: E402
from repro import sparse                      # noqa: E402

CAP = 8
Xls = sparse.from_dense(jnp.asarray(Xl), CAP)
np.testing.assert_array_equal(np.asarray(sparse.to_dense(Xls)), Xl)
Xsp = sparse.SparseRows(
    cluster.make_global_array(mesh, P("data"), np.asarray(Xls.indices),
                              (N_ROWS, CAP)),
    cluster.make_global_array(mesh, P("data"), np.asarray(Xls.values),
                              (N_ROWS, CAP)),
    D)

for shuffle in ("allgather", "ring", "hier"):
    cfg_d = MRSVMConfig(sv_capacity=64, svm=SVMConfig(C=1.0, max_epochs=15),
                        shuffle_impl=shuffle, shuffle_wire_dtype="float32")
    cfg_s = dc.replace(cfg_d, svm=dc.replace(
        cfg_d.svm, row_format="sparse_csr", nnz_cap=CAP))
    fn = build_sharded_round(mesh, ("data",), cfg_s, per)
    sv_s = init_sv_buffer(cfg_s.sv_capacity, D, nnz_cap=CAP)
    risks_s = None
    for _ in range(ROUNDS):
        sv_s, risks_s, w_s, b_s = fn(Xsp, y, mask, sv_s)

    sv_f, risks_f = reference(cfg_d)
    np.testing.assert_allclose(np.asarray(risks_s), np.asarray(risks_f),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(sv_s.ids), np.asarray(sv_f.ids))
    np.testing.assert_array_equal(np.asarray(sv_s.mask),
                                  np.asarray(sv_f.mask))
    np.testing.assert_allclose(np.asarray(sv_s.alpha),
                               np.asarray(sv_f.alpha), rtol=1e-4, atol=1e-5)
    assert sparse.is_sparse(sv_s.x) and sv_s.x.nnz_cap == CAP
    np.testing.assert_allclose(np.asarray(sparse.to_dense(sv_s.x)),
                               np.asarray(sv_f.x), rtol=1e-5, atol=1e-6)
    print(f"[p{PID}] {shuffle}: sparse {NPROC}-process round ≡ dense "
          f"functional reference over {ROUNDS} rounds", flush=True)

print("MP_ROUND_OK", flush=True)
