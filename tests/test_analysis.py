"""repro.analysis unit tier (ISSUE 8): the HLO parser against synthetic
post-SPMD text fixtures, each rule family against hand-seeded positives
and negatives, and (slow tier) the ``python -m repro.analysis.lint``
CLI as a subprocess. The full builder matrix lives in `make lint-jax`;
here each rule is exercised in isolation so a regression names the
broken rule, not the whole matrix."""
import subprocess
import sys
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import analysis
from repro.analysis import LintViolation
from conftest import subprocess_env

# ---------------------------------------------------------------- parser

# shapes of real post-SPMD HLO: column-0 computation headers, indented
# ops, ROOT prefixes, async start/done pairs, both replica_groups forms
_HLO_FIXTURE = """\
HloModule jit_step, entry_computation_layout={()->f32[8]{0}}

%region_0.11 (arg0: f32[8], arg1: f32[8]) -> f32[8] {
  %arg0 = f32[8]{0} parameter(0)
  %arg1 = f32[8]{0} parameter(1)
  ROOT %add.1 = f32[8]{0} add(%arg0, %arg1)
}

%while_body.20 (p: (f32[8], u32[])) -> (f32[8], u32[]) {
  %p = (f32[8]{0}, u32[]) parameter(0)
  %gte = f32[8]{0} get-tuple-element(%p), index=0
  %cp.1 = f32[8]{0} collective-permute(%gte), channel_id=3, source_target_pairs={{0,1},{1,2},{2,3},{3,0}}
  ROOT %tup = (f32[8]{0}, u32[]) tuple(%cp.1, %gte)
}

%while_cond.30 (p: (f32[8], u32[])) -> pred[] {
  ROOT %lt = pred[] constant(true)
}

ENTRY %main.42 (arg: f32[2,8]) -> f32[8] {
  %arg = f32[2,8]{1,0} parameter(0)
  %ag-start = (f32[2,8]{1,0}, f32[8,8]{1,0}) all-gather-start(%arg), channel_id=1, replica_groups=[2,4]<=[8], dimensions={0}
  %ag-done = f32[8,8]{1,0} all-gather-done(%ag-start)
  %ar.5 = f32[8]{0} all-reduce(%ag-done), channel_id=2, replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%region_0.11
  %wh = (f32[8]{0}, u32[]) while((f32[8]{0}, u32[]) %init), condition=%while_cond.30, body=%while_body.20
  ROOT %rs.9 = f32[1]{0} reduce-scatter(%ar.5), channel_id=4, replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}, to_apply=%region_0.11
}
"""


def test_parse_collective_ops_fixture():
    ops = analysis.parse_collective_ops(_HLO_FIXTURE)
    kinds = [(op.kind, op.is_start, op.is_done) for op in ops]
    assert kinds == [
        ("collective-permute", False, False),
        ("all-gather", True, False),
        ("all-gather", False, True),
        ("all-reduce", False, False),
        ("reduce-scatter", False, False),
    ]
    by_name = {op.name: op for op in ops}
    # tuple-typed async start yields BOTH element shapes
    ag = by_name["ag-start"]
    assert ag.shapes == (("f32", (2, 8)), ("f32", (8, 8)))
    assert ag.iota_groups == (4, 2)          # group_size=4, 2 groups
    assert ag.group_size == 4
    # brace-form groups
    ar = by_name["ar.5"]
    assert ar.replica_groups == ((0, 1, 2, 3), (4, 5, 6, 7))
    assert ar.channel_id == 2
    # permute pairs + computation attribution inside the while body
    cp = by_name["cp.1"]
    assert cp.source_target_pairs == ((0, 1), (1, 2), (2, 3), (3, 0))
    assert cp.computation == "while_body.20"
    # ROOT-prefixed op still parses
    rs = by_name["rs.9"]
    assert rs.kind == "reduce-scatter"
    assert rs.computation == "main.42"


def test_while_body_computations():
    assert analysis.while_body_computations(_HLO_FIXTURE) == frozenset(
        {"while_body.20", "while_cond.30"})


def test_tensor_shapes_tuple_and_token():
    shapes = analysis.tensor_shapes("(f32[2,8]{1,0}, u32[], token[])")
    # token[] carries no dims and parses as an empty-shape pseudo-tensor
    assert ("f32", (2, 8)) in shapes and ("u32", ()) in shapes


def test_tensor_nbytes_subbyte_and_f8():
    assert analysis.dtype_nbits("f8e4m3fn") == 8
    assert analysis.dtype_nbits("u4") == 4
    # 9 u4 elements round up to 5 whole bytes
    assert analysis.tensor_nbytes("u4[9]") == [5]
    assert analysis.tensor_nbytes("bf16[4,4]") == [32]


def test_unknown_dtype_warns_and_overcounts():
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        sizes = analysis.tensor_nbytes("f6e3m2[64]")
    # conservative 32-bit fallback: overcount, never a silent skip
    assert sizes == [256]
    assert any("unknown dtype" in str(x.message) for x in w)
    # warned once per dtype, not per call
    with warnings.catch_warnings(record=True) as w2:
        warnings.simplefilter("always")
        analysis.tensor_nbytes("f6e3m2[64]")
    assert not [x for x in w2 if "f6e3m2" in str(x.message)]


def test_hlo_analysis_delegates_to_parser():
    from repro.launch.hlo_analysis import collective_stats
    stats = collective_stats(_HLO_FIXTURE)
    # the -done half is not double-counted
    assert stats["all-gather"]["count"] == 1
    assert stats["collective-permute"]["count"] == 1
    assert stats["all-reduce"]["count"] == 1
    assert stats["reduce-scatter"]["count"] == 1
    # all-gather wire at g=4: out*(g-1)/g of the 256-byte gathered block
    assert stats["all-gather"]["wire_bytes"] == pytest.approx(256 * 3 / 4)


# ------------------------------------------------------------- schedule

def test_check_schedule_ok():
    report = analysis.check_schedule(_HLO_FIXTURE, program="fixture")
    assert report.checked == 5


def test_check_schedule_dangling_start():
    text = _HLO_FIXTURE.replace(
        "  %ag-done = f32[8,8]{1,0} all-gather-done(%ag-start)\n", "")
    with pytest.raises(LintViolation) as ei:
        analysis.check_schedule(text, program="fixture")
    assert ei.value.rule == "collective-schedule"
    assert "never consumed" in str(ei.value)


def test_check_schedule_duplicate_permute_target():
    text = _HLO_FIXTURE.replace("{{0,1},{1,2},{2,3},{3,0}}",
                                "{{0,1},{1,2},{2,1},{3,0}}")
    with pytest.raises(LintViolation) as ei:
        analysis.check_schedule(text, program="fixture")
    assert "duplicate target device(s) [1]" in str(ei.value)


def test_check_schedule_overlapping_groups():
    text = _HLO_FIXTURE.replace("replica_groups={{0,1,2,3},{4,5,6,7}}",
                                "replica_groups={{0,1,2,3},{3,5,6,7}}")
    with pytest.raises(LintViolation) as ei:
        analysis.check_schedule(text, program="fixture")
    assert "device 3" in str(ei.value) and "disjoint" in str(ei.value)


def test_schedules_agree_and_diverge():
    sched = analysis.collective_schedule(_HLO_FIXTURE)
    assert len(sched) == 4                   # -done excluded
    analysis.assert_schedules_agree({"p0": sched, "p1": sched})
    with pytest.raises(LintViolation) as ei:
        analysis.assert_schedules_agree({"p0": sched, "p1": sched[:-1]})
    assert "counts diverge" in str(ei.value)
    swapped = (sched[1], sched[0]) + sched[2:]
    with pytest.raises(LintViolation) as ei:
        analysis.assert_schedules_agree({"p0": sched, "p1": swapped})
    assert ei.value.op == "schedule[0]"


def test_compare_collective_counts_stale():
    fresh = {"all-gather": {"count": 2, "wire_bytes": 1.0}}
    analysis.compare_collective_counts(
        {"all-gather": {"count": 2, "wire_bytes": 999.0}}, fresh)
    with pytest.raises(LintViolation) as ei:
        analysis.compare_collective_counts(
            {"all-gather": {"count": 3}}, fresh, program="artifact")
    assert "stale" in str(ei.value) and ei.value.program == "artifact"


# -------------------------------------------------------------- retrace

def test_no_retrace_catches_per_call_jit():
    x = jnp.arange(8.0)
    with pytest.raises(analysis.RetraceError) as ei:
        with analysis.no_retrace(program="steady"):
            # the classic bug: a fresh jit wrapper per call never hits
            # the cache
            jax.jit(lambda v: v * 2.0)(x).block_until_ready()
    assert ei.value.rule == "retrace"
    assert ei.value.program == "steady"
    assert ei.value.events                   # names the compiled fn


def test_no_retrace_allow_absorbs_warmup():
    x = jnp.arange(8.0)
    f = jax.jit(lambda v: v + 1.5)
    with analysis.no_retrace(program="warmup", allow=1) as stats:
        f(x).block_until_ready()             # first call compiles
        f(x).block_until_ready()             # cache hit
    assert stats.count <= 1


def test_watch_compiles_counts_zero_on_cache_hit():
    f = jax.jit(lambda v: v - 3.0)
    x = jnp.arange(4.0)
    f(x).block_until_ready()                 # compile outside the watch
    with analysis.watch_compiles() as stats:
        f(x).block_until_ready()
    assert stats.count == 0


# ------------------------------------------------------------ host-sync

def test_check_no_host_callbacks_flags_debug_callback():
    def bad(v):
        jax.debug.callback(lambda a: None, v)
        return v * 2

    with pytest.raises(LintViolation) as ei:
        analysis.check_no_host_callbacks(bad, (jnp.zeros(4),),
                                         program="hot-loop")
    assert ei.value.rule == "host-sync"
    assert "callback" in ei.value.op

    report = analysis.check_no_host_callbacks(
        bad, (jnp.zeros(4),), program="hot-loop",
        allow=("debug_callback",))
    assert [a.op for a in report.allowed] == ["debug_callback"]


def test_check_no_host_callbacks_clean_program():
    report = analysis.check_no_host_callbacks(
        lambda v: jnp.tanh(v) @ v, (jnp.zeros((4, 4)),), program="clean")
    assert report.checked >= 1 and not report.allowed


def test_runtime_guard_fires_where_enforced():
    x = jnp.ones(4)
    if not analysis.host_guards_enforced():
        # CPU backend: buffers are host-resident, the guard physically
        # cannot fire — the static layer above is the check here.
        with analysis.no_implicit_host_sync():
            np.asarray(x)
        return
    with pytest.raises(Exception):
        with analysis.no_implicit_host_sync():
            np.asarray(x)
    with analysis.no_implicit_host_sync():
        with analysis.allowed_host_sync("designed readback"):
            np.asarray(x)


# ----------------------------------------------------------- dense leak

def test_dense_materialization_flags_full_block():
    d = 512

    def bad(idx):
        return jnp.zeros((d, d)) + idx        # full dense block

    with pytest.raises(LintViolation) as ei:
        analysis.check_no_dense_materialization(
            bad, (jnp.float32(1),), d=d, program="sparse-serve")
    assert ei.value.rule == "dense-materialization"
    assert "(512, 512)" in str(ei.value)


def test_dense_materialization_allows_chunked_densify():
    d = 512

    def chunked(idx):
        return jnp.zeros((64, d)) + idx       # cross_dots-sized scratch

    report = analysis.check_no_dense_materialization(
        chunked, (jnp.float32(1),), d=d, program="sparse-serve")
    assert report.checked >= 1


def test_memory_ceiling_on_compiled_program():
    compiled = jax.jit(lambda v: v * 2.0).lower(jnp.zeros(64)).compile()
    report = analysis.check_memory_ceiling(
        compiled, limit_bytes=1 << 20, program="tiny")
    # either the backend reports temp bytes under the roomy ceiling or
    # it exposes no memory_analysis and the rule says so
    assert report.checked == 1 or "memory_analysis" in (report.note or "")


# ---------------------------------------------------------- dtype drift

def test_dtype_drift_flags_tainted_downcast():
    def bad(alpha):
        return (alpha.astype(jnp.bfloat16) * 2).astype(jnp.float32)

    with pytest.raises(LintViolation) as ei:
        analysis.check_no_dtype_drift(
            bad, (jnp.ones(8),), taint=[True], program="round")
    assert ei.value.rule == "dtype-drift"
    assert "float32" in str(ei.value) and "bfloat16" in str(ei.value)


def test_dtype_drift_ignores_untainted_downcast():
    def mixed(alpha, rows):
        return alpha * 2.0, rows.astype(jnp.bfloat16)

    report = analysis.check_no_dtype_drift(
        mixed, (jnp.ones(8), jnp.ones(8)), taint=[True, False],
        program="round")
    assert report.checked >= 2


def test_dtype_drift_wire_pack_allowlisted():
    from repro.core.mapreduce_svm import pack_wire_rows

    def pack(alpha_rows):
        flat, _ = pack_wire_rows(alpha_rows.astype(jnp.bfloat16),
                                 jnp.bfloat16)
        return flat

    report = analysis.check_no_dtype_drift(
        pack, (jnp.ones((4, 8)),), taint=[True], program="ring-pack")
    assert any("wire pack" in a.reason for a in report.allowed)


def test_dtype_drift_caller_allow_lines():
    def bad(alpha):
        return alpha.astype(jnp.bfloat16)

    with pytest.raises(LintViolation):
        analysis.check_no_dtype_drift(
            bad, (jnp.ones(8),), taint=[True], program="round")
    report = analysis.check_no_dtype_drift(
        bad, (jnp.ones(8),), taint=[True], program="round",
        allow_lines=("test_analysis.py",))
    assert any("caller allowlist" in a.reason for a in report.allowed)


def test_dtype_drift_through_scan_carry():
    def loop(alpha):
        def body(c, _):
            return c.astype(jnp.bfloat16).astype(jnp.float32), ()
        out, _ = jax.lax.scan(body, alpha, None, length=3)
        return out

    with pytest.raises(LintViolation) as ei:
        analysis.check_no_dtype_drift(
            loop, (jnp.ones(8),), taint=[True], program="sweep")
    assert ei.value.rule == "dtype-drift"


# --------------------------------------------------- lint CLI (slow)

@pytest.mark.slow
def test_lint_cli_self_test():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", "--self-test"],
        env=subprocess_env(), capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "OK seeded [retrace]" in proc.stdout
    assert "all invariant rules passed" in proc.stdout


@pytest.mark.slow
def test_lint_cli_full_matrix():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint"],
        env=subprocess_env(), capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "all invariant rules passed" in proc.stdout
