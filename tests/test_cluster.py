"""Cluster-runtime regressions (ISSUE 5 satellites): the 1-process
fast path must stay a no-op (no coordinator handshake), and
make_global_array must round-trip against plain ``jax.device_put`` on
a single host — on both the native assembly and the compat fallback.
The real multi-process behaviour is tests/test_multihost.py."""
import argparse

import jax
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.launch import cluster as cluster_lib
from repro.launch.cluster import (ClusterConfig, add_cluster_flags,
                                  cluster_config_from_args, init_cluster,
                                  local_cluster, simulated_topology)
from repro.launch.mesh import make_cluster_mesh, make_host_mesh


@pytest.fixture(autouse=True)
def _fresh_runtime(monkeypatch):
    """init_cluster is process-global (jax.distributed initializes
    once); isolate each test's view of it."""
    monkeypatch.setattr(cluster_lib, "_CLUSTER", None)


def test_init_cluster_single_process_is_noop_fast_path(monkeypatch):
    """No coordinator configured anywhere → NO distributed handshake:
    jax.distributed.initialize must never be called (a 1-process
    launch needs no open port, no timeout, no gloo)."""
    def boom(*a, **k):
        raise AssertionError("distributed handshake on the 1-process path")
    monkeypatch.setattr(jax.distributed, "initialize", boom)
    monkeypatch.setattr(compat, "enable_cpu_collectives", boom)
    for var in ("REPRO_COORDINATOR", "JAX_COORDINATOR_ADDRESS",
                "REPRO_NUM_PROCESSES", "JAX_NUM_PROCESSES"):
        monkeypatch.delenv(var, raising=False)
    c = init_cluster()
    assert c.process_count == 1 and c.process_index == 0
    assert not c.is_distributed and c.is_coordinator
    assert c.device_count == len(jax.devices())
    assert c.local_device_count == len(jax.local_devices())
    # idempotent: the second call returns the same handle
    assert init_cluster() is c


def test_cluster_config_env_autodetect(monkeypatch):
    monkeypatch.setenv("REPRO_COORDINATOR", "somehost:1234")
    monkeypatch.setenv("REPRO_NUM_PROCESSES", "4")
    monkeypatch.setenv("REPRO_PROCESS_ID", "2")
    cfg = ClusterConfig().resolved()
    assert cfg.coordinator == "somehost:1234"
    assert cfg.num_processes == 4 and cfg.process_id == 2
    assert cfg.is_multiprocess
    # explicit args beat the environment
    cfg = ClusterConfig(process_id=0).resolved()
    assert cfg.process_id == 0


def test_cluster_flags_roundtrip():
    ap = argparse.ArgumentParser()
    add_cluster_flags(ap)
    cfg = cluster_config_from_args(ap.parse_args(
        ["--coordinator", "localhost:9911", "--num-processes", "2",
         "--process-id", "1", "--local-devices", "4"]))
    assert cfg == ClusterConfig(coordinator="localhost:9911",
                                num_processes=2, process_id=1,
                                local_device_count=4)
    # no flags → the single-process config
    assert not cluster_config_from_args(ap.parse_args([])).is_multiprocess


def test_incomplete_multiprocess_config_raises(monkeypatch):
    monkeypatch.setattr(cluster_lib, "_CLUSTER", None)
    with pytest.raises(ValueError, match="triple"):
        init_cluster(ClusterConfig(coordinator="localhost:1"))


def _roundtrip(spec, local, global_shape):
    c = local_cluster()
    n = len(jax.devices())
    mesh = make_host_mesh(n, 1)
    arr = c.make_global_array(mesh, spec, local, global_shape)
    ref = jax.device_put(local, NamedSharding(mesh, spec))
    np.testing.assert_array_equal(np.asarray(arr), np.asarray(ref))
    assert arr.sharding.is_equivalent_to(ref.sharding, local.ndim)
    return arr


def test_make_global_array_roundtrips_against_device_put():
    """On one host the process-local shard IS the whole array, so
    make_global_array must agree with jax.device_put exactly —
    sharded rows and fully-replicated buffers alike."""
    n = len(jax.devices())
    rows = np.arange(4 * n * 3, dtype=np.float32).reshape(4 * n, 3)
    _roundtrip(P("data"), rows, rows.shape)
    _roundtrip(P(), rows, rows.shape)                      # replicated
    _roundtrip(P("data"), np.arange(2 * n, dtype=np.int32), (2 * n,))


def test_make_global_array_fallback_single_device_arrays(monkeypatch):
    """Old-JAX path: without jax.make_array_from_process_local_data the
    compat fallback assembles the same array per device."""
    monkeypatch.delattr(jax, "make_array_from_process_local_data",
                        raising=False)
    n = len(jax.devices())
    rows = np.arange(4 * n * 2, dtype=np.float32).reshape(4 * n, 2)
    _roundtrip(P("data"), rows, rows.shape)
    _roundtrip(P(), rows, rows.shape)
    # fallback needs the explicit global shape
    with pytest.raises(ValueError, match="global_shape"):
        local_cluster().make_global_array(
            make_host_mesh(n, 1), P("data"), rows, None)


def test_make_cluster_mesh_process_order():
    c = local_cluster()
    mesh = make_cluster_mesh(c)
    assert mesh.axis_names == ("data", "model")
    assert mesh.shape["data"] == len(jax.devices())
    assert list(mesh.devices.flat) == list(jax.devices())


def test_simulated_topology():
    assert simulated_topology(4, 256) == {"process_count": 4,
                                          "devices_per_process": 64}
    with pytest.raises(ValueError):
        simulated_topology(3, 256)


def test_streaming_service_admission_is_coordinator_only():
    """svm_stream on a non-coordinator process: snapshots readable,
    admission refused (submit raises; start/run_wave no-op)."""
    from repro.core import MRSVMConfig, SVMConfig, fit_mapreduce
    from repro.serving import StreamingSVMService

    rng = np.random.default_rng(0)
    X = rng.normal(0, 1, (64, 8)).astype(np.float32)
    y = np.sign(X @ rng.normal(0, 1, 8).astype(np.float32) + 1e-3)
    cfg = MRSVMConfig(sv_capacity=16, max_rounds=2,
                      svm=SVMConfig(C=1.0, max_epochs=8))
    model = fit_mapreduce(X, y, 4, cfg)

    replica = cluster_lib.Cluster(process_index=1, process_count=2)
    svc = StreamingSVMService(cfg, num_partitions=4, cluster=replica)
    svc.register("s0", model)
    assert svc.predict("s0", X).shape == (64,)      # snapshot readable
    assert svc.snapshot("s0").version == 0
    with pytest.raises(RuntimeError, match="process 0"):
        svc.submit("s0", X, y)
    svc.start()                                     # symmetric-SPMD no-op
    assert svc._thread is None
    assert svc.run_wave() is None

    coord = cluster_lib.Cluster(process_index=0, process_count=2)
    svc0 = StreamingSVMService(cfg, num_partitions=4, cluster=coord)
    svc0.register("s0", model)
    svc0.submit("s0", X, y)                         # coordinator admits
    assert svc0.run_wave() is not None
    assert svc0.snapshot("s0").version == 1
