"""Unit tests for the version-portable JAX substrate (repro.compat)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat


def test_jax_version_tuple():
    v = compat.jax_version()
    assert isinstance(v, tuple) and len(v) == 3
    assert all(isinstance(p, int) for p in v)
    assert v >= (0, 4, 0)


def test_tree_map():
    out = compat.tree_map(lambda a, b: a + b, {"x": 1, "y": (2, 3)},
                          {"x": 10, "y": (20, 30)})
    assert out == {"x": 11, "y": (22, 33)}


# ---------------------------------------------------------------------------
# pvary: the _pvary regression (ISSUE 1 satellite). On JAX without
# pcast/pvary the old fallback raised AttributeError from inside the
# except block whenever vma_axes was non-empty; it must degrade to the
# identity instead.
# ---------------------------------------------------------------------------

def test_pvary_empty_axes_is_identity():
    x = jnp.arange(3.0)
    assert compat.pvary(x, ()) is x


def test_pvary_nonempty_axes_never_raises():
    tree = (jnp.zeros((4,)), jnp.asarray(1.0))
    out = compat.pvary(tree, ("data",))     # outside shard_map, old JAX
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_svm_pvary_shim_and_vma_axes_path():
    """fit_binary with non-empty vma_axes (the sharded reducer call
    signature) must run on the installed JAX — this is exactly the
    configuration that used to die in _pvary's except block."""
    from repro.core.svm import SVMConfig, _pvary, fit_binary
    x = {"a": jnp.ones((2, 2))}
    out = _pvary(x, ("data",))
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(x["a"]))

    X = jax.random.normal(jax.random.PRNGKey(0), (32, 4))
    y = jnp.sign(X[:, 0] + 1e-3)
    model = fit_binary(X, y, cfg=SVMConfig(C=1.0, max_epochs=10),
                       vma_axes=("data",))
    assert float(jnp.max(model.alpha)) >= 0.0


# ---------------------------------------------------------------------------
# Mesh construction across the constructor drift.
# ---------------------------------------------------------------------------

def test_make_abstract_mesh():
    mesh = compat.make_abstract_mesh((16, 16), ("data", "model"))
    assert mesh.shape["data"] == 16 and mesh.shape["model"] == 16
    assert tuple(mesh.axis_names) == ("data", "model")


def test_make_abstract_mesh_3d():
    mesh = compat.make_abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    assert mesh.shape["pod"] == 2
    assert tuple(mesh.axis_names) == ("pod", "data", "model")


def test_make_mesh_local_devices():
    mesh = compat.make_mesh((len(jax.devices()),), ("data",))
    assert mesh.shape["data"] == len(jax.devices())


# ---------------------------------------------------------------------------
# shard_map wrapper: check_vma mapping + collectives on the installed JAX.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("check_vma", [None, False])
def test_shard_map_psum(check_vma):
    mesh = compat.make_mesh((1,), ("data",))
    fn = compat.shard_map(lambda x: compat.psum(jnp.sum(x), ("data",)),
                          mesh=mesh, in_specs=(P("data"),), out_specs=P(),
                          check_vma=check_vma)
    assert float(jax.jit(fn)(jnp.arange(4.0))) == 6.0


def test_axis_index_multi_axis():
    mesh = compat.make_mesh((1, 1), ("a", "b"))
    fn = compat.shard_map(
        lambda x: x + compat.axis_index(("a", "b")).astype(x.dtype),
        mesh=mesh, in_specs=(P(),), out_specs=P(), check_vma=False)
    assert float(jax.jit(fn)(jnp.asarray(1.0))) == 1.0


def test_all_gather_and_pmax():
    mesh = compat.make_mesh((1,), ("data",))
    def body(x):
        g = compat.all_gather(x, ("data",), tiled=True)
        return g, compat.pmax(jnp.max(x), ("data",))
    fn = compat.shard_map(body, mesh=mesh, in_specs=(P("data"),),
                          out_specs=(P(), P()), check_vma=False)
    g, m = jax.jit(fn)(jnp.arange(4.0))
    assert g.shape == (4,) and float(m) == 3.0


# ---------------------------------------------------------------------------
# Ring-pipelined shuffle primitives (ISSUE 4 tentpole).
# ---------------------------------------------------------------------------

def test_ring_shift_single_device_identity():
    """A 1-device ring is the identity — and ring_shift must map over a
    whole pytree (the SV chunk + packed sideband of the ring merge)."""
    mesh = compat.make_mesh((1,), ("data",))
    fn = compat.shard_map(
        lambda x: compat.ring_shift((x, x * 2.0), ("data",)),
        mesh=mesh, in_specs=(P("data"),), out_specs=(P("data"), P("data")),
        check_vma=False)
    a, b = jax.jit(fn)(jnp.arange(4.0))
    np.testing.assert_array_equal(np.asarray(a), np.arange(4.0))
    np.testing.assert_array_equal(np.asarray(b), 2.0 * np.arange(4.0))


def test_ring_shift_multi_axis_fallback(monkeypatch):
    """Where jax.lax.ppermute rejects a tuple of axis names, ring_shift
    must rebuild the flattened ring from per-axis permutes (inner shift
    + wrap-correcting outer shift) instead of failing."""
    orig = jax.lax.ppermute

    def single_axis_only(x, axis_name, perm):
        if not isinstance(axis_name, str):
            raise TypeError("tuple axis names unsupported (old JAX)")
        return orig(x, axis_name, perm)

    monkeypatch.setattr(jax.lax, "ppermute", single_axis_only)
    mesh = compat.make_mesh((1, 1), ("a", "b"))
    fn = compat.shard_map(lambda x: compat.ring_shift(x, ("a", "b")),
                          mesh=mesh, in_specs=(P(),), out_specs=P(),
                          check_vma=False)
    out = jax.jit(fn)(jnp.arange(3.0))
    np.testing.assert_array_equal(np.asarray(out), np.arange(3.0))


def test_ppermute_single_axis():
    mesh = compat.make_mesh((1,), ("data",))
    fn = compat.shard_map(
        lambda x: compat.ppermute(x, ("data",), [(0, 0)]),
        mesh=mesh, in_specs=(P("data"),), out_specs=P("data"),
        check_vma=False)
    np.testing.assert_array_equal(np.asarray(jax.jit(fn)(jnp.arange(2.0))),
                                  np.arange(2.0))
