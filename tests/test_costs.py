"""Validate the analytic cost model against an UNROLLED XLA compile
(promised in launch/costs.py): with lax.scan bodies unrolled there is
no loop-once undercounting, so XLA's global flop count should agree
with the analytic formula within tolerance."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.launch.costs import forward_flops
from repro.models.config import smoke_variant
from repro.models.transformer import build_model


@pytest.mark.parametrize("arch,tol", [("tinyllama-1.1b", 0.30),
                                      ("qwen2-1.5b", 0.30)])
def test_analytic_forward_flops_vs_xla(arch, tol):
    cfg = smoke_variant(get_config(arch))
    # full-width but 2 layers, modest seq so attention term is visible
    cfg = dataclasses.replace(cfg, num_layers=2, d_model=256, d_ff=512,
                              vocab_size=512)
    model = build_model(cfg)
    B, S = 2, 256
    tokens = jax.ShapeDtypeStruct((B, S), jnp.int32)
    params = model.abstract()

    def fwd(params, tokens):
        logits, _ = model.forward(params, tokens)
        return logits

    lowered = jax.jit(fwd).lower(params, tokens)
    xla_flops = float((lowered.cost_analysis() or {}).get("flops", 0.0))
    if xla_flops == 0.0:
        pytest.skip("cost_analysis unavailable")
    analytic = forward_flops(cfg, B, S)
    # lowered (unoptimized) module still counts scan bodies once; with
    # L=2 the undercount is bounded — compare against the 1-layer-
    # counted analytic equivalent instead:
    one_layer = dataclasses.replace(cfg, num_layers=1)
    analytic_once = forward_flops(one_layer, B, S)
    assert analytic_once * (1 - tol) <= xla_flops <= analytic * (1 + tol), (
        f"xla={xla_flops:.3g} expected in "
        f"[{analytic_once:.3g}·(1-{tol}), {analytic:.3g}·(1+{tol})]")
