"""apply_moe_sharded must match apply_moe numerically (both modes),
and sequence parallelism must not change model outputs.
Subprocess tests: need >1 host device."""
import os
import subprocess
import sys
import textwrap

import pytest

from conftest import subprocess_env

ENV = subprocess_env()
ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_MOE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro import compat
    from repro.models.config import ModelConfig
    from repro.models import moe as moe_lib
    from repro.models.layers import template_init

    mesh = compat.make_mesh((2, 4), ("data", "model"))

    def check(E, K, label):
        cfg = ModelConfig(name="t", family="moe", num_layers=1, d_model=64,
                          num_heads=4, num_kv_heads=2, d_ff=96, vocab_size=64,
                          num_experts=E, experts_per_token=K,
                          moe_capacity_factor=8.0)
        tpl = moe_lib.moe_template(cfg)
        p = template_init(tpl, jax.random.PRNGKey(0), jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 64))

        y_ref, aux_ref = jax.jit(
            lambda p, x: moe_lib.apply_moe(p, x, cfg))(p, x)
        with compat.set_mesh(mesh):
            y_sh, aux_sh = jax.jit(
                lambda p, x: moe_lib.apply_moe_sharded(
                    p, x, cfg, mesh, ("data",)))(p, x)
        np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_sh),
                                   rtol=2e-4, atol=2e-4)
        # aux is the per-shard load-balance loss (Jensen gap vs global)
        np.testing.assert_allclose(float(aux_ref), float(aux_sh), rtol=0.1)
        print(label, "OK")

    check(E=8, K=2, label="expert_parallel")   # 8 % 4 == 0 → EP mode
    check(E=2, K=1, label="tp_mode")           # 2 < 4 → TP mode
""")


_SP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro import compat
    from repro.configs import get_config
    from repro.models.config import smoke_variant
    from repro.models.transformer import build_model
    import dataclasses

    mesh = compat.make_mesh((2, 4), ("data", "model"))
    cfg = smoke_variant(get_config("tinyllama-1.1b"))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                cfg.vocab_size)

    plain = build_model(cfg)                 # no mesh → no constraints
    params = plain.init(jax.random.PRNGKey(0))
    logits_ref, _ = jax.jit(plain.forward)(params, tokens)

    sp = build_model(cfg, mesh=mesh)         # seq-parallel constraints on
    with compat.set_mesh(mesh):
        logits_sp, _ = jax.jit(sp.forward)(params, tokens)
    np.testing.assert_allclose(np.asarray(logits_ref),
                               np.asarray(logits_sp), rtol=2e-4, atol=2e-4)
    print("SEQPAR OK")
""")


@pytest.mark.slow
def test_sharded_moe_matches_reference():
    r = subprocess.run([sys.executable, "-c", _MOE_SCRIPT],
                       capture_output=True, text=True, timeout=420,
                       env=ENV, cwd=ROOT)
    assert "expert_parallel OK" in r.stdout, r.stdout + r.stderr
    assert "tp_mode OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_sequence_parallel_preserves_outputs():
    r = subprocess.run([sys.executable, "-c", _SP_SCRIPT],
                       capture_output=True, text=True, timeout=420,
                       env=ENV, cwd=ROOT)
    assert "SEQPAR OK" in r.stdout, r.stdout + r.stderr
