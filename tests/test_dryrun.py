"""Dry-run integration tests (subprocess: needs 512 fake devices)."""
import json
import os
import subprocess
import sys

import pytest

from conftest import subprocess_env

ENV = subprocess_env()


def _run(args, timeout=560):
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args,
         "--out", "/tmp/dryrun_test_artifacts"],
        capture_output=True, text=True, timeout=timeout, env=ENV,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def _parse(stdout):
    i = stdout.index("{")
    return json.loads(stdout[i:])


@pytest.mark.slow
def test_dryrun_small_arch_decode():
    r = _run(["--arch", "qwen2-1.5b", "--shape", "decode_32k"])
    assert r.returncode == 0, r.stdout + r.stderr
    rec = _parse(r.stdout)
    assert rec["status"] == "ok"
    assert rec["chips"] == 256
    assert rec["roofline"]["memory_s"] > 0
    assert rec["dominant"] in ("compute_s", "memory_s", "collective_s")


@pytest.mark.slow
def test_dryrun_skip_reason_recorded():
    r = _run(["--arch", "llama3-8b", "--shape", "long_500k"])
    assert r.returncode == 0
    rec = _parse(r.stdout)
    assert rec["status"] == "skip"
    assert "sub-quadratic" in rec["reason"]


@pytest.mark.slow
def test_dryrun_multipod_mesh():
    r = _run(["--arch", "tinyllama-1.1b", "--shape", "decode_32k",
              "--multi-pod"])
    assert r.returncode == 0, r.stdout + r.stderr
    rec = _parse(r.stdout)
    assert rec["status"] == "ok"
    assert rec["chips"] == 512
    assert rec["mesh"] == "2x16x16"
