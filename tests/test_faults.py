"""Fault-injection harness + degraded-mode hardening (ISSUE 9).

Unit/regression legs of the survived-vs-detected contract: plan
determinism, seam firing semantics, retry/backoff typing, the
collective watchdog, generation fallback in both the flat checkpointer
and the streaming service, quarantine at submit(), and the doomed-wait
detectors. The end-to-end sweep lives in ``make test-chaos``
(repro.faults.chaos + the 2-process leg in test_multihost.py).
"""
import json
import os
import tempfile
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import faults
from repro.ckpt import checkpoint as ckpt
from repro.core import MRSVMConfig, SVMConfig, fit_mapreduce
from repro.serving import StreamingSVMService


def _sep_data(seed, n, d=16, w_key=9):
    w = jax.random.normal(jax.random.PRNGKey(w_key), (d,))
    X = jax.random.normal(jax.random.PRNGKey(seed), (n, d))
    return X, jnp.sign(X @ w)


@pytest.fixture(scope="module")
def svc_cfg():
    return MRSVMConfig(sv_capacity=64, gamma=1e-4, max_rounds=3,
                       svm=SVMConfig(C=1.0, max_epochs=15))


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"w": rng.normal(size=(8, 4)).astype(np.float32),
            "b": rng.normal(size=(4,)).astype(np.float32),
            "ids": rng.integers(0, 100, size=(8,)).astype(np.int32)}


# ---------------------------------------------------------------------------
# plan: determinism + seam semantics
# ---------------------------------------------------------------------------

def test_plan_single_is_deterministic():
    for kind in sorted(faults.KINDS):
        a = faults.FaultPlan.single(kind, seed=7)
        b = faults.FaultPlan.single(kind, seed=7)
        assert a == b
        np.testing.assert_array_equal(a.rng("salt").integers(0, 99, 16),
                                      b.rng("salt").integers(0, 99, 16))
    # different seeds draw different schedules somewhere in the sweep
    whens = {faults.FaultPlan.single("ring_garble", seed=s).specs[0].when
             for s in range(16)}
    assert len(whens) > 1


def test_fire_consumes_counts_and_matches_when():
    plan = faults.FaultPlan(
        seed=0, specs=(faults.FaultSpec("transport_exc", when=2, count=2),))
    with faults.inject(plan) as armed:
        assert faults.fire("s", ("transport_exc",), when=1) is None
        assert faults.fire("s", ("transport_exc",), when=2) is not None
        assert faults.fire("s", ("transport_exc",), when=2) is not None
        assert faults.fire("s", ("transport_exc",), when=2) is None
        assert len(armed.fired) == 2 and armed.remaining == [0]
    # disarmed: the seam is free
    assert faults.fire("s", ("transport_exc",), when=2) is None


def test_maybe_raise_error_typing():
    cases = [("ckpt_write_fail", faults.InjectedWriteError),
             ("transport_exc", faults.TransientFault),
             ("handshake_flake", faults.TransientFault),
             ("scheduler_kill", faults.InjectedFault)]
    for kind, exc_type in cases:
        plan = faults.FaultPlan(seed=0, specs=(faults.FaultSpec(kind),))
        with faults.inject(plan):
            with pytest.raises(exc_type):
                faults.maybe_raise("s", kinds=(kind,))
    assert issubclass(faults.InjectedWriteError, OSError)


def test_unknown_kind_rejected():
    with pytest.raises(ValueError, match="unknown fault kind"):
        faults.FaultSpec("cosmic_ray")


# ---------------------------------------------------------------------------
# retry with backoff
# ---------------------------------------------------------------------------

def test_retry_absorbs_transient_failures():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise OSError("transient")
        return "ok"

    seen = []
    before = faults.counters().get("retries", 0)
    out = faults.retry_with_backoff(flaky, attempts=4, base_s=0.001,
                                    retry_on=OSError,
                                    on_retry=lambda i, e: seen.append(i))
    assert out == "ok" and calls["n"] == 3 and seen == [0, 1]
    assert faults.counters()["retries"] == before + 2


def test_retry_exhaustion_raises_typed_and_chained():
    def doomed():
        raise OSError("disk on fire")

    with pytest.raises(faults.FaultDetected) as ei:
        faults.retry_with_backoff(doomed, attempts=2, base_s=0.001,
                                  retry_on=OSError, layer="ckpt",
                                  cause="snapshot write", action="fix disk")
    assert ei.value.layer == "ckpt"
    assert "2 attempts" in str(ei.value)
    assert isinstance(ei.value.__cause__, OSError)


def test_retry_does_not_swallow_foreign_errors():
    def buggy():
        raise ValueError("a validation error is not a flaky wire")

    with pytest.raises(ValueError):
        faults.retry_with_backoff(buggy, attempts=5, base_s=0.001,
                                  retry_on=OSError)


# ---------------------------------------------------------------------------
# collective watchdog
# ---------------------------------------------------------------------------

def test_watchdog_fires_writes_heartbeat_and_check_raises(tmp_path):
    hb = str(tmp_path / "hb.json")
    fired = []
    with faults.CollectiveWatchdog(0.15, heartbeat_path=hb,
                                   layer="serving", cause="test fold",
                                   on_timeout=fired.append) as wd:
        time.sleep(0.5)                      # strand: no beat
    assert wd.fired and fired and fired[0]["layer"] == "serving"
    with open(hb) as f:
        payload = json.load(f)
    assert payload["status"] == "timeout"
    with pytest.raises(faults.FaultDetected, match="test fold"):
        wd.check()


def test_watchdog_beats_keep_it_quiet(tmp_path):
    hb = str(tmp_path / "hb.json")
    with faults.CollectiveWatchdog(0.25, heartbeat_path=hb,
                                   on_timeout=lambda info: None) as wd:
        for _ in range(5):
            time.sleep(0.1)
            wd.beat()                        # progress inside the deadline
    assert not wd.fired
    wd.check()                               # no raise
    with open(hb) as f:
        assert json.load(f)["status"] == "alive"


def test_watchdog_rejects_nonpositive_deadline():
    with pytest.raises(ValueError):
        faults.CollectiveWatchdog(0.0)


# ---------------------------------------------------------------------------
# host readback detection
# ---------------------------------------------------------------------------

def test_check_finite_risks_arms():
    faults.check_finite_risks(np.ones((3, 4)))          # silent
    with pytest.raises(faults.FaultDetected) as ei:
        faults.check_finite_risks(np.array([1.0, np.inf]))
    assert ei.value.layer == "transport"                # wire checksum
    with pytest.raises(faults.FaultDetected) as ei:
        faults.check_finite_risks(np.array([1.0, np.nan]))
    assert ei.value.layer == "core"                     # poisoned rows
    # masked-out lanes don't count (parked sweep configs hold junk)
    faults.check_finite_risks(np.array([1.0, np.inf]),
                              mask=np.array([True, False]))


# ---------------------------------------------------------------------------
# checkpoint: durability, generations, fallback
# ---------------------------------------------------------------------------

def test_atomic_write_json_retries_injected_write_failures(tmp_path):
    path = str(tmp_path / "meta.json")
    plan = faults.FaultPlan(
        seed=0, specs=(faults.FaultSpec("ckpt_write_fail", count=2),))
    with faults.inject(plan) as armed:
        ckpt.atomic_write_json(path, {"ok": 1})
        assert armed.remaining == [0]        # both injected failures fired
    with open(path) as f:
        assert json.load(f) == {"ok": 1}
    # exhaustion is typed: more failures than attempts
    plan = faults.FaultPlan(
        seed=0, specs=(faults.FaultSpec("ckpt_write_fail", count=5),))
    with faults.inject(plan):
        with pytest.raises(faults.FaultDetected) as ei:
            ckpt.atomic_write_json(str(tmp_path / "m2.json"), {})
    assert ei.value.layer == "ckpt"


def test_generations_prune_and_gc(tmp_path):
    d = str(tmp_path)
    for t in range(5):
        ckpt.save(os.path.join(d, f"s_{t}.npz"), _tree(t), step=t, keep=3)
    meta = json.load(open(os.path.join(d, "ckpt_meta.json")))
    assert [g["step"] for g in meta["generations"]] == [2, 3, 4]
    assert meta["latest_step"] == 4                     # flat compat pointer
    kept = sorted(f for f in os.listdir(d) if f.endswith(".npz"))
    assert kept == ["s_2.npz", "s_3.npz", "s_4.npz"]    # older media GC'd
    assert ckpt.latest_step(d) == 4
    assert ckpt.latest_path(d).endswith("s_4.npz")


def test_latest_path_falls_back_past_corrupt_generations(tmp_path):
    d = str(tmp_path)
    for t in range(3):
        ckpt.save(os.path.join(d, f"s_{t}.npz"), _tree(t), step=t)

    def flip(path):
        with open(path, "r+b") as f:
            f.seek(os.path.getsize(path) // 2)
            b = f.read(1)
            f.seek(-1, 1)
            f.write(bytes([b[0] ^ 0x10]))

    before = faults.counters().get("ckpt_fallbacks", 0)
    flip(os.path.join(d, "s_2.npz"))
    assert ckpt.latest_step(d) == 1                     # skipped newest
    assert ckpt.latest_path(d).endswith("s_1.npz")
    assert faults.counters()["ckpt_fallbacks"] > before
    os.remove(os.path.join(d, "s_1.npz"))               # missing ≡ corrupt
    assert ckpt.latest_step(d) == 0
    flip(os.path.join(d, "s_0.npz"))
    assert ckpt.latest_step(d) is None                  # nothing intact left
    assert ckpt.latest_path(d) is None


def test_restore_verifies_leaf_checksums(tmp_path):
    path = str(tmp_path / "t.npz")
    tree = _tree(1)
    ckpt.save(path, tree)
    sums = ckpt.leaf_checksums(tree)
    out = ckpt.restore(path, tree, checksums=sums)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(out[k]), tree[k])
    # same container, different payload, ORIGINAL checksums → detected
    evil = dict(tree, w=tree["w"] + 1)
    ckpt.save(path, evil)
    with pytest.raises(ckpt.CorruptCheckpointError, match="checksum"):
        ckpt.restore(path, tree, checksums=sums)


def test_ckpt_media_corruption_seam_breaks_the_crc(tmp_path):
    """The injected corruption lands AFTER the crc is recorded — so the
    generation it produced is exactly the kind restore must skip."""
    d = str(tmp_path)
    ckpt.save(os.path.join(d, "s_0.npz"), _tree(0), step=0)
    plan = faults.FaultPlan(seed=3,
                            specs=(faults.FaultSpec("ckpt_corrupt",
                                                    param=2),))
    with faults.inject(plan):
        ckpt.save(os.path.join(d, "s_1.npz"), _tree(1), step=1)
    assert ckpt.latest_step(d) == 0          # corrupt gen 1 skipped


# ---------------------------------------------------------------------------
# property: a snapshot restores bit-exact or raises — never silently wrong
# ---------------------------------------------------------------------------

def _corrupt_roundtrip_case(seed: int, frac: float, bit: int) -> None:
    tree = _tree(seed)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "t.npz")
        ckpt.save(path, tree)
        sums = ckpt.leaf_checksums(tree)
        size = os.path.getsize(path)
        off = min(int(frac * size), size - 1)
        with open(path, "r+b") as f:
            f.seek(off)
            b = f.read(1)
            f.seek(off)
            f.write(bytes([b[0] ^ (1 << bit)]))
        try:
            out = ckpt.restore(path, tree, checksums=sums)
        except Exception:
            return          # detected: container or leaf refused to load
        for k in tree:      # …or the flip missed every stored payload bit
            np.testing.assert_array_equal(np.asarray(out[k]), tree[k])


try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    # the container has no hypothesis (requirements-dev.txt): run the
    # same property over a seeded sample so the invariant stays tested
    def test_single_byte_corruption_never_restores_silently():
        rng = np.random.default_rng(2026)
        for _ in range(30):
            _corrupt_roundtrip_case(int(rng.integers(0, 2 ** 16)),
                                    float(rng.uniform()),
                                    int(rng.integers(0, 8)))
else:
    @given(seed=st.integers(0, 2 ** 16), frac=st.floats(0.0, 1.0),
           bit=st.integers(0, 7))
    @settings(max_examples=30, deadline=None)
    def test_single_byte_corruption_never_restores_silently(seed, frac, bit):
        _corrupt_roundtrip_case(seed, frac, bit)


# ---------------------------------------------------------------------------
# serving: quarantine, doomed waits, generation fallback
# ---------------------------------------------------------------------------

def test_quarantine_diverts_nonfinite_batches(svc_cfg):
    X0, y0 = _sep_data(0, 128)
    svc = StreamingSVMService(svc_cfg, num_partitions=4)
    svc.register("t", fit_mapreduce(X0, y0, 4, svc_cfg))
    Xp = np.array(_sep_data(1, 64)[0])
    Xp[3, 2] = np.nan
    uid = svc.submit("t", jnp.asarray(Xp), _sep_data(1, 64)[1])
    assert uid > 0 and svc.pending() == 0    # acknowledged but diverted
    assert len(svc.quarantined) == 1
    assert svc.run_wave() is None            # nothing poisoned to fold
    assert svc.snapshot("t").version == 0
    assert svc.throughput_report()["quarantined"] == 1
    # opt-out: a service folding raw firehose data can accept them
    svc2 = StreamingSVMService(svc_cfg, num_partitions=4, quarantine=False)
    svc2.register("t", fit_mapreduce(X0, y0, 4, svc_cfg))
    svc2.submit("t", jnp.asarray(Xp), _sep_data(1, 64)[1])
    assert svc2.pending() == 1


def test_injected_poison_rows_are_quarantined(svc_cfg):
    X0, y0 = _sep_data(0, 128)
    svc = StreamingSVMService(svc_cfg, num_partitions=4)
    svc.register("t", fit_mapreduce(X0, y0, 4, svc_cfg))
    Xc, yc = _sep_data(2, 64)
    plan = faults.FaultPlan.single("poison_rows", seed=5)
    with faults.inject(plan) as armed:
        svc.submit("t", Xc, yc)
        assert armed.fired                   # the seam poisoned the batch
    assert len(svc.quarantined) == 1 and svc.pending() == 0


def test_wait_idle_surfaces_doomed_states(svc_cfg):
    X0, y0 = _sep_data(0, 128)
    svc = StreamingSVMService(svc_cfg, num_partitions=4)
    svc.register("t", fit_mapreduce(X0, y0, 4, svc_cfg))
    svc.submit("t", *_sep_data(1, 64))
    # queued work, no scheduler: raise now, don't burn the timeout
    with pytest.raises(RuntimeError, match="no scheduler is running"):
        svc.wait_idle(timeout_s=30.0)
    # a scheduler killed mid-wave records its error; doomed waits and
    # later submits surface it instead of queueing forever
    with faults.inject(faults.FaultPlan.single("scheduler_kill", seed=1)):
        svc.start(idle_poll_s=0.01)
        with pytest.raises(RuntimeError, match="scheduler died"):
            svc.wait_idle(timeout_s=30.0)
    with pytest.raises(RuntimeError, match="scheduler died"):
        svc.submit("t", *_sep_data(3, 64))
    with pytest.raises(RuntimeError, match="scheduler died"):
        svc.stop()
    assert svc.pending() >= 1                # the wave was requeued intact


def test_stall_watchdog_detects_stuck_fold(svc_cfg):
    X0, y0 = _sep_data(0, 128)
    fires = []
    svc = StreamingSVMService(svc_cfg, num_partitions=4,
                              fold_deadline_s=0.2,
                              watchdog_handler=fires.append)
    svc.register("t", fit_mapreduce(X0, y0, 4, svc_cfg))
    svc.submit("t", *_sep_data(1, 64))
    with faults.inject(faults.FaultPlan.single("stall", seed=0)):
        with pytest.raises(faults.FaultDetected, match="fold"):
            svc.run_wave()
    assert fires and svc.throughput_report()["watchdog_fires"] == 1
    # the fold itself finished and PUBLISHED before the deadline check
    # raised — exactly-once keeps the batch completed, not requeued
    assert svc.pending() == 0
    assert svc.snapshot("t").version == 1
    # healthy folds pass under the same watchdog
    svc.submit("t", *_sep_data(2, 64))
    assert svc.run_wave() is not None
    assert svc.snapshot("t").version == 2


def test_service_restore_falls_back_past_corrupt_generation(
        svc_cfg, tmp_path):
    d = str(tmp_path / "ck")
    X0, y0 = _sep_data(0, 128)
    svc = StreamingSVMService(svc_cfg, num_partitions=4, checkpoint_dir=d,
                              checkpoint_every_waves=1)
    svc.register("t", fit_mapreduce(X0, y0, 4, svc_cfg))   # generation 0
    svc.submit("t", *_sep_data(1, 64))
    assert svc.run_wave() is not None                      # generation 1
    man = json.load(open(os.path.join(d, "service_manifest.json")))
    assert man["format"] == 2 and len(man["generations"]) == 2
    newest = man["generations"][-1]["streams"]["t"]["file"]
    with open(os.path.join(d, newest), "r+b") as f:
        f.seek(os.path.getsize(os.path.join(d, newest)) // 2)
        b = f.read(1)
        f.seek(-1, 1)
        f.write(bytes([b[0] ^ 0x20]))
    r = StreamingSVMService.restore(svc_cfg, d)
    assert r.restore_fallbacks == 1
    assert r.snapshot("t").version == 0      # the intact generation 0
    # every generation corrupt → typed, named, actionable
    for fn in os.listdir(d):
        if fn.endswith(".npz"):
            with open(os.path.join(d, fn), "r+b") as f:
                f.truncate(8)
    with pytest.raises(faults.FaultDetected) as ei:
        StreamingSVMService.restore(svc_cfg, d)
    assert ei.value.layer == "ckpt"
    assert "no intact snapshot generation" in str(ei.value)


def test_stop_detects_refused_to_die_thread(svc_cfg):
    X0, y0 = _sep_data(0, 128)
    svc = StreamingSVMService(svc_cfg, num_partitions=4)
    svc.register("t", fit_mapreduce(X0, y0, 4, svc_cfg))
    release = threading.Event()
    svc._thread = threading.Thread(target=release.wait, daemon=True)
    svc._thread.start()                      # a "stranded" scheduler stub
    try:
        with pytest.raises(faults.FaultDetected, match="refused to die"):
            svc.stop(timeout_s=0.2)
    finally:
        release.set()
        svc._thread.join(timeout=5)
        svc._thread = None
