"""Fault-tolerant elastic serving (ISSUE 7): checkpointed SV state,
restore-then-fold equivalence, mid-wave recovery, admission control,
and the sparse fold path of the streaming service."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sparse
from repro.core import (MRSVMConfig, SVMConfig, decision_values,
                        fit_mapreduce, restore_sweep_state,
                        save_sweep_state, update_mapreduce)
from repro.serving import StreamingSVMService
from repro.serving.svm_stream import _MANIFEST  # noqa: F401  (layout)


def _sep_data(seed, n, d=16, w_key=9):
    w = jax.random.normal(jax.random.PRNGKey(w_key), (d,))
    X = jax.random.normal(jax.random.PRNGKey(seed), (n, d))
    return X, jnp.sign(X @ w)


def _sparse_data(seed, n, d=16, cap=8, w_key=9):
    X, y = _sep_data(seed, n, d, w_key)
    # svm-test rows are dense; zero all but the top-cap magnitudes per
    # row so from_dense at nnz_cap=cap is lossless
    keep = jnp.argsort(-jnp.abs(X), axis=1)[:, :cap]
    m = jnp.zeros_like(X).at[jnp.arange(n)[:, None], keep].set(1.0)
    Xs = X * m
    return sparse.from_dense(Xs, cap), jnp.sign(Xs @ jax.random.normal(
        jax.random.PRNGKey(w_key), (d,)))


@pytest.fixture(scope="module")
def cfg():
    return MRSVMConfig(sv_capacity=64, gamma=1e-4, max_rounds=3,
                       svm=SVMConfig(C=1.0, max_epochs=15))


@pytest.fixture(scope="module")
def sparse_cfg():
    return MRSVMConfig(sv_capacity=64, gamma=1e-4, max_rounds=3,
                       svm=SVMConfig(C=1.0, max_epochs=15,
                                     row_format="sparse_csr", nnz_cap=8))


def _tree_equal(a, b):
    fa = jax.tree_util.tree_leaves(a)
    fb = jax.tree_util.tree_leaves(b)
    assert len(fa) == len(fb)
    for la, lb in zip(fa, fb):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------------------------
# ModelSnapshot checkpoint round-trips
# ---------------------------------------------------------------------------

def test_snapshot_roundtrip_dense(cfg, tmp_path):
    """A checkpointed dense stream restores bit-exact: every model
    leaf, the SolverParams, the version, and the served scores."""
    X0, y0 = _sep_data(0, 256)
    params = SVMConfig(C=0.5, max_epochs=15).params()
    model = fit_mapreduce(X0, y0, 4, cfg, params=params)
    svc = StreamingSVMService(cfg, num_partitions=4,
                              checkpoint_dir=str(tmp_path))
    svc.register("t", model, params=params)

    back = StreamingSVMService.restore(cfg, str(tmp_path))
    assert back.streams() == ["t"]
    snap, orig = back.snapshot("t"), svc.snapshot("t")
    assert snap.version == orig.version == 0
    assert snap.model.rounds == orig.model.rounds
    _tree_equal(snap.model.sv, orig.model.sv)
    _tree_equal(snap.model.final, orig.model.final)
    _tree_equal(snap.params, orig.params)
    Xt, _ = _sep_data(50, 200)
    np.testing.assert_array_equal(
        np.asarray(back.decision_values("t", Xt)),
        np.asarray(svc.decision_values("t", Xt)))


def test_snapshot_roundtrip_sparse_and_bf16(sparse_cfg, tmp_path):
    """Blocked-CSR SV buffers and bf16 feature rows (the wire dtype)
    both survive the flat-npz round trip exactly."""
    Xs, ys = _sparse_data(1, 256)
    model = fit_mapreduce(Xs, ys, 4, sparse_cfg)
    assert sparse.is_sparse(model.sv.x)
    # a bf16 second stream exercises the u16-view leaf path
    bf_model = model._replace(
        sv=model.sv._replace(x=model.sv.x.astype(jnp.bfloat16)))
    svc = StreamingSVMService(sparse_cfg, num_partitions=4,
                              checkpoint_dir=str(tmp_path))
    svc.register("sp", model)
    svc.register("bf", bf_model)

    back = StreamingSVMService.restore(sparse_cfg, str(tmp_path))
    for name in ("sp", "bf"):
        got, want = back.snapshot(name).model, svc.snapshot(name).model
        assert sparse.is_sparse(got.sv.x)
        assert got.sv.x.nnz_cap == want.sv.x.nnz_cap
        assert got.sv.x.values.dtype == want.sv.x.values.dtype
        _tree_equal(got.sv, want.sv)
        _tree_equal(got.final, want.final)
    Xt, _ = _sparse_data(51, 128)
    np.testing.assert_array_equal(
        np.asarray(back.decision_values("sp", Xt)),
        np.asarray(svc.decision_values("sp", Xt)))


def test_restore_then_fold_matches_never_crashed(cfg, tmp_path):
    """The acceptance bar: checkpoint after wave 1, 'crash', restore,
    fold wave 2 — the result is bit-for-bit the uninterrupted run."""
    models = {s: fit_mapreduce(*_sep_data(10 + i, 192, w_key=3 + i), 4, cfg)
              for i, s in enumerate("ab")}
    wave1 = {s: _sep_data(20 + i, 128, w_key=3 + i)
             for i, s in enumerate("ab")}
    wave2 = {s: _sep_data(30 + i, 128, w_key=3 + i)
             for i, s in enumerate("ab")}

    def feed(svc, batches):
        for s, (X, y) in batches.items():
            svc.submit(s, X, y)
        st = svc.run_wave()
        assert st is not None and st.streams == 2

    control = StreamingSVMService(cfg, num_partitions=4)
    crashed = StreamingSVMService(cfg, num_partitions=4,
                                  checkpoint_dir=str(tmp_path))
    for s in "ab":
        control.register(s, models[s])
        crashed.register(s, models[s])
    feed(control, wave1)
    feed(crashed, wave1)          # checkpoints after the wave

    resumed = StreamingSVMService.restore(cfg, str(tmp_path))
    assert resumed.snapshot("a").version == 1
    feed(control, wave2)
    feed(resumed, wave2)

    Xt, _ = _sep_data(60, 256)
    for s in "ab":
        assert resumed.snapshot(s).version == control.snapshot(s).version
        _tree_equal(resumed.snapshot(s).model.sv,
                    control.snapshot(s).model.sv)
        np.testing.assert_array_equal(
            np.asarray(resumed.decision_values(s, Xt)),
            np.asarray(control.decision_values(s, Xt)))


def test_restore_requires_manifest_and_matching_capacity(cfg, tmp_path):
    with pytest.raises(FileNotFoundError, match="manifest"):
        StreamingSVMService.restore(cfg, str(tmp_path / "nope"))
    svc = StreamingSVMService(cfg, num_partitions=4,
                              checkpoint_dir=str(tmp_path))
    svc.register("t", fit_mapreduce(*_sep_data(0, 128), 4, cfg))
    import dataclasses as dc
    other = dc.replace(cfg, sv_capacity=32)
    with pytest.raises(ValueError, match="sv_capacity"):
        StreamingSVMService.restore(other, str(tmp_path))


# ---------------------------------------------------------------------------
# sweep round-state (dedup ring) ser/de
# ---------------------------------------------------------------------------

def test_sweep_state_roundtrip_dedup_bf16_wire(tmp_path):
    """The dedup ring's shared-row DedupChunk state — bf16 wire rows,
    int32 ids/ptr, f32 sidebands — round-trips exactly; shape or wire
    dtype drift at restore raises instead of resuming a wrong sweep."""
    ring = MRSVMConfig(sv_capacity=32, svm=SVMConfig(),
                       shuffle_impl="ring", shuffle_wire_dtype="bfloat16")
    from repro.core.sweep import init_sharded_sweep_sv, uses_dedup_state
    assert uses_dedup_state(ring, False)
    state = init_sharded_sweep_sv(ring, 3, 16, 4, 8)
    # fill with distinguishable values (leaf-wise ramps in each dtype)
    state = jax.tree_util.tree_map(
        lambda a: (jnp.arange(a.size).reshape(a.shape) % 7).astype(a.dtype),
        state)
    path = str(tmp_path / "sweep_0.npz")
    save_sweep_state(path, state, step=0)
    back = restore_sweep_state(path, ring, 3, 16, 4, 8)
    _tree_equal(back, state)

    with pytest.raises(ValueError, match="shape mismatch"):
        restore_sweep_state(path, ring, 2, 16, 4, 8)     # width drift
    import dataclasses as dc
    f32_ring = dc.replace(ring, shuffle_wire_dtype="float32")
    with pytest.raises(ValueError, match="dtype mismatch"):
        restore_sweep_state(path, f32_ring, 3, 16, 4, 8)  # wire drift


# ---------------------------------------------------------------------------
# mid-wave recovery: exactly-once at the model level
# ---------------------------------------------------------------------------

def test_mid_wave_failure_requeues_all_unswapped(cfg, monkeypatch):
    """A fold that dies before ANY swap puts every admitted batch back
    at the head of its queue; the retry folds them exactly once."""
    svc = StreamingSVMService(cfg, num_partitions=4)
    for i, s in enumerate("ab"):
        svc.register(s, fit_mapreduce(*_sep_data(10 + i, 192), 4, cfg))
        svc.submit(s, *_sep_data(20 + i, 96))
    assert svc.pending() == 2

    import repro.serving.svm_stream as mod
    def boom(*a, **k):
        raise RuntimeError("worker lost mid-wave")
    monkeypatch.setattr(mod, "fit_mapreduce_sweep", boom)
    with pytest.raises(RuntimeError, match="worker lost"):
        svc.run_wave()
    assert svc.pending() == 2                    # requeued, rows pinned
    for s in "ab":
        assert svc.snapshot(s).version == 0
        assert svc._queues[s][0].X is not None
    monkeypatch.undo()

    st = svc.run_wave()                          # surviving-mesh retry
    assert st.streams == 2 and st.batches == 2
    assert svc.pending() == 0 and len(svc.done) == 2
    assert all(svc.snapshot(s).version == 1 for s in "ab")
    assert svc.throughput_report()["requeued"] == 2


def test_mid_wave_failure_completes_swapped_streams(cfg, monkeypatch):
    """Partial wave: streams that already swapped are done (their fold
    is published); only the un-swapped stream's batches requeue."""
    import dataclasses as dc
    svc = StreamingSVMService(cfg, num_partitions=4)
    # different feature dims → two singleton fold groups, d=16 first
    svc.register("lo", fit_mapreduce(*_sep_data(1, 192, d=16), 4, cfg))
    svc.register("hi", fit_mapreduce(*_sep_data(2, 192, d=24), 4, cfg))
    svc.submit("lo", *_sep_data(21, 96, d=16))
    svc.submit("hi", *_sep_data(22, 96, d=24))

    import repro.serving.svm_stream as mod
    real = mod.update_mapreduce
    def die_on_hi(model, *a, **k):
        if model.sv.x.shape[1] == 24:
            raise RuntimeError("worker lost mid-wave")
        return real(model, *a, **k)
    monkeypatch.setattr(mod, "update_mapreduce", die_on_hi)
    with pytest.raises(RuntimeError, match="worker lost"):
        svc.run_wave()
    assert svc.snapshot("lo").version == 1       # published before loss
    assert svc.snapshot("hi").version == 0
    assert svc.pending() == 1 and len(svc.done) == 1
    monkeypatch.undo()
    st = svc.run_wave()
    assert st.streams == 1 and svc.snapshot("hi").version == 1


def test_submit_after_scheduler_death_raises():
    """Doomed work is refused: once the background scheduler has died,
    submit surfaces the error instead of growing queues forever."""
    bad_cfg = MRSVMConfig(sv_capacity=36, max_rounds=2,
                          svm=SVMConfig(C=1.0, max_epochs=5))
    X0, y0 = _sep_data(9, 128)
    svc = StreamingSVMService(bad_cfg, num_partitions=8)
    svc.register("t", fit_mapreduce(X0, y0, 4, bad_cfg))
    svc.start(idle_poll_s=0.005)
    svc.submit("t", X0, y0)
    with pytest.raises(RuntimeError, match="scheduler died"):
        svc.wait_idle(timeout_s=60)
    with pytest.raises(RuntimeError, match="scheduler died"):
        svc.submit("t", X0, y0)


# ---------------------------------------------------------------------------
# sparse tenants stream end to end (the PR 6 format bugfix)
# ---------------------------------------------------------------------------

def test_sparse_tenant_streams_end_to_end(sparse_cfg):
    """A blocked-CSR tenant submits, folds (single and batched wave),
    and serves — matching update_mapreduce exactly on the single-stream
    path and at solver tolerance on the batched one."""
    Xs0, ys0 = _sparse_data(3, 256)
    m0 = fit_mapreduce(Xs0, ys0, 4, sparse_cfg)
    svc = StreamingSVMService(sparse_cfg, num_partitions=4)
    svc.register("sp", m0)

    Xn, yn = _sparse_data(13, 96)
    svc.submit("sp", Xn, yn)
    st = svc.run_wave()
    assert st is not None and not st.batched
    ref = update_mapreduce(m0, Xn, yn, 4, sparse_cfg)
    Xt, _ = _sparse_data(53, 128)
    np.testing.assert_array_equal(
        np.asarray(svc.decision_values("sp", Xt)),
        np.asarray(decision_values(ref, Xt, sparse_cfg)))
    assert sparse.is_sparse(svc.snapshot("sp").model.sv.x)

    # second sparse tenant → the wave rides the batched sweep fold
    Xs1, ys1 = _sparse_data(4, 256, w_key=5)
    m1 = fit_mapreduce(Xs1, ys1, 4, sparse_cfg)
    svc.register("sp2", m1)
    new = {"sp": _sparse_data(14, 96), "sp2": _sparse_data(15, 96, w_key=5)}
    base = {s: svc.snapshot(s).model for s in new}
    for s, (X, y) in new.items():
        svc.submit(s, X, y)
    st = svc.run_wave()
    assert st.batched and st.streams == 2
    for s, (X, y) in new.items():
        ref = update_mapreduce(base[s], X, y, 4, sparse_cfg)
        np.testing.assert_allclose(
            np.asarray(svc.decision_values(s, Xt)),
            np.asarray(decision_values(ref, Xt, sparse_cfg)),
            rtol=1e-4, atol=1e-4)


def test_mixed_format_wave_folds_by_group(cfg, sparse_cfg):
    """Sparse and dense tenants admitted in ONE wave fold group-wise
    instead of failing on the stack."""
    svc = StreamingSVMService(sparse_cfg, num_partitions=4)
    Xs, ys = _sparse_data(6, 192)
    Xd, yd = _sep_data(7, 192)
    svc.register("sp", fit_mapreduce(Xs, ys, 4, sparse_cfg))
    svc.register("de", fit_mapreduce(Xd, yd, 4, cfg))
    svc.submit("sp", *_sparse_data(16, 96))
    svc.submit("de", *_sep_data(17, 96))
    st = svc.run_wave()
    assert st is not None and st.streams == 2
    assert svc.snapshot("sp").version == 1
    assert svc.snapshot("de").version == 1
    with pytest.raises(ValueError, match="row format"):
        svc.submit("de", *_sparse_data(18, 32))


# ---------------------------------------------------------------------------
# elasticity + admission control
# ---------------------------------------------------------------------------

def test_bucket_padding_keeps_results_correct(cfg):
    """An odd tenant count folds at the next power-of-two job width;
    padded mask-zero jobs must not perturb the real tenants."""
    svc = StreamingSVMService(cfg, num_partitions=4)
    assert [svc._bucket_width(n) for n in (1, 2, 3, 5, 8)] == [1, 2, 4, 8, 8]
    models, new = {}, {}
    for i, s in enumerate("abc"):
        models[s] = fit_mapreduce(*_sep_data(10 + i, 192, w_key=3 + i),
                                  4, cfg)
        svc.register(s, models[s])
        new[s] = _sep_data(20 + i, 96, w_key=3 + i)
        svc.submit(s, *new[s])
    st = svc.run_wave()
    assert st.batched and st.streams == 3
    Xt, _ = _sep_data(60, 256)
    for s in "abc":
        ref = update_mapreduce(models[s], *new[s], 4, cfg)
        np.testing.assert_allclose(
            np.asarray(svc.decision_values(s, Xt)),
            np.asarray(decision_values(ref, Xt, cfg)),
            rtol=1e-4, atol=1e-4)


def test_queue_cap_sheds_oldest_or_rejects(cfg):
    X, y = _sep_data(0, 256)
    m = fit_mapreduce(X, y, 4, cfg)
    svc = StreamingSVMService(cfg, num_partitions=4,
                              max_queue_per_stream=2)
    svc.register("t", m)
    uids = [svc.submit("t", *_sep_data(i + 1, 32)) for i in range(3)]
    assert svc.pending() == 2                    # oldest shed, not grown
    assert [mb.uid for mb in svc._queues["t"]] == uids[1:]
    assert svc.throughput_report()["shed"] == 1

    rej = StreamingSVMService(cfg, num_partitions=4,
                              max_queue_per_stream=1,
                              shed_policy="reject")
    rej.register("t", m)
    rej.submit("t", *_sep_data(4, 32))
    with pytest.raises(RuntimeError, match="admission control"):
        rej.submit("t", *_sep_data(5, 32))
    with pytest.raises(ValueError, match="shed_policy"):
        StreamingSVMService(cfg, shed_policy="drop_newest")


def test_wave_width_bound_admits_oldest_first(cfg):
    svc = StreamingSVMService(cfg, num_partitions=4,
                              max_streams_per_wave=2, slo_s=0.0)
    for i, s in enumerate("abc"):
        svc.register(s, fit_mapreduce(*_sep_data(10 + i, 192), 4, cfg))
        svc.submit(s, *_sep_data(20 + i, 64))
    st = svc.run_wave()
    assert st.streams == 2
    assert svc.snapshot("a").version == 1 and svc.snapshot("b").version == 1
    assert svc.snapshot("c").version == 0        # width-bounded out
    st2 = svc.run_wave()
    assert st2.streams == 1 and svc.snapshot("c").version == 1
    # slo_s=0 counts every completion as a violation → the counter works
    assert svc.throughput_report()["slo_violations"] == 3
