"""Pallas kernel validation: shape/dtype sweeps vs the ref.py oracles
(interpret=True executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import decode_attention, gram_matrix, risk_eval
from repro.kernels import ref

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("n,m,d", [(64, 64, 32), (130, 70, 96),
                                   (256, 256, 128), (300, 200, 260)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("kind", ["linear", "rbf", "poly"])
def test_gram_sweep(n, m, d, dtype, kind):
    k1, k2 = jax.random.split(KEY)
    X = jax.random.normal(k1, (n, d), dtype)
    Z = jax.random.normal(k2, (m, d), dtype)
    K = gram_matrix(X, Z, kind=kind, gamma=0.5, coef0=1.0, degree=2,
                    bm=128, bn=128, bk=128)
    Kr = ref.gram_ref(X.astype(jnp.float32), Z.astype(jnp.float32), kind,
                      gamma=0.5, coef0=1.0, degree=2)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(K), np.asarray(Kr),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("n,d,L", [(100, 32, 4), (512, 64, 16), (700, 48, 3)])
def test_hinge_sweep(n, d, L):
    ks = jax.random.split(KEY, 5)
    X = jax.random.normal(ks[0], (n, d))
    W = jax.random.normal(ks[1], (L, d))
    b = jax.random.normal(ks[2], (L,))
    y = jnp.sign(jax.random.normal(ks[3], (n,)))
    m = (jax.random.uniform(ks[4], (n,)) > 0.2).astype(jnp.float32)
    loss, cnt = risk_eval(X, W, b, y, m, bn=128)
    loss_r, cnt_r = ref.hinge_scores_ref(X, W, b, y, m)
    np.testing.assert_allclose(np.asarray(loss), np.asarray(loss_r),
                               rtol=1e-4, atol=1e-3)
    assert float(cnt) == pytest.approx(float(cnt_r))


@pytest.mark.parametrize("B,H,KV,S,hd", [
    (1, 4, 4, 128, 64),      # MHA
    (2, 8, 2, 256, 64),      # GQA 4:1
    (2, 16, 4, 512, 128),    # GQA + bigger blocks
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_sweep(B, H, KV, S, hd, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, KV, S, hd), dtype)
    v = jax.random.normal(ks[2], (B, KV, S, hd), dtype)
    vlen = jnp.asarray(S - S // 4)
    out = decode_attention(q, k, v, vlen, bs=64)
    outr = ref.decode_attention_ref(q.astype(jnp.float32),
                                    k.astype(jnp.float32),
                                    v.astype(jnp.float32), vlen)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(outr), rtol=tol, atol=tol)


def test_flash_decode_valid_len_zero_region_ignored():
    """Changing K/V beyond valid_len must not change the output."""
    ks = jax.random.split(KEY, 4)
    B, H, KV, S, hd = 1, 4, 4, 128, 32
    q = jax.random.normal(ks[0], (B, H, hd))
    k = jax.random.normal(ks[1], (B, KV, S, hd))
    v = jax.random.normal(ks[2], (B, KV, S, hd))
    vlen = jnp.asarray(60)
    out1 = decode_attention(q, k, v, vlen, bs=64)
    k2 = k.at[:, :, 60:, :].set(99.0)
    v2 = v.at[:, :, 60:, :].set(-99.0)
    out2 = decode_attention(q, k2, v2, vlen, bs=64)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("n,d", [(64, 32), (200, 96), (300, 128)])
def test_cd_epoch_matches_sequential_oracle(n, d):
    from repro.kernels import svm_cd_epoch
    ks = jax.random.split(KEY, 3)
    X = jax.random.normal(ks[0], (n, d))
    y = jnp.sign(jax.random.normal(ks[1], (n,)))
    mask = (jax.random.uniform(ks[2], (n,)) > 0.1).astype(jnp.float32)
    a0 = jnp.zeros((n,))
    w0 = jnp.zeros((d,))
    a, w, b = svm_cd_epoch(X, y, a0, w0, jnp.float32(0), mask, C=1.0, bn=64)
    ar, wr, br = ref.cd_epoch_ref(X, alpha=a0, w=w0, b=0.0, y=y, mask=mask)
    np.testing.assert_allclose(np.asarray(a), ar, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(w), wr, rtol=1e-4, atol=1e-4)
    assert float(b) == pytest.approx(float(br), abs=1e-4)


def test_cd_epoch_matches_solver_epoch():
    """One Pallas epoch == one fit_binary_linear epoch (max_epochs=1)."""
    from repro.core import SVMConfig, fit_binary
    from repro.kernels import svm_cd_epoch
    X = jax.random.normal(KEY, (128, 24))
    y = jnp.sign(jax.random.normal(jax.random.PRNGKey(9), (128,)))
    mask = jnp.ones((128,))
    m = fit_binary(X, y, mask, SVMConfig(C=1.0, max_epochs=1, tol=0.0))
    a, w, b = svm_cd_epoch(X, y, jnp.zeros((128,)), jnp.zeros((24,)),
                           jnp.float32(0), mask, C=1.0, bn=64)
    np.testing.assert_allclose(np.asarray(w), np.asarray(m.w),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(a), np.asarray(m.alpha),
                               rtol=1e-4, atol=1e-5)


def test_decode_step_pallas_path_matches_jnp():
    """attention_decode_step(use_pallas=True) == jnp reference path."""
    from repro.models import attention as attn_lib
    from repro.models.config import ModelConfig
    from repro.models.layers import template_init
    cfg = ModelConfig(name="t", family="dense", num_layers=1, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=64)
    p = template_init(attn_lib.attn_template(cfg), KEY, jnp.float32)
    cache = attn_lib.init_layer_cache(cfg, 2, 128, 1, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 1, 64))
    # pre-fill a few positions
    for t in range(5):
        xt = jax.random.normal(jax.random.PRNGKey(10 + t), (2, 1, 64))
        y_ref, cache = attn_lib.attention_decode_step(
            p, xt, cache, jnp.int32(t), cfg)
    y1, c1 = attn_lib.attention_decode_step(p, x, cache, jnp.int32(5), cfg)
    y2, c2 = attn_lib.attention_decode_step(p, x, cache, jnp.int32(5), cfg,
                                            use_pallas=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(c1.k), np.asarray(c2.k))
