"""Integration tests: the paper's iterative MapReduce SVM (core)."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (MRSVMConfig, SVMConfig, confusion_matrix,
                        fit_binary, fit_mapreduce, fit_one_vs_rest, predict)


def _data(n=480, d=16, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    X = jax.random.normal(k1, (n, d))
    w = jax.random.normal(k2, (d,))
    y = jnp.sign(X @ w + 0.1)
    return X, y


def test_risk_decreases_over_rounds():
    """The paper's core claim (eq. 9): augmenting partitions with the
    global SV set drives empirical risk down over rounds."""
    X, y = _data()
    cfg = MRSVMConfig(sv_capacity=64, gamma=0.0, max_rounds=6,
                      svm=SVMConfig(C=1.0, max_epochs=30))
    model = fit_mapreduce(X, y, num_partitions=8, cfg=cfg)
    risks = [h["risk"] for h in model.history]
    assert risks[-1] < risks[0]
    assert min(risks) == pytest.approx(float(model.risk), abs=1e-6)


def test_converges_close_to_single_node():
    """Distributed model ends within a few % of the undistributed SVM."""
    X, y = _data(n=600)
    single = fit_binary(X, y, cfg=SVMConfig(C=1.0, max_epochs=60))
    acc_single = float(jnp.mean(jnp.sign(X @ single.w + single.b) == y))
    cfg = MRSVMConfig(sv_capacity=128, gamma=1e-5, max_rounds=8,
                      svm=SVMConfig(C=1.0, max_epochs=30))
    mr = fit_mapreduce(X, y, num_partitions=8, cfg=cfg)
    acc_mr = float(jnp.mean(predict(mr, X, cfg) == y))
    assert acc_mr >= acc_single - 0.03


def test_eq8_stopping_rule():
    X, y = _data(n=320)
    cfg = MRSVMConfig(sv_capacity=64, gamma=1.0,   # huge γ → stop at round 2
                      max_rounds=10, svm=SVMConfig(C=1.0, max_epochs=20))
    model = fit_mapreduce(X, y, num_partitions=4, cfg=cfg)
    assert model.rounds == 2


def test_sv_buffer_is_capacity_bounded_and_masked(fast_mr_cfg):
    X, y = _data(n=320)
    cfg = fast_mr_cfg
    model = fit_mapreduce(X, y, num_partitions=4, cfg=cfg)
    assert model.sv.x.shape == (32, X.shape[1])
    assert float(jnp.sum(model.sv.mask)) <= 32
    # masked slots are zeroed
    dead = np.asarray(model.sv.mask) == 0
    if dead.any():
        assert float(jnp.max(jnp.abs(model.sv.x[dead]))) == 0.0


def test_three_class_ovr_confusion(fast_mr_cfg):
    rng = np.random.default_rng(1)
    y = rng.integers(-1, 2, size=360)
    X = jnp.asarray(rng.normal(0, 1, (360, 8)).astype(np.float32))
    X = X + 2.0 * jnp.asarray(y)[:, None]
    cfg = fast_mr_cfg
    ovr = fit_one_vs_rest(X, jnp.asarray(y), [-1, 0, 1], 4, cfg)
    pred = ovr.predict(X)
    cm = confusion_matrix(jnp.asarray(y), pred, [-1, 0, 1])
    assert cm.shape == (3, 3)
    assert abs(cm.sum() - 100.0) < 1e-3          # paper-style global %
    assert np.trace(cm) > 70.0                   # mostly diagonal


_SHARD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro import compat
    from repro.core import MRSVMConfig, SVMConfig
    from repro.core.mapreduce_svm import (build_sharded_round,
                                          init_sv_buffer, mapreduce_round)
    n, d = 512, 12
    X = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    w = jax.random.normal(jax.random.PRNGKey(1), (d,))
    y = jnp.sign(X @ w)
    mask = jnp.ones((n,))
    cfg = MRSVMConfig(sv_capacity=64, svm=SVMConfig(C=1.0, max_epochs=20))

    mesh = compat.make_mesh((8,), ("data",))
    fn = build_sharded_round(mesh, ("data",), cfg, n // 8)
    sv_s = init_sv_buffer(64, d)
    for _ in range(3):
        sv_s, risks_s, w_s, b_s = fn(X, y, mask, sv_s)

    # functional-mode reference on identical partitioning
    Xp = X.reshape(8, n // 8, d)
    yp = y.reshape(8, n // 8)
    mp = mask.reshape(8, n // 8)
    sv_f = init_sv_buffer(64, d)
    for _ in range(3):
        out = mapreduce_round(Xp, yp, mp, sv_f, cfg)
        sv_f, risks_f = out.sv, out.risks

    np.testing.assert_allclose(np.sort(np.asarray(risks_s)),
                               np.sort(np.asarray(risks_f)),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(jnp.sum(sv_s.mask)),
                               np.asarray(jnp.sum(sv_f.mask)))
    # same selected SV ids (order may differ)
    ids_s = np.sort(np.asarray(sv_s.ids))
    ids_f = np.sort(np.asarray(sv_f.ids))
    np.testing.assert_array_equal(ids_s, ids_f)
    print("SHARDED_OK")
""")


def test_sharded_matches_functional():
    """shard_map mode must reproduce the vmap mode exactly (8 devices)."""
    from conftest import subprocess_env
    r = subprocess.run([sys.executable, "-c", _SHARD_SCRIPT],
                       capture_output=True, text=True, timeout=300,
                       env=subprocess_env())
    assert "SHARDED_OK" in r.stdout, r.stdout + r.stderr


def test_incremental_update_paper_future_work():
    """§SONUÇ future work: updating on drifted data keeps the model
    current while retaining only old SVs (not the old corpus)."""
    from repro.core.mapreduce_svm import update_mapreduce
    rng_w = jax.random.PRNGKey(7)
    w_old = jax.random.normal(rng_w, (12,))
    w_new = w_old + 0.8 * jax.random.normal(jax.random.PRNGKey(8), (12,))

    X1 = jax.random.normal(jax.random.PRNGKey(1), (320, 12))
    y1 = jnp.sign(X1 @ w_old)
    cfg = MRSVMConfig(sv_capacity=64, gamma=1e-4, max_rounds=4,
                      svm=SVMConfig(C=1.0, max_epochs=25))
    m1 = fit_mapreduce(X1, y1, 4, cfg)

    X2 = jax.random.normal(jax.random.PRNGKey(2), (320, 12))
    y2 = jnp.sign(X2 @ w_new)
    m2 = update_mapreduce(m1, X2, y2, 4, cfg)

    acc_new = float(jnp.mean(predict(m2, X2, cfg) == y2))
    acc_stale = float(jnp.mean(predict(m1, X2, cfg) == y2))
    assert acc_new > 0.9
    assert acc_new > acc_stale        # the update actually adapted
    assert m2.sv.x.shape == m1.sv.x.shape   # capacity unchanged


def test_mapreduce_rbf_kernel_path():
    """The paper's method with a nonlinear (rbf) reducer — XOR data that
    defeats the linear path."""
    from repro.core import KernelConfig
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(0, 1, (256, 2)).astype(np.float32))
    y = jnp.sign(X[:, 0] * X[:, 1])
    cfg_lin = MRSVMConfig(sv_capacity=64, max_rounds=3,
                          svm=SVMConfig(C=1.0, max_epochs=20))
    m_lin = fit_mapreduce(X, y, 4, cfg_lin)
    acc_lin = float(jnp.mean(predict(m_lin, X, cfg_lin) == y))

    cfg_rbf = MRSVMConfig(
        sv_capacity=64, max_rounds=3,
        svm=SVMConfig(C=10.0, max_epochs=30,
                      kernel=KernelConfig("rbf", gamma=1.0)))
    m_rbf = fit_mapreduce(X, y, 4, cfg_rbf)
    acc_rbf = float(jnp.mean(predict(m_rbf, X, cfg_rbf) == y))
    assert acc_rbf > 0.85
    assert acc_rbf > acc_lin + 0.15


def test_one_vs_one_multiclass(fast_mr_cfg):
    from repro.core import fit_one_vs_one
    rng = np.random.default_rng(3)
    y = rng.integers(-1, 2, size=240)
    X = jnp.asarray(rng.normal(0, 1, (240, 8)).astype(np.float32))
    X = X + 2.0 * jnp.asarray(y)[:, None]
    cfg = fast_mr_cfg
    ovo = fit_one_vs_one(X, jnp.asarray(y), [-1, 0, 1], 4, cfg)
    pred = ovo.predict(X)
    acc = float(jnp.mean(pred == jnp.asarray(y, pred.dtype)))
    assert acc > 0.85
