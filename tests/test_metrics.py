import os, tempfile
from repro.metrics import MetricsLogger, read_jsonl


def test_metrics_roundtrip_and_summary():
    with tempfile.TemporaryDirectory() as d:
        log = MetricsLogger(d, "unit", flush_every=2)
        for s in range(10):
            log.log(s, loss=10.0 - s, lr=1e-3)
        log.flush()
        recs = read_jsonl(os.path.join(d, "unit.jsonl"))
        assert len(recs) == 10
        assert recs[0]["loss"] == 10.0 and recs[-1]["loss"] == 1.0
        summ = log.summary("loss")
        assert summ["min"] == 1.0 and summ["max"] == 10.0 and summ["n"] == 10


def test_metrics_no_dir_is_memory_only():
    log = MetricsLogger(None)
    log.log(0, loss=3.0)
    assert log.summary("loss")["last"] == 3.0
