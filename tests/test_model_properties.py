"""Property tests for model building blocks (hypothesis + targeted)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed "
                    "(see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, rmsnorm
from repro.models import moe as moe_lib
from repro.models.layers import template_init

_SET = dict(max_examples=10, deadline=None)


@given(st.integers(0, 500), st.integers(2, 6), st.sampled_from([32, 64]))
@settings(**_SET)
def test_rope_preserves_norm(offset, heads, hd):
    """Rotation: ‖RoPE(x)‖ == ‖x‖ per head (it's orthogonal)."""
    x = jax.random.normal(jax.random.PRNGKey(offset), (1, 4, heads, hd))
    pos = jnp.arange(4)[None, :] + offset
    r = apply_rope(x, pos, 1.0, 10000.0)
    np.testing.assert_allclose(
        np.asarray(jnp.linalg.norm(r, axis=-1)),
        np.asarray(jnp.linalg.norm(x, axis=-1)), rtol=1e-4)


def test_rope_relative_position_invariance():
    """q·k after RoPE depends only on relative offsets: shifting BOTH
    positions by Δ leaves the inner products unchanged."""
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 2, 64))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 2, 64))
    p = jnp.arange(8)[None, :]
    for delta in (1, 17, 1000):
        s0 = jnp.einsum("bshd,bthd->bhst",
                        apply_rope(q, p, 1.0, 1e4),
                        apply_rope(k, p, 1.0, 1e4))
        s1 = jnp.einsum("bshd,bthd->bhst",
                        apply_rope(q, p + delta, 1.0, 1e4),
                        apply_rope(k, p + delta, 1.0, 1e4))
        np.testing.assert_allclose(np.asarray(s0), np.asarray(s1),
                                   rtol=2e-3, atol=2e-3)


def test_partial_rope_leaves_tail_untouched():
    """chatglm3-style fraction=0.5: the unrotated half passes through."""
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 4, 2, 64))
    r = apply_rope(x, jnp.arange(4)[None, :] + 3, 0.5, 1e4)
    np.testing.assert_array_equal(np.asarray(r[..., 32:]),
                                  np.asarray(x[..., 32:]))
    assert not np.allclose(np.asarray(r[..., :32]), np.asarray(x[..., :32]))


@given(st.integers(0, 100))
@settings(**_SET)
def test_rmsnorm_unit_rms(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (3, 5, 64)) * 7.0
    y = rmsnorm(x, jnp.ones((64,)), 1e-6)
    rms = jnp.sqrt(jnp.mean(jnp.square(y), axis=-1))
    np.testing.assert_allclose(np.asarray(rms), 1.0, rtol=1e-3)


def _moe_cfg(E=4, K=2, cf=8.0):
    return ModelConfig(name="t", family="moe", num_layers=1, d_model=32,
                       num_heads=4, num_kv_heads=2, d_ff=48, vocab_size=64,
                       num_experts=E, experts_per_token=K,
                       moe_capacity_factor=cf)


def test_moe_is_token_permutation_equivariant():
    """Permuting tokens permutes outputs (no cross-token leakage in the
    dispatch/combine bookkeeping) given no capacity drops."""
    cfg = _moe_cfg()
    p = template_init(moe_lib.moe_template(cfg), jax.random.PRNGKey(0),
                      jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 32))
    y, _ = moe_lib.apply_moe(p, x, cfg)
    perm = jax.random.permutation(jax.random.PRNGKey(2), 16)
    y_perm, _ = moe_lib.apply_moe(p, x[:, perm, :], cfg)
    np.testing.assert_allclose(np.asarray(y[:, perm, :]),
                               np.asarray(y_perm), rtol=2e-4, atol=1e-5)


def test_moe_capacity_drops_are_bounded():
    """With cf=1.0 and adversarially identical tokens, the combine must
    drop overflow rather than corrupt outputs: dropped tokens get 0."""
    cfg = _moe_cfg(E=4, K=1, cf=0.25)     # capacity ≈ T/16: heavy overflow
    p = template_init(moe_lib.moe_template(cfg), jax.random.PRNGKey(0),
                      jnp.float32)
    x = jnp.broadcast_to(jax.random.normal(jax.random.PRNGKey(1), (1, 1, 32)),
                         (1, 32, 32))     # all tokens identical → same expert
    y, _ = moe_lib.apply_moe(p, x, cfg)
    norms = np.asarray(jnp.linalg.norm(y[0], axis=-1))
    served = (norms > 1e-6).sum()
    C = max(1, int(32 * 1 * 0.25 / 4))
    assert served <= C                    # only capacity-many served
    assert np.isfinite(np.asarray(y)).all()


def test_moe_gates_convex_combination():
    """Outputs are gate-weighted sums: scaling all expert weights by c
    scales outputs by c (linearity in the expert stack's last layer)."""
    cfg = _moe_cfg()
    p = template_init(moe_lib.moe_template(cfg), jax.random.PRNGKey(0),
                      jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 32))
    y1, _ = moe_lib.apply_moe(p, x, cfg)
    p2 = dict(p, w_down=p["w_down"] * 3.0)
    y3, _ = moe_lib.apply_moe(p2, x, cfg)
    np.testing.assert_allclose(np.asarray(y3), 3.0 * np.asarray(y1),
                               rtol=1e-4, atol=1e-5)


def test_sliding_window_masks_distant_tokens():
    """mixtral-style SWA: token t must not attend beyond the window —
    perturbing x_0 must not change outputs at t ≥ window."""
    from repro.models import attention as attn_lib
    cfg = ModelConfig(name="t", family="dense", num_layers=1, d_model=64,
                      num_heads=4, num_kv_heads=4, d_ff=64, vocab_size=64,
                      sliding_window=4)
    p = template_init(attn_lib.attn_template(cfg), jax.random.PRNGKey(0),
                      jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 12, 64))
    pos = jnp.arange(12)[None, :]
    y1 = attn_lib.attention(p, x, cfg, positions=pos)
    x2 = x.at[0, 0].add(10.0)
    y2 = attn_lib.attention(p, x2, cfg, positions=pos)
    # positions ≥ 4 can't see token 0
    np.testing.assert_allclose(np.asarray(y1[0, 4:]), np.asarray(y2[0, 4:]),
                               rtol=1e-4, atol=1e-5)
    # position 1 can
    assert not np.allclose(np.asarray(y1[0, 1]), np.asarray(y2[0, 1]),
                           rtol=1e-4)


def test_mamba2_chunked_matches_stepwise():
    """Chunked SSD == step-by-step recurrence (the decode path) when
    fed the same projections."""
    from repro.models import mamba2
    cfg = ModelConfig(name="t", family="hybrid", num_layers=1, d_model=64,
                      num_heads=4, num_kv_heads=4, d_ff=64, vocab_size=64,
                      ssm_state=16, attn_every=1)
    p = template_init(mamba2.mamba2_template(cfg), jax.random.PRNGKey(0),
                      jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 64)) * 0.5
    y_chunked = mamba2.apply_mamba2(p, x, cfg)

    st = mamba2.init_mamba2_state(cfg, 2, jnp.float32)
    outs = []
    for t in range(8):
        y_t, st = mamba2.mamba2_decode_step(p, x[:, t:t + 1, :], st, cfg)
        outs.append(y_t)
    y_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunked), np.asarray(y_step),
                               rtol=2e-3, atol=2e-3)


def test_rwkv_chunked_matches_stepwise():
    from repro.models import rwkv6
    cfg = ModelConfig(name="t", family="ssm", attn_free=True, num_layers=1,
                      d_model=128, num_heads=2, num_kv_heads=2, d_ff=256,
                      vocab_size=64, norm_style="layernorm")
    p = template_init(rwkv6.rwkv6_template(cfg), jax.random.PRNGKey(0),
                      jnp.float32)["time"]
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 128)) * 0.5
    y_chunked = rwkv6.apply_rwkv_time(p, x, cfg,
                                      jnp.zeros((2, 1, 128)))
    S = jnp.zeros((2, rwkv6.rwkv_heads(cfg), 64, 64))
    x_prev = jnp.zeros((2, 1, 128))
    outs = []
    for t in range(8):
        y_t, S = rwkv6.rwkv_time_decode_step(p, x[:, t:t + 1, :], S,
                                             x_prev, cfg)
        x_prev = x[:, t:t + 1, :]
        outs.append(y_t)
    y_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunked), np.asarray(y_step),
                               rtol=2e-3, atol=2e-3)
