"""Per-architecture smoke tests (assignment requirement): a REDUCED
variant of each family runs one forward/train step + one decode step
on CPU, asserting output shapes and finiteness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs import ARCH_IDS, get_config
from repro.models.config import smoke_variant
from repro.models.transformer import build_model

ARCHS = [a for a in ARCH_IDS if a != "svm_tfidf"]
B, S = 2, 32


def _batch(cfg):
    batch = {"tokens": jnp.zeros((B, S), jnp.int32),
             "labels": jnp.ones((B, S), jnp.int32)}
    if cfg.family == "vlm":
        P = cfg.num_prefix_tokens
        batch["tokens"] = jnp.zeros((B, S - P), jnp.int32)
        batch["labels"] = jnp.ones((B, S - P), jnp.int32)
        batch["prefix_embeds"] = jnp.ones((B, P, cfg.d_model), cfg.jdtype)
    if cfg.family == "audio":
        batch["frames"] = jnp.ones((B, cfg.encoder_seq, cfg.d_model),
                                   cfg.jdtype)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_loss(arch):
    cfg = smoke_variant(get_config(arch))
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    assert cfg.num_experts <= 4
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    loss, metrics = jax.jit(model.loss)(params, _batch(cfg))
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch} loss not finite"
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step_reduces_loss(arch):
    cfg = smoke_variant(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ostate = optim.init(params)
    ocfg = optim.OptConfig(lr=5e-3, warmup_steps=2, total_steps=50)

    @jax.jit
    def step(params, ostate, batch):
        (loss, _), grads = jax.value_and_grad(model.loss, has_aux=True)(
            params, batch)
        params, ostate, _ = optim.apply_updates(params, grads, ostate, ocfg)
        return params, ostate, loss

    batch = _batch(cfg)   # same batch → loss must drop fast
    losses = []
    for _ in range(8):
        params, ostate, loss = step(params, ostate, batch)
        losses.append(float(loss))
        assert np.isfinite(losses[-1]), f"{arch} diverged"
    assert losses[-1] < losses[0], f"{arch}: {losses[0]} -> {losses[-1]}"


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = smoke_variant(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if cfg.family == "audio":
        frames = jnp.ones((B, cfg.encoder_seq, cfg.d_model), cfg.jdtype)
        state = model.init_decode_state(B, 64, frames=frames, params=params)
    else:
        state = model.init_decode_state(B, 64)
    step = jax.jit(model.decode_step)
    tok = jnp.zeros((B, 1), jnp.int32)
    for i in range(3):
        logits, state = step(params, state, tok)
        assert logits.shape == (B, 1, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
        tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
    assert int(state.pos) == 3


@pytest.mark.parametrize("arch", ["tinyllama_1_1b", "rwkv6_7b",
                                  "zamba2_1_2b", "mixtral_8x22b"])
def test_decode_matches_forward(arch):
    """Teacher-forced decode must reproduce the training forward's logits
    (the KV-cache/state path is the same function, incrementally)."""
    cfg = smoke_variant(get_config(arch))
    cfg = dataclasses.replace(cfg, sliding_window=None)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    T = 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0,
                                cfg.vocab_size)
    full_logits, _ = model.forward(params, tokens)

    state = model.init_decode_state(B, T)
    outs = []
    for t in range(T):
        logits, state = model.decode_step(params, state, tokens[:, t:t + 1])
        outs.append(logits[:, 0, :])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full_logits),
                               rtol=2e-2, atol=2e-3)


def test_param_counts_match_assignment():
    """Full configs carry the exact assigned dimensions."""
    cfg = get_config("qwen3-moe-235b-a22b")
    assert (cfg.num_layers, cfg.d_model, cfg.num_heads,
            cfg.num_kv_heads, cfg.d_ff, cfg.vocab_size,
            cfg.num_experts, cfg.experts_per_token) == \
        (94, 4096, 64, 4, 1536, 151936, 128, 8)
    cfg = get_config("mixtral-8x22b")
    assert (cfg.num_layers, cfg.d_model, cfg.num_experts,
            cfg.experts_per_token, cfg.sliding_window) == (56, 6144, 8, 2, 4096)
    cfg = get_config("llama3-8b")
    # analytic parameter count should be ~8B
    assert 7.0e9 < cfg.param_count() < 9.0e9
    cfg = get_config("tinyllama-1.1b")
    assert 1.0e9 < cfg.param_count() < 1.25e9
    cfg = get_config("whisper-base")
    assert cfg.is_encoder_decoder and cfg.encoder_layers == 6
