"""Multi-process cluster equivalence (ISSUE 5 tentpole).

The 2-process CPU run of the sharded MapReduce-SVM round — real
``jax.distributed`` processes over a localhost coordinator and gloo
CPU collectives, per-host loaders feeding disjoint row shards — must
match the single-process functional reference, with
``build_sharded_round`` unchanged, under BOTH merge transports
(allgather and ring). ``tests/mp_worker.py`` is the per-process body;
this file is the launcher (``make test-dist-mp`` runs just this).
"""
import socket
import subprocess
import sys
from pathlib import Path

import pytest

from conftest import subprocess_env

REPO = Path(__file__).resolve().parents[1]


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("localhost", 0))
        return s.getsockname()[1]


def _launch(num_processes: int, rounds: int = 3, timeout: int = 900):
    port = _free_port()
    env = subprocess_env(PYTHONPATH=str(REPO / "src"))
    procs = [
        subprocess.Popen(
            [sys.executable, str(REPO / "tests" / "mp_worker.py"),
             str(pid), str(num_processes), str(port), str(rounds)],
            stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
            text=True, env=env)
        for pid in range(num_processes)
    ]
    outs = []
    try:
        for p in procs:
            out, _ = p.communicate(timeout=timeout)
            outs.append(out)
    finally:
        for p in procs:
            if p.poll() is None:
                p.kill()
    return procs, outs


@pytest.mark.slow
def test_two_process_round_matches_functional():
    """2 processes × 4 local devices: same 8-partition problem as the
    single-process sharded tests, now crossing a real process boundary
    on every merge collective."""
    procs, outs = _launch(2)
    for pid, (p, out) in enumerate(zip(procs, outs)):
        assert p.returncode == 0, f"process {pid} failed:\n{out}"
        assert "MP_ROUND_OK" in out, f"process {pid}:\n{out}"
