"""Multi-process cluster equivalence (ISSUE 5 tentpole).

The 2-process CPU run of the sharded MapReduce-SVM round — real
``jax.distributed`` processes over a localhost coordinator and gloo
CPU collectives, per-host loaders feeding disjoint row shards — must
match the single-process functional reference, with
``build_sharded_round`` unchanged, under BOTH merge transports
(allgather and ring). ``tests/mp_worker.py`` is the per-process body;
this file is the launcher (``make test-dist-mp`` runs just this).
"""
import signal
import socket
import subprocess
import sys
import time
from pathlib import Path

import pytest

from conftest import subprocess_env

REPO = Path(__file__).resolve().parents[1]


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("localhost", 0))
        return s.getsockname()[1]


def _launch(num_processes: int, rounds: int = 3, timeout: int = 900):
    port = _free_port()
    env = subprocess_env(PYTHONPATH=str(REPO / "src"))
    procs = [
        subprocess.Popen(
            [sys.executable, str(REPO / "tests" / "mp_worker.py"),
             str(pid), str(num_processes), str(port), str(rounds)],
            stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
            text=True, env=env)
        for pid in range(num_processes)
    ]
    outs = []
    try:
        for p in procs:
            out, _ = p.communicate(timeout=timeout)
            outs.append(out)
    finally:
        for p in procs:
            if p.poll() is None:
                p.kill()
    return procs, outs


@pytest.mark.slow
def test_two_process_round_matches_functional():
    """2 processes × 4 local devices: same 8-partition problem as the
    single-process sharded tests, now crossing a real process boundary
    on every merge collective."""
    procs, outs = _launch(2)
    for pid, (p, out) in enumerate(zip(procs, outs)):
        assert p.returncode == 0, f"process {pid} failed:\n{out}"
        assert "MP_ROUND_OK" in out, f"process {pid}:\n{out}"


def _launch_ft(port: int, ckpt_dir: str, phase: str,
               rounds: int = 4, kill_round: int = 2, kind: str = "ft"):
    env = subprocess_env(PYTHONPATH=str(REPO / "src"))
    return [
        subprocess.Popen(
            [sys.executable, str(REPO / "tests" / "mp_worker.py"),
             str(pid), "2", str(port), str(rounds),
             kind, ckpt_dir, str(kill_round), phase],
            stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
            text=True, env=env)
        for pid in range(2)
    ]


@pytest.mark.slow
def test_kill_worker_midwave_restart_converges(tmp_path):
    """The kill-a-worker leg (ISSUE 7): SIGKILL one of the 2
    jax.distributed processes mid-wave, restart both from the durable
    round-state checkpoint, and prove the resumed sweep converges to
    the SAME model — bit-for-bit against an uninterrupted run (risks,
    per-config SV buffers, ws, bs)."""
    kill_round = 2
    ckpt_dir = str(tmp_path / "ft_ckpt")
    (tmp_path / "ft_ckpt").mkdir()

    # Phase A — crash: process 1 SIGKILLs itself after completing round
    # kill_round-1; process 0 is stranded mid-collective in round
    # kill_round. The coordinator's last durable snapshot must be round
    # kill_round-1 (a round is saved only after it fully completes).
    procs = _launch_ft(_free_port(), ckpt_dir, "crash",
                       kill_round=kill_round)
    try:
        assert procs[1].wait(timeout=600) == -signal.SIGKILL
        sys.path.insert(0, str(REPO / "src"))
        from repro.ckpt.checkpoint import latest_step
        deadline = time.time() + 60       # process 0 may still be saving
        while (latest_step(ckpt_dir) != kill_round - 1
               and time.time() < deadline):
            time.sleep(0.5)
        assert latest_step(ckpt_dir) == kill_round - 1
    finally:
        for p in procs:
            if p.poll() is None:
                p.kill()
        outs = [p.communicate()[0] for p in procs]
    assert procs[1].returncode == -signal.SIGKILL, outs[1]

    # Phase B — restart on a FRESH coordinator port: both processes
    # restore the round state and must land exactly where an
    # uninterrupted run lands.
    procs = _launch_ft(_free_port(), ckpt_dir, "resume",
                       kill_round=kill_round)
    outs = []
    try:
        for p in procs:
            out, _ = p.communicate(timeout=900)
            outs.append(out)
    finally:
        for p in procs:
            if p.poll() is None:
                p.kill()
    for pid, (p, out) in enumerate(zip(procs, outs)):
        assert p.returncode == 0, f"restarted process {pid} failed:\n{out}"
        assert "MP_FT_OK" in out, f"restarted process {pid}:\n{out}"


@pytest.mark.slow
def test_chaos_kill_corrupt_restart_converges(tmp_path):
    """The 2-process chaos leg (ISSUE 9): peer loss AND checkpoint
    corruption, both detected and named — never a hang, never a silent
    wrong answer. Phase A SIGKILLs process 1 mid-wave; the stranded
    process 0 must EXIT with the watchdog's typed transport diagnosis
    (code 17, heartbeat file flipped to timeout/detected) instead of
    hanging in gloo. This test then flips one byte mid-file in the
    newest snapshot generation; phase B restarts both processes through
    a flaky (retried) coordinator handshake, restores from the previous
    INTACT generation, and still lands bit-for-bit on the uninterrupted
    model."""
    import json
    import os

    sys.path.insert(0, str(REPO / "src"))
    from repro.faults import WATCHDOG_EXIT_CODE

    kill_round = 2
    ckpt_dir = str(tmp_path / "chaos_ckpt")
    (tmp_path / "chaos_ckpt").mkdir()

    # Phase A — crash under the watchdog.
    procs = _launch_ft(_free_port(), ckpt_dir, "crash",
                       kill_round=kill_round, kind="chaos")
    try:
        assert procs[1].wait(timeout=600) == -signal.SIGKILL
        # p0 strands in the merge collective → the watchdog (or a gloo
        # error) must convert that into a typed exit, not a hang.
        rc0 = procs[0].wait(timeout=300)
        assert rc0 == WATCHDOG_EXIT_CODE, rc0
    finally:
        for p in procs:
            if p.poll() is None:
                p.kill()
        outs = [p.communicate()[0] for p in procs]
    assert "transport" in outs[0], outs[0]
    with open(os.path.join(ckpt_dir, "hb_p0.json")) as f:
        hb = json.load(f)
    assert hb["status"] in ("timeout", "detected"), hb

    sys.path.insert(0, str(REPO / "src"))
    from repro.ckpt.checkpoint import latest_path, latest_step
    assert latest_step(ckpt_dir) == kill_round - 1

    # Corrupt the newest generation's medium: one flipped byte
    # mid-file. The crc walk must now land one generation earlier.
    newest = latest_path(ckpt_dir)
    with open(newest, "r+b") as f:
        f.seek(os.path.getsize(newest) // 2)
        byte = f.read(1)
        f.seek(-1, 1)
        f.write(bytes([byte[0] ^ 0x40]))
    assert latest_step(ckpt_dir) == kill_round - 2

    # Phase B — restart through a flaky handshake, restore from the
    # intact generation, converge bit-for-bit.
    procs = _launch_ft(_free_port(), ckpt_dir, "resume",
                       kill_round=kill_round, kind="chaos")
    outs = []
    try:
        for p in procs:
            out, _ = p.communicate(timeout=900)
            outs.append(out)
    finally:
        for p in procs:
            if p.poll() is None:
                p.kill()
    for pid, (p, out) in enumerate(zip(procs, outs)):
        assert p.returncode == 0, f"restarted process {pid} failed:\n{out}"
        assert "MP_CHAOS_OK" in out, f"restarted process {pid}:\n{out}"
