"""End-to-end golden test of the paper pipeline (ISSUE 2 satellite):

    tweets → TF×IDF (eq. 10-11) → 2-class / 3-class MapReduce SVM
    (Tablo 1-2, eq. 6-9) → confusion matrix (Tablo 6 / Tablo 8)

This harness locks the whole reproduction down for every future PR:
accuracy floors on held-out data for both polarization models, the
confusion-matrix conventions, and sweep-based model selection (the
best config must beat the worst on held-out data)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (MRSVMConfig, SVMConfig, confusion_matrix,
                        fit_mapreduce, fit_mapreduce_sweep,
                        fit_one_vs_rest, predict, predict_sweep, sweep_grid)
from repro.text import CorpusConfig, fit_transform, generate, vectorize


def _pipeline_data(classes, num_messages=1024, num_features=1024, seed=0):
    """Synthetic corpus → hashed counts → TF×IDF, split 75/25."""
    corpus = generate(CorpusConfig(num_messages=num_messages,
                                   classes=classes, seed=seed))
    counts = jnp.asarray(vectorize(corpus.texts, num_features))
    X, _ = fit_transform(counts)
    y = jnp.asarray(corpus.labels, jnp.float32)
    n_train = int(0.75 * num_messages)
    return (X[:n_train], y[:n_train]), (X[n_train:], y[n_train:])


@pytest.fixture(scope="module")
def two_class_data():
    return _pipeline_data((-1, 1))


@pytest.fixture(scope="module")
def three_class_data():
    return _pipeline_data((-1, 0, 1))


@pytest.fixture(scope="module")
def mr_cfg():
    return MRSVMConfig(sv_capacity=128, gamma=1e-4, max_rounds=4,
                       svm=SVMConfig(C=1.0, max_epochs=15))


def test_two_class_pipeline_golden(two_class_data, mr_cfg):
    """Tablo 6 analogue: the 2-class (Olumlu/Olumsuz) model."""
    (X_tr, y_tr), (X_te, y_te) = two_class_data
    model = fit_mapreduce(X_tr, y_tr, 8, mr_cfg)
    pred = predict(model, X_te, mr_cfg)
    acc = float(jnp.mean(pred == y_te))
    assert acc > 0.85, f"2-class held-out accuracy regressed: {acc:.3f}"

    cm = confusion_matrix(y_te, pred, [-1, 1])
    assert cm.shape == (2, 2)
    assert abs(cm.sum() - 100.0) < 1e-3            # global % (paper)
    assert np.trace(cm) > 85.0

    cm_row = confusion_matrix(y_te, pred, [-1, 1], normalize="true")
    np.testing.assert_allclose(cm_row.sum(axis=1), [100.0, 100.0],
                               atol=1e-6)
    assert (np.diag(cm_row) > 80.0).all()          # per-class recall


def test_three_class_pipeline_golden(three_class_data, mr_cfg):
    """Tablo 8 analogue: the 3-class ({-1, 0, +1}) model via OvR."""
    (X_tr, y_tr), (X_te, y_te) = three_class_data
    ovr = fit_one_vs_rest(X_tr, y_tr, [-1, 0, 1], 8, mr_cfg)
    pred = ovr.predict(X_te)
    acc = float(jnp.mean(pred == y_te.astype(pred.dtype)))
    assert acc > 0.75, f"3-class held-out accuracy regressed: {acc:.3f}"

    cm = confusion_matrix(y_te, pred, [-1, 0, 1])
    assert cm.shape == (3, 3)
    assert abs(cm.sum() - 100.0) < 1e-3
    assert np.trace(cm) > 75.0


def test_sweep_selected_config_beats_worst_on_held_out(two_class_data):
    """Model selection: the sweep's risk-ranked best config must beat
    its worst config on held-out data (the Tablo 6/8 comparison the
    paper does by hand, batched). An rbf (C, γ) grid includes a
    memorizing γ — huge γ makes K ≈ I on L2-normalized TF×IDF rows, so
    that config collapses to the class prior on held-out data while a
    sane γ generalizes; the sweep has to rank them apart."""
    from repro.core import KernelConfig
    (X_tr, y_tr), (X_te, y_te) = two_class_data
    cfg = MRSVMConfig(sv_capacity=128, gamma=1e-4, max_rounds=3,
                      svm=SVMConfig(C=10.0, max_epochs=15,
                                    kernel=KernelConfig("rbf", gamma=1.0)))
    params = sweep_grid(cfg.svm, C=[1.0, 10.0], gamma=[0.5, 200.0])
    res = fit_mapreduce_sweep(X_tr, y_tr, 8, cfg, params)
    preds = predict_sweep(res, X_te, cfg)
    accs = np.asarray(jnp.mean(preds == y_te[None, :], axis=1))
    worst = int(np.argmax(np.asarray(res.risks)))
    assert res.best != worst
    assert accs[res.best] > accs[worst] + 0.1
    assert accs[res.best] > 0.85
