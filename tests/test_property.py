"""Property-based tests (hypothesis) for system invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed "
                    "(see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import SVMConfig, fit_binary
from repro.core.risk import converged, empirical_risk, hinge_loss
from repro.text import fit_idf, transform
from repro.text.tokenizer import hash_token

_SETTINGS = dict(max_examples=15, deadline=None)


@st.composite
def svm_problem(draw):
    n = draw(st.integers(24, 60))
    d = draw(st.integers(2, 8))
    seed = draw(st.integers(0, 2 ** 16))
    rng = np.random.default_rng(seed)
    X = rng.normal(0, 1, (n, d)).astype(np.float32)
    w = rng.normal(0, 1, d).astype(np.float32)
    y = np.sign(X @ w + 1e-3).astype(np.float32)
    y[y == 0] = 1.0
    return jnp.asarray(X), jnp.asarray(y)


@given(svm_problem(), st.floats(0.1, 10.0))
@settings(**_SETTINGS)
def test_alpha_always_in_box(problem, C):
    X, y = problem
    m = fit_binary(X, y, cfg=SVMConfig(C=C, max_epochs=15))
    assert float(jnp.min(m.alpha)) >= -1e-6
    assert float(jnp.max(m.alpha)) <= C + 1e-5


@given(svm_problem())
@settings(**_SETTINGS)
def test_label_flip_flips_hyperplane(problem):
    """fit(X, -y) must yield the mirrored decision function."""
    X, y = problem
    cfg = SVMConfig(C=1.0, max_epochs=25, tol=1e-6)
    m1 = fit_binary(X, y, cfg=cfg)
    m2 = fit_binary(X, -y, cfg=cfg)
    np.testing.assert_allclose(np.asarray(m1.w), -np.asarray(m2.w),
                               rtol=1e-3, atol=1e-4)


@given(svm_problem(), st.integers(1, 10))
@settings(**_SETTINGS)
def test_padding_invariance(problem, pad):
    X, y = problem
    cfg = SVMConfig(C=1.0, max_epochs=20)
    m1 = fit_binary(X, y, cfg=cfg)
    Xp = jnp.concatenate([X, jnp.ones((pad, X.shape[1]))])
    yp = jnp.concatenate([y, jnp.ones((pad,))])
    mask = jnp.concatenate([jnp.ones((X.shape[0],)), jnp.zeros((pad,))])
    m2 = fit_binary(Xp, yp, mask, cfg=cfg)
    np.testing.assert_allclose(np.asarray(m1.w), np.asarray(m2.w),
                               rtol=1e-4, atol=1e-5)


@given(st.lists(st.floats(-5, 5), min_size=4, max_size=32),
       st.lists(st.sampled_from([-1.0, 1.0]), min_size=4, max_size=32))
@settings(**_SETTINGS)
def test_hinge_loss_nonnegative_and_correct_side(scores, ys):
    n = min(len(scores), len(ys))
    s = jnp.asarray(scores[:n], jnp.float32)
    y = jnp.asarray(ys[:n], jnp.float32)
    h = hinge_loss(s, y)
    assert float(jnp.min(h)) >= 0.0
    big = y * s >= 1.0
    assert float(jnp.max(jnp.where(big, h, 0.0))) == 0.0


@given(st.floats(0, 1), st.floats(0, 1), st.floats(0, 0.5))
@settings(**_SETTINGS)
def test_convergence_rule_symmetry(r1, r2, gamma):
    assert bool(converged(r1, r2, gamma)) == bool(converged(r2, r1, gamma))
    assert bool(converged(r1, r1, 0.0))


@given(st.integers(2, 50), st.integers(2, 16))
@settings(**_SETTINGS)
def test_idf_monotone_in_rarity(n_docs, d):
    """Rarer terms must never get smaller idf (eq. 10 monotonicity)."""
    rng = np.random.default_rng(n_docs * 31 + d)
    counts = (rng.random((n_docs, d)) > 0.5).astype(np.float32)
    model = fit_idf(jnp.asarray(counts))
    df = counts.astype(bool).sum(0)
    idf = np.asarray(model.idf)
    order = np.argsort(df)
    for a, b in zip(order[:-1], order[1:]):
        if df[a] < df[b]:
            assert idf[a] >= idf[b] - 1e-6


@given(st.text(min_size=1, max_size=30), st.integers(2, 2 ** 20))
@settings(**_SETTINGS)
def test_hash_token_in_range(tok, dim):
    h = hash_token(tok, dim)
    assert 0 <= h < dim


@given(svm_problem())
@settings(max_examples=8, deadline=None)
def test_empirical_risk_masked_subset(problem):
    """Risk over a mask equals risk over the corresponding subset."""
    X, y = problem
    n = X.shape[0]
    scores = X @ jnp.ones((X.shape[1],))
    mask = jnp.asarray(np.random.default_rng(0).random(n) > 0.4,
                       jnp.float32)
    r_masked = empirical_risk(scores, y, mask)
    sel = np.asarray(mask) > 0
    if sel.sum() == 0:
        return
    r_subset = empirical_risk(scores[sel], y[sel])
    assert float(jnp.abs(r_masked - r_subset)) < 1e-5


# ---------------------------------------------------------------------------
# TF×IDF invariants (ISSUE 2 satellite).
# ---------------------------------------------------------------------------

@st.composite
def count_matrix(draw):
    n = draw(st.integers(2, 24))
    d = draw(st.integers(2, 12))
    seed = draw(st.integers(0, 2 ** 16))
    rng = np.random.default_rng(seed)
    # sparse small-integer term counts, with guaranteed empty buckets
    counts = rng.poisson(0.7, (n, d)).astype(np.float32)
    counts[:, draw(st.integers(0, d - 1))] = 0.0
    return jnp.asarray(counts)


@given(count_matrix())
@settings(**_SETTINGS)
def test_smooth_idf_always_finite_and_positive(counts):
    """Smoothed eq. 10 must stay finite/positive even for df=0 buckets."""
    model = fit_idf(counts, smooth=True)
    idf = np.asarray(model.idf)
    assert np.isfinite(idf).all()
    assert (idf > 0.0).all()


@given(count_matrix())
@settings(**_SETTINGS)
def test_l2_normalized_rows_have_unit_norm(counts):
    model = fit_idf(counts)
    X = np.asarray(transform(counts, model, l2_normalize=True))
    norms = np.linalg.norm(X, axis=1)
    nonzero = np.asarray(jnp.sum(counts, axis=1)) > 0
    np.testing.assert_allclose(norms[nonzero], 1.0, rtol=1e-5)
    # all-zero rows must stay zero, not NaN
    assert np.isfinite(X).all()
    np.testing.assert_allclose(norms[~nonzero], 0.0, atol=1e-12)


@given(count_matrix(), st.booleans(), st.booleans())
@settings(**_SETTINGS)
def test_fit_transform_is_transform_after_fit_idf(counts, smooth, l2):
    """fit_transform ≡ transform ∘ fit_idf on the same data."""
    from repro.text import fit_transform
    X1, model1 = fit_transform(counts, smooth=smooth, l2_normalize=l2)
    model2 = fit_idf(counts, smooth=smooth)
    X2 = transform(counts, model2, l2_normalize=l2)
    np.testing.assert_array_equal(np.asarray(model1.idf),
                                  np.asarray(model2.idf))
    np.testing.assert_array_equal(np.asarray(X1), np.asarray(X2))


# ---------------------------------------------------------------------------
# Sweep invariant: batching S configs is semantics-preserving.
# ---------------------------------------------------------------------------

@st.composite
def sweep_problem(draw):
    n = draw(st.integers(32, 64))
    d = draw(st.integers(3, 6))
    seed = draw(st.integers(0, 2 ** 16))
    rng = np.random.default_rng(seed)
    X = rng.normal(0, 1, (n, d)).astype(np.float32)
    w = rng.normal(0, 1, d).astype(np.float32)
    y = np.sign(X @ w + 1e-3).astype(np.float32)
    y[y == 0] = 1.0
    Cs = sorted(draw(st.lists(st.floats(0.05, 10.0), min_size=2,
                              max_size=3, unique=True)))
    return jnp.asarray(X), jnp.asarray(y), Cs


@given(sweep_problem())
@settings(max_examples=8, deadline=None)
def test_sweep_batched_equals_sequential(problem):
    """fit_mapreduce_sweep ≡ per-config fit_mapreduce (hypothesis-drawn
    configs): vmap-over-configs must be a pure batching transform."""
    from repro import compat
    from repro.core import (MRSVMConfig, fit_mapreduce, fit_mapreduce_sweep,
                            sweep_grid)
    X, y, Cs = problem
    cfg = MRSVMConfig(sv_capacity=16, gamma=1e-3, max_rounds=2,
                      svm=SVMConfig(C=1.0, max_epochs=8))
    params = sweep_grid(cfg.svm, C=Cs)
    res = fit_mapreduce_sweep(X, y, 2, cfg, params)
    for s in range(len(Cs)):
        p_s = compat.tree_map(lambda a: a[s], params)
        seq = fit_mapreduce(X, y, 2, cfg, params=p_s)
        np.testing.assert_allclose(float(res.risks[s]), float(seq.risk),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(res.ws[s]), np.asarray(seq.w),
                                   rtol=1e-3, atol=1e-4)
        # round counts can differ by one on drawn problems whose eq. 8
        # delta lands within float-reassociation distance of gamma; the
        # deterministic tests in test_sweep.py assert exact equality.
        assert abs(int(res.rounds[s]) - seq.rounds) <= 1


@st.composite
def dedup_candidates_problem(draw):
    """A device's per-config candidate chunks, as _round_candidates
    would emit them: distinct home-row picks per config (top_k), α
    above the SV threshold on live slots, arbitrary dead slots."""
    per = draw(st.integers(8, 24))
    k = draw(st.integers(2, 8))
    k = min(k, per)
    S = draw(st.integers(1, 4))
    d = draw(st.integers(2, 5))
    idx = draw(st.integers(0, 3))
    seed = draw(st.integers(0, 2 ** 16))
    rng = np.random.default_rng(seed)
    Xl = rng.normal(0, 1, (per, d)).astype(np.float32)
    yl = np.where(rng.random(per) < 0.5, -1.0, 1.0).astype(np.float32)
    topi = np.stack([rng.choice(per, size=k, replace=False)
                     for _ in range(S)])
    live = (rng.random((S, k)) < 0.8).astype(np.float32)
    alpha = rng.uniform(1e-3, 1.0, (S, k)).astype(np.float32) * live
    return Xl, yl, topi, live, alpha, idx, per


@given(dedup_candidates_problem())
@settings(max_examples=20, deadline=None)
def test_dedup_roundtrip_lossless(problem):
    """Cross-config SV dedup (ISSUE 4): expand_chunk ∘ dedup_candidates
    must reproduce every config's (x, y, α, ids, mask) chunk exactly —
    order included — whenever the unique capacity is the lossless
    default min(S·k, per)."""
    from repro.core.mapreduce_svm import SVBuffer
    from repro.core.sweep import dedup_candidates, expand_chunk

    Xl, yl, topi, live, alpha, idx, per = problem
    S, k = live.shape
    Xl_j, yl_j = jnp.asarray(Xl), jnp.asarray(yl)
    cand = SVBuffer(
        x=jnp.asarray(Xl[topi] * live[..., None]),
        y=jnp.asarray(yl[topi] * live),
        alpha=jnp.asarray(alpha),
        ids=jnp.asarray(np.where(live > 0, idx * per + topi, -1)
                        .astype(np.int32)),
        mask=jnp.asarray(live))
    U = min(S * k, per)
    chunk = dedup_candidates(cand, Xl_j, yl_j, idx, per, U,
                             wire_dtype=jnp.float32)
    # unique rows really are unique (each live id appears once)
    ids_u = np.asarray(chunk.ids)
    live_ids = ids_u[ids_u >= 0]
    assert len(live_ids) == len(set(live_ids.tolist()))
    back = expand_chunk(chunk, jnp.float32)
    np.testing.assert_array_equal(np.asarray(back.ids),
                                  np.asarray(cand.ids))
    np.testing.assert_array_equal(np.asarray(back.mask),
                                  np.asarray(cand.mask))
    np.testing.assert_array_equal(np.asarray(back.alpha),
                                  np.asarray(cand.alpha))
    np.testing.assert_array_equal(np.asarray(back.y), np.asarray(cand.y))
    np.testing.assert_array_equal(np.asarray(back.x), np.asarray(cand.x))


# ---------------------------------------------------------------------------
# Blocked-CSR sparse rows (ISSUE 6).
# ---------------------------------------------------------------------------

@st.composite
def sparse_rows_problem(draw):
    """A dense matrix whose rows hold ≤ cap nonzeros at distinct
    columns — exactly the regime where from_dense is lossless."""
    n = draw(st.integers(2, 16))
    d = draw(st.integers(8, 48))
    cap = draw(st.integers(2, 8))
    nnz = draw(st.integers(1, min(cap, d)))
    seed = draw(st.integers(0, 2 ** 16))
    rng = np.random.default_rng(seed)
    dense = np.zeros((n, d), np.float32)
    for i in range(n):
        cols = rng.choice(d, nnz, replace=False)
        dense[i, cols] = (rng.uniform(0.1, 2.0, nnz)
                          * rng.choice([-1.0, 1.0], nnz))
    return jnp.asarray(dense), cap


@given(sparse_rows_problem())
@settings(**_SETTINGS)
def test_sparse_dense_roundtrip(problem):
    """to_dense ∘ from_dense is the identity whenever every row fits in
    nnz_cap slots (the featurizer/generator contract)."""
    from repro import sparse
    Xd, cap = problem
    sp = sparse.from_dense(Xd, cap)
    assert sp.shape == Xd.shape
    np.testing.assert_array_equal(np.asarray(sparse.to_dense(sp)),
                                  np.asarray(Xd))


@given(sparse_rows_problem())
@settings(**_SETTINGS)
def test_sparse_wire_roundtrip_f32(problem):
    """pack_wire_rows ∘ unpack_wire_rows is exact on an f32 wire, and
    bitcast int32 indices survive any wire dtype untouched."""
    from repro import sparse
    from repro.core.mapreduce_svm import pack_wire_rows, unpack_wire_rows
    Xd, cap = problem
    sp = sparse.from_dense(Xd, cap)
    for wire in (jnp.float32, jnp.bfloat16):
        flat, wslots = pack_wire_rows(sp, jnp.dtype(wire))
        back = unpack_wire_rows(flat, Xd.shape[0], sp.d, jnp.dtype(wire),
                                wslots, nnz_cap=cap)
        np.testing.assert_array_equal(np.asarray(back.indices),
                                      np.asarray(sp.indices))
        if wire is jnp.float32:
            np.testing.assert_array_equal(np.asarray(back.values),
                                          np.asarray(sp.values))


@given(sparse_rows_problem(), st.sampled_from(["linear", "rbf", "poly"]),
       st.floats(0.05, 2.0), st.floats(0.0, 1.0))
@settings(max_examples=6, deadline=None)
def test_sparse_gram_impls_agree(problem, kind, gamma, coef0):
    """pallas_sparse ≡ XLA sparse reference ≡ dense reference on the
    same data, with gamma/coef0 TRACED (shipped as operands, not baked
    into the compiled kernel)."""
    from repro import sparse
    from repro.kernels.gram import sparse_gram
    from repro.kernels.ref import gram_ref, sparse_gram_ref
    Xd, cap = problem
    Xs = sparse.from_dense(Xd, cap)
    Zs = sparse.from_dense(Xd[::-1], cap)
    want = np.asarray(gram_ref(sparse.to_dense(Xs), sparse.to_dense(Zs),
                               kind=kind, gamma=gamma, coef0=coef0))
    got_ref = np.asarray(sparse_gram_ref(Xs, Zs, kind, gamma, coef0))
    np.testing.assert_allclose(got_ref, want, rtol=1e-4, atol=1e-5)
    # traced scalars: jnp arrays go through sparse_gram's jit as operands
    got_pl = np.asarray(sparse_gram(Xs, Zs, jnp.float32(gamma),
                                    jnp.float32(coef0), kind=kind,
                                    bm=8, bn=8, interpret=True))
    np.testing.assert_allclose(got_pl, want, rtol=1e-4, atol=1e-5)


@given(st.integers(1, 2200), st.integers(1, 8), st.integers(2, 2 ** 16),
       st.integers(4, 48))
@settings(max_examples=20, deadline=None)
def test_host_row_shards_partition_dataset(rows, procs, seed, d):
    """Per-host loader invariants (ISSUE 5): shards are pairwise
    disjoint, deterministic under re-iteration, and their in-order
    union IS the single-host dataset — for arbitrary (rows, processes,
    seed), including row counts straddling the stateless block size."""
    from repro.data import host_row_range, svm_rows, svm_rows_shard

    full_X, full_y = svm_rows(rows, d, seed=seed)
    ranges = [host_row_range(rows, p, procs) for p in range(procs)]
    # contiguous, disjoint, covering: each range starts where the
    # previous one stopped
    assert ranges[0][0] == 0 and ranges[-1][1] == rows
    for (_, stop_prev), (start, _) in zip(ranges, ranges[1:]):
        assert start == stop_prev
    shards = [svm_rows_shard(rows, d, seed=seed, process_index=p,
                             process_count=procs) for p in range(procs)]
    for p, ((start, stop), (Xp, yp)) in enumerate(zip(ranges, shards)):
        assert Xp.shape == (stop - start, d) and yp.shape == (stop - start,)
        # deterministic under re-iteration
        Xp2, yp2 = svm_rows_shard(rows, d, seed=seed, process_index=p,
                                  process_count=procs)
        np.testing.assert_array_equal(Xp, Xp2)
        np.testing.assert_array_equal(yp, yp2)
    np.testing.assert_array_equal(
        np.concatenate([X for X, _ in shards]), full_X)
    np.testing.assert_array_equal(
        np.concatenate([y for _, y in shards]), full_y)
