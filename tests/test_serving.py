"""Serving scheduler: wave batching, completion, determinism."""
import jax
import pytest

from repro.configs import get_config
from repro.models.config import smoke_variant
from repro.models.transformer import build_model
from repro.serving import BatchScheduler, Request


@pytest.fixture(scope="module")
def served_model():
    cfg = smoke_variant(get_config("tinyllama-1.1b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_scheduler_completes_all_requests(served_model):
    cfg, model, params = served_model
    sched = BatchScheduler(model, params, batch_size=4, cache_len=96)
    for i in range(10):                      # 10 requests → 3 waves of ≤4
        sched.submit(Request(uid=i, prompt=[1 + i, 2, 3],
                             max_new_tokens=5 + (i % 3)))
    done = sched.run()
    assert len(done) == 10
    for r in done:
        assert 1 <= len(r.output) <= r.max_new_tokens
    rep = sched.throughput_report()
    assert rep["requests"] == 10 and rep["waves"] == 3
    assert rep["tok_per_s"] > 0


def test_scheduler_eos_stops_early(served_model):
    cfg, model, params = served_model
    # discover the model's first greedy token for this prompt, use as EOS
    probe = BatchScheduler(model, params, batch_size=1, cache_len=64)
    probe.submit(Request(uid=0, prompt=[5, 6], max_new_tokens=4))
    first = probe.run()[0].output[0]

    sched = BatchScheduler(model, params, batch_size=1, cache_len=64)
    sched.submit(Request(uid=1, prompt=[5, 6], max_new_tokens=20,
                         eos_id=first))
    done = sched.run()
    assert done[0].output[-1] == first
    assert len(done[0].output) < 20


def test_batched_matches_single(served_model):
    """A request's output must not depend on its batch companions
    (same prompt length ⇒ identical padding/positions)."""
    cfg, model, params = served_model
    solo = BatchScheduler(model, params, batch_size=1, cache_len=64)
    solo.submit(Request(uid=0, prompt=[7, 8, 9], max_new_tokens=6))
    ref = solo.run()[0].output

    duo = BatchScheduler(model, params, batch_size=3, cache_len=64)
    duo.submit(Request(uid=1, prompt=[7, 8, 9], max_new_tokens=6))
    duo.submit(Request(uid=2, prompt=[3, 2, 1], max_new_tokens=6))
    duo.submit(Request(uid=3, prompt=[9, 9, 9], max_new_tokens=6))
    outs = {r.uid: r.output for r in duo.run()}
    assert outs[1] == ref
