"""Sharded-vs-functional MapReduce round equivalence (ISSUE 1 tentpole).

The distributed mode (shard_map over the ``data`` mesh axis, via
repro.compat) must reproduce the functional mode (vmap over a leading
partition axis) bit-for-bit in structure: same per-reducer risks, same
merged global SV buffer.

Runs in-process when ≥8 devices exist (e.g. under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``, see
``make test-dist``); otherwise re-executes itself in a subprocess with
the flag set, since XLA fixes the device count at first backend init.
"""
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

REPO = Path(__file__).resolve().parents[1]
NDEV = 8


def _problem(n=512, d=12):
    X = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    w = jax.random.normal(jax.random.PRNGKey(1), (d,))
    y = jnp.sign(X @ w)
    return X, y, jnp.ones((n,))


def _functional_reference(X, y, mask, cfg, rounds):
    from repro.core.mapreduce_svm import init_sv_buffer, mapreduce_round
    n, d = X.shape
    per = n // NDEV
    Xp = X.reshape(NDEV, per, d)
    yp = y.reshape(NDEV, per)
    mp = mask.reshape(NDEV, per)
    sv = init_sv_buffer(cfg.sv_capacity, d)
    risks = None
    for _ in range(rounds):
        out = mapreduce_round(Xp, yp, mp, sv, cfg)
        sv, risks = out.sv, out.risks
    return sv, risks


def _assert_round_equivalence(mesh_shape, mesh_axes, rounds=3,
                              shuffle_impl="allgather",
                              hier_num_hosts=None):
    from repro import compat
    from repro.core import MRSVMConfig, SVMConfig
    from repro.core.mapreduce_svm import build_sharded_round, init_sv_buffer

    X, y, mask = _problem()
    n, d = X.shape
    # ring/hier: wire dtype = data dtype so the transport is bit-exact
    # and the functional reference stays the strict oracle (the bf16
    # wire is exercised separately with bf16-representable data)
    cfg = MRSVMConfig(sv_capacity=64, svm=SVMConfig(C=1.0, max_epochs=15),
                      shuffle_impl=shuffle_impl,
                      shuffle_wire_dtype="float32",
                      hier_num_hosts=hier_num_hosts)

    mesh = compat.make_mesh(mesh_shape, mesh_axes)
    data_axes = tuple(a for a in mesh_axes if a != "model")
    fn = build_sharded_round(mesh, data_axes, cfg, n // NDEV)
    sv_s = init_sv_buffer(cfg.sv_capacity, d)
    risks_s = None
    for _ in range(rounds):
        sv_s, risks_s, w_s, b_s = fn(X, y, mask, sv_s)

    sv_f, risks_f = _functional_reference(X, y, mask, cfg, rounds)

    # same per-reducer risks (device order == partition order: rows are
    # sharded contiguously over the flattened data axes)
    np.testing.assert_allclose(np.asarray(risks_s), np.asarray(risks_f),
                               rtol=1e-4, atol=1e-5)
    # same merged SV buffer: ids, live count, evidence, feature rows
    np.testing.assert_array_equal(np.asarray(sv_s.ids), np.asarray(sv_f.ids))
    np.testing.assert_array_equal(np.asarray(sv_s.mask), np.asarray(sv_f.mask))
    np.testing.assert_allclose(np.asarray(sv_s.alpha), np.asarray(sv_f.alpha),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(sv_s.x), np.asarray(sv_f.x),
                               rtol=1e-5, atol=1e-6)
    # the selected hypothesis is one of the reducers', replicated
    assert np.asarray(w_s).shape == (d,)
    assert np.asarray(b_s).shape == ()


def _in_subprocess(check_name: str):
    """Re-run one check with 8 faked host devices (own process, since
    the device count is locked at first backend init)."""
    code = (f"import sys; sys.path.insert(0, {str(REPO / 'tests')!r}); "
            f"import test_sharded_round as t; t.{check_name}(); "
            "print('SHARDED_ROUND_OK')")
    from conftest import subprocess_env
    env = subprocess_env(
        XLA_FLAGS="--xla_force_host_platform_device_count=8",
        PYTHONPATH=str(REPO / "src"))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600, env=env)
    assert "SHARDED_ROUND_OK" in r.stdout, r.stdout + r.stderr


def _assert_gram_round_equivalence(gram_impl: str, rounds=2):
    """use_gram=True through build_sharded_round ≡ the functional round
    (ISSUE 2 satellite / ROADMAP: the Gram path — including the Pallas
    kernel — must be exercised under the sharded mode, not only the
    functional one)."""
    from repro import compat
    from repro.core import MRSVMConfig, SVMConfig
    from repro.core.mapreduce_svm import (build_sharded_round,
                                          init_sv_buffer, mapreduce_round)

    X, y, mask = _problem(n=256, d=8)
    n, d = X.shape
    cfg = MRSVMConfig(sv_capacity=32, svm=SVMConfig(
        C=1.0, max_epochs=10, use_gram=True, gram_impl=gram_impl))

    mesh = compat.make_mesh((NDEV,), ("data",))
    fn = build_sharded_round(mesh, ("data",), cfg, n // NDEV)
    sv_s = init_sv_buffer(cfg.sv_capacity, d)
    for _ in range(rounds):
        sv_s, risks_s, w_s, b_s = fn(X, y, mask, sv_s)

    per = n // NDEV
    Xp = X.reshape(NDEV, per, d)
    yp = y.reshape(NDEV, per)
    mp = mask.reshape(NDEV, per)
    sv_f = init_sv_buffer(cfg.sv_capacity, d)
    for _ in range(rounds):
        out = mapreduce_round(Xp, yp, mp, sv_f, cfg)
        sv_f, risks_f = out.sv, out.risks

    np.testing.assert_allclose(np.asarray(risks_s), np.asarray(risks_f),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(sv_s.ids), np.asarray(sv_f.ids))
    np.testing.assert_allclose(np.asarray(sv_s.alpha), np.asarray(sv_f.alpha),
                               rtol=1e-4, atol=1e-5)


def _check_1d():
    _assert_round_equivalence((NDEV,), ("data",))


def _check_pod_2d():
    # multi-axis data sharding: exercises compat.axis_index over a tuple
    _assert_round_equivalence((2, NDEV // 2), ("pod", "data"))


def _check_ring_1d():
    # ISSUE 4 tentpole: the ring-pipelined merge must reproduce the
    # functional round exactly (f32 wire ≡ no quantization)
    _assert_round_equivalence((NDEV,), ("data",), shuffle_impl="ring")


def _check_ring_pod_2d():
    # ring over the flattened ("pod", "data") index — multi-axis ppermute
    _assert_round_equivalence((2, NDEV // 2), ("pod", "data"),
                              shuffle_impl="ring")


def _check_ring_fallback_pod_2d():
    """Old-JAX decomposition path: force single-axis-only ppermute so
    compat.ring_shift rebuilds the flattened ("pod","data") ring from
    the inner shift + wrap-correcting outer shift, and re-run the full
    pod-mesh ring equivalence against the functional oracle — the
    1×1-mesh unit test can't catch a misrouted wrap."""
    import jax.lax as _lax
    orig = _lax.ppermute

    def single_axis_only(x, axis_name, perm):
        if not isinstance(axis_name, str):
            raise TypeError("tuple axis names unsupported (forced)")
        return orig(x, axis_name, perm)

    _lax.ppermute = single_axis_only
    try:
        _assert_round_equivalence((2, NDEV // 2), ("pod", "data"),
                                  shuffle_impl="ring")
    finally:
        _lax.ppermute = orig


def _check_ring_bf16_wire(rounds=3, shuffle_impl="ring",
                          hier_num_hosts=None):
    """The production wire dtype: with bf16-representable rows the wire
    round-trip is lossless, so the packed transport ≡ allgather stays
    strict."""
    import dataclasses as dc

    import jax.numpy as jnp
    from repro import compat
    from repro.core import MRSVMConfig, SVMConfig
    from repro.core.mapreduce_svm import build_sharded_round, init_sv_buffer

    X, y, mask = _problem()
    X = X.astype(jnp.bfloat16).astype(jnp.float32)
    y = jnp.sign(X @ jax.random.normal(jax.random.PRNGKey(1), (X.shape[1],)))
    n, d = X.shape
    cfg_a = MRSVMConfig(sv_capacity=64, svm=SVMConfig(C=1.0, max_epochs=15))
    cfg_r = dc.replace(cfg_a, shuffle_impl=shuffle_impl,   # bf16 wire default
                       hier_num_hosts=hier_num_hosts)
    mesh = compat.make_mesh((NDEV,), ("data",))
    fa = build_sharded_round(mesh, ("data",), cfg_a, n // NDEV)
    fr = build_sharded_round(mesh, ("data",), cfg_r, n // NDEV)
    sv_a = init_sv_buffer(cfg_a.sv_capacity, d)
    sv_r = sv_a._replace(x=sv_a.x.astype(jnp.bfloat16))
    for _ in range(rounds):
        sv_a, risks_a, w_a, b_a = fa(X, y, mask, sv_a)
        sv_r, risks_r, w_r, b_r = fr(X, y, mask, sv_r)
    np.testing.assert_allclose(np.asarray(risks_a), np.asarray(risks_r),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(sv_a.ids), np.asarray(sv_r.ids))
    np.testing.assert_allclose(np.asarray(sv_a.alpha),
                               np.asarray(sv_r.alpha), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(sv_a.x),
                               np.asarray(sv_r.x).astype(np.float32),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(w_a), np.asarray(w_r),
                               rtol=1e-5, atol=1e-6)


def _assert_sparse_round_equivalence(shuffle_impl: str, rounds=3,
                                     n=512, d=64, nnz=8, cap=16,
                                     hier_num_hosts=None):
    """ISSUE 6 tentpole invariant: the blocked-CSR sharded round — SV
    buffer, shuffle wire and all — must reproduce the DENSE functional
    reference at matched data (sparse rows densified for the oracle).
    An f32 wire keeps the transport bit-exact; indices ship bitcast and
    are exact under any wire dtype."""
    import dataclasses as dc

    from repro import compat, sparse
    from repro.core import MRSVMConfig, SVMConfig
    from repro.core.mapreduce_svm import build_sharded_round, init_sv_buffer
    from repro.data import svm_rows

    Xd, y = svm_rows(n, d, seed=3, nnz=nnz)
    Xd, y = jnp.asarray(Xd), jnp.asarray(y)
    mask = jnp.ones((n,))
    Xs = sparse.from_dense(Xd, cap)          # lossless: nnz < cap
    np.testing.assert_array_equal(np.asarray(sparse.to_dense(Xs)),
                                  np.asarray(Xd))

    cfg_d = MRSVMConfig(sv_capacity=64, svm=SVMConfig(C=1.0, max_epochs=15),
                        shuffle_impl=shuffle_impl,
                        shuffle_wire_dtype="float32",
                        hier_num_hosts=hier_num_hosts)
    cfg_s = dc.replace(cfg_d, svm=dc.replace(
        cfg_d.svm, row_format="sparse_csr", nnz_cap=cap))

    mesh = compat.make_mesh((NDEV,), ("data",))
    fn = build_sharded_round(mesh, ("data",), cfg_s, n // NDEV)
    sv_s = init_sv_buffer(cfg_s.sv_capacity, d, nnz_cap=cap)
    risks_s = None
    for _ in range(rounds):
        sv_s, risks_s, w_s, b_s = fn(Xs, y, mask, sv_s)

    sv_f, risks_f = _functional_reference(Xd, y, mask, cfg_d, rounds)

    np.testing.assert_allclose(np.asarray(risks_s), np.asarray(risks_f),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(sv_s.ids), np.asarray(sv_f.ids))
    np.testing.assert_array_equal(np.asarray(sv_s.mask),
                                  np.asarray(sv_f.mask))
    np.testing.assert_allclose(np.asarray(sv_s.alpha), np.asarray(sv_f.alpha),
                               rtol=1e-4, atol=1e-5)
    # the merged buffer stays blocked-CSR end to end; densified it is
    # the dense run's buffer (f32 wire, distinct-index rows)
    assert sparse.is_sparse(sv_s.x) and sv_s.x.nnz_cap == cap
    np.testing.assert_allclose(np.asarray(sparse.to_dense(sv_s.x)),
                               np.asarray(sv_f.x), rtol=1e-5, atol=1e-6)
    assert np.asarray(w_s).shape == (d,)     # hypothesis stays dense


def _assert_sparse_gram_round_equivalence(rounds=2, n=256, d=32,
                                          nnz=4, cap=8):
    """pallas_sparse Gram under the sharded round ≡ the dense xla Gram
    functional reference at matched data."""
    import dataclasses as dc

    from repro import compat, sparse
    from repro.core import MRSVMConfig, SVMConfig
    from repro.core.mapreduce_svm import build_sharded_round, init_sv_buffer
    from repro.data import svm_rows

    Xd, y = svm_rows(n, d, seed=5, nnz=nnz)
    Xd, y = jnp.asarray(Xd), jnp.asarray(y)
    mask = jnp.ones((n,))
    Xs = sparse.from_dense(Xd, cap)

    cfg_d = MRSVMConfig(sv_capacity=32, svm=SVMConfig(
        C=1.0, max_epochs=10, use_gram=True, gram_impl="xla"),
        shuffle_wire_dtype="float32")
    cfg_s = dc.replace(cfg_d, svm=dc.replace(
        cfg_d.svm, gram_impl="pallas_sparse", row_format="sparse_csr",
        nnz_cap=cap))

    mesh = compat.make_mesh((NDEV,), ("data",))
    fn = build_sharded_round(mesh, ("data",), cfg_s, n // NDEV)
    sv_s = init_sv_buffer(cfg_s.sv_capacity, d, nnz_cap=cap)
    risks_s = None
    for _ in range(rounds):
        sv_s, risks_s, w_s, b_s = fn(Xs, y, mask, sv_s)

    sv_f, risks_f = _functional_reference(Xd, y, mask, cfg_d, rounds)

    np.testing.assert_allclose(np.asarray(risks_s), np.asarray(risks_f),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(sv_s.ids), np.asarray(sv_f.ids))
    np.testing.assert_allclose(np.asarray(sv_s.alpha), np.asarray(sv_f.alpha),
                               rtol=1e-4, atol=1e-5)


def _check_hier_1d():
    # ISSUE 10 tentpole: the two-level hier merge (2 simulated hosts ×
    # 4 locals) must reproduce the functional round exactly (f32 wire)
    _assert_round_equivalence((NDEV,), ("data",), shuffle_impl="hier",
                              hier_num_hosts=2)


def _check_hier_pod_2d():
    # hier over the flattened ("pod", "data") index — multi-axis
    # grouped all_gather + slice-exchange ppermute
    _assert_round_equivalence((2, NDEV // 2), ("pod", "data"),
                              shuffle_impl="hier", hier_num_hosts=2)


def _check_hier_bf16_wire():
    _check_ring_bf16_wire(shuffle_impl="hier", hier_num_hosts=2)


def _check_tree_converge():
    """converge_impl="tree" (recursive-doubling readback) ≡ the flat
    psum readback, transport-independent, on 8 devices. Summation
    order differs (log-depth pairwise vs backend reduce) so risks get
    a float tolerance; everything downstream of the argmin-selected
    hypothesis must agree exactly."""
    import dataclasses as dc

    from repro import compat
    from repro.core import MRSVMConfig, SVMConfig
    from repro.core.mapreduce_svm import build_sharded_round, init_sv_buffer

    X, y, mask = _problem()
    n, d = X.shape
    cfg_p = MRSVMConfig(sv_capacity=64, svm=SVMConfig(C=1.0, max_epochs=15),
                        shuffle_impl="hier", hier_num_hosts=2,
                        shuffle_wire_dtype="float32")
    cfg_t = dc.replace(cfg_p, converge_impl="tree")
    mesh = compat.make_mesh((NDEV,), ("data",))
    fp = build_sharded_round(mesh, ("data",), cfg_p, n // NDEV)
    ft = build_sharded_round(mesh, ("data",), cfg_t, n // NDEV)
    sv_p = init_sv_buffer(cfg_p.sv_capacity, d)
    sv_t = init_sv_buffer(cfg_t.sv_capacity, d)
    for _ in range(3):
        sv_p, risks_p, w_p, b_p = fp(X, y, mask, sv_p)
        sv_t, risks_t, w_t, b_t = ft(X, y, mask, sv_t)
    np.testing.assert_allclose(np.asarray(risks_p), np.asarray(risks_t),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_array_equal(np.asarray(sv_p.ids), np.asarray(sv_t.ids))
    np.testing.assert_array_equal(np.asarray(sv_p.x), np.asarray(sv_t.x))
    np.testing.assert_array_equal(np.asarray(w_p), np.asarray(w_t))


def _check_gram_xla():
    _assert_gram_round_equivalence("xla")


def _check_gram_pallas():
    _assert_gram_round_equivalence("pallas")


def _check_sparse_1d():
    _assert_sparse_round_equivalence("allgather")


def _check_sparse_ring_1d():
    _assert_sparse_round_equivalence("ring")


def _check_sparse_hier_1d():
    _assert_sparse_round_equivalence("hier", hier_num_hosts=2)


def _check_sparse_gram_pallas():
    _assert_sparse_gram_round_equivalence()


def test_sharded_round_matches_functional():
    if len(jax.devices()) >= NDEV:
        _check_1d()
    else:
        _in_subprocess("_check_1d")


def test_sharded_round_matches_functional_pod_mesh():
    if len(jax.devices()) >= NDEV:
        _check_pod_2d()
    else:
        _in_subprocess("_check_pod_2d")


def test_sharded_round_gram_path():
    if len(jax.devices()) >= NDEV:
        _check_gram_xla()
    else:
        _in_subprocess("_check_gram_xla")


def test_sharded_round_pallas_gram_path():
    if len(jax.devices()) >= NDEV:
        _check_gram_pallas()
    else:
        _in_subprocess("_check_gram_pallas")


def test_ring_round_matches_functional():
    if len(jax.devices()) >= NDEV:
        _check_ring_1d()
    else:
        _in_subprocess("_check_ring_1d")


def test_ring_round_matches_functional_pod_mesh():
    if len(jax.devices()) >= NDEV:
        _check_ring_pod_2d()
    else:
        _in_subprocess("_check_ring_pod_2d")


def test_ring_round_bf16_wire_matches_allgather():
    if len(jax.devices()) >= NDEV:
        _check_ring_bf16_wire()
    else:
        _in_subprocess("_check_ring_bf16_wire")


def test_hier_round_matches_functional():
    if len(jax.devices()) >= NDEV:
        _check_hier_1d()
    else:
        _in_subprocess("_check_hier_1d")


def test_hier_round_matches_functional_pod_mesh():
    if len(jax.devices()) >= NDEV:
        _check_hier_pod_2d()
    else:
        _in_subprocess("_check_hier_pod_2d")


def test_hier_round_bf16_wire_matches_allgather():
    if len(jax.devices()) >= NDEV:
        _check_hier_bf16_wire()
    else:
        _in_subprocess("_check_hier_bf16_wire")


def test_tree_converge_matches_psum():
    if len(jax.devices()) >= NDEV:
        _check_tree_converge()
    else:
        _in_subprocess("_check_tree_converge")


def test_sparse_hier_round_matches_dense_functional():
    if len(jax.devices()) >= NDEV:
        _check_sparse_hier_1d()
    else:
        _in_subprocess("_check_sparse_hier_1d")


def test_ring_round_single_axis_ppermute_fallback():
    if len(jax.devices()) >= NDEV:
        _check_ring_fallback_pod_2d()
    else:
        _in_subprocess("_check_ring_fallback_pod_2d")


def test_sparse_round_matches_dense_functional():
    if len(jax.devices()) >= NDEV:
        _check_sparse_1d()
    else:
        _in_subprocess("_check_sparse_1d")


def test_sparse_ring_round_matches_dense_functional():
    if len(jax.devices()) >= NDEV:
        _check_sparse_ring_1d()
    else:
        _in_subprocess("_check_sparse_ring_1d")


def test_sparse_pallas_gram_round_matches_dense_functional():
    if len(jax.devices()) >= NDEV:
        _check_sparse_gram_pallas()
    else:
        _in_subprocess("_check_sparse_gram_pallas")
