"""Sharding-rule unit tests (AbstractMesh — no devices needed)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs import get_config
from repro.launch.rules import RULE_SETS, get_rules
from repro.launch.sharding import (batch_pspec, kv_repeat_for, param_pspecs,
                                   pspec_for)
from repro.models.transformer import build_model

MESH = compat.make_abstract_mesh((16, 16), ("data", "model"))
POD_MESH = compat.make_abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def test_vocab_parallel_embedding():
    spec = pspec_for((151936, 4096), ("vocab", "embed"), MESH)
    assert spec == P("model", "data")


def test_divisibility_fallback():
    # 12 heads don't divide 16 → replicated
    spec = pspec_for((1536, 12, 128), ("embed", "heads", "head_dim"), MESH)
    padded = tuple(spec) + (None,) * 3
    assert padded[1] is None
    # but ffn still shards
    spec = pspec_for((1536, 8960), ("embed", "ffn"), MESH)
    assert spec == P("data", "model")


def test_no_axis_reuse_within_tensor():
    # both dims want "model" → second falls through
    spec = pspec_for((1024, 2048), ("vocab", "ffn"), MESH)
    assert tuple(spec).count("model") == 1


def test_experts_fallback_small_expert_count():
    # mixtral: 8 experts < 16 shards → experts replicated, ffn sharded
    spec = pspec_for((8, 6144, 16384), ("experts", "embed", "ffn"), MESH)
    assert spec[0] is None and "model" in tuple(spec)
    # qwen3: 128 experts shard cleanly
    spec = pspec_for((128, 4096, 1536), ("experts", "embed", "ffn"), MESH)
    assert spec[0] == "model"


def test_kv_repeat():
    assert kv_repeat_for(get_config("llama3-8b"), MESH) == 2      # 8→16
    assert kv_repeat_for(get_config("qwen3-moe-235b-a22b"), MESH) == 4  # 4→16
    assert kv_repeat_for(get_config("chatglm3-6b"), MESH) == 8    # 2→16
    assert kv_repeat_for(get_config("zamba2-1.2b"), MESH) == 1    # 32%16==0
    assert kv_repeat_for(get_config("qwen2-1.5b"), MESH) == 1     # H=12: no
    assert kv_repeat_for(get_config("rwkv6-7b"), MESH) == 1       # attn-free


def test_batch_pspec():
    assert batch_pspec(MESH, 256) == P("data")
    assert batch_pspec(POD_MESH, 256) == P(("pod", "data"))
    assert batch_pspec(MESH, 1) == P(None)      # long_500k: replicated


def test_param_pspecs_cover_all_leaves():
    cfg = get_config("mixtral-8x22b")
    model = build_model(cfg)
    specs = param_pspecs(model, MESH)
    leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert all(isinstance(l, P) for l in leaves)
    abstract = jax.tree.leaves(model.abstract())
    assert len(leaves) == len(abstract)
    # every sharded dim divides the mesh axis
    for spec, a in zip(leaves, abstract):
        for dim, ax in zip(a.shape, tuple(spec) + (None,) * 8):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            total = 1
            for x in axes:
                total *= MESH.shape[x]
            assert dim % total == 0, (spec, a.shape)


def test_rule_sets_exist():
    for name in ("baseline", "tp_only", "fsdp_ffn", "expert_first"):
        assert name in RULE_SETS
        get_rules(name)
    with pytest.raises(KeyError):
        get_rules("nope")
