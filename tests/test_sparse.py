"""Blocked-CSR sparse row path (ISSUE 6): format plumbing, featurizer
emission, wire round-trip, and sparse ≡ dense solver equivalence at
matched data. The sharded-mode sparse legs live in
test_sharded_round.py / mp_worker.py; the hypothesis properties in
test_property.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sparse


def _sparse_dense_pair(n=24, d=40, nnz=5, cap=8, seed=0):
    """Matched (SparseRows, dense) rows with DISTINCT in-row indices
    and ≤ cap nonzeros, so from_dense/to_dense round-trips exactly."""
    rng = np.random.default_rng(seed)
    dense = np.zeros((n, d), np.float32)
    for i in range(n):
        cols = rng.choice(d, nnz, replace=False)
        dense[i, cols] = rng.normal(0, 1, nnz)
    Xd = jnp.asarray(dense)
    return sparse.from_dense(Xd, cap), Xd


# ---------------------------------------------------------------------------
# format plumbing
# ---------------------------------------------------------------------------

def test_roundtrip_exact_when_nnz_below_cap():
    Xs, Xd = _sparse_dense_pair()
    np.testing.assert_array_equal(np.asarray(sparse.to_dense(Xs)),
                                  np.asarray(Xd))
    assert Xs.shape == Xd.shape and Xs.dtype == Xd.dtype
    assert Xs.nnz_cap == 8 and Xs.ndim == 2


def test_from_dense_truncates_to_top_magnitude():
    row = jnp.asarray([[0.1, -5.0, 0.0, 2.0, -0.5, 3.0]])
    sp = sparse.from_dense(row, 3)
    back = np.asarray(sparse.to_dense(sp))[0]
    # the 3 largest-|value| entries survive, the rest drop to 0
    np.testing.assert_array_equal(back, [0.0, -5.0, 0.0, 2.0, 0.0, 3.0])


def test_padding_slots_are_index0_value0():
    Xs, _ = _sparse_dense_pair(nnz=3, cap=8)
    idx, val = np.asarray(Xs.indices), np.asarray(Xs.values)
    pad = val == 0
    assert pad.any()
    np.testing.assert_array_equal(idx[pad], 0)


def test_dense_like_surface_matches_dense_semantics():
    Xs, Xd = _sparse_dense_pair(seed=1)
    n, d = Xd.shape
    W = jax.random.normal(jax.random.PRNGKey(0), (d, 3))
    np.testing.assert_allclose(np.asarray(Xs @ W), np.asarray(Xd @ W),
                               rtol=1e-5, atol=1e-6)
    v = jax.random.normal(jax.random.PRNGKey(1), (d,))
    np.testing.assert_allclose(np.asarray(Xs @ v), np.asarray(Xd @ v),
                               rtol=1e-5, atol=1e-6)
    scale = jnp.arange(1.0, n + 1.0)[:, None]
    np.testing.assert_allclose(np.asarray(sparse.to_dense(Xs * scale)),
                               np.asarray(Xd * scale), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(sparse.to_dense(Xs[4:9])),
                                  np.asarray(Xd[4:9]))
    np.testing.assert_allclose(np.asarray(sparse.row_sq_norms(Xs)),
                               np.asarray(jnp.sum(Xd * Xd, axis=1)),
                               rtol=1e-5, atol=1e-6)
    with pytest.raises(ValueError):
        Xs * jnp.ones((n, d))          # feature-wise scale is structural
    with pytest.raises(ValueError):
        Xs.reshape(n, 7)               # last reshape dim must stay d


def test_structural_ops_match_dense():
    Xs, Xd = _sparse_dense_pair(seed=2)
    Ys, Yd = _sparse_dense_pair(seed=3)
    cat = sparse.rows_concat(Xs, Ys, axis=0)
    np.testing.assert_array_equal(
        np.asarray(sparse.to_dense(cat)),
        np.asarray(jnp.concatenate([Xd, Yd], axis=0)))
    pad = sparse.pad_rows(Xs, 5)
    assert pad.shape == (Xd.shape[0] + 5, Xd.shape[1])
    np.testing.assert_array_equal(
        np.asarray(sparse.to_dense(pad))[-5:], 0.0)
    resh = pad.reshape(1, pad.shape[0], Xs.d)
    topi = jnp.asarray([[3, 0, 7]])
    np.testing.assert_array_equal(
        np.asarray(sparse.to_dense(sparse.take_rows_along(resh, topi))),
        np.asarray(jnp.take_along_axis(
            sparse.to_dense(resh), topi[..., None], axis=1)))
    with pytest.raises(TypeError):
        sparse.rows_concat(Xs, Yd)
    with pytest.raises(ValueError):
        sparse.rows_concat(Xs, sparse.from_dense(Yd, 4))   # cap mismatch


def test_cross_dots_all_format_mixes():
    Xs, Xd = _sparse_dense_pair(n=17, seed=4)
    Zs, Zd = _sparse_dense_pair(n=9, seed=5)
    want = np.asarray(Xd @ Zd.T)
    for a, b in ((Xs, Zs), (Xs, Zd), (Xd, Zs), (Xd, Zd)):
        np.testing.assert_allclose(np.asarray(sparse.cross_dots(a, b)),
                                   want, rtol=1e-5, atol=1e-6)


def test_weighted_row_sum_matches_dense():
    Xs, Xd = _sparse_dense_pair(seed=6)
    coef = jax.random.normal(jax.random.PRNGKey(2), (Xd.shape[0],))
    np.testing.assert_allclose(np.asarray(sparse.weighted_row_sum(Xs, coef)),
                               np.asarray(Xd.T @ coef), rtol=1e-5, atol=1e-6)


def test_sparse_rows_is_a_pytree():
    Xs, Xd = _sparse_dense_pair(seed=7)
    leaves, treedef = jax.tree_util.tree_flatten(Xs)
    assert len(leaves) == 2
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    assert back.d == Xs.d
    # jit/vmap compose through the pytree
    f = jax.jit(lambda x, v: x @ v)
    v = jnp.ones((Xs.d,))
    np.testing.assert_allclose(np.asarray(f(Xs, v)), np.asarray(Xd @ v),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# featurizer emission: tokenizer + tfidf never densify
# ---------------------------------------------------------------------------

_DOCS = ["seçim sonuçları bugün açıklandı açıklandı",
         "bugün hava çok güzel",
         "seçim seçim seçim anketi",
         ""]


def test_count_rows_sparse_matches_dense_counts():
    from repro.text.tokenizer import count_matrix, count_rows_sparse, tokenize
    toks = [tokenize(t) for t in _DOCS]
    dense = count_matrix(toks, 64)
    sp = count_rows_sparse(toks, 64, nnz_cap=8)
    np.testing.assert_array_equal(
        np.asarray(sparse.to_dense(jax.tree_util.tree_map(jnp.asarray, sp))),
        dense)
    # distinct in-row indices (the SparseRows contract)
    for row_i, row_v in zip(np.asarray(sp.indices), np.asarray(sp.values)):
        live = row_i[row_v != 0]
        assert len(live) == len(set(live.tolist()))


def test_count_rows_sparse_truncates_to_top_counts():
    from repro.text.tokenizer import count_rows_sparse
    doc = [["a", "a", "a", "b", "b", "c", "d"]]
    sp = count_rows_sparse(doc, 997, nnz_cap=2)
    vals = sorted(np.asarray(sp.values)[0].tolist(), reverse=True)
    assert vals == [3.0, 2.0]          # highest-count terms kept


def test_tfidf_sparse_matches_dense():
    from repro.text import fit_idf, transform
    from repro.text.tokenizer import count_matrix, count_rows_sparse, tokenize
    toks = [tokenize(t) for t in _DOCS]
    dense = jnp.asarray(count_matrix(toks, 64))
    sp = jax.tree_util.tree_map(
        jnp.asarray, count_rows_sparse(toks, 64, nnz_cap=8))
    md, ms = fit_idf(dense), fit_idf(sp)
    np.testing.assert_allclose(np.asarray(md.idf), np.asarray(ms.idf),
                               rtol=1e-6)
    for l2 in (False, True):
        Xd = transform(dense, md, l2_normalize=l2)
        Xs = transform(sp, ms, l2_normalize=l2)
        assert sparse.is_sparse(Xs)
        np.testing.assert_allclose(np.asarray(sparse.to_dense(Xs)),
                                   np.asarray(Xd), rtol=1e-5, atol=1e-6)


def test_tfidf_weighting_cannot_resurrect_zeros():
    """Padding slots carry column id 0 whose SMOOTHED idf is nonzero —
    the guarded transform must keep them exactly 0 (the satellite
    bugfix: an unguarded gather-multiply would densify column 0)."""
    from repro.text import fit_idf, transform
    sp = sparse.SparseRows(
        jnp.asarray([[3, 0, 0], [1, 2, 0]], jnp.int32),
        jnp.asarray([[2.0, 0.0, 0.0], [1.0, 1.0, 0.0]]), 8)
    model = fit_idf(sp)
    assert float(model.idf[0]) > 0.0     # the hazard exists
    out = transform(sp, model, l2_normalize=False)
    np.testing.assert_array_equal(
        np.asarray(out.values == 0), np.asarray(sp.values == 0))
    np.testing.assert_array_equal(np.asarray(out.indices),
                                  np.asarray(sp.indices))


# ---------------------------------------------------------------------------
# generator: blocked-CSR rows straight from the pipeline
# ---------------------------------------------------------------------------

def test_svm_rows_sparse_invariants():
    from repro.data import svm_rows_sparse
    n, d, cap = 300, 512, 16
    Xs, y = svm_rows_sparse(n, d, cap, seed=11)
    assert Xs.shape == (n, d) and y.shape == (n,)
    idx, val = np.asarray(Xs.indices), np.asarray(Xs.values)
    assert idx.min() >= 0 and idx.max() < d
    # distinct in-row indices; L2-normalized rows; labels ±1
    for i in range(n):
        live = idx[i][val[i] != 0]
        assert len(live) == len(set(live.tolist()))
    np.testing.assert_allclose(np.sqrt((val ** 2).sum(1)), 1.0, rtol=1e-5)
    assert set(np.unique(y)) <= {-1.0, 1.0}


def test_svm_rows_sparse_shards_partition_dataset():
    from repro.data import svm_rows_sparse
    n, d, cap, procs = 2100, 256, 8, 3
    full_X, full_y = svm_rows_sparse(n, d, cap, seed=5)
    xi, xv, ys = [], [], []
    for p in range(procs):
        Xp, yp = svm_rows_sparse(n, d, cap, seed=5,
                                 process_index=p, process_count=procs)
        xi.append(np.asarray(Xp.indices))
        xv.append(np.asarray(Xp.values))
        ys.append(yp)
    np.testing.assert_array_equal(np.concatenate(xi), full_X.indices)
    np.testing.assert_array_equal(np.concatenate(xv), full_X.values)
    np.testing.assert_array_equal(np.concatenate(ys), full_y)


def test_svm_rows_dense_density_knob():
    from repro.data import default_row_nnz, svm_rows
    d = 256
    X, _ = svm_rows(64, d, seed=1, nnz=7)
    np.testing.assert_array_equal((np.asarray(X) != 0).sum(1), 7)
    X2, _ = svm_rows(64, d, seed=1)
    np.testing.assert_array_equal((np.asarray(X2) != 0).sum(1),
                                  default_row_nnz(d))


# ---------------------------------------------------------------------------
# wire format: (values-packed + bitcast indices) lanes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("wire", ["float32", "bfloat16"])
def test_sparse_wire_roundtrip(wire):
    from repro.core.mapreduce_svm import pack_wire_rows, unpack_wire_rows
    wire_dt = jnp.dtype(wire)
    Xs, _ = _sparse_dense_pair(n=12, d=50, nnz=4, cap=6, seed=8)
    if wire == "bfloat16":    # bf16-representable values → lossless wire
        Xs = sparse.SparseRows(
            Xs.indices, Xs.values.astype(jnp.bfloat16).astype(jnp.float32),
            Xs.d)
    flat, wslots = pack_wire_rows(Xs, wire_dt)
    assert flat.ndim == 1 and flat.dtype == jnp.float32
    back = unpack_wire_rows(flat, 12, Xs.d, wire_dt, wslots,
                            nnz_cap=Xs.nnz_cap)
    assert sparse.is_sparse(back)
    # indices ship bitcast, NEVER quantized — exact under any wire dtype
    np.testing.assert_array_equal(np.asarray(back.indices),
                                  np.asarray(Xs.indices))
    np.testing.assert_array_equal(
        np.asarray(back.values.astype(jnp.float32)), np.asarray(Xs.values))


def test_sparse_wire_payload_independent_of_d():
    from repro.core.mapreduce_svm import pack_wire_rows
    for d in (1000, 100000):
        Xs, _ = _sparse_dense_pair(n=4, d=d, nnz=4, cap=6, seed=9)
        flat, _ = pack_wire_rows(Xs, jnp.bfloat16)
        assert flat.size == 4 * (3 + 6)     # ceil(cap/2) value lanes + cap


# ---------------------------------------------------------------------------
# solver equivalence at matched data (functional driver)
# ---------------------------------------------------------------------------

def _matched_problem(n=256, d=64, cap=16):
    from repro.data import svm_rows
    Xd, y = svm_rows(n, d, seed=3, nnz=8)
    Xd = jnp.asarray(Xd)
    return sparse.from_dense(Xd, cap), Xd, jnp.asarray(y)


def test_fit_mapreduce_sparse_matches_dense_linear():
    from repro.core import MRSVMConfig, SVMConfig
    from repro.core.mapreduce_svm import decision_values, fit_mapreduce
    Xs, Xd, y = _matched_problem()
    cap = Xs.nnz_cap
    cfg_d = MRSVMConfig(sv_capacity=32, max_rounds=2,
                        svm=SVMConfig(C=1.0, max_epochs=8))
    cfg_s = MRSVMConfig(sv_capacity=32, max_rounds=2,
                        svm=SVMConfig(C=1.0, max_epochs=8,
                                      row_format="sparse_csr", nnz_cap=cap))
    md = fit_mapreduce(Xd, y, 4, cfg_d)
    ms = fit_mapreduce(Xs, y, 4, cfg_s)
    assert sparse.is_sparse(ms.sv.x)
    np.testing.assert_allclose(float(ms.risk), float(md.risk),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(ms.sv.ids),
                                  np.asarray(md.sv.ids))
    # serve-side decision path: dense queries against the sparse model
    q = Xd[:40]
    np.testing.assert_allclose(np.asarray(decision_values(ms, q, cfg_s)),
                               np.asarray(decision_values(md, q, cfg_d)),
                               rtol=1e-4, atol=1e-5)


def test_fit_mapreduce_sparse_matches_dense_rbf_pallas():
    from repro.core import KernelConfig, MRSVMConfig, SVMConfig
    from repro.core.mapreduce_svm import decision_values, fit_mapreduce
    Xs, Xd, y = _matched_problem(n=128)
    cap = Xs.nnz_cap
    kern = KernelConfig(name="rbf")
    cfg_d = MRSVMConfig(sv_capacity=32, max_rounds=2,
                        svm=SVMConfig(C=1.0, max_epochs=8, kernel=kern))
    cfg_s = MRSVMConfig(sv_capacity=32, max_rounds=2,
                        svm=SVMConfig(C=1.0, max_epochs=8, kernel=kern,
                                      row_format="sparse_csr", nnz_cap=cap,
                                      gram_impl="pallas_sparse"))
    md = fit_mapreduce(Xd, y, 4, cfg_d)
    ms = fit_mapreduce(Xs, y, 4, cfg_s)
    np.testing.assert_allclose(float(ms.risk), float(md.risk),
                               rtol=1e-4, atol=1e-5)
    q = Xd[:24]
    np.testing.assert_allclose(np.asarray(decision_values(ms, q, cfg_s)),
                               np.asarray(decision_values(md, q, cfg_d)),
                               rtol=1e-4, atol=1e-4)


def test_svm_config_validates_sparse_fields():
    from repro.core import SVMConfig
    with pytest.raises(ValueError):
        SVMConfig(row_format="sparse_csr")            # nnz_cap missing
    with pytest.raises(ValueError):
        SVMConfig(row_format="csr")                   # unknown format
    with pytest.raises(ValueError):
        SVMConfig(gram_impl="pallas_sparse")          # needs sparse rows
    with pytest.raises(ValueError):
        SVMConfig(gram_impl="pallas", row_format="sparse_csr", nnz_cap=4)
    SVMConfig(row_format="sparse_csr", nnz_cap=4)     # valid
