"""Streaming polarization service (ISSUE 3): wave folding, snapshot
atomicity, multi-tenant batched updates, and the incremental-update
correctness bugfixes."""
import subprocess
import sys
import threading
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (MRSVMConfig, SVMConfig, decision_values,
                        fit_mapreduce, fit_mapreduce_sweep, predict,
                        stack_params, sweep_grid, update_mapreduce)
from repro.core.risk import empirical_risk, zero_one_loss
from repro.serving import StreamingSVMService

REPO = Path(__file__).resolve().parents[1]


def _sep_data(seed, n, d=16, w_key=9):
    w = jax.random.normal(jax.random.PRNGKey(w_key), (d,))
    X = jax.random.normal(jax.random.PRNGKey(seed), (n, d))
    return X, jnp.sign(X @ w)


@pytest.fixture(scope="module")
def stream_cfg():
    return MRSVMConfig(sv_capacity=64, gamma=1e-4, max_rounds=3,
                       svm=SVMConfig(C=1.0, max_epochs=15))


# ---------------------------------------------------------------------------
# (a) sequential folds ≡ one-shot union update
# ---------------------------------------------------------------------------

def test_sequential_folds_match_union_update(stream_cfg):
    """Folding k micro-batches one wave at a time must land on the same
    decision function (tolerance-level: the intermediate SV truncations
    perturb, not redirect) and the same bounded SV capacity as one
    update_mapreduce on the union."""
    cfg = stream_cfg
    X0, y0 = _sep_data(0, 256)
    m0 = fit_mapreduce(X0, y0, 4, cfg)
    batches = [_sep_data(i + 1, 96) for i in range(3)]

    m_seq = m0
    for Xb, yb in batches:
        m_seq = update_mapreduce(m_seq, Xb, yb, 4, cfg)
    Xu = jnp.concatenate([b[0] for b in batches])
    yu = jnp.concatenate([b[1] for b in batches])
    m_one = update_mapreduce(m0, Xu, yu, 4, cfg)

    assert m_seq.sv.x.shape == m_one.sv.x.shape == (cfg.sv_capacity, 16)
    Xt, yt = _sep_data(50, 400)
    dv_seq = np.asarray(decision_values(m_seq, Xt, cfg))
    dv_one = np.asarray(decision_values(m_one, Xt, cfg))
    assert np.corrcoef(dv_seq, dv_one)[0, 1] > 0.97
    assert (np.sign(dv_seq) == np.sign(dv_one)).mean() > 0.93
    acc_seq = float(jnp.mean(predict(m_seq, Xt, cfg) == yt))
    acc_one = float(jnp.mean(predict(m_one, Xt, cfg) == yt))
    assert acc_seq > 0.9 and abs(acc_seq - acc_one) < 0.05


# ---------------------------------------------------------------------------
# (b) drift scenario: stale < folded
# ---------------------------------------------------------------------------

def test_drift_fold_beats_stale_model(stream_cfg):
    cfg = stream_cfg
    X1, y1 = _sep_data(1, 320, w_key=7)
    svc = StreamingSVMService(cfg, num_partitions=4)
    svc.register("tenant", fit_mapreduce(X1, y1, 4, cfg))

    # drifted separator: the old one plus a sizeable rotation (content
    # drifts month-over-month; it doesn't reset)
    w_old = jax.random.normal(jax.random.PRNGKey(7), (16,))
    w_new = w_old + 0.8 * jax.random.normal(jax.random.PRNGKey(8), (16,))
    X2 = jax.random.normal(jax.random.PRNGKey(2), (320, 16))
    y2 = jnp.sign(X2 @ w_new)
    stale = float(jnp.mean(svc.predict("tenant", X2) == y2))
    svc.submit("tenant", X2[:160], y2[:160])
    svc.submit("tenant", X2[160:], y2[160:])
    st = svc.run_wave()
    assert st is not None and st.batches == 2 and st.rows == 320
    folded = float(jnp.mean(svc.predict("tenant", X2) == y2))
    assert folded > 0.8                  # accuracy floor on the new month
    assert folded > stale + 0.05         # folding genuinely adapted
    assert svc.snapshot("tenant").version == 1


# ---------------------------------------------------------------------------
# multi-tenant wave: S streams = S jobs on the sweep's config axis
# ---------------------------------------------------------------------------

def test_batched_wave_matches_per_stream_updates(stream_cfg):
    """A 2-stream wave folds through ONE fit_mapreduce_sweep pass and
    must match each stream's sequential update_mapreduce."""
    cfg = stream_cfg
    svc = StreamingSVMService(cfg, num_partitions=4,
                              max_batches_per_wave=2)
    models = {}
    for s, wk in (("a", 3), ("b", 4)):
        X0, y0 = _sep_data(10 + ord(s), 192, w_key=wk)
        models[s] = fit_mapreduce(X0, y0, 4, cfg)
        svc.register(s, models[s])

    new = {s: _sep_data(20 + ord(s), 128, w_key=wk)
           for s, wk in (("a", 3), ("b", 4))}
    for s, (Xn, yn) in new.items():
        svc.submit(s, Xn, yn)
    st = svc.run_wave()
    assert st.batched and st.streams == 2

    Xt, _ = _sep_data(60, 256)
    for s, (Xn, yn) in new.items():
        ref = update_mapreduce(models[s], Xn, yn, 4, cfg)
        np.testing.assert_allclose(
            np.asarray(svc.decision_values(s, Xt)),
            np.asarray(decision_values(ref, Xt, cfg)),
            rtol=1e-4, atol=1e-4)


def test_sweep_per_job_data_matches_sequential(stream_cfg):
    """The substrate itself: fit_mapreduce_sweep with per-job (X, y,
    mask) must equal per-job fit_mapreduce runs."""
    cfg = stream_cfg
    S, n, d = 3, 128, 12
    Xs, ys, ms = [], [], []
    for s in range(S):
        X, y = _sep_data(30 + s, n, d=d, w_key=s)
        Xs.append(X)
        ys.append(y)
        ms.append(jnp.where(jnp.arange(n) < n - 8 * s, 1.0, 0.0))
    Xb, yb, mb = jnp.stack(Xs), jnp.stack(ys), jnp.stack(ms)
    params = stack_params([cfg.svm.params()] * S)
    res = fit_mapreduce_sweep(Xb, yb, 4, cfg, params, mask=mb)
    for s in range(S):
        ref = fit_mapreduce(Xs[s], ys[s], 4, cfg, mask=ms[s])
        np.testing.assert_allclose(np.asarray(res.risks[s]),
                                   np.asarray(ref.risk),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(res.final.alpha[s]),
                                   np.asarray(ref.final.alpha),
                                   rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# (c) snapshot swap atomicity under interleaved predicts
# ---------------------------------------------------------------------------

def test_snapshot_swap_atomic_under_interleaved_predicts(stream_cfg):
    """Readers racing the async folder must always see predictions
    consistent with EXACTLY one published snapshot version — never a
    half-updated model."""
    cfg = stream_cfg
    X0, y0 = _sep_data(5, 192)
    svc = StreamingSVMService(cfg, num_partitions=4, max_batches_per_wave=1,
                              keep_history=True)
    svc.register("t", fit_mapreduce(X0, y0, 4, cfg))
    Xq, _ = _sep_data(77, 64)

    seen = []
    errors = []
    stop = threading.Event()

    def reader():
        try:
            while not stop.is_set():
                pred, ver = svc.predict("t", Xq, with_version=True)
                seen.append((ver, np.asarray(pred)))
        except Exception as e:                    # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=reader) for _ in range(2)]
    for th in threads:
        th.start()
    svc.start(idle_poll_s=0.005)
    for i in range(3):
        Xb, yb = _sep_data(100 + i, 96)
        svc.submit("t", Xb, yb)
    assert svc.wait_idle(timeout_s=120)
    stop.set()
    svc.stop()
    for th in threads:
        th.join(timeout=30)

    assert not errors
    assert svc.snapshot("t").version == 3
    history = svc.history("t")
    expected = {v: np.asarray(predict(snap.model, Xq, cfg,
                                      params=snap.params))
                for v, snap in history.items()}
    assert len(seen) > 0
    for ver, pred in seen:
        assert ver in expected
        np.testing.assert_array_equal(pred, expected[ver])


# ---------------------------------------------------------------------------
# (d) bugfix regressions
# ---------------------------------------------------------------------------

def test_update_mapreduce_threads_solver_params(stream_cfg):
    """Regression: update_mapreduce used to drop SolverParams — a
    sweep-trained model (traced C) was re-fit with config defaults.
    With params threaded, the update is exactly a fit_mapreduce on
    (new ∪ SVs) at the SAME hyper-params."""
    cfg = stream_cfg
    X0, y0 = _sep_data(6, 256)
    p = cfg.svm.params()._replace(C=jnp.asarray(0.05, jnp.float32))
    m0 = fit_mapreduce(X0, y0, 4, cfg, params=p)
    Xn, yn = _sep_data(7, 128)

    upd = update_mapreduce(m0, Xn, yn, 4, cfg, params=p)
    Xref = jnp.concatenate([Xn, m0.sv.x])
    yref = jnp.concatenate([yn, m0.sv.y])
    mref = jnp.concatenate([jnp.ones((128,)), m0.sv.mask])
    ref = fit_mapreduce(Xref, yref, 4, cfg, mask=mref, params=p)
    np.testing.assert_allclose(np.asarray(upd.final.alpha),
                               np.asarray(ref.final.alpha),
                               rtol=1e-5, atol=1e-6)
    # and the C actually bit: defaults give a different solution
    no_p = fit_mapreduce(Xref, yref, 4, cfg, mask=mref)
    assert not np.allclose(np.asarray(upd.final.alpha),
                           np.asarray(no_p.final.alpha))


def test_sweep_trained_model_roundtrips_without_kernel_drift(stream_cfg):
    """Acceptance: an rbf model selected by a gamma sweep keeps its
    kernel scale through update_mapreduce (the old code re-fit carried
    SVs at the default gamma)."""
    from repro.core import KernelConfig
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(0, 1, (256, 2)).astype(np.float32))
    y = jnp.sign(X[:, 0] * X[:, 1])              # XOR: needs the rbf scale
    cfg = MRSVMConfig(sv_capacity=64, max_rounds=3,
                      svm=SVMConfig(C=10.0, max_epochs=20,
                                    kernel=KernelConfig("rbf", gamma=0.05)))
    params = sweep_grid(cfg.svm, gamma=[0.05, 1.0])
    res = fit_mapreduce_sweep(X, y, 4, cfg, params)
    best = res.best
    assert float(params.gamma[best]) == pytest.approx(1.0)  # sweep picked γ≠default
    p_best = jax.tree_util.tree_map(lambda a: a[best], params)
    m = fit_mapreduce(X, y, 4, cfg, params=p_best)

    Xn = jnp.asarray(rng.normal(0, 1, (128, 2)).astype(np.float32))
    yn = jnp.sign(Xn[:, 0] * Xn[:, 1])
    upd = update_mapreduce(m, Xn, yn, 4, cfg, params=p_best)
    acc = float(jnp.mean(predict(upd, Xn, cfg, params=p_best) == yn))
    assert acc > 0.85                            # γ=0.05 refit can't do this


def test_update_mapreduce_rejects_feature_dim_mismatch(stream_cfg):
    cfg = stream_cfg
    X0, y0 = _sep_data(8, 128)
    m = fit_mapreduce(X0, y0, 4, cfg)
    Xbad = jnp.ones((32, 8))
    with pytest.raises(ValueError, match="featurizer"):
        update_mapreduce(m, Xbad, jnp.ones((32,)), 4, cfg)


def test_scheduler_death_surfaces_instead_of_hanging():
    """A fold error must not kill the background thread silently: the
    service records it, wait_idle raises, stop re-raises."""
    # sv_capacity=36 does not divide 8 partitions → the first wave's
    # mapreduce_round raises inside the scheduler thread.
    bad_cfg = MRSVMConfig(sv_capacity=36, max_rounds=2,
                          svm=SVMConfig(C=1.0, max_epochs=5))
    X0, y0 = _sep_data(9, 128)
    svc = StreamingSVMService(bad_cfg, num_partitions=8)
    svc.register("t", fit_mapreduce(X0, y0, 4, bad_cfg))   # 4 divides 36
    svc.start(idle_poll_s=0.005)
    svc.submit("t", X0, y0)
    with pytest.raises(RuntimeError, match="scheduler died"):
        svc.wait_idle(timeout_s=60)
    assert isinstance(svc.scheduler_error, ValueError)
    with pytest.raises(RuntimeError, match="scheduler died"):
        svc.stop()


def test_service_submit_rejects_feature_dim_mismatch(stream_cfg):
    cfg = stream_cfg
    X0, y0 = _sep_data(8, 128)
    svc = StreamingSVMService(cfg, num_partitions=4)
    svc.register("t", fit_mapreduce(X0, y0, 4, cfg))
    with pytest.raises(ValueError, match="featurizer"):
        svc.submit("t", jnp.ones((16, 9)), jnp.ones((16,)))


def test_zero_one_loss_boundary_matches_predict():
    """Regression: sign(0) counted a boundary score as an error against
    BOTH classes; predict maps 0 → +1, and the loss must agree."""
    scores = jnp.asarray([0.0, 0.0, 2.0, -2.0])
    y = jnp.asarray([1.0, -1.0, 1.0, 1.0])
    loss = np.asarray(zero_one_loss(scores, y))
    np.testing.assert_array_equal(loss, [0.0, 1.0, 0.0, 1.0])
    # eq. 6 risk under 'zero_one' == served error rate of predict_sign
    pred = jnp.where(scores >= 0, 1.0, -1.0)
    served_err = float(jnp.mean((pred != y).astype(jnp.float32)))
    assert float(empirical_risk(scores, y, loss="zero_one")) == \
        pytest.approx(served_err)


def test_scheduler_per_slot_latency(served_model_latency):
    """Regression: every request in a wave used to be stamped with the
    whole-wave wall time; a slot finishing at its own EOS step must
    report a smaller latency than the wave's longest request."""
    model, params = served_model_latency
    from repro.serving import BatchScheduler, Request
    sched = BatchScheduler(model, params, batch_size=2, cache_len=96)
    sched.submit(Request(uid=0, prompt=[1, 2], max_new_tokens=2))
    sched.submit(Request(uid=1, prompt=[3, 4], max_new_tokens=24))
    done = {r.uid: r for r in sched.run()}
    wave = sched.stats[0]
    assert done[0].latency_s < done[1].latency_s
    assert done[1].latency_s <= wave.wall_s + 1e-6
    assert sched.throughput_report()["mean_latency_s"] > 0


@pytest.fixture(scope="module")
def served_model_latency():
    from repro.configs import get_config
    from repro.models.config import smoke_variant
    from repro.models.transformer import build_model
    cfg = smoke_variant(get_config("tinyllama-1.1b"))
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# sharded per-stream-data path (the serve-wave device program)
# ---------------------------------------------------------------------------

_SHARDED_STREAM_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro import compat
from repro.core import (MRSVMConfig, SVMConfig, stack_params,
                        build_sharded_sweep_round, run_sharded_sweep,
                        fit_mapreduce_sweep)

S, n, d = 3, 256, 12
cfg = MRSVMConfig(sv_capacity=64, gamma=1e-4, max_rounds=3,
                  svm=SVMConfig(C=1.0, max_epochs=15))
Xs, ys, ms = [], [], []
for s in range(S):
    X = jax.random.normal(jax.random.PRNGKey(s), (n, d))
    w = jax.random.normal(jax.random.PRNGKey(100 + s), (d,))
    Xs.append(X); ys.append(jnp.sign(X @ w))
    ms.append(jnp.where(jnp.arange(n) < n - 16 * s, 1.0, 0.0))
Xb, yb, mb = jnp.stack(Xs), jnp.stack(ys), jnp.stack(ms)
params = stack_params([cfg.svm.params()] * S)

mesh = compat.make_mesh((8,), ("data",))
fn = build_sharded_sweep_round(mesh, ("data",), cfg, n // 8,
                               per_config_data=True)
sh = run_sharded_sweep(fn, Xb, yb, mb, cfg, params)

fres = fit_mapreduce_sweep(Xb, yb, 8, cfg, params, mask=mb)
np.testing.assert_allclose(np.asarray(sh.risks), np.asarray(fres.risks),
                           rtol=1e-4, atol=1e-5)
np.testing.assert_allclose(np.asarray(sh.ws), np.asarray(fres.ws),
                           rtol=1e-4, atol=1e-5)
np.testing.assert_array_equal(np.asarray(sh.sv.ids), np.asarray(fres.sv.ids))
print("SHARDED_STREAM_OK")
"""


def test_sharded_per_stream_round_matches_functional():
    """per_config_data=True (each stream its own rows/labels/mask,
    sharded over 8 devices) must equal the functional per-job sweep —
    the device program behind launch.steps.build_svm_serve_step."""
    from conftest import subprocess_env
    r = subprocess.run([sys.executable, "-c", _SHARDED_STREAM_SCRIPT],
                       capture_output=True, text=True, timeout=600,
                       env=subprocess_env(PYTHONPATH=str(REPO / "src")))
    assert "SHARDED_STREAM_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_launcher_serve_mode():
    """`repro.launch.serve --arch svm-tfidf` drives the streaming
    service end to end: stale vs folded accuracy per wave."""
    from conftest import subprocess_env
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "svm-tfidf",
         "--smoke", "--streams", "2", "--waves", "2"],
        capture_output=True, text=True, timeout=600, cwd=str(REPO),
        env=subprocess_env(PYTHONPATH=str(REPO / "src")))
    assert r.stdout.count("folded acc=") == 2, r.stdout + r.stderr
    assert "'batches': 4" in r.stdout
