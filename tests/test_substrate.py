"""Substrate tests: optimizer, checkpointing, data pipeline, costs."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.ckpt import latest_step, restore, save
from repro.configs import get_config
from repro.data import DataConfig, lm_batch_at, svm_rows
from repro.launch.costs import forward_flops, step_flops
from repro.launch.steps import INPUT_SHAPES
from repro.models.config import smoke_variant


def test_adamw_converges_quadratic():
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = optim.init(params)
    cfg = optim.OptConfig(lr=0.2, warmup_steps=5, total_steps=200,
                          weight_decay=0.0)
    loss_fn = lambda p: jnp.sum((p["w"] - target) ** 2)
    for _ in range(150):
        grads = jax.grad(loss_fn)(params)
        params, state, _ = optim.apply_updates(params, grads, state, cfg)
    assert float(loss_fn(params)) < 1e-2


def test_grad_clipping():
    g = {"a": jnp.full((4,), 100.0)}
    clipped, norm = optim.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(200.0)
    assert float(optim.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_schedule_warmup_and_decay():
    cfg = optim.OptConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    s = lambda t: float(optim.schedule(cfg, jnp.asarray(t)))
    assert s(5) == pytest.approx(5e-4)
    assert s(10) == pytest.approx(1e-3, rel=1e-2)
    assert s(100) == pytest.approx(cfg.min_lr_ratio * 1e-3, rel=1e-2)
    assert s(55) < s(20)


def test_ckpt_roundtrip_and_meta():
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)}}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "state.npz")
        save(path, tree, step=7)
        out = restore(path, tree)
        assert latest_step(d) == 7
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))


def test_ckpt_shape_mismatch_raises():
    tree = {"a": jnp.ones((2, 2))}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "s.npz")
        save(path, tree)
        with pytest.raises(ValueError):
            restore(path, {"a": jnp.ones((3, 3))})


def test_ckpt_dtype_mismatch_raises():
    """ISSUE 7 bugfix: restore validates dtypes instead of silently
    casting (the bf16 u16-view round trip is the one transparent case)."""
    tree = {"a": jnp.ones((2, 2), jnp.float32),
            "b": jnp.ones((3,), jnp.bfloat16)}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "s.npz")
        save(path, tree)
        out = restore(path, tree)                       # exact: passes
        assert out["b"].dtype == jnp.bfloat16
        with pytest.raises(ValueError, match="dtype mismatch"):
            restore(path, {"a": jnp.ones((2, 2), jnp.int32),
                           "b": tree["b"]})
        with pytest.raises(ValueError, match="dtype mismatch"):
            restore(path, {"a": tree["a"],              # bf16 → f32 drift
                           "b": jnp.ones((3,), jnp.float32)})


def test_ckpt_meta_written_atomically():
    """ISSUE 7 bugfix: the meta pointer goes through tmp + os.replace
    like the npz payload — no in-place write, no stray tmp left."""
    from repro.ckpt.checkpoint import atomic_write_json, latest_path
    with tempfile.TemporaryDirectory() as d:
        save(os.path.join(d, "s0.npz"), {"a": jnp.ones((2,))}, step=0)
        save(os.path.join(d, "s1.npz"), {"a": jnp.ones((2,))}, step=1)
        assert latest_step(d) == 1
        assert latest_path(d) == os.path.join(d, "s1.npz")
        assert not os.path.exists(os.path.join(d, "ckpt_meta.json.tmp"))
        # a leftover torn tmp (crash mid-write) never shadows the meta
        with open(os.path.join(d, "ckpt_meta.json.tmp"), "w") as f:
            f.write('{"latest_step"')
        atomic_write_json(os.path.join(d, "ckpt_meta.json"),
                          {"latest_step": 2, "file": "s1.npz"})
        assert latest_step(d) == 2


def test_data_batches_deterministic_and_resumable():
    cfg = DataConfig(batch_size=4, seq_len=32, seed=9)
    mcfg = smoke_variant(get_config("tinyllama-1.1b"))
    b1 = lm_batch_at(cfg, mcfg, 5)
    b2 = lm_batch_at(cfg, mcfg, 5)     # stateless: same step → same batch
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = lm_batch_at(cfg, mcfg, 6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    assert b1["tokens"].max() < mcfg.vocab_size
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])


def test_svm_rows_signal():
    X, y = svm_rows(200, 512, seed=1)
    assert set(np.unique(y)) <= {-1.0, 1.0}
    norms = np.linalg.norm(X, axis=1)
    np.testing.assert_allclose(norms[norms > 0], 1.0, rtol=1e-5)


def test_analytic_flops_scaling_laws():
    """Sanity: flops scale ~linearly in depth and ~quadratically in seq
    for attention archs."""
    cfg = get_config("llama3-8b")
    f1 = forward_flops(cfg, B=1, S=4096)
    import dataclasses
    cfg2 = dataclasses.replace(cfg, num_layers=cfg.num_layers * 2)
    f2 = forward_flops(cfg2, B=1, S=4096)
    assert 1.8 < f2 / f1 < 2.2
    # train step ≈ 4× forward (bwd + remat)
    tf = step_flops(cfg, INPUT_SHAPES["train_4k"])
    ff = forward_flops(cfg, 256, 4096)
    assert tf == pytest.approx(4.0 * ff)
    # decode flops ≪ prefill flops
    dec = step_flops(cfg, INPUT_SHAPES["decode_32k"])
    pre = step_flops(cfg, INPUT_SHAPES["prefill_32k"])
    assert dec < pre / 100


def test_moe_active_params():
    cfg = get_config("mixtral-8x22b")
    assert cfg.active_param_count() < cfg.param_count() / 2
    dense = get_config("llama3-8b")
    assert dense.active_param_count() == dense.param_count()
