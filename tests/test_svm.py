"""Unit tests: binary soft-margin SVM dual solver (core.svm)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (KernelConfig, SVMConfig, decision_kernel,
                        decision_linear, fit_binary)
from repro.core.svm import fit_binary_kernel


def _separable(n=200, d=10, margin=0.5, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    X = jax.random.normal(k1, (n, d))
    w = jax.random.normal(k2, (d,))
    w = w / jnp.linalg.norm(w)
    y = jnp.sign(X @ w)
    X = X + margin * y[:, None] * w[None, :]   # push classes apart
    return X, y, w


def test_linear_separable_accuracy():
    X, y, _ = _separable()
    m = fit_binary(X, y, cfg=SVMConfig(C=10.0, max_epochs=100))
    acc = jnp.mean(jnp.sign(decision_linear(m.w, m.b, X)) == y)
    assert float(acc) >= 0.99


def test_alpha_box_constraint():
    X, y, _ = _separable(margin=0.0)
    cfg = SVMConfig(C=0.7, max_epochs=50)
    m = fit_binary(X, y, cfg=cfg)
    assert float(jnp.min(m.alpha)) >= -1e-6
    assert float(jnp.max(m.alpha)) <= cfg.C + 1e-6


def test_primal_dual_w_consistency():
    """w must equal Σ α_i y_i x_i (the dual-primal link)."""
    X, y, _ = _separable()
    m = fit_binary(X, y, cfg=SVMConfig(C=1.0, max_epochs=60))
    w_from_alpha = X.T @ (m.alpha * y)
    np.testing.assert_allclose(np.asarray(m.w), np.asarray(w_from_alpha),
                               rtol=1e-4, atol=1e-5)


def test_kkt_complementary_slackness():
    """Margin violations only where α = C; margin ≥ 1 where α = 0."""
    X, y, _ = _separable(margin=0.2)
    cfg = SVMConfig(C=1.0, max_epochs=200, tol=1e-5)
    m = fit_binary(X, y, cfg=cfg)
    f = decision_linear(m.w, m.b, X)
    margins = y * f
    free = (m.alpha > 1e-4) & (m.alpha < cfg.C - 1e-4)
    at_zero = m.alpha <= 1e-4
    # free SVs sit on the margin
    assert float(jnp.max(jnp.abs(margins[free] - 1.0))) < 5e-2 \
        or int(jnp.sum(free)) == 0
    # zero-α points are (nearly) outside the margin
    assert float(jnp.min(jnp.where(at_zero, margins, jnp.inf))) > 1.0 - 5e-2


def test_gram_path_matches_linear_path():
    X, y, _ = _separable(n=120, d=8)
    cfg_l = SVMConfig(C=1.0, max_epochs=80, tol=1e-6)
    cfg_g = SVMConfig(C=1.0, max_epochs=80, tol=1e-6, use_gram=True)
    ml = fit_binary(X, y, cfg=cfg_l)
    mg = fit_binary(X, y, cfg=cfg_g)
    accl = jnp.mean(jnp.sign(X @ ml.w + ml.b) == y)
    accg = jnp.mean(jnp.sign(X @ mg.w + mg.b) == y)
    np.testing.assert_allclose(np.asarray(ml.w), np.asarray(mg.w),
                               rtol=5e-3, atol=5e-3)
    assert float(accl) == pytest.approx(float(accg), abs=0.02)


def test_mask_excludes_padding():
    """Padded rows must not influence the solution at all."""
    X, y, _ = _separable(n=100, d=6)
    pad = jnp.concatenate([X, 100.0 * jnp.ones((20, 6))])
    ypad = jnp.concatenate([y, jnp.ones((20,))])
    mask = jnp.concatenate([jnp.ones((100,)), jnp.zeros((20,))])
    cfg = SVMConfig(C=1.0, max_epochs=60)
    m_clean = fit_binary(X, y, cfg=cfg)
    m_padded = fit_binary(pad, ypad, mask, cfg=cfg)
    np.testing.assert_allclose(np.asarray(m_clean.w),
                               np.asarray(m_padded.w), rtol=1e-5, atol=1e-6)
    assert float(jnp.max(jnp.abs(m_padded.alpha[100:]))) == 0.0


def test_rbf_kernel_nonlinear_separation():
    """XOR-ish data: linear fails, rbf succeeds."""
    rng = np.random.default_rng(0)
    X = rng.normal(0, 1, (240, 2)).astype(np.float32)
    y = np.sign(X[:, 0] * X[:, 1]).astype(np.float32)
    X, y = jnp.asarray(X), jnp.asarray(y)
    lin = fit_binary(X, y, cfg=SVMConfig(C=1.0, max_epochs=60))
    acc_lin = float(jnp.mean(jnp.sign(X @ lin.w + lin.b) == y))
    cfg = SVMConfig(C=10.0, max_epochs=80,
                    kernel=KernelConfig("rbf", gamma=1.0))
    rbf = fit_binary(X, y, cfg=cfg)
    coef = rbf.alpha * y
    scores = decision_kernel(X, coef, rbf.b, X, cfg.kernel)
    acc_rbf = float(jnp.mean(jnp.sign(scores) == y))
    assert acc_rbf > 0.95
    assert acc_rbf > acc_lin + 0.2


def test_pallas_gram_fn_plugs_into_solver():
    from repro.kernels import gram_matrix
    X, y, _ = _separable(n=150, d=16)
    cfg = SVMConfig(C=1.0, max_epochs=60, use_gram=True)
    m_ref = fit_binary_kernel(X, y, None, cfg)
    m_pal = fit_binary_kernel(X, y, None, cfg,
                              gram_fn=lambda a, b: gram_matrix(
                                  a, b, bm=128, bn=128, bk=128))
    np.testing.assert_allclose(np.asarray(m_ref.w), np.asarray(m_pal.w),
                               rtol=1e-3, atol=1e-4)
