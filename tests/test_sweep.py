"""Batched multi-config sweep (ISSUE 2 tentpole): vmap-over-configs
must be a pure batching transform — every config's trajectory identical
to a sequential per-config ``fit_mapreduce`` run with the same
``SolverParams`` slice — and the per-config eq. 8 masking must stop
finished configs without disturbing the rest."""
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.core import (KernelConfig, MRSVMConfig, SVMConfig,
                        fit_mapreduce, fit_mapreduce_sweep,
                        fit_one_vs_rest_sweep, predict, predict_sweep,
                        stack_params, sweep_grid)

REPO = Path(__file__).resolve().parents[1]


def _problem(n=256, d=10, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    X = jax.random.normal(k1, (n, d))
    w = jax.random.normal(k2, (d,))
    y = jnp.sign(X @ w + 0.05)
    return X, y


def test_sweep_grid_shapes():
    cfg = SVMConfig(C=2.0, tol=1e-4)
    p = sweep_grid(cfg, C=[0.1, 1.0, 10.0], gamma=[0.5, 2.0])
    assert p.C.shape == (6,)
    for leaf in p:
        assert leaf.shape == (6,)
    # unspecified axes take the static-shell defaults
    np.testing.assert_allclose(np.asarray(p.tol), 1e-4)
    # C-major ordering (itertools.product convention)
    np.testing.assert_allclose(np.asarray(p.C),
                               [0.1, 0.1, 1.0, 1.0, 10.0, 10.0])
    np.testing.assert_allclose(np.asarray(p.gamma),
                               [0.5, 2.0, 0.5, 2.0, 0.5, 2.0])


def test_stack_params_roundtrip():
    cfgs = [SVMConfig(C=c) for c in (0.1, 1.0, 10.0)]
    p = stack_params([c.params() for c in cfgs])
    np.testing.assert_allclose(np.asarray(p.C), [0.1, 1.0, 10.0])


def test_batched_sweep_matches_sequential_linear():
    """Acceptance: ≥8 configs, batched risks/predictions ≡ sequential."""
    X, y = _problem()
    cfg = MRSVMConfig(sv_capacity=32, gamma=1e-4, max_rounds=3,
                      svm=SVMConfig(C=1.0, max_epochs=10))
    params = sweep_grid(cfg.svm, C=[0.01, 0.1, 1.0, 10.0],
                        tol=[1e-3, 1e-2])
    S = params.C.shape[0]
    assert S == 8
    res = fit_mapreduce_sweep(X, y, 4, cfg, params)
    preds = predict_sweep(res, X, cfg)
    for s in range(S):
        p_s = compat.tree_map(lambda a: a[s], params)
        seq = fit_mapreduce(X, y, 4, cfg, params=p_s)
        np.testing.assert_allclose(float(res.risks[s]), float(seq.risk),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(res.ws[s]), np.asarray(seq.w),
                                   rtol=1e-4, atol=1e-5)
        assert int(res.rounds[s]) == seq.rounds
        seq_pred = predict(seq, X, cfg, params=p_s)
        np.testing.assert_array_equal(np.asarray(preds[s]),
                                      np.asarray(seq_pred))


def test_batched_sweep_matches_sequential_rbf():
    """(C, kernel-scale) sweep on the Gram path — gamma is traced."""
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(0, 1, (192, 2)).astype(np.float32))
    y = jnp.sign(X[:, 0] * X[:, 1])
    cfg = MRSVMConfig(sv_capacity=32, max_rounds=2, gamma=1e-3,
                      svm=SVMConfig(C=10.0, max_epochs=10,
                                    kernel=KernelConfig("rbf", gamma=1.0)))
    params = sweep_grid(cfg.svm, C=[1.0, 10.0], gamma=[0.3, 1.0, 3.0])
    res = fit_mapreduce_sweep(X, y, 4, cfg, params)
    preds = predict_sweep(res, X, cfg)
    for s in range(params.C.shape[0]):
        p_s = compat.tree_map(lambda a: a[s], params)
        seq = fit_mapreduce(X, y, 4, cfg, params=p_s)
        np.testing.assert_allclose(float(res.risks[s]), float(seq.risk),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_array_equal(
            np.asarray(preds[s]), np.asarray(predict(seq, X, cfg,
                                                     params=p_s)))


def test_per_config_eq8_masking():
    """A huge driver γ stops every config at round 2 (eq. 8) and the
    masking records per-config round counts."""
    X, y = _problem(n=128, d=6, seed=2)
    cfg = MRSVMConfig(sv_capacity=32, gamma=1.0, max_rounds=8,
                      svm=SVMConfig(C=1.0, max_epochs=10))
    params = sweep_grid(cfg.svm, C=[0.1, 1.0, 10.0])
    res = fit_mapreduce_sweep(X, y, 4, cfg, params)
    assert (res.rounds == 2).all()


def test_mixed_convergence_does_not_disturb_active_configs():
    """Configs that converge early must freeze while the rest keep the
    exact sequential trajectory."""
    X, y = _problem(n=192, d=8, seed=3)
    # tiny C converges (risk plateaus) sooner than C=1 with tight gamma
    cfg = MRSVMConfig(sv_capacity=32, gamma=5e-3, max_rounds=6,
                      svm=SVMConfig(C=1.0, max_epochs=12))
    params = sweep_grid(cfg.svm, C=[1e-4, 1.0])
    res = fit_mapreduce_sweep(X, y, 4, cfg, params)
    for s in range(2):
        p_s = compat.tree_map(lambda a: a[s], params)
        seq = fit_mapreduce(X, y, 4, cfg, params=p_s)
        assert int(res.rounds[s]) == seq.rounds
        np.testing.assert_allclose(float(res.risks[s]), float(seq.risk),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(res.sv.alpha[s]),
                                   np.asarray(seq.sv.alpha),
                                   rtol=1e-4, atol=1e-5)


def test_ovr_folds_into_batch_axis():
    """k classes × S configs == one k·S-job batch."""
    rng = np.random.default_rng(1)
    y = rng.integers(-1, 2, size=240)
    X = jnp.asarray(rng.normal(0, 1, (240, 8)).astype(np.float32))
    X = X + 2.0 * jnp.asarray(y)[:, None]
    cfg = MRSVMConfig(sv_capacity=64, gamma=1e-4, max_rounds=4,
                      svm=SVMConfig(C=1.0, max_epochs=20))
    params = sweep_grid(cfg.svm, C=[1e-3, 1.0])
    ovr = fit_one_vs_rest_sweep(X, jnp.asarray(y), [-1, 0, 1], 4, cfg,
                                params)
    assert ovr.result.risks.shape == (6,)          # 2 configs × 3 classes
    preds = ovr.predict(X)
    assert preds.shape == (2, 240)
    accs = np.asarray(jnp.mean(preds == jnp.asarray(y)[None, :], axis=1))
    # the sweep-selected config is (near-)best on accuracy too
    assert accs[ovr.best] >= accs.max() - 0.05
    assert accs[ovr.best] > 0.7
    # risk ranking orders the degenerate C below the working one
    assert ovr.risks()[1] < ovr.risks()[0]


def test_pallas_gram_rejects_traced_kernel_sweep():
    """gram_impl='pallas' bakes γ at trace time; a traced rbf sweep over
    it would train on a Gram the scores never saw — must raise, not
    silently select a meaningless winner."""
    from repro.core import fit_binary
    X, y = _problem(n=32, d=4)
    cfg = SVMConfig(C=1.0, max_epochs=2, use_gram=True, gram_impl="pallas",
                    kernel=KernelConfig("rbf", gamma=1.0))
    with pytest.raises(ValueError, match="pallas"):
        fit_binary(X, y, cfg=cfg, params=cfg.params())
    # linear Gram doesn't involve gamma — traced params stay legal
    cfg_lin = SVMConfig(C=1.0, max_epochs=2, use_gram=True,
                        gram_impl="pallas")
    fit_binary(X, y, cfg=cfg_lin, params=cfg_lin.params())
    # and the static (non-sweep) rbf Pallas path stays legal
    fit_binary(X, y, cfg=cfg)


def test_sweep_rejects_ragged_params():
    X, y = _problem(n=64, d=4)
    cfg = MRSVMConfig(sv_capacity=16, max_rounds=1,
                      svm=SVMConfig(max_epochs=2))
    from repro.core import SolverParams
    bad = SolverParams(C=jnp.ones((3,)), tol=jnp.ones((2,)),
                       sv_threshold=jnp.ones((3,)), gamma=jnp.ones((3,)),
                       coef0=jnp.ones((3,)))
    with pytest.raises(ValueError, match="leading"):
        fit_mapreduce_sweep(X, y, 4, cfg, bad)


_SHARDED_SWEEP_SCRIPT = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro import compat
from repro.core import (MRSVMConfig, SVMConfig, sweep_grid,
                        build_sharded_sweep_round, run_sharded_sweep,
                        fit_mapreduce_sweep)

n, d = 512, 12
X = jax.random.normal(jax.random.PRNGKey(0), (n, d))
w = jax.random.normal(jax.random.PRNGKey(1), (d,))
y = jnp.sign(X @ w)
cfg = MRSVMConfig(sv_capacity=64, gamma=1e-4, max_rounds=3,
                  svm=SVMConfig(C=1.0, max_epochs=15))
params = sweep_grid(cfg.svm, C=[0.05, 0.5, 1.0, 5.0], tol=[1e-3, 1e-2])

mesh = compat.make_mesh((8,), ("data",))
fn = build_sharded_sweep_round(mesh, ("data",), cfg, n // 8)
sh = run_sharded_sweep(fn, X, y, None, cfg, params)

fres = fit_mapreduce_sweep(X, y, 8, cfg, params)
np.testing.assert_allclose(np.asarray(sh.risks), np.asarray(fres.risks),
                           rtol=1e-4, atol=1e-5)
np.testing.assert_allclose(np.asarray(sh.ws), np.asarray(fres.ws),
                           rtol=1e-4, atol=1e-5)
np.testing.assert_array_equal(np.asarray(sh.sv.ids), np.asarray(fres.sv.ids))
np.testing.assert_array_equal(sh.rounds, fres.rounds)
assert sh.best == fres.best
print("SHARDED_SWEEP_OK")
"""


def test_sharded_sweep_matches_functional_sweep():
    """vmap-over-configs INSIDE the shard_map round body (8 devices)
    must equal the functional sweep config-for-config."""
    from conftest import subprocess_env
    r = subprocess.run([sys.executable, "-c", _SHARDED_SWEEP_SCRIPT],
                       capture_output=True, text=True, timeout=600,
                       env=subprocess_env(PYTHONPATH=str(REPO / "src")))
    assert "SHARDED_SWEEP_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_launcher_sweep_mode():
    """`repro.launch.train --arch svm-tfidf --sweep S` drives the
    sharded sweep end to end and reports a selected config."""
    from conftest import subprocess_env
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "svm-tfidf",
         "--smoke", "--sweep", "4", "--rounds", "2"],
        capture_output=True, text=True, timeout=600, cwd=str(REPO),
        env=subprocess_env(
            XLA_FLAGS="--xla_force_host_platform_device_count=8",
            PYTHONPATH=str(REPO / "src")))
    assert "sweep selected C=" in r.stdout, r.stdout + r.stderr
    assert r.stdout.count("config C=") == 4
